"""Batched engine: quota edge cases and parity with the single-query paths.

The parity tests pin the refactor's core guarantee: at ``expand_width=1``
the batched engine is bit-exact — same pool ids, distances, scored bitmap
and ``n_calls`` — against (a) the frozen pre-refactor implementation
(``repro.core._legacy_beam``) and (b) the single-query wrapper, on random
graphs, across quotas.

The sharded tests extend this to a **four-way** parity: legacy per-query /
legacy vmap-baseline / batched / device-parallel sharded engine
(``sharded_greedy_search`` over a forced 8-device host mesh, run in a
subprocess so the main test process keeps its single-device view), at
shards ∈ {1, 2, 4} × quota/unbounded, plus an uneven-shard padding edge
case (N not divisible by the device count).

The dedup-backend suite pins the two dedup-state implementations (dense
(B, N) bitmap vs the quota-proportional sorted membership set) bit-exact
against each other — same pool ids/dists, ``n_calls``, ``n_steps`` and
scored set — across quota {1, 17, N} × shards {1, 2, 4} × uneven N, plus
the ``auto`` selection rule and the zero-capacity (quota 0) edge.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import _legacy_beam, beam, distances
from repro.core.beam import (NO_QUOTA, batched_greedy_search, greedy_search)

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _random_graph(seed, n=128, r=6, dim=8, b=5):
    rng = np.random.default_rng(seed)
    adj = rng.integers(0, n, (n, r)).astype(np.int32)
    adj[rng.random((n, r)) < 0.2] = -1  # ragged out-degrees
    emb = rng.normal(size=(n, dim)).astype(np.float32)
    qs = rng.normal(size=(b, dim)).astype(np.float32)
    return jnp.asarray(adj), jnp.asarray(emb), jnp.asarray(qs)


def _line_graph(n):
    adj = np.full((n, 4), -1, np.int32)
    for i in range(n):
        if i > 0:
            adj[i, 0] = i - 1
        if i < n - 1:
            adj[i, 1] = i + 1
    emb = jnp.arange(n, dtype=jnp.float32)[:, None]
    return jnp.asarray(adj), emb


# ---------------------------------------------------------------- edge cases
def test_quota_zero():
    adj, emb = _line_graph(16)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[3.0], [9.0]], jnp.float32)
    res = batched_greedy_search(
        em.dists_batch, adj, qs, jnp.zeros((2, 2), jnp.int32),
        n_points=16, beam_width=4, quota=0, max_steps=50)
    assert (np.asarray(res.n_calls) == 0).all()
    assert not np.asarray(res.scored).any()
    assert (np.asarray(res.pool_ids) == -1).all()
    assert np.isinf(np.asarray(res.pool_dists)).all()


def test_quota_smaller_than_seed_set():
    adj, emb = _line_graph(16)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[8.0]], jnp.float32)
    entries = jnp.arange(10, dtype=jnp.int32)[None, :]
    res = batched_greedy_search(
        em.dists_batch, adj, qs, entries,
        n_points=16, beam_width=6, quota=4, max_steps=100)
    # exactly the first 4 entries get scored, nothing else
    assert int(res.n_calls[0]) == 4
    assert int(res.scored[0].sum()) == 4
    assert set(np.asarray(res.pool_ids[0][:4]).tolist()) == {0, 1, 2, 3}


@pytest.mark.parametrize("expand_width", [1, 3])
def test_quota_exhausted_mid_expansion(expand_width):
    """Quota lands inside a fanout wave: only the first `remaining` fresh
    candidates may be scored, and the accounting stays exact."""
    adj, emb = _line_graph(64)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[63.0]], jnp.float32)
    for quota in (1, 2, 5, 17):
        res = batched_greedy_search(
            em.dists_batch, adj, qs, jnp.zeros((1, 1), jnp.int32),
            n_points=64, beam_width=4, quota=quota,
            expand_width=expand_width, max_steps=500)
        assert int(res.n_calls[0]) <= quota
        # line graph has no duplicate fanout: calls == scored exactly
        assert int(res.scored[0].sum()) == int(res.n_calls[0])


def test_per_query_quotas():
    """A (B,) quota vector freezes each query at its own budget."""
    adj, emb = _line_graph(64)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[63.0], [63.0], [63.0]], jnp.float32)
    quotas = jnp.array([1, 7, 23], jnp.int32)
    res = batched_greedy_search(
        em.dists_batch, adj, qs, jnp.zeros((3, 1), jnp.int32),
        n_points=64, beam_width=4, quota=quotas, max_steps=500)
    assert np.asarray(res.n_calls).tolist() == [1, 7, 23]


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("quota", [NO_QUOTA, 0, 3, 11, 40])
def test_batched_matches_legacy_and_wrapper(quota):
    """Bit-exact three-way parity on random graphs at expand_width=1."""
    adj, emb, qs = _random_graph(seed=quota % 97, n=128, r=6, b=5)
    em = distances.EmbeddingMetric(emb)
    entries = jnp.broadcast_to(jnp.array([0, 64, 100], jnp.int32), (5, 3))

    batched = jax.jit(lambda q: batched_greedy_search(
        em.dists_batch, adj, q, entries, n_points=128,
        beam_width=8, pool_size=16, quota=quota, max_steps=100))(qs)

    for b in range(5):
        legacy = jax.jit(lambda q, b=b: _legacy_beam.greedy_search(
            lambda ids: em.dists(q, ids), adj, entries[b], n_points=128,
            beam_width=8, pool_size=16, quota=quota, max_steps=100))(qs[b])
        single = jax.jit(lambda q, b=b: greedy_search(
            lambda ids: em.dists(q, ids), adj, entries[b], n_points=128,
            beam_width=8, pool_size=16, quota=quota, max_steps=100))(qs[b])
        for res in (legacy, single):
            assert (np.asarray(batched.pool_ids[b])
                    == np.asarray(res.pool_ids)).all()
            np.testing.assert_array_equal(
                np.asarray(batched.pool_dists[b]),
                np.asarray(res.pool_dists))
            assert int(batched.n_calls[b]) == int(res.n_calls)
            assert (np.asarray(batched.scored[b])
                    == np.asarray(res.scored)).all()
        assert int(batched.n_steps[b]) == int(legacy.n_steps)


def test_expand_width_respects_quota_and_order():
    """Wider waves stay budget-exact and keep pools sorted/deduped."""
    adj, emb, qs = _random_graph(seed=7, n=128, r=6, b=4)
    em = distances.EmbeddingMetric(emb)
    entries = jnp.zeros((4, 1), jnp.int32)
    for e in (2, 4, 8):
        res = batched_greedy_search(
            em.dists_batch, adj, qs, entries, n_points=128,
            beam_width=8, pool_size=16, quota=30, expand_width=e,
            max_steps=100)
        calls = np.asarray(res.n_calls)
        assert (calls <= 30).all()
        d = np.asarray(res.pool_dists)
        ids = np.asarray(res.pool_ids)
        for b in range(4):
            fin = d[b][np.isfinite(d[b])]
            assert (np.diff(fin) >= 0).all()
            valid = ids[b][ids[b] >= 0]
            assert len(valid) == len(set(valid.tolist()))
            # every pool entry was paid for, and (waves are deduped at
            # E > 1) every call scored exactly one distinct vertex
            assert np.asarray(res.scored[b])[valid].all()
            assert int(np.asarray(res.scored[b]).sum()) == int(calls[b])


# ----------------------------------------------------- dedup-backend parity
@pytest.mark.parametrize("n", [130, 97])
@pytest.mark.parametrize("quota_kind", ["one", "mid", "full"])
def test_dedup_backend_parity(n, quota_kind):
    """bitmap vs sorted are bit-exact: pool ids/dists, n_calls, n_steps and
    the scored set, at quota ∈ {1, 17, N} on uneven-N random graphs."""
    quota = {"one": 1, "mid": 17, "full": n}[quota_kind]
    adj, emb, qs = _random_graph(seed=n + quota, n=n)
    em = distances.EmbeddingMetric(emb)
    entries = jnp.broadcast_to(jnp.array([0, n // 2, n - 1], jnp.int32),
                               (5, 3))
    kw = dict(n_points=n, beam_width=8, pool_size=16, quota=quota,
              max_steps=200)
    bm = batched_greedy_search(em.dists_batch, adj, qs, entries,
                               dedup="bitmap", **kw)
    ss = batched_greedy_search(em.dists_batch, adj, qs, entries,
                               dedup="sorted", **kw)
    for name, a, b in zip(bm._fields, bm, ss):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (name, quota)
    assert ss.scored.shape == (5, n)  # materialized, backend-independent
    assert (np.asarray(ss.n_calls) <= quota).all()


def test_dedup_auto_selection():
    """host-driven auto -> sorted iff the quota bound is static and < N;
    fused-loop auto keeps the aliased bitmap; explicit backends are
    honored; undersized capacities are rejected."""
    # fused while_loop drive: the bitmap carry aliases, auto keeps it
    assert beam.resolve_dedup(
        "auto", None, 17, 128, drive="fused") == ("bitmap", None)
    assert beam.resolve_dedup(
        "sorted", None, 17, 128, drive="fused") == ("sorted", 17)
    # host-driven (dispatch-per-step) drive: quota-bounded -> sorted
    assert beam.resolve_dedup("auto", None, 17, 128) == ("sorted", 17)
    assert beam.resolve_dedup("auto", None, np.int64(17), 128) == (
        "sorted", 17)
    assert beam.resolve_dedup(
        "auto", None, np.array([3, 9, 17]), 128) == ("sorted", 17)
    assert beam.resolve_dedup("auto", None, NO_QUOTA, 128) == ("bitmap", None)
    assert beam.resolve_dedup("auto", None, 128, 128) == ("bitmap", None)
    assert beam.resolve_dedup("bitmap", None, 17, 128) == ("bitmap", None)
    assert beam.resolve_dedup("sorted", None, 128, 128) == ("sorted", 128)
    # a continued bitmap forces the bitmap backend
    assert beam.resolve_dedup(
        "auto", None, 17, 128, jnp.zeros((1, 128), bool)) == ("bitmap", None)
    with pytest.raises(ValueError):
        beam.resolve_dedup("sorted", 8, 17, 128)  # capacity < quota bound

    # a traced quota has no static bound: auto falls back to the bitmap
    picked = []

    def probe(q):
        picked.append(beam.resolve_dedup("auto", None, q, 128))
        return q

    jax.jit(probe)(jnp.asarray(17))
    assert picked == [("bitmap", None)]


def test_dedup_zero_capacity():
    """quota 0 rides the sorted backend as a genuine zero-capacity set
    (admission's padded wave rows) — no crash, no calls, empty pools."""
    adj, emb = _line_graph(16)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[3.0], [9.0]], jnp.float32)
    res = batched_greedy_search(
        em.dists_batch, adj, qs, jnp.zeros((2, 2), jnp.int32),
        n_points=16, beam_width=4, quota=0, max_steps=50, dedup="sorted")
    assert (np.asarray(res.n_calls) == 0).all()
    assert not np.asarray(res.scored).any()
    assert (np.asarray(res.pool_ids) == -1).all()
    # the raw set ops degrade to no-ops at capacity 0
    from repro.kernels import ops
    empty = beam.empty_scored_set(2, 0)
    assert not np.asarray(
        ops.sorted_set_lookup(empty.ids, jnp.zeros((2, 3), jnp.int32))).any()
    assert ops.sorted_set_merge(
        empty.ids, jnp.zeros((2, 3), jnp.int32)).shape == (2, 0)
    assert (np.asarray(ops.sorted_set_unique_count(empty.ids)) == 0).all()


def test_dedup_mixed_quota_waves():
    """A (B,) quota vector through the sorted backend: capacity is the max
    quota, each row freezes at its own budget — bit-exact vs bitmap."""
    adj, emb = _line_graph(64)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[63.0], [63.0], [63.0]], jnp.float32)
    quotas = jnp.array([0, 7, 23], jnp.int32)  # quota-0 padding row included
    kw = dict(n_points=64, beam_width=4, max_steps=500, quota=quotas)
    bm = batched_greedy_search(
        em.dists_batch, adj, qs, jnp.zeros((3, 1), jnp.int32),
        dedup="bitmap", **kw)
    ss = batched_greedy_search(
        em.dists_batch, adj, qs, jnp.zeros((3, 1), jnp.int32),
        dedup="sorted", **kw)
    for name, a, b in zip(bm._fields, bm, ss):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name
    assert np.asarray(ss.n_calls).tolist() == [0, 7, 23]
    # the set's occupancy invariant: count tracks insertions (== n_calls
    # here) and never exceeds the static capacity
    state, safe, keep = beam.init_state(
        jnp.zeros((3, 1), jnp.int32), n_points=64, pool_size=8,
        quota=quotas, dedup="sorted", set_capacity=23)
    assert np.array_equal(np.asarray(state.scored.count),
                          np.asarray(state.n_calls))
    assert (np.asarray(state.scored.count) <= 23).all()


# ----------------------------------------------------------- sharded parity
def _run_sharded(body: str) -> str:
    """Run a snippet on 8 forced host devices in a clean subprocess."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.core import _legacy_beam, distances
        from repro.core.beam import (NO_QUOTA, batched_greedy_search,
                                     sharded_greedy_search)

        def random_graph(seed, n, r=6, dim=8, b=5):
            rng = np.random.default_rng(seed)
            adj = rng.integers(0, n, (n, r)).astype(np.int32)
            adj[rng.random((n, r)) < 0.2] = -1
            emb = rng.normal(size=(n, dim)).astype(np.float32)
            qs = rng.normal(size=(b, dim)).astype(np.float32)
            return jnp.asarray(adj), jnp.asarray(emb), jnp.asarray(qs)

        def assert_same(a, b, ctx):
            for name, x, y in zip(a._fields, a, b):
                assert np.array_equal(np.asarray(x), np.asarray(y)), \\
                    (ctx, name)
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=ROOT, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_four_way_parity():
    """legacy per-query / legacy vmap / batched / sharded at {1, 2, 4} are
    bit-exact on pool ids/dists, n_calls, n_steps and the scored bitmap."""
    out = _run_sharded("""
        adj, emb, qs = random_graph(seed=3, n=128)
        em = distances.EmbeddingMetric(emb)
        entries = jnp.broadcast_to(jnp.array([0, 64, 100], jnp.int32), (5, 3))

        for QUOTA in (NO_QUOTA, 13):
            def legacy_one(q, quota=QUOTA):
                return _legacy_beam.greedy_search(
                    lambda ids: em.dists(q, ids), adj, entries[0],
                    n_points=128, beam_width=8, pool_size=16, quota=quota,
                    max_steps=100)
            batched = jax.jit(lambda q: batched_greedy_search(
                em.dists_batch, adj, q, entries, n_points=128, beam_width=8,
                pool_size=16, quota=QUOTA, max_steps=100))(qs)
            vmapped = jax.jit(jax.vmap(legacy_one))(qs)
            assert_same(batched, type(batched)(*vmapped), ("vmap", QUOTA))
            for b in range(5):
                assert_same(
                    type(batched)(*(np.asarray(f)[b] for f in batched)),
                    jax.jit(legacy_one)(qs[b]), ("legacy", QUOTA, b))
            for shards in (1, 2, 4):
                res = sharded_greedy_search(
                    emb, adj, qs, entries, shards=shards, metric="l2",
                    beam_width=8, pool_size=16, quota=QUOTA, max_steps=100)
                assert_same(batched, res, ("sharded", QUOTA, shards))
        print("FOUR_WAY_OK")
    """)
    assert "FOUR_WAY_OK" in out


@pytest.mark.slow
def test_sharded_uneven_and_quota_matrix():
    """shards ∈ {1, 2, 4} × quota/unbounded on corpora whose size does NOT
    divide the shard count (zero-row padding must never be scored)."""
    out = _run_sharded("""
        for n in (130, 97):
            adj, emb, qs = random_graph(seed=n, n=n)
            em = distances.EmbeddingMetric(emb)
            entries = jnp.broadcast_to(
                jnp.array([0, n // 2, n - 1], jnp.int32), (5, 3))
            for quota in (NO_QUOTA, 19):
                base = batched_greedy_search(
                    em.dists_batch, adj, qs, entries, n_points=n,
                    beam_width=8, pool_size=16, quota=quota, max_steps=100)
                assert base.scored.shape == (5, n)
                for shards in (1, 2, 4):
                    res = sharded_greedy_search(
                        emb, adj, qs, entries, shards=shards, metric="l2",
                        beam_width=8, pool_size=16, quota=quota,
                        max_steps=100)
                    assert res.scored.shape == (5, n)
                    assert_same(base, res, (n, quota, shards))
        print("UNEVEN_OK")
    """)
    assert "UNEVEN_OK" in out


@pytest.mark.slow
def test_sharded_plumb_through_vamana_and_bimetric():
    """The shards= knob on vamana.search / bimetric_search is bit-exact vs
    the default single-device path (expand_width > 1 included)."""
    out = _run_sharded("""
        from repro.core import bimetric, vamana
        from repro.data.synthetic import make_dataset
        data = make_dataset(n=160, n_queries=6, dim_D=16, dim_d=8,
                            noise=0.1, seed=5)
        cfg = vamana.VamanaConfig(max_degree=8, l_build=12, pool_size=24,
                                  rev_candidates=8, build_batch=64)
        idx = vamana.build(data.corpus_d, cfg)
        for e in (1, 2):
            ids0, dd0, c0 = vamana.search(
                idx, data.corpus_d, data.queries_d, k=5, beam_width=12,
                expand_width=e)
            ids4, dd4, c4 = vamana.search(
                idx, data.corpus_d, data.queries_d, k=5, beam_width=12,
                expand_width=e, shards=4)
            assert np.array_equal(np.asarray(ids0), np.asarray(ids4))
            assert np.array_equal(np.asarray(dd0), np.asarray(dd4))
            assert np.array_equal(np.asarray(c0), np.asarray(c4))
        em_d = distances.EmbeddingMetric(data.corpus_d)
        em_D = distances.EmbeddingMetric(data.corpus_D)
        base = bimetric.bimetric_search(
            lambda q, i: em_d.dists(q, i), lambda q, i: em_D.dists(q, i),
            idx, data.queries_d, data.queries_D, n_points=160, quota=48, k=5)
        sh = bimetric.bimetric_search(
            None, None, idx, data.queries_d, data.queries_D, n_points=160,
            quota=48, k=5, shards=4,
            corpora=(data.corpus_d, data.corpus_D))
        for a, b in zip(base, sh):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("PLUMB_OK")
    """)
    assert "PLUMB_OK" in out


@pytest.mark.slow
def test_sharded_dedup_backend_parity():
    """bitmap vs sorted dedup under the mesh engine: quota {1, 17, N} ×
    shards {1, 2, 4} × uneven N {130, 97} all bit-exact vs the unsharded
    bitmap reference (the sorted set rides replicated, the bitmap
    column-sharded — same answers either way), incl. exact scored-set
    equality and n_calls. Also pins the ShardedStepper's sorted drive
    (stage-2 shape) and its distinct-count vs the bitmap partition count."""
    out = _run_sharded("""
        from repro.core.beam import ShardedStepper

        for n in (130, 97):
            adj, emb, qs = random_graph(seed=n, n=n)
            em = distances.EmbeddingMetric(emb)
            entries = jnp.broadcast_to(
                jnp.array([0, n // 2, n - 1], jnp.int32), (5, 3))
            for quota in (1, 17, n):
                base = batched_greedy_search(
                    em.dists_batch, adj, qs, entries, n_points=n,
                    beam_width=8, pool_size=16, quota=quota, max_steps=200,
                    dedup="bitmap")
                for dedup in ("bitmap", "sorted"):
                    for shards in (1, 2, 4):
                        res = sharded_greedy_search(
                            emb, adj, qs, entries, shards=shards,
                            metric="l2", beam_width=8, pool_size=16,
                            quota=quota, max_steps=200, dedup=dedup)
                        assert_same(base, res, (n, quota, dedup, shards))

        # ShardedStepper: sorted vs bitmap host-driven drive, bit-exact
        n = 97
        adj, emb, qs = random_graph(seed=n, n=n, b=3)
        em = distances.EmbeddingMetric(emb)
        seeds = jnp.broadcast_to(jnp.array([0, 40, 90], jnp.int32), (3, 3))
        quota = jnp.array([6, 15, 11], jnp.int32)
        L = jnp.full((3,), 8, jnp.int32)
        ms = jnp.full((3,), 60, jnp.int32)

        def drive(shards, dedup, cap):
            st = ShardedStepper(shards=shards, n_points=n)
            state, safe, keep = st.init(
                seeds, quota, pool_size=16, dedup=dedup, set_capacity=cap)
            while True:
                d = em.dists_batch(qs, safe)
                state = st.commit(state, safe, keep, d)
                if not st.active_any(state, quota, L, ms):
                    break
                state, safe, keep, _ = st.plan(state, adj, quota, L, ms)
            return state, np.asarray(st.scored_count(state))

        ref, ref_count = drive(2, "bitmap", None)
        for shards in (2, 4):
            got, got_count = drive(shards, "sorted", 16)
            for name in ("pool_ids", "pool_dists", "n_calls", "n_steps"):
                assert np.array_equal(
                    np.asarray(getattr(ref, name)),
                    np.asarray(getattr(got, name))), (shards, name)
            # replication-invariant distinct count == partition-invariant
            # bitmap popcount
            assert np.array_equal(ref_count, got_count), (shards, got_count)
        print("DEDUP_SHARDED_OK")
    """)
    assert "DEDUP_SHARDED_OK" in out
