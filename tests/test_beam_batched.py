"""Batched engine: quota edge cases and parity with the single-query paths.

The parity tests pin the refactor's core guarantee: at ``expand_width=1``
the batched engine is bit-exact — same pool ids, distances, scored bitmap
and ``n_calls`` — against (a) the frozen pre-refactor implementation
(``repro.core._legacy_beam``) and (b) the single-query wrapper, on random
graphs, across quotas.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import _legacy_beam, distances
from repro.core.beam import (NO_QUOTA, batched_greedy_search, greedy_search)


def _random_graph(seed, n=128, r=6, dim=8, b=5):
    rng = np.random.default_rng(seed)
    adj = rng.integers(0, n, (n, r)).astype(np.int32)
    adj[rng.random((n, r)) < 0.2] = -1  # ragged out-degrees
    emb = rng.normal(size=(n, dim)).astype(np.float32)
    qs = rng.normal(size=(b, dim)).astype(np.float32)
    return jnp.asarray(adj), jnp.asarray(emb), jnp.asarray(qs)


def _line_graph(n):
    adj = np.full((n, 4), -1, np.int32)
    for i in range(n):
        if i > 0:
            adj[i, 0] = i - 1
        if i < n - 1:
            adj[i, 1] = i + 1
    emb = jnp.arange(n, dtype=jnp.float32)[:, None]
    return jnp.asarray(adj), emb


# ---------------------------------------------------------------- edge cases
def test_quota_zero():
    adj, emb = _line_graph(16)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[3.0], [9.0]], jnp.float32)
    res = batched_greedy_search(
        em.dists_batch, adj, qs, jnp.zeros((2, 2), jnp.int32),
        n_points=16, beam_width=4, quota=0, max_steps=50)
    assert (np.asarray(res.n_calls) == 0).all()
    assert not np.asarray(res.scored).any()
    assert (np.asarray(res.pool_ids) == -1).all()
    assert np.isinf(np.asarray(res.pool_dists)).all()


def test_quota_smaller_than_seed_set():
    adj, emb = _line_graph(16)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[8.0]], jnp.float32)
    entries = jnp.arange(10, dtype=jnp.int32)[None, :]
    res = batched_greedy_search(
        em.dists_batch, adj, qs, entries,
        n_points=16, beam_width=6, quota=4, max_steps=100)
    # exactly the first 4 entries get scored, nothing else
    assert int(res.n_calls[0]) == 4
    assert int(res.scored[0].sum()) == 4
    assert set(np.asarray(res.pool_ids[0][:4]).tolist()) == {0, 1, 2, 3}


@pytest.mark.parametrize("expand_width", [1, 3])
def test_quota_exhausted_mid_expansion(expand_width):
    """Quota lands inside a fanout wave: only the first `remaining` fresh
    candidates may be scored, and the accounting stays exact."""
    adj, emb = _line_graph(64)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[63.0]], jnp.float32)
    for quota in (1, 2, 5, 17):
        res = batched_greedy_search(
            em.dists_batch, adj, qs, jnp.zeros((1, 1), jnp.int32),
            n_points=64, beam_width=4, quota=quota,
            expand_width=expand_width, max_steps=500)
        assert int(res.n_calls[0]) <= quota
        # line graph has no duplicate fanout: calls == scored exactly
        assert int(res.scored[0].sum()) == int(res.n_calls[0])


def test_per_query_quotas():
    """A (B,) quota vector freezes each query at its own budget."""
    adj, emb = _line_graph(64)
    em = distances.EmbeddingMetric(emb)
    qs = jnp.array([[63.0], [63.0], [63.0]], jnp.float32)
    quotas = jnp.array([1, 7, 23], jnp.int32)
    res = batched_greedy_search(
        em.dists_batch, adj, qs, jnp.zeros((3, 1), jnp.int32),
        n_points=64, beam_width=4, quota=quotas, max_steps=500)
    assert np.asarray(res.n_calls).tolist() == [1, 7, 23]


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("quota", [NO_QUOTA, 0, 3, 11, 40])
def test_batched_matches_legacy_and_wrapper(quota):
    """Bit-exact three-way parity on random graphs at expand_width=1."""
    adj, emb, qs = _random_graph(seed=quota % 97, n=128, r=6, b=5)
    em = distances.EmbeddingMetric(emb)
    entries = jnp.broadcast_to(jnp.array([0, 64, 100], jnp.int32), (5, 3))

    batched = jax.jit(lambda q: batched_greedy_search(
        em.dists_batch, adj, q, entries, n_points=128,
        beam_width=8, pool_size=16, quota=quota, max_steps=100))(qs)

    for b in range(5):
        legacy = jax.jit(lambda q: _legacy_beam.greedy_search(
            lambda ids: em.dists(q, ids), adj, entries[b], n_points=128,
            beam_width=8, pool_size=16, quota=quota, max_steps=100))(qs[b])
        single = jax.jit(lambda q: greedy_search(
            lambda ids: em.dists(q, ids), adj, entries[b], n_points=128,
            beam_width=8, pool_size=16, quota=quota, max_steps=100))(qs[b])
        for res in (legacy, single):
            assert (np.asarray(batched.pool_ids[b])
                    == np.asarray(res.pool_ids)).all()
            np.testing.assert_array_equal(
                np.asarray(batched.pool_dists[b]),
                np.asarray(res.pool_dists))
            assert int(batched.n_calls[b]) == int(res.n_calls)
            assert (np.asarray(batched.scored[b])
                    == np.asarray(res.scored)).all()
        assert int(batched.n_steps[b]) == int(legacy.n_steps)


def test_expand_width_respects_quota_and_order():
    """Wider waves stay budget-exact and keep pools sorted/deduped."""
    adj, emb, qs = _random_graph(seed=7, n=128, r=6, b=4)
    em = distances.EmbeddingMetric(emb)
    entries = jnp.zeros((4, 1), jnp.int32)
    for e in (2, 4, 8):
        res = batched_greedy_search(
            em.dists_batch, adj, qs, entries, n_points=128,
            beam_width=8, pool_size=16, quota=30, expand_width=e,
            max_steps=100)
        calls = np.asarray(res.n_calls)
        assert (calls <= 30).all()
        d = np.asarray(res.pool_dists)
        ids = np.asarray(res.pool_ids)
        for b in range(4):
            fin = d[b][np.isfinite(d[b])]
            assert (np.diff(fin) >= 0).all()
            valid = ids[b][ids[b] >= 0]
            assert len(valid) == len(set(valid.tolist()))
            # every pool entry was paid for, and (waves are deduped at
            # E > 1) every call scored exactly one distinct vertex
            assert np.asarray(res.scored[b])[valid].all()
            assert int(np.asarray(res.scored[b]).sum()) == int(calls[b])
