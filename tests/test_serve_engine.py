"""Serving engine: exact budget, batch-vs-single parity, cache accounting.

Uses deliberately tiny towers/corpus so the whole file stays test-suite
cheap while still exercising the real path: tower embed -> cheap-only index
build -> batched stage 1 on device -> host-driven stage 2 draining the
expensive tower in batches.
"""
import jax
import numpy as np
import pytest

from repro.configs import qwen3_0_6b
from repro.models import transformer as T
from repro.serve import BiMetricEngine, EmbedTower


@pytest.fixture(scope="module")
def engine_parts():
    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = T.TransformerConfig(
        name="exp-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=cheap_cfg.vocab, embed_dim=32)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(
        T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
    corpus = np.random.default_rng(0).integers(
        0, cheap_cfg.vocab, (96, 10), dtype=np.int32)
    return cheap, expensive, corpus


def _fresh_engine(engine_parts):
    cheap, expensive, corpus = engine_parts
    return BiMetricEngine(cheap, expensive, corpus)


def test_quota_exact_and_batch_single_parity(engine_parts):
    eng = _fresh_engine(engine_parts)
    qs = eng.corpus_tokens[[3, 40, 77]].copy()
    ids_b, dd_b, stats_b = eng.query_batch(qs, quota=15, k=5)
    assert ids_b.shape == (3, 5)
    assert all(s.D_calls <= 15 for s in stats_b)

    # per-query accounting parity: a fresh engine, one query at a time
    eng2 = _fresh_engine(engine_parts)
    for i in range(3):
        ids1, dd1, s1 = eng2.query(qs[i], quota=15, k=5)
        ok = (ids_b[i] >= 0) & np.isfinite(dd_b[i])
        assert (ids1 == ids_b[i][ok]).all()
        np.testing.assert_allclose(dd1, dd_b[i][ok], rtol=1e-5)
        assert s1.D_calls == stats_b[i].D_calls


def test_cache_saves_tower_batches_not_accounting(engine_parts):
    eng = _fresh_engine(engine_parts)
    q = eng.corpus_tokens[7]
    ids1, dd1, s1 = eng.query(q, quota=12, k=5)
    ids2, dd2, s2 = eng.query(q, quota=12, k=5)
    assert (ids1 == ids2).all()
    np.testing.assert_array_equal(dd1, dd2)
    assert s1.D_calls == s2.D_calls  # budget accounting is cache-blind
    assert s2.tower_batches == 0  # but the tower is not re-run
    assert s1.tower_batches > 0


def test_quota_zero_spends_nothing(engine_parts):
    eng = _fresh_engine(engine_parts)
    ids, dd, st = eng.query(eng.corpus_tokens[0], quota=0, k=5)
    assert ids.size == 0 and st.D_calls == 0 and st.tower_batches == 0


def test_rerank_exact_budget(engine_parts):
    eng = _fresh_engine(engine_parts)
    ids, dd, st = eng.rerank_query(eng.corpus_tokens[11], quota=16, k=5)
    assert st.D_calls <= 16
    assert (np.diff(dd) >= 0).all()


def test_dedup_backends_bit_exact(engine_parts):
    """Stage 2 on the sorted (quota-proportional) dedup state answers
    exactly what the bitmap state answers, mixed quotas included."""
    cheap, expensive, corpus = engine_parts
    qs = corpus[[3, 40, 77]].copy()
    quotas = np.array([4, 15, 9], np.int32)
    results = {}
    for dedup in ("bitmap", "sorted", "auto"):
        eng = BiMetricEngine(cheap, expensive, corpus, dedup=dedup)
        results[dedup] = eng.query_batch(qs, quota=quotas, k=5)
    ids_ref, dd_ref, st_ref = results["bitmap"]
    for dedup in ("sorted", "auto"):
        ids, dd, st = results[dedup]
        assert np.array_equal(ids, ids_ref), dedup
        np.testing.assert_array_equal(dd, dd_ref)
        assert [s.D_calls for s in st] == [s.D_calls for s in st_ref]
    assert [s.D_calls for s in st_ref] == [4, 15, 9]


def test_dedup_capacity_rounding_bounds_retraces(engine_parts):
    """The wave capacity is the max quota rounded up to a power of two —
    quota-0 padding rows never raise it, distinct quotas inside one bucket
    share one trace, and an all-quota-0 wave gets a zero-capacity set."""
    from repro.serve.engine import _round_capacity
    assert _round_capacity(0) == 0
    assert _round_capacity(1) == 1
    assert _round_capacity(5) == 8
    assert _round_capacity(8) == 8
    assert _round_capacity(9) == 16
    cheap, expensive, corpus = engine_parts
    eng = BiMetricEngine(cheap, expensive, corpus, dedup="sorted")
    # mixed wave incl. a quota-0 row (the padded-row shape) and a
    # same-bucket wave: both run the sorted backend, answers match solo runs
    ids_m, dd_m, st_m = eng.query_batch(
        corpus[[3, 40, 77]].copy(),
        quota=np.array([0, 12, 9], np.int32), k=5)
    assert st_m[0].D_calls == 0 and (ids_m[0] == -1).all()
    solo = BiMetricEngine(cheap, expensive, corpus, dedup="sorted")
    for i, q in ((1, 12), (2, 9)):
        ids1, dd1, s1 = solo.query(corpus[[3, 40, 77][i]], quota=q, k=5)
        ok = (ids_m[i] >= 0) & np.isfinite(dd_m[i])
        assert np.array_equal(ids1, ids_m[i][ok])
        assert s1.D_calls == st_m[i].D_calls
