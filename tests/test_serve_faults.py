"""Chaos suite: the serving engine's failure-semantics contract.

Deterministic fault injection (``repro.serve.faults.FaultPlan``, seeded
per-site streams) drives the tower lane through transient faults, hangs,
persistent outages and interrupts, pinning the contract documented in
``repro/serve``'s "Failure semantics":

* transient drain faults at 10% -> every request resolves **bit-exact**
  vs the fault-free run (bounded retry + the doc cache's write-after-
  success idempotence), at shards {1, 2, 4};
* a given-up tower call fails only the affected requests
  (``TowerFailure`` chaining the injected fault) or degrades them to the
  stage-1 proxy ranking, per ``on_tower_failure`` — the engine is never
  poisoned and keeps serving afterwards;
* the circuit breaker opens on consecutive failures, half-open probes
  re-close it after the tower heals;
* ``deadline_ms`` fires queued, at admission pop, and **mid-flight**
  (including inside a hung tower drain), leaving non-expired co-resident
  slots bit-exact;
* ``close(timeout=)`` raises on a stuck drive thread instead of
  returning silently, and a submit-vs-close race never strands a future.
"""
import concurrent.futures as cf
import os
import subprocess
import sys
import textwrap
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import qwen3_0_6b
from repro.core import beam, distances
from repro.models import transformer as T
from repro.serve import (AdmissionFailed, BiMetricEngine, DeadlineExceeded,
                         EmbedTower, EngineFailure, FaultPlan, FaultSpec,
                         InjectedFault, SearchRequest, TowerFailure)
from repro.serve.faults import CircuitBreaker

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def engine_parts():
    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = T.TransformerConfig(
        name="exp-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=cheap_cfg.vocab, embed_dim=32)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(
        T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
    corpus = np.random.default_rng(0).integers(
        0, cheap_cfg.vocab, (96, 10), dtype=np.int32)
    return cheap, expensive, corpus


def _reqs(corpus, rows=(3, 40, 77, 12, 55, 9, 61), quota=15, k=5, **kw):
    return [SearchRequest(tokens=corpus[r], quota=quota, k=k, **kw)
            for r in rows]


def _wait_for(pred, timeout=60.0, what="condition"):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if pred():
            return
        time.sleep(0.005)
    raise AssertionError(f"timed out waiting for {what}")


# ----------------------------------------------------------- fault plan unit
def test_fault_plan_deterministic_and_healable():
    a = FaultPlan(seed=7, drain=FaultSpec(rate=0.4))
    b = FaultPlan(seed=7, drain=FaultSpec(rate=0.4))

    def trace(plan, n=40):
        out = []
        for _ in range(n):
            try:
                plan.fire("drain")
                out.append(0)
            except InjectedFault:
                out.append(1)
        return out

    ta, tb = trace(a), trace(b)
    assert ta == tb and sum(ta) > 0  # seeded: identical across instances
    # a transient firing is followed by a forced success (retry recovers)
    for i, hit in enumerate(ta[:-1]):
        if hit:
            assert ta[i + 1] == 0
    # unconfigured sites never fault; unknown sites are rejected up front
    a.fire("embed_queries")
    with pytest.raises(ValueError):
        FaultPlan(drain=FaultSpec(), bogus=FaultSpec())
    # persistent trips forever until healed
    p = FaultPlan(seed=1, drain=FaultSpec(rate=1.0, mode="persistent"))
    for _ in range(3):
        with pytest.raises(InjectedFault):
            p.fire("drain")
    assert p.fired("drain") == 1 and p.calls("drain") == 3
    p.heal()  # the outage ended: the site is disarmed for good
    for _ in range(3):
        p.fire("drain")


def test_circuit_breaker_states():
    t = [0.0]
    br = CircuitBreaker(threshold=3, cooldown_s=10.0, clock=lambda: t[0])
    assert br.state == "closed" and not br.blocked()
    br.on_failure(); br.on_failure()
    assert br.state == "closed"
    br.on_failure()
    assert br.state == "open" and br.blocked() and br.opens == 1
    t[0] = 11.0
    assert br.state == "half_open" and not br.blocked()  # probe allowed
    br.on_failure()  # failed probe re-arms the cooldown, no new "open"
    assert br.blocked() and br.opens == 1
    t[0] = 22.0
    br.on_success()
    assert br.state == "closed" and br.failures == 0


# ------------------------------------------------------- beam.early_resolve
def test_early_resolve_closes_rows_only():
    rng = np.random.default_rng(3)
    corpus = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    adj = jnp.asarray(rng.integers(0, 64, (64, 6)), jnp.int32)
    em = distances.EmbeddingMetric(corpus)
    q = jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32))
    entries = jnp.asarray([[1, 5, 9]] * 4, jnp.int32)
    quota = jnp.asarray([12, 12, 12, 12], jnp.int32)
    state, safe, keep = beam.init_state(
        entries, n_points=64, pool_size=8, quota=quota, dedup="bitmap")
    state = beam.commit_scores(state, safe, keep, em.dists_batch(q, safe))
    assert bool(beam.active_mask(state, beam_width=8, quota=quota,
                                 max_steps=40).all())
    rows = jnp.asarray([False, True, False, True])
    closed = beam.early_resolve(state, rows)
    act = np.asarray(beam.active_mask(closed, beam_width=8, quota=quota,
                                      max_steps=40))
    np.testing.assert_array_equal(act, [True, False, True, False])
    # non-masked rows untouched bit-for-bit, masked rows keep their pools
    for leaf_new, leaf_old in zip(closed, state):
        np.testing.assert_array_equal(np.asarray(leaf_new)[[0, 2]],
                                      np.asarray(leaf_old)[[0, 2]])
    np.testing.assert_array_equal(np.asarray(closed.pool_ids),
                                  np.asarray(state.pool_ids))


# --------------------------------------------------------- transient chaos
def test_transient_drain_faults_bit_exact(engine_parts):
    """10% transient drain faults: every request resolves bit-exact vs the
    fault-free run, retries are counted, the engine is never poisoned."""
    cheap, expensive, corpus = engine_parts
    ref = BiMetricEngine(cheap, expensive, corpus).query_batch(_reqs(corpus))
    plan = FaultPlan(seed=11, drain=FaultSpec(rate=0.10),
                     embed_queries=FaultSpec(rate=0.10))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=3, faults=plan,
                         retry_backoff_ms=1.0)
    futs = [eng.submit(r) for r in _reqs(corpus)]
    for i, f in enumerate(futs):
        got = f.result(timeout=300)
        assert np.array_equal(got.ids, ref[i].ids), i
        np.testing.assert_array_equal(got.dists, ref[i].dists)
        assert got.stats.D_calls == ref[i].stats.D_calls, i
        assert not got.stats.degraded
    c = eng.counters()
    assert c.completed == len(futs) and c.degraded == 0
    fired = plan.fired("drain") + plan.fired("embed_queries")
    assert fired > 0 and c.retries >= fired and c.tower_failures >= fired
    assert eng.health()["breaker_state"] == "closed"
    eng.close()


def test_persistent_drain_fail_policy_isolates(engine_parts):
    """Persistent drain outage under on_tower_failure='fail': affected
    requests fail with TowerFailure chaining the injected fault; after the
    tower heals (and the cooldown passes) the engine serves bit-exact."""
    cheap, expensive, corpus = engine_parts
    plan = FaultPlan(seed=2, drain=FaultSpec(rate=1.0, mode="persistent"))
    # threshold=1: successful query embeds between failed drains reset the
    # *consecutive* count, so a drain-only outage opens at the first failure
    eng = BiMetricEngine(cheap, expensive, corpus, slots=2, faults=plan,
                         retry_backoff_ms=1.0, breaker_threshold=1,
                         breaker_cooldown_ms=50.0)
    futs = [eng.submit(r) for r in _reqs(corpus, rows=(3, 40, 77))]
    errs = []
    for f in futs:
        with pytest.raises(TowerFailure) as ei:
            f.result(timeout=300)
        errs.append(ei.value)
    assert any(isinstance(e.__cause__, InjectedFault)
               or isinstance(getattr(e.__cause__, "__cause__", None),
                             InjectedFault) for e in errs)
    assert eng.counters().breaker_opens >= 1
    plan.heal()
    time.sleep(0.1)  # past the cooldown: next tower call is the probe
    ref = BiMetricEngine(cheap, expensive, corpus).query(
        SearchRequest(tokens=corpus[12], quota=15, k=5))
    got = eng.submit(SearchRequest(tokens=corpus[12], quota=15, k=5)
                     ).result(timeout=300)
    assert np.array_equal(got.ids, ref.ids) and not got.stats.degraded
    assert eng.health()["breaker_state"] == "closed"
    eng.close()


def test_persistent_drain_degrade_policy(engine_parts):
    """Persistent drain outage under on_tower_failure='degrade': every
    request resolves with stage-1 proxy results marked degraded=True; the
    breaker opens and open-circuit admissions short-circuit proxy-only;
    after heal the engine serves full-quality again."""
    cheap, expensive, corpus = engine_parts
    plan = FaultPlan(seed=2, drain=FaultSpec(rate=1.0, mode="persistent"),
                     embed_queries=FaultSpec(rate=1.0, mode="persistent"))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=2, faults=plan,
                         on_tower_failure="degrade", retry_backoff_ms=1.0,
                         breaker_threshold=2, breaker_cooldown_ms=200.0)
    futs = [eng.submit(r) for r in _reqs(corpus)]
    for f in futs:
        got = f.result(timeout=300)
        assert got.stats.degraded
        assert got.ids.size > 0 and got.ids.size <= 5
        assert np.all((got.ids >= 0) & (got.ids < corpus.shape[0]))
        assert np.all(np.diff(got.dists) >= 0)  # proxy-ranked ascending
    c = eng.counters()
    assert c.degraded == len(futs) and c.completed == len(futs)
    assert c.breaker_opens >= 1
    h = eng.health()
    assert h["degraded_mode"] and h["breaker_state"] in ("open", "half_open")
    plan.heal()
    time.sleep(0.25)
    ref = BiMetricEngine(cheap, expensive, corpus).query(
        SearchRequest(tokens=corpus[12], quota=15, k=5))
    got = eng.submit(SearchRequest(tokens=corpus[12], quota=15, k=5)
                     ).result(timeout=300)
    assert not got.stats.degraded and np.array_equal(got.ids, ref.ids)
    eng.close()


def test_cheap_embed_failure_fails_group_only(engine_parts):
    """A cheap-tower failure while staging a group fails that group with
    AdmissionFailed (cause attached) — and the engine keeps serving."""
    cheap, expensive, corpus = engine_parts
    plan = FaultPlan(seed=0,
                     cheap_embed=FaultSpec(rate=1.0, mode="persistent"))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=2, faults=plan)
    f = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5))
    with pytest.raises(AdmissionFailed) as ei:
        f.result(timeout=300)
    assert isinstance(ei.value.__cause__, InjectedFault)
    assert eng.counters().shed >= 1
    plan.heal()
    got = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5)
                     ).result(timeout=300)
    ref = BiMetricEngine(cheap, expensive, corpus).query(
        SearchRequest(tokens=corpus[3], quota=12, k=5))
    assert np.array_equal(got.ids, ref.ids)
    eng.close()


def test_embed_queries_failure_degrades_group(engine_parts):
    """An expensive query-embed outage under 'degrade' resolves the staged
    group proxy-only (no slot residency, D_calls == 0)."""
    cheap, expensive, corpus = engine_parts
    plan = FaultPlan(
        seed=0, embed_queries=FaultSpec(rate=1.0, mode="persistent"))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=2, faults=plan,
                         on_tower_failure="degrade", retry_backoff_ms=1.0)
    got = eng.submit(SearchRequest(tokens=corpus[40], quota=12, k=5)
                     ).result(timeout=300)
    assert got.stats.degraded and got.stats.D_calls == 0
    assert got.ids.size > 0
    eng.close()


# ------------------------------------------------------------------ deadlines
def test_queued_expiry_stays_deadline_exceeded(engine_parts):
    """Queued expiry is DeadlineExceeded even under 'degrade' — a request
    that never ran has no proxy ranking to degrade to."""
    cheap, expensive, corpus = engine_parts
    plan = FaultPlan(seed=0, drain=FaultSpec(rate=1.0, mode="hang",
                                             hang_s=0.4))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=1, faults=plan,
                         on_tower_failure="degrade")
    fa = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5))
    _wait_for(lambda: eng.counters().queue_depth == 0
              and eng.counters().submitted == 1, what="A popped")
    fb = eng.submit(SearchRequest(tokens=corpus[40], quota=8, k=5,
                                  deadline_ms=30.0))
    with pytest.raises(DeadlineExceeded):
        fb.result(timeout=300)
    fa.result(timeout=300)
    assert eng.counters().deadline_misses == 1
    eng.close()


def test_midflight_deadline_degrades_during_hung_drain(engine_parts):
    """A deadline that expires while a tower drain hangs resolves the slot
    mid-flight with its proxy ranking (degraded=True), *before* the drain
    returns; the co-resident deadline-free slot is bit-exact vs the
    fault-free run."""
    cheap, expensive, corpus = engine_parts
    ref = BiMetricEngine(cheap, expensive, corpus).query(
        SearchRequest(tokens=corpus[3], quota=24, k=5))
    # entry drain (call 0) clean; every later drain hangs 0.5 s
    plan = FaultPlan(seed=0, drain=FaultSpec(rate=1.0, mode="hang",
                                             hang_s=0.5, after=1))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=2, faults=plan,
                         on_tower_failure="degrade")
    fa = eng.submit(SearchRequest(tokens=corpus[3], quota=24, k=5))
    fb = eng.submit(SearchRequest(tokens=corpus[40], quota=24, k=5,
                                  deadline_ms=120.0))
    t0 = time.monotonic()
    rb = fb.result(timeout=300)
    tb = time.monotonic() - t0
    assert rb.stats.degraded and rb.ids.size > 0
    ra = fa.result(timeout=300)
    assert not ra.stats.degraded
    assert np.array_equal(ra.ids, ref.ids)
    np.testing.assert_array_equal(ra.dists, ref.dists)
    assert ra.stats.D_calls == ref.stats.D_calls
    c = eng.counters()
    assert c.deadline_misses >= 1 and c.degraded >= 1
    # B resolved from inside a hung drain, not after the full search
    assert tb < 60.0
    eng.close()


def test_midflight_deadline_fail_policy(engine_parts):
    """Same mid-flight expiry under 'fail': DeadlineExceeded, co-resident
    slot still bit-exact."""
    cheap, expensive, corpus = engine_parts
    ref = BiMetricEngine(cheap, expensive, corpus).query(
        SearchRequest(tokens=corpus[3], quota=24, k=5))
    plan = FaultPlan(seed=0, drain=FaultSpec(rate=1.0, mode="hang",
                                             hang_s=0.5, after=1))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=2, faults=plan,
                         on_tower_failure="fail")
    fa = eng.submit(SearchRequest(tokens=corpus[3], quota=24, k=5))
    fb = eng.submit(SearchRequest(tokens=corpus[40], quota=24, k=5,
                                  deadline_ms=120.0))
    with pytest.raises(DeadlineExceeded):
        fb.result(timeout=300)
    ra = fa.result(timeout=300)
    assert np.array_equal(ra.ids, ref.ids)
    assert eng.counters().deadline_misses >= 1
    eng.close()


def test_deadline_priority_refill_order(engine_parts):
    """Deadline x priority in the refill heap: at equal priority the
    sooner deadline admits first into a freed slot; results match the
    fault-free solo runs and no miss is counted."""
    cheap, expensive, corpus = engine_parts
    plan = FaultPlan(seed=0, drain=FaultSpec(rate=1.0, mode="hang",
                                             hang_s=0.25))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=1, faults=plan)
    order: list[str] = []
    fa = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5))
    _wait_for(lambda: eng.counters().queue_depth == 0
              and eng.counters().submitted == 1, what="A popped")
    fb = eng.submit(SearchRequest(tokens=corpus[40], quota=8, k=5,
                                  deadline_ms=60_000.0))
    fc = eng.submit(SearchRequest(tokens=corpus[77], quota=8, k=5,
                                  deadline_ms=30_000.0))
    fb.add_done_callback(lambda f: order.append("B"))
    fc.add_done_callback(lambda f: order.append("C"))
    rb, rc = fb.result(timeout=300), fc.result(timeout=300)
    fa.result(timeout=300)
    eng.close()
    assert order == ["C", "B"]  # sooner deadline refilled the slot first
    solo = BiMetricEngine(cheap, expensive, corpus)
    sb = solo.query(SearchRequest(tokens=corpus[40], quota=8, k=5))
    sc = solo.query(SearchRequest(tokens=corpus[77], quota=8, k=5))
    assert np.array_equal(rb.ids, sb.ids)
    assert np.array_equal(rc.ids, sc.ids)
    assert eng.counters().deadline_misses == 0


def test_drain_timeout_gives_up_without_retry(engine_parts):
    """A drain hung past drain_timeout_ms becomes TowerTimeout -> the
    resident request fails (policy 'fail') while the lane finishes the
    hung call in the background; the engine serves afterwards."""
    cheap, expensive, corpus = engine_parts
    plan = FaultPlan(seed=0, drain=FaultSpec(rate=1.0, mode="hang",
                                             hang_s=0.8))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=1, faults=plan,
                         drain_timeout_ms=150.0)
    f = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5))
    with pytest.raises(TowerFailure):  # TowerTimeout is a TowerFailure
        f.result(timeout=300)
    assert eng.counters().retries == 0  # timeouts are never retried inline
    plan.heal()
    time.sleep(1.0)  # the hung call finishes in the lane's background
    got = eng.submit(SearchRequest(tokens=corpus[40], quota=12, k=5)
                     ).result(timeout=300)
    ref = BiMetricEngine(cheap, expensive, corpus).query(
        SearchRequest(tokens=corpus[40], quota=12, k=5))
    assert np.array_equal(got.ids, ref.ids)
    eng.close()


# ------------------------------------------------------- interrupts + close
@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_keyboard_interrupt_reraised_not_served(engine_parts):
    """An injected KeyboardInterrupt in the tower lane fails the resident
    futures (EngineFailure chaining the interrupt) and kills both loops —
    it is never swallowed into a served answer."""
    cheap, expensive, corpus = engine_parts
    plan = FaultPlan(seed=0, drain=FaultSpec(rate=1.0, mode="persistent",
                                             exc=KeyboardInterrupt))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=1, faults=plan)
    f = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5))
    with pytest.raises(EngineFailure) as ei:
        f.result(timeout=300)
    assert isinstance(ei.value.__cause__, KeyboardInterrupt)
    _wait_for(lambda: all(not t.is_alive() for t in eng._threads),
              what="loops honored the interrupt")
    eng.close(timeout=5.0)  # threads already dead: join is immediate


class _GatedTower:
    """Expensive-tower wrapper whose forward passes block on an Event."""

    def __init__(self, inner: EmbedTower):
        self.inner = inner
        self.gate = threading.Event()
        self.gate.set()

    def embed(self, tokens, batch: int = 64):
        assert self.gate.wait(120), "gate never released"
        return self.inner.embed(tokens, batch)


def test_close_raises_on_stuck_drive(engine_parts):
    """close(timeout=) with the drive thread wedged inside a tower call
    raises instead of returning silently with a live thread."""
    cheap, expensive, corpus = engine_parts
    gated = _GatedTower(expensive)
    eng = BiMetricEngine(cheap, gated, corpus, slots=1)
    gated.gate.clear()
    f = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5))
    _wait_for(lambda: eng.counters().queue_depth == 0
              and eng.counters().submitted == 1, what="A popped")
    with pytest.raises(RuntimeError, match="failed to join"):
        eng.close(timeout=0.3)
    gated.gate.set()
    f.result(timeout=300)
    _wait_for(lambda: all(not t.is_alive() for t in eng._threads),
              what="threads drained after release")
    eng.close()  # idempotent second close: immediate no-op


def test_concurrent_submit_close_stress(engine_parts):
    """Multi-threaded submit racing close(): every future either resolves,
    is cancelled, or the submit itself raised (pool closed) — nothing
    hangs, nothing is silently dropped."""
    cheap, expensive, corpus = engine_parts
    eng = BiMetricEngine(cheap, expensive, corpus, slots=2)
    futs: list = []
    mu = threading.Lock()
    rejected = [0]
    stop = threading.Event()

    def pump(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            try:
                f = eng.submit(SearchRequest(
                    tokens=corpus[int(rng.integers(0, corpus.shape[0]))],
                    quota=6, k=3))
                with mu:
                    futs.append(f)
            except RuntimeError:
                rejected[0] += 1
                return
            time.sleep(0.001)

    threads = [threading.Thread(target=pump, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    _wait_for(lambda: len(futs) >= 8, what="submissions flowing")
    eng.close()
    stop.set()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive()
    resolved = cancelled = 0
    for f in futs:
        try:
            r = f.result(timeout=120)
            assert r.stats.D_calls >= 0
            resolved += 1
        except cf.CancelledError:
            cancelled += 1
    assert resolved + cancelled == len(futs)
    c = eng.counters()
    assert c.completed == resolved and c.cancelled == cancelled
    with pytest.raises(RuntimeError):
        eng.submit(SearchRequest(tokens=corpus[0], quota=5))


# -------------------------------------------------------------------- sharded
def _run(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=ROOT, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_chaos_parity():
    """shards in {1, 2, 4} with 10% transient drain faults: every request
    resolves bit-exact vs the fault-free unsharded reference (retry
    recovery is invisible at any shard count), and the fault stream
    actually fired."""
    out = _run("""
        from repro.configs import qwen3_0_6b
        from repro.models import transformer as T
        from repro.serve import (BiMetricEngine, EmbedTower, FaultPlan,
                                 FaultSpec, SearchRequest)
        key = jax.random.PRNGKey(0)
        cheap_cfg = qwen3_0_6b.smoke()
        exp_cfg = T.TransformerConfig(
            name="exp-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=4, head_dim=16, d_ff=128, vocab=cheap_cfg.vocab,
            embed_dim=32)
        cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
        expensive = EmbedTower(
            T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
        corpus = np.random.default_rng(0).integers(
            0, cheap_cfg.vocab, (97, 10), dtype=np.int32)
        rows = [3, 40, 77, 12, 55]
        quotas = [6, 15, 9, 11, 15]
        reqs = [SearchRequest(tokens=corpus[r], quota=q, k=5)
                for r, q in zip(rows, quotas)]
        base = BiMetricEngine(cheap, expensive, corpus)
        ref = base.query_batch(reqs)
        fired_total = 0
        for s in (1, 2, 4):
            plan = FaultPlan(seed=13, drain=FaultSpec(rate=0.10))
            eng = BiMetricEngine(cheap, expensive, corpus, shards=s,
                                 slots=2, faults=plan,
                                 retry_backoff_ms=1.0)
            futs = [eng.submit(r) for r in reqs]
            for i, f in enumerate(futs):
                got = f.result(timeout=600)
                assert np.array_equal(got.ids, ref[i].ids), (s, i)
                np.testing.assert_array_equal(got.dists, ref[i].dists)
                assert got.stats.D_calls == ref[i].stats.D_calls, (s, i)
                assert not got.stats.degraded
            c = eng.counters()
            assert c.completed == len(reqs) and c.slot_occupancy == 0
            assert c.retries >= plan.fired("drain")
            fired_total += plan.fired("drain")
            eng.close()
        assert fired_total > 0, "fault stream never fired; raise the rate"
        print("SHARDED_CHAOS_OK", fired_total)
    """)
    assert "SHARDED_CHAOS_OK" in out
