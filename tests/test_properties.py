"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import distances  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.train.optimizer import (  # noqa: E402
    dequantize_blockwise, quantize_blockwise)

settings.register_profile("ci", max_examples=15, deadline=None)
settings.load_profile("ci")


@given(st.integers(1, 5), st.integers(1, 500), st.floats(0.1, 100.0))
def test_quantize_roundtrip_bounded(rows, cols, scale):
    rng = np.random.default_rng(rows * 1000 + cols)
    x = jnp.asarray(rng.normal(size=(rows, cols)) * scale, jnp.float32)
    q, s = quantize_blockwise(x)
    back = dequantize_blockwise(q, s)
    # per-block bound: |err| <= block_absmax / 127
    xp = np.asarray(x)
    err = np.abs(np.asarray(back) - xp)
    pad = (-cols) % 128
    xb = np.pad(xp, [(0, 0), (0, pad)]).reshape(rows, -1, 128)
    bound = np.abs(xb).max(-1) / 127.0 + 1e-6
    errb = np.pad(err, [(0, 0), (0, pad)]).reshape(rows, -1, 128).max(-1)
    assert (errb <= bound + 1e-5).all()


@given(st.integers(2, 30), st.integers(2, 30), st.integers(2, 16))
def test_pairwise_symmetry_and_identity(n, m, d):
    rng = np.random.default_rng(n * 100 + m)
    x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    dm = np.asarray(distances.pairwise(x, x))
    np.testing.assert_allclose(dm, dm.T, atol=1e-4)
    # the ||x||^2+||y||^2-2xy expansion cancels catastrophically at zero and
    # the sqrt amplifies it: diag error is O(sqrt(eps)*||x||) in f32
    assert np.abs(np.diag(dm)).max() < 3e-2


@given(st.integers(1, 8), st.integers(1, 32), st.integers(1, 32))
def test_beam_merge_is_sorted_merge(b, L, K):
    rng = np.random.default_rng(b * 7 + L * 3 + K)
    bi = jnp.asarray(rng.integers(0, 1000, (b, L)), jnp.int32)
    bd = jnp.asarray(rng.uniform(size=(b, L)), jnp.float32)
    ci = jnp.asarray(rng.integers(0, 1000, (b, K)), jnp.int32)
    cd = jnp.asarray(rng.uniform(size=(b, K)), jnp.float32)
    mi, md = ref.beam_merge_topk_ref(bi, bd, ci, cd)
    alld = np.concatenate([np.asarray(bd), np.asarray(cd)], 1)
    expect = np.sort(alld, axis=1)[:, :L]
    np.testing.assert_allclose(np.asarray(md), expect, atol=1e-6)
    assert (np.diff(np.asarray(md), axis=1) >= 0).all()


@given(st.integers(4, 64), st.floats(0.0, 0.3))
def test_synthetic_capprox_at_least_one(dim_d, noise):
    from repro.data.synthetic import make_dataset

    data = make_dataset(n=128, n_queries=4, dim_D=64,
                        dim_d=min(dim_d, 64), noise=noise, seed=dim_d)
    assert data.c_estimate >= 1.0


@given(st.integers(1, 4), st.integers(8, 64))
def test_flash_attention_rowsum_one(h, s):
    """Softmax rows integrate to 1: attention of v=ones is ones."""
    key = jax.random.PRNGKey(h * 100 + s)
    q = jax.random.normal(key, (1, h, s, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, h, s, 16))
    v = jnp.ones((1, h, s, 16))
    out = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)
