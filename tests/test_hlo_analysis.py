"""The roofline analyzer must be loop-trip-count exact."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_equals_unrolled_flops():
    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    def unrolled(x, ws):
        for i in range(8):
            x = jnp.tanh(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    fs = H.analyze(_compile_text(scanned, x, ws))["dot_flops_per_device"]
    fu = H.analyze(_compile_text(unrolled, x, ws))["dot_flops_per_device"]
    expect = 8 * 2 * 64 * 128 * 128
    assert fs == expect and fu == expect


def test_dot_flops_exact_batched():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    an = H.analyze(_compile_text(f, a, b))
    assert an["dot_flops_per_device"] == 2 * 4 * 32 * 64 * 16


def test_shape_bytes_parser():
    assert H.shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert H.shape_bytes("(f32[8]{0}, s32[4]{0})") == 8 * 4 + 4 * 4
    assert H.shape_bytes("pred[]") == 1 * 1


def test_shape_bytes_fp8_and_packed_subbyte():
    # fp8 families are one byte per element
    assert H.shape_bytes("f8e4m3[16]{0}") == 16
    assert H.shape_bytes("f8e4m3b11fnuz[7]") == 7
    assert H.shape_bytes("f8e5m2fnuz[3,5]") == 15
    # s4/u4 pack two elements per byte, rounding odd counts up
    assert H.shape_bytes("s4[10]{0}") == 5
    assert H.shape_bytes("u4[3]") == 2
    assert H.shape_bytes("u4[]") == 1  # a scalar still occupies one byte


def test_shape_bytes_bounded_dims_and_tuple_layouts():
    # regression: bounded dynamic dims (f32[<=1024]) used to fall out of
    # _SHAPE_RE entirely, silently dropping the buffer from byte counts —
    # the bound IS the physical buffer size
    assert H.shape_bytes("f32[<=1024]{0}") == 1024 * 4
    assert H.shape_bytes("(f32[<=1024]{0}, s32[])") == 1024 * 4 + 4
    # layout annotations must never parse as shapes of their own
    assert H.shape_bytes("bf16[<=64,128]{1,0}") == 64 * 128 * 2


def test_bytes_reasonable_for_elementwise():
    def f(x):
        return jnp.tanh(x) * 2.0 + 1.0

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    an = H.analyze(_compile_text(f, x))
    nbytes = 1024 * 1024 * 4
    # fused chain: ~read once + write once
    assert nbytes <= an["bytes_per_device"] <= 6 * nbytes


def test_parse_input_output_alias_header():
    text = ("HloModule jit_step, input_output_alias={ {0}: (0, {}, "
            "may-alias), {1}: (2, {}, must-alias) }, "
            "entry_computation_layout={...}")
    entries = H.parse_input_output_alias(text)
    assert [(e.output_index, e.param_number, e.kind) for e in entries] == [
        ((0,), 0, "may-alias"), ((1,), 2, "must-alias")]
    assert H.parse_input_output_alias("HloModule no_table") == []


def test_parse_input_output_alias_real_donation():
    f = jax.jit(lambda x, y: (x + 1.0, y * 2.0), donate_argnums=(0, 1))
    x = jnp.ones((8, 8), jnp.float32)
    y = jnp.ones((8, 8), jnp.float32)
    text = f.lower(x, y).compile().as_text()
    assert {e.param_number
            for e in H.parse_input_output_alias(text)} == {0, 1}


_WHILE_HLO = """\
HloModule synthetic

%fused.1 (pp: pred[4,64]) -> pred[4,64] {
  %pp = pred[4,64] parameter(0)
  ROOT %hidden.copy = pred[4,64] copy(pred[4,64] %pp)
}

%body.1 (carry: (pred[4,64], s32[])) -> (pred[4,64], s32[]) {
  %carry = (pred[4,64], s32[]) parameter(0)
  %bm = pred[4,64] get-tuple-element((pred[4,64], s32[]) %carry), index=0
  %i = s32[] get-tuple-element((pred[4,64], s32[]) %carry), index=1
  %f = pred[4,64] fusion(pred[4,64] %bm), kind=kLoop, calls=%fused.1
  ROOT %t = (pred[4,64], s32[]) tuple(pred[4,64] %f, s32[] %i)
}

%cond.1 (carry: (pred[4,64], s32[])) -> pred[] {
  %carry = (pred[4,64], s32[]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main.2 (p0: pred[4,64]) -> pred[4,64] {
  %p0 = pred[4,64] parameter(0)
  %init.copy = pred[4,64] copy(pred[4,64] %p0)
  %zero = s32[] constant(0)
  %t0 = (pred[4,64], s32[]) tuple(pred[4,64] %init.copy, s32[] %zero)
  %w = (pred[4,64], s32[]) while((pred[4,64], s32[]) %t0), \
condition=%cond.1, body=%body.1
  ROOT %out = pred[4,64] get-tuple-element((pred[4,64], s32[]) %w), index=0
}
"""


def test_while_body_copies_walks_fusions_skips_entry():
    """Copies hiding in fusions the loop body calls ARE per-step copies;
    the entry computation's one-time initial-carry copy is not."""
    copies = H.while_body_copies(_WHILE_HLO, result_type_prefix="pred[4,64]")
    assert [c.name for c in copies] == ["hidden.copy"]
    # shape filter: no s32 copies exist anywhere
    assert H.while_body_copies(_WHILE_HLO, result_type_prefix="s32[") == []


def test_roofline_terms_structure():
    an = dict(dot_flops_per_device=197e12, bytes_per_device=819e9,
              bytes_fused_per_device=819e9, collective_bytes_per_device=0.0)
    t = H.roofline_terms(an)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_fused_s"] - 1.0) < 1e-9
    assert t["bottleneck"] in ("compute", "memory")
