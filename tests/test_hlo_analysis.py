"""The roofline analyzer must be loop-trip-count exact."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_equals_unrolled_flops():
    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    def unrolled(x, ws):
        for i in range(8):
            x = jnp.tanh(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    fs = H.analyze(_compile_text(scanned, x, ws))["dot_flops_per_device"]
    fu = H.analyze(_compile_text(unrolled, x, ws))["dot_flops_per_device"]
    expect = 8 * 2 * 64 * 128 * 128
    assert fs == expect and fu == expect


def test_dot_flops_exact_batched():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
    an = H.analyze(_compile_text(f, a, b))
    assert an["dot_flops_per_device"] == 2 * 4 * 32 * 64 * 16


def test_shape_bytes_parser():
    assert H.shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert H.shape_bytes("(f32[8]{0}, s32[4]{0})") == 8 * 4 + 4 * 4
    assert H.shape_bytes("pred[]") == 1 * 1


def test_bytes_reasonable_for_elementwise():
    def f(x):
        return jnp.tanh(x) * 2.0 + 1.0

    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    an = H.analyze(_compile_text(f, x))
    nbytes = 1024 * 1024 * 4
    # fused chain: ~read once + write once
    assert nbytes <= an["bytes_per_device"] <= 6 * nbytes


def test_roofline_terms_structure():
    an = dict(dot_flops_per_device=197e12, bytes_per_device=819e9,
              bytes_fused_per_device=819e9, collective_bytes_per_device=0.0)
    t = H.roofline_terms(an)
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_fused_s"] - 1.0) < 1e-9
    assert t["bottleneck"] in ("compute", "memory")
