"""Behavioral tests of the paper's core claims on controlled data."""
import pytest

from repro.core import bimetric, distances, metrics, vamana
from repro.data.synthetic import make_dataset


@pytest.fixture(scope="module")
def setup():
    data = make_dataset(n=1024, n_queries=24, dim_D=48, dim_d=8,
                        noise=0.12, seed=1)
    cfg = vamana.VamanaConfig(max_degree=16, l_build=24, alpha=1.2,
                              pool_size=48, rev_candidates=16,
                              build_batch=512, n_rounds=2)
    idx = vamana.build(data.corpus_d, cfg)
    em_d = distances.EmbeddingMetric(data.corpus_d)
    em_D = distances.EmbeddingMetric(data.corpus_D)
    true_ids, _ = em_D.brute_force(data.queries_D, 10)
    return data, idx, em_d, em_D, true_ids


def _run(setup, method, quota):
    data, idx, em_d, em_D, true_ids = setup
    fn = (bimetric.bimetric_search if method == "bimetric"
          else bimetric.rerank_search)
    res = fn(
        lambda q, i: em_d.dists(q, i), lambda q, i: em_D.dists(q, i),
        idx, data.queries_d, data.queries_D,
        n_points=1024, quota=quota, k=10,
    )
    rec = float(metrics.recall_at_k(res.ids, true_ids).mean())
    return res, rec


def test_quota_never_exceeded(setup):
    for quota in (20, 60, 150):
        res, _ = _run(setup, "bimetric", quota)
        assert int(res.D_calls.max()) <= quota
        res2, _ = _run(setup, "rerank", quota)
        assert int(res2.D_calls.max()) <= quota


def test_converges_to_exact(setup):
    """Property 4 of Thm 1.1: with enough budget the true NN under D."""
    _, rec = _run(setup, "bimetric", 700)
    assert rec >= 0.95, rec


def test_bimetric_beats_or_matches_rerank(setup):
    """The paper's empirical headline (Fig. 1): at equal Q, the two-stage
    search dominates re-ranking (checked at a mid-range budget)."""
    _, rec_b = _run(setup, "bimetric", 80)
    _, rec_r = _run(setup, "rerank", 80)
    assert rec_b >= rec_r - 0.02, (rec_b, rec_r)


def test_identical_metrics_reduce_to_single(setup):
    """With d == D (C=1) the bi-metric search equals single-metric search."""
    data, idx, em_d, em_D, _ = setup
    res = bimetric.bimetric_search(
        lambda q, i: em_d.dists(q, i), lambda q, i: em_d.dists(q, i),
        idx, data.queries_d, data.queries_d,
        n_points=1024, quota=400, k=10,
    )
    true_d, _ = em_d.brute_force(data.queries_d, 10)
    rec = float(metrics.recall_at_k(res.ids, true_d).mean())
    assert rec >= 0.95


def test_recall_monotone_in_quota(setup):
    recs = [(_run(setup, "bimetric", q)[1]) for q in (20, 80, 300)]
    assert recs[0] <= recs[1] + 0.05 and recs[1] <= recs[2] + 0.05


def test_seeding_ablation(setup):
    """Figure 3: multi-seed stage-2 beats default-entry stage-2."""
    data, idx, em_d, em_D, true_ids = setup
    kw = dict(n_points=1024, quota=100, k=10)
    multi = bimetric.bimetric_search(
        lambda q, i: em_d.dists(q, i), lambda q, i: em_D.dists(q, i),
        idx, data.queries_d, data.queries_D, **kw)
    default = bimetric.bimetric_search(
        lambda q, i: em_d.dists(q, i), lambda q, i: em_D.dists(q, i),
        idx, data.queries_d, data.queries_D, use_stage1=False, **kw)
    rec_m = float(metrics.recall_at_k(multi.ids, true_ids).mean())
    rec_d = float(metrics.recall_at_k(default.ids, true_ids).mean())
    assert rec_m >= rec_d - 0.02, (rec_m, rec_d)
