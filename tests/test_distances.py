import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distances

try:  # degrade gracefully: only @given tests need hypothesis
    from hypothesis import given, settings, strategies as st

    settings.register_profile("ci", max_examples=20, deadline=None)
    settings.load_profile("ci")
except ImportError:  # pragma: no cover - exercised where hypothesis is absent

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="property test needs hypothesis")(fn)

        return deco


def test_pairwise_l2_matches_numpy(rng_key):
    x = jax.random.normal(rng_key, (13, 7))
    y = jax.random.normal(jax.random.fold_in(rng_key, 1), (9, 7))
    d = distances.pairwise(x, y, "l2")
    ref = np.linalg.norm(np.asarray(x)[:, None] - np.asarray(y)[None], axis=-1)
    np.testing.assert_allclose(np.asarray(d), ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("metric", distances.VALID_METRICS)
def test_point_to_points_consistency(rng_key, metric):
    x = jax.random.normal(rng_key, (11, 5))
    q = jax.random.normal(jax.random.fold_in(rng_key, 2), (5,))
    d1 = distances.point_to_points(q, x, metric)
    d2 = distances.pairwise(q[None], x, metric)[0]
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-5)


def test_embedding_metric_invalid_ids(rng_key):
    emb = jax.random.normal(rng_key, (10, 4))
    em = distances.EmbeddingMetric(emb)
    d = em.dists(emb[0], jnp.array([0, -1, 3]))
    assert np.isinf(np.asarray(d)[1])
    assert np.asarray(d)[0] == pytest.approx(0.0, abs=1e-5)


def test_brute_force_topk(rng_key):
    emb = jax.random.normal(rng_key, (50, 8))
    em = distances.EmbeddingMetric(emb)
    q = emb[:3] + 0.01
    ids, d = em.brute_force(q, 1)
    assert list(np.asarray(ids)[:, 0]) == [0, 1, 2]


@given(scale=st.floats(1.1, 10.0))
def test_measure_capproximation(scale):
    rng = np.random.default_rng(0)
    dd = jnp.asarray(rng.uniform(0.5, 2.0, size=100).astype(np.float32))
    # D within [1, scale] multiplicative band of d
    band = jnp.asarray(rng.uniform(1.0, scale, size=100).astype(np.float32))
    DD = dd * band
    s, c = distances.measure_capproximation(dd, DD)
    # after rescaling by s, d' <= D <= C d' must hold
    dscaled = np.asarray(dd) * s
    assert (dscaled <= np.asarray(DD) * (1 + 1e-5)).all()
    assert (np.asarray(DD) <= c * dscaled * (1 + 1e-5)).all()
    assert c <= scale * 1.01


def test_l2_triangle_inequality(rng_key):
    x = np.asarray(jax.random.normal(rng_key, (20, 6)))
    d = np.asarray(distances.pairwise(jnp.asarray(x), jnp.asarray(x)))
    for i in range(0, 20, 5):
        for j in range(0, 20, 5):
            for k in range(0, 20, 5):
                assert d[i, j] <= d[i, k] + d[k, j] + 1e-4
