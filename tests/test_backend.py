"""Kernel-backend dispatch: parity grid, norm cache, deprecation shims.

The contract (see ``repro/kernels/__init__.py``): ``"ref"`` is the frozen
oracle; ``"xla_matmul"`` and ``"pallas"``(-interpret) score waves in matmul
form over the corpus-norm cache — same math up to fp reassociation, so the
grid pins *pool distances within fp tolerance and recall@10 identical*
against the ref backend, across all four metrics and shard counts
{1, 2, 4}; within one backend, sharded == unsharded stays bit-exact.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import beam, distances, metrics
from repro.kernels import backend as kernel_backend
from repro.kernels import ops

ROOT = os.path.join(os.path.dirname(__file__), "..")

METRICS = ("sqeuclidean", "l2", "ip", "cosine")
FAST_BACKENDS = ("xla_matmul", "pallas-interpret")


def _random_graph(seed=3, n=160, r=6, dim=12, b=4):
    rng = np.random.default_rng(seed)
    adj = rng.integers(0, n, (n, r)).astype(np.int32)
    adj[rng.random((n, r)) < 0.2] = -1
    emb = rng.normal(size=(n, dim)).astype(np.float32)
    qs = rng.normal(size=(b, dim)).astype(np.float32)
    return jnp.asarray(adj), jnp.asarray(emb), jnp.asarray(qs)


# ------------------------------------------------------------- resolution
def test_resolve_backend_names():
    assert kernel_backend.resolve_backend("ref").name == "ref"
    be = kernel_backend.resolve_backend("pallas-interpret")
    assert be.name == "pallas" and be.interpret
    with pytest.raises(ValueError):
        kernel_backend.resolve_backend("mxu9000")
    # a resolved Backend passes through untouched (idempotent knob)
    assert kernel_backend.resolve_backend(be) is be


def test_resolve_backend_auto_matches_devices():
    """The auto rule: pallas iff a TPU is visible, xla_matmul otherwise."""
    be = kernel_backend.resolve_backend("auto")
    has_tpu = any(d.platform == "tpu" for d in jax.devices())
    assert be.name == ("pallas" if has_tpu else "xla_matmul")


def test_legacy_shims_keep_independent_knob_semantics():
    """The historical kwargs were independent: ``use_pallas`` routed the
    scoring kernels only and the merge stayed on the stable XLA cut unless
    ``use_fused_merge=True`` — a shimmed call must not silently flip the
    merge route the way the new name-derived knob does."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        be = kernel_backend.resolve_backend(use_pallas=True)
        assert be.use_pallas and be.merge_pallas is False
        be = kernel_backend.resolve_backend(use_pallas=True,
                                            use_fused_merge=True)
        assert be.use_pallas and be.merge_pallas is True
        # interpret alone keeps the full legacy default (ref + XLA merge)
        be = kernel_backend.resolve_backend(interpret=True)
        assert be.name == "ref" and be.interpret and not be.merge_pallas
    # the new knob derives the fused merge from the backend name
    assert kernel_backend.resolve_backend("pallas").merge_pallas
    assert not kernel_backend.resolve_backend("xla_matmul").merge_pallas


def test_backend_is_jit_static():
    """Backend is frozen/hashable — usable as a jit static argument."""
    be = kernel_backend.Backend("xla_matmul")
    assert hash(be) == hash(kernel_backend.Backend("xla_matmul"))
    f = jax.jit(lambda x, *, backend: x + 1, static_argnames=("backend",))
    assert int(f(jnp.int32(1), backend=be)) == 2


# ------------------------------------------------------------- norm cache
def test_corpus_view_zero_row_padding():
    """Uneven-shard zero padding rows carry norm 0 and a finite inverse
    norm, and score exactly 1.0 under cosine in every backend — padding
    never pollutes the metric (no NaN/inf leaks past the id mask)."""
    rng = np.random.default_rng(0)
    corpus = np.concatenate(
        [rng.normal(size=(6, 8)).astype(np.float32), np.zeros((2, 8), np.float32)])
    view = ops.as_corpus_view(jnp.asarray(corpus))
    np.testing.assert_array_equal(np.asarray(view.sq_norms[6:]), 0.0)
    assert np.isfinite(np.asarray(view.inv_norms)).all()
    # as_corpus_view is idempotent (no double-normalization)
    assert ops.as_corpus_view(view) is view
    qs = jnp.asarray(rng.normal(size=(2, 8)).astype(np.float32))
    ids = jnp.array([[0, 6, 7], [7, 3, -1]], jnp.int32)
    for be in ("ref", *FAST_BACKENDS):
        d = np.asarray(ops.gather_score(view, qs, ids, metric="cosine",
                                        backend=be))
        assert np.isfinite(d[np.asarray(ids) >= 0]).all(), be
        # a zero row has dot 0 with any query -> cosine distance exactly 1
        np.testing.assert_allclose(d[0, 1], 1.0, atol=1e-6)
        np.testing.assert_allclose(d[0, 2], 1.0, atol=1e-6)
        assert np.isinf(d[1, 2])


@pytest.mark.parametrize("metric", METRICS)
def test_matmul_form_matches_oracle(metric):
    """Op-level grid: xla_matmul / pallas-interpret vs the ref oracle."""
    key = jax.random.PRNGKey(11)
    corpus = jax.random.normal(key, (100, 24))
    qs = jax.random.normal(jax.random.fold_in(key, 1), (4, 24))
    ids = jax.random.randint(jax.random.fold_in(key, 2), (4, 17), -1, 100)
    view = ops.as_corpus_view(corpus)
    d_ref = np.asarray(ops.gather_score(corpus, qs, ids, metric=metric))
    fin = np.isfinite(d_ref)
    for be in FAST_BACKENDS:
        d_be = np.asarray(ops.gather_score(view, qs, ids, metric=metric,
                                           backend=be))
        np.testing.assert_allclose(d_be[fin], d_ref[fin], rtol=1e-4,
                                   atol=1e-4, err_msg=be)
        assert (np.isinf(d_be) == ~fin).all(), be


# ------------------------------------------------------- end-to-end parity
@pytest.mark.parametrize("metric", METRICS)
def test_search_parity_grid_unsharded(metric):
    """{ref, xla_matmul, pallas-interpret} through the full batched engine:
    recall@10 identical to the ref backend, pool dists within fp tol."""
    adj, emb, qs = _random_graph()
    n = emb.shape[0]
    entries = jnp.zeros((qs.shape[0], 1), jnp.int32)
    true_ids, _ = distances.EmbeddingMetric(emb, metric).brute_force(qs, 10)

    def search(be):
        return beam.batched_greedy_search(
            beam.fused_dist_fn(emb, metric, backend=be), adj, qs, entries,
            n_points=n, beam_width=8, pool_size=16, quota=40, max_steps=60,
            backend=be)

    base = search("ref")
    rec_ref = np.asarray(metrics.recall_at_k(base.pool_ids[:, :10], true_ids))
    for be in FAST_BACKENDS:
        res = search(be)
        rec = np.asarray(metrics.recall_at_k(res.pool_ids[:, :10], true_ids))
        np.testing.assert_array_equal(rec, rec_ref, err_msg=be)
        np.testing.assert_allclose(
            np.asarray(res.pool_dists), np.asarray(base.pool_dists),
            rtol=1e-4, atol=1e-4, err_msg=be)
        np.testing.assert_array_equal(
            np.asarray(res.n_calls), np.asarray(base.n_calls), err_msg=be)


@pytest.mark.slow
def test_search_parity_grid_sharded():
    """The full acceptance grid on 8 forced host devices: backends ×
    metrics × shards {1, 2, 4}. Within one backend the sharded run is
    bit-exact vs unsharded (norms shard with the corpus blocks; uneven N
    exercises the zero-pad rows); across backends, recall@10 matches ref
    and pool dists agree to fp tolerance."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax.numpy as jnp
        import numpy as np
        from repro.core import distances, metrics
        from repro.core.beam import (batched_greedy_search, fused_dist_fn,
                                     sharded_greedy_search)

        rng = np.random.default_rng(3)
        n, dim, b = 130, 8, 4   # uneven N: shard blocks get zero-pad rows
        adj = rng.integers(0, n, (n, 6)).astype(np.int32)
        adj[rng.random((n, 6)) < 0.2] = -1
        adj = jnp.asarray(adj)
        emb = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
        qs = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
        entries = jnp.broadcast_to(
            jnp.array([0, 64, 100], jnp.int32), (b, 3))

        for met in ("sqeuclidean", "l2", "ip", "cosine"):
            true_ids, _ = distances.EmbeddingMetric(emb, met).brute_force(
                qs, 10)
            per_backend = {}
            for be in ("ref", "xla_matmul", "pallas-interpret"):
                base = batched_greedy_search(
                    fused_dist_fn(emb, met, backend=be), adj, qs, entries,
                    n_points=n, beam_width=8, pool_size=16, quota=13,
                    max_steps=100, backend=be)
                for shards in (1, 2, 4):
                    res = sharded_greedy_search(
                        emb, adj, qs, entries, shards=shards, metric=met,
                        beam_width=8, pool_size=16, quota=13,
                        max_steps=100, backend=be)
                    for name, x, y in zip(base._fields, base, res):
                        assert np.array_equal(
                            np.asarray(x), np.asarray(y)), \\
                            (met, be, shards, name)
                per_backend[be] = base
            rec_ref = np.asarray(metrics.recall_at_k(
                per_backend["ref"].pool_ids[:, :10], true_ids))
            for be in ("xla_matmul", "pallas-interpret"):
                rec = np.asarray(metrics.recall_at_k(
                    per_backend[be].pool_ids[:, :10], true_ids))
                assert np.array_equal(rec, rec_ref), (met, be)
                np.testing.assert_allclose(
                    np.asarray(per_backend[be].pool_dists),
                    np.asarray(per_backend["ref"].pool_dists),
                    rtol=1e-4, atol=1e-4)
            print(met, "OK", flush=True)
        print("BACKEND_GRID_OK")
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=ROOT, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "BACKEND_GRID_OK" in res.stdout


# --------------------------------------------------------------- serving
def test_engine_backend_knob():
    """BiMetricEngine(backend=...) answers match the ref-backend engine
    (identical ids and budget accounting on a well-separated corpus)."""
    from repro.configs import qwen3_0_6b
    from repro.models import transformer as T
    from repro.serve import BiMetricEngine, EmbedTower

    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = T.TransformerConfig(
        name="exp-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=cheap_cfg.vocab, embed_dim=32)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(
        T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
    corpus = np.random.default_rng(0).integers(
        0, cheap_cfg.vocab, (64, 10), dtype=np.int32)
    qs = corpus[[5, 33]].copy()

    eng_ref = BiMetricEngine(cheap, expensive, corpus)
    assert eng_ref.backend.name == "ref"
    ids_ref, dd_ref, st_ref = eng_ref.query_batch(qs, quota=12, k=5)
    eng_mm = BiMetricEngine(cheap, expensive, corpus, backend="xla_matmul")
    ids_mm, dd_mm, st_mm = eng_mm.query_batch(qs, quota=12, k=5)
    np.testing.assert_array_equal(ids_mm, ids_ref)
    np.testing.assert_allclose(dd_mm, dd_ref, rtol=1e-5, atol=1e-5)
    assert [s.D_calls for s in st_mm] == [s.D_calls for s in st_ref]


# ------------------------------------------------------ deprecation shims
def test_deprecated_knobs_warn_exactly_once():
    """Every legacy boolean kwarg maps onto the backend knob and warns once
    per (call site, kwarg) — the second call is silent."""
    key = jax.random.PRNGKey(5)
    corpus = jax.random.normal(key, (30, 8))
    qs = jax.random.normal(jax.random.fold_in(key, 1), (2, 8))
    ids = jax.random.randint(jax.random.fold_in(key, 2), (2, 5), -1, 30)
    kernel_backend._warned.clear()
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            d1 = ops.gather_score(corpus, qs, ids, use_pallas=False)
            d2 = ops.gather_score(corpus, qs, ids, use_pallas=False)
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(dep) == 1
        assert "use_pallas" in str(dep[0].message)
        assert "backend=" in str(dep[0].message)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))
        # the shimmed call is the ref oracle, bit-for-bit
        np.testing.assert_array_equal(
            np.asarray(d1), np.asarray(ops.gather_score(corpus, qs, ids)))
        # a different (call site, kwarg) pair warns independently — once
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            for _ in range(2):
                beam.commit_scores(
                    beam.BatchedSearchState(
                        pool_ids=jnp.full((2, 4), -1, jnp.int32),
                        pool_dists=jnp.full((2, 4), jnp.inf),
                        expanded=jnp.zeros((2, 4), bool),
                        scored=jnp.zeros((2, 30), bool),
                        n_calls=jnp.zeros((2,), jnp.int32),
                        n_steps=jnp.zeros((2,), jnp.int32)),
                    ids, ids >= 0, jnp.abs(jax.random.normal(key, (2, 5))),
                    use_fused_merge=False)
        dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(dep) == 1
        assert "use_fused_merge" in str(dep[0].message)
    finally:
        kernel_backend._warned.clear()
