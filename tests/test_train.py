import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DeterministicIterator, lm_batch_fn
from repro.train import compression
from repro.train.optimizer import (AdamWConfig, dequantize_blockwise,
                                   lr_schedule, make_adamw,
                                   quantize_blockwise)
from repro.train.trainer import Trainer, TrainerConfig


def _quadratic_loss(params, batch):
    r = params["w"] - batch["target"]
    loss = (r * r).sum()
    return loss, {"loss": loss}


def test_adamw_converges():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, grad_clip=1e9)
    init, update = make_adamw(cfg)
    params = {"w": jnp.zeros((8, 8))}
    target = jnp.ones((8, 8)) * 3.0
    st = init(params)
    for _ in range(150):
        g = jax.grad(lambda p: _quadratic_loss(p, {"target": target})[0])(params)
        params, st, _ = update(g, st, params)
    assert float(jnp.abs(params["w"] - target).max()) < 0.15


def test_adamw_quantized_close_to_exact():
    tgt = jnp.asarray(np.random.default_rng(0).normal(size=(4, 256)) * 2,
                      jnp.float32)
    out = {}
    for quant in (False, True):
        cfg = AdamWConfig(lr=0.05, warmup_steps=1, total_steps=500,
                          weight_decay=0.0, grad_clip=1e9,
                          quantized_state=quant)
        init, update = make_adamw(cfg)
        params = {"w": jnp.zeros((4, 256))}
        st = init(params)
        for _ in range(100):
            g = jax.grad(lambda p: _quadratic_loss(p, {"target": tgt})[0])(params)
            params, st, _ = update(g, st, params)
        out[quant] = np.asarray(params["w"])
    err = np.abs(out[True] - out[False]).max()
    assert err < 0.25, err  # int8 states track the exact trajectory


def test_quantize_roundtrip_bound():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(3, 300)),
                    jnp.float32)
    q, s = quantize_blockwise(x)
    back = dequantize_blockwise(q, s)
    blockmax = np.abs(np.asarray(x)).max()
    assert float(jnp.abs(back - x).max()) <= blockmax / 127.0 + 1e-6


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0 and max(lrs) <= 1.0
    assert lrs[-1] == pytest.approx(cfg.min_lr_frac, rel=0.05)


def test_topk_error_feedback_converges():
    """Sparsified-with-EF SGD reaches the dense optimum (DGC property)."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32,)), jnp.float32)

    def loss(p):
        r = A @ p["w"] - b
        return (r * r).mean()

    params = {"w": jnp.zeros((16,))}
    ef = compression.init_error_feedback(params)
    for _ in range(400):
        g = jax.grad(loss)(params)
        sg, ef, _ = compression.topk_sparsify(g, ef, k_frac=0.25)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, sg)
    dense = {"w": jnp.zeros((16,))}
    for _ in range(400):
        g = jax.grad(loss)(dense)
        dense = jax.tree.map(lambda p, gg: p - 0.05 * gg, dense, g)
    assert float(loss(params)) < float(loss(dense)) * 1.1 + 1e-4


def test_trainer_checkpoint_restart(tmp_path):
    """Kill-and-resume: the restarted run continues the same trajectory."""
    opt = AdamWConfig(lr=0.05, warmup_steps=1, total_steps=100,
                      weight_decay=0.0)
    tcfg = TrainerConfig(total_steps=6, ckpt_dir=str(tmp_path), ckpt_every=3,
                         log_every=100)
    params = {"w": jnp.zeros((4, 4))}
    target = jnp.ones((4, 4))

    def batches():
        while True:
            yield {"target": target}

    tr1 = Trainer(_quadratic_loss, params, opt, tcfg)
    tr1.run(batches(), steps=6)
    w_full = np.asarray(tr1.params["w"])

    # "crash" after step 3 checkpoint, then resume
    tr2 = Trainer(_quadratic_loss, params, opt, tcfg)
    tr2.run(batches(), steps=3)
    tr3 = Trainer(_quadratic_loss, params, opt, tcfg)
    tr3.maybe_restore()
    # restored from the latest checkpoint (step 6 from tr1 run... use fresh dir
    assert tr3.step in (3, 6)


def test_trainer_grad_accum_equivalence(tmp_path):
    opt = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, grad_clip=1e9)
    target = jnp.ones((8, 4))

    def loss(params, batch):
        r = params["w"][None] - batch["target"]
        l = (r * r).mean()
        return l, {"loss": l}

    def batches():
        while True:
            yield {"target": jnp.broadcast_to(target[None], (4, 8, 4))
                   .reshape(4 * 8, 4)[:, :]}

    # accum=1 vs accum=4 on identical data -> same params
    outs = {}
    for accum in (1, 4):
        tr = Trainer(loss, {"w": jnp.zeros((4,))}, opt,
                     TrainerConfig(total_steps=5, grad_accum=accum,
                                   log_every=100))
        def gen():
            while True:
                yield {"target": jnp.broadcast_to(target, (8, 4))}
        tr.run(gen(), steps=5)
        outs[accum] = np.asarray(tr.params["w"])
    np.testing.assert_allclose(outs[1], outs[4], rtol=1e-4, atol=1e-5)


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, config={"a": 1})
    tree = {"x": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"y": jnp.ones((4,), jnp.bfloat16)}}
    mgr.save(5, tree, extra={"data_state": {"seed": 1, "step": 9}},
             async_=True)
    mgr.wait()
    restored, manifest = mgr.restore(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(tree["x"]))
    assert restored["nested"]["y"].dtype == jnp.bfloat16
    assert manifest["data_state"]["step"] == 9


def test_checkpoint_gc_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, async_=False)
    assert mgr.all_steps() == [3, 4]
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_checkpoint_config_hash_guard(tmp_path):
    m1 = CheckpointManager(str(tmp_path), config={"lr": 1})
    m1.save(1, {"x": jnp.zeros(2)}, async_=False)
    m2 = CheckpointManager(str(tmp_path), config={"lr": 2})
    with pytest.raises(ValueError):
        m2.restore({"x": jax.ShapeDtypeStruct((2,), jnp.float32)})


def test_deterministic_iterator_state_resume():
    make = lm_batch_fn(4, 8, 100)
    it1 = DeterministicIterator(make, seed=3, prefetch=2)
    batches1 = [next(it1) for _ in range(5)]
    state = it1.state()
    more1 = [next(it1) for _ in range(3)]
    it2 = DeterministicIterator.from_state(make, state, prefetch=2)
    more2 = [next(it2) for _ in range(3)]
    for a, b in zip(more1, more2):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
