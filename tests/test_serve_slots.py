"""Slot-pool admission edge cases + the beam-level slot primitives.

The serving engine's async drive is continuous batching over one resident
slot pool (see ``repro/serve``): finished rows free mid-flight, admission
refills them from a priority/deadline heap on every step, and static
shapes only ever grow (an exact no-op). These tests pin the admission
semantics the parity suites don't reach: priority-ordered slot reuse,
deadline expiry while queued, all-slots-busy backpressure, quota-0 rows,
close() cancellation of never-admitted requests, and the beam primitives
(``reset_slots`` / ``grow_state``) the pool is built on. The sharded
suite (8 forced host devices, subprocess) pins slot-drive parity at
shards ∈ {1, 2, 4}.

A ``_GatedTower`` wraps the expensive tower with a ``threading.Event`` so
a test can hold the drive thread inside a tower call and build a
deterministic admitted-vs-queued split before releasing it.
"""
import concurrent.futures as cf
import os
import subprocess
import sys
import textwrap
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import qwen3_0_6b
from repro.core import beam, distances
from repro.models import transformer as T
from repro.serve import (BiMetricEngine, DeadlineExceeded, EmbedTower,
                         SearchRequest)

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def engine_parts():
    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = T.TransformerConfig(
        name="exp-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=cheap_cfg.vocab, embed_dim=32)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(
        T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
    corpus = np.random.default_rng(0).integers(
        0, cheap_cfg.vocab, (96, 10), dtype=np.int32)
    return cheap, expensive, corpus


class _GatedTower:
    """Expensive-tower wrapper whose forward passes block on an Event."""

    def __init__(self, inner: EmbedTower):
        self.inner = inner
        self.gate = threading.Event()
        self.gate.set()

    def embed(self, tokens, batch: int = 64):
        assert self.gate.wait(120), "gate never released"
        return self.inner.embed(tokens, batch)


def _wait_for(pred, timeout=60.0, what="condition"):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if pred():
            return
        time.sleep(0.005)
    raise AssertionError(f"timed out waiting for {what}")


# ---------------------------------------------------------------- admission
def test_slot_drive_parity_mixed_requests(engine_parts):
    """More requests than slots, mixed quota/k/n_seeds/expand_width through
    the native SearchRequest API: every slot-drive answer is bit-exact vs
    the native synchronous query_batch, and the latency split is sane."""
    cheap, expensive, corpus = engine_parts
    eng = BiMetricEngine(cheap, expensive, corpus, slots=3)
    rows = [3, 40, 77, 12, 55, 9, 61]
    reqs = [
        SearchRequest(tokens=corpus[rows[0]], quota=24, k=10),
        SearchRequest(tokens=corpus[rows[1]], quota=8, k=5),
        SearchRequest(tokens=corpus[rows[2]], quota=16, k=10, n_seeds=4),
        SearchRequest(tokens=corpus[rows[3]], quota=24, k=10,
                      expand_width=2),
        SearchRequest(tokens=corpus[rows[4]], quota=0, k=5),
        SearchRequest(tokens=corpus[rows[5]], quota=12, k=3),
        SearchRequest(tokens=corpus[rows[6]], quota=24, k=10),
    ]
    ref = eng.query_batch(reqs)
    futs = [eng.submit(r) for r in reqs]
    for i, f in enumerate(futs):
        got = f.result(timeout=300)
        assert np.array_equal(got.ids, ref[i].ids), i
        np.testing.assert_array_equal(got.dists, ref[i].dists)
        assert got.stats.D_calls == ref[i].stats.D_calls, i
        assert got.stats.d_calls == ref[i].stats.d_calls, i
        assert got.stats.queue_ms >= 0.0 and got.stats.compute_ms > 0.0
        assert got.stats.latency_ms == pytest.approx(
            got.stats.queue_ms + got.stats.compute_ms)
    c = eng.counters()
    assert c.submitted == c.completed == 7
    assert c.queue_depth == 0 and c.slot_occupancy == 0
    eng.close()


def test_slot_freed_midflight_reused_by_priority(engine_parts):
    """With one slot held busy, a higher-priority late arrival is admitted
    into the freed slot before an earlier low-priority request (the heap
    orders admission, not submit time)."""
    cheap, expensive, corpus = engine_parts
    gated = _GatedTower(expensive)
    eng = BiMetricEngine(cheap, gated, corpus, slots=1)
    order: list[str] = []
    gated.gate.clear()
    fa = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5))
    # the drive thread pops A and blocks inside the gated tower call
    _wait_for(lambda: eng.counters().queue_depth == 0
              and eng.counters().submitted == 1, what="A popped")
    fc = eng.submit(SearchRequest(tokens=corpus[40], quota=8, k=5,
                                  priority=0))
    fb = eng.submit(SearchRequest(tokens=corpus[77], quota=8, k=5,
                                  priority=5))
    fb.add_done_callback(lambda f: order.append("B"))
    fc.add_done_callback(lambda f: order.append("C"))
    gated.gate.set()
    rb, rc = fb.result(timeout=300), fc.result(timeout=300)
    fa.result(timeout=300)
    eng.close()
    assert order == ["B", "C"]  # priority 5 reused the slot first
    # the answers themselves are admission-order-invariant
    ref = BiMetricEngine(cheap, expensive, corpus)
    sb = ref.query(SearchRequest(tokens=corpus[77], quota=8, k=5))
    sc = ref.query(SearchRequest(tokens=corpus[40], quota=8, k=5))
    assert np.array_equal(rb.ids, sb.ids)
    assert np.array_equal(rc.ids, sc.ids)


def test_deadline_expiry_while_queued(engine_parts):
    """A queued request whose deadline_ms passes before a slot frees fails
    with DeadlineExceeded and counts a deadline miss; the in-flight request
    is untouched."""
    cheap, expensive, corpus = engine_parts
    gated = _GatedTower(expensive)
    eng = BiMetricEngine(cheap, gated, corpus, slots=1)
    gated.gate.clear()
    fa = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5))
    _wait_for(lambda: eng.counters().queue_depth == 0
              and eng.counters().submitted == 1, what="A popped")
    fb = eng.submit(SearchRequest(tokens=corpus[40], quota=8, k=5,
                                  deadline_ms=30.0))
    time.sleep(0.1)  # B expires while the only slot is still busy
    gated.gate.set()
    with pytest.raises(DeadlineExceeded):
        fb.result(timeout=300)
    ra = fa.result(timeout=300)
    assert 0 < ra.stats.D_calls <= 12
    assert eng.counters().deadline_misses == 1
    eng.close()


def test_all_slots_busy_backpressure(engine_parts):
    """Arrivals beyond the slot count queue (observable depth), then drain
    to completion; admission snapshots record the pressure."""
    cheap, expensive, corpus = engine_parts
    gated = _GatedTower(expensive)
    eng = BiMetricEngine(cheap, gated, corpus, slots=2)
    gated.gate.clear()
    first = [eng.submit(SearchRequest(tokens=corpus[r], quota=24, k=5))
            for r in (3, 40)]
    # the drive pops a first group (1 or 2 wide, depending on wake timing)
    # and blocks inside the gated tower; the queue is then frozen
    _wait_for(lambda: eng.counters().queue_depth < 2
              and eng.counters().submitted == 2, what="first group popped")
    base = eng.counters().queue_depth
    rest = [eng.submit(SearchRequest(tokens=corpus[r], quota=24, k=5))
            for r in (77, 12, 55, 9)]
    c = eng.counters()
    assert c.queue_depth == base + 4  # backpressure: no free slot, they wait
    gated.gate.set()
    results = [f.result(timeout=300) for f in first + rest]
    assert all(0 < r.stats.D_calls <= 24 for r in results)
    # the queued tail saw a non-empty queue / busy slots at admission
    assert any(r.stats.queue_depth > 0 for r in results)
    assert any(r.stats.slot_occupancy == 2 for r in results)
    c = eng.counters()
    assert c.completed == 6 and c.queue_depth == 0 and c.slot_occupancy == 0
    eng.close()


def test_quota_zero_padding_slots(engine_parts):
    """quota-0 requests ride the pool as padding rows: zero D calls, empty
    results, and no effect on a real slot-mate's answer."""
    cheap, expensive, corpus = engine_parts
    eng = BiMetricEngine(cheap, expensive, corpus, slots=4)
    real = SearchRequest(tokens=corpus[3], quota=15, k=5)
    futs = [eng.submit(SearchRequest(tokens=corpus[r], quota=0, k=5))
            for r in (40, 77)]
    freal = eng.submit(real)
    for f in futs:
        r = f.result(timeout=300)
        assert r.ids.size == 0 and r.stats.D_calls == 0
    got = freal.result(timeout=300)
    eng.close()
    solo = BiMetricEngine(cheap, expensive, corpus)
    ref = solo.query(real)
    assert np.array_equal(got.ids, ref.ids)
    np.testing.assert_array_equal(got.dists, ref.dists)
    assert got.stats.D_calls == ref.stats.D_calls


def test_close_cancels_queued_not_admitted(engine_parts):
    """Regression (the close() bugfix): with one request admitted and one
    still queued, close() cancels the queued one immediately
    (CancelledError) while the admitted one still resolves — the queue is
    never flushed into a final drain."""
    cheap, expensive, corpus = engine_parts
    gated = _GatedTower(expensive)
    eng = BiMetricEngine(cheap, gated, corpus, slots=1)
    gated.gate.clear()
    fa = eng.submit(SearchRequest(tokens=corpus[3], quota=12, k=5))
    _wait_for(lambda: eng.counters().queue_depth == 0
              and eng.counters().submitted == 1, what="A popped")
    fb = eng.submit(SearchRequest(tokens=corpus[40], quota=8, k=5))
    closer = threading.Thread(target=eng.close)
    closer.start()
    # the queued request is cancelled synchronously, before the drive joins
    with pytest.raises(cf.CancelledError):
        fb.result(timeout=60)
    assert not fa.done()  # the admitted one is still computing
    assert eng.counters().cancelled == 1
    gated.gate.set()
    closer.join(timeout=300)
    assert not closer.is_alive()
    ra = fa.result(timeout=60)
    assert 0 < ra.stats.D_calls <= 12
    with pytest.raises(RuntimeError):
        eng.submit(SearchRequest(tokens=corpus[3], quota=5))


# ------------------------------------------------------- beam-level primitives
def _toy_search_parts(n=64, dim=8, deg=6, b=4, seed=0):
    rng = np.random.default_rng(seed)
    corpus = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    adj = jnp.asarray(rng.integers(0, n, (n, deg)), jnp.int32)
    em = distances.EmbeddingMetric(corpus)
    q = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    return corpus, adj, em, q


def _host_drive(em, adj, q, state, safe, keep, quota, bw, ms, ew=1):
    """Host plan/score/commit loop (the serving engine's stage-2 shape)."""
    while True:
        state = beam.commit_scores(state, safe, keep,
                                   em.dists_batch(q, safe))
        if not bool(beam.active_mask(
                state, beam_width=bw, quota=quota, max_steps=ms).any()):
            return state
        state, safe, keep, _ = beam.plan_step(
            state, adj, beam_width=bw, quota=quota, max_steps=ms,
            expand_width=ew)


def test_reset_slots_matches_fresh_init():
    """A recycled row is indistinguishable from a freshly initialized one,
    and non-reset rows pass through bit-for-bit — on both dedup backends."""
    _, adj, em, q = _toy_search_parts()
    b = q.shape[0]
    entries = jnp.asarray([[1, 5, 9]] * b, jnp.int32)
    quota = jnp.asarray([10, 14, 0, 7], jnp.int32)
    for dedup, cap in (("bitmap", None), ("sorted", 16)):
        state, safe, keep = beam.init_state(
            entries, n_points=64, pool_size=8, quota=quota, dedup=dedup,
            set_capacity=cap)
        state = _host_drive(em, adj, q, state, safe, keep, quota, 8, 40)
        # recycle rows 1 and 3 for new entries/quotas
        reset = jnp.asarray([False, True, False, True])
        new_entries = jnp.asarray([[2, 7]] * b, jnp.int32)
        new_quota = jnp.asarray([10, 9, 0, 12], jnp.int32)
        st2, safe2, keep2 = beam.reset_slots(
            state, reset, new_entries, new_quota)
        # non-reset rows untouched, their entry lanes fully masked
        for leaf, old in zip(st2[:3], state[:3]):
            np.testing.assert_array_equal(
                np.asarray(leaf)[[0, 2]], np.asarray(old)[[0, 2]])
        assert not np.asarray(keep2)[[0, 2]].any()
        st2 = _host_drive(em, adj, q, st2, safe2, keep2, new_quota, 8, 40)
        # fresh-init reference for the recycled rows
        ref, rsafe, rkeep = beam.init_state(
            new_entries, n_points=64, pool_size=8, quota=new_quota,
            dedup=dedup, set_capacity=cap)
        ref = _host_drive(em, adj, q, ref, rsafe, rkeep, new_quota, 8, 40)
        for leaf_new, leaf_ref in zip(
                (st2.pool_ids, st2.pool_dists, st2.n_calls, st2.n_steps),
                (ref.pool_ids, ref.pool_dists, ref.n_calls, ref.n_steps)):
            np.testing.assert_array_equal(
                np.asarray(leaf_new)[[1, 3]], np.asarray(leaf_ref)[[1, 3]],
                err_msg=dedup)


def test_grow_state_is_a_no_op():
    """Growing pool_size / set_capacity mid-search leaves the continued
    search's surviving prefix, call counts and steps unchanged."""
    _, adj, em, q = _toy_search_parts(seed=1)
    entries = jnp.asarray([[1, 5, 9]] * q.shape[0], jnp.int32)
    quota = jnp.asarray([12, 9, 15, 6], jnp.int32)
    state, safe, keep = beam.init_state(
        entries, n_points=64, pool_size=8, quota=quota, dedup="sorted",
        set_capacity=16)
    state = beam.commit_scores(state, safe, keep, em.dists_batch(q, safe))
    state, safe, keep, _ = beam.plan_step(
        state, adj, beam_width=8, quota=quota, max_steps=40)
    small = _host_drive(em, adj, q, state, safe, keep, quota, 8, 40)
    grown = beam.grow_state(state, pool_size=16, set_capacity=32)
    assert grown.pool_ids.shape[1] == 16
    assert grown.scored.capacity == 32
    big = _host_drive(em, adj, q, grown, safe, keep, quota, 8, 40)
    np.testing.assert_array_equal(
        np.asarray(big.pool_ids[:, :8]), np.asarray(small.pool_ids))
    np.testing.assert_array_equal(
        np.asarray(big.pool_dists[:, :8]), np.asarray(small.pool_dists))
    np.testing.assert_array_equal(
        np.asarray(big.n_calls), np.asarray(small.n_calls))
    np.testing.assert_array_equal(
        np.asarray(big.n_steps), np.asarray(small.n_steps))


def test_per_row_expand_width_vector():
    """A (B,) expand_width: each row matches the scalar run at its own
    width — including the E=1 duplicate-scoring quirk rows."""
    _, adj, em, q = _toy_search_parts(seed=2)
    b = q.shape[0]
    entries = jnp.asarray([[1, 5, 9]] * b, jnp.int32)
    quota = jnp.asarray([14, 14, 14, 14], jnp.int32)
    ew = jnp.asarray([1, 2, 3, 1], jnp.int32)

    def run(expand, cap=None):
        state, safe, keep = beam.init_state(
            entries, n_points=64, pool_size=8, quota=quota, dedup="bitmap")
        while True:
            state = beam.commit_scores(state, safe, keep,
                                       em.dists_batch(q, safe))
            if not bool(beam.active_mask(
                    state, beam_width=8, quota=quota,
                    max_steps=40).any()):
                return state
            state, safe, keep, _ = beam.plan_step(
                state, adj, beam_width=8, quota=quota, max_steps=40,
                expand_width=expand, expand_cap=cap)

    mixed = run(ew, cap=3)
    for row, e in enumerate(np.asarray(ew)):
        solo = run(int(e))
        np.testing.assert_array_equal(
            np.asarray(mixed.pool_ids)[row], np.asarray(solo.pool_ids)[row])
        np.testing.assert_array_equal(
            np.asarray(mixed.n_calls)[row], np.asarray(solo.n_calls)[row])


# ------------------------------------------------------------------- sharded
def _run(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=ROOT, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_slot_drive_parity():
    """shards ∈ {1, 2, 4}: the slot pool steps through the ShardedStepper
    (admit/plan/commit/active inside the corpus mesh) and every answer —
    with more requests than slots, mixed quotas, quota-0 rows — stays
    bit-exact vs the unsharded synchronous drive."""
    out = _run("""
        from repro.configs import qwen3_0_6b
        from repro.models import transformer as T
        from repro.serve import BiMetricEngine, EmbedTower, SearchRequest
        key = jax.random.PRNGKey(0)
        cheap_cfg = qwen3_0_6b.smoke()
        exp_cfg = T.TransformerConfig(
            name="exp-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=4, head_dim=16, d_ff=128, vocab=cheap_cfg.vocab,
            embed_dim=32)
        cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
        expensive = EmbedTower(
            T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
        corpus = np.random.default_rng(0).integers(
            0, cheap_cfg.vocab, (97, 10), dtype=np.int32)  # uneven N
        rows = [3, 40, 77, 12, 55]
        quotas = [6, 15, 0, 11, 15]
        reqs = [SearchRequest(tokens=corpus[r], quota=q, k=5)
                for r, q in zip(rows, quotas)]
        base = BiMetricEngine(cheap, expensive, corpus)
        ref = base.query_batch(reqs)
        for s in (1, 2, 4):
            eng = BiMetricEngine(cheap, expensive, corpus, shards=s,
                                 slots=2)
            futs = [eng.submit(r) for r in reqs]
            for i, f in enumerate(futs):
                got = f.result(timeout=600)
                assert np.array_equal(got.ids, ref[i].ids), (s, i)
                np.testing.assert_array_equal(got.dists, ref[i].dists)
                assert got.stats.D_calls == ref[i].stats.D_calls, (s, i)
                assert got.stats.d_calls == ref[i].stats.d_calls, (s, i)
            c = eng.counters()
            assert c.completed == len(reqs) and c.slot_occupancy == 0
            eng.close()
        print("SHARDED_SLOTS_OK")
    """)
    assert "SHARDED_SLOTS_OK" in out
