import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances
from repro.core.beam import greedy_search


def _line_graph(n):
    """Path graph 0-1-2-...-n-1; embeddings on a line."""
    adj = np.full((n, 4), -1, np.int32)
    for i in range(n):
        if i > 0:
            adj[i, 0] = i - 1
        if i < n - 1:
            adj[i, 1] = i + 1
    emb = jnp.arange(n, dtype=jnp.float32)[:, None]
    return jnp.asarray(adj), emb


def test_greedy_reaches_nn_on_line():
    adj, emb = _line_graph(32)
    em = distances.EmbeddingMetric(emb)
    q = jnp.array([27.2], jnp.float32)
    res = greedy_search(
        lambda ids: em.dists(q, ids), adj, jnp.array([0], jnp.int32),
        n_points=32, beam_width=4, max_steps=200,
    )
    assert int(res.pool_ids[0]) == 27


def test_quota_exact():
    adj, emb = _line_graph(64)
    em = distances.EmbeddingMetric(emb)
    q = jnp.array([63.0], jnp.float32)
    for quota in [1, 5, 17]:
        res = greedy_search(
            lambda ids: em.dists(q, ids), adj, jnp.array([0], jnp.int32),
            n_points=64, beam_width=4, quota=quota, max_steps=500,
        )
        assert int(res.n_calls) <= quota
        # scored bitmap count == n_calls (each call scored exactly one vertex)
        assert int(res.scored.sum()) == int(res.n_calls)


def test_pool_sorted_and_deduped():
    adj, emb = _line_graph(16)
    em = distances.EmbeddingMetric(emb)
    q = jnp.array([8.0], jnp.float32)
    res = greedy_search(
        lambda ids: em.dists(q, ids), adj,
        jnp.array([0, 0, 15, 3], jnp.int32),  # duplicate entries
        n_points=16, beam_width=6, max_steps=100,
    )
    d = np.asarray(res.pool_dists)
    assert (np.diff(d[np.isfinite(d)]) >= 0).all()
    ids = np.asarray(res.pool_ids)
    valid = ids[ids >= 0]
    assert len(valid) == len(set(valid.tolist()))


def test_entries_respect_quota():
    adj, emb = _line_graph(16)
    em = distances.EmbeddingMetric(emb)
    q = jnp.array([8.0], jnp.float32)
    res = greedy_search(
        lambda ids: em.dists(q, ids), adj,
        jnp.arange(10, dtype=jnp.int32),  # 10 entries but quota 4
        n_points=16, beam_width=6, quota=4, max_steps=100,
    )
    assert int(res.n_calls) == 4
    assert int(res.scored.sum()) == 4
