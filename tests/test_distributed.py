"""Multi-device tests (8 forced host devices, run in a subprocess so the
main test process keeps its single-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh, shard_map
        mesh = make_mesh((8,), ("x",))
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=ROOT, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
def test_overlapped_collectives_match_dense():
    out = _run("""
        from repro.distributed import collectives as C
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (16, 12))
        w = jax.random.normal(jax.random.fold_in(key, 1), (12, 10))
        f = shard_map(partial(C.allgather_matmul, axis_name="x"),
                      mesh=mesh, in_specs=(P("x", None), P(None, None)),
                      out_specs=P(None, None))
        assert float(jnp.abs(f(x, w) - x @ w).max()) < 1e-4
        xk = jax.random.normal(key, (16, 24))
        wk = jax.random.normal(jax.random.fold_in(key, 2), (24, 10))
        g = shard_map(partial(C.matmul_reducescatter, axis_name="x"),
                      mesh=mesh, in_specs=(P(None, "x"), P("x", None)),
                      out_specs=P("x", None))
        assert float(jnp.abs(g(xk, wk) - xk @ wk).max()) < 1e-4
        print("COLLECTIVES_OK")
    """)
    assert "COLLECTIVES_OK" in out


@pytest.mark.slow
def test_gpipe_forward_backward():
    out = _run("""
        from repro.distributed import pipeline as PP
        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])
        def init_stage(k):
            return {"w": jax.random.normal(k, (16, 16)) * 0.5,
                    "b": jnp.zeros(16)}
        key = jax.random.PRNGKey(0)
        sp = PP.stack_stage_params(init_stage, key, 8)
        xm = jax.random.normal(jax.random.fold_in(key, 5), (4, 6, 16))
        def ploss(spp, xmm):
            o = shard_map(
                lambda s_, x_: PP.gpipe_apply(
                    stage_fn, jax.tree.map(lambda a: a[0], s_), x_,
                    axis_name="x", n_micro=4),
                mesh=mesh, in_specs=(P("x"), P(None)),
                out_specs=P(None))(spp, xmm)
            return (o ** 2).sum()
        def rloss(spp, xmm):
            r = xmm
            for s in range(8):
                ps = jax.tree.map(lambda a: a[s], spp)
                r = jax.vmap(lambda mb: stage_fn(ps, mb))(r)
            return (r ** 2).sum()
        assert abs(float(ploss(sp, xm)) - float(rloss(sp, xm))) < 1e-3
        g1 = jax.grad(ploss)(sp, xm)
        g2 = jax.grad(rloss)(sp, xm)
        err = max(float(jnp.abs(a - b).max())
                  for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
        assert err < 1e-4, err
        print("GPIPE_OK")
    """)
    assert "GPIPE_OK" in out


@pytest.mark.slow
def test_quantized_psum_accuracy():
    out = _run("""
        from repro.train.compression import quantized_psum
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
        f = shard_map(lambda t: quantized_psum(t, "x"), mesh=mesh,
                      in_specs=P("x", None), out_specs=P("x", None))
        approx = f(g)
        exact = jnp.broadcast_to(g.sum(0, keepdims=True), (8, 256))
        rel = float(jnp.abs(approx - exact).max() / jnp.abs(exact).max())
        assert rel < 0.05, rel
        print("QPSUM_OK", rel)
    """)
    assert "QPSUM_OK" in out


@pytest.mark.slow
def test_sharded_bimetric_search_matches_quality():
    """Scatter-gather search over 4 corpus shards reaches the recall of the
    exact D ranking at a moderate budget."""
    out = _run("""
        mesh2 = make_mesh((2, 4), ("data", "model"))
        from repro.core import distances, metrics
        from repro.core.distributed import build_sharded, sharded_bimetric_search
        from repro.core.vamana import VamanaConfig
        from repro.data.synthetic import make_dataset
        data = make_dataset(n=1024, n_queries=16, dim_D=48, dim_d=8,
                            noise=0.1, seed=2)
        cfg = VamanaConfig(max_degree=12, l_build=16, pool_size=32,
                           rev_candidates=12, build_batch=256)
        idx = build_sharded(data.corpus_d, data.corpus_D, 4, cfg)
        ids, dd, calls = sharded_bimetric_search(
            mesh2, idx, data.queries_d, data.queries_D, quota=256, k=10)
        em_D = distances.EmbeddingMetric(data.corpus_D)
        true_ids, _ = em_D.brute_force(data.queries_D, 10)
        rec = float(metrics.recall_at_k(ids, true_ids).mean())
        assert rec >= 0.7, rec
        assert int(jnp.asarray(calls).max()) <= 256
        print("SHARDED_OK", rec)
    """)
    assert "SHARDED_OK" in out


@pytest.mark.slow
def test_serve_engine_sharded_stage1_parity():
    """BiMetricEngine(shards=4) answers bit-identically to the single-device
    engine — the stage-1 corpus mesh must not perturb results or budgets."""
    out = _run("""
        from repro.configs import qwen3_0_6b
        from repro.models import transformer as T
        from repro.serve import BiMetricEngine, EmbedTower
        key = jax.random.PRNGKey(0)
        cheap_cfg = qwen3_0_6b.smoke()
        exp_cfg = T.TransformerConfig(
            name="exp-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=4, head_dim=16, d_ff=128, vocab=cheap_cfg.vocab,
            embed_dim=32)
        cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
        expensive = EmbedTower(
            T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
        corpus = np.random.default_rng(0).integers(
            0, cheap_cfg.vocab, (96, 10), dtype=np.int32)
        qs = corpus[[3, 40, 77]].copy()
        eng1 = BiMetricEngine(cheap, expensive, corpus)
        ids1, dd1, st1 = eng1.query_batch(qs, quota=15, k=5)
        eng4 = BiMetricEngine(cheap, expensive, corpus, shards=4)
        ids4, dd4, st4 = eng4.query_batch(qs, quota=15, k=5)
        assert np.array_equal(ids1, ids4)
        np.testing.assert_array_equal(dd1, dd4)
        assert [s.d_calls for s in st1] == [s.d_calls for s in st4]
        assert [s.D_calls for s in st1] == [s.D_calls for s in st4]
        r1, rd1, _ = eng1.rerank_query_batch(qs, quota=20, k=5)
        r4, rd4, _ = eng4.rerank_query_batch(qs, quota=20, k=5)
        assert np.array_equal(r1, r4)
        np.testing.assert_array_equal(rd1, rd4)
        print("SERVE_SHARDED_OK")
    """)
    assert "SERVE_SHARDED_OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard(tmp_path):
    """Save on an 8-device mesh, restore onto a 4-device mesh."""
    out = _run(f"""
        from jax.sharding import NamedSharding
        from repro.checkpoint.manager import CheckpointManager
        arr = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        sh8 = NamedSharding(mesh, P("x", None))
        tree = {{"w": jax.device_put(arr, sh8)}}
        mgr = CheckpointManager("{tmp_path}", keep=2)
        mgr.save(1, tree, async_=False)
        from repro.launch.mesh import axis_types_kw
        mesh4 = jax.make_mesh((4,), ("y",), devices=jax.devices()[:4],
                              **axis_types_kw(1))
        sh4 = NamedSharding(mesh4, P(None, "y"))
        restored, _ = mgr.restore(
            {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}},
            sharding_for=lambda path, a: sh4)
        assert restored["w"].sharding == sh4
        assert float(jnp.abs(restored["w"] - arr).max()) == 0.0
        print("RESHARD_OK")
    """)
    assert "RESHARD_OK" in out
