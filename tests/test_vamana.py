import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distances, metrics, vamana


@pytest.fixture(scope="module")
def built():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 16))
    cfg = vamana.VamanaConfig(max_degree=16, l_build=24, alpha=1.2,
                              pool_size=48, rev_candidates=16,
                              build_batch=256, n_rounds=2)
    return x, vamana.build(x, cfg)


def test_degree_bound(built):
    x, idx = built
    assert idx.adjacency.shape[1] == 16
    assert (np.asarray(idx.adjacency) < 512).all()


def test_no_self_loops(built):
    x, idx = built
    adj = np.asarray(idx.adjacency)
    ids = np.arange(adj.shape[0])[:, None]
    assert not (adj == ids).any()


def test_search_recall(built):
    x, idx = built
    key = jax.random.PRNGKey(7)
    q = x[:32] + 0.05 * jax.random.normal(key, (32, 16))
    em = distances.EmbeddingMetric(x)
    true_ids, _ = em.brute_force(q, 10)
    ids, d, calls = vamana.search(idx, x, q, k=10, beam_width=48)
    rec = float(metrics.recall_at_k(ids, true_ids).mean())
    assert rec >= 0.9, f"recall {rec}"
    # graph search must beat brute force on distance evaluations
    assert float(calls.mean()) < 512


def test_robust_prune_alpha_property(built):
    """Definition 3.1 restricted to the pool: every pruned candidate q has a
    kept neighbor c with alpha * d(c, q) <= d(p, q)."""
    x, idx = built
    alpha = 1.2
    key = jax.random.PRNGKey(3)
    p = 5
    pool = jax.random.choice(key, 512, (64,), replace=False).astype(jnp.int32)
    em = distances.EmbeddingMetric(x)
    d_pool = em.dists(x[p], pool)
    order = jnp.argsort(d_pool)
    pool, d_pool = pool[order], d_pool[order]
    # max_degree >= pool size: every non-kept candidate was *occluded*
    # (with a smaller R, candidates dropped by the degree cap after R
    # selections carry no domination guarantee — that is by design)
    sel = vamana.robust_prune(jnp.int32(p), pool, d_pool, x,
                              alpha=alpha, max_degree=64, metric="l2")
    sel_np = np.asarray(sel)
    kept = sel_np[sel_np >= 0]
    assert len(kept) <= 64
    xn = np.asarray(x)
    for qi, dq in zip(np.asarray(pool), np.asarray(d_pool)):
        if qi == p or qi in kept:
            continue
        # q was pruned: some kept c must dominate it
        ok = any(
            alpha * np.linalg.norm(xn[c] - xn[qi]) <= dq + 1e-4 for c in kept
        )
        assert ok, f"pruned {qi} not dominated"


def test_medoid(built):
    x, idx = built
    m = int(idx.medoid)
    centroid = np.asarray(x).mean(0)
    dists = np.linalg.norm(np.asarray(x) - centroid, axis=1)
    assert dists[m] == pytest.approx(dists.min(), rel=1e-5)


def test_search_normalizes_scalar_quota(built):
    """numpy-scalar / 0-d array quotas must behave exactly like the python
    int (the entry point normalizes once at the boundary — the static
    dedup-backend selection depends on a concrete bound)."""
    x, idx = built
    qs = jax.random.normal(jax.random.PRNGKey(3), (4, 16))
    ref_ids, ref_dd, ref_calls = vamana.search(
        idx, x, qs, k=5, beam_width=12, quota=20)
    for q in (np.int32(20), np.int64(20), np.asarray(20), jnp.asarray(20)):
        ids, dd, calls = vamana.search(
            idx, x, qs, k=5, beam_width=12, quota=q)
        assert np.array_equal(np.asarray(ids), np.asarray(ref_ids)), type(q)
        np.testing.assert_array_equal(np.asarray(dd), np.asarray(ref_dd))
        assert np.array_equal(np.asarray(calls), np.asarray(ref_calls))
    # (B,) per-query vectors pass through untouched
    ids_v, _, calls_v = vamana.search(
        idx, x, qs, k=5, beam_width=12,
        quota=np.array([20, 20, 20, 20], np.int32))
    assert np.array_equal(np.asarray(ids_v), np.asarray(ref_ids))
    assert np.array_equal(np.asarray(calls_v), np.asarray(ref_calls))
