"""Each program-contract checker must fire on a seeded violation and pass
on the registered programs (the CI ``analysis`` lane's guarantee)."""
from functools import partial

import jax
import jax.numpy as jnp
import pytest

from repro import analysis as A
from repro.analysis import astlint, registry, runner
from repro.analysis.retrace import jit_cache_size
from repro.train.optimizer import AdamWConfig, make_adamw


# ------------------------------------------------------------ retrace audit
def test_retrace_audit_fires_on_static_quota():
    """The audited regression: a budget knob as a jit static — every
    distinct request value becomes a fresh trace."""
    @partial(jax.jit, static_argnums=(1,))
    def step(x, quota):
        return x[:quota].sum()

    x = jnp.arange(16.0)

    def run_grid():
        for q in (3, 5, 7, 9):
            step(x, q).block_until_ready()
        return 4

    rep = A.audit_retrace("seeded-static-quota", run_grid,
                          lambda: jit_cache_size(step), bound=1)
    assert not rep.ok
    assert rep.traces == 4 and rep.grid_points == 4


def test_retrace_audit_passes_on_operand_quota():
    @jax.jit
    def step(x, quota):
        return jnp.where(jnp.arange(16) < quota, x, 0.0).sum()

    x = jnp.arange(16.0)

    def run_grid():
        for q in (3, 5, 7, 9):
            step(x, jnp.int32(q)).block_until_ready()
        return 4

    rep = A.audit_retrace("operand-quota", run_grid,
                          lambda: jit_cache_size(step), bound=1)
    assert rep.ok and rep.traces == 1


# ------------------------------------------------------------ dtype flow
def test_dtype_lint_fires_on_unsanctioned_upcast():
    """The PR-5 bug shape: a merge that upcasts the payload itself."""
    def merge(d):
        return jnp.sort(d.astype(jnp.float32), axis=-1)

    d = jnp.ones((4, 8), jnp.bfloat16)
    rep = A.check_dtype_flow(merge, (d,), allow={}, name="seeded-upcast")
    assert not rep.ok
    assert rep.counts.get("bfloat16->float32", 0) >= 1


def test_dtype_lint_fires_on_output_contract_drift():
    def merge(d):
        return jnp.sort(d.astype(jnp.float32), axis=-1)

    d = jnp.ones((4, 8), jnp.bfloat16)
    rep = A.check_dtype_flow(
        merge, (d,), allow={"bfloat16->float32": 1},
        expect_out_dtypes=(jnp.bfloat16,), name="seeded-drift")
    assert rep.violations == [
        "output[0] dtype float32, contract says bfloat16"]


def test_dtype_lint_passes_within_allowance():
    """An f32 ordering *view* whose result returns to storage dtype is the
    sanctioned pattern."""
    def merge(d):
        return jnp.sort(d.astype(jnp.float32), axis=-1).astype(d.dtype)

    d = jnp.ones((4, 8), jnp.bfloat16)
    rep = A.check_dtype_flow(
        merge, (d,), allow={"bfloat16->float32": 1},
        expect_out_dtypes=(jnp.bfloat16,))
    assert rep.ok


# ------------------------------------------------------------ donation
def test_donation_check_passes_on_real_alias():
    rep = A.check_donation(lambda x: x + 1.0, (jnp.ones((8, 8)),), (0,),
                           name="aliasable")
    assert rep.ok
    assert rep.donated == (0,) and 0 in rep.aliased


def test_donation_check_fires_on_impossible_alias():
    """Donating a buffer no output can reuse (shape mismatch): jax forwards
    the donation, XLA drops it, and the declaration is a silent no-op."""
    with pytest.warns(UserWarning, match="donat"):
        rep = A.check_donation(lambda x: x.sum(), (jnp.ones((8, 8)),), (0,),
                               name="seeded-drop")
    assert not rep.ok
    assert rep.missing == (0,)


def test_double_donation_detector():
    x = jnp.ones((4, 4))
    assert A.detect_double_donation((x, jnp.array(x, copy=True)),
                                    (0, 1)) == []
    assert A.detect_double_donation((x, x), (0, 1)) == [(0, 1)]


def test_optimizer_master_init_guards_double_donation():
    """The optimizer's ``copy=True`` master init (train/optimizer.py) is the
    production guard this detector encodes: a no-op astype would alias the
    param buffer into the master weights and donate it twice."""
    init, _ = make_adamw(AdamWConfig())
    params = {"w": jnp.ones((4, 2), jnp.float32),
              "b": jnp.zeros((2,), jnp.float32)}
    state = init(params)
    assert A.detect_double_donation((params, state), (0, 1)) == []
    # seeded violation: exactly what the copy guards against
    bad = state._replace(master=params)
    dupes = A.detect_double_donation((params, bad), (0, 1))
    assert len(dupes) == len(params)


# ------------------------------------------------------------ while carry
_BAD_WHILE_HLO = """\
HloModule synthetic_failed_carry_alias

%body.1 (carry: (pred[4,64], s32[])) -> (pred[4,64], s32[]) {
  %carry = (pred[4,64], s32[]) parameter(0)
  %bm = pred[4,64] get-tuple-element((pred[4,64], s32[]) %carry), index=0
  %i = s32[] get-tuple-element((pred[4,64], s32[]) %carry), index=1
  %bm.copy = pred[4,64]{1,0} copy(pred[4,64]{1,0} %bm)
  ROOT %t = (pred[4,64], s32[]) tuple(pred[4,64] %bm.copy, s32[] %i)
}

%cond.1 (carry: (pred[4,64], s32[])) -> pred[] {
  %carry = (pred[4,64], s32[]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

ENTRY %main.2 (p0: pred[4,64]) -> pred[4,64] {
  %p0 = pred[4,64] parameter(0)
  %init.copy = pred[4,64] copy(pred[4,64] %p0)
  %zero = s32[] constant(0)
  %t0 = (pred[4,64], s32[]) tuple(pred[4,64] %init.copy, s32[] %zero)
  %w = (pred[4,64], s32[]) while((pred[4,64], s32[]) %t0), \
condition=%cond.1, body=%body.1
  ROOT %out = pred[4,64] get-tuple-element((pred[4,64], s32[]) %w), index=0
}
"""


def test_while_carry_fires_on_body_copy():
    """A per-step copy of the carried bitmap inside the loop body is the
    failed-aliasing signature; the entry computation's one-time initial
    copy must NOT count."""
    rep = A.check_while_carry(_BAD_WHILE_HLO, carry_shape="pred[4,64]",
                              name="seeded-copy")
    assert not rep.ok
    assert len(rep.copies) == 1 and "bm.copy" in rep.copies[0]


def test_while_carry_clean_on_real_inplace_loop():
    def f(x):
        return jax.lax.fori_loop(
            0, 5, lambda i, c: c.at[:, i].set(True), x)

    x = jnp.zeros((4, 64), jnp.bool_)
    rep = A.check_while_carry(f, (x,), carry_shape="pred[4,64]")
    assert rep.ok


# ------------------------------------------------------------ AST lint
def test_astlint_fires_on_retired_kwarg():
    src = "ops.gather_score(view, qs, ids, use_pallas=True)\n"
    v = astlint.lint_source(src, "src/repro/core/seeded.py")
    assert [x.rule for x in v] == ["retired-kwarg"]
    assert v[0].line == 1


def test_astlint_allows_retired_kwargs_at_the_funnel():
    src = "be = resolve_backend(None, use_pallas=True, interpret=False)\n"
    assert astlint.lint_source(src, "src/repro/core/seeded.py") == []


def test_astlint_fires_on_quantize_flow():
    src = "engine.search(qs, quantize='int8')\n"
    v = astlint.lint_source(src, "src/repro/serve/seeded.py")
    assert [x.rule for x in v] == ["quantize-flow"]


def test_astlint_quantize_rules():
    ok = "view = as_corpus_view(x, quantize='int8')\n"
    assert astlint.lint_source(ok, "src/repro/core/seeded.py") == []
    # stripping residency (the stage-2 boundary) is always legal
    strip = "be = dataclasses.replace(be1, quantize=None)\n"
    assert astlint.lint_source(strip, "src/repro/core/seeded.py") == []


def test_astlint_fires_on_raw_knob_literal():
    src = "state = stepper.init(ids, dedup='bitmap')\n"
    v = astlint.lint_source(src, "src/repro/core/seeded.py")
    assert [x.rule for x in v] == ["raw-knob-literal"]
    ok = "be = resolve_backend(backend='ref')\n"
    assert astlint.lint_source(ok, "src/repro/core/seeded.py") == []


def test_astlint_shim_layer_is_exempt():
    src = "dispatch(use_pallas=True, dedup='bitmap', quantize='int8')\n"
    assert astlint.lint_source(src, "src/repro/kernels/ops.py") == []


def test_astlint_repo_is_clean():
    assert astlint.lint_paths(["src/repro"]) == []


# ------------------------------------------------------------ the registry
def test_registry_programs_pass_all_checkers():
    """The CI analysis lane's exact assertion: every registered program is
    green on every checker (programs needing more devices than the host
    has report a skip, which is not a failure)."""
    verdicts = runner.run_registry()
    assert len(verdicts) >= 8
    bad = {v.program: v.failures() for v in verdicts if not v.ok}
    assert not bad, bad


def test_runner_skips_programs_needing_more_devices():
    prog = registry.get("beam.sharded_mesh[shards=2,4]")
    if jax.local_device_count() >= prog.min_devices:
        pytest.skip("host has enough devices; skip path not reachable")
    v = runner.run_program(prog)
    assert v.skipped is not None and v.ok
    assert v.retrace is None
