"""Per-architecture smoke tests (deliverable f): REDUCED config of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import gnn, recsys as R, transformer as T

KEY = jax.random.PRNGKey(0)

LM_ARCHS = ["qwen3-0.6b", "granite-20b", "deepseek-coder-33b",
            "granite-moe-3b-a800m", "deepseek-v3-671b"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.make_config(True)
    params = spec.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    loss, metrics = T.loss_fn(
        params, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}, cfg)
    assert np.isfinite(float(loss)), arch
    out = T.forward(params, toks, cfg)
    assert out.logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(out.logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch):
    spec = get_arch(arch)
    cfg = spec.make_config(True)
    params = spec.init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 9), 0, cfg.vocab)
    _, cache = T.prefill(params, toks[:, :8], cfg, max_seq=12)
    logits, cache = T.decode_step(params, toks[:, 8:9], cache, cfg)
    assert logits.shape == (2, 1, cfg.vocab)
    assert int(cache.length) == 9
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


def test_gat_smoke_all_shapes():
    from repro.configs import gat_cora
    for shape in gat_cora.SMOKE_SHAPES:
        cell = gat_cora.build_gnn_cell(None, shape, smoke=True)
        info = gat_cora.SMOKE_SHAPES[shape]
        cfg = gnn.GATConfig(d_in=info["d_feat"], n_classes=info["n_classes"])
        p = gnn.init_params(KEY, cfg)
        n, e = info["n_nodes"], info["n_edges"]
        batch = {
            "feats": jax.random.normal(KEY, (n, info["d_feat"])),
            "src": jax.random.randint(KEY, (e,), 0, n),
            "dst": jax.random.randint(jax.random.fold_in(KEY, 1), (e,), 0, n),
        }
        if info["task"] == "graph":
            ng = info["n_graphs"]
            batch["graph_ids"] = jnp.repeat(
                jnp.arange(ng), n // ng)[:n].astype(jnp.int32)
            batch["graph_labels"] = jax.random.randint(
                KEY, (ng,), 0, info["n_classes"])
        else:
            batch["labels"] = jax.random.randint(KEY, (n,), 0,
                                                 info["n_classes"])
            batch["mask"] = jnp.ones((n,), jnp.float32)
        loss, _ = gat_cora.graph_loss(
            p, batch, cfg, task=info["task"],
            n_graphs=info.get("n_graphs") or 0)
        assert np.isfinite(float(loss)), shape


RS = {
    "bst": lambda cfg: {
        "hist": jax.random.randint(KEY, (4, cfg.seq_len), 0, cfg.vocab),
        "target": jax.random.randint(KEY, (4,), 0, cfg.vocab),
        "label": jnp.ones((4,), jnp.float32)},
    "din": lambda cfg: {
        "hist": jax.random.randint(KEY, (4, cfg.seq_len), 0, cfg.vocab),
        "target": jax.random.randint(KEY, (4,), 0, cfg.vocab),
        "label": jnp.zeros((4,), jnp.float32)},
    "bert4rec": lambda cfg: {
        "items": jax.random.randint(KEY, (4, cfg.seq_len), 0, cfg.vocab),
        "mask_pos": jax.random.randint(KEY, (4, cfg.n_masked), 0, cfg.seq_len),
        "mask_labels": jax.random.randint(KEY, (4, cfg.n_masked), 0, cfg.vocab)},
    "xdeepfm": lambda cfg: {
        "fields": jax.random.randint(KEY, (4, cfg.n_fields), 0,
                                     cfg.field_vocab),
        "label": jnp.ones((4,), jnp.float32)},
}

LOSS = {"bst": R.bst_loss, "din": R.din_loss, "bert4rec": R.bert4rec_loss,
        "xdeepfm": R.xdeepfm_loss}


@pytest.mark.parametrize("arch", list(RS))
def test_recsys_smoke_train(arch):
    spec = get_arch(arch)
    cfg = spec.make_config(True)
    p = spec.init_params(KEY, cfg)
    batch = RS[arch](cfg)
    loss, _ = LOSS[arch](p, batch, cfg)
    assert np.isfinite(float(loss)), arch
    # grads flow into the embedding table
    g = jax.grad(lambda pp: LOSS[arch](pp, batch, cfg)[0])(p)
    leaves = [float(jnp.abs(l.astype(jnp.float32)).sum())
              for l in jax.tree.leaves(g)]
    assert sum(leaves) > 0


def test_all_archs_have_four_shapes():
    assert len(ARCHS) == 10
    for name, spec in ARCHS.items():
        assert len(spec.shapes) == 4, name
    from repro.configs import all_cells
    assert len(all_cells()) == 40


def test_smoke_cells_lower_on_host_mesh():
    """Every cell's step function lowers with the SMOKE config on a 1-device
    mesh — catches abstract-args/step signature mismatches cheaply."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    for arch in ["qwen3-0.6b", "bst", "bert4rec"]:
        spec = get_arch(arch)
        for shape in spec.shapes:
            cfg = spec.make_config(True)
            cell = spec.build_cell(cfg, shape)
            args = cell.abstract_args(mesh)
            with mesh:
                jax.jit(cell.fn, donate_argnums=cell.donate).lower(*args)
