"""Async serving: admission edge cases and slot-drive parity suites.

The engine's async path (``submit`` → priority/deadline queue → the
persistent slot pool) must be *bit-exact* vs the synchronous
``query_batch`` drive of the same requests — every budget knob is a
per-row operand in the core engine and slot recycling is an exact re-init
of the recycled rows, so admission order, slot-mates and padding cannot
perturb a request's answer. The sharded suite (8 forced host devices,
subprocess) pins the same parity with stage 2's bookkeeping running
inside the corpus mesh at shards ∈ {1, 2, 4}. Slot-pool-specific edge
cases (priority reuse, deadline expiry, backpressure, close-cancellation)
live in test_serve_slots.py.
"""
import os
import subprocess
import sys
import textwrap
import time

import jax
import numpy as np
import pytest

from repro.configs import qwen3_0_6b
from repro.models import transformer as T
from repro.serve import BiMetricEngine, EmbedTower

ROOT = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def engine_parts():
    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = T.TransformerConfig(
        name="exp-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=cheap_cfg.vocab, embed_dim=32)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(
        T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
    corpus = np.random.default_rng(0).integers(
        0, cheap_cfg.vocab, (96, 10), dtype=np.int32)
    return cheap, expensive, corpus


def _fresh_engine(engine_parts, **kw):
    cheap, expensive, corpus = engine_parts
    return BiMetricEngine(cheap, expensive, corpus, **kw)


def _assert_request_parity(fut_result, ids_row, dd_row, stat):
    ids1, dd1, s1 = fut_result
    ok = (ids_row >= 0) & np.isfinite(dd_row)
    assert np.array_equal(ids1, ids_row[ok])
    np.testing.assert_array_equal(dd1, dd_row[ok])
    assert s1.D_calls == stat.D_calls
    assert s1.d_calls == stat.d_calls


def test_async_bit_exact_vs_query_batch(engine_parts):
    """One full wave of submits == the synchronous query_batch, bit for bit."""
    eng = _fresh_engine(engine_parts, max_batch=3, max_wait_ms=500.0)
    qs = eng.corpus_tokens[[3, 40, 77]].copy()
    ids_b, dd_b, st_b = eng.query_batch(qs, quota=15, k=5)
    futs = [eng.submit(qs[i], quota=15, k=5) for i in range(3)]
    for i, f in enumerate(futs):
        _assert_request_parity(f.result(timeout=300), ids_b[i], dd_b[i],
                               st_b[i])
    eng.close()


def test_mixed_quotas_in_one_wave(engine_parts):
    """Mixed budgets share a wave with exact per-query accounting — equal to
    the per-query-quota sync batch AND to each request running alone."""
    eng = _fresh_engine(engine_parts, max_batch=3, max_wait_ms=500.0)
    qs = eng.corpus_tokens[[3, 40, 77]].copy()
    quotas = np.array([4, 15, 9], np.int32)
    ids_m, dd_m, st_m = eng.query_batch(qs, quota=quotas, k=5)
    assert [s.D_calls for s in st_m] == [4, 15, 9]
    futs = [eng.submit(qs[i], quota=int(quotas[i]), k=5) for i in range(3)]
    for i, f in enumerate(futs):
        _assert_request_parity(f.result(timeout=300), ids_m[i], dd_m[i],
                               st_m[i])
    eng.close()
    solo = _fresh_engine(engine_parts)
    for i, q in enumerate(quotas):
        ids1, dd1, s1 = solo.query(qs[i], quota=int(q), k=5)
        ok = (ids_m[i] >= 0) & np.isfinite(dd_m[i])
        assert np.array_equal(ids1, ids_m[i][ok])
        assert s1.D_calls == st_m[i].D_calls


def test_max_wait_flush_partial_wave(engine_parts):
    """A lone request must not wait for a full wave: the max_wait_ms deadline
    flushes a padded partial wave, and padding never perturbs the answer."""
    eng = _fresh_engine(engine_parts, max_batch=8, max_wait_ms=5.0)
    q = eng.corpus_tokens[7]
    ids_a, dd_a, st_a = eng.submit(q, quota=12, k=5).result(timeout=300)
    eng.close()
    ref = _fresh_engine(engine_parts)
    ids_s, dd_s, st_s = ref.query(q, quota=12, k=5)
    assert np.array_equal(ids_a, ids_s)
    np.testing.assert_array_equal(dd_a, dd_s)
    assert st_a.D_calls == st_s.D_calls and st_a.d_calls == st_s.d_calls


def test_single_request_latency_parity(engine_parts):
    """submit() of one request answers what query() answers (and within a
    sane wall-clock envelope of it — the pipeline adds admission wait, not
    asymptotics). Generous bound: this box is 2 cores and noisy."""
    eng = _fresh_engine(engine_parts, max_batch=4, max_wait_ms=5.0)
    q = eng.corpus_tokens[11]
    eng.submit(q, quota=12, k=5).result(timeout=300)  # warm both drives
    t0 = time.perf_counter()
    r_async = eng.submit(q, quota=12, k=5).result(timeout=300)
    t_async = time.perf_counter() - t0
    t0 = time.perf_counter()
    r_sync = eng.query(q, quota=12, k=5)
    t_sync = time.perf_counter() - t0
    eng.close()
    assert np.array_equal(r_async[0], r_sync[0])
    np.testing.assert_array_equal(r_async[1], r_sync[1])
    assert r_async[2].D_calls == r_sync[2].D_calls
    assert t_async < 20 * max(t_sync, 1e-3) + 1.0


def test_clean_shutdown_with_inflight_requests(engine_parts):
    """close() settles every future instead of hanging: requests already
    admitted to a slot resolve, requests still queued are *cancelled*
    (CancelledError — never flushed into a final drain). close is
    idempotent and submit after close raises. (The deterministic
    admitted-vs-queued split is pinned in test_serve_slots.py with a gated
    tower; here the split is timing-dependent, so both outcomes are
    legal per future.)"""
    import concurrent.futures as cf

    eng = _fresh_engine(engine_parts, max_batch=2, max_wait_ms=1.0)
    qs = eng.corpus_tokens[[3, 9, 40, 55, 77]].copy()
    futs = [eng.submit(qs[i], quota=10, k=5) for i in range(5)]
    eng.close()  # immediately — slots busy, tail still queued
    resolved = cancelled = 0
    for f in futs:
        try:
            ids, dd, st = f.result(timeout=60)  # settled, not abandoned
            assert st.D_calls <= 10
            resolved += 1
        except cf.CancelledError:
            cancelled += 1
    assert resolved + cancelled == 5
    assert eng.counters().cancelled == cancelled
    eng.close()  # idempotent
    with pytest.raises(RuntimeError):
        eng.submit(qs[0], quota=5)


def test_malformed_request_fails_only_its_wave(engine_parts):
    """A bad request (wrong token length) fails its own future; the
    admission thread survives and later requests still serve."""
    eng = _fresh_engine(engine_parts, max_batch=2, max_wait_ms=1.0)
    bad = eng.submit(np.zeros((7,), np.int32), quota=5)  # corpus S is 10
    with pytest.raises(ValueError):
        bad.result(timeout=60)
    ids, dd, st = eng.submit(
        eng.corpus_tokens[3], quota=10, k=5).result(timeout=300)
    assert st.D_calls <= 10 and ids.size > 0
    eng.close()


def test_quota_zero_async(engine_parts):
    eng = _fresh_engine(engine_parts, max_batch=2, max_wait_ms=1.0)
    ids, dd, st = eng.submit(
        eng.corpus_tokens[0], quota=0, k=5).result(timeout=300)
    eng.close()
    assert ids.size == 0 and st.D_calls == 0


def _run(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=ROOT, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_stage2_async_parity():
    """shards ∈ {1, 2, 4}: stage 2's plan/commit bookkeeping runs inside the
    corpus mesh (column-sharded scored bitmap) and both drives stay
    bit-exact vs the single-device engine; the bitmap partition invariant
    (psum of local popcounts == n scored) holds under the stepper."""
    out = _run("""
        from repro.configs import qwen3_0_6b
        from repro.core import beam
        from repro.models import transformer as T
        from repro.serve import BiMetricEngine, EmbedTower
        key = jax.random.PRNGKey(0)
        cheap_cfg = qwen3_0_6b.smoke()
        exp_cfg = T.TransformerConfig(
            name="exp-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=4, head_dim=16, d_ff=128, vocab=cheap_cfg.vocab,
            embed_dim=32)
        cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
        expensive = EmbedTower(
            T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
        corpus = np.random.default_rng(0).integers(
            0, cheap_cfg.vocab, (97, 10), dtype=np.int32)  # uneven N
        qs = corpus[[3, 40, 77]].copy()
        quotas = np.array([6, 15, 11], np.int32)
        base = BiMetricEngine(cheap, expensive, corpus)
        ids0, dd0, st0 = base.query_batch(qs, quota=quotas, k=5)
        for s in (2, 4):
            eng = BiMetricEngine(cheap, expensive, corpus, shards=s,
                                 max_batch=3, max_wait_ms=500.0)
            ids, dd, st = eng.query_batch(qs, quota=quotas, k=5)
            assert np.array_equal(ids0, ids), s
            np.testing.assert_array_equal(dd0, dd)
            assert [x.D_calls for x in st] == [x.D_calls for x in st0]
            assert [x.d_calls for x in st] == [x.d_calls for x in st0]
            futs = [eng.submit(qs[i], quota=int(quotas[i]), k=5)
                    for i in range(3)]
            for i, f in enumerate(futs):
                rids, rdd, rst = f.result(timeout=600)
                ok = (ids0[i] >= 0) & np.isfinite(dd0[i])
                assert np.array_equal(rids, ids0[i][ok]), (s, i)
                np.testing.assert_array_equal(rdd, dd0[i][ok])
                assert rst.D_calls == st0[i].D_calls
            eng.close()
            # partition invariant on the stepper's column-sharded bitmap
            stepper = eng._stepper
            seeds = jnp.asarray(ids0[:, :3], jnp.int32)
            state, safe, keep = stepper.init(
                seeds, jnp.asarray(quotas), pool_size=8)
            counts = np.asarray(stepper.scored_count(state))
            assert (counts == np.asarray(state.n_calls)).all(), counts
        print("SHARDED_ASYNC_OK")
    """)
    assert "SHARDED_ASYNC_OK" in out
