import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gnn, layers, recsys as R, transformer as T


def _dense_cfg(**kw):
    base = dict(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128, vocab=256, qk_norm=True)
    base.update(kw)
    return T.TransformerConfig(**base)


MLA_CFG = T.TransformerConfig(
    name="mla", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=256, moe=True, n_experts=8, top_k=2, moe_d_ff=32,
    n_shared=1, first_dense=1, mla=True, q_lora_rank=32, kv_lora_rank=24,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16, mtp=True,
    capacity_factor=16.0)


@pytest.mark.parametrize("cfg", [_dense_cfg(), MLA_CFG],
                         ids=["gqa", "mla_moe"])
def test_decode_matches_forward(cfg, rng_key):
    p = T.init_params(rng_key, cfg)
    toks = jax.random.randint(rng_key, (2, 12), 0, cfg.vocab)
    _, cache = T.prefill(p, toks[:, :8], cfg, max_seq=12)
    lg, cache = T.decode_step(p, toks[:, 8:9], cache, cfg)
    lg2, cache = T.decode_step(p, toks[:, 9:10], cache, cfg)
    ref = T.forward(p, toks[:, :10], cfg).logits
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref[:, 8]),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(ref[:, 9]),
                               atol=2e-5, rtol=2e-4)


def test_blockwise_attention_matches_full(rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, 37, 4, 16))
    k = jax.random.normal(ks[1], (2, 37, 4, 16))
    v = jax.random.normal(ks[2], (2, 37, 4, 16))
    out = layers.blockwise_attention(q, k, v, causal=True, block_kv=8)
    from repro.kernels.ref import flash_attention_ref
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(ref), atol=2e-5)


def test_rope_relative_shift(rng_key):
    """RoPE: scores depend only on relative positions."""
    x = jax.random.normal(rng_key, (1, 2, 1, 32))
    q0 = layers.apply_rope(x, jnp.array([[3, 7]]))
    q1 = layers.apply_rope(x, jnp.array([[13, 17]]))
    s0 = (q0[0, 0, 0] * q0[0, 1, 0]).sum()
    s1 = (q1[0, 0, 0] * q1[0, 1, 0]).sum()
    np.testing.assert_allclose(float(s0), float(s1), rtol=1e-5)


def test_mtp_loss_present(rng_key):
    p = T.init_params(rng_key, MLA_CFG)
    toks = jax.random.randint(rng_key, (2, 16), 0, 256)
    loss, m = T.loss_fn(p, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)},
                        MLA_CFG)
    assert "mtp_ce" in m and np.isfinite(float(loss))


def test_moe_grads_flow(rng_key):
    p = T.init_params(rng_key, MLA_CFG)
    toks = jax.random.randint(rng_key, (2, 16), 0, 256)
    g = jax.grad(lambda pp: T.loss_fn(
        pp, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}, MLA_CFG)[0])(p)
    gn = float(jnp.linalg.norm(
        g["moe_blocks"]["moe"]["w_gate"].astype(jnp.float32)))
    assert gn > 0, "expert weights got no gradient"
    rn = float(jnp.linalg.norm(g["moe_blocks"]["moe"]["router"]))
    assert rn > 0, "router got no gradient"


def test_gat_edge_order_invariance(rng_key):
    cfg = gnn.GATConfig(d_in=8, n_classes=3, n_heads=2, d_hidden=4)
    p = gnn.init_params(rng_key, cfg)
    n, e = 20, 60
    src = jax.random.randint(rng_key, (e,), 0, n)
    dst = jax.random.randint(jax.random.fold_in(rng_key, 1), (e,), 0, n)
    x = jax.random.normal(rng_key, (n, 8))
    out1 = gnn.forward(p, x, src, dst, cfg)
    perm = jax.random.permutation(jax.random.fold_in(rng_key, 2), e)
    out2 = gnn.forward(p, x, src[perm], dst[perm], cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-4)


def test_gat_padding_edges_noop(rng_key):
    cfg = gnn.GATConfig(d_in=8, n_classes=3, n_heads=2, d_hidden=4)
    p = gnn.init_params(rng_key, cfg)
    n, e = 10, 30
    src = jax.random.randint(rng_key, (e,), 0, n)
    dst = jax.random.randint(jax.random.fold_in(rng_key, 1), (e,), 0, n)
    x = jax.random.normal(rng_key, (n, 8))
    out1 = gnn.forward(p, x, src, dst, cfg)
    pad = jnp.full((10,), -1, jnp.int32)
    out2 = gnn.forward(p, x, jnp.concatenate([src, pad]),
                       jnp.concatenate([dst, pad]), cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_neighbor_sampler_shapes():
    g = gnn.random_csr_graph(500, 8, 16, 5, seed=0)
    rng = np.random.default_rng(0)
    blk = gnn.sample_block(g, np.arange(32), (5, 3), rng)
    assert blk.feats.shape[0] == blk.src.shape[0]
    assert blk.mask.sum() == 32
    valid = blk.src >= 0
    assert (blk.dst[valid] >= 0).all()
    assert (blk.src[valid] < blk.n_nodes).all()


def test_embedding_bag_ragged_matches_dense(rng_key):
    table = jax.random.normal(rng_key, (50, 8))
    idx = jax.random.randint(rng_key, (6, 5), -1, 50)
    dense = R.embedding_bag(table, idx)
    flat = idx.reshape(-1)
    seg = jnp.repeat(jnp.arange(6), 5)
    ragged = R.embedding_bag_ragged(table, flat, seg, 6)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(ragged), atol=1e-5)


def test_recsys_score_candidates_consistency(rng_key):
    """score_candidates == pointwise forward on tiled inputs."""
    cfg = R.DINConfig(vocab=100, embed_dim=8, seq_len=10, attn_mlp=(8, 4),
                      mlp_dims=(16, 8))
    p = R.din_init(rng_key, cfg)
    hist = jax.random.randint(rng_key, (1, 10), 0, 100)
    cand = jnp.arange(7)
    s1 = R.din_score_candidates(p, hist, cand, cfg)
    s2 = R.din_forward(p, jnp.broadcast_to(hist, (7, 10)), cand, cfg)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-5)
