"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,h,sq,skv,dh,causal,dtype", [
    (2, 4, 128, 128, 64, True, jnp.float32),
    (1, 2, 96, 96, 32, True, jnp.float32),      # non-multiple of block
    (2, 2, 64, 256, 32, False, jnp.float32),    # cross attention
    (1, 1, 128, 128, 128, True, jnp.bfloat16),
    (1, 2, 33, 65, 16, True, jnp.float32),      # odd sizes
])
def test_flash_attention_sweep(b, h, sq, skv, dh, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(sq + skv + dh), 3)
    q = jax.random.normal(ks[0], (b, h, sq, dh), dtype)
    k = jax.random.normal(ks[1], (b, h, skv, dh), dtype)
    v = jax.random.normal(ks[2], (b, h, skv, dh), dtype)
    o_ref = ref.flash_attention_ref(q, k, v, causal=causal)
    o_pl = ops.flash_attention(q, k, v, causal=causal, backend="pallas-interpret",
                               block_q=32, block_k=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o_pl, np.float32), np.asarray(o_ref, np.float32),
        atol=tol, rtol=tol)


def test_flash_attention_mla_vdim():
    """MLA: value head dim differs from qk head dim."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 48))
    k = jax.random.normal(ks[1], (1, 2, 64, 48))
    v = jax.random.normal(ks[2], (1, 2, 64, 32))
    o_ref = ref.flash_attention_ref(q, k, v, causal=True)
    o_pl = ops.flash_attention(q, k, v, causal=True, backend="pallas-interpret",
                               block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref), atol=2e-5)


@pytest.mark.parametrize("b,h,s,dh,block", [
    (2, 4, 256, 64, 64),
    (1, 2, 100, 32, 32),
    (3, 1, 512, 128, 256),
])
def test_flash_decode_sweep(b, h, s, dh, block):
    ks = jax.random.split(jax.random.PRNGKey(s + dh), 4)
    q = jax.random.normal(ks[0], (b, h, dh))
    k = jax.random.normal(ks[1], (b, s, h, dh))
    v = jax.random.normal(ks[2], (b, s, h, dh))
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1)
    o_ref = ref.flash_decode_ref(q, k, v, length=lengths)
    o_pl = ops.flash_decode(q, k, v, length=lengths,
                            backend="pallas-interpret", block_k=block)
    np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref), atol=2e-5)


@pytest.mark.parametrize("n,dim,b,k", [(200, 64, 4, 16), (64, 128, 2, 8),
                                       (100, 32, 8, 32)])
def test_gather_l2_sweep(n, dim, b, k):
    key = jax.random.PRNGKey(n + dim)
    corpus = jax.random.normal(key, (n, dim))
    qs = jax.random.normal(jax.random.fold_in(key, 1), (b, dim))
    ids = jax.random.randint(jax.random.fold_in(key, 2), (b, k), -1, n)
    d_ref = ref.l2_gather_dists_ref(corpus, qs, ids)
    d_pl = ops.gather_l2(corpus, qs, ids, backend="pallas-interpret")
    finite = np.isfinite(np.asarray(d_ref))
    np.testing.assert_allclose(np.asarray(d_pl)[finite],
                               np.asarray(d_ref)[finite], rtol=1e-4, atol=1e-4)
    assert (np.isinf(np.asarray(d_pl)) == ~finite).all()


@pytest.mark.parametrize("metric", ["l2", "sqeuclidean", "ip", "cosine"])
@pytest.mark.parametrize("offset,n_local", [(0, 40), (40, 40), (80, 40),
                                            (100, 33)])
def test_gather_score_local_shard(metric, offset, n_local):
    """Shard-local gather→score (Pallas interpret vs ref): owned lanes carry
    the exact unsharded distance, foreign/padding lanes the psum identity 0,
    and summing all shards' partials reconstructs the full wave."""
    key = jax.random.PRNGKey(29)
    n = 120
    corpus = jax.random.normal(key, (n, 24))
    qs = jax.random.normal(jax.random.fold_in(key, 1), (3, 24))
    ids = jax.random.randint(jax.random.fold_in(key, 2), (3, 20), -1, n)
    local = corpus[offset:offset + n_local]
    d_ref = ref.gather_score_local_ref(local, qs, ids, offset, metric=metric)
    d_pl = ops.gather_score_local(local, qs, ids, jnp.int32(offset),
                                  metric=metric,
                                  backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(d_pl), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-4)
    loc = np.asarray(ids) - offset
    owned = (np.asarray(ids) >= 0) & (loc >= 0) & (loc < local.shape[0])
    assert (np.asarray(d_ref)[~owned] == 0.0).all()
    full = np.asarray(ref.gather_score_ref(corpus, qs, ids, metric=metric))
    np.testing.assert_array_equal(np.asarray(d_ref)[owned], full[owned])
    # psum reconstruction: partials over a full 3-shard partition sum to the
    # unsharded wave exactly on owned lanes (0 elsewhere)
    parts = sum(
        np.asarray(ref.gather_score_local_ref(corpus[s:s + 40], qs, ids, s,
                                              metric=metric))
        for s in (0, 40, 80))
    valid = np.asarray(ids) >= 0
    np.testing.assert_array_equal(parts[valid], full[valid])


@pytest.mark.parametrize("metric", ["l2", "sqeuclidean", "ip", "cosine"])
def test_gather_score_metrics(metric):
    """Metric-parameterized fused gather→score vs oracle and core distances."""
    from repro.core import distances

    key = jax.random.PRNGKey(17)
    corpus = jax.random.normal(key, (120, 48))
    qs = jax.random.normal(jax.random.fold_in(key, 1), (3, 48))
    ids = jax.random.randint(jax.random.fold_in(key, 2), (3, 20), -1, 120)
    d_ref = ref.gather_score_ref(corpus, qs, ids, metric=metric)
    d_pl = ops.gather_score(corpus, qs, ids, metric=metric,
                            backend="pallas-interpret")
    finite = np.isfinite(np.asarray(d_ref))
    np.testing.assert_allclose(np.asarray(d_pl)[finite],
                               np.asarray(d_ref)[finite], rtol=1e-4, atol=1e-4)
    assert (np.isinf(np.asarray(d_pl)) == ~finite).all()
    # the engine's EmbeddingMetric path computes the same values
    em = distances.EmbeddingMetric(corpus, metric)
    d_em = em.dists_batch(qs, ids)
    np.testing.assert_allclose(np.asarray(d_ref)[finite],
                               np.asarray(d_em)[finite], rtol=1e-3, atol=1e-4)


def test_merge_pool_batch_payload():
    """Pool merge carries the expanded payload; XLA path == stable oracle,
    Pallas path matches on distances (ties may reorder)."""
    key = jax.random.PRNGKey(3)
    b, P, K = 4, 16, 24
    pi = jax.random.randint(key, (b, P), 0, 500)
    pd = jnp.sort(jax.random.uniform(jax.random.fold_in(key, 1), (b, P)), 1)
    pf = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (b, P))
    ci = jax.random.randint(jax.random.fold_in(key, 3), (b, K), -1, 500)
    cd = jnp.where(ci >= 0,
                   jax.random.uniform(jax.random.fold_in(key, 4), (b, K)),
                   jnp.inf)
    ri, rd, rf = ref.merge_pool_batch_ref(pi, pd, pf, ci, cd)
    xi, xd, xf = ops.merge_pool_batch(pi, pd, pf, ci, cd)
    assert (np.asarray(xi) == np.asarray(ri)).all()
    np.testing.assert_array_equal(np.asarray(xd), np.asarray(rd))
    assert (np.asarray(xf) == np.asarray(rf)).all()
    gi, gd, gf = ops.merge_pool_batch(pi, pd, pf, ci, cd,
                                      backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(gd), np.asarray(rd), atol=1e-6)
    assert (np.asarray(gi) == np.asarray(ri)).all()
    assert (np.asarray(gf) == np.asarray(rf)).all()


def test_merge_pool_batch_masked_wave_noop():
    """An all-masked candidate wave must leave the pool bit-identical —
    the batched engine relies on this to freeze finished queries."""
    key = jax.random.PRNGKey(9)
    b, P, K = 3, 12, 8
    pi = jax.random.randint(key, (b, P), -1, 100)
    pd = jnp.sort(jnp.where(pi >= 0,
                            jax.random.uniform(jax.random.fold_in(key, 1),
                                               (b, P)), jnp.inf), axis=1)
    pi = jnp.where(jnp.isfinite(pd), jnp.abs(pi), -1)
    pf = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (b, P))
    ci = jnp.full((b, K), -1, pi.dtype)
    cd = jnp.full((b, K), jnp.inf)
    oi, od, of = ops.merge_pool_batch(pi, pd, pf, ci, cd)
    assert (np.asarray(oi) == np.asarray(pi)).all()
    np.testing.assert_array_equal(np.asarray(od), np.asarray(pd))
    assert (np.asarray(of) == np.asarray(pf)).all()


@pytest.mark.parametrize("L,K", [(16, 24), (8, 8), (32, 7), (4, 60)])
def test_beam_merge_sweep(L, K):
    key = jax.random.PRNGKey(L * 100 + K)
    b = 3
    bi = jax.random.randint(key, (b, L), 0, 10_000)
    bd = jax.random.uniform(jax.random.fold_in(key, 1), (b, L))
    ci = jax.random.randint(jax.random.fold_in(key, 2), (b, K), 0, 10_000)
    cd = jax.random.uniform(jax.random.fold_in(key, 3), (b, K))
    ri, rd = ref.beam_merge_topk_ref(bi, bd, ci, cd)
    pi_, pd_ = ops.beam_merge_topk(bi, bd, ci, cd,
                                   backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(pd_), np.asarray(rd), atol=1e-6)
    # ids may differ only where distances tie (random uniforms: none)
    assert (np.asarray(pi_) == np.asarray(ri)).all()


@pytest.mark.parametrize("v,d,b,l,mode", [
    (200, 32, 8, 10, "sum"), (200, 32, 8, 10, "mean"),
    (64, 128, 4, 5, "sum"), (1000, 16, 16, 30, "mean"),
])
def test_embedding_bag_sweep(v, d, b, l, mode):
    key = jax.random.PRNGKey(v + d)
    table = jax.random.normal(key, (v, d))
    idx = jax.random.randint(jax.random.fold_in(key, 1), (b, l), -1, v)
    e_ref = ref.embedding_bag_ref(table, idx, mode=mode)
    e_pl = ops.embedding_bag(table, idx, mode=mode,
                             backend="pallas-interpret")
    np.testing.assert_allclose(np.asarray(e_pl), np.asarray(e_ref),
                               rtol=1e-5, atol=1e-5)


def test_xla_fallback_paths():
    """ops.* with use_pallas=False must equal the refs exactly."""
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 2, 16, 8))
    o1 = ops.flash_attention(q, q, q, causal=True)
    o2 = ref.flash_attention_ref(q, q, q, causal=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_local_topk_clamps_and_pads():
    """k > row width (small shard pools) must clamp to the width and pad
    with (-1, +inf) sentinels instead of crashing lax.top_k."""
    ids = jnp.array([[5, 9, 2], [7, 1, 4]], jnp.int32)
    d = jnp.array([[0.3, 0.1, 0.5], [0.9, 0.2, 0.4]], jnp.float32)
    oi, od = ops.local_topk(ids, d, 5)
    assert oi.shape == (2, 5) and od.shape == (2, 5)
    assert np.asarray(oi).tolist() == [[9, 5, 2, -1, -1], [1, 4, 7, -1, -1]]
    np.testing.assert_array_equal(np.asarray(od[:, :3]),
                                  np.sort(np.asarray(d), axis=1))
    assert np.isinf(np.asarray(od[:, 3:])).all()
    # k <= width keeps the historical cut bit-exactly
    oi2, od2 = ops.local_topk(ids, d, 2)
    assert np.asarray(oi2).tolist() == [[9, 5], [1, 4]]


def test_sorted_set_ops():
    """Membership set: ascending invariant, searchsorted lookup, duplicate
    slots preserved, distinct count collapses them."""
    pad = int(ops.SET_PAD)
    s = jnp.full((2, 6), pad, jnp.int32)
    wave1 = jnp.array([[4, 9, 1], [7, 7, 2]], jnp.int32)
    s = ops.sorted_set_merge(s, wave1)
    assert np.asarray(s).tolist() == [
        [1, 4, 9, pad, pad, pad], [2, 7, 7, pad, pad, pad]]
    hit = ops.sorted_set_lookup(s, jnp.array([[4, 5, -1], [7, 8, 2]],
                                             jnp.int32))
    assert np.asarray(hit).tolist() == [[True, False, False],
                                        [True, False, True]]
    # second wave: masked lanes ride as SET_PAD, order stays ascending
    s = ops.sorted_set_merge(s, jnp.array([[3, pad], [pad, 11]], jnp.int32))
    assert np.asarray(s).tolist() == [
        [1, 3, 4, 9, pad, pad], [2, 7, 7, 11, pad, pad]]
    # duplicate slots (the E=1 duplicate-lane quirk) collapse in the count
    assert np.asarray(ops.sorted_set_unique_count(s)).tolist() == [4, 3]


@pytest.mark.parametrize("metric", ["l2", "sqeuclidean", "ip", "cosine"])
def test_gather_score_matmul_tile(metric):
    """The matmul-form scoring tile (norms operand) under interpret=True:
    same values as the gather-then-reduce oracle for every metric, padding
    lanes still +inf."""
    from repro.kernels import l2_topk

    key = jax.random.PRNGKey(23)
    corpus = jax.random.normal(key, (90, 32))
    qs = jax.random.normal(jax.random.fold_in(key, 1), (3, 32))
    ids = jax.random.randint(jax.random.fold_in(key, 2), (3, 15), -1, 90)
    view = ops.as_corpus_view(corpus)
    d_pl = l2_topk.gather_score(corpus, qs, ids, metric=metric,
                                norms=l2_topk.pack_norms(view),
                                interpret=True)
    d_ref = ref.gather_score_ref(corpus, qs, ids, metric=metric)
    fin = np.isfinite(np.asarray(d_ref))
    np.testing.assert_allclose(np.asarray(d_pl)[fin], np.asarray(d_ref)[fin],
                               rtol=1e-4, atol=1e-4)
    assert (np.isinf(np.asarray(d_pl)) == ~fin).all()


@pytest.mark.parametrize("metric", ["sqeuclidean", "cosine"])
def test_gather_score_local_matmul_tile(metric):
    """Shard-local matmul tile: owned lanes match the oracle, foreign and
    padding lanes emit the psum identity 0.0 (norms shard with the rows)."""
    from repro.kernels import l2_topk

    key = jax.random.PRNGKey(31)
    n, offset, n_local = 100, 40, 35
    corpus = jax.random.normal(key, (n, 16))
    qs = jax.random.normal(jax.random.fold_in(key, 1), (2, 16))
    ids = jax.random.randint(jax.random.fold_in(key, 2), (2, 12), -1, n)
    local = corpus[offset:offset + n_local]
    view = ops.as_corpus_view(local)
    d_pl = l2_topk.gather_score_local(local, qs, ids, jnp.int32(offset),
                                      metric=metric,
                                      norms=l2_topk.pack_norms(view),
                                      interpret=True)
    d_ref = ref.gather_score_local_ref(local, qs, ids, offset, metric=metric)
    np.testing.assert_allclose(np.asarray(d_pl), np.asarray(d_ref),
                               rtol=1e-4, atol=1e-4)
    loc = np.asarray(ids) - offset
    owned = (np.asarray(ids) >= 0) & (loc >= 0) & (loc < n_local)
    np.testing.assert_array_equal(np.asarray(d_pl)[~owned], 0.0)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float16])
def test_gather_score_half_precision_corpus(dtype):
    """bf16/f16 corpora flow through every backend: the norm cache keeps
    the rows in their storage dtype (no silent f32 corpus copy) and the
    distances agree with the f32 oracle to half-precision tolerance."""
    key = jax.random.PRNGKey(41)
    corpus32 = jax.random.normal(key, (80, 24))
    corpus = corpus32.astype(dtype)
    qs = jax.random.normal(jax.random.fold_in(key, 1), (3, 24))
    ids = jax.random.randint(jax.random.fold_in(key, 2), (3, 11), -1, 80)
    view = ops.as_corpus_view(corpus)
    assert view.rows.dtype == dtype  # the cache must not upcast the corpus
    assert view.sq_norms.dtype == jnp.float32
    d32 = np.asarray(ops.gather_score(corpus32, qs, ids))
    fin = np.isfinite(d32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-2
    for be in ("ref", "xla_matmul", "pallas-interpret"):
        d = np.asarray(ops.gather_score(view, qs, ids, backend=be))
        np.testing.assert_allclose(d[fin], d32[fin], rtol=tol, atol=tol,
                                   err_msg=be)
        assert (np.isinf(d) == ~fin).all(), be


def test_local_topk_preserves_dtype():
    """The per-shard cut must not silently upcast half-precision dists."""
    ids = jnp.array([[5, 9, 2], [7, 1, 4]], jnp.int32)
    for dtype in (jnp.bfloat16, jnp.float16, jnp.float32):
        d = jnp.array([[0.3, 0.1, 0.5], [0.9, 0.2, 0.4]], dtype)
        oi, od = ops.local_topk(ids, d, 5)
        assert od.dtype == dtype
        assert np.asarray(oi).tolist() == [[9, 5, 2, -1, -1],
                                           [1, 4, 7, -1, -1]]
        assert np.isinf(np.asarray(od, np.float32)[:, 3:]).all()


def test_merge_preserves_dtype():
    """Pool merges (stable XLA cut and the fused bitonic network) keep the
    distances' input dtype end to end."""
    key = jax.random.PRNGKey(13)
    b, P, K = 2, 8, 6
    pi = jax.random.randint(key, (b, P), 0, 99)
    pf = jnp.zeros((b, P), bool)
    ci = jax.random.randint(jax.random.fold_in(key, 1), (b, K), 0, 99)
    for dtype in (jnp.bfloat16, jnp.float16):
        pd = jnp.sort(jax.random.uniform(key, (b, P)), 1).astype(dtype)
        cd = jax.random.uniform(jax.random.fold_in(key, 2),
                                (b, K)).astype(dtype)
        xi, xd, xf = ops.merge_pool_batch(pi, pd, pf, ci, cd)
        assert xd.dtype == dtype
        gi, gd = ops.beam_merge_topk(pi, pd, ci, cd,
                                     backend="pallas-interpret")
        assert gd.dtype == dtype
        # same multiset of distances as the stable cut (ties may reorder)
        np.testing.assert_array_equal(
            np.sort(np.asarray(gd, np.float32), 1),
            np.sort(np.asarray(xd, np.float32)[:, :P], 1))
