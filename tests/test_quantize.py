"""Quantized corpus residency: round-trip bounds, one-build discipline, parity.

The contract (see ``repro/kernels/__init__.py``): ``as_corpus_view(corpus,
quantize="int8"|"fp8")`` builds a lossy *proxy* residency — int8 rows with a
per-row affine scale/zero-point, fp8 rows with a per-row symmetric scale —
scored identically by all three backends through one dequant semantics
(``ref.dequant_rows_ref``). Quantization error folds into the bi-metric
C-approximation factor of the cheap stage; the exact stage never quantizes.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import beam, distances, metrics, vamana
from repro.kernels import backend as kernel_backend
from repro.kernels import ops
from repro.kernels import ref as kernel_ref

ROOT = os.path.join(os.path.dirname(__file__), "..")

BACKENDS = ("ref", "xla_matmul", "pallas-interpret")
FP8_MODES = tuple(sorted(kernel_backend._FP8_DTYPES))


def _rows(seed=0, n=64, dim=24, scale=3.0):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(n, dim)) * scale).astype(np.float32)
    x[-2:] = 0.0  # zero rows: the shard-padding shape
    return jnp.asarray(x)


# ----------------------------------------------------------- round trips
def test_int8_round_trip_error_bound():
    """|dequant(x) - x| <= s/2 per element: the affine grid's half-step,
    with s = (max - min)/255 per row. Also pins the range guard: every
    code must be representable (no clipping error on top of rounding)."""
    x = _rows(seed=1)
    view = ops.as_corpus_view(x, quantize="int8")
    assert view.quantize == "int8"
    assert view.rows.dtype == jnp.int8
    deq = np.asarray(kernel_ref.dequant_rows_ref(
        view.rows, view.scales, view.zero_points))
    scales = np.asarray(view.scales)
    err = np.abs(deq - np.asarray(x))
    # 1.001 headroom: the bound itself is computed in f32
    assert (err <= 0.5001 * scales[:, None] + 1e-7).all(), err.max()
    # norms were computed over the dequantized rows (lossy-proxy semantics)
    np.testing.assert_allclose(np.asarray(view.sq_norms),
                               (deq ** 2).sum(-1), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", FP8_MODES)
def test_fp8_round_trip_error_bound(mode):
    """fp8 error is *relative* (m mantissa bits -> half-ulp 2^-(m+1)), plus
    one subnormal step of the scaled grid near zero."""
    x = _rows(seed=2)
    view = ops.as_corpus_view(x, quantize=mode)
    assert view.quantize == mode
    assert view.zero_points is None  # symmetric: no zero-point column
    deq = np.asarray(kernel_ref.dequant_rows_ref(view.rows, view.scales))
    rel = {"fp8": 2.0 ** -3, "fp8_e5m2": 2.0 ** -2}[mode]
    dt = kernel_backend._FP8_DTYPES[mode]
    subnormal = float(jnp.finfo(dt).tiny) * np.asarray(view.scales)
    err = np.abs(deq - np.asarray(x))
    bound = rel * np.abs(np.asarray(x)) + subnormal[:, None] + 1e-7
    assert (err <= bound).all(), (err / np.maximum(bound, 1e-12)).max()


@pytest.mark.parametrize("mode", ("int8", *FP8_MODES))
def test_zero_rows_stay_exact(mode):
    """A zero row must dequantize to *exact* zeros (norm 0, finite inverse
    norm, cosine distance exactly 1.0) in every backend — this is what
    makes uneven-shard zero padding safe for quantized views."""
    x = _rows(seed=3, n=10, dim=8)
    view = ops.as_corpus_view(x, quantize=mode)
    zp = view.zero_points
    deq = np.asarray(kernel_ref.dequant_rows_ref(view.rows, view.scales, zp))
    np.testing.assert_array_equal(deq[-2:], 0.0)
    np.testing.assert_array_equal(np.asarray(view.sq_norms[-2:]), 0.0)
    assert np.isfinite(np.asarray(view.inv_norms)).all()
    qs = jnp.asarray(np.random.default_rng(4).normal(size=(2, 8)),
                     jnp.float32)
    ids = jnp.array([[0, 8, 9], [9, 3, -1]], jnp.int32)
    for be in BACKENDS:
        d = np.asarray(ops.gather_score(view, qs, ids, metric="cosine",
                                        backend=be))
        np.testing.assert_allclose(d[0, 1], 1.0, atol=1e-6, err_msg=be)
        np.testing.assert_allclose(d[0, 2], 1.0, atol=1e-6, err_msg=be)
        assert np.isinf(d[1, 2]), be


def test_quantize_mode_validation():
    x = _rows(n=8, dim=4)
    with pytest.raises(ValueError):
        ops.as_corpus_view(x, quantize="int4")
    view = ops.as_corpus_view(x, quantize="int8")
    # requantizing a prebuilt view is never silent
    with pytest.raises(ValueError):
        ops.as_corpus_view(view, quantize="fp8")
    with pytest.raises(ValueError):
        ops.as_corpus_view(ops.as_corpus_view(x), quantize="int8")
    # idempotent with the matching (or unspecified) mode
    assert ops.as_corpus_view(view) is view
    assert ops.as_corpus_view(view, quantize="int8") is view
    with pytest.raises(ValueError):
        kernel_backend.resolve_backend(
            kernel_backend.Backend("xla_matmul", quantize="int8"),
            quantize="fp8")


def test_bytes_per_row_compression():
    """The residency win the bench gates on: int8 code payload is 4x
    smaller than f32; the full per-row residency (codes + norms + dequant
    params) rides along for honesty."""
    x = _rows(n=16, dim=32)
    raw = ops.as_corpus_view(x)
    i8 = ops.as_corpus_view(x, quantize="int8")
    assert raw.bytes_per_row == 32 * 4 + 8
    assert i8.bytes_per_row == 32 * 1 + 8 + 8
    assert (32 * 4) / (32 * 1) == 4.0  # row-payload ratio, the gated number


# ---------------------------------------------------- one build per corpus
def test_view_built_exactly_once_per_corpus(monkeypatch):
    """Every entry point accepts a prebuilt quantized view and never
    rebuilds it: the quantizer must run exactly once (at as_corpus_view)
    across gather_score, a full vamana.search, and a sharded search."""
    calls = {"n": 0}
    real = kernel_backend._quantize_rows_int8

    def counting(rows):
        calls["n"] += 1
        return real(rows)

    monkeypatch.setattr(kernel_backend, "_quantize_rows_int8", counting)
    rng = np.random.default_rng(7)
    n, dim, b = 96, 12, 3
    emb = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
    adj = jnp.asarray(rng.integers(0, n, (n, 5)).astype(np.int32))
    entries = jnp.zeros((b, 1), jnp.int32)

    view = ops.as_corpus_view(emb, quantize="int8")
    assert calls["n"] == 1
    ids = jnp.asarray(rng.integers(0, n, (b, 7), dtype=np.int32))
    for be in BACKENDS:
        ops.gather_score(view, qs, ids, backend=be)
    index = vamana.VamanaIndex(
        adjacency=adj, medoid=0,
        config=vamana.VamanaConfig(max_degree=5, l_build=8))
    vamana.search(index, view, qs, k=5, beam_width=8, quota=20)
    beam.sharded_greedy_search(
        view, adj, qs, entries, shards=1, beam_width=8, pool_size=8,
        quota=20, max_steps=40)
    assert calls["n"] == 1  # prebuilt view: zero rebuilds anywhere
    # and the knob path builds exactly once per call, not once per wave
    vamana.search(index, emb, qs, k=5, beam_width=8, quota=20,
                  quantize="int8")
    assert calls["n"] == 2


# ------------------------------------------------------------ parity grid
@pytest.mark.parametrize("mode", ("int8", *FP8_MODES))
@pytest.mark.parametrize("metric", ("sqeuclidean", "l2", "ip", "cosine"))
def test_quantized_op_grid_matches_quant_oracle(mode, metric):
    """Op-level grid: all three backends score a quantized view identically
    (one dequant semantics) — xla_matmul / pallas-interpret vs the
    quantized ref oracle, all four metrics."""
    x = _rows(seed=5, n=100, dim=24)
    view = ops.as_corpus_view(x, quantize=mode)
    key = jax.random.PRNGKey(9)
    qs = jax.random.normal(key, (4, 24))
    ids = jax.random.randint(jax.random.fold_in(key, 1), (4, 17), -1, 100)
    d_ref = np.asarray(ops.gather_score(view, qs, ids, metric=metric,
                                        backend="ref"))
    d_orc = np.asarray(kernel_ref.gather_score_quant_ref(
        view.rows, view.scales, view.zero_points, qs, ids, metric=metric))
    np.testing.assert_array_equal(d_ref, d_orc)  # ref IS the oracle
    fin = np.isfinite(d_ref)
    for be in ("xla_matmul", "pallas-interpret"):
        d_be = np.asarray(ops.gather_score(view, qs, ids, metric=metric,
                                           backend=be))
        np.testing.assert_allclose(d_be[fin], d_ref[fin], rtol=1e-4,
                                   atol=1e-4, err_msg=(be, mode))
        assert (np.isinf(d_be) == ~fin).all(), (be, mode)


@pytest.mark.slow
def test_quantized_parity_grid_sharded():
    """The acceptance grid on 8 forced host devices: quantized modes ×
    metrics × backends × shards {1, 2, 4}. Within one (backend, mode) the
    sharded run is bit-exact vs unsharded (quant metadata shards with the
    corpus blocks; uneven N exercises the padded rows), and recall@10 at
    the matched quota stays within 0.05 of the exact-residency ref run."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax.numpy as jnp
        import numpy as np
        from repro.core import distances, metrics
        from repro.core.beam import (batched_greedy_search, fused_dist_fn,
                                     sharded_greedy_search)
        from repro.kernels import backend as kernel_backend
        from repro.kernels import ops

        rng = np.random.default_rng(3)
        n, dim, b = 130, 8, 4   # uneven N: shard blocks get padded rows
        adj = rng.integers(0, n, (n, 6)).astype(np.int32)
        adj[rng.random((n, 6)) < 0.2] = -1
        adj = jnp.asarray(adj)
        emb = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
        qs = jnp.asarray(rng.normal(size=(b, dim)).astype(np.float32))
        entries = jnp.broadcast_to(
            jnp.array([0, 64, 100], jnp.int32), (b, 3))

        modes = ["int8"] + sorted(kernel_backend._FP8_DTYPES)[:1]
        for met in ("sqeuclidean", "cosine"):
            true_ids, _ = distances.EmbeddingMetric(emb, met).brute_force(
                qs, 10)
            exact = batched_greedy_search(
                fused_dist_fn(emb, met), adj, qs, entries, n_points=n,
                beam_width=8, pool_size=16, quota=24, max_steps=100)
            rec_exact = np.asarray(metrics.recall_at_k(
                exact.pool_ids[:, :10], true_ids)).mean()
            for mode in modes:
                view = ops.as_corpus_view(emb, quantize=mode)
                for be in ("ref", "xla_matmul", "pallas-interpret"):
                    base = batched_greedy_search(
                        fused_dist_fn(view, met, backend=be), adj, qs,
                        entries, n_points=n, beam_width=8, pool_size=16,
                        quota=24, max_steps=100, backend=be)
                    for shards in (2, 4):
                        res = sharded_greedy_search(
                            view, adj, qs, entries, shards=shards,
                            metric=met, beam_width=8, pool_size=16,
                            quota=24, max_steps=100, backend=be)
                        for name, x, y in zip(base._fields, base, res):
                            assert np.array_equal(
                                np.asarray(x), np.asarray(y)), \\
                                (met, mode, be, shards, name)
                    rec = np.asarray(metrics.recall_at_k(
                        base.pool_ids[:, :10], true_ids)).mean()
                    assert rec >= rec_exact - 0.05, \\
                        (met, mode, be, rec, rec_exact)
                # the raw-corpus + quantize= knob is the same computation
                knob = sharded_greedy_search(
                    emb, adj, qs, entries, shards=2, metric=met,
                    beam_width=8, pool_size=16, quota=24, max_steps=100,
                    backend="xla_matmul", quantize=mode)
                pre = sharded_greedy_search(
                    view, adj, qs, entries, shards=2, metric=met,
                    beam_width=8, pool_size=16, quota=24, max_steps=100,
                    backend="xla_matmul")
                for name, x, y in zip(knob._fields, knob, pre):
                    assert np.array_equal(np.asarray(x), np.asarray(y)), \\
                        (met, mode, name)
            print(met, "OK", flush=True)
        print("QUANT_GRID_OK")
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=ROOT, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "QUANT_GRID_OK" in res.stdout


# --------------------------------------------------------------- bimetric
def test_bimetric_quantize_is_stage1_only():
    """The paper's contract: ``quantize=`` makes the cheap proxy lossy but
    the expensive stage must keep scoring exact residency — the reported
    D-distances of the winning ids match the exact metric bit-for-bit."""
    from repro.core import bimetric

    rng = np.random.default_rng(11)
    n, dim = 200, 16
    emb_d = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    emb_D = emb_d + 0.05 * jnp.asarray(
        rng.normal(size=(n, dim)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(3, dim)).astype(np.float32))
    index = vamana.build(emb_d, vamana.VamanaConfig(
        max_degree=8, l_build=16, build_batch=64, n_rounds=1))
    res = bimetric.bimetric_search(
        None, None, index, qs, qs, n_points=n, quota=48, k=5,
        corpora=(emb_d, emb_D), backend="xla_matmul", quantize="int8")
    em_D = distances.EmbeddingMetric(emb_D)
    exact = np.asarray(
        jax.vmap(lambda q, i: em_D.dists(q, i))(qs, res.ids))
    np.testing.assert_allclose(np.asarray(res.dists), exact, rtol=1e-5,
                               atol=1e-5)
    true_ids, _ = em_D.brute_force(qs, 5)
    rec = np.asarray(metrics.recall_at_k(res.ids, true_ids)).mean()
    assert rec >= 0.8, rec  # lossy stage 1 still seeds the exact stage
