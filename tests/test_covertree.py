import numpy as np
import pytest

from repro.core import covertree


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    x_D = rng.normal(size=(400, 12))
    # proxy: noisy compression (C-approx after scaling)
    proj = rng.normal(size=(12, 5)) / np.sqrt(5)
    x_d = x_D @ proj
    return x_d, x_D


def test_cover_invariants(data):
    x_d, _ = data
    t = covertree.build(x_d, T=1.0)
    # separation: members of each cover are >= 2^i/T apart (scaled)
    for j, level in enumerate(t.levels[:-1]):
        r = t.level_scales[j] / t.T
        pts = x_d[level] * t.scale
        if len(level) > 1:
            dm = np.linalg.norm(pts[:, None] - pts[None], axis=-1)
            np.fill_diagonal(dm, np.inf)
            assert dm.min() > r * 0.999, f"level {j}"
    # root covers everything
    assert len(t.levels[0]) >= 1
    assert len(t.levels[-1]) == t.n


def test_search_exact_same_metric(data):
    x_d, _ = data
    t = covertree.build(x_d, T=1.0)
    q = x_d[17] + 0.01
    ids, dists, calls = covertree.search(
        t, lambda i: np.linalg.norm(x_d[i] - q, axis=-1), eps=0.25, k=1)
    true = np.argmin(np.linalg.norm(x_d - q, axis=-1))
    true_d = np.linalg.norm(x_d - q, axis=-1).min()
    assert dists[0] <= (1 + 0.25) * true_d + 1e-9
    assert calls < 400  # sub-linear in practice


def test_bimetric_cover_tree(data):
    """Build on proxy d (T=C), search with D: 1+eps accuracy wrt D."""
    x_d, x_D = data
    # measure C between the two metrics on sampled pairs
    rng = np.random.default_rng(1)
    ii = rng.integers(0, 400, 200)
    jj = rng.integers(0, 400, 200)
    dd = np.linalg.norm(x_d[ii] - x_d[jj], axis=-1) + 1e-9
    DD = np.linalg.norm(x_D[ii] - x_D[jj], axis=-1) + 1e-9
    ratio = DD / dd
    C = float(ratio.max() / ratio.min())
    t = covertree.build(x_d * ratio.min(), T=min(C, 8.0))
    q_D = x_D[33] + 0.05
    ids, dists, calls = covertree.search(
        t, lambda i: np.linalg.norm(x_D[i] - q_D, axis=-1), eps=0.5, k=1)
    true_d = np.linalg.norm(x_D - q_D, axis=-1).min()
    # generous slack: C is an empirical estimate on sampled pairs
    assert dists[0] <= (1 + 0.5) * true_d * 1.5 + 1e-9
    assert calls < 400
