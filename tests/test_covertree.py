import numpy as np
import pytest

from repro.core import covertree


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    x_D = rng.normal(size=(400, 12))
    # proxy: noisy compression (C-approx after scaling)
    proj = rng.normal(size=(12, 5)) / np.sqrt(5)
    x_d = x_D @ proj
    return x_d, x_D


def test_cover_invariants(data):
    x_d, _ = data
    t = covertree.build(x_d, T=1.0)
    # separation: members of each cover are >= 2^i/T apart (scaled)
    for j, level in enumerate(t.levels[:-1]):
        r = t.level_scales[j] / t.T
        pts = x_d[level] * t.scale
        if len(level) > 1:
            dm = np.linalg.norm(pts[:, None] - pts[None], axis=-1)
            np.fill_diagonal(dm, np.inf)
            assert dm.min() > r * 0.999, f"level {j}"
    # root covers everything
    assert len(t.levels[0]) >= 1
    assert len(t.levels[-1]) == t.n


def test_search_exact_same_metric(data):
    x_d, _ = data
    t = covertree.build(x_d, T=1.0)
    q = x_d[17] + 0.01
    ids, dists, calls = covertree.search(
        t, lambda i: np.linalg.norm(x_d[i] - q, axis=-1), eps=0.25, k=1)
    true = np.argmin(np.linalg.norm(x_d - q, axis=-1))
    true_d = np.linalg.norm(x_d - q, axis=-1).min()
    assert dists[0] <= (1 + 0.25) * true_d + 1e-9
    assert calls < 400  # sub-linear in practice


def test_bimetric_cover_tree(data):
    """Build on proxy d (T=C), search with D: 1+eps accuracy wrt D."""
    x_d, x_D = data
    # measure C between the two metrics on sampled pairs
    rng = np.random.default_rng(1)
    ii = rng.integers(0, 400, 200)
    jj = rng.integers(0, 400, 200)
    dd = np.linalg.norm(x_d[ii] - x_d[jj], axis=-1) + 1e-9
    DD = np.linalg.norm(x_D[ii] - x_D[jj], axis=-1) + 1e-9
    ratio = DD / dd
    C = float(ratio.max() / ratio.min())
    t = covertree.build(x_d * ratio.min(), T=min(C, 8.0))
    q_D = x_D[33] + 0.05
    ids, dists, calls = covertree.search(
        t, lambda i: np.linalg.norm(x_D[i] - q_D, axis=-1), eps=0.5, k=1)
    true_d = np.linalg.norm(x_D - q_D, axis=-1).min()
    # generous slack: C is an empirical estimate on sampled pairs
    assert dists[0] <= (1 + 0.5) * true_d * 1.5 + 1e-9
    assert calls < 400


# ---------------------------------------------------------------------------
# Flattened layout + batched engine drive (the PR-8 port): parity against
# the frozen per-query NumPy oracle above, across backends and shards.
# ---------------------------------------------------------------------------
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp

from repro.core import beam, bimetric

ROOT = os.path.join(os.path.dirname(__file__), "..")
GRID_EPS = (1.0, 0.5, 0.25)
BACKENDS = ("ref", "xla_matmul", "pallas-interpret")


@pytest.fixture(scope="module")
def flat_parts():
    rng = np.random.default_rng(3)
    n, dim = 300, 12
    corpus = rng.normal(size=(n, dim)).astype(np.float32)  # expensive D
    proj = rng.normal(size=(dim, 5)) / np.sqrt(5)
    x_d = (corpus @ proj).astype(np.float64)               # cheap proxy d
    tree = covertree.build(x_d, T=2.0)
    flat = covertree.flatten(tree)
    queries = rng.normal(size=(8, dim)).astype(np.float32)
    return tree, flat, corpus, queries


def _oracle(tree, corpus, q, *, eps, k, quota=None):
    def D(ids):
        d = corpus[ids].astype(np.float64) - np.asarray(q, np.float64)
        return np.sqrt((d * d).sum(-1))
    return covertree.search(tree, D, eps=eps, k=k, quota=quota)


def test_flatten_invariants(flat_parts):
    tree, flat, _, _ = flat_parts
    l1 = tree.depth - 1
    assert flat.children.shape[0] == l1 and flat.depth == tree.depth
    np.testing.assert_array_equal(flat.root_ids,
                                  np.asarray(tree.levels[0], np.int32))
    np.testing.assert_allclose(
        flat.radii, np.asarray(tree.level_scales[:l1]) / tree.scale)
    for j in range(l1):
        for p in tree.levels[j]:
            row = flat.children[j, int(p)]
            row = row[row >= 0]
            want = np.union1d(tree.children[j].get(int(p), []), [int(p)])
            np.testing.assert_array_equal(row, want.astype(np.int32))
            assert np.all(np.diff(row) > 0)  # ascending, no dups
        # rows of points absent from level j are fully padded
        absent = np.setdiff1d(np.arange(tree.n), tree.levels[j])
        assert (flat.children[j, absent] == -1).all()


def test_batched_parity_vs_oracle_eps_grid(flat_parts):
    """Batched descent == per-query oracle on neighbor ids AND memoized
    D-call counts, at every eps; every kernel backend is bit-identical."""
    tree, flat, corpus, queries = flat_parts
    for eps in GRID_EPS:
        ref = None
        for be in BACKENDS:
            res = covertree.search_corpus(
                flat, corpus, queries, eps=eps, k=10, backend=be)
            ids = np.asarray(res.ids)
            calls = np.asarray(res.n_calls)
            if ref is None:
                ref = (ids, calls)
                for i, q in enumerate(queries):
                    oids, _, ocalls = _oracle(tree, corpus, q, eps=eps, k=10)
                    got = ids[i][ids[i] >= 0]
                    assert list(got) == list(oids[:len(got)]), (eps, i)
                    assert calls[i] == ocalls, (eps, i)
            else:
                np.testing.assert_array_equal(ids, ref[0],
                                              err_msg=f"{eps}/{be}")
                np.testing.assert_array_equal(calls, ref[1],
                                              err_msg=f"{eps}/{be}")


def test_quota_call_counts_match_oracle(flat_parts):
    """The D-call budget is enforced exactly: the engine's memoized counts
    equal the oracle's at every quota (both admit min(quota, demand))."""
    tree, flat, corpus, queries = flat_parts
    for quota in (1, 7, 40, 120):
        res = covertree.search_corpus(
            flat, corpus, queries, eps=0.5, k=10, quota=quota)
        calls = np.asarray(res.n_calls)
        assert (calls <= quota).all()
        for i, q in enumerate(queries):
            _, _, ocalls = _oracle(tree, corpus, q, eps=0.5, k=10,
                                   quota=quota)
            assert calls[i] == ocalls, (quota, i)


def test_bimetric_search_covertree_dispatch(flat_parts):
    """bimetric_search(index=FlatCoverTree) routes to the cover-tree
    descent: the corpora form and the callable form agree exactly."""
    tree, flat, corpus, queries = flat_parts
    corpus_j = jnp.asarray(corpus)

    def exp_one(q_ctx, ids):
        d = corpus_j[jnp.maximum(ids, 0)] - q_ctx[None, :]
        out = jnp.sqrt(jnp.sum(d * d, -1))
        return jnp.where(ids >= 0, out, jnp.inf)

    res_c = bimetric.bimetric_search(
        None, None, flat, None, jnp.asarray(queries),
        n_points=flat.n, quota=120, k=10,
        corpora=(corpus, corpus), eps=0.5)
    res_f = bimetric.bimetric_search(
        None, exp_one, flat, None, jnp.asarray(queries),
        n_points=flat.n, quota=120, k=10, eps=0.5)
    np.testing.assert_array_equal(np.asarray(res_c.ids),
                                  np.asarray(res_f.ids))
    np.testing.assert_array_equal(np.asarray(res_c.D_calls),
                                  np.asarray(res_f.D_calls))
    assert (np.asarray(res_c.d_calls) == 0).all()
    # and the dispatch agrees with the oracle (untruncating quota: under
    # truncation only the call *counts* are pinned, not the id sets)
    res_full = bimetric.bimetric_search(
        None, None, flat, None, jnp.asarray(queries),
        n_points=flat.n, quota=flat.n, k=10,
        corpora=(corpus, corpus), eps=0.5)
    ids = np.asarray(res_full.ids)
    calls = np.asarray(res_full.D_calls)
    for i, q in enumerate(queries):
        oids, _, ocalls = _oracle(tree, corpus, q, eps=0.5, k=10)
        got = ids[i][ids[i] >= 0]
        assert list(got) == list(oids[:len(got)]), i
        assert calls[i] == ocalls, i


def _run(body: str) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=ROOT, timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


@pytest.mark.slow
def test_sharded_parity():
    """shards in {1, 2, 4}: the mesh-stepped descent is bit-exact vs the
    single-device host drive (the fused single-device path may differ in
    dists by fp fusion only — ids and call counts are identical)."""
    out = _run("""
        from repro.core import beam, covertree
        rng = np.random.default_rng(3)
        corpus = rng.normal(size=(300, 12)).astype(np.float32)
        proj = rng.normal(size=(12, 5)) / np.sqrt(5)
        x_d = (corpus @ proj).astype(np.float64)
        tree = covertree.build(x_d, T=2.0)
        flat = covertree.flatten(tree)
        queries = rng.normal(size=(8, 12)).astype(np.float32)
        fn = beam.fused_dist_fn(jnp.asarray(corpus), "l2")
        for eps in (1.0, 0.5, 0.25):
            ref = covertree.search_batched(
                flat, fn, queries, eps=eps, k=10, quota=120,
                fuse_levels=False)
            for s in (1, 2, 4):
                res = covertree.search_corpus(
                    flat, corpus, queries, eps=eps, k=10, quota=120,
                    shards=s)
                np.testing.assert_array_equal(
                    np.asarray(res.ids), np.asarray(ref.ids))
                np.testing.assert_array_equal(
                    np.asarray(res.n_calls), np.asarray(ref.n_calls))
                if s > 1:   # in-mesh drive: bit-exact incl. dists
                    np.testing.assert_array_equal(
                        np.asarray(res.dists), np.asarray(ref.dists))
                else:       # fused lax.scan drive: fp-fusion slack only
                    np.testing.assert_allclose(
                        np.asarray(res.dists), np.asarray(ref.dists),
                        rtol=2e-6)
        print("CT_SHARDED_OK")
    """)
    assert "CT_SHARDED_OK" in out


# ------------------------------------------------------------------- serving
@pytest.fixture(scope="module")
def ct_engine():
    from repro.configs import qwen3_0_6b
    from repro.models import transformer as T
    from repro.serve import BiMetricEngine, EmbedTower
    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = T.TransformerConfig(
        name="exp-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=cheap_cfg.vocab, embed_dim=32)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(
        T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
    corpus = np.random.default_rng(0).integers(
        0, cheap_cfg.vocab, (96, 10), dtype=np.int32)
    eng = BiMetricEngine(cheap, expensive, corpus, index="covertree",
                         slots=3)
    yield eng, corpus
    eng.close()


def test_engine_covertree_slot_pool_parity(ct_engine):
    """index="covertree" serves through the slot pool bit-exact vs the
    synchronous query_batch — mixed quotas, ks, quota-0 padding rows, more
    requests than slots."""
    from repro.serve import SearchRequest
    eng, corpus = ct_engine
    rows = [3, 40, 77, 12, 55, 9, 61]
    quotas = [24, 8, 16, 96, 0, 12, 24]
    ks = [10, 5, 10, 10, 5, 3, 10]
    reqs = [SearchRequest(tokens=corpus[r], quota=q, k=kk)
            for r, q, kk in zip(rows, quotas, ks)]
    ref = eng.query_batch(reqs)
    futs = [eng.submit(r) for r in reqs]
    for i, f in enumerate(futs):
        got = f.result(timeout=300)
        assert np.array_equal(got.ids, ref[i].ids), i
        np.testing.assert_array_equal(got.dists, ref[i].dists)
        assert got.stats.D_calls == ref[i].stats.D_calls, i
        assert got.stats.d_calls == 0  # no proxy stage under the tree
    c = eng.counters()
    assert c.completed >= len(reqs) and c.slot_occupancy == 0


def test_engine_covertree_matches_oracle(ct_engine):
    """The served answer IS Algorithm 3: rebuild the same offline tree and
    replay the per-query oracle on the tower metric."""
    from repro.serve import SearchRequest
    eng, corpus = ct_engine
    emb_d = np.asarray(eng.emb_d, np.float64)
    tree = covertree.build(emb_d, T=2.0)
    emb_D = np.asarray(eng.expensive.embed(corpus))
    rows = [3, 40, 77]
    reqs = [SearchRequest(tokens=corpus[r], quota=96, k=5) for r in rows]
    got = eng.query_batch(reqs)
    q_D = np.asarray(eng.expensive.embed(np.stack(
        [corpus[r] for r in rows])))
    for i, res in enumerate(got):
        def D(ids, qv=q_D[i]):
            d = emb_D[ids].astype(np.float32) - qv.astype(np.float32)
            return np.sqrt((d * d).sum(-1)).astype(np.float64)
        oids, _, ocalls = covertree.search(tree, D, eps=eng.ct_eps, k=5,
                                           quota=96)
        assert list(res.ids) == list(oids[:len(res.ids)]), i
        assert res.stats.D_calls == ocalls, i


def test_engine_covertree_rerank_raises(ct_engine):
    eng, corpus = ct_engine
    with pytest.raises(ValueError, match="vamana"):
        eng.rerank_query_batch(corpus[:2], quota=8, k=5)
