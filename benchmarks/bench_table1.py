"""Table 1 analogue: the tower pairs available to the bi-metric system, with
parameter counts and embedding dims (computed from the actual configs)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs import get_arch


def _count(spec) -> tuple[int, int]:
    cfg = spec.make_config(False)
    abstract = jax.eval_shape(
        lambda k: spec.init_params(k, cfg), jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))
    return n, getattr(cfg, "embed_dim", 0)


def run() -> None:
    for name in ["qwen3-0.6b", "granite-20b", "deepseek-coder-33b",
                 "granite-moe-3b-a800m", "deepseek-v3-671b",
                 "sfr-mistral-7b"]:
        n, ed = _count(get_arch(name))
        emit(f"table1/{name}", 0.0, f"params={n/1e9:.3f}B;embed_dim={ed}")


if __name__ == "__main__":
    run()
