"""Theorem B.3 instantiation: Cover Tree built on d, searched with D —
expensive-call counts vs accuracy, next to the DiskANN instantiation.

Two query drives over the same offline tree: the frozen per-query NumPy
oracle (``covertree.search``, the parity reference) and the batched engine
(``covertree.search_batched`` — ``plan_step``/``commit_scores`` waves at
B=32, the fused gather→score closure built once so the level programs stay
jit-warm across the ε grid). The gateable ``result`` dict carries batched
recall@10 and mean D-calls at the paper's ε grid plus the batched-vs-NumPy
wall ratio at B=32.

Operating point: the theorem wants the tree built at ``T = C``, but the
measured expansion constant of this synthetic dataset (``c_estimate`` ≈ 21,
emitted below) degenerates at n=2048 — a T=8 tree already memoizes ~95% of
the corpus per query, a linear scan in tree clothing. The bench builds at
``T = 3.0``, where the descent actually prunes (~23% of the corpus
memoized) while holding recall@10 ≈ 0.99, and records the theorem-vs-
practice gap in the emitted rows. The pool is right-sized to the observed
memoization demand; ``max(n_calls) < P`` is asserted each run, which by
P-invariance witnesses that the truncated pool changed nothing.

``speedup_at_32`` is a drift tracker, not a victory lap: on a small-n CPU
host the slab waves score ``fanout``-padded lanes (most lanes -1) that the
per-query loop never materializes, so the honest ratio sits below 1. What
the batched drive buys is device residency — shards/backends and the slot
pool ride it unchanged — and the gate guards the drive against getting
*slower* from here.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Setup, emit
from repro.core import beam, covertree

_T = 3.0
_POOL = 1024  # next pow2 above the observed per-query memoization demand


def run() -> dict:
    setup = Setup(n=2048, n_queries=32)
    x_d = np.asarray(setup.data.corpus_d, np.float64)
    x_D = np.asarray(setup.data.corpus_D, np.float64)
    x_D32 = np.asarray(setup.data.corpus_D, np.float32)
    qs = np.asarray(setup.data.queries_D, np.float64)
    qs32 = np.asarray(setup.data.queries_D, np.float32)
    true = np.asarray(setup.true_ids)
    t0 = time.perf_counter()
    tree = covertree.build(x_d, T=_T)
    build_us = (time.perf_counter() - t0) * 1e6
    emit("covertree/build", build_us,
         f"levels={tree.depth};T={_T};c_estimate={setup.data.c_estimate:.1f}")
    flat = covertree.flatten(tree)
    emit("covertree/flatten", 0.0,
         f"fanout={flat.fanout};roots={flat.root_ids.shape[0]}")
    # one fused closure for the whole grid — the closure is a jit static of
    # the level program, so rebuilding it per call would retrace every level
    dist_fn = beam.fused_dist_fn(jnp.asarray(x_D32), "l2")

    result: dict = {"eps": {}, "T": _T, "n": setup.n,
                    "c_estimate": float(setup.data.c_estimate)}
    np_wall = 0.0
    batched_wall = 0.0
    recalls_batched = []
    calls_all: list[float] = []
    for eps in (1.0, 0.5, 0.25):
        # frozen per-query NumPy oracle — the timed region wraps the whole
        # query loop: us/query is one covertree.search at this eps
        recalls_np, calls_np = [], []
        t0 = time.perf_counter()
        for qi in range(qs.shape[0]):
            ids, _, calls = covertree.search(
                tree, lambda i, q=qs[qi]: np.linalg.norm(x_D[i] - q, axis=-1),
                eps=eps, k=10)
            recalls_np.append(
                len(set(ids.tolist()) & set(true[qi].tolist())) / 10)
            calls_np.append(calls)
        t_np = time.perf_counter() - t0
        np_wall += t_np
        emit(f"covertree/eps={eps}", t_np * 1e6 / qs.shape[0],
             f"recall@10={np.mean(recalls_np):.4f};"
             f"mean_D_calls={np.mean(calls_np):.0f};n={setup.n}")

        # batched engine, whole B=32 batch as one wave-driven descent
        res = covertree.search_batched(
            flat, dist_fn, qs32, eps=eps, k=10, pool_size=_POOL)
        jax.block_until_ready(res.ids)  # warm the per-eps level programs
        t0 = time.perf_counter()
        res = covertree.search_batched(
            flat, dist_fn, qs32, eps=eps, k=10, pool_size=_POOL)
        ids_b = np.asarray(jax.block_until_ready(res.ids))
        t_b = time.perf_counter() - t0
        batched_wall += t_b
        n_calls = np.asarray(res.n_calls)
        assert int(n_calls.max()) < _POOL, \
            "pool overflow: P-invariance witness violated, grow _POOL"
        rec_b = float(np.mean([
            len(set(ids_b[qi].tolist()) & set(true[qi].tolist())) / 10
            for qi in range(qs.shape[0])]))
        mean_calls = float(np.mean(n_calls))
        recalls_batched.append(rec_b)
        calls_all.append(mean_calls)
        emit(f"covertree/batched/eps={eps}", t_b * 1e6 / qs.shape[0],
             f"recall@10={rec_b:.4f};mean_D_calls={mean_calls:.0f};B=32")
        result["eps"][str(eps)] = {
            "recall_np": float(np.mean(recalls_np)),
            "recall_batched": rec_b,
            "mean_D_calls_np": float(np.mean(calls_np)),
            "mean_D_calls_batched": mean_calls,
        }

    result["recall_at_10"] = float(np.mean(recalls_batched))
    result["mean_D_calls"] = float(np.mean(calls_all))
    result["speedup_at_32"] = float(np_wall / batched_wall)
    emit("covertree/batched/speedup_at_32",
         batched_wall * 1e6 / (3 * qs.shape[0]),
         f"speedup={result['speedup_at_32']:.2f}x;"
         f"recall@10={result['recall_at_10']:.4f}")

    # DiskANN bi-metric at the cover tree's budget, for comparison
    budget = int(np.mean(calls_all))
    t0 = time.perf_counter()
    rec, ndcg, _, _ = setup.run("bimetric", budget)
    run_us = (time.perf_counter() - t0) * 1e6 / qs.shape[0]
    emit(f"covertree/diskann_at_same_budget/Q={budget}", run_us,
         f"recall@10={rec:.4f}")
    result["diskann_at_same_budget"] = {"quota": budget, "recall": rec}
    return result


if __name__ == "__main__":
    run()
