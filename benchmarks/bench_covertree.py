"""Theorem B.3 instantiation: Cover Tree built on d (T=C), searched with D —
expensive-call counts vs accuracy, next to the DiskANN instantiation."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Setup, emit
from repro.core import covertree


def run() -> None:
    setup = Setup(n=2048, n_queries=32)
    x_d = np.asarray(setup.data.corpus_d, np.float64)
    x_D = np.asarray(setup.data.corpus_D, np.float64)
    C = min(setup.data.c_estimate, 8.0)
    t0 = time.perf_counter()
    tree = covertree.build(x_d, T=C)
    build_us = (time.perf_counter() - t0) * 1e6
    emit("covertree/build", build_us, f"levels={tree.depth};T={C:.2f}")
    qs = np.asarray(setup.data.queries_D, np.float64)
    true = np.asarray(setup.true_ids)
    for eps in (1.0, 0.5, 0.25):
        recalls, calls_all = [], []
        # the timed region wraps the actual query loop: us/call is the mean
        # wall clock of one covertree.search query at this eps
        t0 = time.perf_counter()
        for qi in range(qs.shape[0]):
            ids, dists, calls = covertree.search(
                tree, lambda i, q=qs[qi]: np.linalg.norm(x_D[i] - q, axis=-1),
                eps=eps, k=10)
            recalls.append(len(set(ids.tolist()) & set(true[qi].tolist())) / 10)
            calls_all.append(calls)
        us_per_query = (time.perf_counter() - t0) * 1e6 / qs.shape[0]
        emit(f"covertree/eps={eps}", us_per_query,
             f"recall@10={np.mean(recalls):.4f};"
             f"mean_D_calls={np.mean(calls_all):.0f};n={setup.n}")
    # DiskANN bi-metric at the cover tree's budget, for comparison
    budget = int(np.mean(calls_all))
    t0 = time.perf_counter()
    rec, ndcg, _, _ = setup.run("bimetric", budget)
    run_us = (time.perf_counter() - t0) * 1e6 / qs.shape[0]
    emit(f"covertree/diskann_at_same_budget/Q={budget}", run_us,
         f"recall@10={rec:.4f}")


if __name__ == "__main__":
    run()
