"""Figure 2 / Table 1 reproduction: the bi-metric advantage as a function of
the proxy model's quality (bge-micro / gte-small / bge-base analogues with
measured empirical C)."""
from __future__ import annotations

from benchmarks.common import Setup, emit

QUOTAS = (64, 256)
TIERS = ("bge-micro-like", "gte-small-like", "bge-base-like")


def run() -> None:
    for tier in TIERS:
        setup = Setup(quality=tier, n=4096, n_queries=48)
        emit(f"fig2/{tier}/empirical_C", 0.0,
             f"C={setup.data.c_estimate:.2f};index_build_s={setup.build_s:.1f}")
        for q in QUOTAS:
            rb, nb, wb, _ = setup.run("bimetric", q)
            rr, nr, wr, _ = setup.run("rerank", q)
            emit(f"fig2/{tier}/Q={q}", wb * 1e6 / q,
                 f"bimetric_ndcg={nb:.4f};rerank_ndcg={nr:.4f};"
                 f"advantage={nb - nr:+.4f}")


if __name__ == "__main__":
    run()
