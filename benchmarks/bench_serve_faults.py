"""Fault-tolerant serving: goodput and tail latency under injected faults.

Drives the ``BiMetricEngine`` slot pool through seeded fault schedules
(``repro.serve.faults.FaultPlan``) and measures what the fault-tolerance
layer buys:

* **transient sweep** — the same 24-request burst at injected transient
  drain-fault rates {0%, 10%, 30%}. Bounded retry + the doc cache's
  write-after-success idempotence mean every recovered request is
  **bit-exact** vs the fault-free synchronous reference;
  ``goodput_under_faults`` (CI-gated at 1.0, zero tolerance) is the
  fraction of requests at the 10% rate that resolve full-quality and
  bit-exact — the chaos-suite claim as a number. Per-rate p95
  submit→resolve latency rides in the artifact (ungated: retries trade
  tail latency for goodput by design).

* **degraded quality** — a persistent expensive-tower outage under
  ``on_tower_failure="degrade"``: every request resolves with its stage-1
  proxy ranking (``ServeStats.degraded``). ``degraded_recall_at_10``
  (CI-gated, direction higher) is recall@10 of those proxy-only answers
  against the fault-free full bi-metric results — the paper's premise
  (arXiv 2406.02891: the cheap metric C-approximates the ground truth)
  priced as an operational fallback. The two towers here are small
  random-init transformers, so this is the *band* the degraded mode
  lives in on this harness, not a model-quality claim.

Writes ``BENCH_serve_faults.json`` (via benchmarks/run.py, or directly
when executed as a script).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.configs import qwen3_0_6b
from repro.models import transformer as T
from repro.serve import (BiMetricEngine, EmbedTower, FaultPlan, FaultSpec,
                         SearchRequest)

N_DOCS = 256
SEQ = 12
N_REQUESTS = 24
SLOTS = 8
QUOTA = 24
K = 10
FAULT_RATES = (0.0, 0.10, 0.30)
GOODPUT_RATE = 0.10  # the gated point of the sweep
SEED = 17


def _build_parts():
    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = T.TransformerConfig(
        name="exp-bench", n_layers=4, d_model=128, n_heads=8, n_kv_heads=8,
        head_dim=16, d_ff=256, vocab=cheap_cfg.vocab, embed_dim=64)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(
        T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, cheap_cfg.vocab, (N_DOCS, SEQ), dtype=np.int32)
    queries = corpus[rng.integers(0, N_DOCS, N_REQUESTS)].copy()
    queries[:, :4] = rng.integers(0, cheap_cfg.vocab, (N_REQUESTS, 4))
    reqs = [SearchRequest(tokens=q, quota=QUOTA, k=K) for q in queries]
    return cheap, expensive, corpus, reqs


def _burst(eng: BiMetricEngine, reqs):
    eng.reset_doc_cache()
    futs = [eng.submit(r) for r in reqs]
    return [f.result(timeout=600) for f in futs]


def run() -> dict:
    cheap, expensive, corpus, reqs = _build_parts()

    # fault-free synchronous reference: the bit-exactness + recall anchor
    ref_eng = BiMetricEngine(cheap, expensive, corpus)
    ref = ref_eng.query_batch(reqs)
    ref_eng.close()

    sweep = []
    goodput_under_faults = 0.0
    for rate in FAULT_RATES:
        plan = (FaultPlan(seed=SEED, drain=FaultSpec(rate=rate))
                if rate > 0 else None)
        eng = BiMetricEngine(cheap, expensive, corpus, slots=SLOTS,
                             faults=plan, retry_backoff_ms=2.0)
        _burst(eng, reqs[:SLOTS])  # warm (jit, threads), fault stream rides
        res = _burst(eng, reqs)
        lats = np.array([r.stats.latency_ms for r in res])
        good = sum(
            1 for got, want in zip(res, ref)
            if not got.stats.degraded
            and np.array_equal(got.ids, want.ids)
            and np.array_equal(got.dists, want.dists))
        goodput = good / len(reqs)
        c = eng.counters()
        row = {
            "fault_rate": rate,
            "goodput": goodput,
            "latency_p50_ms": float(np.percentile(lats, 50)),
            "latency_p95_ms": float(np.percentile(lats, 95)),
            "retries": c.retries,
            "tower_failures": c.tower_failures,
            "faults_fired": plan.fired("drain") if plan else 0,
        }
        sweep.append(row)
        if rate == GOODPUT_RATE:
            goodput_under_faults = goodput
        emit(f"serve_faults/rate_{int(100 * rate)}",
             row["latency_p95_ms"] * 1e3,
             f"p95_us;goodput={goodput:.3f};retries={c.retries}")
        eng.close()

    # persistent outage, proxy-only serving: price the degraded mode
    plan = FaultPlan(seed=SEED,
                     drain=FaultSpec(rate=1.0, mode="persistent"),
                     embed_queries=FaultSpec(rate=1.0, mode="persistent"))
    eng = BiMetricEngine(cheap, expensive, corpus, slots=SLOTS, faults=plan,
                         on_tower_failure="degrade", retry_backoff_ms=2.0,
                         breaker_threshold=1, breaker_cooldown_ms=60_000.0)
    res = _burst(eng, reqs)
    assert all(r.stats.degraded for r in res), "outage must degrade all"
    recalls = [
        len(set(got.ids.tolist()) & set(want.ids.tolist())) / K
        for got, want in zip(res, ref)]
    degraded_recall = float(np.mean(recalls))
    degraded_lats = np.array([r.stats.latency_ms for r in res])
    health = eng.health()
    eng.close()

    emit("serve_faults/goodput_under_faults", goodput_under_faults * 100,
         f"pct_at_rate_{int(100 * GOODPUT_RATE)}")
    emit("serve_faults/degraded_recall_at_10", degraded_recall * 100,
         f"pct;breaker={health['breaker_state']}")

    return {
        "n_requests": N_REQUESTS,
        "slots": SLOTS,
        "quota": QUOTA,
        "fault_rates": list(FAULT_RATES),
        "sweep": sweep,
        "goodput_under_faults": goodput_under_faults,
        "degraded_recall_at_10": degraded_recall,
        "degraded_latency_p95_ms": float(np.percentile(degraded_lats, 95)),
        "degraded_all": 1.0 if all(r.stats.degraded for r in res) else 0.0,
        "breaker_opens": int(health["breaker_opens"]),
    }


if __name__ == "__main__":
    from benchmarks.common import drain_emitted

    drain_emitted()
    _t0 = time.time()
    _result = run()
    write_bench_json("serve_faults", {  # same schema as benchmarks/run.py
        "bench": "serve_faults",
        "wall_seconds": time.time() - _t0,
        "rows": drain_emitted(),
        "result": _result,
    })
