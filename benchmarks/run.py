"""Benchmark harness — one module per paper table/figure.

Each row: ``name,us_per_call,derived`` CSV. Additionally, every benchmark's
emitted rows (plus whatever dict its ``run()`` returns) are written to a
machine-readable ``BENCH_<slug>.json`` artifact so the perf trajectory is
tracked from PR to PR (``BENCH_OUT_DIR`` overrides the destination). Every
artifact carries an ``env`` stamp (jax version, device platform/kind/count
— see ``benchmarks.common.bench_env``) so baselines from different
toolchains or hardware are distinguishable at a glance.

``--only <slug>[,<slug>...]`` runs a subset by artifact slug — the CI
bench-gate uses ``--only search_perf`` and compares the fresh artifact
against the committed baseline with ``scripts/check_bench.py``.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)  # the `benchmarks` package itself, in script mode


def main() -> None:
    from benchmarks import (bench_bimetric, bench_covertree, bench_model_gap,
                            bench_search_perf, bench_seeding,
                            bench_serve_async, bench_serve_faults,
                            bench_table1, common)

    benches = [
        ("table1", "table1", bench_table1.run),
        ("fig1", "bimetric", bench_bimetric.run),
        ("fig2", "model_gap", bench_model_gap.run),
        ("fig3", "seeding", bench_seeding.run),
        ("covertree", "covertree", bench_covertree.run),
        ("perf", "search_perf", bench_search_perf.run),
        ("serve_async", "serve_async", bench_serve_async.run),
        ("serve_faults", "serve_faults", bench_serve_faults.run),
    ]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None, metavar="SLUG[,SLUG...]",
                    help="run only the benchmarks with these artifact slugs")
    args = ap.parse_args()
    if args.only:
        wanted = set(args.only.split(","))
        unknown = wanted - {slug for _, slug, _ in benches}
        if unknown:
            raise SystemExit(f"unknown bench slug(s): {sorted(unknown)}")
        benches = [b for b in benches if b[1] in wanted]
    print("name,us_per_call,derived")
    failures = []
    for name, slug, fn in benches:
        common.drain_emitted()
        t0 = time.time()
        try:
            result = fn()
            wall = time.time() - t0
            if result is None:
                print(f"WARNING: bench {name!r} returned no result dict — "
                      f"BENCH_{slug}.json will carry result: null, so "
                      "nothing in it is gateable by scripts/check_bench.py",
                      file=sys.stderr)
            print(f"{name}/_wall,{wall*1e6:.0f},seconds={wall:.1f}")
            common.write_bench_json(slug, {
                "bench": name,
                "wall_seconds": wall,
                "rows": common.drain_emitted(),
                "result": result,
            })
        except Exception as e:
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
