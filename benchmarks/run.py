"""Benchmark harness — one module per paper table/figure.

Each row: ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import time
import traceback

sys.path.insert(0, "src")


def main() -> None:
    from benchmarks import (bench_bimetric, bench_covertree, bench_model_gap,
                            bench_search_perf, bench_seeding, bench_table1)

    benches = [
        ("table1", bench_table1.run),
        ("fig1", bench_bimetric.run),
        ("fig2", bench_model_gap.run),
        ("fig3", bench_seeding.run),
        ("covertree", bench_covertree.run),
        ("perf", bench_search_perf.run),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in benches:
        t0 = time.time()
        try:
            fn()
            print(f"{name}/_wall,{(time.time()-t0)*1e6:.0f},seconds="
                  f"{time.time()-t0:.1f}")
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((name, repr(e)))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
