"""Search-engine efficiency: µs per beam step / per query (jitted, CPU), and
kernel-vs-oracle microbenches (interpret mode measures correctness path; on
TPU the Pallas kernels replace the XLA fallbacks)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Setup, emit
from repro.core import distances
from repro.core.beam import greedy_search
from repro.kernels import ops


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run() -> None:
    setup = Setup(n=4096, n_queries=32)
    em = distances.EmbeddingMetric(setup.data.corpus_d)

    def search_batch(queries):
        def one(q):
            r = greedy_search(
                lambda ids: em.dists(q, ids), setup.index_d.adjacency,
                jnp.array([setup.index_d.medoid], jnp.int32),
                n_points=setup.n, beam_width=32, pool_size=32, max_steps=128)
            return r.pool_ids[:10], r.n_calls

        return jax.vmap(one)(queries)

    jfn = jax.jit(search_batch)
    wall = _time(jfn, setup.data.queries_d)
    ids, calls = jfn(setup.data.queries_d)
    per_q = wall / setup.data.queries_d.shape[0]
    per_call = wall / float(np.asarray(calls).sum())
    emit("perf/query_latency", per_q * 1e6, f"us_per_query;beam=32")
    emit("perf/distance_call", per_call * 1e6,
         f"us_per_d_call;mean_calls={float(np.asarray(calls).mean()):.0f}")

    # kernel micro-benches (XLA path = production CPU path; pallas path is
    # interpret-mode, correctness-only on CPU)
    corpus = setup.data.corpus_d
    qs = setup.data.queries_d
    idsb = jax.random.randint(jax.random.PRNGKey(0), (32, 24), 0, setup.n)
    f_x = jax.jit(lambda c, q, i: ops.gather_l2(c, q, i))
    emit("perf/gather_l2_xla", _time(f_x, corpus, qs, idsb) * 1e6 / 32,
         "us_per_query_row")
    bi = jax.random.randint(jax.random.PRNGKey(1), (32, 32), 0, setup.n)
    bd = jax.random.uniform(jax.random.PRNGKey(2), (32, 32))
    cd = jax.random.uniform(jax.random.PRNGKey(3), (32, 24))
    f_m = jax.jit(lambda a, b, c, d: ops.beam_merge_topk(a, b, c, d))
    emit("perf/beam_merge_xla", _time(f_m, bi, bd, idsb, cd) * 1e6 / 32,
         "us_per_query_row")


if __name__ == "__main__":
    run()
