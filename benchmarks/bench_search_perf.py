"""Search-engine throughput: retired per-query path vs the batched engine.

The "old" path is the pre-refactor implementation, frozen verbatim in
``repro.core._legacy_beam`` — a ``jax.vmap`` over single-query greedy
searches (one vertex expanded per query per step, stable-argsort merges).
The "new" path is the batched engine stepping the whole query batch through
one fixed-shape hot loop with ``expand_width`` frontier vertices per wave.

Two "old" baselines are reported, because the retired code had two shapes:

* ``old_perquery`` — the serving reality: the pre-refactor engine answered
  queries one at a time (stage 2 was a per-request host loop), so its batch
  throughput is B sequential single-query searches. The headline
  ``speedup_at_32`` is measured against this — it is what the refactor
  changes for the serving path.
* ``old_vmap`` — the pre-refactor core batch path (``jax.vmap`` of the
  single-query search), the strongest form the old engine ever had.

Two scenarios, matching the two halves of the paper's search:

* ``stage2_quota`` — the paper's cost model: quota-bounded search under the
  expensive metric D. Both paths stop at the same exact call budget, so this
  is a pure engine-efficiency comparison (equal work per query).
  ``expand_width=2`` — wider waves spend the fixed budget more greedily and
  cost recall under tight quotas.
* ``stage1_unbounded`` — convergence-bounded search under the cheap proxy d
  (no quota). Runtime depends on query difficulty, so these numbers are
  noisier; ``expand_width=6`` both raises recall and cuts steps here.

Also kernel-vs-oracle microbenches (interpret mode measures the correctness
path; on TPU the Pallas kernels replace the XLA fallbacks), and a
``sharded`` scenario: the same quota-bounded search run device-parallel at
2/4/8 forced host devices (``--xla_force_host_platform_device_count``, in a
subprocess so this process keeps its device view), parity-checked bit-exact
against the single-device engine. On a CPU host the shards share the same
cores, so this tracks collective overhead, not a real speedup — the
trajectory artifact is what CI gates on.

The ``dedup`` scenario compares the engine's two dedup-state backends at
quota 256 on a large random-graph corpus (bit-exact parity asserted):

* ``fused_loop`` — one jitted ``while_loop`` (the stage-1 / bi-metric
  shape). XLA aliases the loop carry, so the (B, N) bitmap's scatter is
  in-place and cheap; the sorted set pays an O(quota) merge per step. The
  bitmap wins this shape on CPU at small/medium N (which is why the fused
  engine's ``dedup="auto"`` keeps it); at the scenario's 1M rows the
  bitmap's O(B·N) init/materialization starts to tell and the two roughly
  tie — recorded for honesty either way.
* ``serve_drive`` — the serving engine's host-driven stage-2 plan/commit
  shape: separate jitted dispatches per step, exactly like
  ``serve.engine``. The non-donated (B, N) bitmap is round-tripped (copied)
  through every dispatch while the sorted set moves (B, quota) — this is
  the path the quota-proportional state was built for, and its
  ``speedup_at_quota_256`` is the gated headline.

The ``matmul`` scenario (see :func:`_matmul_scenario`) compares the two
wave-scoring forms behind the kernel backend knob — gather-then-reduce
(``backend="ref"``) vs MXU-form over the corpus-norm cache
(``backend="xla_matmul"``) — on a 1M-row corpus at B ∈ {1..128};
``result.matmul.speedup_at_32`` (the scoring stage at batch 32) is gated.

The ``quantized`` scenario (see :func:`_quantized_scenario`) runs the same
1M-row waves against int8 / fp8 quantized residency
(``ops.as_corpus_view(corpus, quantize=...)``): recall@10 of the lossy
scoring path against the exact-f32 ranking of the identical wave (matched
quota by construction), scoring-stage speedup, and bytes-per-row.
``result.quantized.recall_at_10`` (int8 fidelity, tolerance 0.05) and
``result.quantized.compression_int8`` (row-payload compression, >= 3.9x)
are gated.

Writes ``BENCH_search_perf.json`` (via benchmarks/run.py, or directly when
executed as a script) — the machine-readable perf trajectory artifact.
"""
from __future__ import annotations

import functools
import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Setup, emit, write_bench_json
from repro.core import _legacy_beam, beam, distances, metrics
from repro.core.beam import batched_greedy_search
from repro.kernels import ops

BATCH_SIZES = (1, 8, 32, 64, 128)
BEAM = 32
K = 10
QUOTA = 128  # stage-2 scenario budget
E_QUOTA = 2  # wave width under a quota (recall-safe)
E_UNBOUNDED = 6  # wave width for convergence-bounded search
SHARD_COUNTS = (2, 4, 8)  # forced host devices for the sharded scenario
SHARD_BATCH = 32
# dedup-backend scenario: a corpus big enough that the (B, N) bitmap's
# round-trips through the host-driven dispatches dominate the fixed
# dispatch cost (at 1M rows each step copies ~2 x 32MB of bitmap; the
# sorted set moves ~32KB) — the quota-proportional win is ~9x here and
# grows with N
DEDUP_N = 1 << 20
DEDUP_QUOTA = 256
DEDUP_BATCH = 32
DEDUP_DEGREE = 16
DEDUP_DIM = 16
# matmul-form wave-scoring scenario (the PR-5 backend rewrite): 1M-row
# corpus at a serving-realistic embedding width, waves of 512 candidate
# lanes (a stage-1 fanout / small rerank block)
MM_N = 1 << 20
MM_DIM = 256
MM_WAVE = 512
MM_BATCHES = (1, 8, 32, 128)


def _time(fn, *args, reps=7):
    """Best-of-reps wall time (robust on shared/noisy CPU hosts)."""
    fn(*args)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _scenario(name, setup, em, queries, true_ids, *, quota, expand_width,
              max_steps):
    """Old-vs-new sweep over batch sizes for one (metric, quota) regime."""
    entries = jnp.array([setup.index_d.medoid], jnp.int32)

    def old_one(q):  # the retired per-query path, frozen verbatim
        r = _legacy_beam.greedy_search(
            lambda ids: em.dists(q, ids), setup.index_d.adjacency,
            entries, n_points=setup.n, beam_width=BEAM, pool_size=BEAM,
            quota=quota, max_steps=max_steps)
        return r.pool_ids[:K], r.n_calls

    def new_search(qs):  # one shared batched hot loop
        b = qs.shape[0]
        r = batched_greedy_search(
            em.dists_batch, setup.index_d.adjacency, qs,
            jnp.broadcast_to(entries, (b, 1)), n_points=setup.n,
            beam_width=BEAM, pool_size=BEAM, quota=quota,
            expand_width=expand_width, max_steps=max_steps)
        return r.pool_ids[:, :K], r.n_calls

    old_one_j = jax.jit(old_one)
    old_vmap_j = jax.jit(jax.vmap(old_one))
    new_j = jax.jit(new_search)

    def old_perquery(qs):  # the retired serving loop: one query at a time
        outs = [old_one_j(q) for q in qs]
        return jax.block_until_ready(outs)[-1]

    batches = {}
    for b in BATCH_SIZES:
        qs = queries[:b]
        wall_pq = _time(old_perquery, qs, reps=3)
        wall_vm = _time(old_vmap_j, qs)
        wall_new = _time(new_j, qs)
        ids_old, calls_old = old_vmap_j(qs)
        ids_new, calls_new = new_j(qs)
        rec_old = float(metrics.recall_at_k(ids_old, true_ids[:b]).mean())
        rec_new = float(metrics.recall_at_k(ids_new, true_ids[:b]).mean())
        speedup_pq = wall_pq / wall_new
        speedup_vm = wall_vm / wall_new
        batches[str(b)] = {
            "qps_old_perquery": b / wall_pq,
            "qps_old_vmap": b / wall_vm,
            "qps_new": b / wall_new,
            "speedup_vs_perquery": speedup_pq,
            "speedup_vs_vmap": speedup_vm,
            "recall_old": rec_old, "recall_new": rec_new,
            "us_per_query_old_perquery": wall_pq / b * 1e6,
            "us_per_query_old_vmap": wall_vm / b * 1e6,
            "us_per_query_new": wall_new / b * 1e6,
            "mean_calls_old": float(np.asarray(calls_old).mean()),
            "mean_calls_new": float(np.asarray(calls_new).mean()),
        }
        emit(f"perf/{name}_old_perquery_b{b}", wall_pq / b * 1e6,
             f"us_per_query;recall={rec_old:.3f}")
        emit(f"perf/{name}_old_vmap_b{b}", wall_vm / b * 1e6,
             f"us_per_query;recall={rec_old:.3f}")
        emit(f"perf/{name}_new_b{b}", wall_new / b * 1e6,
             f"us_per_query;recall={rec_new:.3f}")
        emit(f"perf/{name}_speedup_b{b}", speedup_pq,
             f"x_vs_perquery;x_vs_vmap={speedup_vm:.2f};E={expand_width}")
    return {"expand_width": expand_width, "quota": quota, "batches": batches}


_SHARDED_PROG = """
import os, sys, json, time
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=" + sys.argv[2])
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
from repro.core.beam import sharded_greedy_search

data = np.load(sys.argv[1])
emb, adj = jnp.asarray(data["emb"]), jnp.asarray(data["adj"])
qs, entries = jnp.asarray(data["qs"]), jnp.asarray(data["entries"])
quota, beam, e = int(data["quota"]), int(data["beam"]), int(data["e"])

def timed(shards):
    f = lambda q: sharded_greedy_search(
        emb, adj, q, entries, shards=shards, metric="l2", beam_width=beam,
        pool_size=beam, quota=quota, expand_width=e, max_steps=4 * quota)
    r = jax.block_until_ready(f(qs))  # compile
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(f(qs))
        best = min(best, time.perf_counter() - t0)
    return best, r

base_wall, base = timed(1)
out = {"devices": int(sys.argv[2]), "unsharded_us_per_query":
       base_wall / qs.shape[0] * 1e6, "shards": {}}
for s in (int(x) for x in sys.argv[3].split(",")):
    wall, r = timed(s)
    parity = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(base, r))
    assert parity, f"sharded engine diverged at shards={s}"
    out["shards"][str(s)] = {
        "us_per_query": wall / qs.shape[0] * 1e6,
        "speedup_vs_unsharded": base_wall / wall,
        "parity_bit_exact": parity,
    }
print("RESULT_JSON=" + json.dumps(out))
"""


def _sharded_scenario(setup, em, queries) -> dict:
    """Device-parallel engine at 2/4/8 forced host devices (subprocess)."""
    b = SHARD_BATCH
    entries = jnp.broadcast_to(
        jnp.array([setup.index_d.medoid], jnp.int32), (b, 1))
    ndev = max(SHARD_COUNTS)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "sharded_bench.npz")
        np.savez(path, emb=np.asarray(em.embeddings),
                 adj=np.asarray(setup.index_d.adjacency),
                 qs=np.asarray(queries[:b]), entries=np.asarray(entries),
                 quota=QUOTA, beam=BEAM, e=E_QUOTA)
        res = subprocess.run(
            [sys.executable, "-c", _SHARDED_PROG, path, str(ndev),
             ",".join(str(s) for s in SHARD_COUNTS)],
            capture_output=True, text=True, timeout=1800,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if res.returncode != 0:
        raise RuntimeError(f"sharded scenario failed: {res.stderr[-2000:]}")
    line = next(ln for ln in res.stdout.splitlines()
                if ln.startswith("RESULT_JSON="))
    out = json.loads(line[len("RESULT_JSON="):])
    for s, row in sorted(out["shards"].items(), key=lambda kv: int(kv[0])):
        emit(f"perf/sharded_s{s}_b{b}", row["us_per_query"],
             f"us_per_query;x_vs_unsharded={row['speedup_vs_unsharded']:.2f}"
             f";parity={row['parity_bit_exact']}")
    return out


@functools.partial(jax.jit, static_argnames=(
    "n_points", "pool_size", "dedup", "set_capacity"))
def _dedup_init_j(entry_ids, quota, *, n_points, pool_size, dedup,
                  set_capacity):
    return beam.init_state(
        entry_ids, n_points=n_points, pool_size=pool_size, quota=quota,
        dedup=dedup, set_capacity=set_capacity)


@jax.jit
def _dedup_plan_j(state, adjacency, quota, beam_width, max_steps):
    return beam.plan_step(
        state, adjacency, beam_width=beam_width, quota=quota,
        max_steps=max_steps)


_dedup_commit_j = jax.jit(beam.commit_scores)
_dedup_active_j = jax.jit(lambda s, q, bw, ms: beam.active_mask(
    s, beam_width=bw, quota=q, max_steps=ms).any())


def _dedup_scenario() -> dict:
    """Sorted-set vs bitmap dedup state at quota 256 (both drive shapes)."""
    n, b, quota = DEDUP_N, DEDUP_BATCH, DEDUP_QUOTA
    rng = np.random.default_rng(0)
    adj = jnp.asarray(rng.integers(0, n, (n, DEDUP_DEGREE), dtype=np.int32))
    emb = jnp.asarray(rng.normal(size=(n, DEDUP_DIM)).astype(np.float32))
    qs = jnp.asarray(rng.normal(size=(b, DEDUP_DIM)).astype(np.float32))
    em = distances.EmbeddingMetric(emb)
    entries = jnp.zeros((b, 1), jnp.int32)
    seeds = jnp.asarray(rng.integers(0, n, (b, 8), dtype=np.int32))
    out = {"n": n, "quota": quota, "batch": b}

    # --- fused_loop: one while_loop program per backend (stage-1 shape) ---
    def fused(backend):
        return jax.jit(lambda q: batched_greedy_search(
            em.dists_batch, adj, q, entries, n_points=n, beam_width=BEAM,
            pool_size=BEAM, quota=quota, expand_width=E_QUOTA,
            max_steps=4 * quota, dedup=backend))

    f_bm, f_ss = fused("bitmap"), fused("sorted")
    wall = {"bitmap": _time(f_bm, qs, reps=5),
            "sorted": _time(f_ss, qs, reps=5)}
    r_bm, r_ss = f_bm(qs), f_ss(qs)
    parity = all(np.array_equal(np.asarray(x), np.asarray(y))
                 for x, y in zip(r_bm, r_ss))
    assert parity, "dedup backends diverged in the fused loop"
    out["fused_loop"] = {
        "us_per_query_bitmap": wall["bitmap"] / b * 1e6,
        "us_per_query_sorted": wall["sorted"] / b * 1e6,
        "speedup_sorted_vs_bitmap": wall["bitmap"] / wall["sorted"],
        "parity_bit_exact": parity,
    }
    emit("perf/dedup_fused_q256", wall["sorted"] / b * 1e6,
         f"us_per_query_sorted;x_vs_bitmap="
         f"{out['fused_loop']['speedup_sorted_vs_bitmap']:.2f}")

    # --- serve_drive: host-driven plan/commit dispatches (stage-2 shape) --
    qv = jnp.full((b,), quota, jnp.int32)
    bw = jnp.full((b,), BEAM, jnp.int32)
    ms = jnp.full((b,), 4 * quota, jnp.int32)

    def drive(backend):
        cap = quota if backend == "sorted" else None
        state, safe, keep = _dedup_init_j(
            seeds, qv, n_points=n, pool_size=BEAM, dedup=backend,
            set_capacity=cap)
        while True:
            dists = em.dists_batch(qs, safe)
            state = _dedup_commit_j(state, safe, keep, dists)
            if not bool(_dedup_active_j(state, qv, bw, ms)):
                break
            state, safe, keep, _ = _dedup_plan_j(state, adj, qv, bw, ms)
        return jax.block_until_ready(state)

    def best_of(fn, reps=5):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    s_bm = drive("bitmap")  # compile
    s_ss = drive("sorted")
    dwall = {"bitmap": best_of(lambda: drive("bitmap")),
             "sorted": best_of(lambda: drive("sorted"))}
    dparity = (
        np.array_equal(np.asarray(s_bm.pool_ids), np.asarray(s_ss.pool_ids))
        and np.array_equal(np.asarray(s_bm.pool_dists),
                           np.asarray(s_ss.pool_dists))
        and np.array_equal(np.asarray(s_bm.n_calls),
                           np.asarray(s_ss.n_calls))
        and np.array_equal(np.asarray(s_bm.n_steps),
                           np.asarray(s_ss.n_steps))
        and np.array_equal(
            np.asarray(s_bm.scored),
            np.asarray(beam.scored_set_to_bitmap(s_ss.scored, n))))
    assert dparity, "dedup backends diverged in the serve drive"
    speedup = dwall["bitmap"] / dwall["sorted"]
    out["serve_drive"] = {
        "us_per_query_bitmap": dwall["bitmap"] / b * 1e6,
        "us_per_query_sorted": dwall["sorted"] / b * 1e6,
        "speedup_sorted_vs_bitmap": speedup,
        "parity_bit_exact": dparity,
    }
    # the gated headline: quota-proportional state on the serving stage-2
    # dispatch shape, where the (B, N) bitmap is copied every step
    out["speedup_at_quota_256"] = speedup
    emit("perf/dedup_serve_drive_q256", dwall["sorted"] / b * 1e6,
         f"us_per_query_sorted;x_vs_bitmap={speedup:.2f}")
    return out


def _matmul_scenario() -> dict:
    """MXU-form wave scoring (corpus-norm cache) vs gather-then-reduce.

    Two measurements per batch size, parity-asserted against each other
    (allclose distances AND identical per-wave top-10 ranking — recall@10
    unchanged):

    * ``score_stage`` — both forms score the **same resident wave** (rows
      gathered once, outside the timer): the gather-then-reduce inner
      reduction vs ``‖x‖² − 2·dot_general(rows, q) + ‖q‖²`` with ``‖x‖²``
      from the corpus-norm cache. This isolates exactly the computation
      the backend rewrite changes — the matmul form does ~⅓ fewer flops
      and its reduce is a BLAS/MXU ``dot_general``. The gated headline
      ``speedup_at_32`` comes from here (compute-bound, stable on a noisy
      host).
    * ``fused_op`` — the full ``ops.gather_score`` (ref vs xla_matmul
      backends) on random waves, recorded honestly: on this CPU host XLA
      fuses the row gather *into* the reduce loop (one pass, no (B, K, D)
      temp), while ``dot_general`` forces the gathered operand to
      materialize — so the full op is a memory-bandwidth wash here. On
      TPU the Pallas tile streams rows HBM→VMEM by prefetched id either
      way, which is where the full-op win lands; the trajectory artifact
      records both so that shift is visible when accelerator CI exists.
    """
    rng = np.random.default_rng(7)
    corpus = jnp.asarray(
        rng.normal(size=(MM_N, MM_DIM)).astype(np.float32))
    view = ops.as_corpus_view(corpus)
    jax.block_until_ready(view.sq_norms)

    # the two scoring-stage forms, exactly as the backends lower them
    def score_reduce(qs, rows):
        return ((rows - qs[:, None]) ** 2).sum(-1)

    def score_matmul(qs, rows, sq):
        dots = jax.lax.dot_general(rows, qs, (((2,), (1,)), ((0,), (0,))))
        return jnp.maximum(sq - 2.0 * dots + (qs * qs).sum(-1)[:, None], 0.0)

    f_red = jax.jit(score_reduce)
    f_mm = jax.jit(score_matmul)
    f_op_ref = jax.jit(
        lambda q, i: ops.gather_score(corpus, q, i, backend="ref"))
    f_op_mm = jax.jit(
        lambda q, i: ops.gather_score(view, q, i, backend="xla_matmul"))

    def interleaved(fa, a_args, fb, b_args, reps=9):
        """Best-of with the two forms interleaved (shared host noise)."""
        wa = wb = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fa(*a_args))
            wa = min(wa, time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fb(*b_args))
            wb = min(wb, time.perf_counter() - t0)
        return wa, wb

    out = {"n": MM_N, "dim": MM_DIM, "wave": MM_WAVE, "batches": {}}
    for b in MM_BATCHES:
        qs = jnp.asarray(rng.normal(size=(b, MM_DIM)).astype(np.float32))
        ids = jnp.asarray(
            rng.integers(0, MM_N, (b, MM_WAVE), dtype=np.int32))
        rows = jax.block_until_ready(corpus[ids])
        sq = jax.block_until_ready(view.sq_norms[ids])
        # parity: same distances (fp tolerance) and identical ranking
        d_red = np.asarray(f_red(qs, rows))
        d_mm = np.asarray(f_mm(qs, rows, sq))
        np.testing.assert_allclose(d_mm, d_red, rtol=2e-3, atol=5e-2)
        top_red = np.argsort(d_red, axis=1, kind="stable")[:, :K]
        top_mm = np.argsort(d_mm, axis=1, kind="stable")[:, :K]
        assert np.array_equal(top_red, top_mm), "matmul form changed recall"
        # the shipped op computes the same values as the bench form
        np.testing.assert_allclose(
            np.asarray(f_op_mm(qs, ids)), d_mm, rtol=1e-5, atol=1e-4)
        f_red(qs, rows).block_until_ready()
        f_mm(qs, rows, sq).block_until_ready()
        w_red, w_mm = interleaved(f_red, (qs, rows), f_mm, (qs, rows, sq))
        f_op_ref(qs, ids).block_until_ready()
        f_op_mm(qs, ids).block_until_ready()
        wo_ref, wo_mm = interleaved(f_op_ref, (qs, ids), f_op_mm, (qs, ids),
                                    reps=5)
        speed = w_red / w_mm
        out["batches"][str(b)] = {
            "score_stage_us_reduce": w_red / b * 1e6,
            "score_stage_us_matmul": w_mm / b * 1e6,
            "score_stage_speedup": speed,
            "fused_op_us_ref": wo_ref / b * 1e6,
            "fused_op_us_matmul": wo_mm / b * 1e6,
            "fused_op_speedup": wo_ref / wo_mm,
            "ranking_parity": True,
        }
        emit(f"perf/matmul_score_b{b}", w_mm / b * 1e6,
             f"us_per_query;x_vs_reduce={speed:.2f}"
             f";fused_op_x={wo_ref / wo_mm:.2f}")
    # gated headline: the scoring-stage rewrite at the serving batch size
    out["speedup_at_32"] = out["batches"]["32"]["score_stage_speedup"]
    out["fused_op_speedup_at_32"] = out["batches"]["32"]["fused_op_speedup"]
    return out


def _quantized_scenario() -> dict:
    """Quantized corpus residency (int8 / fp8 rows) vs f32, same 1M corpus.

    Three numbers per batch size, for each quantized mode the host's jax
    build supports:

    * ``recall_at_10`` — the quantized scoring path's wave top-10 against
      the exact-f32 ranking of the *same* wave. Both paths score the
      identical MM_WAVE-candidate set, so the comparison is at matched
      quota by construction; the quantization error is the only difference.
      The int8 mean across batch sizes is the gated headline
      (``result.quantized.recall_at_10``).
    * ``score_stage_speedup`` — interleaved best-of timing of the full
      fused ``ops.gather_score`` (xla_matmul backend) over the f32 view vs
      the quantized view. On this CPU host the dequant epilogue is extra
      ALU work against the same gather traffic, so this hovers near 1x —
      recorded honestly; the bytes-per-row column is where the win is (4x
      less residency = 4x more corpus per device on the accelerator lane).
    * ``bytes_per_row`` — full per-row residency from the view itself
      (codes + norm cache + scale/zero-point). ``compression_int8`` (the
      second gated headline) is the *row-payload* ratio — f32 code bytes
      over quantized code bytes, 4.0x for int8 — because the 8-byte norm
      cache rides both residencies identically and is not part of the
      compression lever; the full-residency ratio is also recorded
      (``residency_compression``) for honesty.
    """
    rng = np.random.default_rng(11)
    corpus = jnp.asarray(
        rng.normal(size=(MM_N, MM_DIM)).astype(np.float32))
    view_f32 = ops.as_corpus_view(corpus)
    views = {"int8": ops.as_corpus_view(corpus, quantize="int8")}
    try:
        views["fp8"] = ops.as_corpus_view(corpus, quantize="fp8")
    except ValueError:  # jax build without float8_e4m3fn
        pass
    jax.block_until_ready(view_f32.sq_norms)

    def fused(v):
        return jax.jit(
            lambda q, i, v=v: ops.gather_score(v, q, i, backend="xla_matmul"))

    f_f32 = fused(view_f32)
    f_q = {m: fused(v) for m, v in views.items()}

    def interleaved(fa, a_args, fb, b_args, reps=7):
        wa = wb = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fa(*a_args))
            wa = min(wa, time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(fb(*b_args))
            wb = min(wb, time.perf_counter() - t0)
        return wa, wb

    row_bytes_f32 = MM_DIM * 4
    out = {
        "n": MM_N, "dim": MM_DIM, "wave": MM_WAVE,
        "modes": sorted(views),
        "bytes_per_row": {"f32": view_f32.bytes_per_row,
                          **{m: v.bytes_per_row for m, v in views.items()}},
        "row_payload_compression": {
            m: row_bytes_f32 / (MM_DIM * v.rows.dtype.itemsize)
            for m, v in views.items()},
        "residency_compression": {
            m: view_f32.bytes_per_row / v.bytes_per_row
            for m, v in views.items()},
        "batches": {},
    }
    recalls = {m: [] for m in views}
    for b in MM_BATCHES:
        qs = jnp.asarray(rng.normal(size=(b, MM_DIM)).astype(np.float32))
        ids = jnp.asarray(
            rng.integers(0, MM_N, (b, MM_WAVE), dtype=np.int32))
        d_exact = np.asarray(f_f32(qs, ids))
        top_exact = np.argsort(d_exact, axis=1, kind="stable")[:, :K]
        rec = {}
        for m in views:
            d_q = np.asarray(f_q[m](qs, ids))
            top_q = np.argsort(d_q, axis=1, kind="stable")[:, :K]
            overlap = np.mean([
                len(set(top_q[r]) & set(top_exact[r])) / K
                for r in range(b)])
            rec[m] = float(overlap)
            recalls[m].append(float(overlap))
        f_f32(qs, ids).block_until_ready()
        f_q["int8"](qs, ids).block_until_ready()
        w_f32, w_i8 = interleaved(f_f32, (qs, ids), f_q["int8"], (qs, ids))
        out["batches"][str(b)] = {
            "score_stage_us_f32": w_f32 / b * 1e6,
            "score_stage_us_int8": w_i8 / b * 1e6,
            "score_stage_speedup": w_f32 / w_i8,
            "recall_at_10": rec,
        }
        emit(f"perf/quantized_score_b{b}", w_i8 / b * 1e6,
             f"us_per_query;x_vs_f32={w_f32 / w_i8:.2f}"
             f";recall@10_int8={rec['int8']:.4f}")
    # gated headlines: int8 fidelity at matched quota, and the residency win
    out["recall_at_10"] = float(np.mean(recalls["int8"]))
    out["recall_at_10_by_mode"] = {
        m: float(np.mean(v)) for m, v in recalls.items()}
    out["compression_int8"] = out["row_payload_compression"]["int8"]
    out["speedup_at_32"] = out["batches"]["32"]["score_stage_speedup"]
    return out


def run() -> dict:
    setup = Setup(n=4096, n_queries=max(BATCH_SIZES))
    em_d = distances.EmbeddingMetric(setup.data.corpus_d)
    em_D = distances.EmbeddingMetric(setup.data.corpus_D)
    true_d, _ = em_d.brute_force(setup.data.queries_d, K)
    true_D, _ = em_D.brute_force(setup.data.queries_D, K)

    stage2 = _scenario(
        "stage2_quota", setup, em_D, setup.data.queries_D, true_D,
        quota=QUOTA, expand_width=E_QUOTA, max_steps=4 * QUOTA)
    stage1 = _scenario(
        "stage1_unbounded", setup, em_d, setup.data.queries_d, true_d,
        quota=_legacy_beam.NO_QUOTA, expand_width=E_UNBOUNDED, max_steps=128)
    sharded = _sharded_scenario(setup, em_D, setup.data.queries_D)
    dedup = _dedup_scenario()
    matmul = _matmul_scenario()
    quantized = _quantized_scenario()

    # kernel micro-benches (XLA path = production CPU path; pallas path is
    # interpret-mode, correctness-only on CPU)
    corpus = setup.data.corpus_d
    qs = setup.data.queries_d[:32]
    idsb = jax.random.randint(jax.random.PRNGKey(0), (32, 24), 0, setup.n)
    f_x = jax.jit(lambda c, q, i: ops.gather_score(c, q, i))
    emit("perf/gather_score_xla", _time(f_x, corpus, qs, idsb) * 1e6 / 32,
         "us_per_query_row")
    bi = jax.random.randint(jax.random.PRNGKey(1), (32, 32), 0, setup.n)
    bd = jax.random.uniform(jax.random.PRNGKey(2), (32, 32))
    cd = jax.random.uniform(jax.random.PRNGKey(3), (32, 24))
    f_m = jax.jit(lambda a, b_, c, d: ops.beam_merge_topk(a, b_, c, d))
    emit("perf/beam_merge_xla", _time(f_m, bi, bd, idsb, cd) * 1e6 / 32,
         "us_per_query_row")

    payload = {
        "beam_width": BEAM,
        "n": setup.n,
        "stage2_quota": stage2,
        "stage1_unbounded": stage1,
        "sharded": sharded,
        "dedup": dedup,
        "matmul": matmul,
        "quantized": quantized,
        # headline: batched engine vs the retired per-query serving loop,
        # on the paper's quota-bounded cost model, at batch 32
        "speedup_at_32": stage2["batches"]["32"]["speedup_vs_perquery"],
        "speedup_at_32_vs_vmap": stage2["batches"]["32"]["speedup_vs_vmap"],
    }
    return payload


if __name__ == "__main__":
    from benchmarks.common import drain_emitted

    drain_emitted()
    _t0 = time.time()
    _result = run()
    write_bench_json("search_perf", {  # same schema as benchmarks/run.py
        "bench": "perf",
        "wall_seconds": time.time() - _t0,
        "rows": drain_emitted(),
        "result": _result,
    })
