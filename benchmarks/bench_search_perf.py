"""Search-engine throughput: retired per-query path vs the batched engine.

The "old" path is the pre-refactor implementation, frozen verbatim in
``repro.core._legacy_beam`` — a ``jax.vmap`` over single-query greedy
searches (one vertex expanded per query per step, stable-argsort merges).
The "new" path is the batched engine stepping the whole query batch through
one fixed-shape hot loop with ``expand_width`` frontier vertices per wave.

Two "old" baselines are reported, because the retired code had two shapes:

* ``old_perquery`` — the serving reality: the pre-refactor engine answered
  queries one at a time (stage 2 was a per-request host loop), so its batch
  throughput is B sequential single-query searches. The headline
  ``speedup_at_32`` is measured against this — it is what the refactor
  changes for the serving path.
* ``old_vmap`` — the pre-refactor core batch path (``jax.vmap`` of the
  single-query search), the strongest form the old engine ever had.

Two scenarios, matching the two halves of the paper's search:

* ``stage2_quota`` — the paper's cost model: quota-bounded search under the
  expensive metric D. Both paths stop at the same exact call budget, so this
  is a pure engine-efficiency comparison (equal work per query).
  ``expand_width=2`` — wider waves spend the fixed budget more greedily and
  cost recall under tight quotas.
* ``stage1_unbounded`` — convergence-bounded search under the cheap proxy d
  (no quota). Runtime depends on query difficulty, so these numbers are
  noisier; ``expand_width=6`` both raises recall and cuts steps here.

Also kernel-vs-oracle microbenches (interpret mode measures the correctness
path; on TPU the Pallas kernels replace the XLA fallbacks).

Writes ``BENCH_search_perf.json`` (via benchmarks/run.py, or directly when
executed as a script) — the machine-readable perf trajectory artifact.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Setup, emit, write_bench_json
from repro.core import _legacy_beam, distances, metrics
from repro.core.beam import batched_greedy_search
from repro.kernels import ops

BATCH_SIZES = (1, 8, 32, 64, 128)
BEAM = 32
K = 10
QUOTA = 128  # stage-2 scenario budget
E_QUOTA = 2  # wave width under a quota (recall-safe)
E_UNBOUNDED = 6  # wave width for convergence-bounded search


def _time(fn, *args, reps=7):
    """Best-of-reps wall time (robust on shared/noisy CPU hosts)."""
    fn(*args)  # compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _scenario(name, setup, em, queries, true_ids, *, quota, expand_width,
              max_steps):
    """Old-vs-new sweep over batch sizes for one (metric, quota) regime."""
    entries = jnp.array([setup.index_d.medoid], jnp.int32)

    def old_one(q):  # the retired per-query path, frozen verbatim
        r = _legacy_beam.greedy_search(
            lambda ids: em.dists(q, ids), setup.index_d.adjacency,
            entries, n_points=setup.n, beam_width=BEAM, pool_size=BEAM,
            quota=quota, max_steps=max_steps)
        return r.pool_ids[:K], r.n_calls

    def new_search(qs):  # one shared batched hot loop
        b = qs.shape[0]
        r = batched_greedy_search(
            em.dists_batch, setup.index_d.adjacency, qs,
            jnp.broadcast_to(entries, (b, 1)), n_points=setup.n,
            beam_width=BEAM, pool_size=BEAM, quota=quota,
            expand_width=expand_width, max_steps=max_steps)
        return r.pool_ids[:, :K], r.n_calls

    old_one_j = jax.jit(old_one)
    old_vmap_j = jax.jit(jax.vmap(old_one))
    new_j = jax.jit(new_search)

    def old_perquery(qs):  # the retired serving loop: one query at a time
        outs = [old_one_j(q) for q in qs]
        return jax.block_until_ready(outs)[-1]

    batches = {}
    for b in BATCH_SIZES:
        qs = queries[:b]
        wall_pq = _time(old_perquery, qs, reps=3)
        wall_vm = _time(old_vmap_j, qs)
        wall_new = _time(new_j, qs)
        ids_old, calls_old = old_vmap_j(qs)
        ids_new, calls_new = new_j(qs)
        rec_old = float(metrics.recall_at_k(ids_old, true_ids[:b]).mean())
        rec_new = float(metrics.recall_at_k(ids_new, true_ids[:b]).mean())
        speedup_pq = wall_pq / wall_new
        speedup_vm = wall_vm / wall_new
        batches[str(b)] = {
            "qps_old_perquery": b / wall_pq,
            "qps_old_vmap": b / wall_vm,
            "qps_new": b / wall_new,
            "speedup_vs_perquery": speedup_pq,
            "speedup_vs_vmap": speedup_vm,
            "recall_old": rec_old, "recall_new": rec_new,
            "us_per_query_old_perquery": wall_pq / b * 1e6,
            "us_per_query_old_vmap": wall_vm / b * 1e6,
            "us_per_query_new": wall_new / b * 1e6,
            "mean_calls_old": float(np.asarray(calls_old).mean()),
            "mean_calls_new": float(np.asarray(calls_new).mean()),
        }
        emit(f"perf/{name}_old_perquery_b{b}", wall_pq / b * 1e6,
             f"us_per_query;recall={rec_old:.3f}")
        emit(f"perf/{name}_old_vmap_b{b}", wall_vm / b * 1e6,
             f"us_per_query;recall={rec_old:.3f}")
        emit(f"perf/{name}_new_b{b}", wall_new / b * 1e6,
             f"us_per_query;recall={rec_new:.3f}")
        emit(f"perf/{name}_speedup_b{b}", speedup_pq,
             f"x_vs_perquery;x_vs_vmap={speedup_vm:.2f};E={expand_width}")
    return {"expand_width": expand_width, "quota": quota, "batches": batches}


def run() -> dict:
    setup = Setup(n=4096, n_queries=max(BATCH_SIZES))
    em_d = distances.EmbeddingMetric(setup.data.corpus_d)
    em_D = distances.EmbeddingMetric(setup.data.corpus_D)
    true_d, _ = em_d.brute_force(setup.data.queries_d, K)
    true_D, _ = em_D.brute_force(setup.data.queries_D, K)

    stage2 = _scenario(
        "stage2_quota", setup, em_D, setup.data.queries_D, true_D,
        quota=QUOTA, expand_width=E_QUOTA, max_steps=4 * QUOTA)
    stage1 = _scenario(
        "stage1_unbounded", setup, em_d, setup.data.queries_d, true_d,
        quota=_legacy_beam.NO_QUOTA, expand_width=E_UNBOUNDED, max_steps=128)

    # kernel micro-benches (XLA path = production CPU path; pallas path is
    # interpret-mode, correctness-only on CPU)
    corpus = setup.data.corpus_d
    qs = setup.data.queries_d[:32]
    idsb = jax.random.randint(jax.random.PRNGKey(0), (32, 24), 0, setup.n)
    f_x = jax.jit(lambda c, q, i: ops.gather_score(c, q, i))
    emit("perf/gather_score_xla", _time(f_x, corpus, qs, idsb) * 1e6 / 32,
         "us_per_query_row")
    bi = jax.random.randint(jax.random.PRNGKey(1), (32, 32), 0, setup.n)
    bd = jax.random.uniform(jax.random.PRNGKey(2), (32, 32))
    cd = jax.random.uniform(jax.random.PRNGKey(3), (32, 24))
    f_m = jax.jit(lambda a, b_, c, d: ops.beam_merge_topk(a, b_, c, d))
    emit("perf/beam_merge_xla", _time(f_m, bi, bd, idsb, cd) * 1e6 / 32,
         "us_per_query_row")

    payload = {
        "beam_width": BEAM,
        "n": setup.n,
        "stage2_quota": stage2,
        "stage1_unbounded": stage1,
        # headline: batched engine vs the retired per-query serving loop,
        # on the paper's quota-bounded cost model, at batch 32
        "speedup_at_32": stage2["batches"]["32"]["speedup_vs_perquery"],
        "speedup_at_32_vs_vmap": stage2["batches"]["32"]["speedup_vs_vmap"],
    }
    return payload


if __name__ == "__main__":
    from benchmarks.common import drain_emitted

    drain_emitted()
    _t0 = time.time()
    _result = run()
    write_bench_json("search_perf", {  # same schema as benchmarks/run.py
        "bench": "perf",
        "wall_seconds": time.time() - _t0,
        "rows": drain_emitted(),
        "result": _result,
    })
