"""Shared benchmark setup: synthetic bi-metric corpora at paper-like regimes.

All benchmarks print ``name,us_per_call,derived`` CSV rows (harness contract):
``us_per_call`` is wall-µs per expensive-metric call (or per op for kernel
benches); ``derived`` carries the figure's metric (NDCG/recall/etc.).

Every emitted row is also recorded so ``benchmarks/run.py`` can write one
machine-readable ``BENCH_<slug>.json`` artifact per benchmark (the perf
trajectory across PRs); ``BENCH_OUT_DIR`` overrides the output directory
(default: current working directory, i.e. the repo root under the tier-1
invocation).
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.core import bimetric, distances, metrics, vamana  # noqa: E402
from repro.data.synthetic import make_dataset, proxy_quality_sweep  # noqa: E402

INDEX_CFG = vamana.VamanaConfig(
    max_degree=24, l_build=32, alpha=1.2, pool_size=64, rev_candidates=24,
    build_batch=1024, n_rounds=2,
)

_EMITTED: list[dict] = []


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
    _EMITTED.append({"name": name, "us_per_call": float(us_per_call),
                     "derived": str(derived)})


def drain_emitted() -> list[dict]:
    """Rows emitted since the last drain (run.py snapshots per benchmark)."""
    rows = _EMITTED[:]
    _EMITTED.clear()
    return rows


def _jsonable(obj):
    """Coerce benchmark results (tuple keys, numpy scalars, ...) to JSON."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.ndarray, jax.Array)):
        return np.asarray(obj).tolist()
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    return str(obj)


def bench_env() -> dict:
    """The measurement environment, stamped into every artifact.

    jax version + device kind/count make trajectory artifacts comparable
    across PRs: a speedup measured on a different jax release or device
    class is a different experiment, and the stamp makes that visible in
    the committed baseline instead of reverse-engineering it from git
    archaeology. Host and device memory sizes ride along so bytes-per-row
    results (the quantized-residency scenario) stay comparable across the
    future accelerator bench lane — a compression ratio only means
    something against the memory it was measured to fit.
    """
    dev = jax.devices()[0]
    host_mem = None
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemTotal:"):
                    host_mem = int(line.split()[1]) * 1024
                    break
    except OSError:
        pass
    dev_mem = None
    try:
        stats = dev.memory_stats()
        if stats:
            dev_mem = stats.get("bytes_limit")
    except (AttributeError, NotImplementedError, RuntimeError):
        pass
    return {
        "jax_version": jax.__version__,
        "platform": dev.platform,
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "host_memory_bytes": host_mem,
        "device_memory_bytes": dev_mem,
    }


def write_bench_json(slug: str, payload: dict) -> str:
    """Write ``BENCH_<slug>.json`` to ``BENCH_OUT_DIR`` (default: cwd).

    Every artifact gets the :func:`bench_env` stamp under ``"env"`` (unless
    the caller already provided one).
    """
    out_dir = os.environ.get("BENCH_OUT_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    payload = dict(payload)
    payload.setdefault("env", bench_env())
    path = os.path.join(out_dir, f"BENCH_{slug}.json")
    with open(path, "w") as f:
        json.dump(_jsonable(payload), f, indent=2, sort_keys=True)
    return path


class Setup:
    def __init__(self, *, n=8192, n_queries=64, dim_D=96, quality="bge-micro-like",
                 seed=0, index_cfg=INDEX_CFG):
        kw = proxy_quality_sweep(quality)
        self.data = make_dataset(n=n, n_queries=n_queries, dim_D=dim_D,
                                 seed=seed, **kw)
        self.n = n
        self.quality = quality
        t0 = time.time()
        self.index_d = vamana.build(self.data.corpus_d, index_cfg)
        self.build_s = time.time() - t0
        self.em_d = distances.EmbeddingMetric(self.data.corpus_d)
        self.em_D = distances.EmbeddingMetric(self.data.corpus_D)
        self.true_ids, _ = self.em_D.brute_force(self.data.queries_D, 10)
        self._index_D = None

    @property
    def index_D(self):
        """Single-metric baseline index (built with D; build calls ignored
        per the paper's accounting)."""
        if self._index_D is None:
            self._index_D = vamana.build(self.data.corpus_D, INDEX_CFG)
        return self._index_D

    def run(self, method: str, quota: int, **kw):
        """-> (recall@10, ndcg@10, wall seconds, max D calls)."""
        t0 = time.time()
        if method == "bimetric":
            res = bimetric.bimetric_search(
                lambda q, i: self.em_d.dists(q, i),
                lambda q, i: self.em_D.dists(q, i),
                self.index_d, self.data.queries_d, self.data.queries_D,
                n_points=self.n, quota=quota, k=10, **kw)
            ids, calls = res.ids, res.D_calls
        elif method == "rerank":
            res = bimetric.rerank_search(
                lambda q, i: self.em_d.dists(q, i),
                lambda q, i: self.em_D.dists(q, i),
                self.index_d, self.data.queries_d, self.data.queries_D,
                n_points=self.n, quota=quota, k=10)
            ids, calls = res.ids, res.D_calls
        elif method == "single":
            ids, _, calls = vamana.search(
                self.index_D, self.data.corpus_D, self.data.queries_D,
                k=10, beam_width=max(16, min(quota, 128)), quota=quota)
        else:
            raise ValueError(method)
        jax.block_until_ready(ids)
        wall = time.time() - t0
        rec = float(metrics.recall_at_k(ids, self.true_ids).mean())
        ndcg = float(metrics.ndcg_at_k(ids, self.true_ids).mean())
        return rec, ndcg, wall, int(np.asarray(calls).max())
