"""Figure 1 / Figure 4 reproduction: accuracy vs expensive-call budget Q for
Bi-metric (ours) / Bi-metric-baseline (re-rank) / Single-metric."""
from __future__ import annotations

from benchmarks.common import Setup, emit

QUOTAS = (32, 64, 128, 256, 512, 1024)
METHODS = ("bimetric", "rerank", "single")


def run(setup: Setup | None = None, quotas=QUOTAS) -> dict:
    setup = setup or Setup()
    out = {}
    for method in METHODS:
        for q in quotas:
            rec, ndcg, wall, calls = setup.run(method, q)
            us = wall * 1e6 / max(calls, 1) / setup.data.queries_d.shape[0]
            emit(f"fig1/{method}/Q={q}", us,
                 f"ndcg@10={ndcg:.4f};recall@10={rec:.4f};D_calls={calls}")
            out[(method, q)] = (rec, ndcg)
    # headline check (paper: ours dominates re-rank on nearly all budgets)
    wins = sum(out[("bimetric", q)][1] >= out[("rerank", q)][1] - 1e-9
               for q in quotas)
    emit("fig1/bimetric_wins_frac", 0.0, f"{wins}/{len(quotas)}")
    return out


if __name__ == "__main__":
    run()
