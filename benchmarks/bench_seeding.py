"""Figure 3 reproduction: stage-2 seeding ablation — default entry point
vs top-1 vs top-100 vs top-Q/2 stage-1 seeds."""
from __future__ import annotations

from benchmarks.common import Setup, emit

QUOTAS = (128, 512)


def run(setup: Setup | None = None) -> None:
    setup = setup or Setup(n=4096, n_queries=48)
    for q in QUOTAS:
        variants = {
            "default": dict(use_stage1=False),
            "top1": dict(n_seeds=1),
            "top100": dict(n_seeds=min(100, q)),
            "topQ/2": dict(n_seeds=max(1, q // 2)),
        }
        for name, kw in variants.items():
            rec, ndcg, wall, calls = setup.run("bimetric", q, **kw)
            emit(f"fig3/seed={name}/Q={q}", wall * 1e6 / max(calls, 1),
                 f"ndcg@10={ndcg:.4f};recall@10={rec:.4f}")


if __name__ == "__main__":
    run()
