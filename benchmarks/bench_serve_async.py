"""Async serving pipeline: overlap speedup and request-stream parity.

Drives the same 32-request stream (4 full waves of 8) through the
``BiMetricEngine`` three ways:

* ``sync``  — the synchronous baseline: ``query_batch`` per wave, one wave
  at a time (tower drain and device plan/commit strictly serialized);
* ``pipe1`` — the async pipeline with ``max_inflight=1``: same admission
  machinery, but only one wave in flight, so nothing overlaps — this
  isolates the pipeline's bookkeeping overhead;
* ``pipe2`` — the shipped double buffer (``max_inflight=2``): the
  expensive-tower drain of wave *i* overlaps the device plan/commit of
  wave *i+1*.

Headline ``overlap_speedup`` = best-of-N wall(pipe1) / wall(pipe2) — what
the double buffer alone buys on this stream. On this 2-core CPU host the
tower forward passes and the device hot loop contend for the same cores,
so the measured overlap is a *lower bound* on what real accelerator tiles
(async dispatch, separate tower/search devices) would see; the trajectory
artifact is what CI gates on. ``parity_ok`` asserts the pipelined results
are bit-exact vs the synchronous drive (ids, dists, and per-query budget
accounting) — the gate pins it at 1.0 with zero tolerance.

The pipelined run also reports the per-request wall-clock latency
distribution (``submit()`` → future resolution, stamped by the engine in
``ServeStats.latency_ms``): ``latency_p50_ms`` is CI-gated (direction
*lower*, wide tolerance — 2-core host, contended percentiles) and
``latency_p95_ms`` rides along for the trajectory.

The expensive-tower document cache is reset between timed runs, so every
mode pays the same tower work (the engine-lifetime cache would otherwise
make whichever mode runs second look free).

Writes ``BENCH_serve_async.json`` (via benchmarks/run.py, or directly when
executed as a script).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.configs import qwen3_0_6b
from repro.models import transformer as T
from repro.serve import BiMetricEngine, EmbedTower

N_DOCS = 256
SEQ = 12
N_REQUESTS = 32
WAVE = 8
QUOTA = 24
K = 10
REPS = 3


def _build_parts():
    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    # the expensive tower is deliberately the heavy side (the paper's cost
    # model): 4 layers / d_model 128 vs the smoke cheap tower
    exp_cfg = T.TransformerConfig(
        name="exp-bench", n_layers=4, d_model=128, n_heads=8, n_kv_heads=8,
        head_dim=16, d_ff=256, vocab=cheap_cfg.vocab, embed_dim=64)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(
        T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, cheap_cfg.vocab, (N_DOCS, SEQ), dtype=np.int32)
    queries = corpus[rng.integers(0, N_DOCS, N_REQUESTS)].copy()
    queries[:, :4] = rng.integers(0, cheap_cfg.vocab, (N_REQUESTS, 4))
    return cheap, expensive, corpus, queries


def _run_sync(eng: BiMetricEngine, queries: np.ndarray):
    """Strictly serialized waves: the pre-pipeline serving behavior."""
    out = []
    for s in range(0, len(queries), WAVE):
        ids, dd, st = eng.query_batch(queries[s:s + WAVE], quota=QUOTA, k=K)
        out.extend(_trim(ids[i], dd[i], st[i]) for i in range(ids.shape[0]))
    return out


def _run_async(eng: BiMetricEngine, queries: np.ndarray):
    futs = [eng.submit(q, quota=QUOTA, k=K) for q in queries]
    return [(f.result(timeout=600)) for f in futs]


def _trim(ids_row, dd_row, stat):
    ok = (ids_row >= 0) & np.isfinite(dd_row)
    return ids_row[ok], dd_row[ok], stat


def _timed(fn, eng, queries):
    best, results = float("inf"), None
    for _ in range(REPS):
        eng.reset_doc_cache()
        t0 = time.perf_counter()
        results = fn(eng, queries)
        best = min(best, time.perf_counter() - t0)
    return best, results


def run() -> dict:
    cheap, expensive, corpus, queries = _build_parts()
    eng1 = BiMetricEngine(cheap, expensive, corpus, max_batch=WAVE,
                          max_wait_ms=100.0, max_inflight=1)
    eng2 = BiMetricEngine(cheap, expensive, corpus, max_batch=WAVE,
                          max_wait_ms=100.0, max_inflight=2)

    # warm every drive path once (jit compiles, admission threads)
    _run_sync(eng1, queries[:WAVE])
    _run_async(eng1, queries[:WAVE])
    _run_async(eng2, queries[:WAVE])

    wall_sync, res_sync = _timed(_run_sync, eng1, queries)
    wall_pipe1, res_pipe1 = _timed(_run_async, eng1, queries)
    wall_pipe2, res_pipe2 = _timed(_run_async, eng2, queries)
    eng1.close()
    eng2.close()

    # per-request wall-clock latencies (submit -> future resolution),
    # recorded by the engine in ServeStats.latency_ms — the double-buffered
    # pipeline's serving-latency distribution over the measured stream
    lats = np.array([s.latency_ms for _, _, s in res_pipe2])
    lat_p50 = float(np.percentile(lats, 50))
    lat_p95 = float(np.percentile(lats, 95))

    parity = all(
        np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
        and a[2].D_calls == b[2].D_calls and a[2].d_calls == b[2].d_calls
        for a, b in zip(res_sync, res_pipe2)) and all(
        np.array_equal(a[0], b[0])
        for a, b in zip(res_sync, res_pipe1))
    overlap = wall_pipe1 / wall_pipe2
    vs_sync = wall_sync / wall_pipe2
    max_calls = max(s.D_calls for _, _, s in res_pipe2)

    emit("serve_async/sync_wall", wall_sync / N_REQUESTS * 1e6,
         f"us_per_request;wall_s={wall_sync:.2f}")
    emit("serve_async/pipe1_wall", wall_pipe1 / N_REQUESTS * 1e6,
         f"us_per_request;wall_s={wall_pipe1:.2f}")
    emit("serve_async/pipe2_wall", wall_pipe2 / N_REQUESTS * 1e6,
         f"us_per_request;wall_s={wall_pipe2:.2f}")
    emit("serve_async/overlap_speedup", overlap,
         f"x_pipe1_over_pipe2;x_vs_sync={vs_sync:.2f};parity={parity}")
    emit("serve_async/latency_p50", lat_p50 * 1e3,
         f"us_per_request;p95_ms={lat_p95:.1f}")

    return {
        "n_requests": N_REQUESTS,
        "wave": WAVE,
        "quota": QUOTA,
        "wall_sync_s": wall_sync,
        "wall_pipe1_s": wall_pipe1,
        "wall_pipe2_s": wall_pipe2,
        "us_per_request_pipe2": wall_pipe2 / N_REQUESTS * 1e6,
        "latency_p50_ms": lat_p50,
        "latency_p95_ms": lat_p95,
        "overlap_speedup": overlap,
        "pipeline_vs_sync": vs_sync,
        "max_D_calls": max_calls,
        "parity_ok": 1.0 if parity else 0.0,
    }


if __name__ == "__main__":
    from benchmarks.common import drain_emitted

    drain_emitted()
    _t0 = time.time()
    _result = run()
    write_bench_json("serve_async", {  # same schema as benchmarks/run.py
        "bench": "serve_async",
        "wall_seconds": time.time() - _t0,
        "rows": drain_emitted(),
        "result": _result,
    })
