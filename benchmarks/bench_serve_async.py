"""Continuous-batching serving: open-loop throughput-at-SLO and parity.

Drives the ``BiMetricEngine`` slot pool against the retired fixed-wave
admission discipline on the same request streams:

* **closed 32-burst** — all requests submitted at once. ``slot`` is the
  shipped continuous-batching drive (``submit()`` into a ``slots=8``
  pool); ``waves`` simulates the pre-slot-pool engine honestly: strictly
  serialized ``query_batch`` calls of up to 8, each wave blocking the
  next (head-of-line). ``parity_ok`` asserts the slot drive is bit-exact
  (ids, dists, per-request budget accounting) vs one synchronous
  ``query_batch`` of the whole burst — the gate pins it at 1.0 with zero
  tolerance. ``latency_p50_ms`` / ``latency_p95_ms`` (CI-gated, direction
  lower) are the slot drive's submit→resolve distribution over this
  burst, stamped by the engine in ``ServeStats``.

* **Poisson open-loop sweep** — the serving-shaped measurement. Requests
  arrive on a Poisson clock (same seeded arrival sequence for both
  modes) at offered rates swept as fractions of the measured closed-loop
  service capacity. The slot pool admits each arrival into the first
  freed slot mid-flight; the wave baseline accumulates arrivals into
  fixed waves (flush at 8 or after a 100 ms max-wait — the old engine's
  admission rule) and serves them serially. ``throughput_at_slo`` (the
  headline gate, direction higher) is the highest offered rate, in
  requests/s, whose slot-pool p95 latency stays under the SLO; the SLO is
  four ideal full-wave service times of the measured closed burst, so a
  genuine engine slowdown drags down both the swept rates and the pass
  boundary. The per-rate p95 of both modes rides in the artifact — the
  slot pool's open-loop p95 beating the wave baseline *is* the
  continuous-batching claim (a request no longer waits out its wave-mates
  or a wave boundary).

On this CPU host the towers and the device hot loop contend for the same
cores, so absolute rates are small and the slot-vs-wave gap is a lower
bound on what separate tower/search accelerator tiles would see; the
trajectory artifact is what CI gates on. The expensive-tower document
cache is reset between timed runs so every mode pays the same tower work.

Writes ``BENCH_serve_async.json`` (via benchmarks/run.py, or directly
when executed as a script).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, write_bench_json
from repro.configs import qwen3_0_6b
from repro.models import transformer as T
from repro.serve import BiMetricEngine, EmbedTower, SearchRequest

N_DOCS = 256
SEQ = 12
N_REQUESTS = 32
WAVE = 8  # slot count == the old fixed-wave width: same resident batch
QUOTA = 24
K = 10
REPS = 2
MAX_WAIT_S = 0.1  # the old engine's partial-wave flush deadline
RATE_FRACS = (0.5, 0.75, 1.0)  # offered-rate sweep, x closed-loop capacity
SLO_WAVES = 4.0  # SLO in ideal full-wave service times of the closed burst


def _build_parts():
    key = jax.random.PRNGKey(0)
    # the expensive tower is deliberately the heavy side (the paper's cost
    # model): 4 layers / d_model 128 vs the smoke cheap tower
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = T.TransformerConfig(
        name="exp-bench", n_layers=4, d_model=128, n_heads=8, n_kv_heads=8,
        head_dim=16, d_ff=256, vocab=cheap_cfg.vocab, embed_dim=64)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(
        T.init_params(jax.random.fold_in(key, 1), exp_cfg), exp_cfg)
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, cheap_cfg.vocab, (N_DOCS, SEQ), dtype=np.int32)
    queries = corpus[rng.integers(0, N_DOCS, N_REQUESTS)].copy()
    queries[:, :4] = rng.integers(0, cheap_cfg.vocab, (N_REQUESTS, 4))
    reqs = [SearchRequest(tokens=q, quota=QUOTA, k=K) for q in queries]
    return cheap, expensive, corpus, reqs


# ------------------------------------------------------------ closed burst
def _burst_slot(eng: BiMetricEngine, reqs):
    futs = [eng.submit(r) for r in reqs]
    return [f.result(timeout=600) for f in futs]


def _burst_waves(eng: BiMetricEngine, reqs):
    """The retired admission discipline: serialized full waves of WAVE."""
    out = []
    for s in range(0, len(reqs), WAVE):
        out.extend(eng.query_batch(reqs[s:s + WAVE]))
    return out


def _timed(fn, eng, reqs):
    best, results = float("inf"), None
    for _ in range(REPS):
        eng.reset_doc_cache()
        t0 = time.perf_counter()
        results = fn(eng, reqs)
        best = min(best, time.perf_counter() - t0)
    return best, results


# ------------------------------------------------------- open-loop streams
def _arrivals(rate_rps: float, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_rps, size=n))


def _open_slot(eng: BiMetricEngine, reqs, arrivals) -> np.ndarray:
    """Poisson arrivals into the slot pool; latency stamped by the engine."""
    eng.reset_doc_cache()
    t0 = time.perf_counter()
    futs = []
    for r, ta in zip(reqs, arrivals):
        wait = ta - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(wait)
        futs.append(eng.submit(r))
    res = [f.result(timeout=600) for f in futs]
    return np.array([r.stats.latency_ms for r in res])


def _open_waves(eng: BiMetricEngine, reqs, arrivals) -> np.ndarray:
    """The same arrival sequence through fixed-wave admission: accumulate
    up to WAVE arrivals (or MAX_WAIT_S past the oldest), then one blocking
    query_batch — later arrivals head-of-line-wait behind the wave."""
    eng.reset_doc_cache()
    t0 = time.perf_counter()
    lats = []
    i, n = 0, len(reqs)
    while i < n:
        now = time.perf_counter() - t0
        if arrivals[i] > now:
            time.sleep(arrivals[i] - now)
        cutoff = max(time.perf_counter() - t0,
                     float(arrivals[i]) + MAX_WAIT_S)
        wait = cutoff - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(wait)
        now = time.perf_counter() - t0
        j = i
        while j < n and j - i < WAVE and arrivals[j] <= now:
            j += 1
        eng.query_batch(reqs[i:j])
        tc = time.perf_counter() - t0
        lats.extend(tc - arrivals[m] for m in range(i, j))
        i = j
    return np.array(lats) * 1e3


# ------------------------------------------------------------------ driver
def run() -> dict:
    cheap, expensive, corpus, reqs = _build_parts()
    eng_slot = BiMetricEngine(cheap, expensive, corpus, slots=WAVE,
                              max_wait_ms=5.0)
    eng_wave = BiMetricEngine(cheap, expensive, corpus)

    # warm every drive path once (jit compiles, admission threads)
    _burst_waves(eng_wave, reqs[:WAVE])
    _burst_slot(eng_slot, reqs[:WAVE])
    ref = eng_slot.query_batch(reqs)  # sync parity reference, B=32

    wall_wave, _ = _timed(_burst_waves, eng_wave, reqs)
    wall_slot, res_slot = _timed(_burst_slot, eng_slot, reqs)

    parity = all(
        np.array_equal(a.ids, b.ids) and np.array_equal(a.dists, b.dists)
        and a.stats.D_calls == b.stats.D_calls
        and a.stats.d_calls == b.stats.d_calls
        for a, b in zip(res_slot, ref))
    lats_burst = np.array([r.stats.latency_ms for r in res_slot])
    lat_p50 = float(np.percentile(lats_burst, 50))
    lat_p95 = float(np.percentile(lats_burst, 95))

    # open-loop sweep: offered rates as fractions of the measured
    # closed-loop capacity; SLO = SLO_WAVES ideal full-wave service times
    cap_rps = N_REQUESTS / wall_slot
    slo_ms = SLO_WAVES * (wall_slot / N_REQUESTS) * WAVE * 1e3
    sweep = []
    throughput_at_slo = 0.0
    for idx, frac in enumerate(RATE_FRACS):
        rate = frac * cap_rps
        arr = _arrivals(rate, N_REQUESTS, seed=100 + idx)
        slot_lats = _open_slot(eng_slot, reqs, arr)
        wave_lats = _open_waves(eng_wave, reqs, arr)
        s95 = float(np.percentile(slot_lats, 95))
        w95 = float(np.percentile(wave_lats, 95))
        if s95 <= slo_ms:
            throughput_at_slo = max(throughput_at_slo, rate)
        sweep.append({
            "rate_rps": rate, "rate_frac": frac,
            "slot_p50_ms": float(np.percentile(slot_lats, 50)),
            "slot_p95_ms": s95,
            "wave_p50_ms": float(np.percentile(wave_lats, 50)),
            "wave_p95_ms": w95,
            "p95_gain_vs_waves": w95 / s95,
        })
        emit(f"serve_async/open_loop_{int(100 * frac)}", s95 * 1e3,
             f"slot_p95_us;rate_rps={rate:.2f};wave_p95_ms={w95:.0f}")
    eng_slot.close()
    eng_wave.close()

    mid = sweep[len(sweep) // 2]
    emit("serve_async/burst_wave_wall", wall_wave / N_REQUESTS * 1e6,
         f"us_per_request;wall_s={wall_wave:.2f}")
    emit("serve_async/burst_slot_wall", wall_slot / N_REQUESTS * 1e6,
         f"us_per_request;wall_s={wall_slot:.2f};parity={parity}")
    emit("serve_async/latency_p50", lat_p50 * 1e3,
         f"us_per_request;p95_ms={lat_p95:.1f}")
    emit("serve_async/throughput_at_slo", throughput_at_slo,
         f"rps;slo_ms={slo_ms:.0f};p95_gain_mid={mid['p95_gain_vs_waves']:.2f}")

    return {
        "n_requests": N_REQUESTS,
        "slots": WAVE,
        "quota": QUOTA,
        "wall_wave_burst_s": wall_wave,
        "wall_slot_burst_s": wall_slot,
        "slot_vs_waves_burst": wall_wave / wall_slot,
        "capacity_rps": cap_rps,
        "slo_ms": slo_ms,
        "sweep": sweep,
        "p95_gain_vs_waves_mid": mid["p95_gain_vs_waves"],
        "throughput_at_slo": throughput_at_slo,
        "latency_p50_ms": lat_p50,
        "latency_p95_ms": lat_p95,
        "parity_ok": 1.0 if parity else 0.0,
    }


if __name__ == "__main__":
    from benchmarks.common import drain_emitted

    drain_emitted()
    _t0 = time.time()
    _result = run()
    write_bench_json("serve_async", {  # same schema as benchmarks/run.py
        "bench": "serve_async",
        "wall_seconds": time.time() - _t0,
        "rows": drain_emitted(),
        "result": _result,
    })
