import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf-iteration harness: compile one cell with knob overrides, print the
roofline terms. Used by the §Perf hypothesis→change→measure loop.

  python scripts/perf_cell.py --arch granite-20b --shape train_4k \
      [--multi-pod] [--zero1] [--no-sp] [--ce-chunk N] [--block-kv N]
      [--capacity-factor F] [--moe-groups N] [--cand-pad]
"""
import argparse
import dataclasses
import json
import sys
import time

sys.path.insert(0, "src")
sys.path.insert(0, ".")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--ce-chunk", type=int, default=None)
    ap.add_argument("--block-kv", type=int, default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--moe-groups", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--log", default="results/perf_log.json")
    args = ap.parse_args()

    import jax

    from repro.configs import get_arch
    from repro.distributed import sharding as shr
    from repro.launch import hlo_analysis as H
    from repro.launch.mesh import make_production_mesh

    if args.zero1:
        shr.ZERO_STAGE = 1

    spec = get_arch(args.arch)
    cfg = spec.make_config(False)
    overrides = {}
    if args.no_sp:
        overrides["seq_parallel"] = False
    if args.ce_chunk is not None:
        overrides["ce_chunk"] = args.ce_chunk
    if args.block_kv is not None:
        overrides["block_kv"] = args.block_kv
    if args.capacity_factor is not None:
        overrides["capacity_factor"] = args.capacity_factor
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if args.moe_groups is not None:
        import repro.models.moe as moe_mod
        # thread through MoEConfig default by monkeypatching the cfg builder
        orig = cfg.moe_cfg
        cfg = dataclasses.replace(cfg)
        object.__setattr__(cfg, "_moe_groups", args.moe_groups)
        # MoEConfig n_groups flows from TransformerConfig.moe_cfg — patch:
        import repro.models.transformer as T
        old_moe_cfg = T.TransformerConfig.moe_cfg
        def moe_cfg(self):
            c = old_moe_cfg(self)
            return c._replace(n_groups=args.moe_groups)
        T.TransformerConfig.moe_cfg = moe_cfg

    cell = spec.build_cell(cfg, args.shape)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    t0 = time.time()
    cell_args = cell.abstract_args(mesh)
    dp = (shr.all_axes(mesh) if getattr(cell, "act_axes", "dp") == "all"
          else shr.batch_axes(mesh))
    out_sh = cell.out_shardings(cell_args) if cell.out_shardings else None
    with mesh, shr.activation_mesh(mesh, dp):
        compiled = jax.jit(cell.fn, donate_argnums=cell.donate,
                           out_shardings=out_sh).lower(*cell_args).compile()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
    an = H.analyze(hlo)
    terms = H.roofline_terms(an)
    rec = {
        "cell": f"{args.arch}/{args.shape}",
        "mesh": "multi" if args.multi_pod else "single",
        "tag": args.tag or "baseline",
        "knobs": {k: v for k, v in vars(args).items()
                  if k not in ("arch", "shape", "tag", "log") and v},
        "temp_gb": mem.temp_size_in_bytes / 1e9,
        "args_gb": mem.argument_size_in_bytes / 1e9,
        "compile_s": round(time.time() - t0, 1),
        **{k: v for k, v in an.items() if isinstance(v, float)},
        **terms,
    }
    print(json.dumps(rec, indent=1))
    try:
        data = json.load(open(args.log))
    except (FileNotFoundError, json.JSONDecodeError):
        data = []
    data.append(rec)
    json.dump(data, open(args.log, "w"), indent=1)


if __name__ == "__main__":
    main()
