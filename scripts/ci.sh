#!/usr/bin/env bash
# Tier-1 verification — the exact command the roadmap pins:
#   PYTHONPATH=src python -m pytest -x -q
# Run from the repo root (locally or in CI). Extra args go to pytest.
#
# `scripts/ci.sh --bench [check_bench args...]` instead runs the perf gate:
# measure every artifact named by the gate manifest (benchmarks/gates.json)
# into a scratch dir with `benchmarks/run.py --only <slugs>`, then compare
# each gated metric against the committed baselines with
# `scripts/check_bench.py --manifest` (regression beyond a gate's tolerance
# fails the job).
#
# `scripts/ci.sh --lint-contracts` runs the AST contract lint over src/repro
# (retired kwargs, quantize flow, raw knob literals — see
# src/repro/analysis/astlint.py).
#
# `scripts/ci.sh --analysis [run_analysis args...]` runs the program-contract
# analysis lane: lint-contracts plus the registry checkers (retrace audit,
# dtype-flow lint, donation/aliasing verification) over 8 forced host
# devices, as the CI `analysis` job does.
#
# `scripts/ci.sh --faults [pytest args...]` runs the chaos lane: the
# fault-injection suite (tests/test_serve_faults.py — failure isolation,
# retry/breaker, deadlines, degradation, and the sharded {1,2,4} chaos
# parity test) over 8 forced host devices, as the CI `faults` job does.
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--lint-contracts" ]]; then
  shift
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.analysis.astlint src/repro "$@"
  exit 0
fi

if [[ "${1:-}" == "--analysis" ]]; then
  shift
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.analysis.astlint src/repro
  XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/run_analysis.py "$@"
  exit 0
fi

if [[ "${1:-}" == "--faults" ]]; then
  shift
  XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q tests/test_serve_faults.py "$@"
  exit 0
fi

if [[ "${1:-}" == "--bench" ]]; then
  shift
  out="$(mktemp -d)"
  trap 'rm -rf "$out"' EXIT
  slugs="$(python scripts/check_bench.py --manifest benchmarks/gates.json \
    --list-slugs)"
  BENCH_OUT_DIR="$out" PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/run.py --only "$slugs"
  python scripts/check_bench.py --manifest benchmarks/gates.json \
    --baseline-dir . --new-dir "$out" "$@"
  exit 0
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
