#!/usr/bin/env bash
# Tier-1 verification — the exact command the roadmap pins:
#   PYTHONPATH=src python -m pytest -x -q
# Run from the repo root (locally or in CI). Extra args go to pytest.
#
# `scripts/ci.sh --bench [check_bench args...]` instead runs the perf gate:
# measure `benchmarks/run.py --only search_perf` into a scratch dir and
# compare result.speedup_at_32 against the committed BENCH_search_perf.json
# (>20% regression fails).
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--bench" ]]; then
  shift
  out="$(mktemp -d)"
  trap 'rm -rf "$out"' EXIT
  BENCH_OUT_DIR="$out" PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python benchmarks/run.py --only search_perf
  python scripts/check_bench.py --baseline BENCH_search_perf.json \
    --new "$out/BENCH_search_perf.json" "$@"
  exit 0
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
