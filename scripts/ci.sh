#!/usr/bin/env bash
# Tier-1 verification — the exact command the roadmap pins:
#   PYTHONPATH=src python -m pytest -x -q
# Run from the repo root (locally or in CI).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
