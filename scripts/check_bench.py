#!/usr/bin/env python
"""CI perf gate: compare fresh BENCH artifacts against committed baselines.

Two modes:

* **manifest** (what ``scripts/ci.sh --bench`` runs) — gate every entry of
  ``benchmarks/gates.json``: for each gate, read the committed baseline
  artifact from ``--baseline-dir`` (default: repo root) and the freshly
  measured one from ``--new-dir``, and fail (exit 1) when any gated metric
  regresses beyond its tolerance. The full gate table (measured vs
  baseline vs bound/tolerance per gate) is printed on success as well as
  failure, so every CI log records the actual numbers. ``--list-slugs``
  prints the comma-joined ``benchmarks/run.py --only`` slugs the manifest
  needs, so the CI script measures exactly the gated artifacts.

      python scripts/check_bench.py --manifest benchmarks/gates.json \\
          --baseline-dir . --new-dir <tmp>

* **single-key** (legacy) — one artifact, one dotted key:

      python scripts/check_bench.py --baseline BENCH_search_perf.json \\
          --new <tmp>/BENCH_search_perf.json [--key K] [--tolerance T]

Dotted keys index dicts by name and lists by integer position, e.g.
``result.('bimetric', 256).0`` is recall@10 inside the fig-1 payload.
A gate's ``direction`` is "higher" (default: regression = new below
baseline*(1-tol)) or "lower" (regression = new above baseline*(1+tol)).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import _summary


def lookup(payload, dotted: str) -> float:
    node = payload
    for part in dotted.split("."):
        if isinstance(node, list):
            try:
                node = node[int(part)]
                continue
            except (ValueError, IndexError):
                raise KeyError(
                    f"key {dotted!r}: {part!r} is not a valid list "
                    "index") from None
        if not isinstance(node, dict) or part not in node:
            raise KeyError(f"key {dotted!r} not found (missing {part!r})")
        node = node[part]
    return float(node)


def check_one(base: float, new: float, *, key: str, direction: str,
              tolerance: float, artifact: str = "") -> tuple[bool, dict]:
    """Evaluate one gate -> (passed, table row)."""
    if direction == "higher":
        bound = base * (1.0 - tolerance)
        ok = new >= bound
        regress = 1.0 - new / base if base else 0.0
    elif direction == "lower":
        bound = base * (1.0 + tolerance)
        ok = new <= bound
        regress = new / base - 1.0 if base else 0.0
    else:
        raise ValueError(f"unknown direction {direction!r}")
    tag = f"{artifact}:{key}" if artifact else key
    row = {
        "gate": tag, "baseline": base, "measured": new, "bound": bound,
        "tolerance": tolerance, "direction": direction,
        "verdict": "OK" if ok else "REGRESSION",
    }
    if not ok:
        print(f"FAIL: {tag} regressed {regress:.1%} "
              f"(> {tolerance:.0%} allowed) — if this is a real, justified "
              "tradeoff, re-measure and commit a new baseline artifact in "
              "the same PR.", file=sys.stderr)
    return ok, row


def print_gate_table(rows: list[dict]) -> None:
    """The full gate table — printed on success AND failure, so every CI log
    records what was measured against what, not just the verdict (table
    rendering + ``$GITHUB_STEP_SUMMARY`` markdown live in ``_summary.py``,
    shared with the analysis lane)."""
    if not rows:
        print("bench-gate: no gates to check")
        return
    headers = ("gate", "baseline", "measured", "bound", "tol", "dir",
               "verdict")
    fmt_rows = [(
        r["gate"], f"{r['baseline']:.4f}", f"{r['measured']:.4f}",
        f"{r['bound']:.4f}", f"{r['tolerance']:.0%}", r["direction"],
        r["verdict"],
    ) for r in rows]
    _summary.print_table(headers, fmt_rows)
    n_fail = sum(r["verdict"] != "OK" for r in rows)
    _summary.append_step_summary(
        f"Bench gates — {len(rows) - n_fail}/{len(rows)} passed",
        headers, fmt_rows, highlight=("REGRESSION",))


def run_manifest(manifest_path: str, baseline_dir: str, new_dir: str) -> int:
    with open(manifest_path) as f:
        gates = json.load(f)["gates"]
    loaded: dict[str, dict] = {}

    def artifact_json(root: str, name: str) -> dict:
        path = os.path.join(root, name)
        if path not in loaded:
            with open(path) as f:
                loaded[path] = json.load(f)
        return loaded[path]

    failures = 0
    rows = []
    for gate in gates:
        art = gate["artifact"]
        base = lookup(artifact_json(baseline_dir, art), gate["key"])
        new = lookup(artifact_json(new_dir, art), gate["key"])
        ok, row = check_one(base, new, key=gate["key"],
                            direction=gate.get("direction", "higher"),
                            tolerance=float(gate.get("tolerance", 0.2)),
                            artifact=art)
        if not ok:
            failures += 1
        rows.append(row)
    print_gate_table(rows)
    print(f"bench-gate: {len(gates) - failures}/{len(gates)} gates passed")
    return 1 if failures else 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--manifest", default=None,
                    help="gate manifest (benchmarks/gates.json); enables "
                         "manifest mode")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory of committed baseline artifacts")
    ap.add_argument("--new-dir", default=None,
                    help="directory of freshly measured artifacts")
    ap.add_argument("--list-slugs", action="store_true",
                    help="print the comma-joined run.py --only slugs the "
                         "manifest gates need, and exit")
    ap.add_argument("--baseline", default=None,
                    help="[single-key mode] committed BENCH_*.json artifact")
    ap.add_argument("--new", default=None, dest="fresh",
                    help="[single-key mode] freshly measured BENCH_*.json")
    ap.add_argument("--key", default="result.speedup_at_32",
                    help="[single-key mode] dotted path of the gated metric")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="[single-key mode] allowed fractional regression")
    args = ap.parse_args(argv)

    if args.manifest:
        if args.list_slugs:
            with open(args.manifest) as f:
                gates = json.load(f)["gates"]
            slugs = list(dict.fromkeys(g["slug"] for g in gates))
            print(",".join(slugs))
            return 0
        if args.new_dir is None:
            ap.error("--manifest mode needs --new-dir")
        return run_manifest(args.manifest, args.baseline_dir, args.new_dir)

    if not (args.baseline and args.fresh):
        ap.error("either --manifest or --baseline/--new is required")
    with open(args.baseline) as f:
        base = lookup(json.load(f), args.key)
    with open(args.fresh) as f:
        new = lookup(json.load(f), args.key)
    ok, row = check_one(base, new, key=args.key, direction="higher",
                        tolerance=args.tolerance)
    print_gate_table([row])
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
