#!/usr/bin/env python
"""CI perf gate: compare a fresh BENCH artifact against the committed baseline.

Fails (exit 1) when the gated metric regresses more than ``--tolerance``
(default 20%) below the baseline. The headline metric is
``result.speedup_at_32`` in ``BENCH_search_perf.json`` — the batched
engine's speedup over the retired per-query serving path at batch 32, the
number PR 1 bought and every later PR must keep.

Usage (what ``scripts/ci.sh --bench`` runs):

    python benchmarks/run.py --only search_perf   # BENCH_OUT_DIR=<tmp>
    python scripts/check_bench.py \
        --baseline BENCH_search_perf.json \
        --new <tmp>/BENCH_search_perf.json
"""
from __future__ import annotations

import argparse
import json
import sys


def lookup(payload: dict, dotted: str) -> float:
    node = payload
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            raise KeyError(f"key {dotted!r} not found (missing {part!r})")
        node = node[part]
    return float(node)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_*.json artifact")
    ap.add_argument("--new", required=True, dest="fresh",
                    help="freshly measured BENCH_*.json artifact")
    ap.add_argument("--key", default="result.speedup_at_32",
                    help="dotted path of the gated metric (higher is better)")
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional regression below the baseline")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = lookup(json.load(f), args.key)
    with open(args.fresh) as f:
        new = lookup(json.load(f), args.key)

    floor = base * (1.0 - args.tolerance)
    verdict = "OK" if new >= floor else "REGRESSION"
    print(f"bench-gate {args.key}: baseline={base:.4f} new={new:.4f} "
          f"floor={floor:.4f} ({args.tolerance:.0%} tolerance) -> {verdict}")
    if new < floor:
        print(f"FAIL: {args.key} regressed {1.0 - new / base:.1%} "
              f"(> {args.tolerance:.0%} allowed) — if this is a real, "
              "justified tradeoff, re-measure and commit a new baseline "
              "artifact in the same PR.", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
