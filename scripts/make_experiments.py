"""Generate EXPERIMENTS.md §Dry-run + §Roofline from the dryrun JSONs.

MODEL_FLOPS convention: train = 6·N·D (dense) or 6·N_active·D (MoE),
serve/prefill = 2·N(_active)·D, with D = cell.tokens; decode cells process
one token per sequence, so their MODEL_FLOPS is parameter-bound while the
compiled FLOPs are cache-attention-bound — the ratio column makes that
visible rather than hiding it.
"""
import json
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import ARCHS, get_arch

PEAK = dict(flops=197e12, hbm=819e9, link=50e9)


def n_params(arch: str) -> tuple[float, float]:
    spec = get_arch(arch)
    cfg = spec.make_config(False)
    abstract = jax.eval_shape(lambda k: spec.init_params(k, cfg),
                              jax.random.PRNGKey(0))
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))
    active = total
    if getattr(cfg, "moe", False):
        n_moe_layers = cfg.n_layers - cfg.first_dense
        expert_p = n_moe_layers * cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
        active = total - expert_p * (1 - cfg.top_k / cfg.n_experts)
    return float(total), float(active)


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return {}


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e5:
        return f"{x:.2e}"
    return f"{x:.{digits}g}"


def main(single_path, multi_path, out_path):
    single = load(single_path)
    multi = load(multi_path)
    counts = {a: n_params(a) for a in ARCHS}

    lines = []
    lines.append("## §Dry-run\n")
    lines.append(
        "Every (architecture × shape) cell lowered **and compiled** with "
        "`jax.jit(step).lower(...).compile()` on the single-pod mesh "
        "(16×16 = 256 chips, axes data×model) and the multi-pod mesh "
        "(2×16×16 = 512 chips, axes pod×data×model). Memory columns are "
        "per-device from `compiled.memory_analysis()`; `fits` compares "
        "args+temp against 16 GB HBM (TPU v5e).\n")
    for mesh_name, data in (("single-pod 16×16", single),
                            ("multi-pod 2×16×16", multi)):
        lines.append(f"\n### {mesh_name}\n")
        lines.append("| cell | entry | args GB | temp GB | fits | compile s |"
                     " collectives GB/dev |")
        lines.append("|---|---|---|---|---|---|---|")
        for key in sorted(data):
            v = data[key]
            if not v.get("ok"):
                lines.append(f"| {v['cell']} | — | — | — | FAILED | — | — |")
                continue
            m = v["memory"]
            args = m["argument_bytes"] / 1e9
            temp = m["temp_bytes"] / 1e9
            fits = "yes" if args + temp <= 16.0 else "**no**"
            coll = v["hlo_analysis"]["collective_bytes_per_device"] / 1e9
            lines.append(
                f"| {v['cell']} | {v['entry']} | {args:.2f} | {temp:.2f} | "
                f"{fits} | {v['t_compile_s']:.0f} | {coll:.1f} |")

    lines.append("\n## §Roofline\n")
    lines.append(
        "Per-chip roofline terms from the trip-count-corrected HLO analysis "
        "(launch/hlo_analysis.py) of the **single-pod** compile: "
        "compute = dot-FLOPs / 197 TFLOP/s bf16; memory = bytes at fusion "
        "boundaries / 819 GB/s (two models: `mem⁺` = CPU-HLO fusion-boundary "
        "upper bound, `mem` = TPU-like every-buffer-once lower bound — the "
        "bottleneck/fraction columns use `mem`); collective = collective op "
        "output bytes / 50 GB/s per ICI link. MODEL_FLOPS = 6·N(_active)·D "
        "(train) or 2·N(_active)·D (serve), per chip. `useful` = "
        "MODEL_FLOPS / compiled dot-FLOPs (catches remat/redundant "
        "compute; decode cells are attention-dominated so the ratio is "
        "structurally small there).\n")
    lines.append("| cell | compute s | mem s | mem⁺ s | coll s | bottleneck |"
                 " roofline frac | useful |")
    lines.append("|---|---|---|---|---|---|---|---|")
    notes = []
    for key in sorted(single):
        v = single[key]
        if not v.get("ok"):
            continue
        r = v["roofline"]
        an = v["hlo_analysis"]
        total, active = counts[v["arch"]]
        n = active
        mult = 6.0 if v["entry"] == "train" else 2.0
        chips = v["n_chips"]
        model_flops = mult * n * v["tokens"] / chips
        useful = model_flops / max(an["dot_flops_per_device"], 1.0)
        lines.append(
            f"| {v['cell']} | {fmt(r['compute_s'])} | "
            f"{fmt(r['memory_fused_s'])} | {fmt(r['memory_s'])} | "
            f"{fmt(r['collective_s'])} | {r['bottleneck']} | "
            f"{r['roofline_fraction']:.3f} | {useful:.2f} |")
    text = "\n".join(lines) + "\n"
    with open(out_path, "w") as f:
        f.write(text)
    print(f"wrote {out_path} ({len(lines)} lines)")


if __name__ == "__main__":
    main("results/dryrun_single.json", "results/dryrun_multi.json",
         "results/experiments_tables.md")
