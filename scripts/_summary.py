"""Shared CI table rendering: aligned stdout tables + GitHub step summaries.

Extracted from ``check_bench.py`` so every gate script (bench gates,
the analysis lane) renders verdicts the same way: the full table goes to
stdout on success AND failure — every CI log records what was measured —
and, when ``$GITHUB_STEP_SUMMARY`` is set (GitHub Actions), the same
table is appended there as markdown so verdicts are readable from the
Actions summary page without digging through logs.
"""
from __future__ import annotations

import os
from typing import Iterable, Sequence


def format_table(headers: Sequence[str],
                 rows: Iterable[Sequence[str]]) -> str:
    """Fixed-width aligned text table (headers + rule + rows)."""
    rows = [tuple(str(c) for c in r) for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    line = "  ".join(h.ljust(w) for h, w in zip(headers, widths))
    out = [line, "-" * len(line)]
    out.extend("  ".join(c.ljust(w) for c, w in zip(r, widths))
               for r in rows)
    return "\n".join(out)


def print_table(headers: Sequence[str],
                rows: Iterable[Sequence[str]]) -> None:
    print(format_table(headers, rows))


def append_step_summary(title: str, headers: Sequence[str],
                        rows: Iterable[Sequence[str]],
                        highlight: Sequence[str] = ()) -> None:
    """Append a markdown table to ``$GITHUB_STEP_SUMMARY`` (no-op when the
    env var is unset, i.e. outside GitHub Actions). Cells whose text is in
    ``highlight`` are bolded — failure verdicts should jump out."""
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if not summary:
        return
    with open(summary, "a") as f:
        f.write(f"### {title}\n\n")
        f.write("| " + " | ".join(headers) + " |\n")
        f.write("|" + " --- |" * len(headers) + "\n")
        for r in rows:
            cells = [f"**{c}**" if str(c) in highlight else str(c)
                     for c in r]
            f.write("| " + " | ".join(cells) + " |\n")
        f.write("\n")
