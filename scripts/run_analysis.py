#!/usr/bin/env python
"""CI analysis lane: run the program-contract checkers over the registry.

Drives ``repro.analysis.runner.run_registry()`` — for every program in
``repro.analysis.registry.REGISTRY``, audit retrace counts over its input
grid, lint the jaxpr dtype flow, and verify donation / buffer aliasing
against the compiled HLO — then print one verdict row per program (and
append the same table to ``$GITHUB_STEP_SUMMARY`` on GitHub Actions).
Exit 1 when any program fails any checker.

Programs whose ``min_devices`` exceeds the host's report SKIP (the CI
lane forces 8 host devices via ``XLA_FLAGS=--xla_force_host_platform_
device_count=8`` so nothing skips there).

    PYTHONPATH=src python scripts/run_analysis.py [--only name[,name...]]
"""
from __future__ import annotations

import argparse
import sys

import _summary


def _checks_cell(v) -> str:
    """Compact per-checker summary, e.g. ``retrace 9/9 dtype[3] donate``."""
    parts = []
    if v.retrace is not None:
        parts.append(f"retrace {v.retrace.traces}/{v.retrace.bound}")
    if v.dtype:
        bad = sum(not d.ok for d in v.dtype)
        parts.append(f"dtype[{len(v.dtype)}]"
                     + (f" {bad} bad" if bad else ""))
    if v.donation is not None:
        parts.append("donate" + ("" if v.donation.ok else " MISSING"))
    if v.double_donation is not None:
        parts.append("dd" + (f" {len(v.double_donation)}"
                             if v.double_donation else ""))
    if v.while_carry is not None:
        parts.append("carry" + ("" if v.while_carry.ok
                                else f" {len(v.while_carry.copies)} copies"))
    return " ".join(parts)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-joined registry names to run (default: all)")
    args = ap.parse_args(argv)

    from repro.analysis.runner import run_registry

    names = args.only.split(",") if args.only else None
    verdicts = run_registry(names)
    if not verdicts:
        print(f"analysis: no registered programs match {args.only!r}",
              file=sys.stderr)
        return 1

    headers = ("program", "checks", "verdict")
    rows = []
    failures = 0
    for v in verdicts:
        if v.skipped is not None:
            rows.append((v.program, v.skipped, "SKIP"))
            continue
        ok = v.ok
        failures += not ok
        rows.append((v.program, _checks_cell(v), "OK" if ok else "FAIL"))
    _summary.print_table(headers, rows)
    n_run = sum(r[2] != "SKIP" for r in rows)
    _summary.append_step_summary(
        f"Program contracts — {n_run - failures}/{n_run} passed"
        + (f", {len(rows) - n_run} skipped" if n_run != len(rows) else ""),
        headers, rows, highlight=("FAIL",))

    for v in verdicts:
        for line in v.failures():
            print(f"FAIL {v.program}: {line}", file=sys.stderr)
    print(f"analysis: {n_run - failures}/{n_run} programs passed"
          + (f" ({len(rows) - n_run} skipped)" if n_run != len(rows) else ""))
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
