"""End-to-end driver: train a proxy embedding tower with InfoNCE, then build
a bi-metric index over its embeddings and query it under a D-call budget.

This is the full production loop: data pipeline -> contrastive training
(with checkpoint/restart) -> corpus embedding -> index build (cheap metric
only) -> budgeted two-stage retrieval against a bigger tower.

    PYTHONPATH=src python examples/train_biencoder.py --steps 200   # full
    PYTHONPATH=src python examples/train_biencoder.py --steps 20    # quick
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import qwen3_0_6b
from repro.core import bimetric, distances, metrics, vamana
from repro.data.pipeline import DeterministicIterator, contrastive_batch_fn
from repro.models import transformer as T
from repro.train.contrastive import info_nce_loss
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=24)
    ap.add_argument("--ckpt-dir", default="/tmp/biencoder_ckpt")
    ap.add_argument("--scale", choices=["smoke", "100m"], default="smoke",
                    help="100m trains a ~100M-param tower (slow on CPU)")
    args = ap.parse_args()

    if args.scale == "100m":
        cfg = T.TransformerConfig(
            name="proxy-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=12, head_dim=64, d_ff=2048, vocab=32768,
            qk_norm=True, embed_dim=384)
    else:
        cfg = qwen3_0_6b.smoke()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"proxy tower: {n/1e6:.1f}M params")

    # ---- contrastive training with checkpoint/restart -------------------
    opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=max(args.steps, 100))
    trainer = Trainer(
        lambda p, b: info_nce_loss(p, b, cfg), params, opt,
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=max(args.steps // 4, 10), log_every=10))
    make = contrastive_batch_fn(args.batch, args.seq, cfg.vocab)
    it = DeterministicIterator(make)
    state = trainer.maybe_restore(it.state())
    if state:
        it = DeterministicIterator.from_state(make, state)
        print(f"resumed from step {trainer.step}")
    out = trainer.run(it, data_state_fn=it.state)
    print(f"trained to loss {out['final_loss']:.4f}")

    # ---- embed a corpus with the trained proxy; D = teacher tower -------
    rng = np.random.default_rng(0)
    corpus_tokens = rng.integers(0, cfg.vocab, (1024, args.seq), dtype=np.int32)
    embed = jax.jit(lambda p, t: T.embed_pool(p, t, cfg))
    emb_d = np.concatenate([
        np.asarray(embed(trainer.params, corpus_tokens[s:s + 128]))
        for s in range(0, 1024, 128)])

    # teacher: a wider random tower (stands in for the API-tier model)
    tcfg = T.TransformerConfig(
        name="teacher", n_layers=4, d_model=128, n_heads=8, n_kv_heads=8,
        head_dim=16, d_ff=256, vocab=cfg.vocab, embed_dim=64)
    tparams = T.init_params(jax.random.fold_in(key, 7), tcfg)
    tembed = jax.jit(lambda p, t: T.embed_pool(p, t, tcfg))
    emb_D = np.concatenate([
        np.asarray(tembed(tparams, corpus_tokens[s:s + 128]))
        for s in range(0, 1024, 128)])

    index = vamana.build(jnp.asarray(emb_d),
                         vamana.VamanaConfig(max_degree=16, l_build=24,
                                             pool_size=48, rev_candidates=16))
    qidx = rng.integers(0, 1024, 16)
    q_tokens = corpus_tokens[qidx].copy()
    q_tokens[:, : args.seq // 2] = rng.integers(0, cfg.vocab,
                                                (16, args.seq // 2))
    q_d = np.asarray(embed(trainer.params, q_tokens))
    q_D = np.asarray(tembed(tparams, q_tokens))
    em_d = distances.EmbeddingMetric(jnp.asarray(emb_d))
    em_D = distances.EmbeddingMetric(jnp.asarray(emb_D))
    true_ids, _ = em_D.brute_force(jnp.asarray(q_D), 10)
    res = bimetric.bimetric_search(
        lambda q, i: em_d.dists(q, i), lambda q, i: em_D.dists(q, i),
        index, jnp.asarray(q_d), jnp.asarray(q_D),
        n_points=1024, quota=96, k=10)
    rec = float(metrics.recall_at_k(res.ids, true_ids).mean())
    print(f"bi-metric retrieval vs teacher: recall@10={rec:.3f} at Q=96 "
          f"(corpus=1024)")


if __name__ == "__main__":
    main()
