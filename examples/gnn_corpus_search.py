"""GNN-produced corpus + bi-metric search: GAT node embeddings become the
expensive metric D (2-layer message passing per node), while raw node
features projected down serve as the cheap proxy d.

Shows the framework is metric-source agnostic (DESIGN.md
§Arch-applicability note 1).

    PYTHONPATH=src python examples/gnn_corpus_search.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bimetric, distances, metrics, vamana
from repro.models import gnn


def main() -> None:
    key = jax.random.PRNGKey(0)
    g = gnn.random_csr_graph(n_nodes=2048, avg_degree=8, d_feat=64,
                             n_classes=8, seed=0)
    src = np.repeat(np.arange(2048), np.diff(g.indptr)).astype(np.int32)
    dst = g.indices.astype(np.int32)

    cfg = gnn.GATConfig(d_in=64, n_classes=32, n_layers=2, d_hidden=16,
                        n_heads=4)
    params = gnn.init_params(key, cfg)
    emb_D = gnn.forward(params, jnp.asarray(g.feats), jnp.asarray(src),
                        jnp.asarray(dst), cfg)  # (N, 32) structural embedding
    proj = jax.random.normal(jax.random.fold_in(key, 1), (64, 8)) / np.sqrt(8)
    emb_d = jnp.asarray(g.feats) @ proj  # cheap: raw features, no messages

    index = vamana.build(emb_d, vamana.VamanaConfig(
        max_degree=16, l_build=24, pool_size=48, rev_candidates=16))
    em_d = distances.EmbeddingMetric(emb_d)
    em_D = distances.EmbeddingMetric(emb_D)
    qids = np.random.default_rng(0).integers(0, 2048, 16)
    q_d, q_D = emb_d[qids], emb_D[qids]
    true_ids, _ = em_D.brute_force(q_D, 10)
    for quota in (64, 256):
        res = bimetric.bimetric_search(
            lambda q, i: em_d.dists(q, i), lambda q, i: em_D.dists(q, i),
            index, q_d, q_D, n_points=2048, quota=quota, k=10)
        rec = float(metrics.recall_at_k(res.ids, true_ids).mean())
        print(f"Q={quota}: recall@10 vs GAT metric = {rec:.3f} "
              f"(vs brute force = {2048} D calls)")


if __name__ == "__main__":
    main()
