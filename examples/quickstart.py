"""Quickstart: the bi-metric framework in ~40 lines.

Builds a DiskANN-style index with a cheap proxy metric d, then answers
queries to (1+eps) accuracy under an expensive metric D using a bounded
number of D evaluations — and shows the two-stage search beating re-ranking
at the same budget (the paper's Figure 1 phenomenon).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import bimetric, distances, metrics, vamana
from repro.data.synthetic import make_dataset


def main() -> None:
    # a corpus where the proxy is a lossy compression of the ground truth
    data = make_dataset(n=4096, n_queries=32, dim_D=96, dim_d=8, noise=0.15)
    print(f"corpus: n=4096, empirical C-approximation = {data.c_estimate:.1f}")

    # 1. index construction touches ONLY the cheap metric
    index = vamana.build(
        data.corpus_d,
        vamana.VamanaConfig(max_degree=24, l_build=32, pool_size=64,
                            rev_candidates=24),
    )

    em_d = distances.EmbeddingMetric(data.corpus_d)
    em_D = distances.EmbeddingMetric(data.corpus_D)
    true_ids, _ = em_D.brute_force(data.queries_D, 10)  # exact answer under D

    # 2. query under an expensive-call budget Q
    for quota in (64, 128, 256):
        ours = bimetric.bimetric_search(
            lambda q, i: em_d.dists(q, i), lambda q, i: em_D.dists(q, i),
            index, data.queries_d, data.queries_D,
            n_points=4096, quota=quota, k=10)
        base = bimetric.rerank_search(
            lambda q, i: em_d.dists(q, i), lambda q, i: em_D.dists(q, i),
            index, data.queries_d, data.queries_D,
            n_points=4096, quota=quota, k=10)
        r_ours = float(metrics.recall_at_k(ours.ids, true_ids).mean())
        r_base = float(metrics.recall_at_k(base.ids, true_ids).mean())
        print(f"Q={quota:4d}: bi-metric recall@10={r_ours:.3f} "
              f"(max D calls {int(np.asarray(ours.D_calls).max())}) | "
              f"re-rank recall@10={r_base:.3f}")


if __name__ == "__main__":
    main()
