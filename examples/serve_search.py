"""Serving example: the continuous-batching BiMetricEngine with
model-backed metrics — the paper's "small local model + expensive API
model" deployment, including exact budget accounting per request.

Requests are frozen ``SearchRequest`` records submitted into the engine's
persistent slot pool: each arrival is admitted into the first freed slot
mid-flight (no fixed waves, no head-of-line blocking), ordered by
``priority`` and guarded by ``deadline_ms`` while queued. The future
resolves to a ``SearchResult`` whose ``ServeStats`` split latency into
queue vs compute time.

    PYTHONPATH=src python examples/serve_search.py
"""
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import qwen3_0_6b
from repro.models import transformer as T
from repro.serve import BiMetricEngine, EmbedTower, SearchRequest


def main() -> None:
    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = T.TransformerConfig(
        name="expensive-smoke", n_layers=4, d_model=128, n_heads=8,
        n_kv_heads=8, head_dim=16, d_ff=256, vocab=cheap_cfg.vocab,
        embed_dim=64)
    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(T.init_params(jax.random.fold_in(key, 1), exp_cfg),
                           exp_cfg)

    rng = np.random.default_rng(0)
    corpus = rng.integers(0, cheap_cfg.vocab, (256, 16), dtype=np.int32)
    engine = BiMetricEngine(cheap, expensive, corpus, slots=4)
    print("index built with the cheap tower only (0 expensive calls)")

    emb_D = expensive.embed(corpus)  # eval-only ground truth

    futures = []
    for i in range(6):
        q = corpus[rng.integers(0, 256)].copy()
        q[:8] = rng.integers(0, cheap_cfg.vocab, 8)
        req = SearchRequest(tokens=q, quota=32, k=10,
                            priority=1 if i == 5 else 0)  # jump the queue
        futures.append((q, engine.submit(req)))
    for i, (q, fut) in enumerate(futures):
        res = fut.result(timeout=300)
        q_emb = expensive.embed(q[None])[0]
        true10 = np.argsort(np.linalg.norm(emb_D - q_emb, axis=1))[:10]
        rec = len(set(res.ids.tolist()) & set(true10.tolist())) / 10
        print(f"req{i}: recall@10={rec:.2f} D_calls={res.stats.D_calls} "
              f"d_calls={res.stats.d_calls} "
              f"queue={res.stats.queue_ms:.0f}ms "
              f"compute={res.stats.compute_ms:.0f}ms")
    engine.close()


if __name__ == "__main__":
    main()
