"""Deterministic, checkpointable, sharded data pipeline.

The iterator's cursor is a tiny dict (seed + step) that lives inside every
checkpoint, so a restarted job replays the *exact* sample stream from the
failure point (no skipped or duplicated batches). Batches are generated
host-side (synthetic corpora here; a real deployment swaps the generator) and
optionally placed with a NamedSharding so each data-parallel shard touches
only its slice — with a prefetch depth so host generation overlaps device
compute (straggler mitigation at the input layer).
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Iterator

import jax
import numpy as np


class DeterministicIterator:
    """Stateful wrapper: batch = make_batch(seed, step)."""

    def __init__(self, make_batch: Callable[[int, int], dict], *,
                 seed: int = 0, start_step: int = 0,
                 sharding: Any | None = None, prefetch: int = 2):
        self.make_batch = make_batch
        self.seed = seed
        self.step = start_step
        self.sharding = sharding
        self.prefetch = prefetch
        self._queue: collections.deque = collections.deque()
        self._lock = threading.Lock()

    # --- checkpointable cursor ------------------------------------------
    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step - len(self._queue)}

    @classmethod
    def from_state(cls, make_batch, state: dict, **kw) -> "DeterministicIterator":
        return cls(make_batch, seed=state["seed"], start_step=state["step"], **kw)

    # --- iteration --------------------------------------------------------
    def _produce(self) -> dict:
        batch = self.make_batch(self.seed, self.step)
        self.step += 1
        if self.sharding is not None:
            batch = jax.tree.map(
                lambda x: jax.device_put(x, self.sharding), batch
            )
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        with self._lock:
            while len(self._queue) < self.prefetch:
                self._queue.append(self._produce())
            return self._queue.popleft()


def lm_batch_fn(batch: int, seq_len: int, vocab: int):
    def make(seed: int, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
        toks = rng.integers(0, vocab, size=(batch, seq_len + 1), dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return make


def contrastive_batch_fn(batch: int, seq_len: int, vocab: int):
    def make(seed: int, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
        q = rng.integers(0, vocab, size=(batch, seq_len), dtype=np.int32)
        d = q.copy()
        tail = seq_len // 2
        d[:, tail:] = rng.integers(0, vocab, size=(batch, seq_len - tail),
                                   dtype=np.int32)
        return {"query_tokens": q, "doc_tokens": d}

    return make
