"""Synthetic bi-metric corpora with *controllable* C-approximation.

Offline stand-in for the paper's (MTEB corpus, bge-micro / SFR-Mistral) pairs:

* the ground-truth embedding ``E_D`` is a clustered Gaussian mixture (dim_D),
  so nearest-neighbor structure is non-trivial (intrinsic dim ≪ ambient);
* the proxy embedding ``E_d`` is a random linear *compression* of E_D (JL
  projection to dim_d ≪ dim_D) plus bounded multiplicative noise — exactly the
  regime of Definition 2.1: d is a C-approximation of D, with C increasing as
  dim_d shrinks / noise grows. ``quality`` sweeps the proxy from
  bge-base-like (high) to bge-micro-like (low), the paper's Figure 2 axis.

The empirical C of a generated pair is measured (distances.measure_capproximation)
and reported by the benchmarks next to each curve.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class BiMetricData(NamedTuple):
    corpus_D: Array  # (N, dim_D) ground-truth embeddings
    corpus_d: Array  # (N, dim_d) proxy embeddings
    queries_D: Array  # (B, dim_D)
    queries_d: Array  # (B, dim_d)
    c_estimate: float  # empirical C on sampled pairs


def make_dataset(
    *,
    n: int = 4096,
    n_queries: int = 64,
    dim_D: int = 128,
    dim_d: int = 16,
    n_clusters: int = 64,
    noise: float = 0.05,
    local_visibility: float = 1.0,
    query_noise: float = 0.0,
    seed: int = 0,
) -> BiMetricData:
    """``local_visibility`` < 1 makes the proxy *locally blind* (sees coarse
    cluster structure, compresses fine geometry). ``query_noise`` corrupts
    the proxy's *query* embeddings only — the dominant failure mode of small
    embedding models (queries are short/out-of-distribution while
    corpus↔corpus proxy similarity stays decent). Re-ranking is capped by
    the noisy query-side ranking; the two-stage search escapes it by walking
    corpus↔corpus graph edges under the true metric D — the paper's
    phenomenon."""
    key = jax.random.PRNGKey(seed)
    kc, kx, kq, kp, kn1, kn2 = jax.random.split(key, 6)

    centers = jax.random.normal(kc, (n_clusters, dim_D)) * 4.0
    assign = jax.random.randint(kx, (n,), 0, n_clusters)
    local = jax.random.normal(jax.random.fold_in(kx, 1), (n, dim_D))
    corpus_D = centers[assign] + local

    # queries live near corpus structure (perturbed corpus points)
    qidx = jax.random.randint(kq, (n_queries,), 0, n)
    q_noise = 0.5 * jax.random.normal(jax.random.fold_in(kq, 1),
                                      (n_queries, dim_D))
    queries_D = corpus_D[qidx] + q_noise

    # proxy = coarse structure + attenuated local detail, JL-projected, with
    # multiplicative noise (bounded distortion -> a C-approximation)
    lv = local_visibility
    proxy_corpus_in = centers[assign] + lv * local
    proxy_query_in = centers[assign[qidx]] + lv * (local[qidx] + q_noise)
    proj = jax.random.normal(kp, (dim_D, dim_d)) / jnp.sqrt(dim_d)
    corpus_d = proxy_corpus_in @ proj
    queries_d = proxy_query_in @ proj
    corpus_d = corpus_d * (1.0 + noise * jax.random.normal(kn1, corpus_d.shape))
    queries_d = queries_d * (1.0 + noise * jax.random.normal(kn2, queries_d.shape))
    if query_noise:
        # additive noise at the scale of projected local structure
        local_scale = jnp.std(local[:256] @ proj)
        queries_d = queries_d + query_noise * local_scale * jax.random.normal(
            jax.random.fold_in(kn2, 1), queries_d.shape)

    # estimate C on sampled pairs
    from repro.core import distances

    m = min(n, 512)
    dd = distances.pairwise(queries_d, corpus_d[:m])
    dD = distances.pairwise(queries_D, corpus_D[:m])
    _, c = distances.measure_capproximation(dd.reshape(-1), dD.reshape(-1))
    return BiMetricData(
        corpus_D=corpus_D,
        corpus_d=corpus_d,
        queries_D=queries_D,
        queries_d=queries_d,
        c_estimate=float(c),
    )


def proxy_quality_sweep(quality: str) -> dict:
    """Map a named proxy quality tier to (dim_d, noise, local_visibility) —
    the Table 1 analogue (smaller models see less local structure)."""
    return {
        "bge-micro-like": dict(dim_d=8, noise=0.10, local_visibility=0.25,
                               query_noise=2.0),
        "gte-small-like": dict(dim_d=16, noise=0.06, local_visibility=0.5,
                               query_noise=1.0),
        "bge-base-like": dict(dim_d=48, noise=0.02, local_visibility=0.85,
                              query_noise=0.25),
    }[quality]


def make_lm_tokens(
    *, batch: int, seq_len: int, vocab: int, seed: int = 0
) -> dict[str, np.ndarray]:
    """Synthetic LM batch (tokens + shifted labels) for training drivers."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab, size=(batch, seq_len + 1), dtype=np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_contrastive_pairs(
    *, batch: int, seq_len: int, vocab: int, seed: int = 0
) -> dict[str, np.ndarray]:
    """(query, positive-doc) token pairs for bi-encoder InfoNCE training.

    Positives share a prefix with the query (synthetic relevance signal).
    """
    rng = np.random.default_rng(seed)
    q = rng.integers(0, vocab, size=(batch, seq_len), dtype=np.int32)
    d = q.copy()
    tail = seq_len // 2
    d[:, tail:] = rng.integers(0, vocab, size=(batch, seq_len - tail), dtype=np.int32)
    return {"query_tokens": q, "doc_tokens": d}
