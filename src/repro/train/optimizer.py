"""Optimizers: AdamW with optional int8-quantized moment states.

No optax dependency — states are plain pytrees so the checkpoint manager and
the dry-run (ShapeDtypeStruct pytrees) can treat them uniformly.

Mixed precision: model params may be bf16; the optimizer keeps an f32 master
copy and casts back after the update. The int8 variant stores the Adam
moments block-quantized (block 128 along the last axis, per-block absmax
scales) — 6 bytes/param of optimizer state instead of 12, which is what lets
the 671B config fit 16 GB/chip HBM at 512 chips (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Pytree = Any

QBLOCK = 128


# --------------------------------------------------------------------------
# block quantization helpers (also reused by gradient compression)
# --------------------------------------------------------------------------
def _pad_last(x: Array, mult: int) -> Array:
    pad = (-x.shape[-1]) % mult
    if pad:
        x = jnp.pad(x, [*[(0, 0)] * (x.ndim - 1), (0, pad)])
    return x


def quantize_blockwise(x: Array) -> tuple[Array, Array]:
    """f32 (..., d) -> (int8 (..., d), f32 scales (..., ceil(d/128)))."""
    orig = x.shape[-1]
    xp = _pad_last(x.astype(jnp.float32), QBLOCK)
    blocks = xp.reshape(*xp.shape[:-1], -1, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0  # (..., nb)
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-12))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q.reshape(*xp.shape[:-1], -1)[..., :orig], scale


def dequantize_blockwise(q: Array, scale: Array) -> Array:
    orig = q.shape[-1]
    qp = _pad_last(q, QBLOCK).astype(jnp.float32)
    blocks = qp.reshape(*qp.shape[:-1], -1, QBLOCK)
    x = blocks * scale[..., None]
    return x.reshape(*qp.shape[:-1], -1)[..., :orig]


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_state: bool = False  # int8 moments
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: Array) -> Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


class AdamWState(NamedTuple):
    step: Array
    master: Pytree  # f32 master weights
    m: Pytree  # f32, or (int8 q, f32 scale) pairs when quantized
    v: Pytree


def _zeros_moment(p: Array, quantized: bool):
    if quantized:
        q, s = quantize_blockwise(jnp.zeros(p.shape, jnp.float32))
        return {"q": q, "scale": s}
    return jnp.zeros(p.shape, jnp.float32)


def _read_moment(mm, quantized: bool) -> Array:
    return dequantize_blockwise(mm["q"], mm["scale"]) if quantized else mm


def _write_moment(x: Array, quantized: bool):
    if quantized:
        q, s = quantize_blockwise(x)
        return {"q": q, "scale": s}
    return x


def global_norm(tree: Pytree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
    )


def make_adamw(cfg: AdamWConfig):
    def init(params: Pytree) -> AdamWState:
        # copy=True: a no-op astype would alias the param buffer and break
        # donation (same buffer donated twice in the fused train step)
        master = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
        m = jax.tree.map(lambda p: _zeros_moment(p, cfg.quantized_state), params)
        v = jax.tree.map(lambda p: _zeros_moment(p, cfg.quantized_state), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), master=master, m=m, v=v)

    def update(grads: Pytree, state: AdamWState, params: Pytree):
        step = state.step + 1
        lr = lr_schedule(cfg, step)
        gn = global_norm(grads)
        clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

        is_q = cfg.quantized_state

        def upd(g, master, mm, vv, p):
            g = g.astype(jnp.float32) * clip
            m_f = _read_moment(mm, is_q)
            v_f = _read_moment(vv, is_q)
            if is_q:  # v stored as sqrt(v): halves the dynamic range the
                v_f = v_f * v_f  # int8 grid has to span
            m_new = cfg.b1 * m_f + (1 - cfg.b1) * g
            v_new = cfg.b2 * v_f + (1 - cfg.b2) * g * g
            upd_ = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
            decay = cfg.weight_decay * master if master.ndim >= 2 else 0.0
            master_new = master - lr * (upd_ + decay)
            v_store = jnp.sqrt(v_new) if is_q else v_new
            return (
                master_new,
                _write_moment(m_new, is_q),
                _write_moment(v_store, is_q),
                master_new.astype(p.dtype),
            )

        # tree_map over (grads, master, m, v, params). m/v leaves may be dicts
        # when quantized, so map over param structure explicitly.
        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_ma = treedef.flatten_up_to(state.master)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(g, ma, mm, vv, p)
               for g, ma, mm, vv, p in zip(flat_g, flat_ma, flat_m, flat_v, flat_p)]
        master_new = treedef.unflatten([o[0] for o in out])
        m_new = treedef.unflatten([o[1] for o in out])
        v_new = treedef.unflatten([o[2] for o in out])
        params_new = treedef.unflatten([o[3] for o in out])
        return params_new, AdamWState(step=step, master=master_new, m=m_new, v=v_new), {
            "grad_norm": gn, "lr": lr,
        }

    return init, update


def make_sgd(lr: float = 1e-2):
    """Plain SGD (used by convergence tests for gradient compression)."""

    def init(params):
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
                          m=None, v=None)

    def update(grads, state, params):
        master = jax.tree.map(
            lambda ma, g: ma - lr * g.astype(jnp.float32), state.master, grads
        )
        params_new = jax.tree.map(lambda ma, p: ma.astype(p.dtype), master, params)
        return params_new, AdamWState(step=state.step + 1, master=master,
                                      m=None, v=None), {}

    return init, update
