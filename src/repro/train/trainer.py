"""Fault-tolerant training loop.

Features (all exercised by tests):
* jitted train step with **microbatch gradient accumulation** (lax.scan);
* optional top-k gradient sparsification with error feedback;
* checkpoint/restart: params + optimizer state + data-iterator state are
  saved atomically and restored on construction if a checkpoint exists —
  a killed job resumes at the exact step with the exact data stream;
* **straggler watchdog**: per-step wall-time EMA; steps slower than
  ``watchdog_factor``×EMA are recorded (and surfaced to the launcher, which
  in a multi-host deployment triggers the skip-ahead / replace protocol);
* deterministic data pipeline (repro.data.pipeline) whose cursor lives in
  the checkpoint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.train import compression
from repro.train.optimizer import AdamWConfig, make_adamw

Pytree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    grad_accum: int = 1
    ckpt_dir: str | None = None
    ckpt_every: int = 50
    keep_ckpts: int = 2
    watchdog_factor: float = 3.0
    topk_compress: float = 0.0  # 0 = off; else fraction of grads communicated
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        loss_fn: Callable[[Pytree, dict], tuple[jax.Array, dict]],
        params: Pytree,
        opt_cfg: AdamWConfig,
        cfg: TrainerConfig,
        *,
        donate: bool = True,
    ):
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.opt_init, self.opt_update = make_adamw(opt_cfg)
        # own our copy: the fused step donates param buffers, which must not
        # invalidate the caller's pytree
        self.params = jax.tree.map(lambda p: jnp.array(p, copy=True), params)
        self.opt_state = self.opt_init(params)
        self.ef = (
            compression.init_error_feedback(params) if cfg.topk_compress else None
        )
        self.step = 0
        self.step_times: list[float] = []
        self.straggler_steps: list[int] = []
        self.manager = (
            CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts, config=opt_cfg)
            if cfg.ckpt_dir
            else None
        )
        self._train_step = jax.jit(
            self._step_impl, donate_argnums=(0, 1, 2) if donate else ()
        )

    # ------------------------------------------------------------------
    def _step_impl(self, params, opt_state, ef, batch):
        accum = self.cfg.grad_accum

        def micro(carry, mb):
            g_acc, loss_acc = carry
            (loss, _), g = jax.value_and_grad(self.loss_fn, has_aux=True)(
                params, mb
            )
            g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
            return (g_acc, loss_acc + loss), None

        if accum > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch,
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
        else:
            (loss, _), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
                params, batch
            )

        if ef is not None:
            grads, ef, _ = compression.topk_sparsify(
                grads, ef, self.cfg.topk_compress
            )
        params, opt_state, stats = self.opt_update(grads, opt_state, params)
        return params, opt_state, ef, loss, stats

    # ------------------------------------------------------------------
    def maybe_restore(self, data_state: dict | None = None) -> dict | None:
        """Resume from the latest checkpoint if one exists."""
        if self.manager is None or self.manager.latest_step() is None:
            return data_state
        tree = {"params": self.params, "opt": self.opt_state}
        if self.ef is not None:
            tree["ef"] = self.ef
        restored, manifest = self.manager.restore(tree)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.ef = restored.get("ef", self.ef)
        self.step = manifest["step"]
        return manifest.get("data_state", data_state)

    def save(self, data_state: dict | None = None, *, sync: bool = False) -> None:
        if self.manager is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        if self.ef is not None:
            tree["ef"] = self.ef
        self.manager.save(
            self.step, tree,
            extra={"data_state": data_state or {}},
            async_=not sync,
        )

    # ------------------------------------------------------------------
    def run(self, batches: Iterator[dict], *, steps: int | None = None,
            data_state_fn: Callable[[], dict] | None = None,
            log: Callable[[str], None] = print) -> dict:
        steps = steps if steps is not None else self.cfg.total_steps
        losses = []
        ema = None
        while self.step < steps:
            batch = next(batches)
            t0 = time.perf_counter()
            self.params, self.opt_state, self.ef, loss, stats = self._train_step(
                self.params, self.opt_state, self.ef, batch
            )
            loss = float(loss)
            dt = time.perf_counter() - t0
            self.step += 1
            self.step_times.append(dt)
            if ema is None:
                ema = dt
            elif dt > self.cfg.watchdog_factor * ema and self.step > 3:
                self.straggler_steps.append(self.step)
            ema = 0.9 * (ema or dt) + 0.1 * dt
            losses.append(loss)
            if self.step % self.cfg.log_every == 0:
                log(
                    f"step {self.step}: loss={loss:.4f} "
                    f"gnorm={float(stats.get('grad_norm', 0)):.3f} {dt*1e3:.0f}ms"
                )
            if self.manager and self.step % self.cfg.ckpt_every == 0:
                self.save(data_state_fn() if data_state_fn else None)
        if self.manager:
            self.save(data_state_fn() if data_state_fn else None, sync=True)
        return {
            "losses": losses,
            "final_loss": losses[-1] if losses else None,
            "stragglers": self.straggler_steps,
        }
