"""InfoNCE bi-encoder training — how the metric towers (d and D) are made.

In-batch-negative symmetric InfoNCE, the standard recipe for the embedding
models the paper uses (bge/gte/SFR are all trained this way). The end-to-end
driver (examples/train_biencoder.py) trains the cheap proxy tower with this
loss and then builds a bi-metric index over its embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer

Array = jax.Array


def info_nce_loss(params: dict, batch: dict, cfg: transformer.TransformerConfig,
                  *, temperature: float = 0.05) -> tuple[Array, dict]:
    q = transformer.embed_pool(params, batch["query_tokens"], cfg)  # (B, E)
    d = transformer.embed_pool(params, batch["doc_tokens"], cfg)  # (B, E)
    logits = (q @ d.T) / temperature  # (B, B) — in-batch negatives
    labels = jnp.arange(q.shape[0])
    lse_q = jax.nn.logsumexp(logits, axis=1)
    lse_d = jax.nn.logsumexp(logits, axis=0)
    diag = jnp.diagonal(logits)
    loss = ((lse_q - diag).mean() + (lse_d - diag).mean()) / 2
    acc = (logits.argmax(axis=1) == labels).mean()
    return loss, {"loss": loss, "acc": acc}
