from repro.train import compression, contrastive, optimizer, trainer  # noqa: F401
