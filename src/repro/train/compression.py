"""Gradient compression for bandwidth-bound data parallelism.

Two standard tricks, both testable numerically:

* **top-k sparsification with error feedback** (Deep Gradient Compression):
  only the largest-|g| fraction of each leaf is communicated; the residual is
  accumulated locally and folded into the next step, so the method converges
  to the dense optimum. The returned tree is dense-shaped (zeros elsewhere) —
  the collective volume is k_frac of dense, which is what the roofline's
  collective term credits.

* **int8 quantized all-reduce**: per-block absmax int8 quantization before
  psum, dequantize after — 4× collective-byte reduction with unbiased-ish
  rounding error bounded by the block absmax / 127.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def init_error_feedback(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def topk_sparsify(grads: Pytree, ef: Pytree, k_frac: float = 0.1):
    """Returns (sparse_grads, new_ef, stats). Dense-shaped, zero off-support."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        flat = gf.reshape(-1)
        k = max(1, int(k_frac * flat.shape[0]))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(gf) >= thresh).astype(jnp.float32)
        sparse = gf * mask
        return sparse.astype(g.dtype), gf - sparse

    flat, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    outs = [one(g, e) for g, e in zip(flat, flat_e)]
    sparse = treedef.unflatten([o[0] for o in outs])
    new_ef = treedef.unflatten([o[1] for o in outs])
    return sparse, new_ef, {"k_frac": k_frac}


QBLOCK = 128


def quantized_psum(tree: Pytree, axis_name: str) -> Pytree:
    """int8 all-reduce (inside shard_map): agree on per-block scales via a
    tiny pmax collective, integer-quantize against the *shared* scale, psum
    the int payload, dequantize. Exact integer summation; total quantization
    error per element is bounded by the global block absmax / 127. Wire
    bytes: 1 B/element + 4 B per 128 elements of scale (vs 4 B/element f32).
    """

    def one(g):
        gf = g.astype(jnp.float32)
        orig = gf.shape[-1]
        pad = (-orig) % QBLOCK
        gp = jnp.pad(gf, [*[(0, 0)] * (gf.ndim - 1), (0, pad)]) if pad else gf
        blocks = gp.reshape(*gp.shape[:-1], -1, QBLOCK)
        local_scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
        scale = jax.lax.pmax(local_scale, axis_name)  # shared scale (tiny)
        q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int8 on the wire
        out = (total.astype(jnp.float32) * scale).reshape(*gp.shape)
        return out[..., :orig].astype(g.dtype)

    return jax.tree.map(one, tree)
