"""Mixture-of-Experts FFN with grouped sort-based capacity dispatch.

Token-choice top-k routing. Dispatch avoids both the O(T·E·C) GShard one-hot
and a *global* sort:

  1. tokens are split into G groups aligned with the data-parallel shards;
  2. within each group (vmapped → fully shard-local): flatten (token, slot)
     assignments, sort by expert id, rank-within-expert via searchsorted,
     drop overflow beyond the per-group capacity C_g;
  3. scatter into a (G, E, C_g, d) buffer — G lives on the dp axes, E on
     "model", so the only cross-device movement is the token→expert
     all-to-all that GSPMD derives from the buffer's expert sharding;
  4. one batched einsum per expert matmul against stacked weights, then the
     inverse gather combines weighted expert outputs per group.

Aux losses: load-balancing (Switch) + router z-loss, computed globally.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_moe_buf
from repro.models import layers

Array = jax.Array


class MoEConfig(NamedTuple):
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int  # per-expert
    n_shared: int = 0  # always-on shared experts (DeepSeek-V3 style)
    capacity_factor: float = 1.25
    n_groups: int = 32  # dispatch groups (≥ #dp shards keeps scatters local)
    dtype: jnp.dtype = jnp.float32


def init_moe(key, cfg: MoEConfig) -> dict:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * scale_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * scale_in).astype(cfg.dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * scale_in).astype(cfg.dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * scale_out).astype(cfg.dtype),
    }
    if cfg.n_shared:
        p["shared"] = layers.init_swiglu(
            ks[4], d, cfg.d_ff * cfg.n_shared, cfg.dtype
        )
    return p


def capacity(cfg: MoEConfig, n_tokens: int) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


class MoEOut(NamedTuple):
    y: Array
    aux_loss: Array
    z_loss: Array


def _dispatch_group(xg: Array, top_e: Array, top_p: Array, e: int, c: int):
    """One group's sort-based dispatch. xg (Tg, d) -> buffer (E, C, d) plus
    the bookkeeping needed to combine back."""
    tg, d = xg.shape
    k = top_e.shape[-1]
    flat_e = top_e.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype), side="left")
    pos = jnp.arange(tg * k, dtype=jnp.int32) - starts[se]
    keep = pos < c
    se_c = jnp.where(keep, se, 0)
    pos_c = jnp.where(keep, pos, 0)
    buf = jnp.zeros((e, c, d), xg.dtype)
    buf = buf.at[se_c, pos_c].add(jnp.where(keep[:, None], xg[st], 0))
    return buf, (se_c, pos_c, st, sw, keep)


def _combine_group(out_buf: Array, book, tg: int) -> Array:
    se_c, pos_c, st, sw, keep = book
    gathered = out_buf[se_c, pos_c]  # (Tg*K, d)
    contrib = jnp.where(keep[:, None],
                        gathered * sw[:, None].astype(out_buf.dtype), 0)
    return jnp.zeros((tg, out_buf.shape[-1]), out_buf.dtype).at[st].add(contrib)


def moe_ffn(params: dict, x: Array, cfg: MoEConfig) -> MoEOut:
    """x: (..., d_model) -> same shape. Flattens leading dims to tokens."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    e, k = cfg.n_experts, cfg.top_k
    g = cfg.n_groups if (t % cfg.n_groups == 0 and t >= cfg.n_groups) else 1
    tg = t // g
    c = capacity(cfg, tg)

    xg = xt.reshape(g, tg, d)
    logits = xg.astype(jnp.float32) @ params["router"]  # (G, Tg, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (G, Tg, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    ep = e % 16 == 0  # expert-parallel when E divides the model axis
    buf, book = jax.vmap(
        lambda xg_, te_, tp_: _dispatch_group(xg_, te_, tp_, e, c)
    )(xg, top_e, top_p)  # buf (G, E, C, d)
    buf = constrain_moe_buf(buf, ep)

    gte = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    h = constrain_moe_buf(
        jax.nn.silu(gte.astype(jnp.float32)).astype(x.dtype) * up, ep)
    out_buf = constrain_moe_buf(
        jnp.einsum("gecf,efd->gecd", h, params["w_down"]), ep)  # (G, E, C, d)

    y = jax.vmap(lambda ob, bk: _combine_group(ob, bk, tg))(out_buf, book)
    y = y.reshape(t, d)

    # ---- shared experts (dense) -----------------------------------------
    if "shared" in params:
        s = params["shared"]
        y = y + layers.swiglu(xt, s["w_gate"], s["w_up"], s["w_down"])

    # ---- aux losses ------------------------------------------------------
    ohot = jax.nn.one_hot(top_e[..., 0].reshape(-1), e, dtype=jnp.float32)
    frac_tok = ohot.mean(axis=0)
    frac_prob = probs.reshape(-1, e).mean(axis=0)
    aux = e * jnp.sum(frac_tok * frac_prob)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    return MoEOut(y=y.reshape(*lead, d), aux_loss=aux, z_loss=z)
