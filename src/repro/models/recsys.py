"""RecSys ranking models: BST, DIN, BERT4Rec, xDeepFM.

The embedding *lookup-reduce* is the hot path; JAX has no nn.EmbeddingBag, so
we build it: dense `jnp.take` + masked reduce for fixed-length bags, and a
`segment_sum` variant for ragged multi-hot bags. Tables are row-sharded over
the ``model`` mesh axis in the launch configs (the tables are the memory
footprint; the MLP heads are tiny).

In the bi-metric system these models are the *expensive metric D*: scoring a
(user, candidate) pair requires a forward pass (target attention / CIN over
the joint features) and cannot be precomputed — precisely the regime where
the paper's two-stage search beats re-ranking. ``score_candidates`` is the
budgeted D-call entry; cheap retrieval embeddings provide d.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_axis, constrain_batch
from repro.models import layers

Array = jax.Array


# --------------------------------------------------------------------------
# EmbeddingBag (built, not stubbed)
# --------------------------------------------------------------------------
def embedding_bag(table: Array, idx: Array, mask: Array | None = None,
                  mode: str = "sum") -> Array:
    """Fixed-shape bag: table (V, D), idx (..., L) -> (..., D).

    ``mask`` (..., L) marks valid entries (padding rows excluded from the
    reduce). mode: sum | mean.
    """
    rows = jnp.take(table, jnp.maximum(idx, 0), axis=0)
    if mask is None:
        mask = (idx >= 0).astype(rows.dtype)
    rows = rows * mask[..., None].astype(rows.dtype)
    s = rows.sum(axis=-2)
    if mode == "mean":
        s = s / jnp.maximum(mask.sum(-1, keepdims=True), 1.0).astype(s.dtype)
    return s


def embedding_bag_ragged(table: Array, indices: Array, segment_ids: Array,
                         n_bags: int, mode: str = "sum") -> Array:
    """Ragged multi-hot bag: gather rows then segment-reduce per bag."""
    rows = jnp.take(table, jnp.maximum(indices, 0), axis=0)
    rows = jnp.where((indices >= 0)[:, None], rows, 0)
    out = jax.ops.segment_sum(rows, jnp.maximum(segment_ids, 0), num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(
            (indices >= 0).astype(rows.dtype), jnp.maximum(segment_ids, 0),
            num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def _bce(logit: Array, label: Array) -> Array:
    lf = logit.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(lf, 0) - lf * label.astype(jnp.float32)
        + jnp.log1p(jnp.exp(-jnp.abs(lf)))
    )


def _init_mlp(key, dims: list[int], dtype) -> dict:
    ws, bs = [], []
    for i in range(len(dims) - 1):
        key, k = jax.random.split(key)
        ws.append(layers.dense_init(k, dims[i], dims[i + 1], dtype))
        bs.append(jnp.zeros((dims[i + 1],), dtype))
    return {"ws": ws, "bs": bs}


# ==========================================================================
# BST — Behavior Sequence Transformer (arXiv:1905.06874)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    vocab: int = 1_048_576
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: tuple = (1024, 512, 256)
    dtype: Any = jnp.float32


def bst_init(key, cfg: BSTConfig) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.embed_dim
    s1 = cfg.seq_len + 1
    p = {
        "item_emb": layers.embed_init(ks[0], cfg.vocab, d, cfg.dtype),
        "pos_emb": layers.embed_init(ks[1], s1, d, cfg.dtype),
        "blocks": [],
        "head": _init_mlp(ks[2], [s1 * d, *cfg.mlp_dims, 1], cfg.dtype),
    }
    for i in range(cfg.n_blocks):
        k = jax.random.fold_in(ks[3], i)
        ka, kf = jax.random.split(k)
        hd = d // cfg.n_heads
        p["blocks"].append({
            "wq": layers.dense_init(jax.random.fold_in(ka, 0), d, d, cfg.dtype),
            "wk": layers.dense_init(jax.random.fold_in(ka, 1), d, d, cfg.dtype),
            "wv": layers.dense_init(jax.random.fold_in(ka, 2), d, d, cfg.dtype),
            "wo": layers.dense_init(jax.random.fold_in(ka, 3), d, d, cfg.dtype),
            "ln1": jnp.ones((d,), cfg.dtype),
            "ln1b": jnp.zeros((d,), cfg.dtype),
            "ffn": _init_mlp(kf, [d, 4 * d, d], cfg.dtype),
            "ln2": jnp.ones((d,), cfg.dtype),
            "ln2b": jnp.zeros((d,), cfg.dtype),
        })
    return p


def _mha(p, x, n_heads: int):
    b, s, d = x.shape
    hd = d // n_heads
    q = (x @ p["wq"]).reshape(b, s, n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, n_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, n_heads, hd)
    out = layers.blockwise_attention(q, k, v, causal=False, block_kv=max(s, 16))
    return out.reshape(b, s, d) @ p["wo"]


def bst_forward(params: dict, hist: Array, target: Array, cfg: BSTConfig) -> Array:
    """hist (B, L) item ids (-1 pad), target (B,) -> logits (B,)."""
    b = hist.shape[0]
    seq = jnp.concatenate([hist, target[:, None]], axis=1)  # (B, L+1)
    x = embedding_bag(params["item_emb"], seq[..., None])  # (B, L+1, D) via take
    x = constrain_batch(x + params["pos_emb"][None, :, :])
    for blk in params["blocks"]:
        h = layers.layer_norm(x, blk["ln1"], blk["ln1b"])
        x = x + _mha(blk, h, cfg.n_heads)
        h = layers.layer_norm(x, blk["ln2"], blk["ln2b"])
        x = constrain_batch(x + layers.mlp(h, blk["ffn"]["ws"], blk["ffn"]["bs"],
                                           act=jax.nn.leaky_relu))
    flat = x.reshape(b, -1)
    return layers.mlp(flat, params["head"]["ws"], params["head"]["bs"],
                      act=jax.nn.leaky_relu)[:, 0]


def bst_loss(params, batch, cfg: BSTConfig):
    logit = bst_forward(params, batch["hist"], batch["target"], cfg)
    loss = _bce(logit, batch["label"])
    return loss, {"loss": loss}


def bst_score_candidates(params, hist: Array, cand: Array, cfg: BSTConfig) -> Array:
    """hist (1, L) one user; cand (N,) -> (N,) scores. Broadcasts the history;
    the candidate axis is pinned to "model" so the 1M-deep scoring batch
    stays sharded through the broadcast."""
    n = cand.shape[0]
    hist_b = constrain_axis(jnp.broadcast_to(hist, (n, hist.shape[1])), 0,
                            axes=("data", "model"))
    return bst_forward(params, hist_b, cand, cfg)


# ==========================================================================
# DIN — Deep Interest Network (arXiv:1706.06978)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    vocab: int = 1_048_576
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    mlp_dims: tuple = (200, 80)
    dtype: Any = jnp.float32


def din_init(key, cfg: DINConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "item_emb": layers.embed_init(k1, cfg.vocab, d, cfg.dtype),
        "attn": _init_mlp(k2, [4 * d, *cfg.attn_mlp, 1], cfg.dtype),
        "head": _init_mlp(k3, [2 * d, *cfg.mlp_dims, 1], cfg.dtype),
    }


def din_forward(params, hist: Array, target: Array, cfg: DINConfig) -> Array:
    h = constrain_batch(
        jnp.take(params["item_emb"], jnp.maximum(hist, 0), axis=0))  # (B, L, D)
    mask = (hist >= 0)
    t = jnp.take(params["item_emb"], target, axis=0)  # (B, D)
    tb = jnp.broadcast_to(t[:, None], h.shape)
    att_in = jnp.concatenate([h, tb, h - tb, h * tb], axis=-1)  # (B, L, 4D)
    w = layers.mlp(att_in, params["attn"]["ws"], params["attn"]["bs"],
                   act=jax.nn.sigmoid)[..., 0]  # (B, L)
    w = jnp.where(mask, w.astype(jnp.float32), -jnp.inf)
    w = jax.nn.softmax(w, axis=-1)
    w = jnp.where(mask, w, 0.0).astype(h.dtype)
    pooled = (h * w[..., None]).sum(axis=1)  # (B, D)
    feat = jnp.concatenate([pooled, t], axis=-1)
    return layers.mlp(feat, params["head"]["ws"], params["head"]["bs"],
                      act=jax.nn.sigmoid)[:, 0]


def din_loss(params, batch, cfg: DINConfig):
    logit = din_forward(params, batch["hist"], batch["target"], cfg)
    loss = _bce(logit, batch["label"])
    return loss, {"loss": loss}


def din_score_candidates(params, hist: Array, cand: Array, cfg: DINConfig) -> Array:
    n = cand.shape[0]
    hist_b = constrain_axis(jnp.broadcast_to(hist, (n, hist.shape[1])), 0,
                            axes=("data", "model"))
    return din_forward(params, hist_b, cand, cfg)


# ==========================================================================
# BERT4Rec (arXiv:1904.06690)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    vocab: int = 65_536
    embed_dim: int = 64
    seq_len: int = 200
    n_blocks: int = 2
    n_heads: int = 2
    n_masked: int = 40  # masked positions per sequence (20%)
    dtype: Any = jnp.float32


def bert4rec_init(key, cfg: Bert4RecConfig) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.embed_dim
    p = {
        "item_emb": layers.embed_init(ks[0], cfg.vocab, d, cfg.dtype),
        "pos_emb": layers.embed_init(ks[1], cfg.seq_len, d, cfg.dtype),
        "blocks": [],
        "final_ln": jnp.ones((d,), cfg.dtype),
        "final_lnb": jnp.zeros((d,), cfg.dtype),
    }
    for i in range(cfg.n_blocks):
        k = jax.random.fold_in(ks[2], i)
        ka, kf = jax.random.split(k)
        p["blocks"].append({
            "wq": layers.dense_init(jax.random.fold_in(ka, 0), d, d, cfg.dtype),
            "wk": layers.dense_init(jax.random.fold_in(ka, 1), d, d, cfg.dtype),
            "wv": layers.dense_init(jax.random.fold_in(ka, 2), d, d, cfg.dtype),
            "wo": layers.dense_init(jax.random.fold_in(ka, 3), d, d, cfg.dtype),
            "ln1": jnp.ones((d,), cfg.dtype),
            "ln1b": jnp.zeros((d,), cfg.dtype),
            "ffn": _init_mlp(kf, [d, 4 * d, d], cfg.dtype),
            "ln2": jnp.ones((d,), cfg.dtype),
            "ln2b": jnp.zeros((d,), cfg.dtype),
        })
    return p


def bert4rec_encode(params, items: Array, cfg: Bert4RecConfig) -> Array:
    x = jnp.take(params["item_emb"], jnp.maximum(items, 0), axis=0)
    x = x + params["pos_emb"][None, : items.shape[1], :]

    @jax.checkpoint
    def block(blk, x):
        h = layers.layer_norm(x, blk["ln1"], blk["ln1b"])
        x = x + _mha(blk, h, cfg.n_heads)
        h = layers.layer_norm(x, blk["ln2"], blk["ln2b"])
        return x + layers.mlp(h, blk["ffn"]["ws"], blk["ffn"]["bs"],
                              act=jax.nn.gelu)

    for blk in params["blocks"]:
        x = block(blk, x)
    return layers.layer_norm(x, params["final_ln"], params["final_lnb"])


def bert4rec_loss(params, batch, cfg: Bert4RecConfig, chunk: int = 8192):
    """Masked-item prediction: items (B, S), mask_pos (B, M), mask_labels (B, M).

    The (B, M, V) logits are kept vocab-sharded over "model": the gold logit
    is a direct row-dot (no V-axis gather), and the logsumexp is computed
    shard-split so the full catalogue never materializes per device. Large
    batches stream in row chunks (scan + remat) so the live logits block is
    one chunk deep."""

    @jax.checkpoint
    def chunk_loss(items, mask_pos, mask_labels):
        h = bert4rec_encode(params, items, cfg)  # (b, S, D)
        hm = jnp.take_along_axis(h, mask_pos[..., None], axis=1)  # (b, M, D)
        b, m, d = hm.shape
        v = params["item_emb"].shape[0]
        # gold logit without touching the (b, M, V) tensor
        gold_rows = jnp.take(params["item_emb"], mask_labels, axis=0)
        gold = jnp.einsum("bmd,bmd->bm", hm.astype(jnp.float32),
                          gold_rows.astype(jnp.float32))
        # shard-split logsumexp over the catalogue
        n_shard = 16 if v % 16 == 0 else 1
        l4 = (hm @ params["item_emb"].T).reshape(b, m, n_shard, v // n_shard)
        l4 = constrain_axis(l4, 2)
        lse = jax.nn.logsumexp(
            jax.nn.logsumexp(l4.astype(jnp.float32), axis=-1), axis=-1)
        return (lse - gold).sum()

    n = batch["items"].shape[0]
    if n <= chunk or n % chunk:
        loss = chunk_loss(batch["items"], batch["mask_pos"],
                          batch["mask_labels"]) / (n * cfg.n_masked)
        return loss, {"loss": loss}
    rs = lambda x: x.reshape(n // chunk, chunk, *x.shape[1:])
    total, _ = jax.lax.scan(
        lambda acc, inp: (acc + chunk_loss(*inp), None),
        jnp.float32(0),
        (rs(batch["items"]), rs(batch["mask_pos"]), rs(batch["mask_labels"])),
    )
    loss = total / (n * cfg.n_masked)
    return loss, {"loss": loss}


def bert4rec_score_candidates(params, items: Array, cand: Array,
                              cfg: Bert4RecConfig) -> Array:
    """Next-item scores: last-position hidden · candidate item embeddings."""
    h = bert4rec_encode(params, items, cfg)[:, -1]  # (B, D)
    ce = constrain_axis(jnp.take(params["item_emb"], cand, axis=0), 0,
                        axes=("data", "model"))  # (N, D)
    return (h @ ce.T)[0]  # single user


# ==========================================================================
# xDeepFM (arXiv:1803.05170)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_fields: int = 39
    field_vocab: int = 1_048_576  # rows per field (one stacked table)
    embed_dim: int = 10
    cin_layers: tuple = (200, 200, 200)
    mlp_dims: tuple = (400, 400)
    n_item_fields: int = 13  # trailing fields supplied by the candidate
    dtype: Any = jnp.float32


def xdeepfm_init(key, cfg: XDeepFMConfig) -> dict:
    ks = jax.random.split(key, 5)
    m, d = cfg.n_fields, cfg.embed_dim
    p = {
        "table": layers.embed_init(ks[0], cfg.n_fields * cfg.field_vocab, d, cfg.dtype),
        "linear": (jax.random.normal(ks[1], (cfg.n_fields * cfg.field_vocab, 1))
                   * 0.01).astype(cfg.dtype),
        "cin": [],
        "dnn": _init_mlp(ks[2], [m * d, *cfg.mlp_dims, 1], cfg.dtype),
        "cin_out": layers.dense_init(ks[3], sum(cfg.cin_layers), 1, cfg.dtype),
        "bias": jnp.zeros((), cfg.dtype),
    }
    h_prev = m
    for i, h in enumerate(cfg.cin_layers):
        k = jax.random.fold_in(ks[4], i)
        p["cin"].append(
            (jax.random.normal(k, (h, h_prev * m)) / (h_prev * m) ** 0.5).astype(cfg.dtype)
        )
        h_prev = h
    return p


def xdeepfm_forward(params, fields: Array, cfg: XDeepFMConfig) -> Array:
    """fields: (B, n_fields) per-field row index -> logits (B,)."""
    b, m = fields.shape
    offsets = (jnp.arange(m, dtype=fields.dtype) * cfg.field_vocab)[None, :]
    flat_idx = fields + offsets
    emb = constrain_batch(jnp.take(params["table"], flat_idx, axis=0))  # (B, m, D)

    # CIN
    x0 = emb
    xk = emb
    pools = []
    for w in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)  # (B, Hk, m, D)
        z = z.reshape(b, -1, cfg.embed_dim)  # (B, Hk*m, D)
        xk = jnp.einsum("bpd,hp->bhd", z, w)  # (B, Hk+1, D)
        pools.append(xk.sum(axis=-1))  # (B, Hk+1)
    cin_feat = jnp.concatenate(pools, axis=-1)
    cin_logit = (cin_feat @ params["cin_out"])[:, 0]

    dnn_logit = layers.mlp(emb.reshape(b, -1), params["dnn"]["ws"],
                           params["dnn"]["bs"], act=jax.nn.relu)[:, 0]
    lin_logit = jnp.take(params["linear"], flat_idx, axis=0)[..., 0].sum(-1)
    return cin_logit + dnn_logit + lin_logit + params["bias"]


def xdeepfm_loss(params, batch, cfg: XDeepFMConfig):
    logit = xdeepfm_forward(params, batch["fields"], cfg)
    loss = _bce(logit, batch["label"])
    return loss, {"loss": loss}


def xdeepfm_score_candidates(params, user_fields: Array, cand_fields: Array,
                             cfg: XDeepFMConfig, chunk: int = 100_000) -> Array:
    """user_fields (1, m-k); cand_fields (N, k) -> (N,) scores.

    The CIN's (B, H·m, D) outer-product tensor is inherently large, so the
    1M-candidate sweep runs as a scan over candidate chunks — peak memory is
    one chunk's CIN, wall work identical."""
    n = cand_fields.shape[0]
    uf = jnp.broadcast_to(user_fields, (n, user_fields.shape[1]))
    fields = constrain_axis(jnp.concatenate([uf, cand_fields], axis=-1), 0,
                            axes=("data", "model"))
    if n % chunk or n <= chunk:
        return xdeepfm_forward(params, fields, cfg)
    fc = fields.reshape(n // chunk, chunk, cfg.n_fields)
    return jax.lax.map(
        lambda f: xdeepfm_forward(params, constrain_axis(f, 0), cfg), fc
    ).reshape(n)
