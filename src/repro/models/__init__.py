from repro.models import gnn, layers, moe, recsys, transformer  # noqa: F401
