"""Config-driven LM-family transformer: dense GQA/MQA, Qwen3 qk-norm,
DeepSeek MLA (+ absorbed decode), MoE FFN with shared experts, MTP head.

Layer stacks are scanned (`lax.scan` over stacked params) so the HLO size is
depth-independent; activation rematerialization is configurable. Three entry
points per model: ``forward`` (train/score), ``prefill`` (build KV cache),
``decode`` (one token against a cache — O(cache) flash-decode semantics).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_batch, constrain_seq
from repro.models import layers
from repro.models.moe import MoEConfig, init_moe, moe_ffn

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    qk_norm: bool = False
    rope_theta: float = 1e4
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared: int = 0
    first_dense: int = 0  # leading dense-FFN layers (DeepSeek-V3: 3)
    # MLA
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False  # multi-token-prediction auxiliary head
    embed_dim: int = 0  # retrieval-embedding head (0 = none)
    dtype: Any = jnp.float32
    remat: str = "none"  # none | full
    block_kv: int = 512
    aux_loss_coef: float = 0.001
    z_loss_coef: float = 1e-4
    mtp_coef: float = 0.3
    capacity_factor: float = 1.25
    # distribution/memory policy (see EXPERIMENTS.md §Perf)
    seq_parallel: bool = True  # Megatron SP on the residual stream
    ce_chunk: int = 2048  # sequence-chunked cross entropy (0 = dense)

    @property
    def qk_dim(self) -> int:
        return (self.qk_nope_dim + self.qk_rope_dim) if self.mla else self.head_dim

    @property
    def v_dim(self) -> int:
        return self.v_head_dim if self.mla else self.head_dim

    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(
            n_experts=self.n_experts,
            top_k=self.top_k,
            d_model=self.d_model,
            d_ff=self.moe_d_ff,
            n_shared=self.n_shared,
            capacity_factor=self.capacity_factor,
            dtype=self.dtype,
        )


# ==========================================================================
# parameter construction
# ==========================================================================
def _init_attn(key, cfg: TransformerConfig) -> dict:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    if cfg.mla:
        p = {
            "q_a": layers.dense_init(ks[0], d, cfg.q_lora_rank, cfg.dtype),
            "q_a_norm": jnp.ones((cfg.q_lora_rank,), cfg.dtype),
            "q_b": layers.dense_init(
                ks[1], cfg.q_lora_rank, h * cfg.qk_dim, cfg.dtype
            ),
            "kv_a": layers.dense_init(
                ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, cfg.dtype
            ),
            "kv_a_norm": jnp.ones((cfg.kv_lora_rank,), cfg.dtype),
            "k_b": layers.dense_init(
                ks[3], cfg.kv_lora_rank, h * cfg.qk_nope_dim, cfg.dtype
            ),
            "v_b": layers.dense_init(
                ks[4], cfg.kv_lora_rank, h * cfg.v_head_dim, cfg.dtype
            ),
            "wo": layers.dense_init(ks[5], h * cfg.v_head_dim, d, cfg.dtype),
        }
    else:
        p = {
            "wq": layers.dense_init(ks[0], d, h * hd, cfg.dtype),
            "wk": layers.dense_init(ks[1], d, hk * hd, cfg.dtype),
            "wv": layers.dense_init(ks[2], d, hk * hd, cfg.dtype),
            "wo": layers.dense_init(ks[3], h * hd, d, cfg.dtype),
        }
        if cfg.qk_norm:
            p["q_norm"] = jnp.ones((hd,), cfg.dtype)
            p["k_norm"] = jnp.ones((hd,), cfg.dtype)
    return p


def _init_block(key, cfg: TransformerConfig, use_moe: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "attn": _init_attn(k1, cfg),
        "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if use_moe:
        p["moe"] = init_moe(k2, cfg.moe_cfg())
    else:
        p["ffn"] = layers.init_swiglu(k2, cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def init_params(key, cfg: TransformerConfig) -> dict:
    ke, kd, km, kh, kt, kp = jax.random.split(key, 6)
    n_dense = cfg.first_dense if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0
    p: dict = {
        "embed": layers.embed_init(ke, cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if n_dense:
        keys = jax.random.split(kd, n_dense)
        p["dense_blocks"] = jax.vmap(lambda k: _init_block(k, cfg, False))(keys)
    if n_moe:
        keys = jax.random.split(km, n_moe)
        p["moe_blocks"] = jax.vmap(lambda k: _init_block(k, cfg, True))(keys)
    if cfg.mtp:
        k1, k2 = jax.random.split(kt)
        p["mtp"] = {
            "proj": layers.dense_init(k1, 2 * cfg.d_model, cfg.d_model, cfg.dtype),
            "block": _init_block(k2, cfg, False),
            "norm": jnp.ones((cfg.d_model,), cfg.dtype),
        }
    if cfg.embed_dim:
        p["embed_head"] = layers.dense_init(kh, cfg.d_model, cfg.embed_dim, cfg.dtype)
    return p


# ==========================================================================
# blocks
# ==========================================================================
def _attention(p: dict, x: Array, positions: Array, cfg: TransformerConfig,
               kv_override=None) -> Array:
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    b, s, d = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla:
        qa = layers.rms_norm(x @ p["q_a"], p["q_a_norm"])
        q = (qa @ p["q_b"]).reshape(b, s, h, cfg.qk_dim)
        q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
        q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

        kv = x @ p["kv_a"]  # (B, S, kv_lora + rope)
        c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
        c_kv = layers.rms_norm(c_kv, p["kv_a_norm"])
        k_rope = layers.apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
        k_nope = (c_kv @ p["k_b"]).reshape(b, s, h, cfg.qk_nope_dim)
        v = (c_kv @ p["v_b"]).reshape(b, s, h, cfg.v_head_dim)

        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        kk = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, cfg.qk_rope_dim))], axis=-1
        )
        out = layers.blockwise_attention(
            qq, kk, v, causal=True, block_kv=cfg.block_kv,
            scale=1.0 / math.sqrt(cfg.qk_dim),
        )
        return out.reshape(b, s, h * cfg.v_head_dim) @ p["wo"]

    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, hk, hd)
    v = (x @ p["wv"]).reshape(b, s, hk, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    # grouped attention: the GQA/MQA repeat stays inside the einsums
    out = layers.blockwise_attention(q, k, v, causal=True, block_kv=cfg.block_kv)
    return out.reshape(b, s, h * hd) @ p["wo"]


def _block(p: dict, x: Array, positions: Array, cfg: TransformerConfig,
           use_moe: bool):
    # keep the residual stream batch-sharded (and sequence-sharded under SP)
    # against the FSDP/TP weights — including the scan's remat stash.
    _c = constrain_seq if cfg.seq_parallel else constrain_batch
    x = _c(x)
    a = _attention(p["attn"], layers.rms_norm(x, p["ln1"]), positions, cfg)
    x = _c(x + a)
    hn = layers.rms_norm(x, p["ln2"])
    if use_moe:
        out = moe_ffn(p["moe"], hn, cfg.moe_cfg())
        return _c(x + out.y), out.aux_loss, out.z_loss
    f = layers.swiglu(hn, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"])
    return _c(x + f), jnp.float32(0), jnp.float32(0)


def _scan_blocks(blocks: dict, x: Array, positions: Array,
                 cfg: TransformerConfig, use_moe: bool):
    def step(carry, lp):
        h, aux, z = carry
        f = partial(_block, cfg=cfg, use_moe=use_moe)
        if cfg.remat != "none":
            f = jax.checkpoint(f, static_argnums=())
        h, a, zz = f(lp, h, positions)
        return (h, aux + a, z + zz), None

    (x, aux, z), _ = jax.lax.scan(step, (x, jnp.float32(0), jnp.float32(0)), blocks)
    return x, aux, z


# ==========================================================================
# entry points
# ==========================================================================
class ForwardOut(NamedTuple):
    hidden: Array  # (B, S, d) final hidden (pre-norm applied)
    logits: Array | None
    aux_loss: Array
    z_loss: Array


def forward(params: dict, tokens: Array, cfg: TransformerConfig,
            *, with_logits: bool = True) -> ForwardOut:
    b, s = tokens.shape
    x = constrain_batch(params["embed"][tokens].astype(cfg.dtype))
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    aux = z = jnp.float32(0)
    if "dense_blocks" in params:
        x, a1, z1 = _scan_blocks(params["dense_blocks"], x, positions, cfg, False)
        aux, z = aux + a1, z + z1
    if "moe_blocks" in params:
        x, a2, z2 = _scan_blocks(params["moe_blocks"], x, positions, cfg, True)
        aux, z = aux + a2, z + z2
    x = layers.rms_norm(x, params["final_norm"])
    logits = None
    if with_logits:
        logits = x @ params["embed"].T  # tied head
    return ForwardOut(hidden=x, logits=logits, aux_loss=aux, z_loss=z)


def cross_entropy(logits: Array, labels: Array) -> Array:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return (lse - gold).mean()


def chunked_cross_entropy(hidden: Array, embed: Array, labels: Array,
                          chunk: int) -> Array:
    """CE against a tied vocab head without materializing (B, S, V) logits:
    scan over sequence chunks, rematerializing each chunk's logits in the
    backward pass. Peak extra memory = one (B, chunk, V_shard) block."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk
    et = embed.T

    @jax.checkpoint
    def one(h_c, l_c):
        logits = h_c @ et
        lf = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, l_c[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    def step(tot, inp):
        h_c, l_c = inp
        return tot + one(h_c, l_c), None

    hc = hidden[:, : n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1)
    lc = labels[:, : n * chunk].reshape(b, n, chunk).swapaxes(0, 1)
    total, _ = jax.lax.scan(step, jnp.float32(0), (hc, lc))
    if rem:
        total = total + one(hidden[:, n * chunk:], labels[:, n * chunk:])
    return total / (b * s)


def loss_fn(params: dict, batch: dict, cfg: TransformerConfig) -> tuple[Array, dict]:
    use_chunked = cfg.ce_chunk and batch["tokens"].shape[1] > cfg.ce_chunk
    out = forward(params, batch["tokens"], cfg, with_logits=not use_chunked)
    if use_chunked:
        ce = chunked_cross_entropy(out.hidden, params["embed"],
                                   batch["labels"], cfg.ce_chunk)
    else:
        ce = cross_entropy(out.logits, batch["labels"])
    total = ce + cfg.aux_loss_coef * out.aux_loss + cfg.z_loss_coef * out.z_loss
    metrics = {"ce": ce, "aux": out.aux_loss, "z": out.z_loss}
    if cfg.mtp:
        # MTP (DeepSeek-V3): one extra block predicts token t+2 from
        # [h_t ; emb(token_{t+1})]  — trains lookahead without a second trunk.
        h = out.hidden[:, :-1]
        nxt = params["embed"][batch["tokens"][:, 1:]].astype(cfg.dtype)
        m = params["mtp"]
        hm = jnp.concatenate([h, nxt], axis=-1) @ m["proj"]
        b, s, _ = hm.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        hm, _, _ = _block(m["block"], hm, positions, cfg, False)
        hm = layers.rms_norm(hm, m["norm"])
        # position t predicts token t+2 == labels[t+1]
        if use_chunked:
            mtp_ce = chunked_cross_entropy(hm, params["embed"],
                                           batch["labels"][:, 1:], cfg.ce_chunk)
        else:
            mtp_ce = cross_entropy(hm @ params["embed"].T,
                                   batch["labels"][:, 1:])
        total = total + cfg.mtp_coef * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = total
    return total, metrics


def embed_pool(params: dict, tokens: Array, cfg: TransformerConfig) -> Array:
    """Retrieval-embedding tower: mean-pool final hidden -> proj -> l2 norm."""
    out = forward(params, tokens, cfg, with_logits=False)
    pooled = out.hidden.mean(axis=1)
    if "embed_head" in params:
        pooled = pooled @ params["embed_head"]
    pooled = pooled.astype(jnp.float32)
    return pooled * jax.lax.rsqrt((pooled * pooled).sum(-1, keepdims=True) + 1e-9)


# ---------------------------- decode path ---------------------------------
class KVCache(NamedTuple):
    """GQA: k/v (L, B, S, Hkv, dh). MLA: c_kv (L, B, S, rank), k_rope (L, B, S, rope)."""
    k: Array
    v: Array
    length: Array  # () int32 — tokens already in the cache


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               length: int = 0) -> KVCache:
    L = cfg.n_layers
    if cfg.mla:
        k = jnp.zeros((L, batch, max_seq, cfg.kv_lora_rank), cfg.dtype)
        v = jnp.zeros((L, batch, max_seq, cfg.qk_rope_dim), cfg.dtype)
    else:
        k = jnp.zeros((L, batch, max_seq, cfg.n_kv_heads, cfg.head_dim), cfg.dtype)
        v = jnp.zeros_like(k)
    return KVCache(k=k, v=v, length=jnp.int32(length))


def _decode_attn_gqa(p, x, cache_k, cache_v, length, cfg: TransformerConfig):
    b, s1, d = x.shape  # s1 == 1
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = jnp.broadcast_to(length[None, None], (b, 1))
    q = (x @ p["wq"]).reshape(b, 1, h, hd)
    k = (x @ p["wk"]).reshape(b, 1, hk, hd)
    v = (x @ p["wv"]).reshape(b, 1, hk, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"])
        k = layers.rms_norm(k, p["k_norm"])
    q = layers.apply_rope(q, pos, cfg.rope_theta)
    k = layers.apply_rope(k, pos, cfg.rope_theta)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, length, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, length, 0, 0))
    # grouped attention — the GQA repeat stays inside the einsum
    out = layers.decode_attention(q, cache_k, cache_v, length=length + 1)
    return out.reshape(b, 1, h * hd) @ p["wo"], cache_k, cache_v


def _decode_attn_mla(p, x, cache_c, cache_r, length, cfg: TransformerConfig):
    """Absorbed MLA decode: cache holds (c_kv, k_rope); W_UK/W_UV folded in."""
    b, _, d = x.shape
    h = cfg.n_heads
    pos = jnp.broadcast_to(length[None, None], (b, 1))
    qa = layers.rms_norm(x @ p["q_a"], p["q_a_norm"])
    q = (qa @ p["q_b"]).reshape(b, 1, h, cfg.qk_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = layers.apply_rope(q_rope, pos, cfg.rope_theta)

    kv = x @ p["kv_a"]
    c_new, r_new = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
    c_new = layers.rms_norm(c_new, p["kv_a_norm"])
    r_new = layers.apply_rope(r_new[:, :, None, :], pos, cfg.rope_theta)[:, :, 0]
    cache_c = jax.lax.dynamic_update_slice(cache_c, c_new, (0, length, 0))
    cache_r = jax.lax.dynamic_update_slice(cache_r, r_new, (0, length, 0))

    # absorb: q_abs[b,h,r] = q_nope[b,h,n] @ W_UK[r, h, n]
    w_uk = p["k_b"].reshape(cfg.kv_lora_rank, h, cfg.qk_nope_dim)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)
    s_c = jnp.einsum("bhr,bsr->bhs", q_abs.astype(jnp.float32),
                     cache_c.astype(jnp.float32))
    s_r = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                     cache_r.astype(jnp.float32))
    logits = (s_c + s_r) / math.sqrt(cfg.qk_dim)
    valid = jnp.arange(cache_c.shape[1])[None, None, :] < (length + 1)
    logits = jnp.where(valid, logits, -jnp.inf)
    m = logits.max(-1, keepdims=True)
    pdist = jnp.exp(logits - m)
    pdist = pdist / jnp.maximum(pdist.sum(-1, keepdims=True), 1e-30)
    ctx = jnp.einsum("bhs,bsr->bhr", pdist, cache_c.astype(jnp.float32))
    w_uv = p["v_b"].reshape(cfg.kv_lora_rank, h, cfg.v_head_dim)
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32)).astype(cfg.dtype)
    return out.reshape(b, 1, h * cfg.v_head_dim) @ p["wo"], cache_c, cache_r


def decode_step(params: dict, tokens: Array, cache: KVCache,
                cfg: TransformerConfig) -> tuple[Array, KVCache]:
    """One decode step. tokens: (B, 1). Returns (logits (B, 1, V), new cache)."""
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(cfg.dtype)
    length = cache.length

    def block_step(carry, inp):
        h = carry
        lp, ck, cv = inp
        h = constrain_batch(h)
        hn = layers.rms_norm(h, lp["ln1"])
        if cfg.mla:
            a, ck, cv = _decode_attn_mla(lp["attn"], hn, ck, cv, length, cfg)
        else:
            a, ck, cv = _decode_attn_gqa(lp["attn"], hn, ck, cv, length, cfg)
        h = h + a
        hn2 = layers.rms_norm(h, lp["ln2"])
        if "moe" in lp:
            h = h + moe_ffn(lp["moe"], hn2, cfg.moe_cfg()).y
        else:
            f = lp["ffn"]
            h = h + layers.swiglu(hn2, f["w_gate"], f["w_up"], f["w_down"])
        return h, (ck, cv)

    n_dense = cfg.first_dense if cfg.moe else cfg.n_layers
    x_h = x
    new_k, new_v = [], []
    if "dense_blocks" in params:
        nd = n_dense
        x_h, (k1, v1) = jax.lax.scan(
            block_step, x_h,
            (params["dense_blocks"], cache.k[:nd], cache.v[:nd]),
        )
        new_k.append(k1)
        new_v.append(v1)
    if "moe_blocks" in params:
        x_h, (k2, v2) = jax.lax.scan(
            block_step, x_h,
            (params["moe_blocks"], cache.k[n_dense:], cache.v[n_dense:]),
        )
        new_k.append(k2)
        new_v.append(v2)
    x_h = layers.rms_norm(x_h, params["final_norm"])
    logits = x_h @ params["embed"].T
    cache = KVCache(
        k=jnp.concatenate(new_k) if len(new_k) > 1 else new_k[0],
        v=jnp.concatenate(new_v) if len(new_v) > 1 else new_v[0],
        length=length + 1,
    )
    return logits, cache


def prefill(params: dict, tokens: Array, cfg: TransformerConfig,
            max_seq: int | None = None) -> tuple[Array, KVCache]:
    """Run the full prompt, return (last-position logits, populated cache).

    Uses the train-style blockwise forward to compute hidden states and
    re-derives the cache tensors layer by layer (single pass, no score matrix).
    """
    b, s = tokens.shape
    max_seq = max_seq or s
    x = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def block_step(h, lp):
        use_moe = "moe" in lp
        h = constrain_batch(h)
        hn = layers.rms_norm(h, lp["ln1"])
        p = lp["attn"]
        if cfg.mla:
            kv = hn @ p["kv_a"]
            c_kv, k_rope = jnp.split(kv, [cfg.kv_lora_rank], axis=-1)
            c_kv = layers.rms_norm(c_kv, p["kv_a_norm"])
            k_rope = layers.apply_rope(
                k_rope[:, :, None, :], positions, cfg.rope_theta
            )[:, :, 0]
            ck, cv = c_kv, k_rope
        else:
            k = (hn @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
            v = (hn @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
            if cfg.qk_norm:
                k = layers.rms_norm(k, p["k_norm"])
            k = layers.apply_rope(k, positions, cfg.rope_theta)
            ck, cv = k, v
        h2, _, _ = _block(lp, h, positions, cfg, use_moe)
        pad = max_seq - s
        if pad > 0:
            ck = jnp.pad(ck, [(0, 0), (0, pad), *[(0, 0)] * (ck.ndim - 2)])
            cv = jnp.pad(cv, [(0, 0), (0, pad), *[(0, 0)] * (cv.ndim - 2)])
        return h2, (ck, cv)

    n_dense = cfg.first_dense if cfg.moe else cfg.n_layers
    ks, vs = [], []
    if "dense_blocks" in params:
        x, (k1, v1) = jax.lax.scan(block_step, x, params["dense_blocks"])
        ks.append(k1)
        vs.append(v1)
    if "moe_blocks" in params:
        x, (k2, v2) = jax.lax.scan(block_step, x, params["moe_blocks"])
        ks.append(k2)
        vs.append(v2)
    x = layers.rms_norm(x, params["final_norm"])
    logits = x[:, -1:] @ params["embed"].T
    cache = KVCache(
        k=jnp.concatenate(ks) if len(ks) > 1 else ks[0],
        v=jnp.concatenate(vs) if len(vs) > 1 else vs[0],
        length=jnp.int32(s),
    )
    return logits, cache
