"""GAT (Veličković et al., arXiv:1710.10903) with segment-op message passing.

JAX has no CSR SpMM — message passing is implemented the idiomatic way:
SDDMM-style edge scores from gathered endpoints, **segment-softmax** over
incoming edges (segment_max → exp → segment_sum), then a scatter-reduce of
messages (`jax.ops.segment_sum`). This *is* part of the system, per spec.

Also includes the host-side fanout neighbor sampler (GraphSAGE-style) used by
the ``minibatch_lg`` shape: it samples a 2-hop block from a CSR graph and
emits fixed-shape padded arrays suitable for jit.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain_batch
from repro.models import layers

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    negative_slope: float = 0.2
    dtype: object = jnp.float32


def init_params(key, cfg: GATConfig) -> dict:
    params = {"layers": []}
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        d_out = cfg.n_classes if i == cfg.n_layers - 1 else cfg.d_hidden
        params["layers"].append(
            {
                "w": layers.dense_init(k1, d_in, cfg.n_heads * d_out, cfg.dtype),
                "a_src": (jax.random.normal(k2, (cfg.n_heads, d_out)) * 0.1).astype(cfg.dtype),
                "a_dst": (jax.random.normal(k3, (cfg.n_heads, d_out)) * 0.1).astype(cfg.dtype),
                "bias": jnp.zeros((cfg.n_heads * d_out,), cfg.dtype),
            }
        )
        d_in = cfg.n_heads * d_out if i < cfg.n_layers - 1 else d_out
    return params


def gat_layer(p: dict, x: Array, src: Array, dst: Array, n_nodes: int,
              *, n_heads: int, slope: float, average_heads: bool) -> Array:
    """One GAT layer. x: (N, d_in); src/dst: (E,) int32 (−1 = padding edge)."""
    h = constrain_batch(x @ p["w"]).reshape(x.shape[0], n_heads, -1)  # (N, H, dh)
    valid = src >= 0
    s = jnp.maximum(src, 0)
    t = jnp.maximum(dst, 0)
    e_src = (h * p["a_src"][None]).sum(-1)  # (N, H)
    e_dst = (h * p["a_dst"][None]).sum(-1)
    logits = constrain_batch(e_src[s] + e_dst[t])  # (E, H) — edge-sharded
    logits = jax.nn.leaky_relu(logits.astype(jnp.float32), slope)
    logits = jnp.where(valid[:, None], logits, -jnp.inf)
    # segment softmax over incoming edges of each destination
    seg_max = jax.ops.segment_max(logits, t, num_segments=n_nodes)  # (N, H)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.where(valid[:, None], jnp.exp(logits - seg_max[t]), 0.0)
    denom = jax.ops.segment_sum(ex, t, num_segments=n_nodes)
    coef = ex / jnp.maximum(denom[t], 1e-16)  # (E, H)
    msg = constrain_batch(h[s].astype(jnp.float32) * coef[..., None])  # (E, H, dh)
    out = constrain_batch(
        jax.ops.segment_sum(msg, t, num_segments=n_nodes))  # (N, H, dh)
    if average_heads:
        return out.mean(axis=1).astype(x.dtype)
    return out.reshape(n_nodes, -1).astype(x.dtype)


def forward(params: dict, x: Array, src: Array, dst: Array,
            cfg: GATConfig) -> Array:
    n = x.shape[0]
    h = x.astype(cfg.dtype)
    for i, p in enumerate(params["layers"]):
        last = i == cfg.n_layers - 1
        h = gat_layer(
            p, h, src, dst, n,
            n_heads=cfg.n_heads, slope=cfg.negative_slope, average_heads=last,
        )
        if not last:
            h = jax.nn.elu(h.astype(jnp.float32)).astype(cfg.dtype)
    return h  # (N, n_classes)


def loss_fn(params: dict, batch: dict, cfg: GATConfig):
    """batch: feats (N,F), src/dst (E,), labels (N,), mask (N,)."""
    logits = forward(params, batch["feats"], batch["src"], batch["dst"], cfg)
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, batch["labels"][:, None], axis=-1)[:, 0]
    per_node = lse - gold
    mask = batch["mask"].astype(jnp.float32)
    loss = (per_node * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    acc = (((lf.argmax(-1) == batch["labels"]) * mask).sum()
           / jnp.maximum(mask.sum(), 1.0))
    return loss, {"loss": loss, "acc": acc}


# --------------------------------------------------------------------------
# host-side neighbor sampler (minibatch_lg)
# --------------------------------------------------------------------------
class SampledBlock(NamedTuple):
    feats: np.ndarray  # (n_max, F) padded node features
    src: np.ndarray  # (e_max,) local edge endpoints, -1 padded
    dst: np.ndarray
    labels: np.ndarray  # (n_max,)
    mask: np.ndarray  # (n_max,) 1 on seed nodes
    n_nodes: int


class CSRGraph(NamedTuple):
    indptr: np.ndarray
    indices: np.ndarray
    feats: np.ndarray
    labels: np.ndarray


def random_csr_graph(n_nodes: int, avg_degree: int, d_feat: int,
                     n_classes: int, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    degs = rng.poisson(avg_degree, size=n_nodes).astype(np.int64)
    indptr = np.concatenate([[0], np.cumsum(degs)])
    indices = rng.integers(0, n_nodes, size=int(indptr[-1]))
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    return CSRGraph(indptr, indices, feats, labels)


def sample_block(g: CSRGraph, seeds: np.ndarray, fanouts: tuple[int, ...],
                 rng: np.random.Generator) -> SampledBlock:
    """GraphSAGE fanout sampling; returns a fixed-shape padded block."""
    n_max = len(seeds)
    f_prod = 1
    for f in fanouts:
        f_prod *= f
        n_max += len(seeds) * f_prod
    e_max = n_max  # one sampled edge per non-seed node (tree block) upper bound

    nodes = list(seeds)
    local = {int(v): i for i, v in enumerate(seeds)}
    src_l, dst_l = [], []
    frontier = list(seeds)
    for f in fanouts:
        nxt = []
        for v in frontier:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            if hi <= lo:
                continue
            nbrs = g.indices[rng.integers(lo, hi, size=min(f, hi - lo))]
            for u in nbrs:
                u = int(u)
                if u not in local:
                    local[u] = len(nodes)
                    nodes.append(u)
                src_l.append(local[u])
                dst_l.append(local[int(v)])
                nxt.append(u)
        frontier = nxt
    n = len(nodes)
    feats = np.zeros((n_max, g.feats.shape[1]), np.float32)
    feats[:n] = g.feats[nodes]
    labels = np.zeros((n_max,), np.int32)
    labels[:n] = g.labels[nodes]
    src = np.full((e_max,), -1, np.int32)
    dst = np.full((e_max,), -1, np.int32)
    src[: len(src_l)] = src_l
    dst[: len(dst_l)] = dst_l
    mask = np.zeros((n_max,), np.float32)
    mask[: len(seeds)] = 1.0
    return SampledBlock(feats, src, dst, labels, mask, n)
