"""Shared neural-net layers (pure JAX, dict-pytree params).

Conventions:
* params are nested dicts of jnp arrays; ``init_*`` functions build them from
  a PRNG key (usable under ``jax.eval_shape`` for allocation-free dry-runs);
* compute dtype is configurable (bf16 on TPU, f32 in CPU tests); normalization
  statistics, softmax and logits always accumulate in f32;
* attention supports MHA / GQA / MQA via ``n_kv_heads`` and optional qk-norm
  (Qwen3), with RoPE applied at call sites.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array
Params = dict


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype) -> Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# normalization
# --------------------------------------------------------------------------
def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float = 10000.0) -> Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, n_heads, head_dim); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (blockwise / flash-style in XLA; Pallas kernel swaps in on TPU)
# --------------------------------------------------------------------------
def repeat_kv(k: Array, n_rep: int) -> Array:
    """(B, S, Hkv, dh) -> (B, S, Hkv*n_rep, dh) for GQA/MQA."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def blockwise_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    block_kv: int = 512,
    scale: float | None = None,
    q_offset: int = 0,
) -> Array:
    """Flash-style grouped attention in pure XLA: scan over KV blocks with
    online softmax.

    q: (B, Sq, H, dh); k, v: (B, Skv, Hkv, dh|dv) with H = Hkv·rep — the
    GQA/MQA repeat is expressed inside the einsums and never materialized.
    Never materializes the (Sq, Skv) score matrix; peak extra memory is one
    (B, Hkv, rep, Sq, block_kv) block, rematerialized in the backward pass.
    ``q_offset`` positions queries at ``q_offset + arange(Sq)`` within the
    KV sequence (decode/prefill-append).
    """
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    rep = h // hkv
    dv = v.shape[-1]  # value head dim may differ (MLA)
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    nb = max(1, (skv + block_kv - 1) // block_kv)
    pad = nb * block_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nb, block_kv, hkv, dh)
    vb = v.reshape(b, nb, block_kv, hkv, dv)

    # inputs stay in model dtype (bf16) — the einsums accumulate in f32 via
    # preferred_element_type, so cotangents of q/k/v (and the collectives
    # that move them) stay bf16. Softmax statistics are f32 throughout.
    qf = (q * scale).reshape(b, sq, hkv, rep, dh)
    q_pos = q_offset + jnp.arange(sq)

    @jax.checkpoint  # recompute each block's scores in the backward pass —
    def step(carry, inp):  # never stash (Sq × Skv) worth of probabilities
        m, l, acc = carry  # (B,Hkv,rep,Sq), same, (B,Hkv,rep,Sq,dv) — f32
        kblk, vblk, blk_idx = inp
        kv_pos = blk_idx * block_kv + jnp.arange(block_kv)
        s_blk = jnp.einsum(
            "bqkrd,bckd->bkrqc", qf, kblk,
            preferred_element_type=jnp.float32,
        )  # (B,Hkv,rep,Sq,block) f32
        mask = kv_pos[None, :] <= q_pos[:, None] if causal else (
            kv_pos[None, :] >= 0
        ) & jnp.ones((sq, 1), bool)
        mask = mask & (kv_pos[None, :] < skv)  # mask the tail padding
        s_blk = jnp.where(mask[None, None, None], s_blk, -jnp.inf)
        m_blk = jnp.max(s_blk, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        # guard fully-masked rows (m_new = -inf)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s_blk - safe_m[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkrqc,bckd->bkrqd", p.astype(q.dtype), vblk,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, rep, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, rep, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, a0),
        (
            jnp.moveaxis(kb, 1, 0),
            jnp.moveaxis(vb, 1, 0),
            jnp.arange(nb),
        ),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,rep,Sq,dv)
    out = out.reshape(b, h, sq, dv)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, H, dv)


def decode_attention(
    q: Array, k_cache: Array, v_cache: Array, *, length: Array | int, scale=None
) -> Array:
    """Single-token grouped attention vs a (possibly sequence-sharded) KV cache.

    q: (B, 1, H, dh); caches: (B, S, Hkv, dh) with H = Hkv * rep (GQA/MQA —
    the KV repeat is expressed inside the einsum, never materialized).
    O(S) work, no score matrix bigger than (B, H, S). When the cache's S dim
    is sharded over a mesh axis, XLA lowers the softmax reductions to
    cross-shard collectives (distributed flash-decode: partial (m, l, acc) +
    psum merge).
    """
    b, _, h, dh = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    rep = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = (q[:, 0] * scale).reshape(b, hkv, rep, dh).astype(jnp.float32)
    logits = jnp.einsum("bkrd,bskd->bkrs", qg, k_cache.astype(jnp.float32))
    valid = jnp.arange(s)[None, None, None, :] < jnp.asarray(length).reshape(
        -1, 1, 1, 1
    )
    logits = jnp.where(valid, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum(
        "bkrs,bskd->bkrd", p / jnp.maximum(l, 1e-30), v_cache.astype(jnp.float32)
    )
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------
def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    g = x @ w_gate
    u = x @ w_up
    return (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u) @ w_down


def init_swiglu(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(x: Array, ws: list[Array], bs: list[Array], act=jax.nn.relu) -> Array:
    """Plain MLP tower (recsys heads)."""
    h = x
    for i, (w, b) in enumerate(zip(ws, bs)):
        h = h @ w + b
        if i < len(ws) - 1:
            h = act(h)
    return h
