"""granite-20b [arXiv:2405.04324; dense code model] — 52L d6144 48H (MQA,
kv=1) d_ff=24576 vocab=49152, llama-style blocks.

Role: mid-tier expensive tower D."""
import jax.numpy as jnp

from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import AdamWConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="granite-20b", n_layers=52, d_model=6144, n_heads=48,
        n_kv_heads=1, head_dim=128, d_ff=24576, vocab=49152,
        dtype=jnp.bfloat16, remat="full", embed_dim=1024, block_kv=1024,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="granite-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        head_dim=16, d_ff=256, vocab=512, embed_dim=32,
    )


SPEC = make_lm_arch("granite-20b", full, smoke, AdamWConfig())
