"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

from repro.configs import (
    bert4rec,
    bimetric_paper,
    bst,
    deepseek_coder_33b,
    deepseek_v3_671b,
    din,
    gat_cora,
    granite_20b,
    granite_moe_3b_a800m,
    qwen3_0_6b,
    xdeepfm,
)

# the ten assigned architectures (+ the paper's own expensive tower)
ARCHS = {
    "qwen3-0.6b": qwen3_0_6b.SPEC,
    "granite-20b": granite_20b.SPEC,
    "deepseek-coder-33b": deepseek_coder_33b.SPEC,
    "granite-moe-3b-a800m": granite_moe_3b_a800m.SPEC,
    "deepseek-v3-671b": deepseek_v3_671b.SPEC,
    "gat-cora": gat_cora.SPEC,
    "bst": bst.SPEC,
    "din": din.SPEC,
    "bert4rec": bert4rec.SPEC,
    "xdeepfm": xdeepfm.SPEC,
}

EXTRA_ARCHS = {
    "sfr-mistral-7b": bimetric_paper.SPEC,
}


def get_arch(name: str):
    if name in ARCHS:
        return ARCHS[name]
    if name in EXTRA_ARCHS:
        return EXTRA_ARCHS[name]
    raise KeyError(f"unknown arch {name!r}; choose from "
                   f"{sorted(ARCHS) + sorted(EXTRA_ARCHS)}")


def all_cells() -> list[tuple[str, str]]:
    """The 40 assigned (arch × shape) dry-run cells."""
    return [(a, s) for a, spec in ARCHS.items() for s in spec.shapes]
