"""The paper's own experimental configuration (§4.1).

Two embedding towers at a 3-orders-of-magnitude size gap (Table 1) plus the
DiskANN index parameters used in the paper ("standard ANN-benchmark choices"):
alpha=1.2, l_build=125, max_outdegree=64. The expensive tower also registers
as an extra LM arch ("sfr-mistral-7b") so its serving path lowers on the
production mesh like any assigned architecture.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.lm_common import make_lm_arch
from repro.core.vamana import VamanaConfig
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import AdamWConfig


def expensive_tower() -> TransformerConfig:
    """SFR-Embedding-Mistral-like 7B encoder (D)."""
    return TransformerConfig(
        name="sfr-mistral-7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32768,
        dtype=jnp.bfloat16, remat="full", embed_dim=4096, rope_theta=1e6,
    )


def cheap_tower() -> TransformerConfig:
    """bge-micro-v2-like 17M encoder (d): 3 layers, 384-dim embeddings."""
    return TransformerConfig(
        name="bge-micro-like", n_layers=3, d_model=384, n_heads=6,
        n_kv_heads=6, head_dim=64, d_ff=1536, vocab=32768,
        dtype=jnp.float32, embed_dim=384,
    )


def cheap_tower_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="bge-micro-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab=512, embed_dim=32,
    )


# Paper §4.1 index parameters (DiskANN / ANN-benchmarks standard).
PAPER_DISKANN = VamanaConfig(
    max_degree=64, l_build=125, alpha=1.2, pool_size=256,
    rev_candidates=64, metric="l2",
)


@dataclasses.dataclass(frozen=True)
class BiMetricSystemConfig:
    """End-to-end system: towers + index + query policy (paper defaults)."""

    index: VamanaConfig = PAPER_DISKANN
    k: int = 10  # report top-10 (paper metric: NDCG@10 / Recall@10)
    seed_frac: float = 0.5  # stage-2 seeds = Q/2 (Figure 3 default)
    quota: int = 1000  # expensive-call budget Q (swept in benchmarks)


SPEC = make_lm_arch("sfr-mistral-7b", expensive_tower, cheap_tower_smoke,
                    AdamWConfig())
