"""gat-cora [arXiv:1710.10903] — 2L, d_hidden=8, 8 heads, attn aggregator.

Four graph regimes (padded to 512-divisible sizes for even sharding on both
production meshes; padding edges are -1 and padded nodes are masked):

  full_graph_sm — Cora: 2,708 nodes / 10,556 edges / 1,433 feats (pad 3072/10752)
  minibatch_lg  — Reddit-scale sampled block: 1,024 seeds × fanout 15·10
                  -> 169,984-node block (exactly 512-divisible), 602 feats
  ogb_products  — 2,449,029 nodes / 61,859,140 edges / 100 feats
                  (pad 2,449,408 / 61,859,840)
  molecule      — 128 disjoint graphs × 30 nodes / 64 edges, graph-level
                  classification via segment-mean readout (pad N to 4096)

Weights are tiny -> replicated; node/edge data sharded over every mesh axis.
The paper's technique does not live *inside* the GNN (see DESIGN.md
§Arch-applicability): GAT is an embedding producer whose outputs feed the
bi-metric index (examples/gnn_corpus_search.py)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import common
from repro.distributed import sharding as shr
from repro.models import gnn
from repro.train.optimizer import AdamWConfig

GNN_SHAPES = {
    "full_graph_sm": dict(n_nodes=3072, n_edges=10752, d_feat=1433,
                          n_classes=7, task="node",
                          true_nodes=2708, true_edges=10556),
    "minibatch_lg": dict(n_nodes=169984, n_edges=169984, d_feat=602,
                         n_classes=41, task="node",
                         true_nodes=232965, true_edges=114615892),
    "ogb_products": dict(n_nodes=2449408, n_edges=61859840, d_feat=100,
                         n_classes=47, task="node",
                         true_nodes=2449029, true_edges=61859140),
    "molecule": dict(n_nodes=4096, n_edges=8192, d_feat=16, n_classes=2,
                     task="graph", n_graphs=128,
                     true_nodes=3840, true_edges=8192),
}

SMOKE_SHAPES = {
    k: dict(v, n_nodes=min(v["n_nodes"], 256), n_edges=min(v["n_edges"], 512),
            d_feat=min(v["d_feat"], 32),
            n_graphs=min(v.get("n_graphs", 0), 8) or v.get("n_graphs"))
    for k, v in GNN_SHAPES.items()
}


def graph_loss(params, batch, cfg: gnn.GATConfig, *, task: str,
               n_graphs: int = 0):
    if task == "node":
        return gnn.loss_fn(params, batch, cfg)
    # graph classification: per-node logits -> segment-mean readout per graph
    logits = gnn.forward(params, batch["feats"], batch["src"], batch["dst"], cfg)
    g = jax.ops.segment_sum(logits.astype(jnp.float32), batch["graph_ids"],
                            num_segments=n_graphs)
    cnt = jax.ops.segment_sum(jnp.ones(logits.shape[0], jnp.float32),
                              batch["graph_ids"], num_segments=n_graphs)
    g = g / jnp.maximum(cnt, 1.0)[:, None]
    lse = jax.nn.logsumexp(g, axis=-1)
    gold = jnp.take_along_axis(g, batch["graph_labels"][:, None], axis=-1)[:, 0]
    loss = (lse - gold).mean()
    return loss, {"loss": loss}


def build_gnn_cell(cfg_dummy, shape_name: str, *, smoke: bool = False,
                   opt_cfg: AdamWConfig | None = None) -> common.CellSpec:
    shapes = SMOKE_SHAPES if smoke else GNN_SHAPES
    info = shapes[shape_name]
    opt_cfg = opt_cfg or AdamWConfig(weight_decay=0.0)
    cfg = gnn.GATConfig(
        name="gat", n_layers=2, d_hidden=8, n_heads=8,
        d_in=info["d_feat"], n_classes=info["n_classes"],
    )
    task = info["task"]
    n_graphs = info.get("n_graphs") or 0
    loss = partial(graph_loss, cfg=cfg, task=task, n_graphs=n_graphs)
    step = common.make_train_step(loss, opt_cfg)

    def abstract_args(mesh: Mesh):
        p_abs = jax.eval_shape(partial(gnn.init_params, cfg=cfg),
                               jax.random.PRNGKey(0))
        p_specs = shr.replicated_specs(p_abs)
        o_abs = common.abstract_opt_state(opt_cfg, p_abs)
        o_specs = shr.opt_state_specs(p_specs, o_abs, p_abs)
        ax = shr.all_axes(mesh)
        n, e, f = info["n_nodes"], info["n_edges"], info["d_feat"]
        nspec = P(ax if n % _axprod(mesh, ax) == 0 else None, None)
        espec = P(ax if e % _axprod(mesh, ax) == 0 else None)
        b = {
            "feats": common.sds((n, f), jnp.float32, mesh, nspec),
            "src": common.sds((e,), jnp.int32, mesh, espec),
            "dst": common.sds((e,), jnp.int32, mesh, espec),
            "labels": common.sds((n,), jnp.int32, mesh, P(nspec[0])),
            "mask": common.sds((n,), jnp.float32, mesh, P(nspec[0])),
        }
        if task == "graph":
            b["graph_ids"] = common.sds((n,), jnp.int32, mesh, P(nspec[0]))
            b["graph_labels"] = common.sds((n_graphs,), jnp.int32, mesh, P())
            del b["labels"], b["mask"]
        return (
            common.with_shardings(p_abs, p_specs, mesh),
            common.with_shardings(o_abs, o_specs, mesh),
            b,
        )

    return common.CellSpec(
        name=f"gat-cora/{shape_name}", entry="train", fn=step,
        abstract_args=abstract_args, donate=(0, 1), tokens=info["n_nodes"],
        act_axes="all",
        out_shardings=lambda args: (
            common.arg_shardings(args[0]), common.arg_shardings(args[1]),
            None),
    )


def _axprod(mesh, axes):
    t = 1
    for a in axes:
        t *= mesh.shape[a]
    return t


SPEC = common.ArchSpec(
    name="gat-cora",
    family="gnn",
    make_config=lambda smoke=False: gnn.GATConfig(),
    shapes=GNN_SHAPES,
    build_cell=lambda cfg, shape: build_gnn_cell(cfg, shape, smoke=False),
    init_params=lambda key, cfg: gnn.init_params(key, cfg),
)
