"""qwen3-0.6b [hf:Qwen/Qwen3-8B family; dense] — 28L d1024 16H (GQA kv=8)
d_ff=3072 vocab=151936, qk-norm, explicit head_dim=128 (Qwen3 style).

Role in the bi-metric system: the cheap proxy tower d (small, local)."""
import jax.numpy as jnp

from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import AdamWConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-0.6b", n_layers=28, d_model=1024, n_heads=16,
        n_kv_heads=8, head_dim=128, d_ff=3072, vocab=151936,
        qk_norm=True, rope_theta=1e6, dtype=jnp.bfloat16, remat="full",
        embed_dim=384,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=128, vocab=512, qk_norm=True, embed_dim=32,
    )


SPEC = make_lm_arch("qwen3-0.6b", full, smoke, AdamWConfig())
