"""din [arXiv:1706.06978] — Deep Interest Network. embed 18, seq 100,
attention MLP 80-40, head MLP 200-80, item vocab 2^20.

Role: expensive pair scorer D (target attention over the user history)."""
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common
from repro.configs.recsys_common import cand_ids_abs, make_recsys_arch
from repro.models import recsys as R


def full() -> R.DINConfig:
    return R.DINConfig(name="din", vocab=1_048_576, embed_dim=18, seq_len=100,
                       attn_mlp=(80, 40), mlp_dims=(200, 80))


def smoke() -> R.DINConfig:
    return R.DINConfig(name="din-smoke", vocab=512, embed_dim=8, seq_len=16,
                       attn_mlp=(16, 8), mlp_dims=(32, 16))


def _batch_abs(cfg, batch, mesh, bspec):
    return {
        "hist": common.sds((batch, cfg.seq_len), jnp.int32, mesh,
                           P(bspec[0], None)),
        "target": common.sds((batch,), jnp.int32, mesh, bspec),
        "label": common.sds((batch,), jnp.float32, mesh, bspec),
    }


SPEC = make_recsys_arch(
    "din",
    full_cfg_fn=full, smoke_cfg_fn=smoke,
    init_fn=lambda key, cfg: R.din_init(key, cfg),
    loss_fn=lambda params, batch, cfg: R.din_loss(params, batch, cfg),
    serve_fn=lambda params, batch, cfg: R.din_forward(
        params, batch["hist"], batch["target"], cfg),
    retrieval_fn=lambda params, user, cand, cfg: R.din_score_candidates(
        params, user["hist"], cand, cfg),
    batch_abs_fn=_batch_abs,
    user_abs_fn=lambda cfg, mesh: {
        "hist": common.sds((1, cfg.seq_len), jnp.int32, mesh, P(None, None))
    },
    cand_abs_fn=cand_ids_abs,
)
