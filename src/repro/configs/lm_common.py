"""LM-family cells: train_4k / prefill_32k / decode_32k / long_500k.

Shape semantics (per assignment):
  train_4k    — train_step, seq 4096, global batch 256
  prefill_32k — serve_prefill, seq 32768, global batch 32
  decode_32k  — serve_step: ONE new token, KV cache of 32768, batch 128
  long_500k   — serve_step: ONE token, 524288-entry KV cache, batch 1.
                All five assigned LM archs are full-attention; the decode
                entry is O(cache), and the cache is sequence-sharded over
                ("data","model") with a distributed softmax merge — the
                sub-quadratic path (see DESIGN.md §Arch-applicability).

Sharding: params FSDP×TP (ZeRO-3-equivalent), activations batch-sharded over
(pod, data); decode caches sharded (batch → dp, seq → model), except
long_500k where batch=1 → seq over (data, model).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import common
from repro.distributed import sharding as shr
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig

LM_SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, entry="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, entry="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, entry="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, entry="decode"),
}

SMOKE_SHAPES = {
    "train_4k": dict(seq_len=64, global_batch=4, entry="train"),
    "prefill_32k": dict(seq_len=128, global_batch=2, entry="prefill"),
    "decode_32k": dict(seq_len=128, global_batch=4, entry="decode"),
    "long_500k": dict(seq_len=256, global_batch=1, entry="decode"),
}


def _dp(mesh: Mesh):
    return shr.batch_axes(mesh)


def _params_shardings(cfg, mesh):
    p_abs = common.abstract_params(T.init_params, cfg)
    fsdp = _dp(mesh) if shr.ZERO_STAGE >= 3 else ()
    specs = shr.lm_param_specs(p_abs, mesh, fsdp=fsdp)
    return p_abs, specs


def _opt_base_shardings(cfg, mesh, p_abs):
    """Optimizer states are always fully sharded (ZeRO-1 keeps master/m/v
    on the fsdp axes even when the working params are TP-only)."""
    return shr.lm_param_specs(p_abs, mesh, fsdp=_dp(mesh))


def _batch_spec(mesh, batch: int):
    dp = _dp(mesh)
    total = 1
    for a in dp:
        total *= mesh.shape[a]
    return P(dp if batch % total == 0 else None, None)


def _cache_specs(cfg: T.TransformerConfig, mesh: Mesh, batch: int):
    """KVCache sharding: batch -> dp, seq -> model; if batch==1, seq ->
    (data, model) so a 512k cache fits (the SP decode path)."""
    dp = _dp(mesh)
    total_dp = 1
    for a in dp:
        total_dp *= mesh.shape[a]
    if batch == 1 or batch % total_dp:
        bspec, sspec = None, ("data", "model")
    else:
        bspec, sspec = dp, "model"
    if cfg.mla:
        kv = P(None, bspec, sspec, None)
    else:
        kv = P(None, bspec, sspec, None, None)
    return T.KVCache(k=kv, v=kv, length=P())


def build_lm_cell(cfg: T.TransformerConfig, shape_name: str,
                  opt_cfg: AdamWConfig, shapes=None,
                  arch_name: str = "lm") -> common.CellSpec:
    info = (shapes or LM_SHAPES)[shape_name]
    seq, batch, entry = info["seq_len"], info["global_batch"], info["entry"]

    if entry == "train":
        loss = partial(_lm_loss, cfg=cfg)
        holder: dict = {}
        step = common.make_train_step(loss, opt_cfg, grad_specs_holder=holder)

        def abstract_args(mesh):
            p_abs, p_specs = _params_shardings(cfg, mesh)
            o_abs = common.abstract_opt_state(opt_cfg, p_abs)
            opt_base = _opt_base_shardings(cfg, mesh, p_abs)
            o_specs = shr.opt_state_specs(opt_base, o_abs, p_abs)
            holder["mesh"] = mesh
            holder["specs"] = opt_base  # grads live where the opt shards live
            bspec = _batch_spec(mesh, batch)
            b_abs = {
                "tokens": common.sds((batch, seq), jnp.int32, mesh, bspec),
                "labels": common.sds((batch, seq), jnp.int32, mesh, bspec),
            }
            return (
                common.with_shardings(p_abs, p_specs, mesh),
                common.with_shardings(o_abs, o_specs, mesh),
                b_abs,
            )

        return common.CellSpec(
            name=f"{arch_name}/{shape_name}", entry="train", fn=step,
            abstract_args=abstract_args, donate=(0, 1), tokens=batch * seq,
            out_shardings=lambda args: (
                common.arg_shardings(args[0]), common.arg_shardings(args[1]),
                None),
        )

    if entry == "prefill":
        def prefill_fn(params, tokens):
            return T.prefill(params, tokens, cfg)

        def abstract_args(mesh):
            p_abs, p_specs = _params_shardings(cfg, mesh)
            bspec = _batch_spec(mesh, batch)
            toks = common.sds((batch, seq), jnp.int32, mesh, bspec)
            return (common.with_shardings(p_abs, p_specs, mesh), toks)

        return common.CellSpec(
            name=f"{arch_name}/{shape_name}", entry="prefill", fn=prefill_fn,
            abstract_args=abstract_args, tokens=batch * seq,
        )

    # decode: one token against a `seq`-deep cache
    def decode_fn(params, tokens, cache):
        logits, cache = T.decode_step(params, tokens, cache, cfg)
        return logits, cache

    def abstract_args(mesh):
        p_abs, p_specs = _params_shardings(cfg, mesh)
        bspec = _batch_spec(mesh, batch)
        toks = common.sds((batch, 1), jnp.int32, mesh, bspec)
        cache_abs = jax.eval_shape(
            partial(T.init_cache, cfg, batch, seq, length=seq - 1)
        )
        c_specs = _cache_specs(cfg, mesh, batch)
        cache = T.KVCache(
            k=common.with_shardings(cache_abs.k, c_specs.k, mesh),
            v=common.with_shardings(cache_abs.v, c_specs.v, mesh),
            length=common.sds((), jnp.int32, mesh, P()),
        )
        return (common.with_shardings(p_abs, p_specs, mesh), toks, cache)

    return common.CellSpec(
        name=f"{arch_name}/{shape_name}", entry="decode", fn=decode_fn,
        abstract_args=abstract_args, donate=(2,), tokens=batch,
        out_shardings=lambda args: (None, common.arg_shardings(args[2])),
    )


def _lm_loss(params, batch, cfg):
    return T.loss_fn(params, batch, cfg)


def make_lm_arch(name: str, full_cfg_fn, smoke_cfg_fn,
                 opt_cfg: AdamWConfig | None = None) -> common.ArchSpec:
    opt_cfg = opt_cfg or AdamWConfig()

    def build(cfg, shape):
        shapes = LM_SHAPES if cfg.vocab > 4096 else SMOKE_SHAPES
        return build_lm_cell(cfg, shape, opt_cfg, shapes=shapes, arch_name=name)

    return common.ArchSpec(
        name=name,
        family="lm",
        make_config=lambda smoke=False: smoke_cfg_fn() if smoke else full_cfg_fn(),
        shapes=LM_SHAPES,
        build_cell=build,
        init_params=lambda key, cfg: T.init_params(key, cfg),
    )
