"""Shared machinery for architecture configs: cells, step builders, specs.

An *arch* module exposes ``SPEC: ArchSpec``. Each of its shapes defines one
dry-run **cell**: a jittable step function plus allocation-free abstract
arguments (ShapeDtypeStructs) and their NamedShardings for a given mesh.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.train.optimizer import AdamWConfig, make_adamw

Pytree = Any


def sds(shape, dtype, mesh: Mesh | None = None, spec: P | None = None):
    if mesh is not None and spec is not None:
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.ShapeDtypeStruct(shape, dtype)


def with_shardings(abstract: Pytree, specs: Pytree, mesh: Mesh) -> Pytree:
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""

    def one(a, s):
        return jax.ShapeDtypeStruct(a.shape, a.dtype,
                                    sharding=NamedSharding(mesh, s))

    return jax.tree.map(one, abstract, specs,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@dataclasses.dataclass
class CellSpec:
    """One (arch × shape) dry-run target."""

    name: str  # f"{arch}/{shape}"
    entry: str  # train | prefill | decode | serve
    fn: Callable  # jittable step
    # mesh -> (args pytree of ShapeDtypeStructs WITH shardings, donate_argnums)
    abstract_args: Callable[[Mesh], tuple]
    donate: tuple[int, ...] = ()
    # batch-like dims for MODEL_FLOPS accounting
    tokens: int = 0  # tokens processed per step (LM) / items scored (recsys)
    # mesh axes for activation batch constraints ("dp" = pod+data,
    # "all" = pod+data+model — GNN node/edge data)
    act_axes: str = "dp"
    # output shardings: maps abstract args -> out_shardings pytree (None
    # entries = let XLA choose). Critical for train cells: without it XLA
    # may materialize the updated optimizer state replicated (f32 grad
    # all-reduce instead of reduce-scatter).
    out_shardings: Any = None  # Callable[args_tuple] -> pytree | None


def arg_shardings(tree):
    return jax.tree.map(
        lambda s: s.sharding, tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


@dataclasses.dataclass
class ArchSpec:
    name: str
    family: str  # lm | gnn | recsys
    make_config: Callable[[bool], Any]  # smoke -> config
    shapes: dict[str, dict]  # shape name -> shape kwargs
    build_cell: Callable[[Any, str], CellSpec]  # (config, shape) -> cell
    init_params: Callable[[jax.Array, Any], Pytree]
    n_params: Callable[[Any], int] | None = None
    n_active_params: Callable[[Any], int] | None = None

    def cells(self, smoke: bool = False):
        cfg = self.make_config(smoke)
        return {s: self.build_cell(cfg, s) for s in self.shapes}

    def cell(self, shape: str, smoke: bool = False) -> CellSpec:
        cfg = self.make_config(smoke)
        return self.build_cell(cfg, shape)


def count_params(abstract: Pytree) -> int:
    return sum(
        int(jnp.prod(jnp.array(l.shape))) for l in jax.tree.leaves(abstract)
    )


def abstract_params(init_fn: Callable, cfg) -> Pytree:
    return jax.eval_shape(partial(init_fn, cfg=cfg), jax.random.PRNGKey(0))


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    grad_specs_holder: dict | None = None):
    """Generic fused forward+backward+AdamW step: (params, opt, batch) ->
    (params, opt, metrics).

    ``grad_specs_holder`` (populated by the cell's abstract_args with
    {"mesh": Mesh, "specs": param-spec pytree}) pins each gradient, cast to
    the param dtype, to the *optimizer-shard* layout — which turns XLA's
    default f32 gradient all-reduce into a bf16 reduce-scatter (ZeRO grad
    sharding). See EXPERIMENTS.md §Perf.
    """
    _, opt_update = make_adamw(opt_cfg)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        if grad_specs_holder and grad_specs_holder.get("mesh") is not None:
            from jax.sharding import NamedSharding

            mesh = grad_specs_holder["mesh"]
            specs = grad_specs_holder["specs"]
            grads = jax.tree.map(
                lambda g, p, s: jax.lax.with_sharding_constraint(
                    g.astype(p.dtype), NamedSharding(mesh, s)),
                grads, params, specs)
        params, opt_state, stats = opt_update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **stats}

    return train_step


def abstract_opt_state(opt_cfg: AdamWConfig, params_abs: Pytree) -> Pytree:
    opt_init, _ = make_adamw(opt_cfg)
    return jax.eval_shape(opt_init, params_abs)
