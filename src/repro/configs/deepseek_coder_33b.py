"""deepseek-coder-33b [arXiv:2401.14196; dense] — 62L d7168 56H (GQA kv=8)
d_ff=19200 vocab=32256, llama-arch.

Role: expensive tower D."""
import jax.numpy as jnp

from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import AdamWConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-coder-33b", n_layers=62, d_model=7168, n_heads=56,
        n_kv_heads=8, head_dim=128, d_ff=19200, vocab=32256,
        dtype=jnp.bfloat16, remat="full", embed_dim=2048, block_kv=1024,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="dsc-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        head_dim=8, d_ff=192, vocab=512, embed_dim=32,
    )


SPEC = make_lm_arch("deepseek-coder-33b", full, smoke, AdamWConfig())
