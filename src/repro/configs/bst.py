"""bst [arXiv:1905.06874] — Behavior Sequence Transformer (Alibaba).
embed 32, seq 20, 1 block × 8 heads, MLP 1024-512-256, item vocab 2^20.

Role: expensive pair scorer D (target is attended jointly with the history —
non-factorizable, so retrieval under a budget is the paper's exact regime)."""
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common
from repro.configs.recsys_common import cand_ids_abs, make_recsys_arch
from repro.models import recsys as R


def full() -> R.BSTConfig:
    return R.BSTConfig(name="bst", vocab=1_048_576, embed_dim=32, seq_len=20,
                       n_blocks=1, n_heads=8, mlp_dims=(1024, 512, 256))


def smoke() -> R.BSTConfig:
    return R.BSTConfig(name="bst-smoke", vocab=512, embed_dim=16, seq_len=8,
                       n_blocks=1, n_heads=4, mlp_dims=(64, 32))


def _batch_abs(cfg, batch, mesh, bspec):
    return {
        "hist": common.sds((batch, cfg.seq_len), jnp.int32, mesh,
                           P(bspec[0], None)),
        "target": common.sds((batch,), jnp.int32, mesh, bspec),
        "label": common.sds((batch,), jnp.float32, mesh, bspec),
    }


def _loss(params, batch, cfg):
    return R.bst_loss(params, batch, cfg)


def _serve(params, batch, cfg):
    return R.bst_forward(params, batch["hist"], batch["target"], cfg)


def _retrieval(params, user, cand, cfg):
    return R.bst_score_candidates(params, user["hist"], cand, cfg)


SPEC = make_recsys_arch(
    "bst",
    full_cfg_fn=full, smoke_cfg_fn=smoke,
    init_fn=lambda key, cfg: R.bst_init(key, cfg),
    loss_fn=_loss, serve_fn=_serve, retrieval_fn=_retrieval,
    batch_abs_fn=_batch_abs,
    user_abs_fn=lambda cfg, mesh: {
        "hist": common.sds((1, cfg.seq_len), jnp.int32, mesh, P(None, None))
    },
    cand_abs_fn=cand_ids_abs,
)
