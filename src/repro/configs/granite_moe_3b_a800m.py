"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0 family; MoE] — 32L d1536
24H (GQA kv=8) per-expert d_ff=512, vocab=49155, 40 experts top-8.

Note: the assignment text lists both "MoE 40e top-8" (inline spec) and "32
experts" (citation note); we follow the inline spec (40 experts, top-8).
40 % 16 != 0, so the sharding engine uses intra-expert TP instead of EP for
this arch (see distributed/sharding.py)."""
import jax.numpy as jnp

from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import AdamWConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
        n_kv_heads=8, head_dim=64, d_ff=512, vocab=49155,
        moe=True, n_experts=40, top_k=8, moe_d_ff=512, n_shared=0,
        first_dense=0, dtype=jnp.bfloat16, remat="full", embed_dim=384,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=512,
        moe=True, n_experts=8, top_k=2, moe_d_ff=64, n_shared=0,
        first_dense=0, embed_dim=32, capacity_factor=4.0,
    )


SPEC = make_lm_arch("granite-moe-3b-a800m", full, smoke, AdamWConfig())
