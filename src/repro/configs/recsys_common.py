"""RecSys-family cells: train_batch / serve_p99 / serve_bulk / retrieval_cand.

  train_batch    — train_step, batch 65,536
  serve_p99      — pointwise scoring, batch 512 (online)
  serve_bulk     — pointwise scoring, batch 262,144 (offline)
  retrieval_cand — ONE user vs 1,000,000 candidates: batched broadcast
                   scoring (no loops); candidates sharded over "model"
                   (1e6 / 16 = 62,500 per shard, exact).

Embedding tables row-sharded over "model" (they are the memory); MLP heads
small enough to FSDP or replicate; activations batch-sharded over (pod,data).

This family is where the bi-metric framework bites hardest: BST/DIN/xDeepFM
are non-factorizable pair scorers (the expensive D), and ``retrieval_cand``
under a D-call budget is exactly the paper's query model — see
repro/serve/engine.py for the budgeted two-stage integration.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import common
from repro.distributed import sharding as shr
from repro.train.optimizer import AdamWConfig

RS_SHAPES = {
    "train_batch": dict(batch=65536, entry="train"),
    "serve_p99": dict(batch=512, entry="serve"),
    "serve_bulk": dict(batch=262144, entry="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=1_000_000, entry="retrieval"),
}

SMOKE_SHAPES = {
    "train_batch": dict(batch=32, entry="train"),
    "serve_p99": dict(batch=16, entry="serve"),
    "serve_bulk": dict(batch=64, entry="serve"),
    "retrieval_cand": dict(batch=1, n_candidates=256, entry="retrieval"),
}


def _dp_spec(mesh: Mesh, batch: int):
    dp = shr.batch_axes(mesh)
    total = 1
    for a in dp:
        total *= mesh.shape[a]
    return P(dp if batch % total == 0 else None)


def make_recsys_arch(
    name: str,
    *,
    full_cfg_fn,
    smoke_cfg_fn,
    init_fn,                      # (key, cfg) -> params
    loss_fn,                      # (params, batch, cfg) -> (loss, metrics)
    serve_fn,                     # (params, batch, cfg) -> scores
    retrieval_fn,                 # (params, user_batch, cand, cfg) -> scores
    batch_abs_fn,                 # (cfg, batch, mesh, bspec) -> batch SDS dict
    user_abs_fn,                  # (cfg, mesh) -> user-side SDS dict (B=1)
    cand_abs_fn,                  # (cfg, n_cand, mesh) -> candidate SDS
    opt_cfg: AdamWConfig | None = None,
) -> common.ArchSpec:
    opt_cfg = opt_cfg or AdamWConfig(weight_decay=0.0)

    def build(cfg, shape_name, smoke=False):
        shapes = SMOKE_SHAPES if "smoke" in cfg.name else RS_SHAPES
        info = shapes[shape_name]
        entry = info["entry"]

        def params_shardings(mesh):
            p_abs = jax.eval_shape(partial(init_fn, cfg=cfg),
                                   jax.random.PRNGKey(0))
            specs = shr.lm_param_specs(p_abs, mesh, fsdp=shr.batch_axes(mesh))
            return p_abs, specs

        if entry == "train":
            batch = info["batch"]
            step = common.make_train_step(partial(loss_fn, cfg=cfg), opt_cfg)

            def abstract_args(mesh):
                p_abs, p_specs = params_shardings(mesh)
                o_abs = common.abstract_opt_state(opt_cfg, p_abs)
                o_specs = shr.opt_state_specs(p_specs, o_abs, p_abs)
                b = batch_abs_fn(cfg, batch, mesh, _dp_spec(mesh, batch))
                return (
                    common.with_shardings(p_abs, p_specs, mesh),
                    common.with_shardings(o_abs, o_specs, mesh),
                    b,
                )

            return common.CellSpec(
                name=f"{name}/{shape_name}", entry="train", fn=step,
                abstract_args=abstract_args, donate=(0, 1), tokens=batch,
                out_shardings=lambda args: (
                    common.arg_shardings(args[0]),
                    common.arg_shardings(args[1]), None),
            )

        if entry == "serve":
            batch = info["batch"]

            def serve_step(params, batch_):
                return serve_fn(params, batch_, cfg)

            def abstract_args(mesh):
                p_abs, p_specs = params_shardings(mesh)
                b = batch_abs_fn(cfg, batch, mesh, _dp_spec(mesh, batch))
                b.pop("label", None)
                b.pop("mask_labels", None)
                return (common.with_shardings(p_abs, p_specs, mesh), b)

            return common.CellSpec(
                name=f"{name}/{shape_name}", entry="serve", fn=serve_step,
                abstract_args=abstract_args, tokens=batch,
            )

        # retrieval
        n_cand = info["n_candidates"]

        def retrieval_step(params, user, cand):
            # pad the candidate sweep to a 512-divisible length so it shards
            # over every mesh axis (1e6 alone only divides "model"=16 — that
            # left 16/32× of the mesh idle; see EXPERIMENTS.md §Perf).
            n = cand.shape[0]
            pad = (-n) % 512
            if pad:
                cand = jnp.concatenate(
                    [cand, jnp.zeros((pad, *cand.shape[1:]), cand.dtype)])
            cand = shr.constrain_axis(cand, 0, axes=("data", "model"))
            scores = retrieval_fn(params, user, cand, cfg)
            return scores[:n]

        def abstract_args(mesh):
            p_abs, p_specs = params_shardings(mesh)
            user = user_abs_fn(cfg, mesh)
            cand = cand_abs_fn(cfg, n_cand, mesh)
            return (common.with_shardings(p_abs, p_specs, mesh), user, cand)

        return common.CellSpec(
            name=f"{name}/{shape_name}", entry="retrieval", fn=retrieval_step,
            abstract_args=abstract_args, tokens=n_cand, act_axes="all",
        )

    return common.ArchSpec(
        name=name,
        family="recsys",
        make_config=lambda smoke=False: smoke_cfg_fn() if smoke else full_cfg_fn(),
        shapes=RS_SHAPES,
        build_cell=build,
        init_params=init_fn,
    )


def cand_ids_abs(cfg, n_cand: int, mesh: Mesh):
    """1-D candidate id vector sharded over 'model' (divides 1e6 exactly)."""
    spec = P("model" if n_cand % mesh.shape["model"] == 0 else None)
    return common.sds((n_cand,), jnp.int32, mesh, spec)
