"""deepseek-v3-671b [arXiv:2412.19437; MoE+MLA] — 61L d7168 128H MLA,
1 shared + 256 routed experts top-8 (per-expert d_ff=2048), first 3 layers
dense (d_ff=18432), MTP head, vocab=129280.

Role: flagship expensive tower D (the "API-tier" model of the paper's
deployment story). Optimizer uses int8-quantized Adam moments — the 12→6
byte/param optimizer-state cut is what fits 671B on 512 chips × 16 GB
(see EXPERIMENTS.md §Dry-run)."""
import jax.numpy as jnp

from repro.configs.lm_common import make_lm_arch
from repro.models.transformer import TransformerConfig
from repro.train.optimizer import AdamWConfig


def full() -> TransformerConfig:
    return TransformerConfig(
        name="deepseek-v3-671b", n_layers=61, d_model=7168, n_heads=128,
        n_kv_heads=128, head_dim=128, d_ff=18432, vocab=129280,
        moe=True, n_experts=256, top_k=8, moe_d_ff=2048, n_shared=1,
        first_dense=3,
        mla=True, q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
        qk_rope_dim=64, v_head_dim=128, mtp=True,
        dtype=jnp.bfloat16, remat="full", embed_dim=4096, block_kv=1024,
    )


def smoke() -> TransformerConfig:
    return TransformerConfig(
        name="dsv3-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab=512,
        moe=True, n_experts=8, top_k=2, moe_d_ff=32, n_shared=1,
        first_dense=1,
        mla=True, q_lora_rank=32, kv_lora_rank=24, qk_nope_dim=16,
        qk_rope_dim=8, v_head_dim=16, mtp=True, embed_dim=32,
        capacity_factor=4.0,
    )


OPT = AdamWConfig(quantized_state=True)
SPEC = make_lm_arch("deepseek-v3-671b", full, smoke, OPT)
