"""xdeepfm [arXiv:1803.05170] — 39 fields × embed 10, CIN 200-200-200,
DNN 400-400, per-field vocab 2^20 (one stacked 39×2^20-row table).

Role: expensive pointwise ranker D (CIN crosses candidate × user fields)."""
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common
from repro.configs.recsys_common import make_recsys_arch
from repro.models import recsys as R


def full() -> R.XDeepFMConfig:
    return R.XDeepFMConfig(name="xdeepfm", n_fields=39, field_vocab=1_048_576,
                           embed_dim=10, cin_layers=(200, 200, 200),
                           mlp_dims=(400, 400), n_item_fields=13)


def smoke() -> R.XDeepFMConfig:
    return R.XDeepFMConfig(name="xdeepfm-smoke", n_fields=39, field_vocab=256,
                           embed_dim=4, cin_layers=(16, 16),
                           mlp_dims=(32, 32), n_item_fields=13)


def _batch_abs(cfg, batch, mesh, bspec):
    return {
        "fields": common.sds((batch, cfg.n_fields), jnp.int32, mesh,
                             P(bspec[0], None)),
        "label": common.sds((batch,), jnp.float32, mesh, bspec),
    }


def _cand_abs(cfg, n_cand, mesh):
    spec = P("model" if n_cand % mesh.shape["model"] == 0 else None, None)
    return common.sds((n_cand, cfg.n_item_fields), jnp.int32, mesh, spec)


SPEC = make_recsys_arch(
    "xdeepfm",
    full_cfg_fn=full, smoke_cfg_fn=smoke,
    init_fn=lambda key, cfg: R.xdeepfm_init(key, cfg),
    loss_fn=lambda params, batch, cfg: R.xdeepfm_loss(params, batch, cfg),
    serve_fn=lambda params, batch, cfg: R.xdeepfm_forward(
        params, batch["fields"], cfg),
    retrieval_fn=lambda params, user, cand, cfg: R.xdeepfm_score_candidates(
        params, user["fields"], cand, cfg),
    batch_abs_fn=_batch_abs,
    user_abs_fn=lambda cfg, mesh: {
        "fields": common.sds((1, cfg.n_fields - cfg.n_item_fields), jnp.int32,
                             mesh, P(None, None))
    },
    cand_abs_fn=_cand_abs,
)
