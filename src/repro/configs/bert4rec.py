"""bert4rec [arXiv:1904.06690] — bidirectional sequence model, embed 64,
2 blocks × 2 heads, seq 200, masked-item training (40 masked positions),
item vocab 65,536 (ML-25M scale, 16-divisible).

Encoder-only: no decode shapes exist in the recsys shape set (nothing to
skip). Retrieval is factorizable (last-hidden · item embedding), so bert4rec
doubles as the *cheap* proxy d for the recsys bi-metric demo."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common
from repro.configs.recsys_common import cand_ids_abs, make_recsys_arch
from repro.models import recsys as R


def full() -> R.Bert4RecConfig:
    return R.Bert4RecConfig(name="bert4rec", vocab=65_536, embed_dim=64,
                            seq_len=200, n_blocks=2, n_heads=2, n_masked=40)


def smoke() -> R.Bert4RecConfig:
    return R.Bert4RecConfig(name="bert4rec-smoke", vocab=512, embed_dim=16,
                            seq_len=16, n_blocks=2, n_heads=2, n_masked=4)


def _batch_abs(cfg, batch, mesh, bspec):
    return {
        "items": common.sds((batch, cfg.seq_len), jnp.int32, mesh,
                            P(bspec[0], None)),
        "mask_pos": common.sds((batch, cfg.n_masked), jnp.int32, mesh,
                               P(bspec[0], None)),
        "mask_labels": common.sds((batch, cfg.n_masked), jnp.int32, mesh,
                                  P(bspec[0], None)),
    }


def _serve(params, batch, cfg, chunk: int = 8192):
    """Next-item top-10 over the catalogue for a batch of users.

    Two-stage top-k: per-vocab-shard top-10 (runs sharded over "model"),
    then a tiny global re-top-k — the full (B, V) logits never exist on one
    device. Bulk batches additionally stream in row chunks so the live
    logits block is bounded."""
    from repro.distributed.sharding import constrain_axis, constrain_batch

    def score_rows(items):
        h = R.bert4rec_encode(params, items, cfg)[:, -1]  # (b, D)
        b = h.shape[0]
        v = params["item_emb"].shape[0]
        n_shard = 16 if v % 16 == 0 else 1
        shard_v = v // n_shard
        l3 = (h @ params["item_emb"].T).reshape(b, n_shard, shard_v)
        l3 = constrain_axis(l3, 1)  # catalogue shards stay on "model"
        vals, idx = jax.lax.top_k(l3, 10)  # (b, n_shard, 10) — sharded top-k
        idx = idx + (jnp.arange(n_shard) * shard_v)[None, :, None]
        vals2, pos = jax.lax.top_k(vals.reshape(b, -1), 10)
        return vals2, jnp.take_along_axis(idx.reshape(b, -1), pos, axis=1)

    items = batch["items"]
    n = items.shape[0]
    if n <= chunk or n % chunk:
        return score_rows(items)
    ic = items.reshape(n // chunk, chunk, items.shape[1])
    vals, ids = jax.lax.map(
        lambda it: score_rows(constrain_batch(it)), ic)
    return vals.reshape(n, 10), ids.reshape(n, 10)

SPEC = make_recsys_arch(
    "bert4rec",
    full_cfg_fn=full, smoke_cfg_fn=smoke,
    init_fn=lambda key, cfg: R.bert4rec_init(key, cfg),
    loss_fn=lambda params, batch, cfg: R.bert4rec_loss(params, batch, cfg),
    serve_fn=_serve,
    retrieval_fn=lambda params, user, cand, cfg: R.bert4rec_score_candidates(
        params, user["items"], cand, cfg),
    batch_abs_fn=_batch_abs,
    user_abs_fn=lambda cfg, mesh: {
        "items": common.sds((1, cfg.seq_len), jnp.int32, mesh, P(None, None))
    },
    cand_abs_fn=cand_ids_abs,
)
