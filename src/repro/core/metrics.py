"""Retrieval quality metrics: Recall@k and NDCG@k (paper §4.1 "Metric")."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def recall_at_k(pred_ids: Array, true_ids: Array) -> Array:
    """Recall@k of predicted ids vs ground-truth ids. (B,k),(B,k) -> (B,)."""
    hits = (pred_ids[:, :, None] == true_ids[:, None, :]) & (
        pred_ids[:, :, None] >= 0
    )
    return hits.any(axis=2).sum(axis=1) / true_ids.shape[1]


def dcg(gains: Array) -> Array:
    """(B, k) gains in rank order -> (B,) discounted cumulative gain."""
    ranks = jnp.arange(gains.shape[1], dtype=jnp.float32)
    disc = 1.0 / jnp.log2(ranks + 2.0)
    return (gains * disc[None, :]).sum(axis=1)


def ndcg_at_k(pred_ids: Array, true_ids: Array, true_gains: Array | None = None) -> Array:
    """NDCG@k against graded ground truth.

    ``true_ids`` (B, k) are the ideal top-k; ``true_gains`` their relevance
    grades (defaults to descending 2^(k-rank)-style linear grades, which makes
    NDCG sensitive to rank order as in MTEB-style evaluation).
    """
    b, k = true_ids.shape
    if true_gains is None:
        true_gains = jnp.broadcast_to(
            jnp.arange(k, 0, -1, dtype=jnp.float32)[None, :], (b, k)
        )
    match = (pred_ids[:, :, None] == true_ids[:, None, :]) & (
        pred_ids[:, :, None] >= 0
    )
    pred_gain = (match * true_gains[:, None, :]).sum(axis=2)  # (B, k_pred)
    ideal = dcg(true_gains)
    return dcg(pred_gain[:, :k]) / jnp.maximum(ideal, 1e-9)
