"""Batched greedy graph search (paper Algorithm 1) — the one shared hot loop.

Every search in the system — index construction (metric d), stage-1 search
(d), stage-2 search (D), the single-metric baseline, and the serving engine —
runs this engine. One step processes a whole batch of ``B`` queries in a
single fixed-shape update:

* each query's frontier is a fixed-size *pool* of its best ``pool_size``
  scored vertices (sorted by distance); the classic beam is the length-``L``
  prefix;
* one step = pick up to ``expand_width`` best unexpanded vertices in each
  query's beam prefix, gather the ``(B, E, R)`` neighbor fanout, drop
  already-scored vertices against the per-query scored bitmap, score the
  survivors with one batched distance call, and merge (beam ‖ fanout) back
  into the pools in one call (``repro.kernels.ops.merge_pool_batch`` — the
  stable jnp merge off-TPU, the fused Pallas bitonic kernel on TPU);
* per-query *dedup state* provides exact dedup — a vertex's distance is
  computed at most once per step wave, so counting scored candidates counts
  distance-function *calls* exactly (the paper's cost model). Two backends
  implement it behind ``_scored_lookup`` / ``_scored_scatter``:

  - ``bitmap`` — the dense (B, N) bool bitmap: O(1) lookup/scatter per
    lane, O(B·N) state. The only choice when the call budget is unbounded
    (graph construction, stage-1 proxy search).
  - ``sorted`` — a :class:`ScoredSet`: per-query **ascending id arrays of
    static capacity C = quota** (+ a count), lookup via ``searchsorted``,
    insertion via the same tie-stable top-k merge as the pools
    (``repro.kernels.ops.sorted_set_merge``). The bi-metric quota guarantee
    — one insertion per counted distance call, ``n_calls <= quota`` — means
    the set never overflows, so quota-bounded searches carry
    O(B·quota) dedup state instead of O(B·N) (NMSLIB's visited-set trick,
    sized to the budget rather than the corpus).

  ``dedup="auto"`` (the default) is drive-shape aware — see
  :func:`resolve_dedup`: host-driven dispatch loops (the serving engine's
  stage 2, where the non-donated bitmap would be copied every step) pick
  ``sorted`` exactly when the quota bound is static and smaller than the
  corpus; fused ``while_loop`` programs keep the bitmap (XLA aliases the
  carry, so on CPU the bitmap's step cost is O(wave) regardless of N —
  force ``dedup="sorted"`` when the bitmap's *memory* is the problem;
  note the fused entry points still materialize the (B, N) bitmap once at
  loop exit for ``SearchResult.scored``, so when even that single
  allocation is too large, drive :func:`init_state` / :func:`plan_step` /
  :func:`commit_scores` directly, as the serving engine does — that path
  never materializes it).
  Both backends are **bit-exact** to each other: same pool ids/dists,
  ``n_calls``, ``n_steps`` and scored set (the sorted backend materializes
  the equivalent bitmap once, after the loop, for :class:`SearchResult`);
* an explicit ``quota`` bounds the number of distance calls per query:
  candidates that would exceed the quota are masked out (never scored, never
  used), so the search is *exactly* budget-feasible per query, not just in
  expectation. Queries whose quota or frontier is exhausted freeze in place
  while the rest of the batch keeps stepping.

With ``expand_width=1`` a batched search is bit-exact to running each query
alone (and to the historical per-query engine): same pool ids, distances and
call counts. ``expand_width>1`` is the throughput knob — it cuts the step
count roughly E-fold at the cost of a slightly greedier expansion order (the
standard batched relaxation used by GPU graph-ANN engines); each wave's
fanout is positionally deduped, so a vertex reachable from two same-wave
frontier vertices is still paid for exactly once. (At E=1 the historical
behavior is preserved bit-exactly, including its quirk of scoring duplicate
ids inside one adjacency row twice.)

The step is exposed as ``plan_step`` / ``commit_scores`` so callers that
cannot score inside a ``while_loop`` (the serving engine, whose expensive
metric is a lazily-evaluated model forward pass) drive the identical loop
from the host: plan on device, score through the tower, commit on device.

The same plan/commit wave runs **device-parallel** over a corpus mesh
(:func:`sharded_greedy_search`): each device owns a contiguous corpus block,
waves are scored by a psum of shard-local fused gathers, and the pools stay
replicated — every device runs the identical merge on the identical
replicated wave, so the sharded engine is *bit-exact* vs the unsharded one
(pool ids/dists, n_calls and the scored set). The dedup backends shard
differently: the ``bitmap`` is **column-sharded** — each device holds the
(B, N/shards) slice of the columns it owns, lookups psum-OR the owner's
answer, scatters land on the owner only — while the ``sorted`` set is
**replicated like the pools** — (B, quota) per device, every membership op
collective-free. That is the memory trade: column-sharding divides the
O(B·N) bitmap across the mesh but pays a collective per lookup; the
replicated set costs O(B·quota) per device (independent of N *and* of the
shard count) and removes the dedup collective from the wave entirely.
``ShardCtx`` is the per-step handle; the collectives live in
``repro.distributed.collectives``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels import backend as kernel_backend
from repro.kernels import ops

Array = jax.Array

NO_QUOTA = jnp.iinfo(jnp.int32).max // 2


class ShardCtx(NamedTuple):
    """Handle for running the engine inside a ``shard_map`` over a corpus mesh.

    ``axis_name`` is the mesh axis the corpus (and the scored bitmap's column
    dim) is sharded over; ``n_local`` is the contiguous block of corpus rows
    each device owns (global rows ``[axis_index * n_local, ...)``). When a
    ``ShardCtx`` is passed, ``BatchedSearchState.scored`` is the *local*
    (B, n_local) column slice; everything else in the state is replicated.
    """

    axis_name: str
    n_local: int


class ScoredSet(NamedTuple):
    """Quota-proportional dedup state: per-query sorted membership arrays.

    ``ids`` (B, C) int32 ascending with ``repro.kernels.ops.SET_PAD``
    padding; the static capacity C must be >= every per-query quota, so the
    engine's exact quota accounting (one insertion per counted call,
    ``n_calls <= quota``) guarantees no entry is ever dropped. ``count``
    (B,) is the set's occupancy — insertions so far, i.e. ``n_calls``
    minus any ``calls_init``; the search itself never branches on it (the
    quota mask already bounds insertions), it exists as the overflow
    diagnostic: ``count <= capacity`` must hold at every step. Duplicate
    ids inside one E=1 adjacency row occupy one slot each, exactly
    mirroring their ``n_calls`` cost. C = 0 is a valid zero-capacity set
    (quota-0 rows): every op degenerates to a no-op.

    Under a :class:`ShardCtx` the set is **replicated** across the shard
    axis, like the pools — membership ops are collective-free.
    """

    ids: Array  # (B, C) int32 ascending; SET_PAD padded
    count: Array  # (B,) int32 insertions so far

    @property
    def capacity(self) -> int:
        return self.ids.shape[1]


def empty_scored_set(batch: int, capacity: int) -> ScoredSet:
    return ScoredSet(
        ids=jnp.full((batch, capacity), ops.SET_PAD, jnp.int32),
        count=jnp.zeros((batch,), jnp.int32),
    )


class BatchedSearchState(NamedTuple):
    """Per-query search state, batch-leading. All shapes are static.

    ``scored`` is the dedup state: the dense (B, N) bool bitmap *or* a
    :class:`ScoredSet` (the quota-proportional sorted backend) — every
    consumer dispatches on the type. Under a :class:`ShardCtx` the bitmap
    form is the device-local (B, n_local) column slice of the global (B, N)
    bitmap while the sorted form stays replicated; all other fields are
    replicated across the shard axis (the replicated-pool invariant).
    """

    pool_ids: Array  # (B, P) int32, sorted by dist; -1 pad
    pool_dists: Array  # (B, P) f32; +inf pad
    expanded: Array  # (B, P) bool
    scored: Array | ScoredSet  # dedup state + exact call counting
    n_calls: Array  # (B,) int32
    n_steps: Array  # (B,) int32


class SearchResult(NamedTuple):
    pool_ids: Array
    pool_dists: Array
    scored: Array
    n_calls: Array
    n_steps: Array


def _positional_dedup(ids: Array) -> Array:
    """Per row: an id equal to an earlier id in the row becomes -1."""
    e = ids.shape[-1]
    dup = (ids[..., :, None] == ids[..., None, :]) & (
        jnp.arange(e)[:, None] > jnp.arange(e)[None, :]
    )
    return jnp.where(dup.any(axis=-1), -1, ids)


def _static_quota_bound(quota) -> int | None:
    """max(quota) as a static int, or None when quota is a traced value.

    Python ints, numpy scalars/arrays and *concrete* jax arrays all have a
    static bound; a tracer (e.g. a jitted operand) does not — note that
    merely wrapping a constant in ``jnp`` ops inside a trace stages it, so
    the tracer check must come before any conversion.
    """
    if isinstance(quota, jax.core.Tracer):
        return None
    return int(np.max(np.asarray(quota)))


def resolve_dedup(
    dedup: str,
    set_capacity: int | None,
    quota,
    n_points: int,
    scored_init=None,
    *,
    drive: str = "host",
) -> tuple[str, int | None]:
    """Pick the dedup backend -> ``("bitmap", None) | ("sorted", capacity)``.

    ``"auto"`` selects ``sorted`` exactly when the quota bound is *static*
    (concrete at trace time) and smaller than the corpus — the regime where
    O(quota) membership state beats the O(N) bitmap; a traced quota (no
    static bound), an unbounded quota, or a continued bitmap
    (``scored_init``) falls back to ``bitmap``. An explicit backend is
    honored as given; ``sorted`` derives its capacity from the static quota
    bound when ``set_capacity`` is None.

    ``drive`` qualifies the auto rule by loop shape. ``"host"`` (the
    serving engine's dispatch-per-step stage 2) applies the rule above: the
    non-donated bitmap is round-tripped through every dispatch, so
    quota-proportional state wins by the corpus/quota ratio — ~9x at
    quota 256 on a 1M-row corpus (the gated BENCH_search_perf dedup
    scenario). ``"fused"`` (one jitted
    ``while_loop`` — :func:`batched_greedy_search` and the stage-1 /
    bi-metric paths) keeps the bitmap on auto: XLA aliases the loop carry,
    making the bitmap's per-step cost O(wave) regardless of N, and the
    sorted merge measures slower there on CPU at every N that fits memory
    (recorded in the same bench scenario). Explicit ``dedup="sorted"``
    still opts a fused loop into O(quota) state — the right call when the
    bitmap itself is the memory problem (huge N × batch, or accelerator
    HBM budgets).
    """
    if dedup == "bitmap":
        return "bitmap", None
    if dedup == "auto" and drive == "fused" and not isinstance(
            scored_init, ScoredSet):
        return "bitmap", None
    if scored_init is not None and not isinstance(scored_init, ScoredSet):
        if dedup == "sorted":
            raise ValueError(
                "dedup='sorted' cannot continue a bitmap scored_init")
        return "bitmap", None
    if isinstance(scored_init, ScoredSet):
        return "sorted", scored_init.capacity
    if dedup not in ("sorted", "auto"):
        raise ValueError(f"unknown dedup backend {dedup!r}")
    qmax = _static_quota_bound(quota)
    if set_capacity is None:
        if qmax is None:
            if dedup == "sorted":
                raise ValueError(
                    "dedup='sorted' with a traced quota needs an explicit "
                    "static set_capacity")
            return "bitmap", None  # auto: no static quota bound -> bitmap
        set_capacity = qmax
    elif qmax is not None and qmax <= NO_QUOTA // 2 and set_capacity < qmax:
        # an undersized set would silently drop scored ids (dedup holes)
        raise ValueError(f"set_capacity={set_capacity} < quota bound {qmax}")
    set_capacity = max(int(set_capacity), 0)
    if dedup == "auto" and set_capacity >= n_points:
        return "bitmap", None  # the bitmap is the smaller structure
    return "sorted", set_capacity


def scored_set_to_bitmap(sset: ScoredSet, n_points: int) -> Array:
    """Materialize the (B, N) bool bitmap a ScoredSet is equivalent to.

    One scatter outside the hot loop — used to keep ``SearchResult.scored``
    backend-independent (bit-identical across backends).
    """
    b, c = sset.ids.shape
    bitmap = jnp.zeros((b, n_points), dtype=bool)
    if c == 0:
        return bitmap
    rows = jnp.arange(b)[:, None]
    valid = sset.ids != ops.SET_PAD
    # pads clip onto column n-1 with valid=False, so .max() is a no-op there
    return bitmap.at[rows, jnp.clip(sset.ids, 0, n_points - 1)].max(valid)


def _scored_lookup(
    scored: Array | ScoredSet, ids: Array, shard: ShardCtx | None
) -> Array:
    """(B, K) bool: which (valid) ids are already in the dedup state."""
    if isinstance(scored, ScoredSet):
        if shard is None:
            return ops.sorted_set_lookup(scored.ids, ids)
        from repro.distributed import collectives

        return collectives.member_lookup(
            scored.ids, ids, axis_name=shard.axis_name)
    if shard is None:
        return (ids >= 0) & jnp.take_along_axis(
            scored, jnp.maximum(ids, 0), axis=1
        )
    from repro.distributed import collectives

    return collectives.bitmap_lookup(scored, ids, axis_name=shard.axis_name)


def _scored_scatter(
    scored: Array | ScoredSet, ids: Array, mark: Array,
    shard: ShardCtx | None,
) -> Array | ScoredSet:
    """Mark the kept lanes' ids in the dedup state (backend dispatch)."""
    if isinstance(scored, ScoredSet):
        if shard is None:
            merged = ops.sorted_set_merge(
                scored.ids, jnp.where(mark, ids, ops.SET_PAD))
        else:
            from repro.distributed import collectives

            merged = collectives.member_insert(
                scored.ids, ids, mark, axis_name=shard.axis_name)
        return ScoredSet(
            ids=merged,
            count=scored.count + mark.sum(axis=1, dtype=jnp.int32))
    if shard is None:
        rows = jnp.arange(ids.shape[0])[:, None]
        # scatter-OR (max): padding ids all alias index 0, so a plain set()
        # races
        return scored.at[rows, jnp.maximum(ids, 0)].max(mark)
    from repro.distributed import collectives

    return collectives.bitmap_scatter(scored, ids, mark,
                                      axis_name=shard.axis_name)


def init_state(
    entry_ids: Array,
    *,
    n_points: int,
    pool_size: int,
    quota: Array,
    scored_init: Array | ScoredSet | None = None,
    calls_init: Array | int = 0,
    shard: ShardCtx | None = None,
    dedup: str = "bitmap",
    set_capacity: int | None = None,
) -> tuple[BatchedSearchState, Array, Array]:
    """Empty pools + the entry wave, quota-masked but not yet scored.

    Returns ``(state, safe_entries (B, E0), keep (B, E0))``; the caller scores
    ``safe_entries`` (ids < 0 are masked) and feeds the result to
    :func:`commit_scores`. ``scored`` / ``n_calls`` already account for the
    kept entries — a wave is paid for when it is planned.

    ``dedup`` selects the dedup backend *concretely* (``"bitmap"`` or
    ``"sorted"`` — resolve ``"auto"`` first via :func:`resolve_dedup`);
    ``set_capacity`` is the sorted backend's static capacity (>= the max
    quota; 0 is a valid zero-capacity set for all-quota-0 batches). Under a
    :class:`ShardCtx` the bitmap is allocated as the device-local
    (B, n_local) column slice (entry marks land on their owning shard)
    while the sorted set is replicated.
    """
    b, e = entry_ids.shape
    entry_ids = _positional_dedup(entry_ids.astype(jnp.int32))
    valid = entry_ids >= 0
    order_idx = jnp.cumsum(valid.astype(jnp.int32), axis=1) - 1
    quota = jnp.broadcast_to(jnp.asarray(quota, jnp.int32), (b,))
    calls0 = jnp.broadcast_to(jnp.asarray(calls_init, jnp.int32), (b,))
    keep = valid & (order_idx < (quota - calls0)[:, None])
    safe = jnp.where(keep, entry_ids, -1)

    if scored_init is not None:
        scored = scored_init
    elif dedup == "sorted":
        if set_capacity is None:
            raise ValueError("dedup='sorted' needs a static set_capacity")
        scored = empty_scored_set(b, int(set_capacity))
    else:
        n_cols = n_points if shard is None else shard.n_local
        scored = jnp.zeros((b, n_cols), dtype=bool)
    scored = _scored_scatter(scored, safe, keep, shard)
    n_calls = calls0 + keep.sum(axis=1, dtype=jnp.int32)

    p = pool_size
    state = BatchedSearchState(
        pool_ids=jnp.full((b, p), -1, jnp.int32),
        pool_dists=jnp.full((b, p), jnp.inf, jnp.float32),
        expanded=jnp.zeros((b, p), dtype=bool),
        scored=scored,
        n_calls=n_calls,
        n_steps=jnp.zeros((b,), jnp.int32),
    )
    return state, safe, keep


def _per_query(v: int | Array, b: int) -> Array:
    """Broadcast a scalar-or-(B,) knob to a (B,) int32 vector."""
    return jnp.broadcast_to(jnp.asarray(v, jnp.int32), (b,))


def reset_slots(
    state: BatchedSearchState,
    reset: Array,
    entry_ids: Array,
    quota: Array,
    *,
    shard: ShardCtx | None = None,
) -> tuple[BatchedSearchState, Array, Array]:
    """Re-initialize the rows in ``reset`` to a fresh entry wave, in place.

    The slot-pool admission primitive: ``reset`` (B,) bool marks the rows
    (slots) being recycled for newly admitted queries; their pools, dedup
    state and counters are cleared and re-seeded from ``entry_ids`` exactly
    as :func:`init_state` would — positional entry dedup, quota-masked keep,
    scored/n_calls pre-paid at plan time. Rows outside ``reset`` are
    untouched bit-for-bit (their lanes in the returned ``safe`` are -1, so
    the follow-up entry :func:`commit_scores` is an exact no-op on them).

    Returns ``(state', safe (B, E0), keep (B, E0))``; the caller scores
    ``safe`` and commits, same contract as :func:`init_state`. Under a
    :class:`ShardCtx` the bitmap rows are cleared on every shard's local
    column slice and the entry marks land on their owners, so a recycled
    slot's dedup state is indistinguishable from a freshly initialized one.
    """
    b, p = state.pool_ids.shape
    reset = jnp.broadcast_to(jnp.asarray(reset, bool), (b,))
    entry_ids = _positional_dedup(entry_ids.astype(jnp.int32))
    valid = entry_ids >= 0
    order_idx = jnp.cumsum(valid.astype(jnp.int32), axis=1) - 1
    quota = _per_query(quota, b)
    keep = valid & (order_idx < quota[:, None]) & reset[:, None]
    safe = jnp.where(keep, entry_ids, -1)

    scored = state.scored
    if isinstance(scored, ScoredSet):
        scored = ScoredSet(
            ids=jnp.where(reset[:, None], ops.SET_PAD, scored.ids),
            count=jnp.where(reset, 0, scored.count),
        )
    else:
        scored = jnp.where(reset[:, None], False, scored)
    scored = _scored_scatter(scored, safe, keep, shard)

    rm = reset[:, None]
    state = BatchedSearchState(
        pool_ids=jnp.where(rm, -1, state.pool_ids),
        pool_dists=jnp.where(rm, jnp.inf, state.pool_dists),
        expanded=jnp.where(rm, False, state.expanded),
        scored=scored,
        n_calls=jnp.where(
            reset, keep.sum(axis=1, dtype=jnp.int32), state.n_calls),
        n_steps=jnp.where(reset, 0, state.n_steps),
    )
    return state, safe, keep


def grow_state(
    state: BatchedSearchState,
    *,
    pool_size: int | None = None,
    set_capacity: int | None = None,
) -> BatchedSearchState:
    """Right-pad a state's static shapes — an exact semantic no-op.

    The slot pool grows its resident state monotonically when an admitted
    request needs a larger pool (P) or sorted-set capacity (C) than any
    before it. Both growths are provably invisible to the search: pools are
    streaming exact top-P structures, so appended (-1, +inf, unexpanded)
    lanes never alter the surviving prefix (P-invariance), and
    ``ops.SET_PAD`` sorts to the tail of each ascending ScoredSet row, so
    appended pad slots leave every lookup/merge result unchanged. Shrinking
    is not supported (it could drop live entries); passing a smaller value
    keeps the current shape.
    """
    pool_ids, pool_dists, expanded = (
        state.pool_ids, state.pool_dists, state.expanded)
    p = pool_ids.shape[1]
    if pool_size is not None and pool_size > p:
        pad = ((0, 0), (0, pool_size - p))
        pool_ids = jnp.pad(pool_ids, pad, constant_values=-1)
        pool_dists = jnp.pad(pool_dists, pad, constant_values=jnp.inf)
        expanded = jnp.pad(expanded, pad, constant_values=False)
    scored = state.scored
    if (isinstance(scored, ScoredSet) and set_capacity is not None
            and set_capacity > scored.capacity):
        scored = ScoredSet(
            ids=jnp.pad(
                scored.ids,
                ((0, 0), (0, set_capacity - scored.capacity)),
                constant_values=ops.SET_PAD),
            count=scored.count,
        )
    return state._replace(
        pool_ids=pool_ids, pool_dists=pool_dists, expanded=expanded,
        scored=scored)


def active_mask(
    state: BatchedSearchState,
    *,
    beam_width: int | Array,
    quota: Array,
    max_steps: int | Array,
) -> Array:
    """(B,) — which queries still have an open frontier, budget and steps.

    ``beam_width`` and ``max_steps`` may be scalars or per-query (B,)
    vectors — mixed-configuration batches (the serving engine's request
    waves) give every query *its own* beam prefix and step cap, so a query
    behaves bit-exactly as if it ran alone regardless of its wave-mates.
    """
    b, p = state.pool_ids.shape
    L = _per_query(beam_width, b)
    in_beam = jnp.arange(p)[None, :] < L[:, None]
    frontier = (~state.expanded) & jnp.isfinite(state.pool_dists) & in_beam
    quota = _per_query(quota, b)
    steps = _per_query(max_steps, b)
    return (
        frontier.any(axis=1)
        & (state.n_calls < quota)
        & (state.n_steps < steps)
    )


def reset_expanded(state: BatchedSearchState, rows: Array) -> BatchedSearchState:
    """Re-open the frontier on the masked ``rows`` (clear ``expanded``).

    The graph descent never revisits a vertex, but the cover-tree level
    descent expands the *same* surviving centers again at the next (finer)
    level — between levels the driver clears the expanded flags so
    :func:`plan_step`'s frontier selection sees the whole pool prefix
    afresh. ``rows`` is a (B,) bool mask (or scalar); pools, scores,
    dedup state and call counters are untouched, so re-expansion stays an
    exact no-op for already-memoized ids.
    """
    b = state.pool_ids.shape[0]
    rows = jnp.broadcast_to(jnp.asarray(rows, bool), (b,))
    return state._replace(
        expanded=jnp.where(rows[:, None], False, state.expanded)
    )


def early_resolve(state: BatchedSearchState, rows: Array) -> BatchedSearchState:
    """Close the frontier on the masked ``rows`` — the inverse of
    :func:`reset_expanded`: every pool lane is marked expanded, so
    :func:`active_mask` reports the row inactive regardless of its
    remaining quota/step budget.

    This is the serving layer's graceful-degradation primitive: a slot
    being resolved early (mid-flight deadline expiry, or proxy-only
    results while the expensive tower is open-circuit) is frozen in the
    resident state so no later plan re-expands it and no level-descent
    ``reset_expanded`` can resurrect it. Pools, scores, dedup state and
    call counters are untouched — the already-scored pool prefix stays
    readable for the degraded answer — and non-masked rows pass through
    bit-for-bit. ``rows`` is a (B,) bool mask (or scalar).
    """
    b = state.pool_ids.shape[0]
    rows = jnp.broadcast_to(jnp.asarray(rows, bool), (b,))
    return state._replace(
        expanded=jnp.where(rows[:, None], True, state.expanded)
    )


def plan_step(
    state: BatchedSearchState,
    adjacency: Array,
    *,
    beam_width: int | Array,
    quota: Array,
    max_steps: int | Array,
    expand_width: int | Array = 1,
    expand_cap: int | None = None,
    shard: ShardCtx | None = None,
    level: Array | None = None,
    wave_dedup: bool = True,
) -> tuple[BatchedSearchState, Array, Array, Array]:
    """One expansion wave: pick frontiers, gather fanout, mask to the quota.

    Returns ``(state', safe (B, E*R), keep (B, E*R), active (B,))`` where
    ``state'`` has ``expanded`` / ``scored`` / ``n_calls`` / ``n_steps``
    advanced (a wave is paid for when planned). The caller scores ``safe``
    and calls :func:`commit_scores`. Frozen (inactive) queries plan an
    all-masked wave, which commits as an exact no-op.

    ``expand_width`` may be a scalar or a per-query (B,) vector (the slot
    pool's mixed-request batches); the wave's static lane count E is
    ``expand_cap`` when given (required when the vector is traced),
    otherwise the concrete max. A row with expand_width 1 keeps the
    historical E=1 semantics bit-exactly — including its quirk of paying
    for duplicate ids inside one adjacency row twice — regardless of its
    batch-mates' widths.

    ``level`` (a per-query (B,) int vector) switches the fanout table from
    a flat graph ``(N, R)`` to a level-stacked ``(L, N, R)`` one —
    ``adjacency[level[b], vertex]`` — which is how the cover-tree descent
    steps co-resident queries sitting at *different* tree levels in one
    program. ``wave_dedup=False`` skips the O((E·R)²) same-wave positional
    dedup; only safe when the expanded rows' fanouts are disjoint by
    construction (cover-tree child slabs partition the next level).

    Under a :class:`ShardCtx`, the already-scored lookup OR-reduces the
    owning shard's bitmap slice across the axis and the scatter lands only
    on the owner; all other planning math runs on replicated inputs, so the
    planned wave is replicated (and bit-exact vs the unsharded plan).
    """
    b, p = state.pool_ids.shape
    L = _per_query(beam_width, b)
    if expand_cap is None:
        expand_cap = _static_quota_bound(expand_width)
        if expand_cap is None:
            raise ValueError(
                "a traced (B,) expand_width needs a static expand_cap")
    E = max(int(expand_cap), 1)
    ew = _per_query(expand_width, b)
    r = adjacency.shape[-1]
    quota = jnp.broadcast_to(jnp.asarray(quota, jnp.int32), (b,))

    active = active_mask(
        state, beam_width=L, quota=quota, max_steps=max_steps
    )
    # best unexpanded slots in the beam prefix (pool sorted -> first open)
    open_ = (
        (~state.expanded)
        & jnp.isfinite(state.pool_dists)
        & (jnp.arange(p)[None, :] < L[:, None])
    )
    rank = jnp.cumsum(open_.astype(jnp.int32), axis=1) - 1
    sel = open_ & (rank < ew[:, None]) & active[:, None]
    expanded = state.expanded | sel
    # slot positions of the selected vertices, in pool order; p == "none"
    # (top_k of the negated positions == first-E ascending, without a sort)
    slot_pos = -jax.lax.top_k(
        jnp.where(sel, -jnp.arange(p)[None, :], -p), E
    )[0]
    has = slot_pos < p
    verts = jnp.where(
        has,
        jnp.take_along_axis(state.pool_ids, jnp.minimum(slot_pos, p - 1), 1),
        -1,
    )

    adj = adjacency.astype(jnp.int32)
    if level is None:
        nbrs = adj[jnp.maximum(verts, 0)]  # (B, E, R)
    else:
        lev = _per_query(level, b)
        nbrs = adj[lev[:, None], jnp.maximum(verts, 0)]  # (B, E, R)
    nbrs = jnp.where((verts >= 0)[:, :, None], nbrs, -1)
    cand = nbrs.reshape(b, E * r)
    if E > 1 and wave_dedup:
        # a vertex reachable from two same-wave frontier vertices must be
        # paid for once; a row at expand_width 1 keeps the historical
        # behavior bit-exactly (which scores duplicate ids inside one
        # adjacency row twice), even when its batch-mates run wider.
        cand = jnp.where((ew > 1)[:, None], _positional_dedup(cand), cand)
    fresh = (cand >= 0) & ~_scored_lookup(state.scored, cand, shard)
    # exact quota masking: only the first `remaining` fresh ids get scored
    call_idx = jnp.cumsum(fresh.astype(jnp.int32), axis=1) - 1
    keep = fresh & (call_idx < (quota - state.n_calls)[:, None])
    safe = jnp.where(keep, cand, -1)

    scored = _scored_scatter(state.scored, safe, keep, shard)
    n_calls = state.n_calls + keep.sum(axis=1, dtype=jnp.int32)
    n_steps = state.n_steps + active.astype(jnp.int32)
    state = state._replace(
        expanded=expanded, scored=scored, n_calls=n_calls, n_steps=n_steps
    )
    return state, safe, keep, active


def commit_scores(
    state: BatchedSearchState,
    safe: Array,
    keep: Array,
    dists: Array,
    *,
    backend: str | kernel_backend.Backend | None = None,
    use_fused_merge: bool | None = None,
    interpret: bool | None = None,
) -> BatchedSearchState:
    """Merge a scored wave into the pools (masked lanes are +inf no-ops).

    ``backend`` selects the merge route (``"pallas"`` = the lane-padded
    bitonic kernel; everything else = the stable XLA merge); the legacy
    ``use_fused_merge`` / ``interpret`` kwargs remain as deprecated shims.
    """
    be = kernel_backend.resolve_backend(
        backend, use_fused_merge=use_fused_merge, interpret=interpret,
        _caller="beam.commit_scores")
    d = jnp.where(keep, dists.astype(jnp.float32), jnp.inf)
    pool_ids, pool_dists, expanded = ops.merge_pool_batch(
        state.pool_ids,
        state.pool_dists,
        state.expanded,
        safe,
        d,
        backend=be,
    )
    return state._replace(
        pool_ids=pool_ids, pool_dists=pool_dists, expanded=expanded
    )


def batched_greedy_search(
    dist_fn_batch: Callable[[Array, Array], Array],
    adjacency: Array,
    query_ctx: Array | None,
    entry_ids: Array,
    *,
    n_points: int,
    beam_width: int | Array,
    pool_size: int | None = None,
    quota: int | Array = NO_QUOTA,
    expand_width: int = 1,
    max_steps: int | Array | None = None,
    scored_init: Array | ScoredSet | None = None,
    calls_init: Array | int = 0,
    backend: str | kernel_backend.Backend | None = None,
    use_fused_merge: bool | None = None,
    interpret: bool | None = None,
    shard: ShardCtx | None = None,
    dedup: str = "auto",
    set_capacity: int | None = None,
) -> SearchResult:
    """Greedy beam search over ``adjacency`` for a whole query batch.

    Args:
      dist_fn_batch: maps ``(query_ctx, ids (B, K) int32) -> (B, K) f32``
        distances; ids < 0 must map to +inf. Every *finite* evaluation is one
        metric call. ``repro.core.distances.EmbeddingMetric.dists_batch`` and
        the fused ``repro.kernels.ops.gather_score`` both satisfy this.
      adjacency: (N, R) int32 out-neighbors, -1 padded.
      query_ctx: opaque per-query context forwarded to ``dist_fn_batch``
        (usually the (B, dim) query embeddings; may be None).
      entry_ids: (B, E0) int32 starting vertices (deduped here; -1 pads ok).
      n_points: N (for the scored bitmap).
      beam_width: L — expansion happens within the best-L prefix. Scalar or
        (B,) for mixed per-query widths (a (B,) beam width requires an
        explicit static ``pool_size``).
      pool_size: P >= L — how many best-scored vertices to retain.
      quota: max distance calls per query (incl. entry scoring); scalar or
        (B,) for mixed per-query budgets.
      expand_width: E — frontier vertices expanded per query per step. 1 is
        bit-exact to the per-query engine; >1 trades exact expansion order
        for ~E-fold fewer steps.
      max_steps: cap on per-query expansions (defaults to a safe bound);
        scalar or (B,) for mixed per-query caps.
      scored_init / calls_init: continue an earlier search's accounting —
        used by the bi-metric stage-2 search (see bimetric.py).
      backend: merge-route selection (``repro.kernels.resolve_backend``
        values — ``"pallas"`` runs the lane-padded bitonic pool merge, the
        default keeps the stable XLA merge). Distance scoring is the
        caller's ``dist_fn_batch``, so its backend is chosen where that
        closure is built (:func:`fused_dist_fn`).
      use_fused_merge / interpret: deprecated shims for ``backend``.
      shard: run the loop device-parallel inside a ``shard_map`` over a
        corpus mesh — ``dist_fn_batch`` must then be the wave-gather
        collective and the bitmap form of ``scored`` is the local column
        slice (callers use :func:`sharded_greedy_search`, which sets all of
        this up).
      dedup / set_capacity: dedup-state backend — ``"auto"`` (default)
        resolves via :func:`resolve_dedup` with ``drive="fused"`` (this is
        one jitted while_loop, where the aliased bitmap carry wins on CPU);
        ``"bitmap"`` / ``"sorted"`` force a backend (``"sorted"`` = the
        O(quota)-state :class:`ScoredSet`, the memory-bound choice). The
        backends are bit-exact to each other; ``SearchResult.scored`` is
        always the (B, N) bitmap (the sorted backend materializes it once,
        after the loop).

    Returns a batch-leading SearchResult, pools sorted ascending by distance
    (under ``shard`` with the bitmap backend, ``scored`` is the local
    (B, n_local) slice).
    """
    adjacency = adjacency.astype(jnp.int32)
    n, _ = adjacency.shape
    assert n == n_points
    b, e0 = entry_ids.shape
    L = beam_width
    if isinstance(L, int) or getattr(L, "ndim", 0) == 0:
        L = int(L)
        P = max(pool_size or 0, L, e0)
        if max_steps is None:
            max_steps = 4 * L + 16
    else:
        if pool_size is None:
            raise ValueError(
                "a per-query (B,) beam_width needs an explicit pool_size")
        if max_steps is None:
            raise ValueError(
                "a per-query (B,) beam_width needs an explicit max_steps")
        # keep the scalar branch's P >= L invariant when the widths are
        # concrete (eager callers); under a trace the caller must guarantee
        # pool_size >= max(beam_width) — sharded_greedy_search does
        try:
            bw_cap = int(jnp.max(jnp.asarray(L)))
        except jax.errors.ConcretizationTypeError:
            bw_cap = 0
        P = max(pool_size, bw_cap, e0)
    dedup, set_capacity = resolve_dedup(
        dedup, set_capacity, quota, n_points, scored_init, drive="fused")
    be = kernel_backend.resolve_backend(
        backend, use_fused_merge=use_fused_merge, interpret=interpret,
        _caller="beam.batched_greedy_search")
    quota = jnp.broadcast_to(jnp.asarray(quota, jnp.int32), (b,))

    state, safe, keep = init_state(
        entry_ids,
        n_points=n_points,
        pool_size=P,
        quota=quota,
        scored_init=scored_init,
        calls_init=calls_init,
        shard=shard,
        dedup=dedup,
        set_capacity=set_capacity,
    )
    state = commit_scores(
        state, safe, keep, dist_fn_batch(query_ctx, safe), backend=be,
    )

    def cond(s: BatchedSearchState) -> Array:
        return active_mask(
            s, beam_width=L, quota=quota, max_steps=max_steps
        ).any()

    def body(s: BatchedSearchState) -> BatchedSearchState:
        s, safe, keep, _ = plan_step(
            s,
            adjacency,
            beam_width=L,
            quota=quota,
            max_steps=max_steps,
            expand_width=expand_width,
            shard=shard,
        )
        return commit_scores(
            s, safe, keep, dist_fn_batch(query_ctx, safe), backend=be,
        )

    final = lax.while_loop(cond, body, state)
    scored = final.scored
    if isinstance(scored, ScoredSet):
        # one scatter outside the hot loop keeps the result's scored field
        # backend-independent (bit-identical to the bitmap backend's)
        scored = scored_set_to_bitmap(scored, n_points)
    return SearchResult(
        final.pool_ids,
        final.pool_dists,
        scored,
        final.n_calls,
        final.n_steps,
    )


def fused_dist_fn(
    corpus: Array,
    metric: str = "sqeuclidean",
    *,
    backend: str | kernel_backend.Backend | None = None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    quantize: str | None = None,
) -> Callable[[Array, Array], Array]:
    """A ``dist_fn_batch`` that runs the backend-dispatched gather→score.

    ``query_ctx`` must then be the (B, dim) query embeddings. The default
    backend is the jnp gather-then-reduce oracle, which matches
    ``EmbeddingMetric`` up to fp association; the matmul backends
    (``"xla_matmul"`` / ``"pallas"`` / ``"auto"``) build the corpus-norm
    cache **here, once** — the returned closure threads the prebuilt
    :class:`repro.kernels.CorpusView` through every wave, so ``‖x‖²`` is
    never re-reduced inside the hot loop. ``quantize`` (or a Backend
    carrying the mode) builds the view with quantized residency — also
    here, once; a prebuilt (possibly quantized) view passes straight
    through and is scored as-is.
    """
    be = kernel_backend.resolve_backend(
        backend, use_pallas=use_pallas, interpret=interpret,
        quantize=quantize, _caller="beam.fused_dist_fn")
    if (be.matmul or be.quantize is not None
            or isinstance(corpus, kernel_backend.CorpusView)):
        src = kernel_backend.as_corpus_view(corpus, quantize=be.quantize)
    else:
        src = corpus

    def fn(q_embs: Array, ids: Array) -> Array:
        return ops.gather_score(src, q_embs, ids, metric=metric, backend=be)

    return fn


def sharded_greedy_search(
    corpus: Array,
    adjacency: Array,
    query_embs: Array,
    entry_ids: Array,
    *,
    shards: int,
    metric: str = "sqeuclidean",
    mesh=None,
    axis_name: str | None = None,
    beam_width: int | Array,
    pool_size: int | None = None,
    quota: int | Array = NO_QUOTA,
    expand_width: int = 1,
    max_steps: int | Array | None = None,
    backend: str | kernel_backend.Backend | None = None,
    use_pallas: bool | None = None,
    use_fused_merge: bool | None = None,
    interpret: bool | None = None,
    quantize: str | None = None,
    dedup: str = "auto",
    set_capacity: int | None = None,
) -> SearchResult:
    """Device-parallel batched greedy search over a sharded corpus.

    The corpus is split into ``shards`` contiguous row blocks, one per
    device of a 1-D mesh (built over the first ``shards`` local devices when
    ``mesh`` is None). Inside ``shard_map`` each device gathers and scores
    the wave lanes it owns with the fused local gather→score kernel; a psum
    over the shard axis reconstructs the replicated wave. Pools, call
    counters and step counters are replicated — every device runs the
    identical plan and merge, so the result (including the scored set) is
    **bit-exact** vs :func:`batched_greedy_search` with
    :func:`fused_dist_fn` on one device.

    Dedup state under the mesh (``dedup`` resolves like the unsharded
    engine's): the ``bitmap`` backend column-shards the (B, N) bitmap —
    lookups psum-OR the owning shard's answer, scatters land on the owner —
    while the ``sorted`` backend keeps the (B, quota) :class:`ScoredSet`
    replicated like the pools, so its per-device dedup state is independent
    of both N and the shard count and its membership ops are
    collective-free.

    ``backend`` selects the wave-scoring/merge route
    (``repro.kernels.resolve_backend``); the matmul backends build the
    corpus-norm cache once on the host and shard the norms **with** the
    corpus blocks (same contiguous placement, zero-padded rows carry norm
    0), so the cache adds nothing to the wave's psum traffic. ``quantize``
    (or a Backend carrying the mode, or a prebuilt quantized view as
    ``corpus``) holds the resident blocks as int8/fp8 codes with the
    per-row dequant parameters sharded alongside the norms — pad rows
    dequantize to exact zeros, and the replicated pools/counters make the
    quantized sharded run bit-exact vs the quantized unsharded run for
    the same view. The parity guarantee is per-backend: sharded ==
    unsharded under the *same* backend (the ``"ref"`` default additionally
    stays bit-exact vs the legacy engine).

    ``shards=1`` short-circuits to the single-device engine (today's path).
    """
    from jax.sharding import PartitionSpec as _P

    from repro.distributed import collectives
    from repro.distributed.sharding import (SEARCH_AXIS, search_mesh,
                                            shard_corpus, shard_corpus_view)
    from repro.launch.mesh import shard_map

    n_points = kernel_backend.corpus_rows(corpus).shape[0]
    be = kernel_backend.resolve_backend(
        backend, use_pallas=use_pallas, use_fused_merge=use_fused_merge,
        interpret=interpret, quantize=quantize,
        _caller="beam.sharded_greedy_search")
    if shards == 1:
        return batched_greedy_search(
            fused_dist_fn(corpus, metric, backend=be),
            adjacency, query_embs, entry_ids, n_points=n_points,
            beam_width=beam_width, pool_size=pool_size, quota=quota,
            expand_width=expand_width, max_steps=max_steps,
            backend=be, dedup=dedup, set_capacity=set_capacity)
    # resolve the backend on the host (quota is concrete here) so the mesh
    # program is built against one concrete dedup structure
    dedup, set_capacity = resolve_dedup(
        dedup, set_capacity, quota, n_points, drive="fused")

    axis = axis_name or SEARCH_AXIS
    # the resident form is static on the host: a view is built (and
    # quantized) here exactly once, with the norms and dequant parameters
    # sharded like the row blocks — nothing metadata enters the wave psum
    quant = be.quantize
    if quant is None and isinstance(corpus, kernel_backend.CorpusView):
        quant = corpus.quantize
    need_view = be.matmul or quant is not None
    if need_view:
        (stacked, sq_stack, inv_stack, sc_stack, zp_stack,
         n_local) = shard_corpus_view(corpus, shards, quantize=be.quantize)
    else:
        stacked, n_local = shard_corpus(
            kernel_backend.corpus_rows(corpus), shards)
        sq_stack = jnp.zeros((shards, 0), jnp.float32)
        inv_stack = jnp.zeros((shards, 0), jnp.float32)
        sc_stack = jnp.zeros((shards, 0), jnp.float32)
        zp_stack = jnp.zeros((shards, 0), jnp.float32)
    has_zp = quant is not None and zp_stack.shape[-1] > 0
    mesh = mesh if mesh is not None else search_mesh(shards, axis)
    ctx = ShardCtx(axis_name=axis, n_local=n_local)
    b, e0 = entry_ids.shape
    quota_arr = jnp.broadcast_to(jnp.asarray(quota, jnp.int32), (b,))
    # static shape params from the per-query knobs (scalar knobs keep the
    # historical values exactly); the (B,) vectors ride in as operands so a
    # mixed-width batch does not retrace per composition
    bw_max = int(jnp.max(jnp.asarray(beam_width)))
    pool = max(pool_size or 0, bw_max, e0)
    if max_steps is None:
        max_steps = 4 * bw_max + 16
    bw_arr = _per_query(beam_width, b)
    ms_arr = _per_query(max_steps, b)

    def program(local_corpus, local_sq, local_inv, local_sc, local_zp,
                adj, q_embs, entries, q, bw, ms):
        local_corpus = local_corpus[0]  # (1, n_local, dim) block -> local rows
        if need_view:
            local_src = kernel_backend.CorpusView(
                rows=local_corpus, sq_norms=local_sq[0],
                inv_norms=local_inv[0],
                scales=local_sc[0] if quant is not None else None,
                zero_points=local_zp[0] if has_zp else None)
        else:
            local_src = local_corpus

        def dist_fn(qe, ids):
            return collectives.wave_gather_score(
                local_src, qe, ids, axis_name=axis, metric=metric,
                backend=be)

        return batched_greedy_search(
            dist_fn, adj, q_embs, entries, n_points=n_points,
            beam_width=bw, pool_size=pool, quota=q,
            expand_width=expand_width, max_steps=ms,
            backend=be, shard=ctx,
            dedup=dedup, set_capacity=set_capacity)

    rep2, rep1 = _P(None, None), _P(None)
    # bitmap: local column slices -> global (B, S*nl); sorted: the program
    # materializes the replicated (B, N) bitmap from the replicated set
    scored_spec = _P(None, axis) if dedup == "bitmap" else rep2
    res = shard_map(
        program,
        mesh=mesh,
        in_specs=(_P(axis, None, None), _P(axis, None), _P(axis, None),
                  _P(axis, None), _P(axis, None),
                  rep2, rep2, rep2, rep1, rep1, rep1),
        out_specs=SearchResult(
            pool_ids=rep2, pool_dists=rep2, scored=scored_spec,
            n_calls=rep1, n_steps=rep1),
    )(stacked, sq_stack, inv_stack, sc_stack, zp_stack,
      adjacency.astype(jnp.int32), query_embs,
      entry_ids.astype(jnp.int32), quota_arr, bw_arr, ms_arr)
    if dedup == "bitmap":
        # drop the zero-padding columns (global ids >= N never get scored)
        res = res._replace(scored=res.scored[:, :n_points])
    return res


class ShardedStepper:
    """Host-driven plan/commit stepping with the search state resident on a
    corpus mesh — the device side of the serving engine's stage 2.

    The serving engine cannot score inside a ``while_loop`` (its expensive
    metric is a lazily-evaluated model forward pass), so it drives
    :func:`plan_step` / :func:`commit_scores` from the host. This class is
    the sharded form of that drive loop: each method is a jitted
    ``shard_map`` program over the corpus mesh. The dedup state follows the
    backend chosen at :meth:`init`: the ``bitmap`` form lives as
    (B, n_local) column slices — the lookup OR-reduces the owning shard's
    answer, the scatter lands on the owner only
    (``repro.distributed.collectives``) — while the ``sorted``
    :class:`ScoredSet` form is replicated like the pools, shrinking the
    per-device dedup state from (B, n_local) to (B, quota) and making every
    membership op collective-free; both exactly like stage 1's
    :func:`sharded_greedy_search`. Pools, call and step counters stay
    replicated, every device plans the identical wave, and the host sees
    replicated ``safe`` / ``keep`` lanes to drain through the tower — so the
    sharded stage 2 is **bit-exact** vs the single-device drive loop under
    either backend.

    State produced by :meth:`init` must be threaded through :meth:`plan` /
    :meth:`commit` unmodified — its ``scored`` leaf carries the mesh
    sharding (or replication) between calls; everything stays on device
    until the final pools are read off. ``beam_width`` / ``max_steps`` /
    ``quota`` are (B,) operands, so mixed per-query budgets in one wave do
    not retrace; the sorted backend's capacity is a static shape, so
    callers should quantize it (the engine rounds up to a power of two) to
    keep retraces bounded.
    """

    def __init__(self, *, shards: int, n_points: int, mesh=None,
                 axis_name: str | None = None,
                 backend: str | kernel_backend.Backend | None = None):
        from repro.distributed.sharding import SEARCH_AXIS, search_mesh

        self.shards = shards
        self.n_points = n_points
        self.axis_name = axis_name or SEARCH_AXIS
        self.mesh = mesh if mesh is not None else search_mesh(
            shards, self.axis_name)
        self.n_local = -(-n_points // shards)
        self.ctx = ShardCtx(axis_name=self.axis_name, n_local=self.n_local)
        # merge route for commit (the stepper never scores — its caller's
        # tower does — so the backend only picks the pool-merge kernel)
        self.backend = kernel_backend.resolve_backend(
            backend, _caller="beam.ShardedStepper")
        self._programs: dict = {}

    # ------------------------------------------------------------- internals
    def _specs(self, dedup: str = "bitmap"):
        from jax.sharding import PartitionSpec as _P

        rep2, rep1 = _P(None, None), _P(None)
        scored_spec = (
            ScoredSet(ids=rep2, count=rep1)  # replicated, like the pools
            if dedup == "sorted" else _P(None, self.axis_name))
        state_spec = BatchedSearchState(
            pool_ids=rep2, pool_dists=rep2, expanded=rep2,
            scored=scored_spec, n_calls=rep1, n_steps=rep1)
        return rep2, rep1, state_spec

    @staticmethod
    def _dedup_of(state: BatchedSearchState) -> str:
        return "sorted" if isinstance(state.scored, ScoredSet) else "bitmap"

    def _program(self, key, build):
        if key not in self._programs:
            self._programs[key] = build()
        return self._programs[key]

    # -------------------------------------------------------------- step API
    def init(self, entry_ids: Array, quota: Array, *, pool_size: int,
             dedup: str = "bitmap", set_capacity: int | None = None,
             ) -> tuple[BatchedSearchState, Array, Array]:
        """Sharded :func:`init_state`: the entry wave, dedup state
        column-sharded (bitmap) or replicated (sorted). ``dedup`` must be
        concrete here — the engine resolves "auto" and quantizes
        ``set_capacity`` before calling."""
        from repro.launch.mesh import shard_map

        rep2, rep1, state_spec = self._specs(dedup)

        def build():
            def f(entries, q):
                return init_state(
                    entries, n_points=self.n_points, pool_size=pool_size,
                    quota=q, shard=self.ctx, dedup=dedup,
                    set_capacity=set_capacity)

            return jax.jit(shard_map(
                f, mesh=self.mesh, in_specs=(rep2, rep1),
                out_specs=(state_spec, rep2, rep2)))

        return self._program(("init", pool_size, dedup, set_capacity),
                             build)(
            jnp.asarray(entry_ids, jnp.int32), _per_query(
                quota, entry_ids.shape[0]))

    def plan(self, state: BatchedSearchState, adjacency: Array, quota: Array,
             beam_width: Array, max_steps: Array,
             *, expand_width: int | Array = 1,
             expand_cap: int | None = None,
             level: Array | None = None,
             wave_dedup: bool = True,
             ) -> tuple[BatchedSearchState, Array, Array, Array]:
        """Sharded :func:`plan_step` (owner-only scatter + psum lookup for
        the bitmap backend; collective-free replicated membership for the
        sorted backend). ``expand_width`` may be a (B,) vector — it rides
        in as an operand, the program is keyed on the static lane cap.
        ``level`` (a (B,) vector) selects slabs of a level-stacked
        ``(L, N, R)`` fanout table (replicated, like the flat one) — the
        cover-tree descent's program shape."""
        from jax.sharding import PartitionSpec as _P

        from repro.launch.mesh import shard_map

        dedup = self._dedup_of(state)
        rep2, rep1, state_spec = self._specs(dedup)
        if expand_cap is None:
            expand_cap = _static_quota_bound(expand_width)
            if expand_cap is None:
                raise ValueError(
                    "a traced (B,) expand_width needs a static expand_cap")
        cap = max(int(expand_cap), 1)
        has_level = level is not None
        adj_spec = _P(*([None] * adjacency.ndim))

        def build():
            if has_level:
                def f(s, adj, q, bw, ms, ew, lev):
                    return plan_step(
                        s, adj, beam_width=bw, quota=q, max_steps=ms,
                        expand_width=ew, expand_cap=cap, shard=self.ctx,
                        level=lev, wave_dedup=wave_dedup)

                return jax.jit(shard_map(
                    f, mesh=self.mesh,
                    in_specs=(state_spec, adj_spec, rep1, rep1, rep1, rep1,
                              rep1),
                    out_specs=(state_spec, rep2, rep2, rep1)))

            def f(s, adj, q, bw, ms, ew):
                return plan_step(
                    s, adj, beam_width=bw, quota=q, max_steps=ms,
                    expand_width=ew, expand_cap=cap, shard=self.ctx,
                    wave_dedup=wave_dedup)

            return jax.jit(shard_map(
                f, mesh=self.mesh,
                in_specs=(state_spec, adj_spec, rep1, rep1, rep1, rep1),
                out_specs=(state_spec, rep2, rep2, rep1)))

        b = state.pool_ids.shape[0]
        key = ("plan", cap, dedup, has_level, wave_dedup, adjacency.ndim)
        operands = (
            state, adjacency.astype(jnp.int32), _per_query(quota, b),
            _per_query(beam_width, b), _per_query(max_steps, b),
            _per_query(expand_width, b))
        if has_level:
            operands = (*operands, _per_query(level, b))
        return self._program(key, build)(*operands)

    def reopen(self, state: BatchedSearchState,
               rows: Array) -> BatchedSearchState:
        """Sharded :func:`reset_expanded` — re-open the masked rows'
        frontiers between cover-tree levels (pools and dedup untouched)."""
        from repro.launch.mesh import shard_map

        dedup = self._dedup_of(state)
        _, rep1, state_spec = self._specs(dedup)

        def build():
            def f(s, r):
                return reset_expanded(s, r)

            return jax.jit(shard_map(
                f, mesh=self.mesh, in_specs=(state_spec, rep1),
                out_specs=state_spec))

        b = state.pool_ids.shape[0]
        return self._program(("reopen", dedup), build)(
            state, jnp.broadcast_to(jnp.asarray(rows, bool), (b,)))

    def commit(self, state: BatchedSearchState, safe: Array, keep: Array,
               dists: Array) -> BatchedSearchState:
        """Sharded :func:`commit_scores` (replicated merge, dedup untouched)."""
        from repro.launch.mesh import shard_map

        dedup = self._dedup_of(state)
        rep2, _, state_spec = self._specs(dedup)
        be = self.backend

        def build():
            def f(s, sf, kp, d):
                return commit_scores(s, sf, kp, d, backend=be)

            return jax.jit(shard_map(
                f, mesh=self.mesh,
                in_specs=(state_spec, rep2, rep2, rep2),
                out_specs=state_spec))

        return self._program(("commit", dedup, be), build)(
            state, safe, keep, jnp.asarray(dists, jnp.float32))

    def admit(self, state: BatchedSearchState, reset: Array,
              entry_ids: Array, quota: Array,
              ) -> tuple[BatchedSearchState, Array, Array]:
        """Sharded :func:`reset_slots`: recycle the ``reset`` rows of a
        resident state for newly admitted queries (the slot pool's
        admission step). Non-reset rows pass through bit-exactly; the
        returned entry wave commits as a no-op on them."""
        from repro.launch.mesh import shard_map

        dedup = self._dedup_of(state)
        rep2, rep1, state_spec = self._specs(dedup)

        def build():
            def f(s, rs, entries, q):
                return reset_slots(s, rs, entries, q, shard=self.ctx)

            return jax.jit(shard_map(
                f, mesh=self.mesh,
                in_specs=(state_spec, rep1, rep2, rep1),
                out_specs=(state_spec, rep2, rep2)))

        b = state.pool_ids.shape[0]
        return self._program(("admit", dedup), build)(
            state, jnp.asarray(reset, bool),
            jnp.asarray(entry_ids, jnp.int32), _per_query(quota, b))

    def active(self, state: BatchedSearchState, quota: Array,
               beam_width: Array, max_steps: Array) -> Array:
        """Replicated per-row :func:`active_mask` — the slot pool reads it
        every step to detect finished slots (occupied & ~active)."""
        from repro.launch.mesh import shard_map

        dedup = self._dedup_of(state)
        _, rep1, state_spec = self._specs(dedup)

        def build():
            def f(s, q, bw, ms):
                return active_mask(s, beam_width=bw, quota=q, max_steps=ms)

            return jax.jit(shard_map(
                f, mesh=self.mesh,
                in_specs=(state_spec, rep1, rep1, rep1), out_specs=rep1))

        b = state.pool_ids.shape[0]
        return self._program(("active_mask", dedup), build)(
            state, _per_query(quota, b), _per_query(beam_width, b),
            _per_query(max_steps, b))

    def active_any(self, state: BatchedSearchState, quota: Array,
                   beam_width: Array, max_steps: Array) -> bool:
        """Replicated ``active_mask(...).any()`` — the host loop condition."""
        from jax.sharding import PartitionSpec as _P

        from repro.launch.mesh import shard_map

        dedup = self._dedup_of(state)
        _, rep1, state_spec = self._specs(dedup)

        def build():
            def f(s, q, bw, ms):
                return active_mask(
                    s, beam_width=bw, quota=q, max_steps=ms).any()

            return jax.jit(shard_map(
                f, mesh=self.mesh,
                in_specs=(state_spec, rep1, rep1, rep1), out_specs=_P()))

        b = state.pool_ids.shape[0]
        return bool(self._program(("active", dedup), build)(
            state, _per_query(quota, b), _per_query(beam_width, b),
            _per_query(max_steps, b)))

    def scored_count(self, state: BatchedSearchState) -> Array:
        """(B,) distinct scored ids. Bitmap backend: psum of local popcounts
        — the partition invariant (no bit duplicated across shards, none
        lost). Sorted backend: the replicated set's unique count — the
        replication invariant (every device computes the same answer)."""
        from repro.distributed import collectives
        from repro.launch.mesh import shard_map

        dedup = self._dedup_of(state)
        _, rep1, state_spec = self._specs(dedup)

        def build():
            def f(s):
                if isinstance(s.scored, ScoredSet):
                    return collectives.member_count(
                        s.scored.ids, axis_name=self.axis_name)
                return collectives.bitmap_count(
                    s.scored, axis_name=self.axis_name)

            return jax.jit(shard_map(
                f, mesh=self.mesh, in_specs=(state_spec,), out_specs=rep1))

        return self._program(("count", dedup), build)(state)


def greedy_search(
    dist_fn: Callable[[Array], Array],
    adjacency: Array,
    entry_ids: Array,
    *,
    n_points: int,
    beam_width: int,
    pool_size: int | None = None,
    quota: int | Array = NO_QUOTA,
    max_steps: int | None = None,
    scored_init: Array | None = None,
    calls_init: Array | int = 0,
) -> SearchResult:
    """Single-query wrapper over the batched engine (B = 1).

    ``dist_fn`` maps (k,) int32 vertex ids -> (k,) f32 distances to the query
    (ids < 0 -> +inf). Semantics are unchanged from the historical per-query
    engine: expand-one-vertex steps, exact quota, scored-bitmap dedup.
    """

    def dist_fn_batch(_ctx, ids):
        # vmapped even at B=1 so the lowering (and hence fp association) is
        # identical to a real batch — parity is bit-exact, not just close.
        return jax.vmap(dist_fn)(ids)

    res = batched_greedy_search(
        dist_fn_batch,
        adjacency,
        None,
        entry_ids[None, :],
        n_points=n_points,
        beam_width=beam_width,
        pool_size=pool_size,
        quota=quota,
        max_steps=max_steps,
        scored_init=None if scored_init is None else scored_init[None, :],
        calls_init=calls_init,
    )
    return SearchResult(*(a[0] for a in res))


def greedy_search_batch(
    dist_fn_batch: Callable[[Array, Array], Array],
    adjacency: Array,
    query_ctx: Array,
    entry_ids: Array,
    **kw,
) -> SearchResult:
    """Batched search with a *per-query* distance function (legacy contract).

    ``dist_fn_batch(q_ctx, ids)`` scores (k,) ids against one query context;
    it is vmapped over the batch and fed to the batched engine.
    ``query_ctx``: (B, ...) per-query context; ``entry_ids``: (B, E) or (E,).
    """
    if entry_ids.ndim == 1:
        entry_ids = jnp.broadcast_to(
            entry_ids, (query_ctx.shape[0], entry_ids.shape[0])
        )
    return batched_greedy_search(
        jax.vmap(dist_fn_batch), adjacency, query_ctx, entry_ids, **kw
    )
