"""FROZEN pre-refactor per-query greedy search — parity oracle, not for use.

This is the retired single-query engine exactly as it shipped before the
batched refactor (one vertex expanded per ``while_loop`` iteration, stable
argsort pool merge). It exists for two reasons only:

* the parity tests assert the batched engine (``repro.core.beam``) is
  bit-exact against it — same pool ids, distances, ``n_calls`` — at
  ``expand_width=1``;
* ``benchmarks/bench_search_perf.py`` uses it as the "old" baseline when
  reporting the refactor's throughput gain.

Do not extend it and do not call it from production paths; new code goes
through ``repro.core.beam``.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

NO_QUOTA = jnp.iinfo(jnp.int32).max // 2


class SearchState(NamedTuple):
    pool_ids: Array  # (P,) int32, sorted by dist; -1 pad
    pool_dists: Array  # (P,) f32; +inf pad
    expanded: Array  # (P,) bool
    scored: Array  # (N,) bool bitmap — dedup + exact call counting
    n_calls: Array  # () int32
    step: Array  # () int32


class SearchResult(NamedTuple):
    pool_ids: Array
    pool_dists: Array
    scored: Array
    n_calls: Array
    n_steps: Array


def _merge_pool(
    pool_ids: Array,
    pool_dists: Array,
    expanded: Array,
    new_ids: Array,
    new_dists: Array,
) -> tuple[Array, Array, Array]:
    """Merge new scored candidates into the sorted pool, keep best P."""
    p = pool_ids.shape[0]
    ids = jnp.concatenate([pool_ids, new_ids])
    dists = jnp.concatenate([pool_dists, new_dists])
    exp = jnp.concatenate([expanded, jnp.zeros(new_ids.shape, dtype=bool)])
    order = jnp.argsort(dists, stable=True)
    return ids[order][:p], dists[order][:p], exp[order][:p]


def greedy_search(
    dist_fn: Callable[[Array], Array],
    adjacency: Array,
    entry_ids: Array,
    *,
    n_points: int,
    beam_width: int,
    pool_size: int | None = None,
    quota: int | Array = NO_QUOTA,
    max_steps: int | None = None,
    scored_init: Array | None = None,
    calls_init: Array | int = 0,
) -> SearchResult:
    """Greedy beam search over ``adjacency`` for a single query (frozen)."""
    adjacency = adjacency.astype(jnp.int32)
    n, r = adjacency.shape
    assert n == n_points
    L = beam_width
    P = pool_size or max(L, entry_ids.shape[0])
    P = max(P, L, entry_ids.shape[0])
    if max_steps is None:
        max_steps = 4 * L + 16
    quota = jnp.asarray(quota, jnp.int32)

    # --- score entries (respecting the quota) -----------------------------
    e = entry_ids.shape[0]
    entry_ids = entry_ids.astype(jnp.int32)
    # dedup entries positionally: an id equal to an earlier id becomes -1.
    dup = (entry_ids[:, None] == entry_ids[None, :]) & (
        jnp.arange(e)[:, None] > jnp.arange(e)[None, :]
    )
    entry_ids = jnp.where(dup.any(axis=1), -1, entry_ids)
    valid = entry_ids >= 0
    order_idx = jnp.cumsum(valid.astype(jnp.int32)) - 1  # call index per entry
    budget0 = quota - jnp.asarray(calls_init, jnp.int32)
    keep = valid & (order_idx < budget0)
    safe_entries = jnp.where(keep, entry_ids, -1)
    entry_dists = jnp.where(keep, dist_fn(safe_entries), jnp.inf)
    n_calls0 = jnp.asarray(calls_init, jnp.int32) + keep.sum(dtype=jnp.int32)

    scored0 = (
        jnp.zeros((n,), dtype=bool) if scored_init is None else scored_init
    )
    # scatter-OR (max): padding ids all alias index 0, so a plain set() races
    scored0 = scored0.at[jnp.maximum(safe_entries, 0)].max(keep)

    pool_ids = jnp.full((P,), -1, jnp.int32)
    pool_dists = jnp.full((P,), jnp.inf, jnp.float32)
    expanded = jnp.zeros((P,), dtype=bool)
    pool_ids, pool_dists, expanded = _merge_pool(
        pool_ids, pool_dists, expanded, safe_entries, entry_dists
    )

    state = SearchState(
        pool_ids, pool_dists, expanded, scored0, n_calls0, jnp.int32(0)
    )

    def frontier_open(s: SearchState) -> Array:
        frontier = (~s.expanded[:L]) & jnp.isfinite(s.pool_dists[:L])
        return frontier.any()

    def cond(s: SearchState) -> Array:
        return frontier_open(s) & (s.step < max_steps) & (s.n_calls < quota)

    def body(s: SearchState) -> SearchState:
        frontier = (~s.expanded[:L]) & jnp.isfinite(s.pool_dists[:L])
        # best unexpanded in the beam prefix (pool is sorted -> first open slot)
        idx = jnp.argmax(frontier)  # first True
        v = s.pool_ids[idx]
        expanded = s.expanded.at[idx].set(True)

        nbrs = adjacency[jnp.maximum(v, 0)]  # (R,)
        fresh = (nbrs >= 0) & ~s.scored[jnp.maximum(nbrs, 0)]
        # exact quota masking: only the first `remaining` fresh ids get scored
        call_idx = jnp.cumsum(fresh.astype(jnp.int32)) - 1
        remaining = quota - s.n_calls
        keep = fresh & (call_idx < remaining)
        safe = jnp.where(keep, nbrs, -1)
        d = jnp.where(keep, dist_fn(safe), jnp.inf)
        n_calls = s.n_calls + keep.sum(dtype=jnp.int32)
        scored = s.scored.at[jnp.maximum(safe, 0)].max(keep)

        pool_ids, pool_dists, expanded = _merge_pool(
            s.pool_ids, s.pool_dists, expanded, safe, d
        )
        return SearchState(
            pool_ids, pool_dists, expanded, scored, n_calls, s.step + 1
        )

    final = lax.while_loop(cond, body, state)
    return SearchResult(
        final.pool_ids, final.pool_dists, final.scored, final.n_calls, final.step
    )
