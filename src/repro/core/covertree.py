"""Cover Tree under the bi-metric framework (paper Appendix B), on the engine.

Algorithm 2 builds a cover tree with the *cheap* metric d and slack parameter
``T = C``; Algorithm 3 answers queries with the *expensive* metric D, counting
D evaluations (memoized per query — a vertex is paid for once even if it
appears at many levels, since C_i ⊆ C_{i-1}).

**Build** stays an offline, per-query NumPy recursion (greedy covers on the
proxy — :func:`build`), exactly the paper's deployment split. **Queries**
run on the shared batched engine: :func:`flatten` emits a device-resident
layout — a level-stacked child table ``(depth-1, N, R)`` (row ``p`` of slab
``j`` is ``{p} ∪ children_j(p)``, -1 padded; the slabs *partition* each
finer level because every finer point has exactly one parent) plus a raw-unit
per-level scale vector — that :func:`repro.core.beam.plan_step` indexes with
static shapes via its ``level=`` operand.

The descent itself is a corollary of the pools being sorted: the thresholds
``d_min + 2^i`` shrink monotonically down the levels, and a point that fails
one filter can never pass a later one, so Algorithm 3's candidate set Q_i is
*exactly* the prefix of the engine's pool within the previous level's radius
of the row minimum (:func:`repro.kernels.ops.frontier_count` measures it, and
it doubles as the wave's expand width). Each level is one wave driven through
``plan_step``/``commit_scores`` — ``reset_expanded`` re-opens the surviving
frontier between levels — and the memoized D-call set is exactly the engine's
dedup state (a :class:`repro.core.beam.ScoredSet` under a bounded quota), so
cover-tree queries inherit the batched expensive-tower drain, ``shards=``
mesh execution (:class:`repro.core.beam.ShardedStepper` bookkeeping with
caller-side scoring) and every ``backend=`` kernel route for free. Large
frontiers are planned in fixed-width chunks with *deferred* commits (commits
mid-level would let finer points displace true frontier members from the
prefix); on a single device the whole level fuses into one jitted
``lax.scan`` program.

:func:`search` is the frozen per-query NumPy oracle: at matched ε and an
unbounded (or un-hit) quota the batched drive returns the same neighbors and
bit-exact D-call memoization counts (under truncation the *counts* still
match — both admit exactly ``quota`` calls — but the admitted id sets may
differ by admission order). ``tests/test_covertree.py`` pins the grid.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import beam
from repro.kernels import backend as kernel_backend
from repro.kernels import ops

Array = jax.Array
DistToMany = Callable[[np.ndarray], np.ndarray]  # ids -> D(q, ids)


@dataclasses.dataclass
class CoverTree:
    levels: list[np.ndarray]  # levels[j] = ids in cover C_{i_j}; j=0 is root level
    children: list[dict[int, np.ndarray]]  # children[j][p] = ids in next level covered by p
    level_scales: list[float]  # 2^i (scaled d units) per level
    scale: float  # multiplier applied to raw distances
    T: float  # the paper's T (set to C at build time)
    n: int

    @property
    def depth(self) -> int:
        return len(self.levels)


def build(
    x: np.ndarray,
    *,
    T: float = 1.0,
    metric: str = "l2",
    seed: int = 0,
    max_levels: int = 64,
) -> CoverTree:
    """Algorithm 2: nested greedy covers C_i (2^i/T-covers of C_{i-1}), built on d."""
    assert metric == "l2", "cover tree reference implementation uses l2"
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    x = np.asarray(x, np.float64)

    def dist(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return np.sqrt(np.maximum(((a[:, None, :] - b[None, :, :]) ** 2).sum(-1), 0))

    # Scale so all pairwise distances are > 1 (WLOG step of Algorithm 2).
    # Estimate the closest-pair distance from a sample (exact for small n).
    if n <= 4096:
        dmat = dist(x, x)
    else:
        idx = rng.choice(n, size=4096, replace=False)
        dmat = dist(x[idx], x[idx])
    np.fill_diagonal(dmat, np.inf)
    dmin = float(dmat.min())
    dmax = float(np.where(np.isfinite(dmat), dmat, 0).max())
    dmin = max(dmin, 1e-12)
    scale = 1.001 / dmin

    def sdist_rows(p: int, ids: np.ndarray) -> np.ndarray:
        return np.sqrt(np.maximum(((x[p][None] - x[ids]) ** 2).sum(-1), 0)) * scale

    # levels bottom-up: C_0 = all points; C_i is a 2^i/T cover of C_{i-1}.
    covers = [np.arange(n, dtype=np.int64)]
    parent_maps: list[dict[int, int]] = []  # parent of each member of C_{i-1} in C_i
    i = 0
    while len(covers[-1]) > 1 and i < max_levels:
        i += 1
        r = (2.0**i) / T
        prev = covers[-1]
        remaining = prev.copy()
        rng.shuffle(remaining)
        members: list[int] = []
        parent: dict[int, int] = {}
        rem_mask = np.ones(len(prev), bool)
        pos = {int(v): j for j, v in enumerate(prev)}
        for v in remaining:
            j = pos[int(v)]
            if not rem_mask[j]:
                continue
            members.append(int(v))
            alive = prev[rem_mask]
            d_va = sdist_rows(int(v), alive)
            covered = alive[d_va <= r]
            for c in covered:
                parent[int(c)] = int(v)
                rem_mask[pos[int(c)]] = False
        covers.append(np.asarray(sorted(members), np.int64))
        parent_maps.append(parent)

    # top-down ordering for the query recursion
    covers = covers[::-1]
    parent_maps = parent_maps[::-1]
    top_i = len(covers) - 1
    children: list[dict[int, np.ndarray]] = []
    for j in range(len(covers) - 1):
        pm = parent_maps[j]
        ch: dict[int, list[int]] = {int(p): [] for p in covers[j]}
        for c, p in pm.items():
            ch[int(p)].append(int(c))
        children.append({p: np.asarray(v, np.int64) for p, v in ch.items()})
    level_scales = [2.0 ** (top_i - j) for j in range(len(covers))]
    return CoverTree(
        levels=covers,
        children=children,
        level_scales=level_scales,
        scale=scale,
        T=T,
        n=n,
    )


def search(
    tree: CoverTree,
    expensive_fn: DistToMany,
    *,
    eps: float = 0.5,
    k: int = 10,
    quota: int | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Algorithm 3 with metric D. Returns (top-k ids, D dists, n_D_calls).

    ``expensive_fn(ids)`` returns *raw* D distances; thresholds are applied in
    the scaled units used at build time (Lemma B.4 alignment).
    """
    memo: dict[int, float] = {}
    calls = 0

    def D(ids: np.ndarray) -> np.ndarray:
        nonlocal calls
        new = [int(i) for i in ids if int(i) not in memo]
        if new:
            if quota is not None and calls + len(new) > quota:
                new = new[: max(0, quota - calls)]
            if new:
                vals = np.asarray(expensive_fn(np.asarray(new, np.int64)), np.float64)
                for i, v in zip(new, vals * tree.scale):
                    memo[int(i)] = float(v)
                calls += len(new)
        return np.asarray([memo.get(int(i), np.inf) for i in ids], np.float64)

    Q_i = tree.levels[0]
    _ = D(Q_i)
    for j in range(len(tree.levels) - 1):
        two_i = tree.level_scales[j]
        ch = tree.children[j]
        q_next = set()
        for p in Q_i:
            q_next.update(ch.get(int(p), np.empty(0, np.int64)).tolist())
            q_next.add(int(p))  # self-child: C_i ⊆ C_{i-1}
        Q = np.asarray(sorted(q_next), np.int64)
        dq = D(Q)
        keep = dq <= dq.min() + two_i
        Q_i = Q[keep]
        if dq[keep].min() >= two_i * (1.0 + 1.0 / eps):
            break
        if quota is not None and calls >= quota:
            break

    scored = np.asarray(sorted(memo), np.int64)
    vals = np.asarray([memo[int(i)] for i in scored])
    order = np.argsort(vals, kind="stable")[:k]
    return scored[order], vals[order] / tree.scale, calls


# --------------------------------------------------------------------------
# Flattened device layout + the batched engine drive (Algorithm 3 as waves)
# --------------------------------------------------------------------------

class FlatCoverTree(NamedTuple):
    """Device-indexable cover tree: level-stacked child slabs + raw radii.

    ``children[j, p]`` lists ``{p} ∪ children_j(p)`` (ascending, -1 padded)
    for every ``p ∈ levels[j]``; rows of points absent from level ``j`` are
    all -1 and unreachable (the descent only expands pool members, which are
    memoized level members). ``radii[j]`` is ``level_scales[j] / scale`` —
    the level-j filter radius in *raw* D units, so the engine's f32 pools
    compare against it directly while the NumPy oracle works in scaled f64
    (same inequality, one f64 division apart).
    """
    children: np.ndarray   # (depth-1, N, R) int32, -1 padded
    radii: np.ndarray      # (depth-1,) float64, raw distance units
    root_ids: np.ndarray   # (E0,) int32 — the top cover, ascending
    scale: float
    T: float
    n: int

    @property
    def depth(self) -> int:
        return self.children.shape[0] + 1

    @property
    def fanout(self) -> int:
        return self.children.shape[2]


class CoverSearchResult(NamedTuple):
    ids: Array      # (B, k) int32, -1 padded past the scored count
    dists: Array    # (B, k) f32 raw D, +inf on padding
    n_calls: Array  # (B,) int32 memoized D evaluations


def flatten(tree: CoverTree) -> FlatCoverTree:
    """Stack the per-level child dicts into the engine's fixed-shape table."""
    l1 = tree.depth - 1
    n = tree.n
    r_max = 1
    for ch in tree.children:
        for p, kids in ch.items():
            r_max = max(r_max, len(np.union1d(kids, [p])))
    children = np.full((l1, n, max(r_max, 1)), -1, np.int32)
    for j, ch in enumerate(tree.children):
        for p, kids in ch.items():
            row = np.union1d(kids, [p]).astype(np.int32)  # ascending, self in
            children[j, p, : len(row)] = row
    radii = np.asarray(
        [s / tree.scale for s in tree.level_scales[:l1]], np.float64)
    return FlatCoverTree(
        children=children,
        radii=radii,
        root_ids=np.asarray(tree.levels[0], np.int32),
        scale=tree.scale,
        T=tree.T,
        n=n,
    )


def wave_chunk(fanout: int, *, lane_budget: int = 4096) -> int:
    """Frontier chunk width: the largest power of two (≤ 64) whose
    ``chunk × fanout`` wave stays within the lane budget — bounds the
    gather→score working set no matter how wide a level's frontier gets."""
    c = 1
    while c * 2 * fanout <= lane_budget and c * 2 <= 64:
        c *= 2
    return c


_init_j = functools.partial(
    jax.jit, static_argnames=("n_points", "pool_size", "dedup", "set_capacity")
)(beam.init_state)
_commit_j = functools.partial(
    jax.jit, static_argnames=("backend",))(beam.commit_scores)
_reopen_j = jax.jit(beam.reset_expanded)
_count_j = jax.jit(ops.frontier_count)


@functools.partial(jax.jit, static_argnames=("expand_cap",))
def _plan_j(state, children, level, quota, beam_width, max_steps, ew, *,
            expand_cap):
    return beam.plan_step(
        state, children, beam_width=beam_width, quota=quota,
        max_steps=max_steps, expand_width=ew, expand_cap=expand_cap,
        level=level, wave_dedup=False)


@functools.partial(
    jax.jit, static_argnames=("n_chunks", "chunk", "dist_fn", "backend"))
def _level_fused(state, children, level, quota, beam_width, max_steps,
                 ew_target, q_ctx, *, n_chunks, chunk, dist_fn, backend):
    """One whole level as a single program: scan the chunked plans first
    (commits are deferred — a mid-level commit would let finer points
    displace true frontier members from the sorted prefix), then scan the
    score→commit over the recorded waves."""

    def plan_one(s, i):
        ew = jnp.clip(ew_target - i * chunk, 0, chunk)
        s, safe, keep, _ = beam.plan_step(
            s, children, beam_width=beam_width, quota=quota,
            max_steps=max_steps, expand_width=ew, expand_cap=chunk,
            level=level, wave_dedup=False)
        return s, (safe, keep)

    state, waves = jax.lax.scan(plan_one, state, jnp.arange(n_chunks))

    def commit_one(s, wave):
        safe, keep = wave
        d = dist_fn(q_ctx, safe)
        return beam.commit_scores(s, safe, keep, d, backend=backend), None

    state, _ = jax.lax.scan(commit_one, state, waves)
    return state


def search_batched(
    flat: FlatCoverTree,
    dist_fn_batch: Callable[[Array, Array], Array],
    query_ctx: Array,
    *,
    eps: float = 0.5,
    k: int = 10,
    quota: int | Array | None = None,
    pool_size: int | None = None,
    backend: str | kernel_backend.Backend | None = None,
    dedup: str = "auto",
    chunk: int | None = None,
    stepper: beam.ShardedStepper | None = None,
    fuse_levels: bool | None = None,
) -> CoverSearchResult:
    """Algorithm 3 for a whole query batch through ``plan_step`` waves.

    ``dist_fn_batch(query_ctx, ids (B, K)) -> (B, K)`` raw D distances with
    the engine's masking contract (ids < 0 → +inf); ``query_ctx`` is (B, …).
    Per level: :func:`repro.kernels.ops.frontier_count` sizes each row's
    wave (the pool prefix within the previous level's radius),
    ``reset_expanded`` re-opens the surviving centers, and the level's
    fanout is planned in ``chunk``-wide waves against the stacked child
    table (commits deferred to the end of the level). Rows stop
    independently — the ε-criterion (host f64, like the oracle) or quota
    exhaustion (exact wave masking in ``plan_step``) just freeze a row
    while its batch-mates descend.

    ``fuse_levels`` (default: on, unless a ``stepper`` drives a mesh) runs
    each level as one jitted ``lax.scan`` program — requires
    ``dist_fn_batch`` to be traceable (``beam.fused_dist_fn`` is); pass
    False for host-side metrics (the serving engine's tower drain drives
    the chunks itself). With ``stepper`` the bookkeeping runs inside the
    corpus mesh; scoring stays with the caller, exactly the serving
    stage-2 shape.
    """
    q_ctx = jnp.asarray(query_ctx)
    b = q_ctx.shape[0]
    n = flat.n
    e0 = int(flat.root_ids.shape[0])
    if fuse_levels is None:
        fuse_levels = stepper is None
    be = kernel_backend.resolve_backend(
        backend, _caller="covertree.search_batched")

    quota_arr = beam.NO_QUOTA if quota is None else quota
    qmax = beam._static_quota_bound(quota_arr)
    if qmax is None:
        raise ValueError("covertree needs a concrete (untraced) quota")
    if pool_size is None:
        pool_size = max(k, e0, min(n, qmax))
    if chunk is None:
        chunk = wave_chunk(flat.fanout)
    chunk = max(1, min(chunk, pool_size))  # plan selects E slots from pool P
    dedup, set_cap = beam.resolve_dedup(
        dedup, None, quota_arr, n, drive="host")

    quota_j = beam._per_query(quota_arr, b)
    beam_j = beam._per_query(pool_size, b)     # the whole pool is the prefix
    steps_j = beam._per_query(beam.NO_QUOTA, b)
    entries = jnp.broadcast_to(
        jnp.asarray(flat.root_ids, jnp.int32)[None, :], (b, e0))

    if stepper is not None:
        state, safe, keep = stepper.init(
            entries, quota_j, pool_size=pool_size, dedup=dedup,
            set_capacity=set_cap)
    else:
        state, safe, keep = _init_j(
            entries, n_points=n, pool_size=pool_size, quota=quota_j,
            dedup=dedup, set_capacity=set_cap)

    def _commit(s, sf, kp, d):
        if stepper is not None:
            return stepper.commit(s, sf, kp, d)
        return _commit_j(s, sf, kp, d, backend=be)

    state = _commit(state, safe, keep, dist_fn_batch(q_ctx, safe))

    children = jnp.asarray(flat.children)
    radii = np.asarray(flat.radii, np.float64)
    alive = np.ones(b, bool)
    for t in range(flat.depth - 1):
        radius = np.inf if t == 0 else float(radii[t - 1])
        ew_t = np.asarray(_count_j(state.pool_dists, jnp.float32(radius)))
        ew_t = np.where(alive, ew_t, 0).astype(np.int32)
        if not ew_t.any():
            break
        if stepper is not None:
            state = stepper.reopen(state, jnp.asarray(alive))
        else:
            state = _reopen_j(state, jnp.asarray(alive))
        lev = jnp.full((b,), t, jnp.int32)
        if fuse_levels:
            n_chunks = max(1, -(-int(ew_t.max()) // chunk))
            n_chunks = 1 << (n_chunks - 1).bit_length()  # pow2 retrace bound
            state = _level_fused(
                state, children, lev, quota_j, beam_j, steps_j,
                jnp.asarray(ew_t), q_ctx, n_chunks=n_chunks, chunk=chunk,
                dist_fn=dist_fn_batch, backend=be)
        else:
            planned = []
            remaining = ew_t.copy()
            while remaining.max() > 0:
                ew = np.minimum(remaining, chunk).astype(np.int32)
                if stepper is not None:
                    state, safe, keep, _ = stepper.plan(
                        state, children, quota_j, beam_j, steps_j,
                        expand_width=jnp.asarray(ew), expand_cap=chunk,
                        level=lev, wave_dedup=False)
                else:
                    state, safe, keep, _ = _plan_j(
                        state, children, lev, quota_j, beam_j, steps_j,
                        jnp.asarray(ew), expand_cap=chunk)
                planned.append((safe, keep))
                remaining -= ew
            for safe, keep in planned:
                state = _commit(state, safe, keep, dist_fn_batch(q_ctx, safe))
        dmin = np.asarray(state.pool_dists[:, 0], np.float64)
        alive &= dmin < radii[t] * (1.0 + 1.0 / eps)

    return CoverSearchResult(
        ids=state.pool_ids[:, :k],
        dists=state.pool_dists[:, :k],
        n_calls=state.n_calls,
    )


def search_corpus(
    flat: FlatCoverTree,
    corpus: Array,
    queries: Array,
    *,
    metric: str = "l2",
    eps: float = 0.5,
    k: int = 10,
    quota: int | Array | None = None,
    shards: int = 1,
    mesh=None,
    backend: str | kernel_backend.Backend | None = None,
    dedup: str = "auto",
    chunk: int | None = None,
    pool_size: int | None = None,
) -> CoverSearchResult:
    """:func:`search_batched` against an embedding corpus under D.

    Builds the backend-dispatched fused gather→score once (corpus-norm
    cache included for the matmul routes) and, at ``shards > 1``, a
    :class:`repro.core.beam.ShardedStepper` so the descent's bookkeeping
    runs inside the corpus mesh (scoring stays on the fused kernel — the
    stage-2 drive shape, bit-exact vs one device).
    """
    be = kernel_backend.resolve_backend(
        backend, _caller="covertree.search_corpus")
    if not isinstance(corpus, kernel_backend.CorpusView):
        corpus = jnp.asarray(corpus)  # fused levels trace the gather
    fn = beam.fused_dist_fn(corpus, metric, backend=be)
    stepper = None
    if shards > 1:
        stepper = beam.ShardedStepper(
            shards=shards, n_points=flat.n, mesh=mesh, backend=be)
    return search_batched(
        flat, fn, jnp.asarray(queries), eps=eps, k=k, quota=quota,
        pool_size=pool_size, backend=be, dedup=dedup, chunk=chunk,
        stepper=stepper)
