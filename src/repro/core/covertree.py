"""Cover Tree under the bi-metric framework (paper Appendix B).

Algorithm 2 builds a cover tree with the *cheap* metric d and slack parameter
``T = C``; Algorithm 3 answers queries with the *expensive* metric D, counting
D evaluations (memoized per query — a vertex is paid for once even if it
appears at many levels, since C_i ⊆ C_{i-1}).

Index construction is an offline, data-dependent recursion (greedy covers),
so it runs in NumPy; the per-level distance evaluations during queries are
delegated to a user distance function, which in the framework is backed by a
jitted JAX scorer. This matches the paper's deployment: the tree is built
once on the proxy, queries stream against the expensive model.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

DistToMany = Callable[[np.ndarray], np.ndarray]  # ids -> D(q, ids)


@dataclasses.dataclass
class CoverTree:
    levels: list[np.ndarray]  # levels[j] = ids in cover C_{i_j}; j=0 is root level
    children: list[dict[int, np.ndarray]]  # children[j][p] = ids in next level covered by p
    level_scales: list[float]  # 2^i (scaled d units) per level
    scale: float  # multiplier applied to raw distances
    T: float  # the paper's T (set to C at build time)
    n: int

    @property
    def depth(self) -> int:
        return len(self.levels)


def build(
    x: np.ndarray,
    *,
    T: float = 1.0,
    metric: str = "l2",
    seed: int = 0,
    max_levels: int = 64,
) -> CoverTree:
    """Algorithm 2: nested greedy covers C_i (2^i/T-covers of C_{i-1}), built on d."""
    assert metric == "l2", "cover tree reference implementation uses l2"
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    x = np.asarray(x, np.float64)

    def dist(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return np.sqrt(np.maximum(((a[:, None, :] - b[None, :, :]) ** 2).sum(-1), 0))

    # Scale so all pairwise distances are > 1 (WLOG step of Algorithm 2).
    # Estimate the closest-pair distance from a sample (exact for small n).
    if n <= 4096:
        dmat = dist(x, x)
    else:
        idx = rng.choice(n, size=4096, replace=False)
        dmat = dist(x[idx], x[idx])
    np.fill_diagonal(dmat, np.inf)
    dmin = float(dmat.min())
    dmax = float(np.where(np.isfinite(dmat), dmat, 0).max())
    dmin = max(dmin, 1e-12)
    scale = 1.001 / dmin

    def sdist_rows(p: int, ids: np.ndarray) -> np.ndarray:
        return np.sqrt(np.maximum(((x[p][None] - x[ids]) ** 2).sum(-1), 0)) * scale

    # levels bottom-up: C_0 = all points; C_i is a 2^i/T cover of C_{i-1}.
    covers = [np.arange(n, dtype=np.int64)]
    parent_maps: list[dict[int, int]] = []  # parent of each member of C_{i-1} in C_i
    i = 0
    while len(covers[-1]) > 1 and i < max_levels:
        i += 1
        r = (2.0**i) / T
        prev = covers[-1]
        remaining = prev.copy()
        rng.shuffle(remaining)
        members: list[int] = []
        parent: dict[int, int] = {}
        rem_mask = np.ones(len(prev), bool)
        pos = {int(v): j for j, v in enumerate(prev)}
        for v in remaining:
            j = pos[int(v)]
            if not rem_mask[j]:
                continue
            members.append(int(v))
            alive = prev[rem_mask]
            d_va = sdist_rows(int(v), alive)
            covered = alive[d_va <= r]
            for c in covered:
                parent[int(c)] = int(v)
                rem_mask[pos[int(c)]] = False
        covers.append(np.asarray(sorted(members), np.int64))
        parent_maps.append(parent)

    # top-down ordering for the query recursion
    covers = covers[::-1]
    parent_maps = parent_maps[::-1]
    top_i = len(covers) - 1
    children: list[dict[int, np.ndarray]] = []
    for j in range(len(covers) - 1):
        pm = parent_maps[j]
        ch: dict[int, list[int]] = {int(p): [] for p in covers[j]}
        for c, p in pm.items():
            ch[int(p)].append(int(c))
        children.append({p: np.asarray(v, np.int64) for p, v in ch.items()})
    level_scales = [2.0 ** (top_i - j) for j in range(len(covers))]
    return CoverTree(
        levels=covers,
        children=children,
        level_scales=level_scales,
        scale=scale,
        T=T,
        n=n,
    )


def search(
    tree: CoverTree,
    expensive_fn: DistToMany,
    *,
    eps: float = 0.5,
    k: int = 10,
    quota: int | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Algorithm 3 with metric D. Returns (top-k ids, D dists, n_D_calls).

    ``expensive_fn(ids)`` returns *raw* D distances; thresholds are applied in
    the scaled units used at build time (Lemma B.4 alignment).
    """
    memo: dict[int, float] = {}
    calls = 0

    def D(ids: np.ndarray) -> np.ndarray:
        nonlocal calls
        new = [int(i) for i in ids if int(i) not in memo]
        if new:
            if quota is not None and calls + len(new) > quota:
                new = new[: max(0, quota - calls)]
            if new:
                vals = np.asarray(expensive_fn(np.asarray(new, np.int64)), np.float64)
                for i, v in zip(new, vals * tree.scale):
                    memo[int(i)] = float(v)
                calls += len(new)
        return np.asarray([memo.get(int(i), np.inf) for i in ids], np.float64)

    Q_i = tree.levels[0]
    _ = D(Q_i)
    for j in range(len(tree.levels) - 1):
        two_i = tree.level_scales[j]
        ch = tree.children[j]
        q_next = set()
        for p in Q_i:
            q_next.update(ch.get(int(p), np.empty(0, np.int64)).tolist())
            q_next.add(int(p))  # self-child: C_i ⊆ C_{i-1}
        Q = np.asarray(sorted(q_next), np.int64)
        dq = D(Q)
        keep = dq <= dq.min() + two_i
        Q_i = Q[keep]
        if dq[keep].min() >= two_i * (1.0 + 1.0 / eps):
            break
        if quota is not None and calls >= quota:
            break

    scored = np.asarray(sorted(memo), np.int64)
    vals = np.asarray([memo[int(i)] for i in scored])
    order = np.argsort(vals, kind="stable")[:k]
    return scored[order], vals[order] / tree.scale, calls
