"""Distance functions for the bi-metric framework.

A *metric source* in this framework is anything that can score (query, doc-id)
pairs. The two canonical instantiations are

* ``EmbeddingMetric`` — distances induced by a fixed embedding matrix (the
  paper's setting: both d and D are Euclidean distances between model
  embeddings), and
* model-backed metrics (see ``repro.serve.engine``) where scoring a pair runs
  a forward pass of an expensive tower.

All functions are pure jnp and jit/vmap-friendly.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

VALID_METRICS = ("l2", "sqeuclidean", "ip", "cosine")


def _check(metric: str) -> None:
    if metric not in VALID_METRICS:
        raise ValueError(f"metric must be one of {VALID_METRICS}, got {metric!r}")


def pairwise(x: Array, y: Array, metric: str = "l2") -> Array:
    """Pairwise dissimilarity between rows of ``x`` (n, dim) and ``y`` (m, dim).

    Returns an (n, m) array. For "ip"/"cosine" we return a *dissimilarity*
    (negated / one-minus) so that smaller is always better.
    """
    _check(metric)
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if metric in ("l2", "sqeuclidean"):
        # ||x-y||^2 = ||x||^2 + ||y||^2 - 2 x.y  — one matmul, MXU friendly.
        x2 = jnp.sum(x * x, axis=-1, keepdims=True)
        y2 = jnp.sum(y * y, axis=-1, keepdims=True)
        sq = x2 + y2.T - 2.0 * (x @ y.T)
        sq = jnp.maximum(sq, 0.0)
        return sq if metric == "sqeuclidean" else jnp.sqrt(sq)
    if metric == "ip":
        return -(x @ y.T)
    # cosine
    xn = x * jax.lax.rsqrt(jnp.sum(x * x, -1, keepdims=True) + 1e-12)
    yn = y * jax.lax.rsqrt(jnp.sum(y * y, -1, keepdims=True) + 1e-12)
    return 1.0 - xn @ yn.T


def point_to_points(q: Array, xs: Array, metric: str = "l2") -> Array:
    """Distance from one query (dim,) to rows of ``xs`` (m, dim) -> (m,)."""
    return pairwise(q[None, :], xs, metric)[0]


class EmbeddingMetric:
    """A dissimilarity function backed by a fixed embedding matrix.

    ``dists(q_emb, ids)`` gathers corpus rows by id and scores them against a
    query embedding. This is the plug-in point for both the cheap proxy d and
    the expensive ground truth D in benchmarks (where both are precomputed,
    exactly as in the paper's evaluation, with D *calls counted*).
    """

    def __init__(self, embeddings: Array, metric: str = "l2"):
        _check(metric)
        self.embeddings = embeddings
        self.metric = metric

    @property
    def n(self) -> int:
        return self.embeddings.shape[0]

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]

    def embed_query(self, q: Array) -> Array:
        return q  # already an embedding in the precomputed setting

    def dists(self, q_emb: Array, ids: Array) -> Array:
        """(dim,), (k,) int -> (k,) distances. Invalid ids (<0) -> +inf.

        Computed in gather-then-reduce form (not the matmul expansion of
        ``pairwise``): elementwise reductions are batch-size invariant under
        jit, which the batched search engine relies on for bit-exact parity
        between batched and single-query runs, and the formulation matches
        the fused ``repro.kernels`` gather→score kernel exactly.
        """
        valid = ids >= 0
        rows = self.embeddings[jnp.maximum(ids, 0)].astype(jnp.float32)
        q = q_emb.astype(jnp.float32)
        if self.metric in ("l2", "sqeuclidean"):
            diff = rows - q[None, :]
            d = jnp.sum(diff * diff, axis=-1)
            if self.metric == "l2":
                d = jnp.sqrt(d)
        elif self.metric == "ip":
            d = -jnp.sum(rows * q[None, :], axis=-1)
        else:  # cosine
            qn = jax.lax.rsqrt(jnp.sum(q * q) + 1e-12)
            rn = jax.lax.rsqrt(jnp.sum(rows * rows, axis=-1) + 1e-12)
            d = 1.0 - jnp.sum(rows * q[None, :], axis=-1) * qn * rn
        return jnp.where(valid, d, jnp.inf)

    def dists_batch(self, q_embs: Array, ids: Array) -> Array:
        """(B, dim), (B, k) -> (B, k)."""
        return jax.vmap(self.dists)(q_embs, ids)

    def brute_force(self, q_embs: Array, k: int) -> tuple[Array, Array]:
        """Exact top-k ids/dists for each query row. (B, dim) -> (B, k) x2."""
        d = pairwise(q_embs, self.embeddings, self.metric)
        dists, ids = jax.lax.top_k(-d, k)
        return ids, -dists


def measure_capproximation(d_dists: Array, D_dists: Array) -> tuple[float, float]:
    """Empirical C for Definition 2.1 after optimal rescaling of d.

    Returns (scale, C): with d' = scale * d we have d' <= D <= C * d' for all
    sampled pairs (up to numerical floor). The paper's Eq. (1) is scale
    invariant in this sense; we report the tightest C.
    """
    eps = 1e-9
    ratio = D_dists / jnp.maximum(d_dists, eps)
    lo = jnp.min(ratio)  # need scale <= lo so that d' <= D
    hi = jnp.max(ratio)
    scale = float(lo)
    c = float(hi / jnp.maximum(lo, eps))
    return scale, c


def dist_fn_from_embeddings(
    embeddings: Array, metric: str = "l2"
) -> Callable[[Array, Array], Array]:
    """Returns dist(q_emb, ids) -> dists closure (for functional call sites)."""
    em = EmbeddingMetric(embeddings, metric)
    return em.dists
