"""Core bi-metric similarity-search library (the paper's contribution)."""
from repro.core import beam, bimetric, covertree, distances, metrics, vamana  # noqa: F401
