"""Distributed bi-metric search: scatter-gather over corpus shards.

Production ANN layout (what DiskANN/SPANN-scale deployments do):

* the corpus is split into S shards along the ``model`` mesh axis; each shard
  holds its own Vamana sub-index built **only with the proxy metric d**
  (shard-local builds are embarrassingly parallel — a net of a shard is a net
  of the union, so Theorem 1.1 applies per shard);
* queries are data-parallel along the ``data`` (and ``pod``) axes and
  replicated across ``model``;
* every device runs the two-stage bi-metric search on its local sub-index
  with a per-shard quota slice Q/S, then the per-shard top-k (tiny: k ids +
  dists) are all-gathered across ``model`` and merge-sorted into a global
  top-k by D. Total expensive calls = psum of the exact per-shard counters.

This file contains the shard_map program; mesh construction lives in
``repro.launch.mesh``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import distances
from repro.core.bimetric import bimetric_search_single
from repro.core.vamana import VamanaConfig, VamanaIndex
from repro.distributed import collectives

Array = jax.Array


class ShardedIndex(NamedTuple):
    """Stacked per-shard sub-indices. Leading axis = shard (on mesh axis 'model')."""

    adjacency: Array  # (S, n_local, R)
    medoid: Array  # (S,)
    emb_cheap: Array  # (S, n_local, dim_d)
    emb_expensive: Array  # (S, n_local, dim_D)  (precomputed-D evaluation mode)
    config: VamanaConfig


def build_sharded(
    emb_cheap: Array,
    emb_expensive: Array,
    n_shards: int,
    cfg: VamanaConfig,
) -> ShardedIndex:
    """Split the corpus round-robin-contiguously and build per-shard graphs with d."""
    from repro.core import vamana

    n = emb_cheap.shape[0]
    assert n % n_shards == 0, "pad the corpus to a multiple of the shard count"
    nl = n // n_shards
    adj, med = [], []
    for s in range(n_shards):
        idx = vamana.build(emb_cheap[s * nl : (s + 1) * nl], cfg)
        adj.append(idx.adjacency)
        med.append(idx.medoid)
    return ShardedIndex(
        adjacency=jnp.stack(adj),
        medoid=jnp.stack(med),
        emb_cheap=emb_cheap.reshape(n_shards, nl, -1),
        emb_expensive=emb_expensive.reshape(n_shards, nl, -1),
        config=cfg,
    )


def _local_search(
    adjacency, medoid, emb_d, emb_D, q_d, q_D, *, quota, k, n_seeds, cfg
):
    """Bi-metric search on one shard for a block of queries."""
    n_local = emb_d.shape[0]
    em_d = distances.EmbeddingMetric(emb_d, cfg.metric)
    em_D = distances.EmbeddingMetric(emb_D, cfg.metric)
    index = VamanaIndex(adjacency=adjacency, medoid=medoid, config=cfg)

    def one(qd, qD):
        ids, dd, _, n_calls = bimetric_search_single(
            lambda i: em_d.dists(qd, i),
            lambda i: em_D.dists(qD, i),
            index,
            n_points=n_local,
            quota=quota,
            k=k,
            n_seeds=n_seeds,
        )
        return ids, dd, n_calls

    return jax.vmap(one)(q_d, q_D)


def sharded_bimetric_search(
    mesh: Mesh,
    index: ShardedIndex,
    q_cheap: Array,
    q_expensive: Array,
    *,
    quota: int,
    k: int = 10,
    data_axes: tuple[str, ...] = ("data",),
    model_axis: str = "model",
):
    """Scatter-gather bi-metric search across the mesh.

    Returns (global ids (B, k), D dists (B, k), total D calls (B,)).
    """
    s = index.adjacency.shape[0]
    n_local = index.adjacency.shape[1]
    per_shard_quota = max(k, quota // s)
    n_seeds = max(1, per_shard_quota // 2)
    cfg = index.config

    def program(adj, med, ed, eD, qd, qD):
        # shard_map slices the leading shard dim to size 1 on this device
        adj, med = adj[0], med[0]
        ed, eD = ed[0], eD[0]
        ids, dd, n_calls = _local_search(
            adj, med, ed, eD, qd, qD,
            quota=per_shard_quota, k=k, n_seeds=n_seeds, cfg=cfg,
        )
        shard = jax.lax.axis_index(model_axis)
        gids = jnp.where(ids >= 0, ids + shard * n_local, -1)
        # per-shard top-k cut before the all-gather: merge traffic is
        # (S, B_local, k), never the shard-local pools
        top_ids, top_dd = collectives.gather_topk_merge(
            gids, jnp.where(ids >= 0, dd, jnp.inf), k, axis_name=model_axis)
        calls = jax.lax.psum(n_calls, model_axis)
        return top_ids, top_dd, calls

    from repro.launch.mesh import shard_map

    qspec = P(data_axes if len(data_axes) > 1 else data_axes[0], None)
    out = shard_map(
        program,
        mesh=mesh,
        in_specs=(P(model_axis), P(model_axis), P(model_axis), P(model_axis), qspec, qspec),
        out_specs=(qspec, qspec, P(data_axes if len(data_axes) > 1 else data_axes[0])),
    )(index.adjacency, index.medoid, index.emb_cheap, index.emb_expensive,
      q_cheap, q_expensive)
    return out
