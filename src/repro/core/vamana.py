"""Batched Vamana (DiskANN) graph construction in JAX.

The paper (Thm 3.4) builds a C/eps-shortcut-reachable graph with the *cheap*
metric d only; we implement the practical Vamana variant ([24], the "fast
preprocessing" DiskANN) adapted to accelerators:

* instead of inserting points one-by-one (pointer chasing), we run synchronous
  rounds: every round beam-searches *all* points against the current graph
  (one batched-engine run per chunk), robust-prunes each candidate pool, then adds
  reverse edges and prunes again — the standard batched/GPU Vamana schedule;
* robust pruning uses a distance matrix over the pool computed with one MXU
  matmul per point, so the O(P^2) occlusion loop is pure gather/compare;
* all shapes are static: pools are the top-``pool_size`` scored vertices.

The returned index is ``(adjacency (N,R) int32, medoid id)``; the construction
touches only the proxy metric, satisfying property 1 of Theorem 1.1.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import distances
from repro.core.beam import (batched_greedy_search,
                             fused_dist_fn as beam_fused_dist_fn,
                             sharded_greedy_search)
from repro.kernels import backend as kernel_backend

Array = jax.Array


class VamanaConfig(NamedTuple):
    max_degree: int = 64  # R
    l_build: int = 125  # beam width during construction
    alpha: float = 1.2  # shortcut-reachability slack (paper: alpha >= 1)
    n_rounds: int = 2  # pass 1 at alpha=1.0, pass 2..n at alpha
    pool_size: int = 256  # candidate pool fed to robust prune
    rev_candidates: int = 64  # reverse-edge candidates folded per node
    build_batch: int = 1024  # points processed per vmapped chunk
    metric: str = "l2"
    seed: int = 0


class VamanaIndex(NamedTuple):
    adjacency: Array  # (N, R) int32, -1 padded
    medoid: Array  # () int32
    config: VamanaConfig


def find_medoid(x: Array, metric: str = "l2") -> Array:
    """Vertex closest to the centroid — the canonical DiskANN entry point."""
    centroid = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
    d = distances.pairwise(centroid, x, metric)[0]
    return jnp.argmin(d).astype(jnp.int32)


def robust_prune(
    p_id: Array,
    pool_ids: Array,
    pool_dists: Array,
    x: Array,
    *,
    alpha: float,
    max_degree: int,
    metric: str,
) -> Array:
    """DiskANN RobustPrune for one vertex. Pool must be sorted ascending.

    Keeps <= R out-neighbors such that every pruned candidate j has a kept
    neighbor c with alpha * d(c, j) <= d(p, j) — exactly the alpha-shortcut
    property of Definition 3.1 restricted to the candidate pool.
    """
    P = pool_ids.shape[0]
    valid = (pool_ids >= 0) & (pool_ids != p_id) & jnp.isfinite(pool_dists)
    # Pairwise distances among the pool — one matmul, reused by the whole loop.
    rows = x[jnp.maximum(pool_ids, 0)]
    pd = distances.pairwise(rows, rows, metric)  # (P, P)

    def body(t, st):
        sel, n_sel, pruned = st
        ok = valid[t] & (~pruned[t]) & (n_sel < max_degree)
        occl = (alpha * pd[t] <= pool_dists) & (jnp.arange(P) > t)
        pruned = jnp.where(ok, pruned | occl, pruned)
        sel = jnp.where(ok, sel.at[n_sel].set(pool_ids[t]), sel)
        return sel, n_sel + ok.astype(jnp.int32), pruned

    sel0 = jnp.full((max_degree,), -1, jnp.int32)
    sel, _, _ = lax.fori_loop(0, P, body, (sel0, jnp.int32(0), jnp.zeros(P, bool)))
    return sel


def _search_pool(x, adjacency, medoid, ids, cfg: VamanaConfig):
    """Beam-search a chunk of point ids against the current graph in one
    batched engine run; returns each point's candidate pool."""
    em = distances.EmbeddingMetric(x, cfg.metric)
    b = ids.shape[0]
    entries = jnp.broadcast_to(
        jnp.asarray(medoid, jnp.int32).reshape(1, 1), (b, 1)
    )
    res = batched_greedy_search(
        em.dists_batch,
        adjacency,
        x[ids],
        entries,
        n_points=x.shape[0],
        beam_width=cfg.l_build,
        pool_size=cfg.pool_size,
        max_steps=2 * cfg.l_build,
    )
    return res.pool_ids, res.pool_dists


def _prune_batch(x, ids, pool_ids, pool_dists, *, alpha, cfg: VamanaConfig):
    f = functools.partial(
        robust_prune,
        x=x,
        alpha=alpha,
        max_degree=cfg.max_degree,
        metric=cfg.metric,
    )
    return jax.vmap(f)(ids, pool_ids, pool_dists)


def _reverse_candidates(adjacency: Array, k_rev: int) -> Array:
    """(N, k_rev) int32: for each node, up to k_rev vertices that point at it."""
    n, r = adjacency.shape
    src = jnp.repeat(jnp.arange(n, dtype=jnp.int32), r)
    dst = adjacency.reshape(-1)
    # sort edges by destination; invalid (-1) destinations sort first
    order = jnp.argsort(dst)
    dst_s, src_s = dst[order], src[order]
    # first occurrence offset of each destination node
    starts = jnp.searchsorted(dst_s, jnp.arange(n, dtype=jnp.int32), side="left")
    counts = (
        jnp.searchsorted(dst_s, jnp.arange(n, dtype=jnp.int32), side="right") - starts
    )
    take = jnp.minimum(counts, k_rev)
    idx = starts[:, None] + jnp.arange(k_rev)[None, :]
    ok = jnp.arange(k_rev)[None, :] < take[:, None]
    idx = jnp.clip(idx, 0, n * r - 1)
    return jnp.where(ok, src_s[idx], -1)


def _augment_and_prune(x, adjacency, *, alpha, cfg: VamanaConfig):
    """Fold reverse edges into each node's list and robust-prune the union."""
    n = x.shape[0]
    rev = _reverse_candidates(adjacency, cfg.rev_candidates)
    em = distances.EmbeddingMetric(x, cfg.metric)

    def one(i, adj_row, rev_row):
        cand = jnp.concatenate([adj_row, rev_row])
        # drop duplicate ids positionally
        dup = (cand[:, None] == cand[None, :]) & (
            jnp.arange(cand.shape[0])[:, None] > jnp.arange(cand.shape[0])[None, :]
        )
        cand = jnp.where(dup.any(axis=1) | (cand == i), -1, cand)
        d = em.dists(x[i], cand)
        order = jnp.argsort(d, stable=True)
        return robust_prune(
            i,
            cand[order],
            d[order],
            x,
            alpha=alpha,
            max_degree=cfg.max_degree,
            metric=cfg.metric,
        )

    out = []
    ids = jnp.arange(n, dtype=jnp.int32)
    bb = cfg.build_batch
    one_v = jax.jit(jax.vmap(one, in_axes=(0, 0, 0)))
    for s in range(0, n, bb):
        sl = slice(s, min(s + bb, n))
        out.append(one_v(ids[sl], adjacency[sl], rev[sl]))
    return jnp.concatenate(out, axis=0)


def build(x: Array, cfg: VamanaConfig | None = None) -> VamanaIndex:
    """Construct a Vamana graph over corpus embeddings ``x`` (N, dim).

    Only the proxy metric (cfg.metric over ``x``) is evaluated — the expensive
    metric never appears here (Theorem 1.1, property 1).
    """
    if cfg is None:
        cfg = VamanaConfig()
    n = x.shape[0]
    r = cfg.max_degree
    key = jax.random.PRNGKey(cfg.seed)
    # random R-regular-ish initialization (self-loops knocked out)
    init = jax.random.randint(key, (n, r), 0, n, dtype=jnp.int32)
    init = jnp.where(init == jnp.arange(n, dtype=jnp.int32)[:, None], -1, init)
    adjacency = init
    medoid = find_medoid(x, cfg.metric)

    ids = jnp.arange(n, dtype=jnp.int32)
    search_j = jax.jit(
        lambda adj, chunk: _search_pool(x, adj, medoid, chunk, cfg)
    )

    for rnd in range(cfg.n_rounds):
        alpha = 1.0 if rnd < cfg.n_rounds - 1 else cfg.alpha
        new_rows = []
        for s in range(0, n, cfg.build_batch):
            chunk = ids[s : min(s + cfg.build_batch, n)]
            pool_ids, pool_dists = search_j(adjacency, chunk)
            new_rows.append(
                _prune_batch(x, chunk, pool_ids, pool_dists, alpha=alpha, cfg=cfg)
            )
        adjacency = jnp.concatenate(new_rows, axis=0)
        adjacency = _augment_and_prune(x, adjacency, alpha=alpha, cfg=cfg)

    return VamanaIndex(adjacency=adjacency, medoid=medoid, config=cfg)


def search(
    index: VamanaIndex,
    corpus_emb: Array,
    query_emb: Array,
    *,
    k: int,
    beam_width: int | None = None,
    quota: int | Array | None = None,
    metric: str | None = None,
    n_entries: int = 8,
    expand_width: int = 1,
    shards: int = 1,
    mesh=None,
    backend=None,
    quantize=None,
) -> tuple[Array, Array, Array]:
    """Standard single-metric search. Returns (ids (B,k), dists (B,k), calls (B,)).

    Starts from the medoid plus ``n_entries-1`` stratified vertices — on
    strongly clustered corpora a single entry point leaves the greedy search
    stranded in the entry's cluster (multi-entry is standard practice). The
    whole query batch runs through one batched-engine loop; ``expand_width``
    is the step-widening throughput knob (1 = historical semantics).
    ``quota`` may be a per-query (B,) vector for mixed call budgets in one
    batch (each query freezes at its own budget, bit-exact vs running alone).

    ``shards > 1`` runs the identical loop device-parallel over a corpus
    mesh (``repro.core.beam.sharded_greedy_search``) — bit-exact results,
    the corpus (and any column-sharded dedup state) split across ``shards``
    devices.

    ``backend`` selects the wave-scoring kernel route
    (``repro.kernels.resolve_backend`` values). The default keeps the
    frozen gather-then-reduce oracle (bit-exact vs the legacy engine);
    ``"xla_matmul"`` / ``"pallas"`` / ``"auto"`` score in matmul form over
    a corpus-norm cache built once per call — same results up to fp
    association (recall-identical on non-degenerate data).

    ``corpus_emb`` may be a prebuilt ``repro.kernels.CorpusView`` — then
    *no* per-call view construction happens at all (build it once with
    ``repro.kernels.as_corpus_view`` and reuse it across calls), and a
    quantized view is scored in its residency on every backend.
    ``quantize`` (``"int8"`` / ``"fp8"`` / ``"fp8_e5m2"``) quantizes a raw
    corpus for this call; prefer passing a prebuilt quantized view."""
    met = metric or index.config.metric
    L = beam_width or max(k, index.config.l_build)
    n = kernel_backend.corpus_rows(corpus_emb).shape[0]
    b = query_emb.shape[0]
    if (quota is not None and jnp.ndim(quota) == 0
            and not isinstance(quota, jax.core.Tracer)):
        # normalize numpy scalars / 0-d arrays once at the boundary so the
        # static dedup-backend resolution sees a concrete bound; (B,)
        # vectors pass through as per-query budgets, and traced scalars
        # stay traced (they degrade to the bitmap backend downstream)
        quota = int(quota)
    stride = max(1, n // max(n_entries, 1))
    entries = jnp.concatenate([
        jnp.array([index.medoid], jnp.int32),
        (jnp.arange(max(n_entries - 1, 0), dtype=jnp.int32) * stride) % n,
    ])
    entries_b = jnp.broadcast_to(entries, (b, entries.shape[0]))
    quota = quota if quota is not None else jnp.iinfo(jnp.int32).max // 2
    be = kernel_backend.resolve_backend(backend, quantize=quantize,
                                        _caller="vamana.search")
    if shards > 1:
        res = sharded_greedy_search(
            corpus_emb,
            index.adjacency,
            query_emb,
            entries_b,
            shards=shards,
            metric=met,
            mesh=mesh,
            beam_width=L,
            pool_size=max(L, k),
            quota=quota,
            expand_width=expand_width,
            max_steps=4 * L,
            backend=be,
        )
    else:
        if (be.matmul or be.quantize is not None
                or isinstance(corpus_emb, kernel_backend.CorpusView)):
            # matmul-form / quantized scoring over the (possibly prebuilt)
            # corpus view — a raw array is wrapped once here
            dist_fn = beam_fused_dist_fn(corpus_emb, met, backend=be)
        else:
            em = distances.EmbeddingMetric(corpus_emb, met)
            dist_fn = em.dists_batch
        res = batched_greedy_search(
            dist_fn,
            index.adjacency,
            query_emb,
            entries_b,
            n_points=n,
            beam_width=L,
            pool_size=max(L, k),
            quota=quota,
            expand_width=expand_width,
            max_steps=4 * L,
            backend=be,
        )
    return res.pool_ids[:, :k], res.pool_dists[:, :k], res.n_calls
