"""The paper's contribution: bi-metric two-stage search (§4, "Bi-metric (our method)").

Given a graph index built *only* with the cheap metric d (vamana.build):

  stage 1 — greedy search with d; zero D calls; returns the top-K seeds
            (paper default K = Q/2, ablations: 1, 100, Q/2, or none);
  stage 2 — greedy search *on the same graph* with the expensive metric D,
            beam initialized with the stage-1 seeds; every D evaluation
            (including scoring the seeds) counts against the quota Q; the
            scored-bitmap guarantees no pair is ever paid for twice.

Report the top-k vertices by D among everything scored — by construction the
pool holds exactly those.

Also includes the two baselines evaluated in the paper:
  * ``rerank``        — "Bi-metric (baseline)": top-Q by d, score all with D;
  * single-metric     — vamana.search on a D-built graph (see benchmarks).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.beam import NO_QUOTA, greedy_search
from repro.core.vamana import VamanaIndex

Array = jax.Array

DistFn = Callable[[Array], Array]  # ids (k,) -> dists (k,) for one query


class BiMetricResult(NamedTuple):
    ids: Array  # (B, k) best by D
    dists: Array  # (B, k) D-distances
    d_calls: Array  # (B,) cheap-metric calls (stage 1)
    D_calls: Array  # (B,) expensive-metric calls (stage 2) — the paper's cost


def _stage1(
    cheap_fn: DistFn,
    index: VamanaIndex,
    *,
    n_points: int,
    n_seeds: int,
    l_search: int,
) -> tuple[Array, Array]:
    """Cheap-metric greedy search; returns (seed ids (n_seeds,), n_d_calls)."""
    res = greedy_search(
        cheap_fn,
        index.adjacency,
        jnp.array([index.medoid], jnp.int32),
        n_points=n_points,
        beam_width=l_search,
        pool_size=max(l_search, n_seeds),
        quota=NO_QUOTA,
        max_steps=4 * l_search,
    )
    return res.pool_ids[:n_seeds], res.n_calls


def bimetric_search_single(
    cheap_fn: DistFn,
    expensive_fn: DistFn,
    index: VamanaIndex,
    *,
    n_points: int,
    quota: int,
    k: int = 10,
    n_seeds: int | None = None,
    l_search_d: int | None = None,
    beam_width_D: int | None = None,
    use_stage1: bool = True,
) -> tuple[Array, Array, Array, Array]:
    """One query. Returns (ids (k,), D_dists (k,), d_calls, D_calls)."""
    if n_seeds is None:
        n_seeds = max(1, quota // 2)  # paper default: top-Q/2
    l1 = l_search_d or max(index.config.l_build, n_seeds)
    if use_stage1:
        seeds, d_calls = _stage1(
            cheap_fn, index, n_points=n_points, n_seeds=n_seeds, l_search=l1
        )
    else:  # "Default" ablation: start from the graph entry point only
        seeds = jnp.full((max(n_seeds, 1),), -1, jnp.int32)
        seeds = seeds.at[0].set(index.medoid)
        d_calls = jnp.int32(0)

    bw = beam_width_D or max(k, min(quota, 2 * n_seeds + 8))
    res = greedy_search(
        expensive_fn,
        index.adjacency,
        seeds,
        n_points=n_points,
        beam_width=bw,
        pool_size=max(bw, k),
        quota=quota,
        max_steps=4 * quota,  # quota is the real stop; steps are a safety cap
    )
    return res.pool_ids[:k], res.pool_dists[:k], d_calls, res.n_calls


def bimetric_search(
    cheap_fn_batch: Callable[[Array, Array], Array],
    expensive_fn_batch: Callable[[Array, Array], Array],
    index: VamanaIndex,
    q_cheap: Array,
    q_expensive: Array,
    *,
    n_points: int,
    quota: int,
    k: int = 10,
    n_seeds: int | None = None,
    l_search_d: int | None = None,
    use_stage1: bool = True,
) -> BiMetricResult:
    """Batched bi-metric search.

    ``cheap_fn_batch(q_ctx, ids)`` / ``expensive_fn_batch(q_ctx, ids)`` score
    ids against one query's context under d / D respectively; ``q_cheap`` and
    ``q_expensive`` are the per-query contexts (e.g. the two embeddings).
    """

    def one(qc, qe):
        return bimetric_search_single(
            lambda ids: cheap_fn_batch(qc, ids),
            lambda ids: expensive_fn_batch(qe, ids),
            index,
            n_points=n_points,
            quota=quota,
            k=k,
            n_seeds=n_seeds,
            l_search_d=l_search_d,
            use_stage1=use_stage1,
        )

    ids, dd, dc, Dc = jax.vmap(one)(q_cheap, q_expensive)
    return BiMetricResult(ids=ids, dists=dd, d_calls=dc, D_calls=Dc)


def rerank_search(
    cheap_fn_batch: Callable[[Array, Array], Array],
    expensive_fn_batch: Callable[[Array, Array], Array],
    index: VamanaIndex,
    q_cheap: Array,
    q_expensive: Array,
    *,
    n_points: int,
    quota: int,
    k: int = 10,
    l_search_d: int | None = None,
) -> BiMetricResult:
    """"Bi-metric (baseline)" — retrieve top-``quota`` by d, re-rank all by D.

    Exactly ``quota`` D calls per query (the re-ranking scan is unavoidable —
    the paper's issue (2) with re-ranking).
    """
    l1 = l_search_d or max(index.config.l_build, quota)

    def one(qc, qe):
        cand, d_calls = _stage1(
            lambda ids: cheap_fn_batch(qc, ids),
            index,
            n_points=n_points,
            n_seeds=quota,
            l_search=max(l1, quota),
        )
        dd = expensive_fn_batch(qe, cand)
        dd = jnp.where(cand >= 0, dd, jnp.inf)
        order = jnp.argsort(dd, stable=True)
        n_D = (cand >= 0).sum(dtype=jnp.int32)
        return cand[order][:k], dd[order][:k], d_calls, n_D

    ids, dd, dc, Dc = jax.vmap(one)(q_cheap, q_expensive)
    return BiMetricResult(ids=ids, dists=dd, d_calls=dc, D_calls=Dc)
