"""The paper's contribution: bi-metric two-stage search (§4, "Bi-metric (our method)").

Given a graph index built *only* with the cheap metric d (vamana.build):

  stage 1 — greedy search with d; zero D calls; returns the top-K seeds
            (paper default K = Q/2, ablations: 1, 100, Q/2, or none);
  stage 2 — greedy search *on the same graph* with the expensive metric D,
            beam initialized with the stage-1 seeds; every D evaluation
            (including scoring the seeds) counts against the quota Q; the
            scored-bitmap guarantees no pair is ever paid for twice.

Both stages run the batched engine (``repro.core.beam``): the whole query
batch advances through one fixed-shape hot loop per stage instead of a
per-query ``vmap`` of scalar searches. ``expand_width`` widens each wave
(E frontier vertices per query per step) for throughput; the default of 1
keeps the historical expand-one-vertex semantics bit-exactly.

Report the top-k vertices by D among everything scored — by construction the
pool holds exactly those.

Also includes the two baselines evaluated in the paper:
  * ``rerank``        — "Bi-metric (baseline)": top-Q by d, score all with D;
  * single-metric     — vamana.search on a D-built graph (see benchmarks).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import covertree as _covertree
from repro.core.beam import (NO_QUOTA, ShardedStepper, batched_greedy_search,
                             fused_dist_fn, sharded_greedy_search)
from repro.core.vamana import VamanaIndex
from repro.kernels import backend as kernel_backend

Array = jax.Array

DistFn = Callable[[Array], Array]  # ids (k,) -> dists (k,) for one query


class BiMetricResult(NamedTuple):
    ids: Array  # (B, k) best by D
    dists: Array  # (B, k) D-distances
    d_calls: Array  # (B,) cheap-metric calls (stage 1)
    D_calls: Array  # (B,) expensive-metric calls (stage 2) — the paper's cost


def _medoid_entries(index: VamanaIndex, batch: int) -> Array:
    """(B, 1) entry matrix — every query starts at the graph medoid."""
    medoid = jnp.asarray(index.medoid, jnp.int32).reshape(1, 1)
    return jnp.broadcast_to(medoid, (batch, 1))


def _stage1_batch(
    cheap_fn_batch: Callable[[Array, Array], Array],
    index: VamanaIndex,
    q_cheap: Array,
    *,
    n_points: int,
    n_seeds: int,
    l_search: int,
    expand_width: int = 1,
    backend=None,
) -> tuple[Array, Array]:
    """Cheap-metric batched greedy search -> (seeds (B, n_seeds), d_calls (B,))."""
    res = batched_greedy_search(
        cheap_fn_batch,
        index.adjacency,
        q_cheap,
        _medoid_entries(index, q_cheap.shape[0]),
        n_points=n_points,
        beam_width=l_search,
        pool_size=max(l_search, n_seeds),
        quota=NO_QUOTA,
        expand_width=expand_width,
        max_steps=4 * l_search,
        backend=backend,
    )
    return res.pool_ids[:, :n_seeds], res.n_calls


def bimetric_search(
    cheap_fn_batch: Callable[[Array, Array], Array],
    expensive_fn_batch: Callable[[Array, Array], Array],
    index: VamanaIndex | _covertree.FlatCoverTree,
    q_cheap: Array,
    q_expensive: Array,
    *,
    n_points: int,
    quota: int | Array,
    k: int = 10,
    n_seeds: int | None = None,
    l_search_d: int | None = None,
    beam_width_D: int | None = None,
    use_stage1: bool = True,
    expand_width: int = 1,
    shards: int = 1,
    corpora: tuple[Array, Array] | None = None,
    metric: str = "l2",
    mesh=None,
    backend=None,
    quantize=None,
    eps: float = 0.5,
) -> BiMetricResult:
    """Batched bi-metric search.

    ``cheap_fn_batch(q_ctx, ids)`` / ``expensive_fn_batch(q_ctx, ids)`` score
    (k,) ids against *one* query's context under d / D respectively (they are
    vmapped over the batch here); ``q_cheap`` and ``q_expensive`` are the
    per-query contexts (e.g. the two embeddings).

    ``index`` is the knob between the paper's two instantiations: a
    :class:`repro.core.vamana.VamanaIndex` runs the DiskANN form (stage 1
    on d, stage-2 greedy on D); a
    :class:`repro.core.covertree.FlatCoverTree` (built offline on d via
    ``covertree.build`` + ``covertree.flatten``) runs Algorithm 3's level
    descent through the same ``plan_step``/``commit_scores`` engine — no
    stage 1 (``d_calls`` is 0; the tree structure *is* the proxy's
    contribution), ``eps`` is its accuracy knob, and the stage-1/beam
    kwargs (``n_seeds``, ``l_search_d``, ``beam_width_D``, ``use_stage1``,
    ``expand_width``) are ignored. Both forms honor ``quota``, ``shards``,
    ``backend``, and per-query (B,) quotas with exact accounting.

    ``quota`` may be a per-query (B,) vector — mixed budgets in one batch
    with exact per-query accounting (what the serving engine's request waves
    do). The pool/beam shapes are static, so a (B,) quota needs explicit
    ``n_seeds`` and ``beam_width_D``; each query still freezes at *its own*
    budget, bit-exact vs running it alone.

    ``shards > 1`` runs both stages device-parallel over a corpus mesh; the
    metrics must then be embedding-backed: pass
    ``corpora=(corpus_cheap, corpus_expensive)`` (the embedding matrices that
    induce d and D under ``metric``) and the distance callables are ignored.
    Results are bit-exact vs the single-device path.

    ``backend`` picks the wave-scoring kernel route
    (``repro.kernels.resolve_backend``). With embedding-backed metrics
    (``corpora=``) the matmul backends score both stages in MXU form over
    per-corpus norm caches (built once per call); with metric callables the
    backend only routes the pool merges, since the scoring closure is the
    caller's. The default keeps the frozen oracle bit-exactly.

    ``corpora`` entries may be prebuilt ``repro.kernels.CorpusView``
    objects — then no per-call view construction happens (build once,
    reuse across calls). ``quantize`` selects quantized residency for the
    **proxy stage only**: the paper's contract is that d may be lossy
    (quantization error folds into the C-approximation factor) while the
    ground-truth stage D stays exact, so ``corpora[1]`` is never
    quantized by this knob — hand in a prebuilt quantized view as
    ``corpora[1]`` if a lossy ground truth is really wanted.
    """
    import dataclasses as _dc

    be1 = kernel_backend.resolve_backend(backend, quantize=quantize,
                                         _caller="bimetric_search")
    be = _dc.replace(be1, quantize=None)  # stage-2 backend: never quantized
    # embedding-backed metrics can score in matmul form even unsharded —
    # the norm caches are built once per corpus here, outside the loops

    def _fused(corpus, bb):
        return (bb.matmul or bb.quantize is not None
                or isinstance(corpus, kernel_backend.CorpusView))

    use_fused1 = corpora is not None and _fused(corpora[0], be1)
    use_fused = corpora is not None and _fused(corpora[1], be)

    if isinstance(index, _covertree.FlatCoverTree):
        # Algorithm 3: the level descent replaces both stages — the proxy's
        # work happened offline in the tree build, every online call is a D
        # call. With embedding-backed D the same fused gather→score closure
        # drives every shard count (which is what makes shards>1 bit-exact
        # vs one device); a metric callable is vmapped like stage 2 does.
        if shards > 1 and corpora is None:
            raise ValueError(
                "shards > 1 needs corpora=(corpus_d, corpus_D) — only "
                "embedding-backed metrics can be sharded")
        stepper = None
        if shards > 1:
            stepper = ShardedStepper(
                shards=shards, n_points=n_points, mesh=mesh, backend=be)
        if corpora is not None:
            corpus_D = corpora[1]
            if not isinstance(corpus_D, kernel_backend.CorpusView):
                corpus_D = jnp.asarray(corpus_D)
            fn = fused_dist_fn(corpus_D, metric, backend=be)
        else:
            fn = jax.vmap(expensive_fn_batch)
        res_ct = _covertree.search_batched(
            index, fn, q_expensive, eps=eps, k=k, quota=quota,
            backend=be, stepper=stepper)
        return BiMetricResult(
            ids=res_ct.ids,
            dists=res_ct.dists,
            d_calls=jnp.zeros_like(res_ct.n_calls),  # d's work was offline
            D_calls=res_ct.n_calls,
        )

    b = q_cheap.shape[0]
    scalar_quota = jnp.ndim(quota) == 0  # python/numpy scalars alike
    if scalar_quota:
        quota = int(quota)
    if n_seeds is None:
        if not scalar_quota:
            raise ValueError(
                "a per-query (B,) quota needs an explicit n_seeds")
        n_seeds = max(1, quota // 2)  # paper default: top-Q/2
    l1 = l_search_d or max(index.config.l_build, n_seeds)
    if shards > 1 and corpora is None:
        raise ValueError("shards > 1 needs corpora=(corpus_d, corpus_D) — "
                         "only embedding-backed metrics can be sharded")

    if use_stage1:
        if shards > 1:
            res1 = sharded_greedy_search(
                corpora[0],
                index.adjacency,
                q_cheap,
                _medoid_entries(index, b),
                shards=shards,
                metric=metric,
                mesh=mesh,
                beam_width=l1,
                pool_size=max(l1, n_seeds),
                quota=NO_QUOTA,
                expand_width=expand_width,
                max_steps=4 * l1,
                backend=be1,
            )
            seeds, d_calls = res1.pool_ids[:, :n_seeds], res1.n_calls
        else:
            seeds, d_calls = _stage1_batch(
                (fused_dist_fn(corpora[0], metric, backend=be1)
                 if use_fused1 else jax.vmap(cheap_fn_batch)),
                index,
                q_cheap,
                n_points=n_points,
                n_seeds=n_seeds,
                l_search=l1,
                expand_width=expand_width,
                backend=be1,
            )
    else:  # "Default" ablation: start from the graph entry point only
        seeds = jnp.full((b, max(n_seeds, 1)), -1, jnp.int32)
        seeds = seeds.at[:, 0].set(jnp.asarray(index.medoid, jnp.int32))
        d_calls = jnp.zeros((b,), jnp.int32)

    if beam_width_D is None:
        if not scalar_quota:
            raise ValueError(
                "a per-query (B,) quota needs an explicit beam_width_D")
        bw = max(k, min(quota, 2 * n_seeds + 8))
    else:
        bw = beam_width_D
    # the quota is the real stop; steps = per-query safety cap
    max_steps_D = (4 * quota if scalar_quota
                   else 4 * jnp.asarray(quota, jnp.int32))
    if shards > 1:
        res = sharded_greedy_search(
            corpora[1],
            index.adjacency,
            q_expensive,
            seeds,
            shards=shards,
            metric=metric,
            mesh=mesh,
            beam_width=bw,
            pool_size=max(bw, k),
            quota=quota,
            expand_width=expand_width,
            max_steps=max_steps_D,
            backend=be,
        )
    else:
        res = batched_greedy_search(
            (fused_dist_fn(corpora[1], metric, backend=be)
             if use_fused else jax.vmap(expensive_fn_batch)),
            index.adjacency,
            q_expensive,
            seeds,
            n_points=n_points,
            beam_width=bw,
            pool_size=max(bw, k),
            quota=quota,
            expand_width=expand_width,
            max_steps=max_steps_D,
            backend=be,
        )
    return BiMetricResult(
        ids=res.pool_ids[:, :k],
        dists=res.pool_dists[:, :k],
        d_calls=d_calls,
        D_calls=res.n_calls,
    )


def bimetric_search_single(
    cheap_fn: DistFn,
    expensive_fn: DistFn,
    index: VamanaIndex,
    *,
    n_points: int,
    quota: int,
    k: int = 10,
    n_seeds: int | None = None,
    l_search_d: int | None = None,
    beam_width_D: int | None = None,
    use_stage1: bool = True,
) -> tuple[Array, Array, Array, Array]:
    """One query (B = 1 through the batched engine).

    ``cheap_fn`` / ``expensive_fn`` close over the query: (k,) ids -> dists.
    Returns (ids (k,), D_dists (k,), d_calls, D_calls).
    """
    res = bimetric_search(
        lambda _q, ids: cheap_fn(ids),
        lambda _q, ids: expensive_fn(ids),
        index,
        jnp.zeros((1, 1), jnp.float32),
        jnp.zeros((1, 1), jnp.float32),
        n_points=n_points,
        quota=quota,
        k=k,
        n_seeds=n_seeds,
        l_search_d=l_search_d,
        beam_width_D=beam_width_D,
        use_stage1=use_stage1,
    )
    return res.ids[0], res.dists[0], res.d_calls[0], res.D_calls[0]


def rerank_search(
    cheap_fn_batch: Callable[[Array, Array], Array],
    expensive_fn_batch: Callable[[Array, Array], Array],
    index: VamanaIndex,
    q_cheap: Array,
    q_expensive: Array,
    *,
    n_points: int,
    quota: int,
    k: int = 10,
    l_search_d: int | None = None,
    expand_width: int = 1,
) -> BiMetricResult:
    """"Bi-metric (baseline)" — retrieve top-``quota`` by d, re-rank all by D.

    Exactly ``quota`` D calls per query (the re-ranking scan is unavoidable —
    the paper's issue (2) with re-ranking).
    """
    l1 = l_search_d or max(index.config.l_build, quota)
    cand, d_calls = _stage1_batch(
        jax.vmap(cheap_fn_batch),
        index,
        q_cheap,
        n_points=n_points,
        n_seeds=quota,
        l_search=max(l1, quota),
        expand_width=expand_width,
    )
    dd = jax.vmap(expensive_fn_batch)(q_expensive, cand)
    dd = jnp.where(cand >= 0, dd, jnp.inf)
    order = jnp.argsort(dd, axis=1, stable=True)
    take = lambda a: jnp.take_along_axis(a, order, axis=1)[:, :k]
    n_D = (cand >= 0).sum(axis=1, dtype=jnp.int32)
    return BiMetricResult(
        ids=take(cand), dists=take(dd), d_calls=d_calls, D_calls=n_D
    )
