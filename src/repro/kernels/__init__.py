"""Compute kernels for the search hot path — and the backend contract.

Layout
------
* ``ref.py``      — pure-jnp oracles, **frozen**: the mathematical definition
  every other execution path must match. Never edited for speed.
* ``ops.py``      — the jitted dispatch layer every engine call site uses;
  one ``backend=`` knob per op.
* ``backend.py``  — :class:`Backend` / :func:`resolve_backend` and the
  corpus-norm cache (:class:`CorpusView` / :func:`as_corpus_view`).
* ``l2_topk.py``, ``flash_attention.py``, ``embedding_bag.py`` — the Pallas
  TPU kernel bodies.

Backend-selection contract
--------------------------
``backend=`` accepts ``"ref" | "xla_matmul" | "pallas" | "pallas-interpret"
| "auto"`` (or a resolved :class:`Backend`):

* **auto rule** — ``"auto"`` resolves at call time against the runtime
  device set: ``"pallas"`` when a TPU is present, ``"xla_matmul"``
  otherwise. Nothing resolves at import time.
* **default** — every public entry point defaults to ``"ref"``: the engine's
  bit-exactness guarantees (batched == legacy == sharded) are stated against
  the oracle, so the faster forms are opt-in knobs, not silent swaps.
* **oracle guarantee** — ``"ref"`` *is* ``ref.py`` through XLA.
  ``"xla_matmul"`` and ``"pallas"`` score waves in matmul form over the
  norm cache (``‖x‖² − 2⟨x, q⟩ + ‖q‖²``): identical math up to fp
  reassociation, pinned against the oracle by the backend parity grid
  (``tests/test_backend.py``: pool distances within fp tolerance,
  recall@10 identical, at shards {1, 2, 4}) and the interpret-mode kernel
  suite (``tests/test_kernels.py``, a dedicated CI job).
* **norm-cache invalidation** — a :class:`CorpusView` is an immutable
  snapshot of ``(rows, ‖x‖², 1/‖x‖)``; build it once per corpus *outside*
  the hot loop with :func:`as_corpus_view` and thread it through. jax
  arrays cannot be mutated, so "corpus mutation" means a new array — build
  a new view then (requantizing an existing view raises: views never
  change residency silently). Zero padding rows (uneven shards) carry
  norm 0 and a finite inverse norm: they score +inf/ignored like every
  other masked lane and never pollute cosine.
* **quantized residency** — ``as_corpus_view(corpus, quantize="int8" |
  "fp8" | "fp8_e5m2")`` stores the rows as quantization codes with
  per-row dequant parameters (int8: affine scale + zero-point; fp8:
  symmetric scale), 4x less row payload than f32 at any dim. The view is
  then a **lossy proxy**: norms are computed over the dequantized rows,
  and every backend scores exactly that proxy through one dequant
  semantics (``ref.dequant_rows_ref``) — the Pallas tile dequantizes
  in-register (scale/zero-point ride the prefetched row-metadata operand
  next to the norms), ``xla_matmul`` runs a dequant-then-dot epilogue,
  and ``"ref"`` dispatches the quantized oracles
  (``ref.gather_score_quant_ref``). This is the bi-metric paper's own
  contract: the cheap stage may be lossy (quantization error folds into
  the C-approximation factor), so quantization is only ever applied to
  proxy corpora — ``bimetric_search``/``BiMetricEngine`` never quantize
  the ground-truth stage, and ``"auto"`` never silently quantizes:
  residency is the caller's explicit ``quantize=`` (or prebuilt-view)
  choice, orthogonal to the execution-path knob. Parity is pinned by
  ``tests/test_quantize.py`` (round-trip bounds, backend × metric ×
  shard grid, recall@10 at matched quota).
* **deprecated shims** — the historical ``use_pallas`` /
  ``use_fused_merge`` / ``interpret`` boolean kwargs still work and map
  onto the equivalent ``Backend``, emitting one ``DeprecationWarning`` per
  call site.
"""
from repro.kernels.backend import Backend, CorpusView  # noqa: F401
from repro.kernels.backend import as_corpus_view, resolve_backend  # noqa: F401
