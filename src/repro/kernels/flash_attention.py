"""Pallas TPU flash attention (train/prefill) and flash decode (serve).

TPU-native tiling: Q blocks × KV blocks staged through VMEM, online softmax
carried in VMEM scratch across the (sequential) KV grid dimension, MXU matmuls
at (block_q × dh) @ (dh × block_k). Block sizes default to 128 — the MXU
systolic width — and must divide the padded sequence lengths.

The dissimilarity hot loop of the bi-metric tower (the expensive D encoder)
spends >90% of its time here at prefill_32k shapes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams; local alias, no namespace mutation
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      sm_scale: float, causal: bool, block_q: int,
                      block_k: int, kv_len: int, causal_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (block_q, dh)
    k = k_ref[0].astype(jnp.float32)  # (block_k, dh)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale  # (block_q, block_k)

    q_pos = (qi * block_q + causal_offset
             + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < kv_len
    if causal:
        mask = mask & (k_pos <= q_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (block_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)  # (block_k, dv)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    sm_scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> Array:
    """q (B, H, Sq, dh); k, v (B, H, Skv, dh|dv) -> (B, H, Sq, dv)."""
    b, h, sq, dh = q.shape
    skv, dv = k.shape[2], v.shape[3]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(dh)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)

    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))) if pad_k else v
    sqp, skp = sq + pad_q, skv + pad_k

    qp = qp.reshape(b * h, sqp, dh)
    kp = kp.reshape(b * h, skp, dh)
    vp = vp.reshape(b * h, skp, dv)
    grid = (b * h, sqp // block_q, skp // block_k)

    kernel = functools.partial(
        _flash_fwd_kernel, sm_scale=sm_scale, causal=causal,
        block_q=block_q, block_k=block_k, kv_len=skv,
        causal_offset=skv - sq,  # queries sit at the end of the KV window
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, dv), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(b, h, sqp, dv)[:, :, :sq]


def _flash_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, sm_scale: float,
                         block_k: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (1, dh) — one (batch*head) row
    k = k_ref[0].astype(jnp.float32)  # (block_k, dh)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale  # (1, block_k)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = k_pos < len_ref[0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q: Array, k: Array, v: Array, *, length: Array | int,
                 sm_scale: float | None = None, block_k: int = 512,
                 interpret: bool = False) -> Array:
    """q (B, H, dh); k, v (B, S, H, dh) -> (B, H, dh). One token vs KV cache."""
    b, h, dh = q.shape
    s = k.shape[1]
    dv = v.shape[3]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(dh)
    block_k = min(block_k, s)
    pad = (-s) % block_k
    kp = jnp.moveaxis(k, 2, 1).reshape(b * h, s, dh)
    vp = jnp.moveaxis(v, 2, 1).reshape(b * h, s, dv)
    if pad:
        kp = jnp.pad(kp, ((0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(vp, ((0, 0), (0, pad), (0, 0)))
    qp = q.reshape(b * h, 1, dh)
    lens = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1, 1), (b, h)
    ).reshape(b * h, 1)
    grid = (b * h, (s + pad) // block_k)

    out = pl.pallas_call(
        functools.partial(_flash_decode_kernel, sm_scale=sm_scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, block_k, dh), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, dv), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, 1), lambda bh, ki: (bh, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, dv), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp, lens)
    return out.reshape(b, h, dv)
