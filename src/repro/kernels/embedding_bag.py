"""Pallas embedding-bag: gather-by-prefetched-id + in-VMEM reduce.

The recsys lookup hot path. Indices are scalar-prefetched so the BlockSpec
index map streams exactly the needed table rows HBM→VMEM; the bag reduction
accumulates in a VMEM scratch across the (sequential) bag-position grid dim.
Padding ids (< 0) contribute zero without branching (masked add).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells it TPUCompilerParams; local alias, no namespace mutation
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

Array = jax.Array


def _bag_kernel(ids_ref, row_ref, o_ref, acc_scr, *, bag_len: int, mode: str):
    b = pl.program_id(0)
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid = (ids_ref[b, l] >= 0).astype(jnp.float32)
    acc_scr[...] += row_ref[...].astype(jnp.float32) * valid

    @pl.when(l == bag_len - 1)
    def _finalize():
        out = acc_scr[...]
        if mode == "mean":
            cnt = jnp.zeros((), jnp.float32)
            for i in range(bag_len):  # bag_len is static and small
                cnt += (ids_ref[b, i] >= 0).astype(jnp.float32)
            out = out / jnp.maximum(cnt, 1.0)
        o_ref[...] = out.astype(o_ref.dtype)


def embedding_bag(table: Array, idx: Array, *, mode: str = "sum",
                  interpret: bool = False) -> Array:
    """table (V, D); idx (B, L) int (-1 pads) -> (B, D) reduced bags."""
    assert mode in ("sum", "mean")
    v, d = table.shape
    b, L = idx.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, L),
        in_specs=[
            pl.BlockSpec((1, d), lambda bi, li, ids: (jnp.maximum(ids[bi, li], 0), 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bi, li, ids: (bi, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bag_kernel, bag_len=L, mode=mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)
