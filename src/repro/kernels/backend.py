"""Kernel-backend selection and the corpus-norm cache (one knob, one cache).

Every wave-scoring and pool-merge call site in the engine dispatches through
a single :class:`Backend` value instead of the historical
``use_pallas`` / ``use_fused_merge`` / ``interpret`` boolean triple:

* ``"ref"``        — the frozen ``repro.kernels.ref`` oracle through XLA
  (gather-then-reduce distances, stable merges). The correctness contract:
  every other backend is tested against it. This is the **default** at every
  public entry point, so existing bit-exact parity guarantees (batched vs
  legacy vs sharded) are untouched unless a caller opts in.
* ``"xla_matmul"`` — MXU/BLAS-form distances over the corpus-norm cache:
  ``d(x, q) = ‖x‖² − 2·⟨x, q⟩ + ‖q‖²`` (resp. plain dot products for
  ip/cosine) with ``‖x‖²`` (and inverse norms for cosine) precomputed once
  per corpus in a :class:`CorpusView`. The inner reduce becomes a
  ``dot_general`` that hits BLAS on CPU and the MXU on TPU, and the per-wave
  flop count drops by ~⅓ (the subtract-square pass disappears). Same math
  as the oracle up to fp association — *tolerance* parity, not bit parity.
* ``"pallas"``     — the fused TPU kernels (``repro.kernels.l2_topk``):
  matmul-form scoring tile with the norm cache as an extra operand, plus
  the payload-carrying bitonic pool merge (lane-width padded).
  ``"pallas-interpret"`` is the same kernels under ``interpret=True`` — the
  CPU-testable form used by the parity grid and CI.
* ``"auto"``       — ``"pallas"`` when a TPU is present, else
  ``"xla_matmul"``. The deployment knob: resolves against the runtime's
  device set, never silently at import time.

The legacy boolean kwargs are kept as deprecated shims: passing any of them
explicitly still works (mapped onto the equivalent Backend) and emits a
``DeprecationWarning`` exactly once per (call-site function, kwarg) pair.

**Corpus-norm cache invalidation**: a :class:`CorpusView` is an immutable
snapshot of ``(rows, ‖x‖², 1/‖x‖)``. jax arrays cannot be mutated in place,
so "mutating the corpus" always means producing a *new* array — build a new
view with :func:`as_corpus_view` at that point; holding the old view against
a new corpus is the only way to get stale norms, and nothing in the engine
does it (the serving engine builds its view once per engine lifetime,
alongside the index, which is itself corpus-immutable).

**Quantized residency**: ``as_corpus_view(corpus, quantize="int8"|"fp8")``
stores the resident rows quantized — int8 with a per-row affine
scale/zero-point pair, or fp8 (e4m3 by default, ``"fp8_e5m2"`` where the
jax dtype exists) with a per-row scale — while the norm cache is computed
over the *dequantized* rows, so the matmul-form expansion stays exact
against the one dequant semantics (``repro.kernels.ref.dequant_rows_ref``).
The paper's framing makes this a principled lever: the proxy stage may be
lossy (quantization error folds into the C-approximation factor) while the
ground-truth stage stays exact, so the resident corpus shrinks 4x vs f32
(2x vs bf16) at dim 256 and the gather-bound wave moves proportionally
fewer HBM bytes. Quantization happens exactly once, at view build; views
stay immutable snapshots, and ``"auto"`` never silently quantizes — a
quantized view only ever exists because a caller asked for one.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref

Array = jax.Array

BACKEND_NAMES = ("ref", "xla_matmul", "pallas")

#: quantized-residency modes accepted by :func:`as_corpus_view` (and the
#: ``quantize=`` knob on the entry points). "fp8" is e4m3; "fp8_e5m2" is the
#: wide-exponent variant. Modes whose jax dtype is missing in this build are
#: rejected at view-build time with a clear error instead of at trace time.
QUANTIZE_MODES = ("int8", "fp8", "fp8_e5m2")

# fp8 dtype table, gated on availability in the installed jax/ml_dtypes
_FP8_DTYPES: dict[str, object] = {}
if hasattr(jnp, "float8_e4m3fn"):
    _FP8_DTYPES["fp8"] = jnp.float8_e4m3fn
if hasattr(jnp, "float8_e5m2"):
    _FP8_DTYPES["fp8_e5m2"] = jnp.float8_e5m2

#: epsilon under the cosine rsqrt — must match ``repro.kernels.ref`` so the
#: matmul form agrees with the oracle on (near-)zero rows: a zero row (e.g.
#: uneven-shard padding) carries ``‖x‖² = 0`` and a *finite* inverse norm,
#: so its cosine distance is exactly 1.0 in every backend, never NaN.
NORM_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class Backend:
    """Resolved kernel-dispatch choice (hashable — safe as a jit static).

    ``fused_merge`` overrides the merge route only: ``None`` (default)
    derives it from the backend name (the bitonic kernel iff ``pallas``);
    the legacy ``use_fused_merge`` shim maps onto it.

    ``quantize`` asks the scoring path to hold the corpus in quantized
    residency (:data:`QUANTIZE_MODES`): entry points that build the view
    build it quantized, and a prebuilt view handed in must carry the same
    mode (mismatches raise — a quantized view is never silently
    requantized or promoted). ``None`` scores whatever residency the view
    already has, so prebuilt quantized views flow through every backend
    without restating the mode at each call site.
    """

    name: str  # "ref" | "xla_matmul" | "pallas"
    interpret: bool = False  # run Pallas bodies in interpret mode (CPU CI)
    fused_merge: bool | None = None
    quantize: str | None = None  # None | "int8" | "fp8" | "fp8_e5m2"

    def __post_init__(self):
        if self.name not in BACKEND_NAMES:
            raise ValueError(
                f"backend must be one of {BACKEND_NAMES}, got {self.name!r}")
        if self.quantize is not None and self.quantize not in QUANTIZE_MODES:
            raise ValueError(
                f"quantize must be None or one of {QUANTIZE_MODES}, "
                f"got {self.quantize!r}")

    @property
    def use_pallas(self) -> bool:
        return self.name == "pallas"

    @property
    def matmul(self) -> bool:
        """Score in matmul form over the corpus-norm cache?"""
        return self.name in ("xla_matmul", "pallas")

    @property
    def merge_pallas(self) -> bool:
        """Route pool merges through the Pallas bitonic network?"""
        if self.fused_merge is not None:
            return self.fused_merge
        return self.name == "pallas"


REF = Backend("ref")


def _tpu_present() -> bool:
    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except RuntimeError:  # no backend initialized at all
        return False


# one DeprecationWarning per (function, kwarg) pair for the whole process —
# the shims must nudge, not spam a hot loop's logs
_warned: set[tuple[str, str]] = set()


def warn_deprecated_knob(func: str, kwarg: str) -> None:
    key = (func, kwarg)
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(
        f"{func}(..., {kwarg}=) is deprecated; pass backend= instead "
        "('ref' | 'xla_matmul' | 'pallas' | 'pallas-interpret' | 'auto' "
        "or a repro.kernels.Backend)",
        DeprecationWarning, stacklevel=3)


def resolve_backend(
    backend: str | Backend | None = None,
    *,
    use_pallas: bool | None = None,
    use_fused_merge: bool | None = None,
    interpret: bool | None = None,
    quantize: str | None = None,
    default: str = "ref",
    _caller: str = "repro.kernels",
) -> Backend:
    """Normalize the backend knob (or the legacy boolean shims) to a Backend.

    Accepted ``backend`` values: a :class:`Backend`, one of
    ``"ref" | "xla_matmul" | "pallas" | "pallas-interpret" | "auto"``, or
    None. ``"auto"`` resolves against the runtime device set (pallas on
    TPU, xla_matmul otherwise). With ``backend=None`` the legacy kwargs
    decide — each one explicitly passed emits a once-per-call-site
    ``DeprecationWarning`` — and when nothing at all is passed the
    ``default`` (the frozen oracle) is returned.

    ``quantize`` rides along onto the resolved Backend (it composes with
    every name, including ``"auto"`` — auto picks the *execution* path,
    never the residency). Passing both ``quantize=`` and a ``Backend``
    that already carries a different mode raises.
    """
    if backend is not None:
        if isinstance(backend, Backend):
            if quantize is not None and backend.quantize not in (None, quantize):
                raise ValueError(
                    f"{_caller}: quantize={quantize!r} conflicts with "
                    f"backend.quantize={backend.quantize!r}")
            if quantize is not None and backend.quantize is None:
                return dataclasses.replace(backend, quantize=quantize)
            return backend
        if backend == "auto":
            return Backend("pallas" if _tpu_present() else "xla_matmul",
                           quantize=quantize)
        if backend == "pallas-interpret":
            return Backend("pallas", interpret=True, quantize=quantize)
        return Backend(backend, quantize=quantize)
    name = default
    fused = None
    interp = False
    legacy = (use_pallas is not None or use_fused_merge is not None
              or interpret is not None)
    if legacy:
        # the historical kwargs were independent: use_pallas only routed
        # the *scoring* kernels and defaulted the merge to the stable XLA
        # cut (use_fused_merge=False) — so a shimmed call must not derive
        # fused_merge from the backend name the way the new knob does
        fused = bool(use_fused_merge) if use_fused_merge is not None else False
    if use_pallas is not None:
        warn_deprecated_knob(_caller, "use_pallas")
        name = "pallas" if use_pallas else default
    if use_fused_merge is not None:
        warn_deprecated_knob(_caller, "use_fused_merge")
    if interpret is not None:
        warn_deprecated_knob(_caller, "interpret")
        interp = bool(interpret)
    return Backend(name, interpret=interp, fused_merge=fused,
                   quantize=quantize)


class CorpusView(NamedTuple):
    """Immutable corpus snapshot + the per-row norm cache (a pytree).

    ``rows`` keeps the corpus dtype untouched (a bf16/f16 corpus is *not*
    upcast — the cache adds 8 bytes/row of f32 norms, not a second f32
    corpus); ``sq_norms`` is ``‖x_i‖²`` and ``inv_norms`` is
    ``1/√(‖x_i‖² + NORM_EPS)``, both f32. Zero rows (uneven-shard padding)
    carry ``sq_norms == 0`` and a finite ``inv_norms``, so they score 0
    under sqeuclidean-vs-origin and exactly 1.0 under cosine — padding
    never pollutes any metric. Under the corpus mesh the norms shard with
    the rows (same contiguous blocks), so the cache adds nothing to the
    wave's psum traffic.

    **Quantized residency** (``scales is not None``): ``rows`` holds int8
    or fp8 codes and ``scales`` / ``zero_points`` the per-row dequant
    parameters (``zero_points`` is None for the symmetric fp8 modes). The
    norms are computed over the *dequantized* rows, so the matmul-form
    expansion scores the dequantized corpus exactly
    (``ref.dequant_rows_ref`` is the one semantics every backend matches).
    Zero rows quantize to codes that dequantize to exact zeros: norm 0,
    finite inverse norm, cosine exactly 1.0 — uneven-shard padding stays
    inert under quantization too. The dequant parameters shard with the
    rows under the corpus mesh, riding the same contiguous blocks as the
    norm cache.

    See the module docstring for the invalidation contract: views are
    snapshots; a new corpus array needs a new view.
    """

    rows: Array  # (N, dim) — corpus: original dtype, or int8/fp8 codes
    sq_norms: Array  # (N,) f32 ‖x‖² (of the dequantized rows if quantized)
    inv_norms: Array  # (N,) f32 1/√(‖x‖² + NORM_EPS)
    scales: Array | None = None  # (N,) f32 per-row dequant scale
    zero_points: Array | None = None  # (N,) f32 per-row zero point (int8)

    @property
    def n(self) -> int:
        return self.rows.shape[0]

    @property
    def dim(self) -> int:
        return self.rows.shape[1]

    @property
    def quantize(self) -> str | None:
        """The residency mode of this view (a :data:`QUANTIZE_MODES` name)."""
        if self.scales is None:
            return None
        if self.rows.dtype == jnp.int8:
            return "int8"
        for mode, dt in _FP8_DTYPES.items():
            if self.rows.dtype == dt:
                return mode
        raise ValueError(
            f"quantized view with unrecognized rows dtype {self.rows.dtype}")

    @property
    def bytes_per_row(self) -> int:
        """Resident bytes per corpus row (codes + norms + dequant params)."""
        per = self.rows.dtype.itemsize * self.dim
        per += self.sq_norms.dtype.itemsize + self.inv_norms.dtype.itemsize
        if self.scales is not None:
            per += self.scales.dtype.itemsize
        if self.zero_points is not None:
            per += self.zero_points.dtype.itemsize
        return per


def _quantize_rows_int8(rows_f32: Array) -> tuple[Array, Array, Array]:
    """Per-row affine int8: q = clip(round(x/s) + z), dequant (q - z)·s.

    ``s = (max - min) / 255`` with a zero-range guard (constant rows take
    s = 1 and quantize exactly onto their zero point), ``z`` the rounded
    affine zero point. A zero row therefore dequantizes to exact zeros.
    """
    mn = jnp.min(rows_f32, axis=-1)
    mx = jnp.max(rows_f32, axis=-1)
    scale = (mx - mn) / 255.0
    scale = jnp.where(scale > 0.0, scale, 1.0)
    zp = jnp.round(-128.0 - mn / scale)
    q = jnp.clip(jnp.round(rows_f32 / scale[:, None]) + zp[:, None],
                 -128.0, 127.0).astype(jnp.int8)
    return q, scale, zp


def _quantize_rows_fp8(rows_f32: Array, dtype) -> tuple[Array, Array]:
    """Per-row symmetric fp8: q = (x/s).astype(fp8), dequant q·s.

    ``s = max|x| / finfo(dtype).max`` with a zero guard, so each row uses
    the format's full dynamic range and zero rows stay exactly zero (fp8
    represents 0 exactly).
    """
    fmax = float(jnp.finfo(dtype).max)
    amax = jnp.max(jnp.abs(rows_f32), axis=-1)
    scale = jnp.where(amax > 0.0, amax / fmax, 1.0)
    q = (rows_f32 / scale[:, None]).astype(dtype)
    return q, scale


def as_corpus_view(corpus: Array | CorpusView,
                   quantize: str | None = None) -> CorpusView:
    """Build (or pass through) the norm cache for a corpus.

    Idempotent: a :class:`CorpusView` is returned unchanged, so call sites
    can accept either form and the norms are only ever computed once per
    corpus — build the view *outside* any hot loop and thread it through.

    ``quantize`` selects quantized residency (:data:`QUANTIZE_MODES`):
    rows are stored as int8/fp8 codes with per-row dequant parameters, and
    the norms are computed over the dequantized rows (the lossy proxy the
    scoring paths actually score). Handing in a prebuilt view with a
    *different* mode raises — requantizing an existing view (raw → int8,
    int8 → fp8, ...) is never done silently; build a fresh view from the
    original corpus instead.
    """
    if isinstance(corpus, CorpusView):
        if quantize is not None and corpus.quantize != quantize:
            raise ValueError(
                f"as_corpus_view(quantize={quantize!r}) got a prebuilt view "
                f"with quantize={corpus.quantize!r}; views are immutable "
                "snapshots — build a new view from the original corpus")
        return corpus
    if quantize is None:
        sq = jnp.sum(jnp.square(corpus.astype(jnp.float32)), axis=-1)
        return CorpusView(
            rows=corpus,
            sq_norms=sq,
            inv_norms=jax.lax.rsqrt(sq + NORM_EPS),
        )
    if quantize not in QUANTIZE_MODES:
        raise ValueError(
            f"quantize must be None or one of {QUANTIZE_MODES}, "
            f"got {quantize!r}")
    rows_f32 = corpus.astype(jnp.float32)
    if quantize == "int8":
        q, scale, zp = _quantize_rows_int8(rows_f32)
    else:
        if quantize not in _FP8_DTYPES:
            raise ValueError(
                f"quantize={quantize!r} needs a jax float8 dtype this build "
                f"does not provide (available: {sorted(_FP8_DTYPES)})")
        q, scale = _quantize_rows_fp8(rows_f32, _FP8_DTYPES[quantize])
        zp = None
    # norms over the DEQUANTIZED rows: the matmul expansion then scores the
    # lossy proxy exactly (one dequant semantics: ref.dequant_rows_ref)
    deq = _ref.dequant_rows_ref(q, scale, zp)
    sq = jnp.sum(jnp.square(deq), axis=-1)
    return CorpusView(
        rows=q,
        sq_norms=sq,
        inv_norms=jax.lax.rsqrt(sq + NORM_EPS),
        scales=scale,
        zero_points=zp,
    )


def corpus_rows(corpus: Array | CorpusView) -> Array:
    """The raw (N, dim) rows of either corpus form."""
    return corpus.rows if isinstance(corpus, CorpusView) else corpus
