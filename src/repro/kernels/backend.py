"""Kernel-backend selection and the corpus-norm cache (one knob, one cache).

Every wave-scoring and pool-merge call site in the engine dispatches through
a single :class:`Backend` value instead of the historical
``use_pallas`` / ``use_fused_merge`` / ``interpret`` boolean triple:

* ``"ref"``        — the frozen ``repro.kernels.ref`` oracle through XLA
  (gather-then-reduce distances, stable merges). The correctness contract:
  every other backend is tested against it. This is the **default** at every
  public entry point, so existing bit-exact parity guarantees (batched vs
  legacy vs sharded) are untouched unless a caller opts in.
* ``"xla_matmul"`` — MXU/BLAS-form distances over the corpus-norm cache:
  ``d(x, q) = ‖x‖² − 2·⟨x, q⟩ + ‖q‖²`` (resp. plain dot products for
  ip/cosine) with ``‖x‖²`` (and inverse norms for cosine) precomputed once
  per corpus in a :class:`CorpusView`. The inner reduce becomes a
  ``dot_general`` that hits BLAS on CPU and the MXU on TPU, and the per-wave
  flop count drops by ~⅓ (the subtract-square pass disappears). Same math
  as the oracle up to fp association — *tolerance* parity, not bit parity.
* ``"pallas"``     — the fused TPU kernels (``repro.kernels.l2_topk``):
  matmul-form scoring tile with the norm cache as an extra operand, plus
  the payload-carrying bitonic pool merge (lane-width padded).
  ``"pallas-interpret"`` is the same kernels under ``interpret=True`` — the
  CPU-testable form used by the parity grid and CI.
* ``"auto"``       — ``"pallas"`` when a TPU is present, else
  ``"xla_matmul"``. The deployment knob: resolves against the runtime's
  device set, never silently at import time.

The legacy boolean kwargs are kept as deprecated shims: passing any of them
explicitly still works (mapped onto the equivalent Backend) and emits a
``DeprecationWarning`` exactly once per (call-site function, kwarg) pair.

**Corpus-norm cache invalidation**: a :class:`CorpusView` is an immutable
snapshot of ``(rows, ‖x‖², 1/‖x‖)``. jax arrays cannot be mutated in place,
so "mutating the corpus" always means producing a *new* array — build a new
view with :func:`as_corpus_view` at that point; holding the old view against
a new corpus is the only way to get stale norms, and nothing in the engine
does it (the serving engine builds its view once per engine lifetime,
alongside the index, which is itself corpus-immutable).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

BACKEND_NAMES = ("ref", "xla_matmul", "pallas")

#: epsilon under the cosine rsqrt — must match ``repro.kernels.ref`` so the
#: matmul form agrees with the oracle on (near-)zero rows: a zero row (e.g.
#: uneven-shard padding) carries ``‖x‖² = 0`` and a *finite* inverse norm,
#: so its cosine distance is exactly 1.0 in every backend, never NaN.
NORM_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class Backend:
    """Resolved kernel-dispatch choice (hashable — safe as a jit static).

    ``fused_merge`` overrides the merge route only: ``None`` (default)
    derives it from the backend name (the bitonic kernel iff ``pallas``);
    the legacy ``use_fused_merge`` shim maps onto it.
    """

    name: str  # "ref" | "xla_matmul" | "pallas"
    interpret: bool = False  # run Pallas bodies in interpret mode (CPU CI)
    fused_merge: bool | None = None

    def __post_init__(self):
        if self.name not in BACKEND_NAMES:
            raise ValueError(
                f"backend must be one of {BACKEND_NAMES}, got {self.name!r}")

    @property
    def use_pallas(self) -> bool:
        return self.name == "pallas"

    @property
    def matmul(self) -> bool:
        """Score in matmul form over the corpus-norm cache?"""
        return self.name in ("xla_matmul", "pallas")

    @property
    def merge_pallas(self) -> bool:
        """Route pool merges through the Pallas bitonic network?"""
        if self.fused_merge is not None:
            return self.fused_merge
        return self.name == "pallas"


REF = Backend("ref")


def _tpu_present() -> bool:
    try:
        return any(d.platform == "tpu" for d in jax.devices())
    except RuntimeError:  # no backend initialized at all
        return False


# one DeprecationWarning per (function, kwarg) pair for the whole process —
# the shims must nudge, not spam a hot loop's logs
_warned: set[tuple[str, str]] = set()


def warn_deprecated_knob(func: str, kwarg: str) -> None:
    key = (func, kwarg)
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(
        f"{func}(..., {kwarg}=) is deprecated; pass backend= instead "
        "('ref' | 'xla_matmul' | 'pallas' | 'pallas-interpret' | 'auto' "
        "or a repro.kernels.Backend)",
        DeprecationWarning, stacklevel=3)


def resolve_backend(
    backend: str | Backend | None = None,
    *,
    use_pallas: bool | None = None,
    use_fused_merge: bool | None = None,
    interpret: bool | None = None,
    default: str = "ref",
    _caller: str = "repro.kernels",
) -> Backend:
    """Normalize the backend knob (or the legacy boolean shims) to a Backend.

    Accepted ``backend`` values: a :class:`Backend`, one of
    ``"ref" | "xla_matmul" | "pallas" | "pallas-interpret" | "auto"``, or
    None. ``"auto"`` resolves against the runtime device set (pallas on
    TPU, xla_matmul otherwise). With ``backend=None`` the legacy kwargs
    decide — each one explicitly passed emits a once-per-call-site
    ``DeprecationWarning`` — and when nothing at all is passed the
    ``default`` (the frozen oracle) is returned.
    """
    if backend is not None:
        if isinstance(backend, Backend):
            return backend
        if backend == "auto":
            return Backend("pallas" if _tpu_present() else "xla_matmul")
        if backend == "pallas-interpret":
            return Backend("pallas", interpret=True)
        return Backend(backend)
    name = default
    fused = None
    interp = False
    legacy = (use_pallas is not None or use_fused_merge is not None
              or interpret is not None)
    if legacy:
        # the historical kwargs were independent: use_pallas only routed
        # the *scoring* kernels and defaulted the merge to the stable XLA
        # cut (use_fused_merge=False) — so a shimmed call must not derive
        # fused_merge from the backend name the way the new knob does
        fused = bool(use_fused_merge) if use_fused_merge is not None else False
    if use_pallas is not None:
        warn_deprecated_knob(_caller, "use_pallas")
        name = "pallas" if use_pallas else default
    if use_fused_merge is not None:
        warn_deprecated_knob(_caller, "use_fused_merge")
    if interpret is not None:
        warn_deprecated_knob(_caller, "interpret")
        interp = bool(interpret)
    return Backend(name, interpret=interp, fused_merge=fused)


class CorpusView(NamedTuple):
    """Immutable corpus snapshot + the per-row norm cache (a pytree).

    ``rows`` keeps the corpus dtype untouched (a bf16/f16 corpus is *not*
    upcast — the cache adds 8 bytes/row of f32 norms, not a second f32
    corpus); ``sq_norms`` is ``‖x_i‖²`` and ``inv_norms`` is
    ``1/√(‖x_i‖² + NORM_EPS)``, both f32. Zero rows (uneven-shard padding)
    carry ``sq_norms == 0`` and a finite ``inv_norms``, so they score 0
    under sqeuclidean-vs-origin and exactly 1.0 under cosine — padding
    never pollutes any metric. Under the corpus mesh the norms shard with
    the rows (same contiguous blocks), so the cache adds nothing to the
    wave's psum traffic.

    See the module docstring for the invalidation contract: views are
    snapshots; a new corpus array needs a new view.
    """

    rows: Array  # (N, dim) — corpus, original dtype
    sq_norms: Array  # (N,) f32 ‖x‖²
    inv_norms: Array  # (N,) f32 1/√(‖x‖² + NORM_EPS)

    @property
    def n(self) -> int:
        return self.rows.shape[0]

    @property
    def dim(self) -> int:
        return self.rows.shape[1]


def as_corpus_view(corpus: Array | CorpusView) -> CorpusView:
    """Build (or pass through) the norm cache for a corpus.

    Idempotent: a :class:`CorpusView` is returned unchanged, so call sites
    can accept either form and the norms are only ever computed once per
    corpus — build the view *outside* any hot loop and thread it through.
    """
    if isinstance(corpus, CorpusView):
        return corpus
    sq = jnp.sum(jnp.square(corpus.astype(jnp.float32)), axis=-1)
    return CorpusView(
        rows=corpus,
        sq_norms=sq,
        inv_norms=jax.lax.rsqrt(sq + NORM_EPS),
    )


def corpus_rows(corpus: Array | CorpusView) -> Array:
    """The raw (N, dim) rows of either corpus form."""
    return corpus.rows if isinstance(corpus, CorpusView) else corpus
