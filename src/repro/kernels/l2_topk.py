"""Fused gather → score → beam-merge kernels (the bi-metric beam step).

This is the query-time hot loop of the paper's method on TPU: each batched
greedy-search step scores the expanded frontier's fanout against the queries
and merges the results into the per-query pools. Two kernels:

* ``gather_score`` — scalar-prefetched candidate ids drive the BlockSpec index
  map, so corpus rows stream HBM→VMEM *by id* (no XLA gather materialization)
  and the metric reduction (l2 / sqeuclidean / ip / cosine, matching
  ``repro.core.distances``) happens in VMEM next to the data. With the
  ``norms`` operand (the corpus-norm cache of
  ``repro.kernels.backend.CorpusView``, packed by :func:`pack_norms`), the
  score is computed in **matmul form** — ``‖x‖² − 2·⟨x, q⟩ + ‖q‖²`` with the
  row-norm term streamed from the cache instead of re-reduced per lane, which
  drops the subtract-square pass (~⅓ of the per-wave flops) and leaves one
  fused dot per lane. Without ``norms`` the historical gather-then-reduce
  body runs unchanged. ``gather_l2`` is the historical sqeuclidean entry
  point, kept as an alias;
* ``beam_merge_topk`` — bitonic merge network over the (beam ‖ fanout) pair
  in VMEM for the whole query batch per invocation, compare-exchange
  implemented with roll/where so it lowers to vector selects (no sort
  primitive needed on TPU). Optionally carries an int32 payload lane
  (the pool's ``expanded`` flags) through the same permutation network so
  the batched engine can merge its full (ids, dists, expanded) pool state
  in one call. The network is padded to a power of two **and to the
  128-wide TPU lane** (``MERGE_LANE``), and the output block is
  lane-aligned too (sliced back to L outside the kernel) — so
  non-power-of-two and non-lane-multiple pools run the fused merge instead
  of being excluded by tiling constraints.

Pure-jnp oracles for both live in ``repro.kernels.ref`` (the CPU/interpret
fallback path used by the core engine off-TPU); backend selection for all of
this lives in ``repro.kernels.backend`` / ``repro.kernels.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import NORM_EPS, CorpusView

Array = jax.Array

VALID_METRICS = ("l2", "sqeuclidean", "ip", "cosine")

MERGE_LANE = 128  # TPU vector lane width — merge rows are padded to it


def pack_norms(view: CorpusView) -> Array:
    """(N, 2) f32 kernel operand: column 0 = ‖x‖², column 1 = 1/‖x‖.

    One row per corpus row so the same prefetched id that streams the
    corpus row also streams its cached norms (the BlockSpec index maps are
    identical).
    """
    return jnp.stack([view.sq_norms, view.inv_norms], axis=1)


def pack_row_meta(view: CorpusView) -> Array:
    """(N, 2) or (N, 4) f32 row-metadata operand for the scoring tile.

    The generalization of :func:`pack_norms`: columns ``[‖x‖², 1/‖x‖]``
    for a raw view, ``[‖x‖², 1/‖x‖, scale, zero_point]`` for a quantized
    one (the zero-point column is 0.0 for the symmetric fp8 modes, so one
    in-tile dequant ``(code - zp) * scale`` serves int8 and fp8 alike).
    Streams by the same prefetched id as the corpus row; the column count
    selects the kernel body in :func:`gather_score`.
    """
    cols = [view.sq_norms, view.inv_norms]
    if view.scales is not None:
        cols.append(view.scales.astype(jnp.float32))
        zp = view.zero_points
        cols.append(jnp.zeros_like(view.scales) if zp is None
                    else zp.astype(jnp.float32))
    return jnp.stack(cols, axis=1)


# --------------------------------------------------------------------------
# per-lane scoring bodies — one definition each, shared by the global and
# shard-local kernels (only the masking tail differs between those)
# --------------------------------------------------------------------------
def _metric_score(q, row, *, metric: str):
    """Gather-then-reduce per-lane score (matches ``ref.gather_score_ref``)."""
    if metric in ("l2", "sqeuclidean"):
        diff = q - row
        d = jnp.sum(diff * diff)
        return jnp.sqrt(d) if metric == "l2" else d
    if metric == "ip":
        return -jnp.sum(q * row)
    # cosine
    qn = jax.lax.rsqrt(jnp.sum(q * q) + NORM_EPS)
    rn = jax.lax.rsqrt(jnp.sum(row * row) + NORM_EPS)
    return 1.0 - jnp.sum(q * row) * qn * rn


def _metric_score_mm(q, row, nsq, ninv, *, metric: str):
    """Matmul-form per-lane score over the cached row norms."""
    dot = jnp.dot(row, q, preferred_element_type=jnp.float32)
    if metric in ("l2", "sqeuclidean"):
        # the expansion can dip epsilon-negative where the oracle is ~0
        d = jnp.maximum(nsq - 2.0 * dot + jnp.sum(q * q), 0.0)
        return jnp.sqrt(d) if metric == "l2" else d
    if metric == "ip":
        return -dot
    return 1.0 - dot * jax.lax.rsqrt(jnp.sum(q * q) + NORM_EPS) * ninv


# --------------------------------------------------------------------------
# fused gather + score (metric-parameterized; gather-then-reduce form)
# --------------------------------------------------------------------------
def _gather_score_kernel(ids_ref, q_ref, row_ref, o_ref, *, metric: str):
    b = pl.program_id(0)
    k = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)  # (dim,) — query b
    row = row_ref[0].astype(jnp.float32)  # (dim,) — corpus[ids[b, k]]
    d = _metric_score(q, row, metric=metric)
    valid = ids_ref[b, k] >= 0
    o_ref[0, 0] = jnp.where(valid, d, float("inf"))


# --------------------------------------------------------------------------
# matmul-form scoring tile: norms streamed from the corpus-norm cache
# --------------------------------------------------------------------------
def _gather_score_mm_kernel(ids_ref, q_ref, row_ref, nrm_ref, o_ref, *,
                            metric: str):
    b = pl.program_id(0)
    k = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    row = row_ref[0].astype(jnp.float32)
    d = _metric_score_mm(q, row, nrm_ref[0, 0], nrm_ref[0, 1], metric=metric)
    valid = ids_ref[b, k] >= 0
    o_ref[0, 0] = jnp.where(valid, d, float("inf"))


def _gather_score_mm_quant_kernel(ids_ref, q_ref, row_ref, nrm_ref, o_ref, *,
                                  metric: str):
    """Matmul-form tile over quantized rows: dequant in-register.

    ``row_ref`` streams the int8/fp8 codes (the HBM traffic is the codes,
    not f32); the dequant ``(code - zp) * scale`` runs on the VMEM-resident
    vector right before the dot, with scale/zp from columns 2/3 of the
    row-metadata operand — ``ref.dequant_rows_ref`` semantics exactly, and
    the cached norms (columns 0/1) already describe the dequantized row.
    """
    b = pl.program_id(0)
    k = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    row = (row_ref[0].astype(jnp.float32) - nrm_ref[0, 3]) * nrm_ref[0, 2]
    d = _metric_score_mm(q, row, nrm_ref[0, 0], nrm_ref[0, 1], metric=metric)
    valid = ids_ref[b, k] >= 0
    o_ref[0, 0] = jnp.where(valid, d, float("inf"))


def gather_score(corpus: Array, queries: Array, ids: Array, *,
                 metric: str = "sqeuclidean", norms: Array | None = None,
                 interpret: bool = False) -> Array:
    """corpus (N, dim); queries (B, dim); ids (B, K) -> (B, K) dissimilarities.

    Ids < 0 are padding and map to +inf. The metric names and conventions
    match ``repro.core.distances`` ("ip" is negated, "cosine" is one-minus).
    With ``norms`` (the packed (N, 2) or (N, 4) row-metadata operand, see
    :func:`pack_row_meta`) the matmul-form tile runs — the row-norm reduce
    is replaced by a cached load streamed by the same prefetched id; the
    4-column form additionally dequantizes int8/fp8 codes in-register
    before the dot.
    """
    if metric not in VALID_METRICS:
        raise ValueError(f"metric must be one of {VALID_METRICS}, got {metric!r}")
    b, dim = queries.shape
    k = ids.shape[1]
    in_specs = [
        pl.BlockSpec((1, dim), lambda bi, ki, ids: (bi, 0)),
        # the gather: block row chosen by the prefetched id
        pl.BlockSpec(
            (1, dim),
            lambda bi, ki, ids: (jnp.maximum(ids[bi, ki], 0), 0),
        ),
    ]
    operands = [queries, corpus]
    if norms is None:
        kernel = functools.partial(_gather_score_kernel, metric=metric)
    else:
        ncols = norms.shape[1]
        body = (_gather_score_mm_kernel if ncols == 2
                else _gather_score_mm_quant_kernel)
        kernel = functools.partial(body, metric=metric)
        # the row metadata streams by the same prefetched id as the row
        in_specs.append(pl.BlockSpec(
            (1, ncols), lambda bi, ki, ids: (jnp.maximum(ids[bi, ki], 0), 0)))
        operands.append(norms.astype(jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda bi, ki, ids: (bi, ki)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), *operands)


def gather_l2(corpus: Array, queries: Array, ids: Array, *,
              interpret: bool = False) -> Array:
    """corpus (N, dim); queries (B, dim); ids (B, K) -> (B, K) sq-l2 dists."""
    return gather_score(corpus, queries, ids, metric="sqeuclidean",
                        interpret=interpret)


def _gather_score_local_kernel(off_ref, ids_ref, q_ref, row_ref, o_ref, *,
                               metric: str, n_local: int):
    b = pl.program_id(0)
    k = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    row = row_ref[0].astype(jnp.float32)
    d = _metric_score(q, row, metric=metric)
    loc = ids_ref[b, k] - off_ref[0]
    owned = (ids_ref[b, k] >= 0) & (loc >= 0) & (loc < n_local)
    # psum identity on foreign/padding lanes — see ref.gather_score_local_ref
    o_ref[0, 0] = jnp.where(owned, d, 0.0)


def _gather_score_local_mm_kernel(off_ref, ids_ref, q_ref, row_ref, nrm_ref,
                                  o_ref, *, metric: str, n_local: int):
    b = pl.program_id(0)
    k = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    row = row_ref[0].astype(jnp.float32)
    d = _metric_score_mm(q, row, nrm_ref[0, 0], nrm_ref[0, 1], metric=metric)
    loc = ids_ref[b, k] - off_ref[0]
    owned = (ids_ref[b, k] >= 0) & (loc >= 0) & (loc < n_local)
    o_ref[0, 0] = jnp.where(owned, d, 0.0)


def _gather_score_local_mm_quant_kernel(off_ref, ids_ref, q_ref, row_ref,
                                        nrm_ref, o_ref, *, metric: str,
                                        n_local: int):
    # shard-local twin of _gather_score_mm_quant_kernel: in-register dequant
    # of the streamed codes, psum identity on foreign/padding lanes
    b = pl.program_id(0)
    k = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)
    row = (row_ref[0].astype(jnp.float32) - nrm_ref[0, 3]) * nrm_ref[0, 2]
    d = _metric_score_mm(q, row, nrm_ref[0, 0], nrm_ref[0, 1], metric=metric)
    loc = ids_ref[b, k] - off_ref[0]
    owned = (ids_ref[b, k] >= 0) & (loc >= 0) & (loc < n_local)
    o_ref[0, 0] = jnp.where(owned, d, 0.0)


def gather_score_local(corpus_local: Array, queries: Array, ids: Array,
                       offset: Array, *, metric: str = "sqeuclidean",
                       norms: Array | None = None,
                       interpret: bool = False) -> Array:
    """Shard-local fused gather→score over *global* ids (see ref oracle).

    ``corpus_local`` (n_local, dim) is this shard's contiguous row block
    starting at global row ``offset`` (a traced scalar — inside ``shard_map``
    it is ``axis_index * n_local``). Owned lanes stream their local row
    HBM→VMEM by remapped id exactly like :func:`gather_score`; foreign and
    padding lanes emit the psum identity 0.0. ``norms`` is the *local*
    block's packed row metadata ((n_local, 2) raw / (n_local, 4)
    quantized — it shards with the rows) and selects the matmul-form tile,
    with in-register dequant for the 4-column form.
    """
    if metric not in VALID_METRICS:
        raise ValueError(f"metric must be one of {VALID_METRICS}, got {metric!r}")
    b, dim = queries.shape
    k = ids.shape[1]
    n_local = corpus_local.shape[0]
    offset = jnp.asarray(offset, jnp.int32).reshape(1)
    in_specs = [
        pl.BlockSpec((1, dim), lambda bi, ki, off, ids: (bi, 0)),
        # the gather: local block row chosen by the remapped global id
        pl.BlockSpec(
            (1, dim),
            lambda bi, ki, off, ids: (
                jnp.clip(ids[bi, ki] - off[0], 0, n_local - 1), 0),
        ),
    ]
    operands = [queries, corpus_local]
    if norms is None:
        kernel = functools.partial(_gather_score_local_kernel, metric=metric,
                                   n_local=n_local)
    else:
        ncols = norms.shape[1]
        body = (_gather_score_local_mm_kernel if ncols == 2
                else _gather_score_local_mm_quant_kernel)
        kernel = functools.partial(body, metric=metric, n_local=n_local)
        in_specs.append(pl.BlockSpec(
            (1, ncols),
            lambda bi, ki, off, ids: (
                jnp.clip(ids[bi, ki] - off[0], 0, n_local - 1), 0)))
        operands.append(norms.astype(jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # offset, then the candidate ids
        grid=(b, k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1), lambda bi, ki, off, ids: (bi, ki)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, k), jnp.float32),
        interpret=interpret,
    )(offset, ids.astype(jnp.int32), *operands)


# --------------------------------------------------------------------------
# bitonic beam merge
# --------------------------------------------------------------------------
def _xor_permute(x: Array, j: int) -> Array:
    """x (1, n) -> x with lanes permuted by index XOR j (j a power of two).

    Implemented as a static reshape + flip (pairs of j-strided halves), which
    lowers to vector shuffles on TPU — no dynamic gather.
    """
    n = x.shape[1]
    return x.reshape(n // (2 * j), 2, j)[:, ::-1, :].reshape(1, n)


def _merge_kernel(bi_ref, bd_ref, bf_ref, ci_ref, cd_ref, cf_ref,
                  oi_ref, od_ref, of_ref, *, n: int):
    d = jnp.concatenate([bd_ref[...], cd_ref[...]], axis=1).astype(jnp.float32)
    idx = jnp.concatenate([bi_ref[...], ci_ref[...]], axis=1)
    flg = jnp.concatenate([bf_ref[...], cf_ref[...]], axis=1)
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    # full bitonic sort (ascending) of the 2^m-length sequence
    m = n.bit_length() - 1
    for stage in range(1, m + 1):
        span = 1 << stage
        desc = (pos & span) != 0
        for sub in range(stage - 1, -1, -1):
            j = 1 << sub
            d_p = _xor_permute(d, j)
            i_p = _xor_permute(idx, j)
            f_p = _xor_permute(flg, j)
            is_lo = (pos & j) == 0
            want_min = desc ^ is_lo
            take_self = jnp.where(want_min, d <= d_p, d >= d_p)
            d = jnp.where(take_self, d, d_p)
            idx = jnp.where(take_self, idx, i_p)
            flg = jnp.where(take_self, flg, f_p)
    w = oi_ref.shape[1]
    oi_ref[...] = idx[:, :w]
    od_ref[...] = d[:, :w].astype(od_ref.dtype)
    of_ref[...] = flg[:, :w]


def beam_merge_topk(beam_ids: Array, beam_dists: Array, cand_ids: Array,
                    cand_dists: Array, *, beam_flags: Array | None = None,
                    cand_flags: Array | None = None, interpret: bool = False):
    """Merge (B, L) beam and (B, K) candidates -> best-(B, L). Bitonic in VMEM.

    One invocation handles the whole query batch (grid over B). When
    ``beam_flags`` is given, an int32 payload lane rides through the same
    compare-exchange network (the batched engine's ``expanded`` markers) and
    a third output is returned. Ties in distance (inf padding included) are
    broken by the network, not by input position — callers needing the
    stable-merge contract use ``repro.kernels.ref.merge_pool_batch_ref``.

    The network length is padded to a power of two **and** to
    :data:`MERGE_LANE` (the TPU vector lane width), and the output block is
    lane-aligned and sliced back to L after the call — arbitrary (L, K)
    shapes run the fused network. The output distances keep the inputs'
    promoted dtype (the compare-exchange runs on an exact f32 embedding for
    bf16/f16), so half-precision pools round-trip without upcasting.
    """
    b, L = beam_ids.shape
    k = cand_ids.shape[1]
    with_flags = beam_flags is not None
    if beam_flags is None:
        beam_flags = jnp.zeros((b, L), jnp.int32)
    if cand_flags is None:
        cand_flags = jnp.zeros((b, k), jnp.int32)
    d_dtype = jnp.result_type(beam_dists.dtype, cand_dists.dtype)
    n = L + k
    # power-of-two for the bitonic network, lane width for the TPU tiling
    n_pad = max(1 << (n - 1).bit_length(), MERGE_LANE)
    pad = n_pad - n
    if pad:
        cand_ids = jnp.pad(cand_ids, ((0, 0), (0, pad)), constant_values=-1)
        cand_dists = jnp.pad(cand_dists, ((0, 0), (0, pad)),
                             constant_values=jnp.inf)
        cand_flags = jnp.pad(cand_flags, ((0, 0), (0, pad)))
        k = k + pad
    # lane-aligned output block, sliced back to L below
    w = min(n_pad, -(-L // MERGE_LANE) * MERGE_LANE)
    kernel = functools.partial(_merge_kernel, n=n_pad)
    oi, od, of = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, L), lambda bi: (bi, 0)),
            pl.BlockSpec((1, L), lambda bi: (bi, 0)),
            pl.BlockSpec((1, L), lambda bi: (bi, 0)),
            pl.BlockSpec((1, k), lambda bi: (bi, 0)),
            pl.BlockSpec((1, k), lambda bi: (bi, 0)),
            pl.BlockSpec((1, k), lambda bi: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, w), lambda bi: (bi, 0)),
            pl.BlockSpec((1, w), lambda bi: (bi, 0)),
            pl.BlockSpec((1, w), lambda bi: (bi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, w), beam_ids.dtype),
            jax.ShapeDtypeStruct((b, w), d_dtype),
            jax.ShapeDtypeStruct((b, w), jnp.int32),
        ],
        interpret=interpret,
    )(beam_ids, beam_dists.astype(jnp.float32),
      beam_flags.astype(jnp.int32), cand_ids,
      cand_dists.astype(jnp.float32), cand_flags.astype(jnp.int32))
    oi, od, of = oi[:, :L], od[:, :L], of[:, :L]
    if with_flags:
        return oi, od, of
    return oi, od
