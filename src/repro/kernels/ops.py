"""Jitted dispatch layer for the Pallas kernels.

``use_pallas`` selects the TPU kernel; the default (False) runs the ref.py
oracle through XLA — that path is used on CPU (tests, dry-run lowering) and is
mathematically identical. Kernel tests run the Pallas bodies with
``interpret=True`` and assert allclose against the same refs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import embedding_bag as _bag
from repro.kernels import flash_attention as _fa
from repro.kernels import l2_topk as _lt

Array = jax.Array


def flash_attention(q, k, v, *, causal=True, sm_scale=None,
                    use_pallas=False, interpret=False, block_q=128, block_k=128):
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)


def flash_decode(q, k, v, *, length, sm_scale=None, use_pallas=False,
                 interpret=False, block_k=512):
    if use_pallas:
        return _fa.flash_decode(q, k, v, length=length, sm_scale=sm_scale,
                                block_k=block_k, interpret=interpret)
    return ref.flash_decode_ref(q, k, v, length=length, sm_scale=sm_scale)


def gather_l2(corpus, queries, ids, *, use_pallas=False, interpret=False):
    if use_pallas:
        return _lt.gather_l2(corpus, queries, ids, interpret=interpret)
    return ref.l2_gather_dists_ref(corpus, queries, ids)


def beam_merge_topk(beam_ids, beam_dists, cand_ids, cand_dists, *,
                    use_pallas=False, interpret=False):
    if use_pallas:
        return _lt.beam_merge_topk(beam_ids, beam_dists, cand_ids, cand_dists,
                                   interpret=interpret)
    return ref.beam_merge_topk_ref(beam_ids, beam_dists, cand_ids, cand_dists)


def embedding_bag(table, idx, *, mode="sum", use_pallas=False, interpret=False):
    if use_pallas:
        return _bag.embedding_bag(table, idx, mode=mode, interpret=interpret)
    return ref.embedding_bag_ref(table, idx, mode=mode)
