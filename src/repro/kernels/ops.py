"""Jitted dispatch layer for the Pallas kernels.

``use_pallas`` selects the TPU kernel; the default (False) runs the ref.py
oracle through XLA — that path is used on CPU (tests, dry-run lowering) and is
mathematically identical. Kernel tests run the Pallas bodies with
``interpret=True`` and assert allclose against the same refs.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import embedding_bag as _bag
from repro.kernels import flash_attention as _fa
from repro.kernels import l2_topk as _lt

Array = jax.Array


def flash_attention(q, k, v, *, causal=True, sm_scale=None,
                    use_pallas=False, interpret=False, block_q=128, block_k=128):
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)


def flash_decode(q, k, v, *, length, sm_scale=None, use_pallas=False,
                 interpret=False, block_k=512):
    if use_pallas:
        return _fa.flash_decode(q, k, v, length=length, sm_scale=sm_scale,
                                block_k=block_k, interpret=interpret)
    return ref.flash_decode_ref(q, k, v, length=length, sm_scale=sm_scale)


def gather_score(corpus, queries, ids, *, metric="sqeuclidean",
                 use_pallas=False, interpret=False):
    """Fused gather→score for a whole query batch: (B, K) ids -> (B, K)."""
    if use_pallas:
        return _lt.gather_score(corpus, queries, ids, metric=metric,
                                interpret=interpret)
    return ref.gather_score_ref(corpus, queries, ids, metric=metric)


def gather_l2(corpus, queries, ids, *, use_pallas=False, interpret=False):
    return gather_score(corpus, queries, ids, metric="sqeuclidean",
                        use_pallas=use_pallas, interpret=interpret)


def gather_score_local(corpus_local, queries, ids, offset, *,
                       metric="sqeuclidean", use_pallas=False,
                       interpret=False):
    """Shard-local gather→score over global ids: (B, K) -> (B, K) partials.

    Owned lanes (offset <= id < offset + n_local) carry the exact distance;
    foreign and padding lanes carry the psum identity 0.0, so a
    ``lax.psum`` over the shard axis reconstructs the unsharded
    :func:`gather_score` wave bit-exactly (each id has one owner and
    x + 0.0 == x). The sharded engine masks ids < 0 to +inf after the psum.
    """
    if use_pallas:
        return _lt.gather_score_local(corpus_local, queries, ids, offset,
                                      metric=metric, interpret=interpret)
    return ref.gather_score_local_ref(corpus_local, queries, ids, offset,
                                      metric=metric)


def local_topk(ids, dists, k):
    """Per-row best-``k`` by distance, ties to the lowest index (stable).

    The per-shard candidate cut applied *before* an all-gather merge: each
    shard sends only its k best (id, dist) pairs instead of its whole pool,
    shrinking the merge collective from O(n_local) to O(k) per query.

    ``k`` may exceed the row width (small shard pools): the cut is clamped
    to the width and the result padded with (-1, +inf) sentinel lanes, which
    sort last in any downstream merge and are dropped by its final cut.
    """
    width = ids.shape[1]
    kk = min(k, width)
    neg, order = jax.lax.top_k(-dists.astype(jnp.float32), kk)
    out_ids = jnp.take_along_axis(ids, order, axis=1)
    out_dists = -neg
    if kk < k:
        b = ids.shape[0]
        out_ids = jnp.concatenate(
            [out_ids, jnp.full((b, k - kk), -1, out_ids.dtype)], axis=1)
        out_dists = jnp.concatenate(
            [out_dists, jnp.full((b, k - kk), jnp.inf, out_dists.dtype)],
            axis=1)
    return out_ids, out_dists


# Padding sentinel for the sorted-membership dedup arrays: larger than any
# real vertex id, so pads always sort to the tail of an ascending row.
SET_PAD = jnp.iinfo(jnp.int32).max


def sorted_set_merge(set_ids, new_ids):
    """Insert a wave of ids into per-row ascending membership arrays.

    ``set_ids`` (B, C) int32 ascending with :data:`SET_PAD` padding;
    ``new_ids`` (B, K) int32 with masked lanes set to ``SET_PAD``. Returns
    the updated (B, C) ascending rows holding the C smallest of the union —
    which is *every* real entry as long as the caller never inserts more
    than C ids total (the quota guarantee of the beam engine: one insertion
    per counted distance call, n_calls <= quota <= C).

    The merge is the same smallest-C cut the pool merges take with
    tie-stable ``lax.top_k`` — but on pure int keys a stable ascending
    ``jnp.sort`` of the concatenated row computes it identically (equal
    ids are indistinguishable) and measures ~5x faster on CPU than
    ``top_k`` at k = C (XLA's TopK is tuned for k << width; the dedup cut
    keeps *most* of the row). Duplicate entries (the E=1 engine's
    duplicate-adjacency-lane quirk) are kept as distinct slots, exactly
    mirroring their ``n_calls`` cost.
    """
    c = set_ids.shape[1]
    if c == 0:  # zero-capacity set (quota-0 rows): insertion is a no-op
        return set_ids
    cat = jnp.concatenate([set_ids, new_ids.astype(jnp.int32)], axis=1)
    return jnp.sort(cat, axis=1)[:, :c]


def sorted_set_lookup(set_ids, ids):
    """(B, K) bool membership of ``ids`` in ascending per-row sets.

    One ``searchsorted`` per row (vmapped); lanes with id < 0 return False.
    ``SET_PAD`` pads never match a real id, so no validity mask is needed.
    """
    c = set_ids.shape[1]
    if c == 0:
        return jnp.zeros(ids.shape, bool)
    pos = jax.vmap(jnp.searchsorted)(set_ids, ids)
    hit = jnp.take_along_axis(set_ids, jnp.minimum(pos, c - 1), axis=1) == ids
    return (ids >= 0) & hit


def sorted_set_unique_count(set_ids):
    """(B,) distinct real ids per ascending row — the popcount the bitmap's
    ``scored.sum()`` would give (duplicate slots collapse, pads don't count).
    """
    b, c = set_ids.shape
    if c == 0:
        return jnp.zeros((b,), jnp.int32)
    first = jnp.ones((b, 1), bool)
    distinct = jnp.concatenate(
        [first, set_ids[:, 1:] != set_ids[:, :-1]], axis=1)
    return (distinct & (set_ids != SET_PAD)).sum(axis=1, dtype=jnp.int32)


def beam_merge_topk(beam_ids, beam_dists, cand_ids, cand_dists, *,
                    use_pallas=False, interpret=False):
    if use_pallas:
        return _lt.beam_merge_topk(beam_ids, beam_dists, cand_ids, cand_dists,
                                   interpret=interpret)
    return ref.beam_merge_topk_ref(beam_ids, beam_dists, cand_ids, cand_dists)


def merge_pool_batch(pool_ids, pool_dists, expanded, cand_ids, cand_dists, *,
                     use_pallas=False, interpret=False):
    """Batched (beam ‖ fanout) pool merge with the ``expanded`` payload.

    The XLA path implements the *stable* merge contract of
    ``ref.merge_pool_batch_ref`` (ties, including inf padding, resolve to the
    earlier position — so an all-masked wave is an exact no-op) via
    ``lax.top_k``, which XLA guarantees returns equal keys lowest-index
    first; it is bit-identical to the argsort oracle but ~3x faster on CPU.
    The Pallas path runs the bitonic network with the payload lane; it
    returns the same multiset but may order equal distances differently.
    """
    if use_pallas:
        oi, od, of = _lt.beam_merge_topk(
            pool_ids, pool_dists, cand_ids, cand_dists,
            beam_flags=expanded.astype(jnp.int32),
            cand_flags=jnp.zeros(cand_ids.shape, jnp.int32),
            interpret=interpret)
        return oi, od, of.astype(bool)
    p = pool_ids.shape[1]
    ids = jnp.concatenate([pool_ids, cand_ids], axis=1)
    d = jnp.concatenate([pool_dists, cand_dists.astype(jnp.float32)], axis=1)
    exp = jnp.concatenate(
        [expanded, jnp.zeros(cand_ids.shape, dtype=bool)], axis=1)
    _, order = jax.lax.top_k(-d, p)
    take = lambda a: jnp.take_along_axis(a, order, axis=1)  # noqa: E731
    return take(ids), take(d), take(exp)


def embedding_bag(table, idx, *, mode="sum", use_pallas=False, interpret=False):
    if use_pallas:
        return _bag.embedding_bag(table, idx, mode=mode, interpret=interpret)
    return ref.embedding_bag_ref(table, idx, mode=mode)
