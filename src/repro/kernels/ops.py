"""Jitted dispatch layer for the Pallas kernels.

``use_pallas`` selects the TPU kernel; the default (False) runs the ref.py
oracle through XLA — that path is used on CPU (tests, dry-run lowering) and is
mathematically identical. Kernel tests run the Pallas bodies with
``interpret=True`` and assert allclose against the same refs.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels import embedding_bag as _bag
from repro.kernels import flash_attention as _fa
from repro.kernels import l2_topk as _lt

Array = jax.Array


def flash_attention(q, k, v, *, causal=True, sm_scale=None,
                    use_pallas=False, interpret=False, block_q=128, block_k=128):
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)


def flash_decode(q, k, v, *, length, sm_scale=None, use_pallas=False,
                 interpret=False, block_k=512):
    if use_pallas:
        return _fa.flash_decode(q, k, v, length=length, sm_scale=sm_scale,
                                block_k=block_k, interpret=interpret)
    return ref.flash_decode_ref(q, k, v, length=length, sm_scale=sm_scale)


def gather_score(corpus, queries, ids, *, metric="sqeuclidean",
                 use_pallas=False, interpret=False):
    """Fused gather→score for a whole query batch: (B, K) ids -> (B, K)."""
    if use_pallas:
        return _lt.gather_score(corpus, queries, ids, metric=metric,
                                interpret=interpret)
    return ref.gather_score_ref(corpus, queries, ids, metric=metric)


def gather_l2(corpus, queries, ids, *, use_pallas=False, interpret=False):
    return gather_score(corpus, queries, ids, metric="sqeuclidean",
                        use_pallas=use_pallas, interpret=interpret)


def gather_score_local(corpus_local, queries, ids, offset, *,
                       metric="sqeuclidean", use_pallas=False,
                       interpret=False):
    """Shard-local gather→score over global ids: (B, K) -> (B, K) partials.

    Owned lanes (offset <= id < offset + n_local) carry the exact distance;
    foreign and padding lanes carry the psum identity 0.0, so a
    ``lax.psum`` over the shard axis reconstructs the unsharded
    :func:`gather_score` wave bit-exactly (each id has one owner and
    x + 0.0 == x). The sharded engine masks ids < 0 to +inf after the psum.
    """
    if use_pallas:
        return _lt.gather_score_local(corpus_local, queries, ids, offset,
                                      metric=metric, interpret=interpret)
    return ref.gather_score_local_ref(corpus_local, queries, ids, offset,
                                      metric=metric)


def local_topk(ids, dists, k):
    """Per-row best-``k`` by distance, ties to the lowest index (stable).

    The per-shard candidate cut applied *before* an all-gather merge: each
    shard sends only its k best (id, dist) pairs instead of its whole pool,
    shrinking the merge collective from O(n_local) to O(k) per query.
    """
    neg, order = jax.lax.top_k(-dists.astype(jnp.float32), k)
    return jnp.take_along_axis(ids, order, axis=1), -neg


def beam_merge_topk(beam_ids, beam_dists, cand_ids, cand_dists, *,
                    use_pallas=False, interpret=False):
    if use_pallas:
        return _lt.beam_merge_topk(beam_ids, beam_dists, cand_ids, cand_dists,
                                   interpret=interpret)
    return ref.beam_merge_topk_ref(beam_ids, beam_dists, cand_ids, cand_dists)


def merge_pool_batch(pool_ids, pool_dists, expanded, cand_ids, cand_dists, *,
                     use_pallas=False, interpret=False):
    """Batched (beam ‖ fanout) pool merge with the ``expanded`` payload.

    The XLA path implements the *stable* merge contract of
    ``ref.merge_pool_batch_ref`` (ties, including inf padding, resolve to the
    earlier position — so an all-masked wave is an exact no-op) via
    ``lax.top_k``, which XLA guarantees returns equal keys lowest-index
    first; it is bit-identical to the argsort oracle but ~3x faster on CPU.
    The Pallas path runs the bitonic network with the payload lane; it
    returns the same multiset but may order equal distances differently.
    """
    if use_pallas:
        oi, od, of = _lt.beam_merge_topk(
            pool_ids, pool_dists, cand_ids, cand_dists,
            beam_flags=expanded.astype(jnp.int32),
            cand_flags=jnp.zeros(cand_ids.shape, jnp.int32),
            interpret=interpret)
        return oi, od, of.astype(bool)
    p = pool_ids.shape[1]
    ids = jnp.concatenate([pool_ids, cand_ids], axis=1)
    d = jnp.concatenate([pool_dists, cand_dists.astype(jnp.float32)], axis=1)
    exp = jnp.concatenate(
        [expanded, jnp.zeros(cand_ids.shape, dtype=bool)], axis=1)
    _, order = jax.lax.top_k(-d, p)
    take = lambda a: jnp.take_along_axis(a, order, axis=1)  # noqa: E731
    return take(ids), take(d), take(exp)


def embedding_bag(table, idx, *, mode="sum", use_pallas=False, interpret=False):
    if use_pallas:
        return _bag.embedding_bag(table, idx, mode=mode, interpret=interpret)
    return ref.embedding_bag_ref(table, idx, mode=mode)
