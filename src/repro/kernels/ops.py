"""Jitted dispatch layer for the search/serving kernels — one backend knob.

Every op takes ``backend=`` (``"ref" | "xla_matmul" | "pallas" |
"pallas-interpret" | "auto"`` or a resolved :class:`repro.kernels.backend.Backend`):

* ``ref`` (default) runs the frozen ``ref.py`` oracle through XLA — the
  correctness contract, bit-stable across PRs;
* ``xla_matmul`` scores waves in MXU/BLAS form over the corpus-norm cache
  (:class:`repro.kernels.backend.CorpusView`): ``‖x‖² − 2⟨x, q⟩ + ‖q‖²``
  instead of gather-subtract-square-reduce — ~⅓ fewer flops per wave and the
  inner reduce is a ``dot_general``;
* ``pallas`` runs the TPU kernels (``pallas-interpret`` = the same bodies
  under ``interpret=True``, the CPU-testable form the parity suite pins
  against ``ref``).

The historical ``use_pallas`` / ``use_fused_merge`` / ``interpret`` boolean
kwargs remain as deprecated shims (one ``DeprecationWarning`` per call site,
see ``repro.kernels.backend``). Ops that gather corpus rows accept either a
raw ``(N, dim)`` array or a prebuilt ``CorpusView`` — pass the view from
outside any hot loop so the norms are computed once per corpus.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import backend as _backend
from repro.kernels import ref
from repro.kernels import embedding_bag as _bag
from repro.kernels import flash_attention as _fa
from repro.kernels import l2_topk as _lt
from repro.kernels.backend import (NORM_EPS, CorpusView, as_corpus_view,
                                   corpus_rows)
from repro.kernels.backend import Backend, resolve_backend  # noqa: F401

Array = jax.Array


def _resolve(backend, use_pallas, interpret, caller, use_fused_merge=None,
             quantize=None):
    return _backend.resolve_backend(
        backend, use_pallas=use_pallas, use_fused_merge=use_fused_merge,
        interpret=interpret, quantize=quantize, _caller=caller)


def _view_for(corpus, be: Backend, caller: str):
    """Normalize the corpus input to what the backend scores.

    A prebuilt :class:`CorpusView` always wins (its residency is scored
    as-is; a conflicting ``be.quantize`` raises rather than requantizing).
    A raw array is wrapped in a view when the backend needs one (matmul
    form, or quantized residency requested) — per call, so hot loops
    should hand in prebuilt views.
    """
    if isinstance(corpus, CorpusView):
        if be.quantize is not None and corpus.quantize != be.quantize:
            raise ValueError(
                f"{caller}: backend asks quantize={be.quantize!r} but the "
                f"prebuilt view carries quantize={corpus.quantize!r}")
        return corpus
    if be.matmul or be.quantize is not None:
        return as_corpus_view(corpus, quantize=be.quantize)
    return corpus


def flash_attention(q, k, v, *, causal=True, sm_scale=None, backend=None,
                    use_pallas=None, interpret=None, block_q=128, block_k=128):
    be = _resolve(backend, use_pallas, interpret, "ops.flash_attention")
    if be.use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                                   block_q=block_q, block_k=block_k,
                                   interpret=be.interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)


def flash_decode(q, k, v, *, length, sm_scale=None, backend=None,
                 use_pallas=None, interpret=None, block_k=512):
    be = _resolve(backend, use_pallas, interpret, "ops.flash_decode")
    if be.use_pallas:
        return _fa.flash_decode(q, k, v, length=length, sm_scale=sm_scale,
                                block_k=block_k, interpret=be.interpret)
    return ref.flash_decode_ref(q, k, v, length=length, sm_scale=sm_scale)


# --------------------------------------------------------------------------
# wave scoring (the serving hot path)
# --------------------------------------------------------------------------
def _matmul_score(view: CorpusView, queries: Array, ids: Array,
                  metric: str) -> Array:
    """MXU-form gather→score over the norm cache: (B, K) ids -> (B, K).

    The inner product is one ``dot_general`` over the gathered rows (BLAS on
    CPU, MXU on TPU); the row-norm term comes from the cache instead of
    being re-reduced every wave. Same values as ``ref.gather_score_ref`` up
    to fp association (the expansion reassociates the reduction).

    Quantized views take a dequant-then-dot epilogue: the gather moves the
    int8/fp8 codes (the HBM-bandwidth win), dequantization happens on the
    gathered (B, K, dim) tile right before the ``dot_general``, and the
    cached norms already describe the dequantized rows — so the result
    equals ``ref.gather_score_quant_ref`` up to the same fp association.
    """
    safe = jnp.maximum(ids, 0)
    if view.scales is not None:
        zp = None if view.zero_points is None else view.zero_points[safe]
        rows = ref.dequant_rows_ref(view.rows[safe], view.scales[safe], zp)
    else:
        rows = view.rows[safe].astype(jnp.float32)  # (B, K, dim)
    q = queries.astype(jnp.float32)
    # batched (K, dim) @ (dim,) — explicit dot_general (no einsum transpose
    # shuffling): BLAS on CPU, MXU on TPU
    dots = jax.lax.dot_general(rows, q, (((2,), (1,)), ((0,), (0,))))
    if metric in ("l2", "sqeuclidean"):
        qsq = jnp.sum(q * q, axis=-1)
        # the expansion can dip epsilon-negative where the oracle is ~0
        d = jnp.maximum(view.sq_norms[safe] - 2.0 * dots + qsq[:, None], 0.0)
        if metric == "l2":
            d = jnp.sqrt(d)
    elif metric == "ip":
        d = -dots
    elif metric == "cosine":
        qn = jax.lax.rsqrt(jnp.sum(q * q, axis=-1) + NORM_EPS)
        d = 1.0 - dots * qn[:, None] * view.inv_norms[safe]
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return jnp.where(ids >= 0, d, jnp.inf)


def _matmul_score_local(view: CorpusView, queries: Array, ids: Array,
                        offset, metric: str) -> Array:
    """Shard-local matmul-form scoring (psum-identity on foreign lanes).

    Mirrors ``ref.gather_score_local_ref``: owned lanes carry the exact
    per-lane value of :func:`_matmul_score` (the norms shard with the
    rows), everything else contributes 0.0 to the wave psum.
    """
    n_local = view.rows.shape[0]
    loc = ids - jnp.asarray(offset, ids.dtype)
    owned = (ids >= 0) & (loc >= 0) & (loc < n_local)
    d = _matmul_score(view, queries, jnp.where(owned, loc, -1), metric)
    return jnp.where(owned, d, 0.0)


def gather_score(corpus, queries, ids, *, metric="sqeuclidean", backend=None,
                 use_pallas=None, interpret=None, quantize=None):
    """Fused gather→score for a whole query batch: (B, K) ids -> (B, K).

    ``corpus`` is a raw (N, dim) array or a
    :class:`~repro.kernels.backend.CorpusView`; the matmul backends build
    the view on the fly when handed a raw array (prefer passing the view —
    it is the whole point of the norm cache). ``quantize`` selects
    quantized residency for a raw corpus (build the quantized view outside
    the hot loop instead — quantization is *not* cached across calls); a
    prebuilt quantized view is scored as-is on every backend (ref takes
    the dequantize-then-score oracle, the matmul forms a dequant epilogue,
    pallas dequantizes in-register inside the tile).
    """
    be = _resolve(backend, use_pallas, interpret, "ops.gather_score",
                  quantize=quantize)
    src = _view_for(corpus, be, "ops.gather_score")
    if be.name == "xla_matmul":
        return _matmul_score(src, queries, ids, metric)
    if be.use_pallas:
        return _lt.gather_score(src.rows, queries, ids, metric=metric,
                                norms=_lt.pack_row_meta(src),
                                interpret=be.interpret)
    if isinstance(src, CorpusView) and src.quantize is not None:
        return ref.gather_score_quant_ref(src.rows, src.scales,
                                          src.zero_points, queries, ids,
                                          metric=metric)
    return ref.gather_score_ref(corpus_rows(src), queries, ids,
                                metric=metric)


def gather_l2(corpus, queries, ids, *, backend=None, use_pallas=None,
              interpret=None):
    return gather_score(corpus, queries, ids, metric="sqeuclidean",
                        backend=backend, use_pallas=use_pallas,
                        interpret=interpret)


def gather_score_local(corpus_local, queries, ids, offset, *,
                       metric="sqeuclidean", backend=None, use_pallas=None,
                       interpret=None, quantize=None):
    """Shard-local gather→score over global ids: (B, K) -> (B, K) partials.

    Owned lanes (offset <= id < offset + n_local) carry the exact distance;
    foreign and padding lanes carry the psum identity 0.0, so a
    ``lax.psum`` over the shard axis reconstructs the unsharded
    :func:`gather_score` wave (bit-exactly within one backend — each id has
    one owner and x + 0.0 == x). The sharded engine masks ids < 0 to +inf
    after the psum. ``corpus_local`` may be the local block's
    :class:`~repro.kernels.backend.CorpusView` (norms — and the dequant
    parameters of a quantized view — shard with the rows).
    """
    be = _resolve(backend, use_pallas, interpret, "ops.gather_score_local",
                  quantize=quantize)
    src = _view_for(corpus_local, be, "ops.gather_score_local")
    if be.name == "xla_matmul":
        return _matmul_score_local(src, queries, ids, offset, metric)
    if be.use_pallas:
        return _lt.gather_score_local(src.rows, queries, ids, offset,
                                      metric=metric,
                                      norms=_lt.pack_row_meta(src),
                                      interpret=be.interpret)
    if isinstance(src, CorpusView) and src.quantize is not None:
        return ref.gather_score_local_quant_ref(
            src.rows, src.scales, src.zero_points, queries, ids, offset,
            metric=metric)
    return ref.gather_score_local_ref(corpus_rows(src), queries,
                                      ids, offset, metric=metric)


def local_topk(ids, dists, k):
    """Per-row best-``k`` by distance, ties to the lowest index (stable).

    The per-shard candidate cut applied *before* an all-gather merge: each
    shard sends only its k best (id, dist) pairs instead of its whole pool,
    shrinking the merge collective from O(n_local) to O(k) per query.

    ``k`` may exceed the row width (small shard pools): the cut is clamped
    to the width and the result padded with (-1, +inf) sentinel lanes, which
    sort last in any downstream merge and are dropped by its final cut.

    The output distances keep the input dtype (ordering runs on an f32 view
    of the keys — a monotonic, tie-stable embedding for bf16/f16) so
    half-precision pools are not silently upcast.
    """
    width = ids.shape[1]
    kk = min(k, width)
    _, order = jax.lax.top_k(-dists.astype(jnp.float32), kk)
    out_ids = jnp.take_along_axis(ids, order, axis=1)
    out_dists = jnp.take_along_axis(dists, order, axis=1)
    if kk < k:
        b = ids.shape[0]
        out_ids = jnp.concatenate(
            [out_ids, jnp.full((b, k - kk), -1, out_ids.dtype)], axis=1)
        out_dists = jnp.concatenate(
            [out_dists, jnp.full((b, k - kk), jnp.inf, out_dists.dtype)],
            axis=1)
    return out_ids, out_dists


# Padding sentinel for the sorted-membership dedup arrays: larger than any
# real vertex id, so pads always sort to the tail of an ascending row.
SET_PAD = jnp.iinfo(jnp.int32).max


def sorted_set_merge(set_ids, new_ids):
    """Insert a wave of ids into per-row ascending membership arrays.

    ``set_ids`` (B, C) int32 ascending with :data:`SET_PAD` padding;
    ``new_ids`` (B, K) int32 with masked lanes set to ``SET_PAD``. Returns
    the updated (B, C) ascending rows holding the C smallest of the union —
    which is *every* real entry as long as the caller never inserts more
    than C ids total (the quota guarantee of the beam engine: one insertion
    per counted distance call, n_calls <= quota <= C).

    The merge is the same smallest-C cut the pool merges take with
    tie-stable ``lax.top_k`` — but on pure int keys a stable ascending
    ``jnp.sort`` of the concatenated row computes it identically (equal
    ids are indistinguishable) and measures ~5x faster on CPU than
    ``top_k`` at k = C (XLA's TopK is tuned for k << width; the dedup cut
    keeps *most* of the row). Duplicate entries (the E=1 engine's
    duplicate-adjacency-lane quirk) are kept as distinct slots, exactly
    mirroring their ``n_calls`` cost.
    """
    c = set_ids.shape[1]
    if c == 0:  # zero-capacity set (quota-0 rows): insertion is a no-op
        return set_ids
    cat = jnp.concatenate([set_ids, new_ids.astype(jnp.int32)], axis=1)
    return jnp.sort(cat, axis=1)[:, :c]


def sorted_set_lookup(set_ids, ids):
    """(B, K) bool membership of ``ids`` in ascending per-row sets.

    One ``searchsorted`` per row (vmapped); lanes with id < 0 return False.
    ``SET_PAD`` pads never match a real id, so no validity mask is needed.
    """
    c = set_ids.shape[1]
    if c == 0:
        return jnp.zeros(ids.shape, bool)
    pos = jax.vmap(jnp.searchsorted)(set_ids, ids)
    hit = jnp.take_along_axis(set_ids, jnp.minimum(pos, c - 1), axis=1) == ids
    return (ids >= 0) & hit


def sorted_set_unique_count(set_ids):
    """(B,) distinct real ids per ascending row — the popcount the bitmap's
    ``scored.sum()`` would give (duplicate slots collapse, pads don't count).
    """
    b, c = set_ids.shape
    if c == 0:
        return jnp.zeros((b,), jnp.int32)
    first = jnp.ones((b, 1), bool)
    distinct = jnp.concatenate(
        [first, set_ids[:, 1:] != set_ids[:, :-1]], axis=1)
    return (distinct & (set_ids != SET_PAD)).sum(axis=1, dtype=jnp.int32)


def frontier_count(pool_dists, radius):
    """(B,) pool entries within ``min + radius`` of each row's best.

    The cover-tree descent's per-level candidate set is exactly the pool
    prefix whose distance is within the level radius of the row minimum
    (``d(q, p) <= d_min + 2^i``) — because the pools are sorted, its size
    is the expand width of the level's wave. ``radius`` broadcasts to (B,);
    a row's +inf radius counts every finite entry (the root level), an
    empty row (all +inf) counts zero.
    """
    finite = jnp.isfinite(pool_dists)
    dmin = jnp.min(jnp.where(finite, pool_dists, jnp.inf), axis=1)
    r = jnp.broadcast_to(jnp.asarray(radius, pool_dists.dtype), dmin.shape)
    within = finite & (pool_dists <= (dmin + r)[:, None])
    return within.sum(axis=1, dtype=jnp.int32)


def beam_merge_topk(beam_ids, beam_dists, cand_ids, cand_dists, *,
                    backend=None, use_pallas=None, interpret=None):
    be = _resolve(backend, use_pallas, interpret, "ops.beam_merge_topk")
    # direct-op legacy semantics: use_pallas=True on the merge ops always
    # meant "run the bitonic kernel here" (the engine-level merge knob was
    # the separate use_fused_merge, which resolves via fused_merge)
    if be.merge_pallas or use_pallas:
        return _lt.beam_merge_topk(beam_ids, beam_dists, cand_ids, cand_dists,
                                   interpret=be.interpret)
    return ref.beam_merge_topk_ref(beam_ids, beam_dists, cand_ids, cand_dists)


def merge_pool_batch(pool_ids, pool_dists, expanded, cand_ids, cand_dists, *,
                     backend=None, use_pallas=None, interpret=None):
    """Batched (beam ‖ fanout) pool merge with the ``expanded`` payload.

    The XLA path implements the *stable* merge contract of
    ``ref.merge_pool_batch_ref`` (ties, including inf padding, resolve to the
    earlier position — so an all-masked wave is an exact no-op) via
    ``lax.top_k``, which XLA guarantees returns equal keys lowest-index
    first; it is bit-identical to the argsort oracle but ~3x faster on CPU.
    The Pallas path (``backend="pallas"`` or the legacy ``use_fused_merge``
    shim on the engine entry points) runs the lane-padded bitonic network
    with the payload lane; it returns the same multiset but may order equal
    distances differently. Both paths keep the distances' input dtype
    (ordering runs on an f32 view of the keys).
    """
    be = _resolve(backend, use_pallas, interpret, "ops.merge_pool_batch")
    # direct-op legacy semantics: see beam_merge_topk
    if be.merge_pallas or use_pallas:
        oi, od, of = _lt.beam_merge_topk(
            pool_ids, pool_dists, cand_ids, cand_dists,
            beam_flags=expanded.astype(jnp.int32),
            cand_flags=jnp.zeros(cand_ids.shape, jnp.int32),
            interpret=be.interpret)
        return oi, od, of.astype(bool)
    p = pool_ids.shape[1]
    dtype = jnp.result_type(pool_dists.dtype, cand_dists.dtype)
    ids = jnp.concatenate([pool_ids, cand_ids], axis=1)
    d = jnp.concatenate(
        [pool_dists.astype(dtype), cand_dists.astype(dtype)], axis=1)
    exp = jnp.concatenate(
        [expanded, jnp.zeros(cand_ids.shape, dtype=bool)], axis=1)
    _, order = jax.lax.top_k(-d.astype(jnp.float32), p)
    take = lambda a: jnp.take_along_axis(a, order, axis=1)
    return take(ids), take(d), take(exp)


def embedding_bag(table, idx, *, mode="sum", backend=None, use_pallas=None,
                  interpret=None):
    be = _resolve(backend, use_pallas, interpret, "ops.embedding_bag")
    if be.use_pallas:
        return _bag.embedding_bag(table, idx, mode=mode,
                                  interpret=be.interpret)
    return ref.embedding_bag_ref(table, idx, mode=mode)
