"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``<name>_ref`` is the mathematical definition the kernel must match
(assert_allclose in tests, and the XLA execution path on CPU / for dry-runs).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                        sm_scale: float | None = None) -> Array:
    """q (B, H, Sq, dh); k, v (B, H, Skv, dh) -> (B, H, Sq, dv). Plain softmax."""
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(dh)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None] + (skv - sq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def flash_decode_ref(q: Array, k: Array, v: Array, *, length: Array | int,
                     sm_scale: float | None = None) -> Array:
    """Single-query attention: q (B, H, dh); k, v (B, S, H, dh) -> (B, H, dh)."""
    b, h, dh = q.shape
    s = k.shape[1]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(dh)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sm_scale
    valid = jnp.arange(s)[None, None, :] < jnp.asarray(length).reshape(-1, 1, 1)
    logits = jnp.where(valid, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def l2_gather_dists_ref(corpus: Array, queries: Array, ids: Array) -> Array:
    """corpus (N, dim); queries (B, dim); ids (B, K) -> (B, K) sq-l2 dists.

    ids < 0 -> +inf (padding). This is the bi-metric beam-step hot op:
    gather fanout candidates and score them against the query.
    """
    rows = corpus[jnp.maximum(ids, 0)]  # (B, K, dim)
    diff = rows.astype(jnp.float32) - queries[:, None].astype(jnp.float32)
    d = (diff * diff).sum(-1)
    return jnp.where(ids >= 0, d, jnp.inf)


def beam_merge_topk_ref(beam_ids: Array, beam_dists: Array, cand_ids: Array,
                        cand_dists: Array) -> tuple[Array, Array]:
    """Merge (B, L) beam with (B, K) candidates, return best (B, L) by dist."""
    L = beam_ids.shape[1]
    ids = jnp.concatenate([beam_ids, cand_ids], axis=1)
    d = jnp.concatenate([beam_dists, cand_dists], axis=1)
    order = jnp.argsort(d, axis=1, stable=True)
    return (
        jnp.take_along_axis(ids, order, axis=1)[:, :L],
        jnp.take_along_axis(d, order, axis=1)[:, :L],
    )


def embedding_bag_ref(table: Array, idx: Array, mode: str = "sum") -> Array:
    """table (V, D); idx (B, L) with -1 padding -> (B, D) reduced bags."""
    rows = table[jnp.maximum(idx, 0)]
    mask = (idx >= 0).astype(table.dtype)
    out = (rows * mask[..., None]).sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
    return out
