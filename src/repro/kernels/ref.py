"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``<name>_ref`` is the mathematical definition the kernel must match
(assert_allclose in tests, and the XLA execution path on CPU / for dry-runs).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Array = jax.Array


def flash_attention_ref(q: Array, k: Array, v: Array, *, causal: bool = True,
                        sm_scale: float | None = None) -> Array:
    """q (B, H, Sq, dh); k, v (B, H, Skv, dh) -> (B, H, Sq, dv). Plain softmax."""
    b, h, sq, dh = q.shape
    skv = k.shape[2]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(dh)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None] + (skv - sq)
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def flash_decode_ref(q: Array, k: Array, v: Array, *, length: Array | int,
                     sm_scale: float | None = None) -> Array:
    """Single-query attention: q (B, H, dh); k, v (B, S, H, dh) -> (B, H, dh)."""
    b, h, dh = q.shape
    s = k.shape[1]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(dh)
    logits = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * sm_scale
    valid = jnp.arange(s)[None, None, :] < jnp.asarray(length).reshape(-1, 1, 1)
    logits = jnp.where(valid, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def gather_score_ref(corpus: Array, queries: Array, ids: Array,
                     metric: str = "sqeuclidean") -> Array:
    """corpus (N, dim); queries (B, dim); ids (B, K) -> (B, K) dissimilarities.

    ids < 0 -> +inf (padding). This is the bi-metric beam-step hot op:
    gather fanout candidates and score them against the query. Metric names
    and conventions match ``repro.core.distances`` ("ip" negated, "cosine"
    one-minus), computed in the gather-then-reduce form of the Pallas kernel.
    """
    rows = corpus[jnp.maximum(ids, 0)].astype(jnp.float32)  # (B, K, dim)
    q = queries[:, None].astype(jnp.float32)  # (B, 1, dim)
    if metric in ("l2", "sqeuclidean"):
        diff = rows - q
        d = (diff * diff).sum(-1)
        if metric == "l2":
            d = jnp.sqrt(d)
    elif metric == "ip":
        d = -(rows * q).sum(-1)
    elif metric == "cosine":
        qn = jax.lax.rsqrt((q * q).sum(-1) + 1e-12)
        rn = jax.lax.rsqrt((rows * rows).sum(-1) + 1e-12)
        d = 1.0 - (rows * q).sum(-1) * qn * rn
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return jnp.where(ids >= 0, d, jnp.inf)


def l2_gather_dists_ref(corpus: Array, queries: Array, ids: Array) -> Array:
    """Historical sqeuclidean entry point of :func:`gather_score_ref`."""
    return gather_score_ref(corpus, queries, ids, metric="sqeuclidean")


def dequant_rows_ref(rows: Array, scales: Array,
                     zero_points: Array | None = None) -> Array:
    """THE dequantization semantics for quantized corpus residency.

    ``rows`` (..., dim) int8 or fp8; ``scales`` (...,) f32 per-row scale;
    ``zero_points`` (...,) f32 per-row zero point (int8 affine) or None
    (fp8, symmetric). Returns f32 ``(rows - zp) * scale``. Every backend's
    quantized scoring path must equal scoring these dequantized rows with
    the plain oracle — dequantization is elementwise, so it commutes with
    the gather, and each backend may apply it pre- or post-gather (or
    in-register inside a tile) without changing the contract.
    """
    f = rows.astype(jnp.float32)
    if zero_points is not None:
        f = f - zero_points[..., None].astype(jnp.float32)
    return f * scales[..., None].astype(jnp.float32)


def gather_score_quant_ref(rows: Array, scales: Array,
                           zero_points: Array | None, queries: Array,
                           ids: Array, metric: str = "sqeuclidean") -> Array:
    """Dequantize-then-score oracle for quantized corpus rows.

    Exactly :func:`gather_score_ref` over :func:`dequant_rows_ref` of the
    gathered rows — the parity statement every quantized backend path
    (matmul epilogue, in-tile dequant) is pinned against.
    """
    safe = jnp.maximum(ids, 0)
    zp = None if zero_points is None else zero_points[safe]
    deq = dequant_rows_ref(rows[safe], scales[safe], zp)  # (B, K, dim) f32
    q = queries[:, None].astype(jnp.float32)  # (B, 1, dim)
    if metric in ("l2", "sqeuclidean"):
        diff = deq - q
        d = (diff * diff).sum(-1)
        if metric == "l2":
            d = jnp.sqrt(d)
    elif metric == "ip":
        d = -(deq * q).sum(-1)
    elif metric == "cosine":
        qn = jax.lax.rsqrt((q * q).sum(-1) + 1e-12)
        rn = jax.lax.rsqrt((deq * deq).sum(-1) + 1e-12)
        d = 1.0 - (deq * q).sum(-1) * qn * rn
    else:
        raise ValueError(f"unknown metric {metric!r}")
    return jnp.where(ids >= 0, d, jnp.inf)


def gather_score_local_quant_ref(rows_local: Array, scales_local: Array,
                                 zp_local: Array | None, queries: Array,
                                 ids: Array, offset: Array | int,
                                 metric: str = "sqeuclidean") -> Array:
    """Shard-local form of :func:`gather_score_quant_ref` (psum identity).

    Same owned-lane remapping contract as :func:`gather_score_local_ref`:
    lanes owned by this shard carry the exact dequantize-then-score value,
    foreign/padding lanes contribute 0.0 to the wave psum.
    """
    n_local = rows_local.shape[0]
    loc = ids - jnp.asarray(offset, ids.dtype)
    owned = (ids >= 0) & (loc >= 0) & (loc < n_local)
    d = gather_score_quant_ref(rows_local, scales_local, zp_local, queries,
                               jnp.where(owned, loc, -1), metric=metric)
    return jnp.where(owned, d, 0.0)


def gather_score_local_ref(corpus_local: Array, queries: Array, ids: Array,
                           offset: Array | int,
                           metric: str = "sqeuclidean") -> Array:
    """Shard-local gather→score with global-id remapping (psum identity form).

    ``corpus_local`` (n_local, dim) holds global rows [offset, offset+n_local);
    ``ids`` (B, K) are *global* ids. Lanes owned by this shard are scored with
    the exact per-lane math of :func:`gather_score_ref`; every other lane
    (foreign shard or padding id < 0) contributes ``0.0`` so that summing the
    per-shard partials over the shard axis reconstructs the unsharded wave
    bit-exactly (x + 0.0 == x; each id has exactly one owner). The caller
    masks ids < 0 back to +inf after the psum.
    """
    n_local = corpus_local.shape[0]
    loc = ids - jnp.asarray(offset, ids.dtype)
    owned = (ids >= 0) & (loc >= 0) & (loc < n_local)
    d = gather_score_ref(corpus_local, queries,
                         jnp.where(owned, loc, -1), metric=metric)
    return jnp.where(owned, d, 0.0)


def beam_merge_topk_ref(beam_ids: Array, beam_dists: Array, cand_ids: Array,
                        cand_dists: Array) -> tuple[Array, Array]:
    """Merge (B, L) beam with (B, K) candidates, return best (B, L) by dist."""
    L = beam_ids.shape[1]
    ids = jnp.concatenate([beam_ids, cand_ids], axis=1)
    d = jnp.concatenate([beam_dists, cand_dists], axis=1)
    order = jnp.argsort(d, axis=1, stable=True)
    return (
        jnp.take_along_axis(ids, order, axis=1)[:, :L],
        jnp.take_along_axis(d, order, axis=1)[:, :L],
    )


def merge_pool_batch_ref(
    pool_ids: Array, pool_dists: Array, expanded: Array,
    cand_ids: Array, cand_dists: Array,
) -> tuple[Array, Array, Array]:
    """Stable (beam ‖ fanout) merge keeping the best pool-width per query.

    (B, P) pool + (B, K) candidates -> (B, P). The ``expanded`` bool payload
    rides along (new candidates enter unexpanded). Stability is part of the
    contract: ties — including the +inf padding — resolve to the earlier
    position, so merging an all-masked candidate wave is an exact no-op.
    """
    p = pool_ids.shape[1]
    ids = jnp.concatenate([pool_ids, cand_ids], axis=1)
    d = jnp.concatenate([pool_dists, cand_dists], axis=1)
    exp = jnp.concatenate(
        [expanded, jnp.zeros(cand_ids.shape, dtype=bool)], axis=1)
    order = jnp.argsort(d, axis=1, stable=True)
    take = lambda a: jnp.take_along_axis(a, order, axis=1)[:, :p]
    return take(ids), take(d), take(exp)


def embedding_bag_ref(table: Array, idx: Array, mode: str = "sum") -> Array:
    """table (V, D); idx (B, L) with -1 padding -> (B, D) reduced bags."""
    rows = table[jnp.maximum(idx, 0)]
    mask = (idx >= 0).astype(table.dtype)
    out = (rows * mask[..., None]).sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
    return out
