"""Bi-metric serving: a persistent slot pool behind a request-centric API.

The engine (``repro.serve.engine.BiMetricEngine``) serves the paper's
two-tower deployment. The native request unit is a frozen ``SearchRequest``
(tokens, quota, k, n_seeds, expand_width, deadline_ms, priority); every
entry point — ``submit()``, ``query()``, ``query_batch()`` — accepts it,
and results come back as ``SearchResult`` (ids, D-dists, ``ServeStats``).
Legacy ``(tokens, quota=...)`` call forms still work through once-warning
deprecation shims.

The async drive is **continuous batching** over one resident slot pool
(the fixed-wave admission pipeline is retired):

* **admission** — ``submit()`` pushes requests onto a priority/deadline
  heap (higher ``priority`` first, FIFO within; ``deadline_ms`` expiry
  while queued fails the future with ``DeadlineExceeded``). The drive
  thread refills freed slots from the heap on *every* plan/commit step —
  not at wave boundaries — so a free lane never idles behind a running
  neighbor.
* **slot pool** — one resident ``(slots,)``-row search state
  (``repro.core.beam.BatchedSearchState``; inside the corpus mesh via
  ``ShardedStepper`` when ``shards > 1``). Admission recycles rows in
  place (``repro.core.beam.reset_slots``); static shapes (pool size,
  sorted-set capacity, seed/expand lane caps) grow monotonically in
  power-of-two buckets, each growth an exact semantic no-op.
* **mid-flight completion** — a slot that goes inactive resolves its
  future on that step and is immediately reusable; a long request never
  blocks its slot-mates (no head-of-line blocking).
* **tower overlap** — while the expensive tower drains a step's fresh
  documents, the drive thread runs the *next* admission group's
  cheap-tower embed + stage-1 search.

Per-row budget knobs (quota, beam width, step cap, seeds, expand width)
are operands in the core engine and the pools are streaming exact top-P
structures, so a slot row's answer is **bit-exact** vs the synchronous
``query_batch`` drive at any shard count — admission order, slot-mates and
capacity growth are invisible to it.

The stage-1 index is the ``index=`` knob: ``"vamana"`` (default — the
DiskANN instantiation, greedy beam search over the proxy-built graph) or
``"covertree"`` (the Theorem B.3 instantiation — per-level cover-tree
descent, paper Algorithm 3, driven through the same slot pool as chunked
``plan_step``/``commit_scores`` waves with the memoized D-call set living
in the slot's ``ScoredSet``). Cover-tree rows ignore ``n_seeds`` /
``expand_width`` (the tree's root cover and fanout take their place),
``covertree_eps`` / ``covertree_T`` tune the descent's stopping rule and
the offline build scale, and ``rerank_query_batch`` is vamana-only. Both
index kinds serve bit-exact vs their synchronous ``query_batch`` drive.

Observability: ``ServeStats`` splits per-request latency into ``queue_ms``
(submit → slot admission) + ``compute_ms`` (admission → resolve), with
``latency_ms`` their sum, plus admission-time ``slot_occupancy`` /
``queue_depth`` snapshots; ``BiMetricEngine.counters()`` exposes the
cumulative ``EngineCounters`` (submitted / admitted / completed /
cancelled / deadline_misses and instantaneous depth/occupancy).
``close()`` cancels still-queued requests (``CancelledError``) instead of
flushing them; admitted slots still resolve. The device-side kernel route
is the ``backend=`` knob (``repro.kernels``).

Failure semantics
-----------------
The contract is **failures are scoped to requests, never to the engine**,
with four nested isolation domains (async path):

* **one request** — malformed input (bad token shape) fails only that
  request's future at admission.
* **one admission group** — a cheap-tower or stage-1 error while staging a
  group fails that group's futures with ``AdmissionFailed`` (the original
  exception on ``__cause__``); resident slots never notice.
* **the tower lane** — an expensive-tower failure (query embed or document
  drain) is retried up to ``tower_retries`` times with exponential backoff
  starting at ``retry_backoff_ms`` (transient errors only: an exception
  carrying ``transient=False`` — or a ``TowerTimeout``, a call that blew
  ``drain_timeout_ms`` — is never retried inline). A retried drain is
  idempotent: the document cache is written only after a successful
  forward pass, so recovered runs are **bit-exact** vs fault-free runs.
  When the lane gives up, the ``on_tower_failure`` policy decides the
  affected residents' fate — ``"fail"`` (default) fails each future with
  ``TowerFailure`` chaining the original traceback; ``"degrade"`` resolves
  each with its stage-1 proxy ranking, ``ServeStats.degraded=True``.
  Either way the engine keeps serving. ``breaker_threshold`` consecutive
  failures open a circuit breaker for ``breaker_cooldown_ms`` (then
  half-open probes): while open, tower calls are refused without being
  attempted — under ``"degrade"`` the engine serves proxy-only without
  occupying slots; under ``"fail"`` requests shed fast with
  ``TowerFailure``.
* **the engine** — only an error *outside* those domains (poisoned
  resident device state) reaches ``fail_all``: every resident + staged
  future fails with ``EngineFailure`` (original on ``__cause__``), the
  resident state is dropped, and the next admission re-initializes it.
  ``KeyboardInterrupt`` / ``SystemExit`` fail the residents and then
  re-raise — they are never converted into a served error.

``deadline_ms`` is enforced at three points: queued expiry and
admission-pop expiry fail the future with ``DeadlineExceeded`` (the
request never ran, so there is nothing to degrade to), and **mid-flight**
expiry — checked every drive iteration and every 20 ms inside a tower
wait when deadlines are resident — follows ``on_tower_failure``:
``"degrade"`` resolves the slot with its proxy ranking (counted in both
``deadline_misses`` and ``degraded``), ``"fail"`` raises
``DeadlineExceeded``. Expired rows close their frontier in place
(``repro.core.beam.early_resolve``); co-resident rows are untouched
bit-for-bit.

**Degraded-result guarantee.** A degraded result is the stage-1 proxy
ranking under the cheap metric ``d``. The paper's premise (arXiv
2406.02891) is that ``d`` is a C-approximation of the ground-truth metric
``D`` — ``D(x,y)/C <= d(x,y) <= C·D(x,y)`` — so proxy-only answers carry
the same bounded quality loss the bi-metric framework's stage 1 does:
every returned id is within ``C²`` of optimal under ``D``. Degradation is
the paper's accuracy/efficiency knob repurposed as an operational
fallback, and ``degraded=True`` marks exactly which answers took it
(cover-tree rows have no proxy stage; they degrade to their current
D-scored pool prefix mid-flight, and shed fast when the breaker is open).

Fault injection for tests/benchmarks is ``repro.serve.faults.FaultPlan``
(seeded, deterministic, threaded through ``BiMetricEngine(faults=...)``);
``BiMetricEngine.health()`` snapshots breaker state + counters.
"""
from repro.serve.engine import (AdmissionFailed,  # noqa: F401
                                BiMetricEngine, DeadlineExceeded, EmbedTower,
                                EngineCounters, EngineFailure, SearchRequest,
                                SearchResult, ServeFuture, ServeStats,
                                TowerFailure, TowerTimeout)
from repro.serve.faults import (CircuitBreaker,  # noqa: F401
                                FaultPlan, FaultSpec, InjectedFault)
