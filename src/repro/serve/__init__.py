"""Bi-metric serving: admission → plan/commit → drain, as one async pipeline.

The engine (``repro.serve.engine.BiMetricEngine``) serves the paper's
two-tower deployment. The historical standalone ``serve/batcher.py`` thread
loop is retired — request batching is now the engine's own admission stage:

* **admission** — ``submit()`` enqueues single requests; an admission thread
  pools up to ``max_batch`` of them (flushing after ``max_wait_ms``, so a
  partial wave never waits behind an empty queue) and pads the group into a
  fixed-shape *wave*. Padding rows carry quota 0; every budget knob is a
  per-query vector in the core engine, so padding and wave-mates never
  perturb a request's answer.
* **plan/commit (device lane)** — each wave's cheap-tower embed, stage-1
  search and stage-2 bookkeeping (``plan_step`` / ``commit_scores``) run on
  device; with ``shards > 1`` they run inside the corpus mesh
  (``repro.core.beam.ShardedStepper``), the scored bitmap column-sharded
  exactly like stage 1.
* **drain (tower lane)** — the expensive-tower forward passes: the query
  embed and one batched drain per stage-2 wave, against an engine-lifetime
  document-embedding cache.

**Double-buffer invariant**: at most ``max_inflight`` (default 2) waves are
in flight, and a wave is on exactly one lane at a time — so the tower drain
of wave *i* overlaps the device plan/commit of wave *i+1*, while the two
lanes never race on one wave's state. Results are bit-exact vs the
synchronous ``query_batch`` path (which drives the identical wave coroutine
inline), at any shard count.

Every async request's submit→resolve wall clock is stamped into its
``ServeStats.latency_ms`` (the serving-latency distribution the async
bench reports and gates at p50); the engine's device-side kernel route is
the ``backend=`` knob (``repro.kernels`` — ``"auto"`` = MXU-form scoring
over an engine-lifetime corpus-norm cache, or the Pallas kernels on TPU).
"""
from repro.serve.engine import (BiMetricEngine, EmbedTower,  # noqa: F401
                                ServeFuture, ServeStats)
