from repro.serve.batcher import Batcher  # noqa: F401
from repro.serve.engine import BiMetricEngine, EmbedTower  # noqa: F401
