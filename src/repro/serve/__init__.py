"""Bi-metric serving: a persistent slot pool behind a request-centric API.

The engine (``repro.serve.engine.BiMetricEngine``) serves the paper's
two-tower deployment. The native request unit is a frozen ``SearchRequest``
(tokens, quota, k, n_seeds, expand_width, deadline_ms, priority); every
entry point — ``submit()``, ``query()``, ``query_batch()`` — accepts it,
and results come back as ``SearchResult`` (ids, D-dists, ``ServeStats``).
Legacy ``(tokens, quota=...)`` call forms still work through once-warning
deprecation shims.

The async drive is **continuous batching** over one resident slot pool
(the fixed-wave admission pipeline is retired):

* **admission** — ``submit()`` pushes requests onto a priority/deadline
  heap (higher ``priority`` first, FIFO within; ``deadline_ms`` expiry
  while queued fails the future with ``DeadlineExceeded``). The drive
  thread refills freed slots from the heap on *every* plan/commit step —
  not at wave boundaries — so a free lane never idles behind a running
  neighbor.
* **slot pool** — one resident ``(slots,)``-row search state
  (``repro.core.beam.BatchedSearchState``; inside the corpus mesh via
  ``ShardedStepper`` when ``shards > 1``). Admission recycles rows in
  place (``repro.core.beam.reset_slots``); static shapes (pool size,
  sorted-set capacity, seed/expand lane caps) grow monotonically in
  power-of-two buckets, each growth an exact semantic no-op.
* **mid-flight completion** — a slot that goes inactive resolves its
  future on that step and is immediately reusable; a long request never
  blocks its slot-mates (no head-of-line blocking).
* **tower overlap** — while the expensive tower drains a step's fresh
  documents, the drive thread runs the *next* admission group's
  cheap-tower embed + stage-1 search.

Per-row budget knobs (quota, beam width, step cap, seeds, expand width)
are operands in the core engine and the pools are streaming exact top-P
structures, so a slot row's answer is **bit-exact** vs the synchronous
``query_batch`` drive at any shard count — admission order, slot-mates and
capacity growth are invisible to it.

The stage-1 index is the ``index=`` knob: ``"vamana"`` (default — the
DiskANN instantiation, greedy beam search over the proxy-built graph) or
``"covertree"`` (the Theorem B.3 instantiation — per-level cover-tree
descent, paper Algorithm 3, driven through the same slot pool as chunked
``plan_step``/``commit_scores`` waves with the memoized D-call set living
in the slot's ``ScoredSet``). Cover-tree rows ignore ``n_seeds`` /
``expand_width`` (the tree's root cover and fanout take their place),
``covertree_eps`` / ``covertree_T`` tune the descent's stopping rule and
the offline build scale, and ``rerank_query_batch`` is vamana-only. Both
index kinds serve bit-exact vs their synchronous ``query_batch`` drive.

Observability: ``ServeStats`` splits per-request latency into ``queue_ms``
(submit → slot admission) + ``compute_ms`` (admission → resolve), with
``latency_ms`` their sum, plus admission-time ``slot_occupancy`` /
``queue_depth`` snapshots; ``BiMetricEngine.counters()`` exposes the
cumulative ``EngineCounters`` (submitted / admitted / completed /
cancelled / deadline_misses and instantaneous depth/occupancy).
``close()`` cancels still-queued requests (``CancelledError``) instead of
flushing them; admitted slots still resolve. The device-side kernel route
is the ``backend=`` knob (``repro.kernels``).
"""
from repro.serve.engine import (BiMetricEngine,  # noqa: F401
                                DeadlineExceeded, EmbedTower, EngineCounters,
                                SearchRequest, SearchResult, ServeFuture,
                                ServeStats)
