"""Fault-tolerance primitives for the serving path.

Two halves:

* :class:`FaultPlan` — a **seeded, deterministic fault-injection harness**
  (test/benchmark-only). The engine calls :meth:`FaultPlan.fire` at each
  injection site (``"drain"`` — an expensive-tower document drain,
  ``"embed_queries"`` — an expensive-tower query embed, ``"cheap_embed"``
  — the cheap tower's admission-group embed); the plan decides, from a
  per-site seeded stream, whether that call fails, hangs, or proceeds.
  Decisions are deterministic in the per-site *call index*, so a chaos run
  is reproducible regardless of thread interleaving between sites.

* :class:`CircuitBreaker` — the tower lane's failure-isolation state
  machine (production code, not test-only): ``closed`` until
  ``threshold`` *consecutive* failures, then ``open`` (tower calls are
  refused without being attempted) until ``cooldown_s`` elapses, then
  **half-open** — one probe call is allowed through; its success closes
  the breaker, its failure re-arms the cooldown. The serving engine
  consults it before every tower call and feeds every outcome back, so a
  dead tower costs one probe per cooldown instead of a timeout per
  request; while open, the engine's ``on_tower_failure`` policy decides
  between failing fast and proxy-only degraded serving (see
  ``repro.serve``).

Fault modes (:class:`FaultSpec.mode`):

* ``"transient"`` — a fired fault fails ``burst`` consecutive calls at
  the site, then the next call is *forced to succeed*: with
  ``burst <= tower_retries`` the engine's bounded retry always recovers,
  which is what makes the chaos suite's bit-exactness assertion
  deterministic instead of probabilistic.
* ``"persistent"`` — once fired, every later call at the site fails until
  :meth:`FaultPlan.heal` — the breaker/degradation path.
* ``"hang"`` — a fired call sleeps ``hang_s`` and then *succeeds*: the
  mid-flight-deadline scenario (a slow drain that eventually lands).

``hang_s`` on a transient/persistent spec delays the raise instead
(a slow failure). All state is guarded by one lock; the sleep itself runs
outside it so a hung site never blocks another site's decisions.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib

import numpy as np


class InjectedFault(RuntimeError):
    """A failure raised by :meth:`FaultPlan.fire` (test-only).

    ``transient`` marks the fault retryable — the engine's tower retry
    loop treats any exception without a falsy ``transient`` attribute as
    retryable, so persistent injected faults short-circuit straight to the
    policy path."""

    def __init__(self, site: str, call_index: int, transient: bool):
        kind = "transient" if transient else "persistent"
        super().__init__(
            f"injected {kind} fault at {site!r} (call {call_index})")
        self.site = site
        self.call_index = call_index
        self.transient = transient


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Failure behavior for one injection site.

    ``rate`` — probability a *fresh* call fires a fault (one seeded draw
    per fresh call; calls consumed by an ongoing burst, the forced-success
    recovery call, or a tripped persistent fault draw nothing, so the
    decision sequence is stable under retries). ``mode`` — ``"transient"``
    / ``"persistent"`` / ``"hang"`` (see the module doc). ``burst`` —
    consecutive failures per transient firing. ``hang_s`` — sleep before
    the outcome. ``after`` — number of initial calls before the site is
    armed (lets a test warm caches fault-free). ``exc`` — optional
    zero-arg exception factory overriding :class:`InjectedFault` (e.g.
    ``KeyboardInterrupt`` to test the drive loop's re-raise contract).
    """

    rate: float = 0.0
    mode: str = "transient"
    burst: int = 1
    hang_s: float = 0.0
    after: int = 0
    exc: type[BaseException] | None = None

    def __post_init__(self):
        if self.mode not in ("transient", "persistent", "hang"):
            raise ValueError(f"unknown fault mode {self.mode!r}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate {self.rate} not in [0, 1]")
        if self.burst < 1:
            raise ValueError("burst must be >= 1")


class FaultPlan:
    """A seeded schedule of injected failures, one spec per site.

    ``FaultPlan(seed, drain=FaultSpec(rate=0.1), ...)``. Sites with no
    spec never fault. Thread-safe; decisions per site depend only on that
    site's call index and the seed.
    """

    SITES = ("drain", "embed_queries", "cheap_embed")

    def __init__(self, seed: int = 0, **specs: FaultSpec):
        unknown = set(specs) - set(self.SITES)
        if unknown:
            raise ValueError(
                f"unknown fault site(s) {sorted(unknown)}; "
                f"valid sites: {self.SITES}")
        self.seed = int(seed)
        self._specs = dict(specs)
        self._mu = threading.Lock()
        self._calls = dict.fromkeys(specs, 0)
        self._fired = dict.fromkeys(specs, 0)
        self._burst_left = dict.fromkeys(specs, 0)
        self._recovering = dict.fromkeys(specs, False)
        self._tripped = dict.fromkeys(specs, False)
        self._disabled = dict.fromkeys(specs, False)
        # one independent deterministic uniform stream per site
        self._rng = {
            site: np.random.default_rng(
                [self.seed, zlib.crc32(site.encode())])
            for site in specs}

    def fire(self, site: str) -> None:
        """Account one call at ``site``; raise/sleep per the site's spec."""
        spec = self._specs.get(site)
        if spec is None:
            return
        with self._mu:
            i = self._calls[site]
            self._calls[site] = i + 1
            if i < spec.after or self._disabled[site]:
                return
            if self._tripped[site]:
                fail = True
            elif self._burst_left[site] > 0:
                self._burst_left[site] -= 1
                if self._burst_left[site] == 0:
                    self._recovering[site] = True
                fail = True
            elif self._recovering[site]:
                # the call right after a transient burst is forced to
                # succeed — bounded retry deterministically recovers
                self._recovering[site] = False
                return
            else:
                fail = float(self._rng[site].random()) < spec.rate
                if fail:
                    self._fired[site] += 1
                    if spec.mode == "persistent":
                        self._tripped[site] = True
                    elif spec.mode == "transient":
                        if spec.burst > 1:
                            self._burst_left[site] = spec.burst - 1
                        else:
                            self._recovering[site] = True
        if not fail:
            return
        if spec.hang_s > 0.0:
            time.sleep(spec.hang_s)
        if spec.mode == "hang":
            return  # slow but successful
        if spec.exc is not None:
            raise spec.exc()
        raise InjectedFault(site, i, transient=spec.mode == "transient")

    def heal(self, site: str | None = None) -> None:
        """The tower 'came back': clear tripped/burst state **and disarm**
        the site — no further faults fire there (``site=None``: every
        site). The breaker's half-open probe then closes it."""
        with self._mu:
            for s in ([site] if site is not None else list(self._specs)):
                self._tripped[s] = False
                self._burst_left[s] = 0
                self._recovering[s] = False
                self._disabled[s] = True

    def fired(self, site: str) -> int:
        """Faults fired at ``site`` so far (fresh firings, not burst
        members or persistent repeats)."""
        with self._mu:
            return self._fired.get(site, 0)

    def calls(self, site: str) -> int:
        with self._mu:
            return self._calls.get(site, 0)


class CircuitBreaker:
    """Consecutive-failure breaker for the expensive-tower lane.

    Mutated only by the engine's drive thread; other threads (``health()``
    readers) see a consistent snapshot because every field is a single
    attribute write. ``blocked()`` is the non-mutating admission check:
    True only while open *and* inside the cooldown window — once the
    cooldown elapses the next tower call is the half-open probe.
    ``on_success`` closes the breaker; ``on_failure`` counts toward
    ``threshold`` and, once open, re-arms the cooldown (a failed probe).
    """

    def __init__(self, threshold: int = 5, cooldown_s: float = 2.0,
                 clock=time.monotonic):
        self.threshold = max(1, int(threshold))
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._open = False
        self.failures = 0  # consecutive
        self.opens = 0  # closed -> open transitions (cumulative)
        self._opened_at = -float("inf")

    @property
    def state(self) -> str:
        """``"closed"`` | ``"open"`` | ``"half_open"`` (open, cooldown
        elapsed — the next tower call is the probe)."""
        if not self._open:
            return "closed"
        if self._clock() - self._opened_at >= self.cooldown_s:
            return "half_open"
        return "open"

    def blocked(self) -> bool:
        return self._open and (
            self._clock() - self._opened_at < self.cooldown_s)

    def on_success(self) -> None:
        self.failures = 0
        self._open = False

    def on_failure(self) -> None:
        self.failures += 1
        if self._open:
            self._opened_at = self._clock()  # failed probe: re-arm
        elif self.failures >= self.threshold:
            self._open = True
            self.opens += 1
            self._opened_at = self._clock()
