"""Bi-metric serving engine: the paper's deployment story, end to end.

* the **cheap tower** (e.g. qwen3-0.6b / bge-micro-like) runs locally and
  embeds the corpus once at index-build time — the graph index is built on
  those embeddings only (Theorem 1.1 property 1);
* the **expensive tower** (e.g. deepseek-v3 / SFR-Mistral-like) is the
  ground-truth metric D: scoring a document costs a forward pass. The engine
  memoizes per-query D embeddings and enforces the call budget *exactly* —
  the quota is literally a compute budget on the big model;
* queries run the two-stage search: stage 1 on-device jitted beam search
  under d; stage 2 host-orchestrated greedy expansion under D (batched
  tower calls, device compute / host control — the standard serving split).

``EmbedTower`` wraps (params, config, pooling); swap in any LM arch config.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances, vamana
from repro.models import transformer as T

Array = jax.Array


@dataclasses.dataclass
class EmbedTower:
    params: dict
    cfg: T.TransformerConfig

    def __post_init__(self):
        self._embed = jax.jit(
            lambda p, toks: T.embed_pool(p, toks, self.cfg))

    def embed(self, tokens: np.ndarray, batch: int = 64) -> np.ndarray:
        out = []
        n = tokens.shape[0]
        pad = (-n) % batch
        toks = np.pad(tokens, ((0, pad), (0, 0))) if pad else tokens
        for s in range(0, len(toks), batch):
            out.append(np.asarray(self._embed(self.params, toks[s:s + batch])))
        return np.concatenate(out)[:n]


@dataclasses.dataclass
class ServeStats:
    d_calls: int = 0
    D_calls: int = 0  # expensive-tower document embeddings (the budget)


class BiMetricEngine:
    """corpus_tokens: (N, S) int32 document tokens."""

    def __init__(self, cheap: EmbedTower, expensive: EmbedTower,
                 corpus_tokens: np.ndarray,
                 index_cfg: vamana.VamanaConfig | None = None):
        self.cheap = cheap
        self.expensive = expensive
        self.corpus_tokens = corpus_tokens
        self.n = corpus_tokens.shape[0]
        # --- index build: cheap metric ONLY --------------------------------
        self.emb_d = jnp.asarray(cheap.embed(corpus_tokens))
        self.index = vamana.build(self.emb_d,
                                  index_cfg or vamana.VamanaConfig(
                                      max_degree=16, l_build=24, pool_size=48,
                                      rev_candidates=16))
        self._em_d = distances.EmbeddingMetric(self.emb_d)
        self._adj = np.asarray(self.index.adjacency)

    # ---------------------------------------------------------------- query
    def query(self, query_tokens: np.ndarray, *, quota: int, k: int = 10,
              n_seeds: int | None = None) -> tuple[np.ndarray, np.ndarray, ServeStats]:
        """One query (S,) tokens. Returns (ids, D-dists, stats)."""
        stats = ServeStats()
        q_d = jnp.asarray(self.cheap.embed(query_tokens[None])[0])
        q_D = self.expensive.embed(query_tokens[None])[0]
        n_seeds = n_seeds or max(1, quota // 2)

        # stage 1 — cheap greedy search on device
        from repro.core.beam import greedy_search
        res = greedy_search(
            lambda ids: self._em_d.dists(q_d, ids),
            self.index.adjacency,
            jnp.array([self.index.medoid], jnp.int32),
            n_points=self.n, beam_width=max(32, n_seeds),
            pool_size=max(32, n_seeds), max_steps=4 * max(32, n_seeds),
        )
        stats.d_calls = int(res.n_calls)
        seeds = [int(i) for i in np.asarray(res.pool_ids[:n_seeds]) if i >= 0]

        # stage 2 — host-orchestrated greedy under the expensive tower
        emb_cache: dict[int, np.ndarray] = {}

        def D(ids: list[int]) -> np.ndarray:
            new = [i for i in ids if i not in emb_cache]
            if new:
                allowed = max(0, quota - stats.D_calls)
                new = new[:allowed]
                if new:
                    embs = self.expensive.embed(self.corpus_tokens[new])
                    for i, e in zip(new, embs):
                        emb_cache[i] = e
                    stats.D_calls += len(new)
            return np.array([
                np.linalg.norm(q_D - emb_cache[i]) if i in emb_cache else np.inf
                for i in ids
            ])

        dists = {i: d for i, d in zip(seeds, D(seeds))}
        expanded: set[int] = set()
        while stats.D_calls < quota:
            frontier = [i for i in sorted(dists, key=dists.get)
                        if i not in expanded and np.isfinite(dists[i])][:1]
            if not frontier:
                break
            v = frontier[0]
            expanded.add(v)
            nbrs = [int(u) for u in self._adj[v] if u >= 0 and u not in dists]
            if nbrs:
                for u, du in zip(nbrs, D(nbrs)):
                    if np.isfinite(du):
                        dists[u] = float(du)
        order = sorted((d, i) for i, d in dists.items() if np.isfinite(d))[:k]
        ids = np.array([i for _, i in order], np.int64)
        dd = np.array([d for d, _ in order], np.float64)
        return ids, dd, stats

    def rerank_query(self, query_tokens: np.ndarray, *, quota: int,
                     k: int = 10) -> tuple[np.ndarray, np.ndarray, ServeStats]:
        """"Bi-metric (baseline)": top-quota by d, embed all with D, rerank."""
        stats = ServeStats()
        q_d = jnp.asarray(self.cheap.embed(query_tokens[None])[0])
        q_D = self.expensive.embed(query_tokens[None])[0]
        from repro.core.beam import greedy_search
        res = greedy_search(
            lambda ids: self._em_d.dists(q_d, ids),
            self.index.adjacency,
            jnp.array([self.index.medoid], jnp.int32),
            n_points=self.n, beam_width=max(32, quota),
            pool_size=max(32, quota), max_steps=8 * max(32, quota),
        )
        stats.d_calls = int(res.n_calls)
        cand = [int(i) for i in np.asarray(res.pool_ids[:quota]) if i >= 0]
        embs = self.expensive.embed(self.corpus_tokens[cand])
        stats.D_calls = len(cand)
        dd = np.linalg.norm(embs - q_D[None], axis=1)
        order = np.argsort(dd)[:k]
        return np.asarray(cand)[order], dd[order], stats
