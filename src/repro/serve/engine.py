"""Bi-metric serving engine: the paper's deployment story, end to end.

* the **cheap tower** (e.g. qwen3-0.6b / bge-micro-like) runs locally and
  embeds the corpus once at index-build time — the graph index is built on
  those embeddings only (Theorem 1.1 property 1);
* the **expensive tower** (e.g. deepseek-v3 / SFR-Mistral-like) is the
  ground-truth metric D: scoring a document costs a forward pass. The engine
  enforces the call budget *exactly* — the quota is literally a compute
  budget on the big model;
* queries run the two-stage search **as a batch**. Stage 1 is one
  batched-engine run under d on device. Stage 2 drives the *same* core hot
  loop (``repro.core.beam.plan_step`` / ``commit_scores``) from the host:
  each wave is planned on device for every query at once, the union of
  documents the wave needs is drained through the expensive tower in batched
  forward passes, and the scores are committed back on device. Per-query
  accounting is identical to running each query alone (a document counts
  against a query's quota the first time that query scores it), while the
  tower only ever embeds a document once per engine lifetime — the
  cross-query cache is pure compute savings.

The native request unit is a frozen :class:`SearchRequest` (tokens, quota,
k, n_seeds, expand_width, deadline_ms, priority); results are
:class:`SearchResult` (ids, D-dists, :class:`ServeStats`). Two drives:

* **synchronous** — :meth:`BiMetricEngine.query_batch` /
  :meth:`BiMetricEngine.query` run one request batch to completion inline;
* **asynchronous** — :meth:`BiMetricEngine.submit` hands one request to a
  deadline/priority-ordered admission queue and returns a
  :class:`ServeFuture`. The engine keeps one resident **slot pool**: an
  (S,)-row :class:`repro.core.beam.BatchedSearchState` (sharded through a
  :class:`repro.core.beam.ShardedStepper` when ``shards > 1``) whose rows
  are recycled continuously. A finished query frees its slot *mid-flight* —
  its future resolves the step it goes inactive, not at a wave boundary —
  and admission refills freed rows from the queue on every plan/commit
  step (``repro.core.beam.reset_slots``), so a long-running request never
  blocks its neighbors (no head-of-line blocking, the continuous-batching
  idiom). The drive thread overlaps the expensive-tower drain of the
  current step with the cheap-tower embed + stage-1 search of the next
  admission group; per-slot drains replace the retired per-wave ping-pong.

Because every budget knob (quota, beam width, step cap, seeds, expand
width) is a per-row operand in the core engine and the pools are streaming
exact top-P structures, a slot row's trajectory is bit-exact to running the
same request through the synchronous drive — admission order, slot-mates
and pool-capacity growth are all invisible to a request's answer.

``EmbedTower`` wraps (params, config, pooling); swap in any LM arch config.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import heapq
import math
import queue
import threading
import time
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels
from repro.core import beam, covertree, distances, vamana
from repro.distributed import sharding
from repro.kernels import ops
from repro.models import transformer as T
from repro.serve import faults as serve_faults

Array = jax.Array


class DeadlineExceeded(Exception):
    """A request's ``deadline_ms`` expired before it resolved.

    Raised into the request's future by the admission layer (expiry while
    queued) or, under ``on_tower_failure="fail"``, by the drive loop's
    mid-flight enforcement (expiry while resident in a slot — checked on
    every step *and* while a tower drain is in flight, so a hung drain
    cannot stall it). Under ``on_tower_failure="degrade"`` a mid-flight
    expiry resolves the request with proxy-ranked results
    (``ServeStats.degraded``) instead. Every expiry is counted in
    ``EngineCounters.deadline_misses``."""


class TowerFailure(RuntimeError):
    """The expensive-tower lane gave up on a request.

    Raised into affected futures under ``on_tower_failure="fail"`` when
    the lane's bounded retries are exhausted, a failure is non-retryable,
    the drain timed out, or the circuit breaker is open. ``__cause__``
    carries the original tower exception with its traceback. Only the
    affected requests fail — the engine keeps serving."""


class TowerTimeout(TowerFailure):
    """A tower-lane call exceeded ``drain_timeout_ms`` (hung lane).

    Never retried inline (the lane is serial — a retry would queue behind
    the hung call); the breaker records the failure and the
    ``on_tower_failure`` policy resolves the resident requests."""


class AdmissionFailed(RuntimeError):
    """A request's admission group failed before slot residency.

    A cheap-tower embed or stage-1 error fails only that group's futures
    (``__cause__`` carries the original exception); resident slots and
    later admissions are untouched."""


class EngineFailure(RuntimeError):
    """Last resort: an unexpected drive-loop error that may have poisoned
    the resident device state. Every resident/staged future fails with
    this (``__cause__`` carries the original traceback) and the state is
    dropped; the engine itself keeps serving — the next admission
    re-initializes a fresh resident state. Tower failures never take this
    path (they have isolation paths: retry, breaker, policy)."""


# --------------------------------------------------------------------------
# legacy-form deprecation shims (the PR-5 ``backend=`` pattern: warn once
# per (call-site, form), keep the old behavior exactly)
# --------------------------------------------------------------------------
_warned: set[tuple[str, str]] = set()


def _warn_legacy(func: str, form: str) -> None:
    key = (func, form)
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(
        f"{func}: the legacy {form} call form is deprecated; pass a "
        "repro.serve.SearchRequest instead",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True, eq=False)
class SearchRequest:
    """One search request — the native unit of the serve API.

    ``tokens`` is the (S,) query token row; ``quota`` the exact expensive-D
    call budget; ``k`` the result size; ``n_seeds`` the stage-1 seed count
    (None = the ``max(1, quota // 2)`` default); ``expand_width`` the
    stage-2 frontier width (per-request — slot-mates may differ);
    ``deadline_ms`` a queue deadline relative to submit (expiry while
    *queued* fails the future with :class:`DeadlineExceeded`); ``priority``
    orders admission (higher first, FIFO within a priority).
    """

    tokens: np.ndarray
    quota: int
    k: int = 10
    n_seeds: int | None = None
    expand_width: int = 1
    deadline_ms: float | None = None
    priority: int = 0


@dataclasses.dataclass
class ServeStats:
    d_calls: int = 0
    D_calls: int = 0  # expensive-tower document scorings (the budget)
    # forward-pass batches the engine drained during this request's
    # residency (slot drive: shared across co-resident slots; sync drive:
    # the whole batch's drains, replicated per row — do not sum)
    tower_batches: int = 0
    # async slot drive only: submit -> slot-admission wait, and admission ->
    # future-resolution compute. Both 0.0 on the synchronous drives, which
    # have no queueing to measure.
    queue_ms: float = 0.0
    compute_ms: float = 0.0
    # admission-time snapshots (async slot drive only)
    slot_occupancy: int = 0
    queue_depth: int = 0
    # True when the graceful-degradation path resolved this request (tower
    # open-circuit, tower-down policy, or mid-flight deadline expiry under
    # on_tower_failure="degrade"): ids/dists are the stage-1 proxy ranking
    # — distances under the cheap metric d, quality bounded by the paper's
    # C-approximation factor — or, for covertree (no proxy stage), the
    # already-D-scored pool prefix. D_calls still counts scorings spent
    # before degradation.
    degraded: bool = False

    @property
    def latency_ms(self) -> float:
        """Submit -> resolve wall clock (``queue_ms + compute_ms``)."""
        return self.queue_ms + self.compute_ms


class SearchResult(NamedTuple):
    """(ids, D-dists, stats) — tuple-unpacks like the legacy return."""

    ids: np.ndarray
    dists: np.ndarray
    stats: ServeStats


@dataclasses.dataclass
class EngineCounters:
    """Cumulative admission-layer observability (:meth:`BiMetricEngine.counters`)."""

    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    cancelled: int = 0
    deadline_misses: int = 0
    queue_depth: int = 0
    slot_occupancy: int = 0
    # fault-tolerance layer (see repro.serve "Failure semantics")
    retries: int = 0  # tower-lane retry attempts after transient failures
    tower_failures: int = 0  # failed tower-lane calls (counted pre-retry)
    degraded: int = 0  # requests resolved degraded (ServeStats.degraded)
    shed: int = 0  # requests failed fast by tower-down policy "fail"
    breaker_opens: int = 0  # breaker closed->open transitions (snapshot)


@dataclasses.dataclass
class EmbedTower:
    params: dict
    cfg: T.TransformerConfig

    def __post_init__(self):
        self._embed = jax.jit(
            lambda p, toks: T.embed_pool(p, toks, self.cfg))

    def embed(self, tokens: np.ndarray, batch: int = 64) -> np.ndarray:
        out = []
        n = tokens.shape[0]
        pad = (-n) % batch
        toks = np.pad(tokens, ((0, pad), (0, 0))) if pad else tokens
        for s in range(0, len(toks), batch):
            out.append(np.asarray(self._embed(self.params, toks[s:s + batch])))
        return np.concatenate(out)[:n]


class ServeFuture(concurrent.futures.Future):
    """Result handle for one :meth:`BiMetricEngine.submit` request.

    A stdlib :class:`concurrent.futures.Future`; ``result(timeout)`` blocks
    for a :class:`SearchResult`. The engine resolves exactly once; a
    user-side ``cancel()`` race is swallowed (an admitted slot still
    computes — admission has no preemption). Requests still queued when
    :meth:`BiMetricEngine.close` runs are cancelled (``result()`` raises
    ``CancelledError``); a queued deadline expiry raises
    :class:`DeadlineExceeded`."""

    def _resolve(self, value) -> None:
        try:
            self.set_result(value)
        except concurrent.futures.InvalidStateError:
            pass  # cancelled by the caller; the computed slot is discarded

    def _fail(self, exc: BaseException) -> None:
        try:
            self.set_exception(exc)
        except concurrent.futures.InvalidStateError:
            pass


@dataclasses.dataclass
class _Pending:
    """One queued request: (request, future, submit stamp)."""

    req: SearchRequest
    future: ServeFuture
    t_submit: float


@dataclasses.dataclass
class _Active:
    """Per-slot bookkeeping for an admitted request."""

    pend: _Pending
    t_admit: float
    d_calls: int
    tower0: int  # pool drain counter at admission
    occ_snap: int
    depth_snap: int
    # stage-1 proxy pool row (ids sorted by d-dist; vamana only) — the
    # degraded-resolution answer when the tower lane is down or the
    # deadline expires mid-flight
    proxy_ids: np.ndarray | None = None
    proxy_dists: np.ndarray | None = None


@dataclasses.dataclass
class _Prepared:
    """An admission group after tower embed + stage 1, ready to reset slots."""

    valid: list  # [(pending, slot)]
    seeds: np.ndarray  # (S, seed_cap)
    quota: np.ndarray  # (S,) — admitted rows only; 0 elsewhere
    nseed: np.ndarray  # (S,)
    d_calls: np.ndarray  # (S,)
    q_D: np.ndarray  # (S, dim_D)
    # full stage-1 pools (vamana; None for covertree) — per-slot degraded
    # answers keep the whole proxy ranking, not just the seed prefix
    proxy_ids: np.ndarray | None = None  # (S, P1)
    proxy_dists: np.ndarray | None = None  # (S, P1)


_STOP = object()  # tower-queue sentinel


# ---------------------------------------------------------------------------
# jitted device-lane steps (shards == 1). beam_width / max_steps / quota /
# expand_width ride as (B,) operands so mixed per-query budgets do not
# retrace; only the static lane cap (expand_cap) recompiles.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=(
    "n_points", "pool_size", "dedup", "set_capacity"))
def _init_j(entry_ids, quota, *, n_points, pool_size, dedup, set_capacity):
    return beam.init_state(
        entry_ids, n_points=n_points, pool_size=pool_size, quota=quota,
        dedup=dedup, set_capacity=set_capacity)


def _round_capacity(quota_max: int) -> int:
    """Static sorted-set capacity for a wave: max quota rounded up to the
    next power of two, so heterogeneous request quotas fall into log-many
    capacity buckets (bounded retraces) instead of one trace per distinct
    quota. An all-quota-0 wave (admission padding only) gets a genuine
    zero-capacity set — same program shape family, no bitmap fallback."""
    return 0 if quota_max <= 0 else 1 << (int(quota_max) - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("expand_cap",))
def _plan_step_j(state, adjacency, quota, beam_width, max_steps,
                 expand_width, *, expand_cap):
    return beam.plan_step(
        state, adjacency, beam_width=beam_width, quota=quota,
        max_steps=max_steps, expand_width=expand_width,
        expand_cap=expand_cap)


_admit_j = jax.jit(beam.reset_slots)
_reopen_j = jax.jit(beam.reset_expanded)
_frontier_j = jax.jit(ops.frontier_count)


@functools.partial(jax.jit, static_argnames=("expand_cap",))
def _plan_ct_j(state, children, level, quota, beam_width, max_steps,
               expand_width, *, expand_cap):
    """Cover-tree wave plan: level-indexed child table, dedup-free lanes
    (child slabs partition each level, so a wave never repeats an id)."""
    return beam.plan_step(
        state, children, beam_width=beam_width, quota=quota,
        max_steps=max_steps, expand_width=expand_width,
        expand_cap=expand_cap, level=level, wave_dedup=False)


@jax.jit
def _wave_dists_j(doc_embs, q_D):
    """L2 under D from gathered doc embeddings (masked lanes fixed later)."""
    diff = doc_embs.astype(jnp.float32) - q_D[:, None, :].astype(jnp.float32)
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1))


# Backend is a frozen (hashable) dataclass — a jit static, so each merge
# route compiles its own program instead of tracing the knob.
_commit_j = functools.partial(
    jax.jit, static_argnames=("backend",))(beam.commit_scores)


@jax.jit
def _active_any_j(state, quota, beam_width, max_steps):
    return beam.active_mask(
        state, beam_width=beam_width, quota=quota, max_steps=max_steps).any()


@jax.jit
def _active_j(state, quota, beam_width, max_steps):
    return beam.active_mask(
        state, beam_width=beam_width, quota=quota, max_steps=max_steps)


class _SlotPool:
    """The drive thread's resident slot state (one per started engine).

    Owns the (S,)-row search state, the per-slot host vectors (quota, beam
    width, step cap, k, expand width), the resident expensive query
    embeddings, and the static-shape caps (pool size P, sorted-set capacity
    C, seed/expand lane caps). Caps only grow, in power-of-two buckets, so
    mixed workloads retrace log-many times; growth is an exact semantic
    no-op (``repro.core.beam.grow_state``). All methods run on the drive
    thread only.
    """

    def __init__(self, eng: "BiMetricEngine"):
        self.eng = eng
        s = eng.slots
        self.S = s
        self.occupied = np.zeros(s, bool)
        self.active_req: list[_Active | None] = [None] * s
        self.quota = np.zeros(s, np.int32)
        self.L = np.ones(s, np.int32)
        self.ms = np.zeros(s, np.int32)
        self.k = np.ones(s, np.int32)
        self.ew = np.ones(s, np.int32)
        self.ct_level = np.zeros(s, np.int32)  # covertree descent position
        self.q_D: np.ndarray | None = None
        self.state = None
        self.pool_size = 0
        self.dedup: str | None = None
        self.cap: int | None = None
        self.ew_cap = 1
        self.tower_total = 0
        self.prepared: _Prepared | None = None
        # rows whose future already resolved early (mid-flight deadline /
        # degradation while a wave was in flight): freed only at the next
        # sweep point so an in-flight commit never races a re-admission
        self.early = np.zeros(s, bool)
        self._tower_exc: BaseException | None = None

    # ---------------------------------------------------------------- admit
    def prepare(self, group: list[_Pending]) -> _Prepared | None:
        """Stage a group for admission: expensive query embeds through the
        tower lane, cheap embed + stage-1 seed search on the drive thread
        (the two overlap when the tower is already busy draining a step).
        Malformed requests fail their own future here and are dropped.

        The group is one isolation domain: a cheap-tower or stage-1 error
        fails only this group's futures (:class:`AdmissionFailed`, the
        original exception on ``__cause__``) and the engine keeps serving.
        An expensive-tower query-embed failure follows the engine's
        ``on_tower_failure`` policy — ``"degrade"`` resolves the group
        proxy-only (stage-1 ranking, ``ServeStats.degraded``) since that
        path needs no expensive embeddings at all. While the tower lane is
        open-circuit under ``"degrade"``, the group short-circuits to
        proxy-only serving without ever occupying a slot."""
        try:
            return self._prepare_inner(group)
        except (KeyboardInterrupt, SystemExit):
            raise
        except BaseException as exc:
            tower = isinstance(exc, TowerFailure)
            shed = 0
            for pend in group:
                if pend.future.done():
                    continue  # failed individually (malformed tokens)
                if tower:
                    # the lane (not the group) is the failure: keep the
                    # class so callers can tell outage from bad input
                    err = TowerFailure(
                        "expensive-tower lane unavailable at admission "
                        "(see __cause__)")
                else:
                    err = AdmissionFailed(
                        "admission group failed before slot residency "
                        "(see __cause__)")
                err.__cause__ = exc
                pend.future._fail(err)
                shed += 1
            with self.eng._mu:
                self.eng._counters.shed += shed
            return None

    def _prepare_inner(self, group: list[_Pending]) -> _Prepared | None:
        eng = self.eng
        seq = eng.corpus_tokens.shape[1]
        slots = np.nonzero(~self.occupied)[0][:len(group)]
        tokens = np.zeros((self.S, seq), eng.corpus_tokens.dtype)
        quota_g = np.zeros(self.S, np.int32)
        nseed_g = np.ones(self.S, np.int32)
        valid: list = []
        for pend, slot in zip(group, slots):
            t = np.asarray(pend.req.tokens)
            if t.ndim != 1 or t.shape[0] != seq:
                pend.future._fail(ValueError(
                    f"request tokens shape {t.shape} != ({seq},)"))
                continue
            q = int(pend.req.quota)
            tokens[slot] = t
            quota_g[slot] = q
            ns = pend.req.n_seeds
            nseed_g[slot] = max(1, q // 2) if ns is None else max(1, int(ns))
            valid.append((pend, int(slot)))
        if not valid:
            return None
        blocked = eng._breaker.blocked()
        if eng.index_kind == "covertree":
            # no proxy stage 1: Algorithm 3 descends from the top cover
            # under D directly — the cheap metric's job ended at build
            # time. With the lane open-circuit there is no proxy ranking
            # to degrade to either, so the group is shed fast.
            if blocked:
                raise TowerFailure(
                    "expensive-tower lane is open-circuit and the "
                    "covertree index has no proxy stage to degrade to")
            qfut = eng._tower_submit(("embed_queries", tokens))
            root = np.asarray(eng._flat.root_ids, np.int32)
            seeds = np.full((self.S, root.shape[0]), -1, np.int32)
            for _, slot in valid:
                seeds[slot] = root
            return _Prepared(
                valid=valid, seeds=seeds, quota=quota_g, nseed=nseed_g,
                d_calls=np.zeros(self.S, np.int32),
                q_D=np.asarray(eng._tower_result(
                    qfut, ("embed_queries", tokens), pool=self)))
        if blocked and eng.on_tower_failure == "fail":
            raise TowerFailure(
                "expensive-tower lane is open-circuit "
                f"({eng._breaker.failures} consecutive failures)")
        degrade_only = blocked  # policy "degrade": proxy-only admission
        # expensive query embed rides the tower lane; the cheap embed and
        # stage-1 proxy search run here meanwhile. Fixed (S, seq) shapes
        # with zero-pad rows keep per-row embeddings bit-exact regardless
        # of group composition (the tower pads to its own batch anyway).
        qfut = (None if degrade_only
                else eng._tower_submit(("embed_queries", tokens)))
        if eng._faults is not None:
            eng._faults.fire("cheap_embed")
        q_d = jnp.asarray(eng.cheap.embed(tokens))
        width1 = np.where(quota_g > 0, np.maximum(32, nseed_g), 1
                          ).astype(np.int32)
        pool1 = _round_capacity(int(max(width1.max(), nseed_g.max())))
        res1 = eng._stage1(
            q_d, width=jnp.asarray(width1), pool=pool1,
            max_steps=jnp.asarray(4 * width1 * (quota_g > 0)))
        lane = np.arange(res1.pool_ids.shape[1], dtype=np.int32)
        seed_cap = _round_capacity(int(nseed_g.max()))
        seeds = np.asarray(jnp.where(
            jnp.asarray(lane[None, :] < nseed_g[:, None]),
            res1.pool_ids, -1))[:, :seed_cap]
        proxy_ids = np.asarray(res1.pool_ids)
        proxy_dists = np.asarray(res1.pool_dists)
        d_calls = np.asarray(res1.n_calls)
        if degrade_only:
            self._finish_degraded_group(valid, proxy_ids, proxy_dists,
                                        d_calls)
            return None
        try:
            q_D = np.asarray(eng._tower_result(
                qfut, ("embed_queries", tokens), pool=self))
        except (KeyboardInterrupt, SystemExit):
            raise
        except BaseException:
            if eng.on_tower_failure == "degrade":
                # the proxy ranking is already in hand — resolve the
                # group degraded instead of failing it
                self._finish_degraded_group(valid, proxy_ids, proxy_dists,
                                            d_calls)
                return None
            raise
        return _Prepared(
            valid=valid, seeds=seeds, quota=quota_g, nseed=nseed_g,
            d_calls=d_calls, q_D=q_D,
            proxy_ids=proxy_ids, proxy_dists=proxy_dists)

    def _finish_degraded_group(self, valid, proxy_ids, proxy_dists,
                               d_calls) -> None:
        """Resolve a staged admission group proxy-only (stage-1 ranking,
        ``degraded=True``) without it ever occupying a slot — the
        open-circuit serving mode. quota-0 rows resolve empty, exactly as
        they would fault-free."""
        eng = self.eng
        now = time.monotonic()
        for pend, s in valid:
            kk = int(pend.req.k)
            ids = np.asarray(proxy_ids[s, :kk], np.int64)
            dd = np.asarray(proxy_dists[s, :kk], np.float64)
            if int(pend.req.quota) <= 0:
                ids, dd = ids[:0], dd[:0]
            ok = (ids >= 0) & np.isfinite(dd)
            stats = ServeStats(
                d_calls=int(d_calls[s]), D_calls=0,
                queue_ms=(now - pend.t_submit) * 1e3, compute_ms=0.0,
                degraded=True)
            pend.future._resolve(SearchResult(ids[ok], dd[ok], stats))
        with eng._mu:
            eng._counters.degraded += len(valid)
            eng._counters.completed += len(valid)

    def admit(self, prep: _Prepared) -> None:
        """Recycle the group's slots in the resident state and pay the entry
        wave (``reset_slots`` + entry drain + commit). Rows outside the
        group are untouched bit-for-bit."""
        eng = self.eng
        now = time.monotonic()
        depth = eng._queue_depth()
        for pend, s in prep.valid:
            r = pend.req
            q = int(r.quota)
            ns = int(prep.nseed[s])
            self.quota[s] = q
            if eng.index_kind == "covertree":
                # level descent: no beam/step budget — termination is the
                # eps rule or the level cap, both applied by step_ct
                self.L[s] = beam.NO_QUOTA
                self.ms[s] = beam.NO_QUOTA
                self.ct_level[s] = 0
            else:
                self.L[s] = max(int(r.k), min(q, 2 * ns + 8))
                self.ms[s] = 4 * q
            self.k[s] = int(r.k)
            self.ew[s] = max(1, int(r.expand_width))
            self.occupied[s] = True
        for pend, s in prep.valid:
            self.active_req[s] = _Active(
                pend=pend, t_admit=now, d_calls=int(prep.d_calls[s]),
                tower0=self.tower_total,
                occ_snap=int(self.occupied.sum()), depth_snap=depth,
                proxy_ids=(None if prep.proxy_ids is None
                           else prep.proxy_ids[s].copy()),
                proxy_dists=(None if prep.proxy_dists is None
                             else prep.proxy_dists[s].copy()))
        if self.q_D is None or self.q_D.shape[1] != prep.q_D.shape[1]:
            self.q_D = np.zeros((self.S, prep.q_D.shape[1]), prep.q_D.dtype)
        for _, s in prep.valid:
            self.q_D[s] = prep.q_D[s]

        # dedup backend: resolved once (first admission), then only the
        # sorted capacity grows — switching backends mid-residency would
        # force a full state rebuild for zero semantic gain (they are
        # bit-exact to each other)
        if self.dedup is None:
            self.dedup, self.cap = beam.resolve_dedup(
                eng.dedup, _round_capacity(int(self.quota.max())),
                self.quota, eng.n, drive="host")
        elif self.dedup == "sorted":
            need = _round_capacity(int(self.quota.max()))
            if self.cap is not None and need > self.cap:
                self.cap = need
                if self.state is not None:
                    self.state = beam.grow_state(
                        self.state, set_capacity=need)
        if eng.index_kind == "covertree":
            # pool = the memoized D-call set (bounded by quota and N), never
            # smaller than the root cover or the static plan chunk
            p_need = max(_round_capacity(int(max(
                int(self.k.max()), eng._flat.root_ids.shape[0],
                min(eng.n, int(self.quota.max()))))), eng._ct_chunk)
        else:
            p_need = _round_capacity(int(max(self.L.max(), self.k.max())))
        if self.state is None:
            self.pool_size = max(p_need, 1)
            empty = np.full((self.S, 1), -1, np.int32)
            zeros = np.zeros((self.S,), np.int32)
            if eng._stepper is not None:
                self.state, _, _ = eng._stepper.init(
                    empty, zeros, pool_size=self.pool_size,
                    dedup=self.dedup, set_capacity=self.cap)
            else:
                self.state, _, _ = _init_j(
                    jnp.asarray(empty), jnp.asarray(zeros),
                    n_points=eng.n, pool_size=self.pool_size,
                    dedup=self.dedup, set_capacity=self.cap)
        elif p_need > self.pool_size:
            self.pool_size = p_need
            self.state = beam.grow_state(self.state, pool_size=p_need)

        reset = np.zeros(self.S, bool)
        for _, s in prep.valid:
            reset[s] = True
        quota_j = jnp.asarray(self.quota)
        if eng._stepper is not None:
            self.state, safe, keep = eng._stepper.admit(
                self.state, reset, prep.seeds, quota_j)
        else:
            self.state, safe, keep = _admit_j(
                self.state, jnp.asarray(reset), jnp.asarray(prep.seeds),
                quota_j)
        self._drain_and_commit(safe, keep)
        with eng._mu:
            eng._counters.admitted += len(prep.valid)
            eng._counters.slot_occupancy = int(self.occupied.sum())

    # ----------------------------------------------------------------- step
    def _overlap_prepare(self) -> None:
        """Stage the next admission group while the tower drains (the slot
        pool's compute overlap) — at most once per in-flight drain."""
        eng = self.eng
        if self.prepared is None and not eng._closed:
            free = int((~self.occupied).sum())
            group = eng._pop_group(free) if free else []
            if group:
                self.prepared = self.prepare(group)

    def _drain_wave(self, ids: np.ndarray, *, overlap: bool) -> int | None:
        """One wave drain through the tower lane with bounded
        exponential-backoff retries (transient failures) and breaker
        accounting. Returns the drained batch count, or ``None`` when the
        lane gave up — breaker open, retries exhausted, non-retryable
        error, or drain timeout — with the terminal exception stashed for
        :meth:`tower_down` to chain onto the affected futures."""
        eng = self.eng
        if eng._breaker.blocked():
            self._tower_exc = TowerFailure(
                "expensive-tower lane is open-circuit "
                f"({eng._breaker.failures} consecutive failures)")
            if overlap:
                self._overlap_prepare()
            return None
        fut = eng._tower_submit(("drain", ids))
        if overlap:
            self._overlap_prepare()
        try:
            return eng._tower_result(fut, ("drain", ids), pool=self)
        except (KeyboardInterrupt, SystemExit):
            raise
        except BaseException as exc:
            self._tower_exc = exc
            return None

    def step(self) -> None:
        """One plan/drain/commit wave over every occupied slot. While the
        tower drains the wave's fresh documents, the drive thread prepares
        the next admission group (cheap embed + stage 1) — the slot pool's
        compute overlap. A drain the tower lane gives up on fails only the
        resident requests (per ``on_tower_failure``) via
        :meth:`tower_down`; mid-flight deadline expiries resolve during
        the drain wait and their rows are swept after the commit."""
        eng = self.eng
        if eng.index_kind == "covertree":
            return self.step_ct()
        self.ew_cap = max(self.ew_cap, int(self.ew.max()))
        quota_j = jnp.asarray(self.quota)
        L_j = jnp.asarray(self.L)
        ms_j = jnp.asarray(self.ms)
        if eng._stepper is not None:
            self.state, safe, keep, _ = eng._stepper.plan(
                self.state, eng._adjacency, quota_j, L_j, ms_j,
                expand_width=jnp.asarray(self.ew), expand_cap=self.ew_cap)
        else:
            self.state, safe, keep, _ = _plan_step_j(
                self.state, eng._adjacency, quota_j, L_j, ms_j,
                jnp.asarray(self.ew), expand_cap=self.ew_cap)
        safe_np = np.asarray(safe)
        batches = self._drain_wave(safe_np[np.asarray(keep)], overlap=True)
        if batches is None:
            return self.tower_down()
        self.tower_total += batches
        doc = jnp.asarray(eng._doc_embs(safe_np, self.q_D.shape[1]))
        dists = _wave_dists_j(doc, jnp.asarray(self.q_D))
        if eng._stepper is not None:
            self.state = eng._stepper.commit(self.state, safe, keep, dists)
        else:
            self.state = _commit_j(self.state, safe, keep, dists,
                                   backend=eng.backend)
        self.sweep_early()

    def step_ct(self) -> None:
        """One cover-tree level for every slot still descending.

        Per stepping row: size the frontier (pool prefix within the previous
        level's radius), re-open it, plan the level's fanout in chunk-wide
        waves (commits deferred past the last plan, so finer points cannot
        displace true frontier members mid-level), drain/commit each wave,
        then advance the row's level — the ε-criterion or the level cap
        freezes a finished row via ``ms = 0`` so ``resolve_finished`` picks
        it up. Rows at different levels ride the same waves; each row's
        chunk schedule depends only on its own frontier, which is what keeps
        a slot row bit-exact vs the synchronous drive."""
        eng = self.eng
        radii = eng._ct_radii
        l1 = eng._flat.depth - 1
        chunk = eng._ct_chunk
        stepping = self.occupied & (self.ms > 0)
        if l1 == 0:
            self.ms[stepping] = 0
            return
        quota_j = jnp.asarray(self.quota)
        L_j = jnp.asarray(self.L)
        ms_j = jnp.asarray(self.ms)
        t = self.ct_level.copy()
        radius = np.where(t == 0, np.inf,
                          radii[np.maximum(t - 1, 0)]).astype(np.float32)
        ew_t = np.asarray(_frontier_j(self.state.pool_dists,
                                      jnp.asarray(radius)))
        ew_t = np.where(stepping, ew_t, 0).astype(np.int32)
        if eng._stepper is not None:
            self.state = eng._stepper.reopen(self.state,
                                             jnp.asarray(stepping))
        else:
            self.state = _reopen_j(self.state, jnp.asarray(stepping))
        lev = jnp.asarray(np.minimum(t, l1 - 1).astype(np.int32))
        planned = []
        remaining = ew_t.copy()
        while remaining.max() > 0:
            ew = np.minimum(remaining, chunk).astype(np.int32)
            if eng._stepper is not None:
                self.state, safe, keep, _ = eng._stepper.plan(
                    self.state, eng._ct_children, quota_j, L_j, ms_j,
                    expand_width=jnp.asarray(ew), expand_cap=chunk,
                    level=lev, wave_dedup=False)
            else:
                self.state, safe, keep, _ = _plan_ct_j(
                    self.state, eng._ct_children, lev, quota_j, L_j, ms_j,
                    jnp.asarray(ew), expand_cap=chunk)
            planned.append((safe, keep))
            remaining -= ew
        for i, (safe, keep) in enumerate(planned):
            safe_np = np.asarray(safe)
            batches = self._drain_wave(safe_np[np.asarray(keep)],
                                       overlap=(i == 0))
            if batches is None:
                return self.tower_down()
            self.tower_total += batches
            doc = jnp.asarray(eng._doc_embs(safe_np, self.q_D.shape[1]))
            dists = _wave_dists_j(doc, jnp.asarray(self.q_D))
            if eng._stepper is not None:
                self.state = eng._stepper.commit(self.state, safe, keep,
                                                 dists)
            else:
                self.state = _commit_j(self.state, safe, keep, dists,
                                       backend=eng.backend)
        pd0 = np.asarray(self.state.pool_dists[:, 0], np.float64)
        cont = np.zeros(self.S, bool)
        for s in np.nonzero(stepping)[0]:
            tt = int(t[s])
            if tt >= l1:
                self.ms[s] = 0
                continue
            self.ct_level[s] = tt + 1
            stop = not (pd0[s] < radii[tt] * (1.0 + 1.0 / eng.ct_eps))
            if stop or tt + 1 >= l1:
                self.ms[s] = 0
            else:
                cont[s] = True
        # rows still descending keep an open frontier so active_mask holds
        # them resident even when a level admitted nothing fresh (the next
        # level's child rows may still reach new points). Rows resolved
        # early mid-level (deadline) stay frozen.
        cont &= ~self.early
        if cont.any():
            if eng._stepper is not None:
                self.state = eng._stepper.reopen(self.state,
                                                 jnp.asarray(cont))
            else:
                self.state = _reopen_j(self.state, jnp.asarray(cont))
        self.sweep_early()

    def _drain_and_commit(self, safe, keep) -> bool:
        """Entry-wave drain + commit (same tower lane as the step drains).
        Returns False when the tower lane gave up — the caller's group is
        already resolved/failed by :meth:`tower_down`."""
        eng = self.eng
        safe_np = np.asarray(safe)
        batches = self._drain_wave(safe_np[np.asarray(keep)], overlap=False)
        if batches is None:
            self.tower_down()
            return False
        self.tower_total += batches
        doc = jnp.asarray(eng._doc_embs(safe_np, self.q_D.shape[1]))
        dists = _wave_dists_j(doc, jnp.asarray(self.q_D))
        if eng._stepper is not None:
            self.state = eng._stepper.commit(self.state, safe, keep, dists)
        else:
            self.state = _commit_j(self.state, safe, keep, dists,
                                   backend=eng.backend)
        self.sweep_early()
        return True

    # ------------------------------------------------- degradation/deadlines
    def has_deadlines(self) -> bool:
        """Any resident request carrying a ``deadline_ms`` (drives the
        polling tower wait — fault-free deadline-less serving keeps the
        cheap blocking wait)."""
        for s in np.nonzero(self.occupied & ~self.early)[0]:
            a = self.active_req[s]
            if a is not None and a.pend.req.deadline_ms is not None:
                return True
        return False

    def _degraded_rows(self, a: _Active, s: int, ids_all, dd_all):
        """Best available ranking for a degraded resolution of slot ``s``:
        the stage-1 proxy pool when one exists (vamana), else the slot's
        current D-scored pool prefix (covertree — already ground-truth
        distances, just short of the full descent)."""
        if a.proxy_ids is not None:
            return a.proxy_ids, a.proxy_dists
        return ids_all[s], dd_all[s]

    def _resolve_degraded(self, s: int, ids_row, dd_row, *, now,
                          D_calls: int) -> None:
        """Resolve slot ``s``'s future with ``degraded=True`` stats from the
        given ranking. Does not free the slot — callers mark ``early`` and
        sweep at the next safe point."""
        a = self.active_req[s]
        r = a.pend.req
        kk = int(r.k)
        ids = np.asarray(ids_row[:kk], np.int64)
        dd = np.asarray(dd_row[:kk], np.float64)
        ok = (ids >= 0) & np.isfinite(dd)
        stats = ServeStats(
            d_calls=a.d_calls, D_calls=D_calls,
            tower_batches=self.tower_total - a.tower0,
            queue_ms=(a.t_admit - a.pend.t_submit) * 1e3,
            compute_ms=(now - a.t_admit) * 1e3,
            slot_occupancy=a.occ_snap, queue_depth=a.depth_snap,
            degraded=True)
        a.pend.future._resolve(SearchResult(ids[ok], dd[ok], stats))

    def expire_inflight(self, *, defer_free: bool = False) -> None:
        """Mid-flight deadline enforcement: resolve every resident slot
        whose deadline has passed — degraded (proxy ranking) under
        ``on_tower_failure="degrade"``, :class:`DeadlineExceeded` under
        ``"fail"`` — and close its frontier (``beam.early_resolve``) so the
        row stops consuming waves. With ``defer_free=True`` (called from
        inside a tower wait, a wave in flight) the rows are only marked
        ``early``; the commit path sweeps them afterward, so the in-flight
        wave never races a re-admission into the same row."""
        eng = self.eng
        if self.state is None:
            return
        now = time.monotonic()
        rows = np.zeros(self.S, bool)
        for s in np.nonzero(self.occupied & ~self.early)[0]:
            a = self.active_req[s]
            dl = a.pend.req.deadline_ms
            if dl is None or (now - a.pend.t_submit) * 1e3 <= dl:
                continue
            rows[s] = True
        if not rows.any():
            return
        ids_all = np.asarray(self.state.pool_ids)
        dd_all = np.asarray(self.state.pool_dists)
        calls = np.asarray(self.state.n_calls)
        degraded = 0
        failed = 0
        for s in np.nonzero(rows)[0]:
            a = self.active_req[s]
            if eng.on_tower_failure == "degrade":
                ids_row, dd_row = self._degraded_rows(a, s, ids_all, dd_all)
                self._resolve_degraded(s, ids_row, dd_row, now=now,
                                       D_calls=int(calls[s]))
                degraded += 1
            else:
                a.pend.future._fail(DeadlineExceeded(
                    f"deadline {a.pend.req.deadline_ms} ms exceeded "
                    "mid-flight"))
                failed += 1
            self.early[s] = True
        # close the expired rows' frontiers so active_mask drops them; the
        # other rows' state is untouched bit-for-bit
        self.state = beam.early_resolve(self.state, jnp.asarray(rows))
        with eng._mu:
            eng._counters.deadline_misses += degraded + failed
            eng._counters.degraded += degraded
            eng._counters.completed += degraded
        if not defer_free:
            self.sweep_early()

    def sweep_early(self) -> None:
        """Free the rows whose futures resolved early, now that no wave is
        in flight over them."""
        if not self.early.any():
            return
        for s in np.nonzero(self.early)[0]:
            self.free_slot(s)
        self.early[:] = False
        with self.eng._mu:
            self.eng._counters.slot_occupancy = int(self.occupied.sum())

    def tower_down(self) -> None:
        """The tower lane gave up on a drain (retries exhausted, breaker
        open, timeout, or a non-retryable error): apply the engine's
        ``on_tower_failure`` policy to every resident request instead of
        poisoning the engine. ``"degrade"`` resolves each slot proxy-only;
        ``"fail"`` fails each slot's future with :class:`TowerFailure`
        chaining the original error. Either way the resident state stays
        consistent (the failed wave was never committed) and the engine
        keeps serving."""
        eng = self.eng
        exc = self._tower_exc or TowerFailure("expensive-tower lane failed")
        self._tower_exc = None
        now = time.monotonic()
        ids_all = np.asarray(self.state.pool_ids)
        dd_all = np.asarray(self.state.pool_dists)
        calls = np.asarray(self.state.n_calls)
        degraded = 0
        failed = 0
        rows = self.occupied & ~self.early
        for s in np.nonzero(rows)[0]:
            a = self.active_req[s]
            if eng.on_tower_failure == "degrade":
                ids_row, dd_row = self._degraded_rows(a, s, ids_all, dd_all)
                self._resolve_degraded(s, ids_row, dd_row, now=now,
                                       D_calls=int(calls[s]))
                degraded += 1
            else:
                err = TowerFailure(
                    "expensive-tower drain failed; request resolved "
                    "against policy on_tower_failure='fail' (see __cause__)")
                err.__cause__ = exc
                a.pend.future._fail(err)
                failed += 1
            self.early[s] = True
        self.sweep_early()
        with eng._mu:
            eng._counters.degraded += degraded
            eng._counters.completed += degraded
            eng._counters.shed += failed

    # -------------------------------------------------------------- resolve
    def resolve_finished(self) -> None:
        """Free every occupied slot that went inactive this step: read its
        pool prefix, stamp stats, resolve the future *now* (mid-flight —
        the slot is immediately reusable by the next admission)."""
        eng = self.eng
        if self.state is None or not self.occupied.any():
            return
        quota_j = jnp.asarray(self.quota)
        L_j = jnp.asarray(self.L)
        ms_j = jnp.asarray(self.ms)
        if eng._stepper is not None:
            act = np.asarray(eng._stepper.active(
                self.state, quota_j, L_j, ms_j))
        else:
            act = np.asarray(_active_j(self.state, quota_j, L_j, ms_j))
        fin = self.occupied & ~act & ~self.early
        if not fin.any():
            return
        ids_all = np.asarray(self.state.pool_ids)
        dd_all = np.asarray(self.state.pool_dists)
        calls = np.asarray(self.state.n_calls)
        now = time.monotonic()
        done = 0
        misses = 0
        for s in np.nonzero(fin)[0]:
            a = self.active_req[s]
            r = a.pend.req
            kk = int(r.k)
            row_ids = ids_all[s, :kk].astype(np.int64)
            row_dd = dd_all[s, :kk].astype(np.float64)
            ok = (row_ids >= 0) & np.isfinite(row_dd)
            stats = ServeStats(
                d_calls=a.d_calls, D_calls=int(calls[s]),
                tower_batches=self.tower_total - a.tower0,
                queue_ms=(a.t_admit - a.pend.t_submit) * 1e3,
                compute_ms=(now - a.t_admit) * 1e3,
                slot_occupancy=a.occ_snap, queue_depth=a.depth_snap)
            if (r.deadline_ms is not None
                    and (now - a.pend.t_submit) * 1e3 > r.deadline_ms):
                misses += 1  # admitted late: resolve anyway, count the miss
            a.pend.future._resolve(
                SearchResult(row_ids[ok], row_dd[ok], stats))
            done += 1
            self.free_slot(s)
        with eng._mu:
            eng._counters.completed += done
            eng._counters.deadline_misses += misses
            eng._counters.slot_occupancy = int(self.occupied.sum())

    def free_slot(self, s: int) -> None:
        self.occupied[s] = False
        self.active_req[s] = None
        self.quota[s] = 0
        self.L[s] = 1
        self.ms[s] = 0
        self.k[s] = 1
        self.ew[s] = 1
        self.ct_level[s] = 0

    def fail_all(self, exc: BaseException) -> None:
        """Genuinely poisoned resident state (an error outside the isolated
        tower/admission paths): fail every resident + staged future with
        :class:`EngineFailure` chaining the original traceback, drop the
        state. The engine survives — the next admission re-initializes a
        fresh resident state. This is the last resort; tower failures are
        handled per-slot by :meth:`tower_down` and never land here."""
        eng = self.eng

        def _wrap() -> EngineFailure:
            err = EngineFailure(
                "engine drive loop failed; resident state dropped "
                "(see __cause__)")
            err.__cause__ = exc
            return err

        if self.prepared is not None:
            for pend, _ in self.prepared.valid:
                if not pend.future.done():
                    pend.future._fail(_wrap())
            self.prepared = None
        for s in np.nonzero(self.occupied)[0]:
            if not self.early[s]:
                self.active_req[s].pend.future._fail(_wrap())
            self.free_slot(s)
        self.early[:] = False
        self._tower_exc = None
        self.state = None
        with eng._mu:
            eng._counters.slot_occupancy = 0


class BiMetricEngine:
    """corpus_tokens: (N, S) int32 document tokens.

    ``shards > 1`` runs the device side of **both** stages device-parallel
    over a corpus mesh. Stage 1 is :func:`repro.core.beam.sharded_greedy_search`
    (corpus split across ``shards`` devices, pools replicated). Stage 2
    keeps its host drive loop — the metric is the expensive tower itself —
    but all its bookkeeping (plan, dedup lookup/insert, commit, slot
    admission) runs inside the mesh via
    :class:`repro.core.beam.ShardedStepper`. Results are bit-exact vs
    ``shards=1``.

    ``dedup`` selects stage 2's dedup-state backend: ``"sorted"`` carries a
    quota-proportional (B, quota) sorted membership set through the wave
    (capacity = the max quota rounded up to a power of two, so mixed
    budgets retrace at most log-many times; quota-0 padding rows ride along
    with zero insertions), ``"bitmap"`` the dense (B, N) bitmap, and
    ``"auto"`` (default) picks sorted whenever the quota bound is below N.
    Under ``shards > 1`` the sorted set is replicated like the pools. Both
    backends are bit-exact to each other. Stage 1 (quota-unbounded proxy
    search) always keeps the bitmap, per the same auto rule.

    ``backend`` selects the device-side kernel route for stage-1 wave
    scoring and the pool merges (``repro.kernels.resolve_backend`` values):
    ``"ref"`` (default) keeps the frozen-oracle numerics every parity
    guarantee is stated against; ``"auto"`` is the deployment knob.
    ``quantize`` (``"int8"`` / ``"fp8"`` / ``"fp8_e5m2"``) holds the
    stage-1 corpus in quantized residency (built once per engine lifetime);
    stage 2 is never quantized.

    ``slots`` (default ``max_batch``) sizes the async drive's persistent
    slot pool — the resident (S,)-row search state whose rows are recycled
    per request (see the module doc). ``max_wait_ms`` bounds the idle
    drive's poll interval. ``max_inflight`` configured the retired
    fixed-wave double buffer and is now inert (accepted for
    compatibility); the slot pool always overlaps the tower drain with the
    next admission group's stage-1 work. All of these are inert for the
    synchronous ``query*`` paths.

    **Fault tolerance** (async path; see ``repro.serve``'s "Failure
    semantics"): transient expensive-tower failures are retried up to
    ``tower_retries`` times with exponential backoff starting at
    ``retry_backoff_ms``; ``breaker_threshold`` consecutive failures open
    a circuit breaker on the tower lane for ``breaker_cooldown_ms``
    (half-open probes re-close it). ``on_tower_failure`` picks what a
    given-up tower call does to the affected requests: ``"fail"``
    (default) fails their futures with :class:`TowerFailure`,
    ``"degrade"`` resolves them with stage-1 proxy-ranked results
    (``ServeStats.degraded``). ``drain_timeout_ms`` bounds any single
    tower call (a hung drain becomes :class:`TowerTimeout`, never retried
    inline). ``faults`` accepts a ``repro.serve.faults.FaultPlan``
    (test/benchmark-only deterministic fault injection).
    """

    def __init__(self, cheap: EmbedTower, expensive: EmbedTower,
                 corpus_tokens: np.ndarray,
                 index_cfg: vamana.VamanaConfig | None = None,
                 tower_batch: int = 64, shards: int = 1,
                 max_batch: int = 8, max_wait_ms: float = 5.0,
                 max_inflight: int = 2, dedup: str = "auto",
                 backend="ref", quantize: str | None = None,
                 slots: int | None = None, index: str = "vamana",
                 covertree_eps: float = 0.5, covertree_T: float = 2.0,
                 on_tower_failure: str = "fail", tower_retries: int = 3,
                 retry_backoff_ms: float = 25.0, breaker_threshold: int = 5,
                 breaker_cooldown_ms: float = 2000.0,
                 drain_timeout_ms: float | None = None,
                 faults: "serve_faults.FaultPlan | None" = None):
        self.cheap = cheap
        self.expensive = expensive
        self.corpus_tokens = corpus_tokens
        self.n = corpus_tokens.shape[0]
        self.tower_batch = tower_batch
        self.shards = shards
        if dedup not in ("auto", "sorted", "bitmap"):
            raise ValueError(f"unknown dedup backend {dedup!r}")
        self.dedup = dedup
        self.backend = kernels.resolve_backend(
            backend, quantize=quantize, _caller="serve.BiMetricEngine")
        self.max_batch = max_batch
        self.slots = int(slots if slots is not None else max_batch)
        if self.slots < 1:
            raise ValueError("slots must be >= 1")
        self.max_wait = max_wait_ms / 1e3
        self.max_inflight = max(1, max_inflight)  # retired knob, kept inert
        if index not in ("vamana", "covertree"):
            raise ValueError(f"unknown index kind {index!r}")
        self.index_kind = index
        self.ct_eps = float(covertree_eps)
        if on_tower_failure not in ("fail", "degrade"):
            raise ValueError(
                f"unknown on_tower_failure policy {on_tower_failure!r}")
        self.on_tower_failure = on_tower_failure
        self.tower_retries = max(0, int(tower_retries))
        self.retry_backoff_s = max(0.0, retry_backoff_ms / 1e3)
        self.drain_timeout_s = (None if drain_timeout_ms is None
                                else max(0.0, drain_timeout_ms / 1e3))
        self._breaker = serve_faults.CircuitBreaker(
            threshold=breaker_threshold,
            cooldown_s=breaker_cooldown_ms / 1e3)
        self._faults = faults
        # --- index build: cheap metric ONLY --------------------------------
        self.emb_d = jnp.asarray(cheap.embed(corpus_tokens))
        if index == "covertree":
            # Algorithm 2 on the cheap embeddings (offline, per-query NumPy
            # — the query path is the batched engine); the flattened layout
            # is what the plan/commit programs index with static shapes
            tree = covertree.build(
                np.asarray(self.emb_d, np.float64), T=covertree_T)
            self._flat = covertree.flatten(tree)
            self._ct_children = jnp.asarray(self._flat.children)
            self._ct_radii = np.asarray(self._flat.radii, np.float64)
            self._ct_chunk = covertree.wave_chunk(self._flat.fanout)
            self.index = None
            self._em_d = None
            self._view_d = None
            self._dist_d = None
            self._adjacency = None
        else:
            self._flat = None
            self.index = vamana.build(self.emb_d,
                                      index_cfg or vamana.VamanaConfig(
                                          max_degree=16, l_build=24,
                                          pool_size=48, rev_candidates=16))
            self._em_d = distances.EmbeddingMetric(self.emb_d)
            # stage-1 scoring route: the matmul backends thread the
            # corpus-norm cache (built ONCE here, like the index) through
            # every wave; with quantize= the view is built quantized, also
            # once — the graph is still built on the exact embeddings, only
            # wave scoring is lossy
            need_view = (self.backend.matmul
                         or self.backend.quantize is not None)
            self._view_d = (kernels.as_corpus_view(
                self.emb_d, quantize=self.backend.quantize)
                if need_view else None)
            if need_view and shards == 1:
                self._dist_d = beam.fused_dist_fn(
                    self._view_d, self._em_d.metric, backend=self.backend)
            else:
                self._dist_d = self._em_d.dists_batch
            self._adjacency = self.index.adjacency.astype(jnp.int32)
        # one mesh for the engine lifetime; stage 2 steps through the same
        # mesh as stage 1 (ShardedStepper = the in-mesh plan/commit programs)
        self._mesh = (sharding.search_mesh(shards) if shards > 1 else None)
        self._stepper = (beam.ShardedStepper(
            shards=shards, n_points=self.n, mesh=self._mesh,
            backend=self.backend)
            if shards > 1 else None)
        # lazy expensive-tower document embeddings (engine-lifetime cache)
        self._emb_D: np.ndarray | None = None
        self._emb_D_valid = np.zeros((self.n,), bool)
        self._cache_lock = threading.Lock()
        # async slot-pool state (threads start lazily on the first submit).
        # _mu guards the admission queue + counters; the lifecycle lock
        # orders start/close vs submit. Lock order: lifecycle -> _mu.
        self._lifecycle_lock = threading.Lock()
        self._started = False
        self._closed = False
        self._threads: list[threading.Thread] = []
        self._mu = threading.RLock()
        self._q_cond = threading.Condition(self._mu)
        self._queue: list = []  # heap of (-priority, deadline, seq, _Pending)
        self._seq = 0
        self._counters = EngineCounters()
        self._tower_q: queue.Queue | None = None
        self._pool: _SlotPool | None = None
        self._tower_thread: threading.Thread | None = None

    # ------------------------------------------------------------ internals
    def _stage1(self, q_d: Array, *, width, pool: int,
                max_steps) -> beam.SearchResult:
        """Batched cheap-metric greedy search from the medoid (stage 1).

        ``width`` / ``max_steps`` may be per-query (B,) vectors (request
        waves mix budgets); ``pool`` is the static pool size. With
        ``shards > 1`` the same loop runs device-parallel over the engine's
        corpus mesh — bit-exact vs the single-device path."""
        b = q_d.shape[0]
        entries = jnp.broadcast_to(
            jnp.asarray(self.index.medoid, jnp.int32).reshape(1, 1), (b, 1))
        if self.shards > 1:
            return beam.sharded_greedy_search(
                self._view_d if self._view_d is not None else self.emb_d,
                self._adjacency, q_d, entries,
                shards=self.shards, metric=self._em_d.metric,
                mesh=self._mesh, beam_width=width, pool_size=pool,
                max_steps=max_steps, backend=self.backend)
        return beam.batched_greedy_search(
            self._dist_d, self._adjacency, q_d, entries,
            n_points=self.n, beam_width=width, pool_size=pool,
            max_steps=max_steps, backend=self.backend)

    def _drain_tower(self, ids: np.ndarray) -> int:
        """Embed not-yet-cached docs through the expensive tower; returns the
        number of forward batches drained. Serialized by the cache lock (the
        tower lane is single-file by construction; the lock also covers
        synchronous callers running concurrently with the slot drive)."""
        with self._cache_lock:
            need = np.unique(
                ids[(ids >= 0) & ~self._emb_D_valid[np.maximum(ids, 0)]])
            if need.size == 0:
                return 0
            embs = self.expensive.embed(self.corpus_tokens[need],
                                        batch=self.tower_batch)
            if self._emb_D is None:
                self._emb_D = np.zeros((self.n, embs.shape[1]), embs.dtype)
            self._emb_D[need] = embs
            self._emb_D_valid[need] = True
            return -(-need.size // self.tower_batch)

    def reset_doc_cache(self) -> None:
        """Drop the expensive-tower document cache (benchmark hygiene)."""
        with self._cache_lock:
            self._emb_D = None
            self._emb_D_valid[:] = False

    def _doc_embs(self, safe_np: np.ndarray, dim: int) -> np.ndarray:
        """(B, K, dim_D) gather from the host cache; rows a wave needs are
        guaranteed drained before the wave's commit runs."""
        emb = self._emb_D
        if emb is None:
            return np.zeros((*safe_np.shape, dim), np.float32)
        return emb[np.maximum(safe_np, 0)]

    # -------------------------------------------------------- wave coroutine
    def _wave_gen(self, query_tokens: np.ndarray, quota, k, n_seeds,
                  expand_width):
        """Dispatch the synchronous batch to the index kind's coroutine.

        Plain function (not a generator) so the dispatch runs eagerly;
        ``n_seeds`` / ``expand_width`` are vamana stage-1/2 knobs — the
        cover-tree descent seeds from the root cover and sizes its own
        frontier per level, so they are accepted and ignored there."""
        if self.index_kind == "covertree":
            return self._wave_gen_ct(query_tokens, quota, k)
        return self._wave_gen_vamana(query_tokens, quota, k, n_seeds,
                                     expand_width)

    def _wave_gen_vamana(self, query_tokens: np.ndarray, quota, k, n_seeds,
                         expand_width):
        """The two-stage search for one synchronous batch, as a coroutine.

        Yields tower-lane work items — ``("embed_queries", tokens)`` then one
        ``("drain", ids)`` per stage-2 wave — and receives the answer via
        ``send`` (the expensive query embeddings / the drained batch count).
        Device-lane work (cheap embed, stage 1, plan/commit bookkeeping)
        runs between yields. Returns ``(ids, dists, stats)`` via
        ``StopIteration.value``. The async slot drive runs the identical
        per-row math against its resident state (same jitted programs, same
        per-row operands), which is what keeps the two drives bit-exact.
        """
        b = query_tokens.shape[0]
        quota_np = np.broadcast_to(
            np.asarray(quota, np.int32), (b,)).copy()
        n_seeds_np = (np.maximum(1, quota_np // 2) if n_seeds is None
                      else np.broadcast_to(
                          np.asarray(n_seeds, np.int32), (b,)).copy())
        k_np = np.broadcast_to(np.asarray(k, np.int32), (b,))
        ew_np = np.maximum(1, np.broadcast_to(
            np.asarray(expand_width, np.int32), (b,)))
        ew_cap = int(ew_np.max())

        q_d = jnp.asarray(self.cheap.embed(query_tokens))
        q_D = yield ("embed_queries", query_tokens)

        # stage 1 — one batched cheap-metric search on device; per-query
        # width/steps so a request's answer never depends on its wave-mates.
        # quota-0 rows (admission padding, or an explicit quota=0 request)
        # can never spend a D call, so they run a width-1, zero-step stage 1
        # — the padded partial-wave flush costs one lane, not a full search
        width1 = np.where(quota_np > 0, np.maximum(32, n_seeds_np), 1
                          ).astype(np.int32)
        pool1 = int(max(width1.max(), n_seeds_np.max()))
        res1 = self._stage1(
            q_d, width=jnp.asarray(width1), pool=pool1,
            max_steps=jnp.asarray(4 * width1 * (quota_np > 0)))
        lane = np.arange(res1.pool_ids.shape[1], dtype=np.int32)
        seeds = jnp.where(
            jnp.asarray(lane[None, :] < n_seeds_np[:, None]),
            res1.pool_ids, -1)[:, :int(n_seeds_np.max())]
        d_calls = np.asarray(res1.n_calls)

        # stage 2 — the core hot loop, host-driven: plan on device, drain the
        # tower for the wave's union of fresh docs, commit scores on device.
        L = np.maximum(
            k_np, np.minimum(quota_np, 2 * np.maximum(n_seeds_np, 1) + 8))
        P = int(max(L.max(), k_np.max()))
        max_steps = 4 * quota_np
        quota_j = jnp.asarray(quota_np)
        L_j = jnp.asarray(L)
        ms_j = jnp.asarray(max_steps)
        ew_j = jnp.asarray(ew_np)
        tower_batches = 0

        # dedup backend for the wave (host-driven drive: the non-donated
        # bitmap would be copied through every dispatch, so auto favors the
        # quota-proportional sorted set). Capacity is a static shape — the
        # pow2 rounding keeps retraces bounded, and quota-0 padding rows
        # never raise the wave's max.
        dedup, cap = beam.resolve_dedup(
            self.dedup, _round_capacity(int(quota_np.max())), quota_np,
            self.n, drive="host")

        stepper = self._stepper
        if stepper is not None:
            state, safe, keep = stepper.init(
                seeds, quota_j, pool_size=P, dedup=dedup, set_capacity=cap)
        else:
            state, safe, keep = _init_j(
                seeds, quota_j, n_points=self.n, pool_size=P, dedup=dedup,
                set_capacity=cap)
        while True:
            safe_np = np.asarray(safe)
            tower_batches += yield ("drain", safe_np[np.asarray(keep)])
            doc_embs = jnp.asarray(self._doc_embs(safe_np, q_D.shape[1]))
            dists = _wave_dists_j(doc_embs, q_D)
            if stepper is not None:
                state = stepper.commit(state, safe, keep, dists)
                if not stepper.active_any(state, quota_j, L_j, ms_j):
                    break
                state, safe, keep, _ = stepper.plan(
                    state, self._adjacency, quota_j, L_j, ms_j,
                    expand_width=ew_j, expand_cap=ew_cap)
            else:
                state = _commit_j(state, safe, keep, dists,
                                  backend=self.backend)
                if not bool(_active_any_j(state, quota_j, L_j, ms_j)):
                    break
                state, safe, keep, _ = _plan_step_j(
                    state, self._adjacency, quota_j, L_j, ms_j, ew_j,
                    expand_cap=ew_cap)

        kmax = int(k_np.max())
        ids = np.asarray(state.pool_ids[:, :kmax], np.int64)
        dd = np.asarray(state.pool_dists[:, :kmax], np.float64)
        D_calls = np.asarray(state.n_calls)
        stats = [ServeStats(d_calls=int(d_calls[i]), D_calls=int(D_calls[i]),
                            tower_batches=tower_batches) for i in range(b)]
        return ids, dd, stats

    def _wave_gen_ct(self, query_tokens: np.ndarray, quota, k):
        """Algorithm 3 for one synchronous batch, as a coroutine.

        Same tower-lane protocol as the vamana coroutine — one
        ``("embed_queries", tokens)`` then one ``("drain", ids)`` per
        level-chunk wave — but the device side is the cover-tree descent of
        :func:`repro.core.covertree.search_batched` (host-chunk drive): per
        level, size each row's frontier, re-open it, plan all chunk waves
        before committing any, then drain/score/commit each wave. Per-row
        math is independent of batch-mates and of the pool capacity, which
        is what keeps this bit-exact vs the async slot drive."""
        b = query_tokens.shape[0]
        quota_np = np.broadcast_to(np.asarray(quota, np.int32), (b,)).copy()
        k_np = np.broadcast_to(np.asarray(k, np.int32), (b,))
        q_D = yield ("embed_queries", query_tokens)

        flat = self._flat
        l1 = flat.depth - 1
        chunk = self._ct_chunk
        radii = self._ct_radii
        e0 = int(flat.root_ids.shape[0])
        # identical static shapes to the slot pool's p_need so the two
        # drives share jitted programs (capacity is invisible to a row)
        P = max(_round_capacity(int(max(
            int(k_np.max()), e0, min(self.n, int(quota_np.max()))))), chunk)
        dedup, cap = beam.resolve_dedup(
            self.dedup, _round_capacity(int(quota_np.max())), quota_np,
            self.n, drive="host")
        quota_j = jnp.asarray(quota_np)
        L_j = jnp.full((b,), beam.NO_QUOTA, jnp.int32)
        ms_j = jnp.full((b,), beam.NO_QUOTA, jnp.int32)
        entries = jnp.broadcast_to(
            jnp.asarray(flat.root_ids, jnp.int32)[None, :], (b, e0))
        stepper = self._stepper
        if stepper is not None:
            state, safe, keep = stepper.init(
                entries, quota_j, pool_size=P, dedup=dedup, set_capacity=cap)
        else:
            state, safe, keep = _init_j(
                entries, quota_j, n_points=self.n, pool_size=P,
                dedup=dedup, set_capacity=cap)
        tower_batches = 0

        def _commit(s, sf, kp):
            nonlocal tower_batches
            safe_np = np.asarray(sf)
            batches = yield ("drain", safe_np[np.asarray(kp)])
            tower_batches += batches
            doc = jnp.asarray(self._doc_embs(safe_np, q_D.shape[1]))
            dists = _wave_dists_j(doc, q_D)
            if stepper is not None:
                return stepper.commit(s, sf, kp, dists)
            return _commit_j(s, sf, kp, dists, backend=self.backend)

        state = yield from _commit(state, safe, keep)
        alive = np.ones(b, bool)
        for t in range(l1):
            radius = np.inf if t == 0 else float(radii[t - 1])
            ew_t = np.asarray(_frontier_j(state.pool_dists,
                                          jnp.float32(radius)))
            ew_t = np.where(alive, ew_t, 0).astype(np.int32)
            if not ew_t.any():
                break
            if stepper is not None:
                state = stepper.reopen(state, jnp.asarray(alive))
            else:
                state = _reopen_j(state, jnp.asarray(alive))
            lev = jnp.full((b,), t, jnp.int32)
            planned = []
            remaining = ew_t.copy()
            while remaining.max() > 0:
                ew = np.minimum(remaining, chunk).astype(np.int32)
                if stepper is not None:
                    state, safe, keep, _ = stepper.plan(
                        state, self._ct_children, quota_j, L_j, ms_j,
                        expand_width=jnp.asarray(ew), expand_cap=chunk,
                        level=lev, wave_dedup=False)
                else:
                    state, safe, keep, _ = _plan_ct_j(
                        state, self._ct_children, lev, quota_j, L_j, ms_j,
                        jnp.asarray(ew), expand_cap=chunk)
                planned.append((safe, keep))
                remaining -= ew
            for safe, keep in planned:
                state = yield from _commit(state, safe, keep)
            dmin = np.asarray(state.pool_dists[:, 0], np.float64)
            alive &= dmin < radii[t] * (1.0 + 1.0 / self.ct_eps)

        kmax = int(k_np.max())
        ids = np.asarray(state.pool_ids[:, :kmax], np.int64)
        dd = np.asarray(state.pool_dists[:, :kmax], np.float64)
        D_calls = np.asarray(state.n_calls)
        stats = [ServeStats(d_calls=0, D_calls=int(D_calls[i]),
                            tower_batches=tower_batches) for i in range(b)]
        return ids, dd, stats

    def _service_tower(self, item):
        """Run one tower-lane work item (the expensive-tower forward passes)."""
        kind, payload = item
        if self._faults is not None:
            # injection precedes the real work (and the doc-cache write), so
            # a retried drain recomputes from the same cache state — retries
            # stay bit-exact vs a fault-free run
            self._faults.fire(kind)
        if kind == "embed_queries":
            # query-side embeddings are not charged to the quota: the budget
            # counts *document* scorings (the paper's cost model)
            return jnp.asarray(self.expensive.embed(payload))
        return self._drain_tower(payload)  # "drain"

    def _drive_sync(self, gen):
        """Run a wave coroutine to completion, servicing tower work inline."""
        try:
            item = next(gen)
            while True:
                item = gen.send(self._service_tower(item))
        except StopIteration as stop:
            return stop.value

    # ---------------------------------------------------------------- query
    @staticmethod
    def _is_request_batch(obj) -> bool:
        return (isinstance(obj, (list, tuple)) and len(obj) > 0
                and all(isinstance(r, SearchRequest) for r in obj))

    def query_batch(self, requests=None, *, quota=None,
                    k: int = 10, n_seeds=None, expand_width=1):
        """Two-stage bi-metric search for a batch of requests, inline.

        Native form: a list of :class:`SearchRequest` -> a list of
        :class:`SearchResult` (per-request k, trimmed rows). Legacy form
        (deprecated, warns once): a (B, S) token array with ``quota`` /
        ``k`` / ``n_seeds`` / ``expand_width`` scalars-or-(B,) vectors ->
        the historical ``(ids (B, k), D-dists (B, k), [ServeStats])`` tuple
        with id -1 / dist +inf padding. Both run the identical wave; mixed
        budgets get exact per-query accounting either way.
        """
        if self._is_request_batch(requests):
            reqs = list(requests)
            tokens = np.stack([np.asarray(r.tokens) for r in reqs])
            quota_v = np.array([int(r.quota) for r in reqs], np.int32)
            k_v = np.array([int(r.k) for r in reqs], np.int32)
            nseed_v = np.array(
                [max(1, int(r.quota) // 2) if r.n_seeds is None
                 else max(1, int(r.n_seeds)) for r in reqs], np.int32)
            ew_v = np.array(
                [max(1, int(r.expand_width)) for r in reqs], np.int32)
            ids, dd, stats = self._drive_sync(
                self._wave_gen(tokens, quota_v, k_v, nseed_v, ew_v))
            out = []
            for i, r in enumerate(reqs):
                row_ids, row_dd = ids[i, :r.k], dd[i, :r.k]
                ok = (row_ids >= 0) & np.isfinite(row_dd)
                out.append(SearchResult(row_ids[ok], row_dd[ok], stats[i]))
            return out
        if isinstance(requests, SearchRequest):
            raise TypeError(
                "query_batch takes a list of SearchRequest; use "
                "query(request) for a single one")
        if quota is None:
            raise TypeError("legacy query_batch(tokens, ...) needs quota=")
        _warn_legacy("query_batch", "query_batch(tokens, quota=...)")
        return self._drive_sync(self._wave_gen(
            np.asarray(requests), quota, k, n_seeds, expand_width))

    def query(self, request=None, *, quota: int | None = None, k: int = 10,
              n_seeds: int | None = None) -> SearchResult:
        """One request, inline. Native form: ``query(SearchRequest)``.
        Legacy form (deprecated, warns once): ``query(tokens, quota=...)``.
        Returns a :class:`SearchResult` (tuple-unpacks as (ids, dists,
        stats), so legacy callers keep working)."""
        if isinstance(request, SearchRequest):
            return self.query_batch([request])[0]
        if quota is None:
            raise TypeError("legacy query(tokens, ...) needs quota=")
        _warn_legacy("query", "query(tokens, quota=...)")
        ids, dd, stats = self._drive_sync(self._wave_gen(
            np.asarray(request)[None], int(quota), int(k), n_seeds, 1))
        ok = (ids[0] >= 0) & np.isfinite(dd[0])
        return SearchResult(ids[0][ok], dd[0][ok], stats[0])

    # ------------------------------------------------------- async slot pool
    def submit(self, request=None, *, quota: int | None = None,
               k: int = 10, n_seeds: int | None = None,
               expand_width: int = 1, deadline_ms: float | None = None,
               priority: int = 0) -> ServeFuture:
        """Queue one request for the slot pool; returns a
        :class:`ServeFuture` resolving to a :class:`SearchResult`. Native
        form: ``submit(SearchRequest)``. Legacy form (deprecated, warns
        once): ``submit(tokens, quota=...)``. Starts the drive threads on
        first use; raises ``RuntimeError`` after :meth:`close`."""
        if not isinstance(request, SearchRequest):
            if quota is None:
                raise TypeError("legacy submit(tokens, ...) needs quota=")
            _warn_legacy("submit", "submit(tokens, quota=...)")
            request = SearchRequest(
                tokens=np.asarray(request), quota=int(quota), k=int(k),
                n_seeds=n_seeds, expand_width=expand_width,
                deadline_ms=deadline_ms, priority=priority)
        fut = ServeFuture()
        now = time.monotonic()
        pend = _Pending(req=request, future=fut, t_submit=now)
        deadline = (math.inf if request.deadline_ms is None
                    else now + request.deadline_ms / 1e3)
        # enqueue under the lifecycle lock: close() flips _closed under the
        # same lock before it cancels the queue, so a request can never land
        # behind the cancellation sweep unresolved
        with self._lifecycle_lock:
            self._ensure_started_locked()
            with self._q_cond:
                self._seq += 1
                heapq.heappush(
                    self._queue,
                    (-int(request.priority), deadline, self._seq, pend))
                self._counters.submitted += 1
                self._counters.queue_depth = len(self._queue)
                self._q_cond.notify_all()
        return fut

    def counters(self) -> EngineCounters:
        """Snapshot of the admission-layer counters (cumulative since
        engine construction; ``queue_depth`` / ``slot_occupancy`` are
        instantaneous)."""
        with self._mu:
            snap = dataclasses.replace(self._counters)
        snap.breaker_opens = self._breaker.opens
        return snap

    def health(self) -> dict:
        """Operational snapshot: breaker state, degradation mode, queue and
        slot pressure, and the cumulative counters (as a dict). Safe to
        call from any thread; values are point-in-time reads (the breaker
        is single-writer — the drive thread — so the reads are coherent
        enough for monitoring)."""
        snap = self.counters()
        state = self._breaker.state
        return {
            "breaker_state": state,
            "consecutive_tower_failures": self._breaker.failures,
            "breaker_opens": self._breaker.opens,
            "degraded_mode": (state != "closed"
                              and self.on_tower_failure == "degrade"),
            "on_tower_failure": self.on_tower_failure,
            "queue_depth": snap.queue_depth,
            "slot_occupancy": snap.slot_occupancy,
            "started": self._started,
            "closed": self._closed,
            "counters": dataclasses.asdict(snap),
        }

    def close(self, timeout: float | None = 60.0) -> None:
        """Stop the slot pool. Requests already admitted to a slot (or
        staged for admission) still resolve; requests **still queued** are
        cancelled immediately — their ``result()`` raises
        ``CancelledError`` — instead of being flushed into a final drain
        that could outlive the timeout. Raises ``RuntimeError`` if the
        drive/tower threads fail to join within ``timeout`` (they are
        daemons, so the process still exits, but silent success would hide
        unresolved resident requests). Idempotent; ``submit`` raises
        afterwards."""
        with self._lifecycle_lock:
            already = self._closed
            self._closed = True
            started = self._started
            dropped: list[_Pending] = []
            if not already and started:
                with self._q_cond:
                    while self._queue:
                        dropped.append(heapq.heappop(self._queue)[-1])
                    self._counters.queue_depth = 0
                    self._counters.cancelled += len(dropped)
                    self._q_cond.notify_all()
        if already or not started:
            return
        for pend in dropped:  # outside the locks: cancel runs callbacks
            pend.future.cancel()
        for t in self._threads:
            t.join(timeout)
        stuck = [t.name for t in self._threads if t.is_alive()]
        if stuck:
            raise RuntimeError(
                f"engine threads failed to join within timeout={timeout}: "
                f"{stuck} (daemon threads — they die with the process, but "
                "resident requests may be unresolved)")

    def _ensure_started_locked(self) -> None:
        """Start the drive + tower threads on first use; caller holds
        ``_lifecycle_lock``."""
        if self._closed:
            raise RuntimeError("engine slot pool is closed")
        if self._started:
            return
        self._tower_q = queue.Queue()
        self._pool = _SlotPool(self)
        self._threads = [
            threading.Thread(target=loop, daemon=True, name=name)
            for name, loop in (("serve-drive", self._drive_loop),
                               ("serve-tower", self._tower_loop))]
        self._tower_thread = self._threads[1]
        for t in self._threads:
            t.start()
        self._started = True

    # ----------------------------------------------------- admission helpers
    def _queue_depth(self) -> int:
        with self._mu:
            return len(self._queue)

    def _pop_group(self, n: int) -> list[_Pending]:
        """Pop up to ``n`` requests in (priority, deadline, FIFO) order.
        Entries whose deadline already expired are failed here (never
        admitted) — the pop is an admission point."""
        now = time.monotonic()
        group: list[_Pending] = []
        expired: list[_Pending] = []
        with self._q_cond:
            while self._queue and len(group) < n:
                _, deadline, _, pend = heapq.heappop(self._queue)
                if deadline < now:
                    expired.append(pend)
                else:
                    group.append(pend)
            self._counters.queue_depth = len(self._queue)
            self._counters.deadline_misses += len(expired)
        for pend in expired:  # outside the lock: _fail runs callbacks
            pend.future._fail(DeadlineExceeded(
                f"deadline_ms={pend.req.deadline_ms} expired while queued"))
        return group

    def _expire_queued(self) -> None:
        """Fail every queued request whose deadline has passed (checked on
        every drive-loop iteration, so expiry does not wait for a free
        slot)."""
        now = time.monotonic()
        expired: list[_Pending] = []
        with self._q_cond:
            if not self._queue:
                return
            alive = [e for e in self._queue if e[1] >= now]
            if len(alive) == len(self._queue):
                return
            expired = [e[-1] for e in self._queue if e[1] < now]
            heapq.heapify(alive)
            self._queue = alive
            self._counters.queue_depth = len(alive)
            self._counters.deadline_misses += len(expired)
        for pend in expired:
            pend.future._fail(DeadlineExceeded(
                f"deadline_ms={pend.req.deadline_ms} expired while queued"))

    def _tower_submit(self, item) -> concurrent.futures.Future:
        fut: concurrent.futures.Future = concurrent.futures.Future()
        if self._tower_thread is not None and not self._tower_thread.is_alive():
            # lane thread died (e.g. an injected KeyboardInterrupt escaped):
            # fail fast instead of waiting forever on a queue nobody reads
            fut.set_exception(TowerFailure(
                "expensive-tower lane thread is dead"))
            return fut
        self._tower_q.put((item, fut))
        return fut

    def _await_tower(self, fut: concurrent.futures.Future, pool):
        """Wait for one tower-lane future. Fault-free deadline-less serving
        keeps the cheap fully-blocking wait; with resident deadlines or a
        ``drain_timeout_ms`` the wait polls every 20 ms so mid-flight
        expiries resolve *during* the tower call (``defer_free=True`` — the
        wave in flight still commits before the rows are recycled) and a
        hung call becomes :class:`TowerTimeout` after the timeout."""
        if self.drain_timeout_s is None and (
                pool is None or not pool.has_deadlines()):
            return fut.result()
        t0 = time.monotonic()
        while True:
            try:
                return fut.result(timeout=0.02)
            except concurrent.futures.TimeoutError:
                if pool is not None:
                    pool.expire_inflight(defer_free=True)
                if (self.drain_timeout_s is not None
                        and time.monotonic() - t0 > self.drain_timeout_s):
                    raise TowerTimeout(
                        f"tower call exceeded drain_timeout_ms="
                        f"{self.drain_timeout_s * 1e3:g}") from None

    def _tower_result(self, fut: concurrent.futures.Future, item,
                      pool=None):
        """Await a tower-lane call with bounded exponential-backoff retries
        and breaker accounting. Retries cover transient failures only (an
        exception whose ``transient`` attribute is falsy, or a
        :class:`TowerTimeout`, goes straight to the caller); each failure
        counts toward the breaker, each success closes it. The terminal
        exception propagates to the caller — the isolation boundary
        (:meth:`_SlotPool.tower_down` / admission policy) decides who it
        fails."""
        attempts = 0
        while True:
            try:
                out = self._await_tower(fut, pool)
            except (KeyboardInterrupt, SystemExit):
                raise
            except BaseException as exc:
                attempts += 1
                self._breaker.on_failure()
                with self._mu:
                    self._counters.tower_failures += 1
                retryable = (getattr(exc, "transient", True)
                             and not isinstance(exc, TowerTimeout))
                if (not retryable or attempts > self.tower_retries
                        or self._breaker.blocked()):
                    raise
                with self._mu:
                    self._counters.retries += 1
                time.sleep(self.retry_backoff_s * (2 ** (attempts - 1)))
                fut = self._tower_submit(item)
                continue
            self._breaker.on_success()
            return out

    # ----------------------------------------------------------- drive loops
    def _drive_loop(self) -> None:
        pool = self._pool
        try:
            while True:
                try:
                    self._expire_queued()
                    pool.expire_inflight()
                    if pool.prepared is not None:
                        prep, pool.prepared = pool.prepared, None
                        pool.admit(prep)
                        pool.resolve_finished()
                        continue
                    free = int((~pool.occupied).sum())
                    if free:
                        group = self._pop_group(free)
                        if group:
                            pool.prepared = pool.prepare(group)
                            continue
                    if pool.occupied.any():
                        pool.step()
                        pool.resolve_finished()
                        continue
                except (KeyboardInterrupt, SystemExit) as exc:
                    # fail the resident futures, then honor the interrupt —
                    # never swallow it into a served error
                    pool.fail_all(exc)
                    raise
                except BaseException as exc:
                    # last resort: tower/admission failures are isolated
                    # upstream (tower_down / prepare); anything landing here
                    # poisoned the resident state itself
                    pool.fail_all(exc)
                    continue
                # idle: no occupied slots, nothing admittable right now
                with self._q_cond:
                    if self._queue:
                        continue
                    if self._closed:
                        break
                    self._q_cond.wait(max(self.max_wait, 0.05))
        finally:
            self._tower_q.put(_STOP)

    def _tower_loop(self) -> None:
        while True:
            got = self._tower_q.get()
            if got is _STOP:
                break
            item, fut = got
            try:
                fut.set_result(self._service_tower(item))
            except (KeyboardInterrupt, SystemExit) as exc:
                fut.set_exception(exc)  # surface on drive, then honor it
                raise
            except BaseException as exc:  # surfaced on the drive thread
                fut.set_exception(exc)

    # --------------------------------------------------------------- rerank
    def _embed_queries(self, query_tokens: np.ndarray):
        """(B, S) tokens -> cheap (B, dim_d) on device, expensive (B, dim_D).

        Query-side embeddings are not charged to the quota: the budget counts
        *document* scorings (the paper's cost model)."""
        q_d = jnp.asarray(self.cheap.embed(query_tokens))
        q_D = jnp.asarray(self.expensive.embed(query_tokens))
        return q_d, q_D

    def rerank_query_batch(self, query_tokens: np.ndarray, *, quota: int,
                           k: int = 10,
                           ) -> tuple[np.ndarray, np.ndarray, list[ServeStats]]:
        """"Bi-metric (baseline)": top-quota by d, embed all with D, rerank."""
        if self.index_kind == "covertree":
            raise ValueError(
                "the rerank baseline needs the vamana proxy graph; "
                "build the engine with index='vamana'")
        b = query_tokens.shape[0]
        q_d, q_D = self._embed_queries(query_tokens)
        width = max(32, quota)
        res1 = self._stage1(q_d, width=width, pool=max(width, quota),
                            max_steps=8 * width)
        cand = np.asarray(res1.pool_ids[:, :quota])
        tower_batches = self._drain_tower(cand)
        doc_embs = self._emb_D[np.maximum(cand, 0)]  # host-side, no transfer
        diff = doc_embs - np.asarray(q_D)[:, None, :]
        dd = np.sqrt((diff * diff).sum(-1))
        dd = np.where(cand >= 0, dd, np.inf)
        order = np.argsort(dd, axis=1, kind="stable")[:, :k]
        d_calls = np.asarray(res1.n_calls)
        n_D = (cand >= 0).sum(1)
        stats = [ServeStats(d_calls=int(d_calls[i]), D_calls=int(n_D[i]),
                            tower_batches=tower_batches) for i in range(b)]
        return (np.take_along_axis(cand, order, 1).astype(np.int64),
                np.take_along_axis(dd, order, 1), stats)

    def rerank_query(self, query_tokens: np.ndarray, *, quota: int,
                     k: int = 10) -> tuple[np.ndarray, np.ndarray, ServeStats]:
        """One query (S,) tokens through the rerank baseline."""
        ids, dd, stats = self.rerank_query_batch(query_tokens[None],
                                                 quota=quota, k=k)
        ok = (ids[0] >= 0) & np.isfinite(dd[0])
        return ids[0][ok], dd[0][ok], stats[0]
