"""Bi-metric serving engine: the paper's deployment story, end to end.

* the **cheap tower** (e.g. qwen3-0.6b / bge-micro-like) runs locally and
  embeds the corpus once at index-build time — the graph index is built on
  those embeddings only (Theorem 1.1 property 1);
* the **expensive tower** (e.g. deepseek-v3 / SFR-Mistral-like) is the
  ground-truth metric D: scoring a document costs a forward pass. The engine
  enforces the call budget *exactly* — the quota is literally a compute
  budget on the big model;
* queries run the two-stage search **as a batch**. Stage 1 is one
  batched-engine run under d on device. Stage 2 drives the *same* core hot
  loop (``repro.core.beam.plan_step`` / ``commit_scores``) from the host:
  each wave is planned on device for every query at once, the union of
  documents the wave needs is drained through the expensive tower in batched
  forward passes, and the scores are committed back on device. Per-query
  accounting is identical to running each query alone (a document counts
  against a query's quota the first time that query scores it), while the
  tower only ever embeds a document once per engine lifetime — the
  cross-query cache is pure compute savings.

Two ways to drive it:

* **synchronous** — :meth:`BiMetricEngine.query_batch` /
  :meth:`BiMetricEngine.query` run one request batch to completion inline;
* **asynchronous** — :meth:`BiMetricEngine.submit` hands a single request to
  the engine's admission queue and returns a :class:`ServeFuture`. An
  admission thread pads/pools pending requests into fixed-shape *waves*
  (up to ``max_batch`` requests, flushed after ``max_wait_ms``), and the
  waves are pipelined through two lanes — a *device lane* (cheap-tower
  embed, stage-1 search, stage-2 plan/commit bookkeeping) and a *tower
  lane* (expensive-tower forward passes) — with ``max_inflight`` waves (the
  double buffer) in flight at once, so the expensive-tower drain of wave
  *i* overlaps the device plan/commit of wave *i+1*. Both drives run the
  **identical** per-wave coroutine, and every per-query knob (quota, seeds,
  beam width, step cap) is a per-query vector in the core engine — so async
  results are bit-exact vs the synchronous path, and a request's answer
  never depends on its wave-mates or on padding.

``EmbedTower`` wraps (params, config, pooling); swap in any LM arch config.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import queue
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels
from repro.core import beam, distances, vamana
from repro.distributed import sharding
from repro.models import transformer as T

Array = jax.Array


@dataclasses.dataclass
class EmbedTower:
    params: dict
    cfg: T.TransformerConfig

    def __post_init__(self):
        self._embed = jax.jit(
            lambda p, toks: T.embed_pool(p, toks, self.cfg))

    def embed(self, tokens: np.ndarray, batch: int = 64) -> np.ndarray:
        out = []
        n = tokens.shape[0]
        pad = (-n) % batch
        toks = np.pad(tokens, ((0, pad), (0, 0))) if pad else tokens
        for s in range(0, len(toks), batch):
            out.append(np.asarray(self._embed(self.params, toks[s:s + batch])))
        return np.concatenate(out)[:n]


@dataclasses.dataclass
class ServeStats:
    d_calls: int = 0
    D_calls: int = 0  # expensive-tower document scorings (the budget)
    # forward-pass batches drained for the WHOLE request batch (replicated
    # on every query's stats for convenience — do not sum across a batch)
    tower_batches: int = 0
    # async path only: submit() -> future-resolution wall clock for THIS
    # request (admission wait + wave compute). 0.0 on the synchronous
    # drives, which have no queueing to measure.
    latency_ms: float = 0.0


class ServeFuture(concurrent.futures.Future):
    """Result handle for one :meth:`BiMetricEngine.submit` request.

    A stdlib :class:`concurrent.futures.Future`; ``result(timeout)`` blocks
    for (ids, D-dists, stats) — the :meth:`query` return shape. The engine
    resolves exactly once; a user-side ``cancel()`` race is swallowed (the
    wave still computes — admission has no preemption)."""

    def _resolve(self, value) -> None:
        try:
            self.set_result(value)
        except concurrent.futures.InvalidStateError:
            pass  # cancelled by the caller; the computed wave is discarded

    def _fail(self, exc: BaseException) -> None:
        try:
            self.set_exception(exc)
        except concurrent.futures.InvalidStateError:
            pass


@dataclasses.dataclass
class _Request:
    tokens: np.ndarray
    quota: int
    k: int
    future: ServeFuture
    t_submit: float = 0.0  # monotonic stamp for the per-request latency


@dataclasses.dataclass
class _Wave:
    """One padded fixed-shape request wave ping-ponging between the lanes."""

    requests: list
    gen: object  # the running _wave_gen coroutine
    started: bool = False
    pending: object = None  # tower lane's answer, sent into the coroutine
    pending_item: object = None  # tower-lane work item yielded by the gen
    tower_exc: BaseException | None = None


_STOP = object()  # lane-queue sentinel


# ---------------------------------------------------------------------------
# jitted device-lane steps (shards == 1). beam_width / max_steps / quota ride
# as (B,) operands so mixed per-query budgets in one wave do not retrace.
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=(
    "n_points", "pool_size", "dedup", "set_capacity"))
def _init_j(entry_ids, quota, *, n_points, pool_size, dedup, set_capacity):
    return beam.init_state(
        entry_ids, n_points=n_points, pool_size=pool_size, quota=quota,
        dedup=dedup, set_capacity=set_capacity)


def _round_capacity(quota_max: int) -> int:
    """Static sorted-set capacity for a wave: max quota rounded up to the
    next power of two, so heterogeneous request quotas fall into log-many
    capacity buckets (bounded retraces) instead of one trace per distinct
    quota. An all-quota-0 wave (admission padding only) gets a genuine
    zero-capacity set — same program shape family, no bitmap fallback."""
    return 0 if quota_max <= 0 else 1 << (int(quota_max) - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("expand_width",))
def _plan_step_j(state, adjacency, quota, beam_width, max_steps, *,
                 expand_width):
    return beam.plan_step(
        state, adjacency, beam_width=beam_width, quota=quota,
        max_steps=max_steps, expand_width=expand_width)


@jax.jit
def _wave_dists_j(doc_embs, q_D):
    """L2 under D from gathered doc embeddings (masked lanes fixed later)."""
    diff = doc_embs.astype(jnp.float32) - q_D[:, None, :].astype(jnp.float32)
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1))


# Backend is a frozen (hashable) dataclass — a jit static, so each merge
# route compiles its own program instead of tracing the knob.
_commit_j = functools.partial(
    jax.jit, static_argnames=("backend",))(beam.commit_scores)


@jax.jit
def _active_any_j(state, quota, beam_width, max_steps):
    return beam.active_mask(
        state, beam_width=beam_width, quota=quota, max_steps=max_steps).any()


class BiMetricEngine:
    """corpus_tokens: (N, S) int32 document tokens.

    ``shards > 1`` runs the device side of **both** stages device-parallel
    over a corpus mesh. Stage 1 is :func:`repro.core.beam.sharded_greedy_search`
    (corpus split across ``shards`` devices, pools replicated). Stage 2
    keeps its host drive loop — the metric is the expensive tower itself —
    but all its bookkeeping (plan, dedup lookup/insert, commit) runs inside
    the mesh via :class:`repro.core.beam.ShardedStepper`. Results are
    bit-exact vs ``shards=1``.

    ``dedup`` selects stage 2's dedup-state backend: ``"sorted"`` carries a
    quota-proportional (B, quota) sorted membership set through the wave
    (capacity = the wave's max quota rounded up to a power of two, so mixed
    budgets retrace at most log-many times; admission's quota-0 padding
    rows ride along with zero insertions and an all-padding wave gets a
    zero-capacity set), ``"bitmap"`` the dense (B, N) bitmap, and
    ``"auto"`` (default) picks sorted whenever the wave's quota bound is
    below N. Under ``shards > 1`` the sorted set is replicated like the
    pools — per-device dedup state shrinks from (B, N/shards) to
    (B, quota) and the bitmap-lookup collective leaves the wave. Both
    backends are bit-exact to each other. Stage 1 (quota-unbounded proxy
    search) always keeps the bitmap, per the same auto rule.

    ``backend`` selects the device-side kernel route for stage-1 wave
    scoring and the pool merges (``repro.kernels.resolve_backend`` values):
    ``"ref"`` (default) keeps the frozen-oracle numerics every parity
    guarantee is stated against; ``"auto"`` is the deployment knob — MXU/
    BLAS-form scoring over a **corpus-norm cache built once per engine
    lifetime** (alongside the index; the index is corpus-immutable, so the
    cache can never go stale) on CPU, the Pallas kernels on TPU. Stage 2's
    distances come from the expensive tower, so its backend choice only
    routes the commit merges.

    ``quantize`` (``"int8"`` / ``"fp8"`` / ``"fp8_e5m2"``) holds the
    stage-1 corpus in quantized residency: the quantized view is built
    **once per engine lifetime**, exactly like the norm cache, and every
    stage-1 wave scores the int8/fp8 codes with dequant-in-the-kernel.
    This is the paper's lossy-proxy lever — quantization error folds into
    stage 1's C-approximation factor while stage 2 (the expensive tower)
    stays exact, so recall@k degrades only through seed quality. Stage 2
    is never quantized.

    ``max_batch`` / ``max_wait_ms`` / ``max_inflight`` configure the async
    admission pipeline (see :meth:`submit`); they are inert for the
    synchronous ``query*`` paths. Async requests additionally report their
    submit→resolve wall clock in ``ServeStats.latency_ms`` (the quantity
    the serving bench gates at p50).
    """

    def __init__(self, cheap: EmbedTower, expensive: EmbedTower,
                 corpus_tokens: np.ndarray,
                 index_cfg: vamana.VamanaConfig | None = None,
                 tower_batch: int = 64, shards: int = 1,
                 max_batch: int = 8, max_wait_ms: float = 5.0,
                 max_inflight: int = 2, dedup: str = "auto",
                 backend="ref", quantize: str | None = None):
        self.cheap = cheap
        self.expensive = expensive
        self.corpus_tokens = corpus_tokens
        self.n = corpus_tokens.shape[0]
        self.tower_batch = tower_batch
        self.shards = shards
        if dedup not in ("auto", "sorted", "bitmap"):
            raise ValueError(f"unknown dedup backend {dedup!r}")
        self.dedup = dedup
        # kernel backend for the device side (stage-1 wave scoring + pool
        # merges). "ref" keeps the frozen-oracle numerics; "auto" is the
        # deployment knob (matmul form over the engine-lifetime corpus-norm
        # cache on CPU, the Pallas kernels on TPU).
        self.backend = kernels.resolve_backend(
            backend, quantize=quantize, _caller="serve.BiMetricEngine")
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.max_inflight = max(1, max_inflight)
        # --- index build: cheap metric ONLY --------------------------------
        self.emb_d = jnp.asarray(cheap.embed(corpus_tokens))
        self.index = vamana.build(self.emb_d,
                                  index_cfg or vamana.VamanaConfig(
                                      max_degree=16, l_build=24, pool_size=48,
                                      rev_candidates=16))
        self._em_d = distances.EmbeddingMetric(self.emb_d)
        # stage-1 scoring route: the matmul backends thread the corpus-norm
        # cache (built ONCE here, like the index) through every wave; with
        # quantize= the view is built quantized, also once — the graph is
        # still built on the exact embeddings, only wave scoring is lossy
        need_view = self.backend.matmul or self.backend.quantize is not None
        self._view_d = (kernels.as_corpus_view(
            self.emb_d, quantize=self.backend.quantize)
            if need_view else None)
        if need_view and shards == 1:
            self._dist_d = beam.fused_dist_fn(
                self._view_d, self._em_d.metric, backend=self.backend)
        else:
            self._dist_d = self._em_d.dists_batch
        self._adjacency = self.index.adjacency.astype(jnp.int32)
        # one mesh for the engine lifetime; stage 2 steps through the same
        # mesh as stage 1 (ShardedStepper = the in-mesh plan/commit programs)
        self._mesh = (sharding.search_mesh(shards) if shards > 1 else None)
        self._stepper = (beam.ShardedStepper(
            shards=shards, n_points=self.n, mesh=self._mesh,
            backend=self.backend)
            if shards > 1 else None)
        # lazy expensive-tower document embeddings (engine-lifetime cache)
        self._emb_D: np.ndarray | None = None
        self._emb_D_valid = np.zeros((self.n,), bool)
        self._cache_lock = threading.Lock()
        # async pipeline state (threads start lazily on the first submit)
        self._lifecycle_lock = threading.Lock()
        self._started = False
        self._closed = False
        self._threads: list[threading.Thread] = []
        self._admit_q: queue.Queue | None = None
        self._device_q: queue.Queue | None = None
        self._tower_q: queue.Queue | None = None
        self._inflight_slots: threading.Semaphore | None = None
        self._inflight = 0
        self._inflight_lock = threading.Lock()

    # ------------------------------------------------------------ internals
    def _stage1(self, q_d: Array, *, width, pool: int,
                max_steps) -> beam.SearchResult:
        """Batched cheap-metric greedy search from the medoid (stage 1).

        ``width`` / ``max_steps`` may be per-query (B,) vectors (request
        waves mix budgets); ``pool`` is the static pool size. With
        ``shards > 1`` the same loop runs device-parallel over the engine's
        corpus mesh — bit-exact vs the single-device path."""
        b = q_d.shape[0]
        entries = jnp.broadcast_to(
            jnp.asarray(self.index.medoid, jnp.int32).reshape(1, 1), (b, 1))
        if self.shards > 1:
            return beam.sharded_greedy_search(
                self._view_d if self._view_d is not None else self.emb_d,
                self._adjacency, q_d, entries,
                shards=self.shards, metric=self._em_d.metric,
                mesh=self._mesh, beam_width=width, pool_size=pool,
                max_steps=max_steps, backend=self.backend)
        return beam.batched_greedy_search(
            self._dist_d, self._adjacency, q_d, entries,
            n_points=self.n, beam_width=width, pool_size=pool,
            max_steps=max_steps, backend=self.backend)

    def _drain_tower(self, ids: np.ndarray) -> int:
        """Embed not-yet-cached docs through the expensive tower; returns the
        number of forward batches drained. Serialized by the cache lock (the
        tower lane is single-file by construction; the lock also covers
        synchronous callers running concurrently with the pipeline)."""
        with self._cache_lock:
            need = np.unique(
                ids[(ids >= 0) & ~self._emb_D_valid[np.maximum(ids, 0)]])
            if need.size == 0:
                return 0
            embs = self.expensive.embed(self.corpus_tokens[need],
                                        batch=self.tower_batch)
            if self._emb_D is None:
                self._emb_D = np.zeros((self.n, embs.shape[1]), embs.dtype)
            self._emb_D[need] = embs
            self._emb_D_valid[need] = True
            return -(-need.size // self.tower_batch)

    def reset_doc_cache(self) -> None:
        """Drop the expensive-tower document cache (benchmark hygiene)."""
        with self._cache_lock:
            self._emb_D = None
            self._emb_D_valid[:] = False

    def _doc_embs(self, safe_np: np.ndarray, dim: int) -> np.ndarray:
        """(B, K, dim_D) gather from the host cache; rows a wave needs are
        guaranteed drained before the wave re-enters the device lane."""
        emb = self._emb_D
        if emb is None:
            return np.zeros(safe_np.shape + (dim,), np.float32)
        return emb[np.maximum(safe_np, 0)]

    # -------------------------------------------------------- wave coroutine
    def _wave_gen(self, query_tokens: np.ndarray, quota, k, n_seeds,
                  expand_width: int):
        """The two-stage search for one wave, as a coroutine.

        Yields tower-lane work items — ``("embed_queries", tokens)`` then one
        ``("drain", ids)`` per stage-2 wave — and receives the answer via
        ``send`` (the expensive query embeddings / the drained batch count).
        Device-lane work (cheap embed, stage 1, plan/commit bookkeeping)
        runs between yields. Returns ``(ids, dists, stats)`` via
        ``StopIteration.value``. Both the synchronous ``query_batch`` and
        the async pipeline drive exactly this generator, which is what makes
        them bit-exact to each other.
        """
        b = query_tokens.shape[0]
        quota_np = np.broadcast_to(
            np.asarray(quota, np.int32), (b,)).copy()
        n_seeds_np = (np.maximum(1, quota_np // 2) if n_seeds is None
                      else np.broadcast_to(
                          np.asarray(n_seeds, np.int32), (b,)).copy())
        k_np = np.broadcast_to(np.asarray(k, np.int32), (b,))

        q_d = jnp.asarray(self.cheap.embed(query_tokens))
        q_D = yield ("embed_queries", query_tokens)

        # stage 1 — one batched cheap-metric search on device; per-query
        # width/steps so a request's answer never depends on its wave-mates.
        # quota-0 rows (admission padding, or an explicit quota=0 request)
        # can never spend a D call, so they run a width-1, zero-step stage 1
        # — the padded partial-wave flush costs one lane, not a full search
        width1 = np.where(quota_np > 0, np.maximum(32, n_seeds_np), 1
                          ).astype(np.int32)
        pool1 = int(max(width1.max(), n_seeds_np.max()))
        res1 = self._stage1(
            q_d, width=jnp.asarray(width1), pool=pool1,
            max_steps=jnp.asarray(4 * width1 * (quota_np > 0)))
        lane = np.arange(res1.pool_ids.shape[1], dtype=np.int32)
        seeds = jnp.where(
            jnp.asarray(lane[None, :] < n_seeds_np[:, None]),
            res1.pool_ids, -1)[:, :int(n_seeds_np.max())]
        d_calls = np.asarray(res1.n_calls)

        # stage 2 — the core hot loop, host-driven: plan on device, drain the
        # tower for the wave's union of fresh docs, commit scores on device.
        L = np.maximum(
            k_np, np.minimum(quota_np, 2 * np.maximum(n_seeds_np, 1) + 8))
        P = int(max(L.max(), k_np.max()))
        max_steps = 4 * quota_np
        quota_j = jnp.asarray(quota_np)
        L_j = jnp.asarray(L)
        ms_j = jnp.asarray(max_steps)
        tower_batches = 0

        # dedup backend for the wave (host-driven drive: the non-donated
        # bitmap would be copied through every dispatch, so auto favors the
        # quota-proportional sorted set). Capacity is a static shape — the
        # pow2 rounding keeps retraces bounded, and quota-0 padding rows
        # never raise the wave's max.
        dedup, cap = beam.resolve_dedup(
            self.dedup, _round_capacity(int(quota_np.max())), quota_np,
            self.n, drive="host")

        stepper = self._stepper
        if stepper is not None:
            state, safe, keep = stepper.init(
                seeds, quota_j, pool_size=P, dedup=dedup, set_capacity=cap)
        else:
            state, safe, keep = _init_j(
                seeds, quota_j, n_points=self.n, pool_size=P, dedup=dedup,
                set_capacity=cap)
        while True:
            safe_np = np.asarray(safe)
            tower_batches += yield ("drain", safe_np[np.asarray(keep)])
            doc_embs = jnp.asarray(self._doc_embs(safe_np, q_D.shape[1]))
            dists = _wave_dists_j(doc_embs, q_D)
            if stepper is not None:
                state = stepper.commit(state, safe, keep, dists)
                if not stepper.active_any(state, quota_j, L_j, ms_j):
                    break
                state, safe, keep, _ = stepper.plan(
                    state, self._adjacency, quota_j, L_j, ms_j,
                    expand_width=expand_width)
            else:
                state = _commit_j(state, safe, keep, dists,
                                  backend=self.backend)
                if not bool(_active_any_j(state, quota_j, L_j, ms_j)):
                    break
                state, safe, keep, _ = _plan_step_j(
                    state, self._adjacency, quota_j, L_j, ms_j,
                    expand_width=expand_width)

        kmax = int(k_np.max())
        ids = np.asarray(state.pool_ids[:, :kmax], np.int64)
        dd = np.asarray(state.pool_dists[:, :kmax], np.float64)
        D_calls = np.asarray(state.n_calls)
        stats = [ServeStats(d_calls=int(d_calls[i]), D_calls=int(D_calls[i]),
                            tower_batches=tower_batches) for i in range(b)]
        return ids, dd, stats

    def _service_tower(self, item):
        """Run one tower-lane work item (the expensive-tower forward passes)."""
        kind, payload = item
        if kind == "embed_queries":
            # query-side embeddings are not charged to the quota: the budget
            # counts *document* scorings (the paper's cost model)
            return jnp.asarray(self.expensive.embed(payload))
        return self._drain_tower(payload)  # "drain"

    def _drive_sync(self, gen):
        """Run a wave coroutine to completion, servicing tower work inline."""
        try:
            item = next(gen)
            while True:
                item = gen.send(self._service_tower(item))
        except StopIteration as stop:
            return stop.value

    # ---------------------------------------------------------------- query
    def query_batch(self, query_tokens: np.ndarray, *, quota,
                    k: int = 10, n_seeds=None, expand_width: int = 1,
                    ) -> tuple[np.ndarray, np.ndarray, list[ServeStats]]:
        """Two-stage bi-metric search for a whole batch of (B, S) queries.

        ``quota`` (and ``n_seeds``) may be scalars or per-query (B,)
        vectors — mixed budgets run in one wave with exact per-query
        accounting. Returns (ids (B, k), D-dists (B, k), per-query stats);
        unfilled result slots are id -1 / dist +inf.
        """
        return self._drive_sync(
            self._wave_gen(query_tokens, quota, k, n_seeds, expand_width))

    def query(self, query_tokens: np.ndarray, *, quota: int, k: int = 10,
              n_seeds: int | None = None,
              ) -> tuple[np.ndarray, np.ndarray, ServeStats]:
        """One query (S,) tokens. Returns (ids, D-dists, stats)."""
        ids, dd, stats = self.query_batch(query_tokens[None], quota=quota,
                                          k=k, n_seeds=n_seeds)
        ok = (ids[0] >= 0) & np.isfinite(dd[0])
        return ids[0][ok], dd[0][ok], stats[0]

    # ------------------------------------------------------- async pipeline
    def submit(self, tokens: np.ndarray, *, quota: int, k: int = 10
               ) -> ServeFuture:
        """Queue one (S,) request; returns a :class:`ServeFuture` resolving
        to the :meth:`query` result shape. Starts the pipeline threads on
        first use. Raises ``RuntimeError`` after :meth:`close`."""
        fut = ServeFuture()
        req = _Request(tokens=np.asarray(tokens), quota=int(quota),
                       k=int(k), future=fut, t_submit=time.monotonic())
        # check-closed + enqueue under the lifecycle lock: close() flips
        # _closed under the same lock before it posts the sentinel, so a
        # request can never land behind the sentinel unresolved
        with self._lifecycle_lock:
            self._ensure_started_locked()
            self._admit_q.put(req)
        return fut

    def close(self, timeout: float | None = 60.0) -> None:
        """Drain and stop the pipeline. Every request admitted before the
        call still resolves; the admission queue is flushed into final
        (possibly partial) waves before the lanes shut down. Idempotent."""
        with self._lifecycle_lock:
            already = self._closed
            self._closed = True
            started = self._started
        if already or not started:
            return
        self._admit_q.put(_STOP)
        for t in self._threads:
            t.join(timeout)

    def _ensure_started_locked(self) -> None:
        """Start the lanes on first use; caller holds ``_lifecycle_lock``."""
        if self._closed:
            raise RuntimeError("engine pipeline is closed")
        if self._started:
            return
        self._admit_q = queue.Queue()
        self._device_q = queue.Queue()
        self._tower_q = queue.Queue()
        self._inflight_slots = threading.Semaphore(self.max_inflight)
        self._threads = [
            threading.Thread(target=loop, daemon=True, name=name)
            for name, loop in (("serve-admission", self._admission_loop),
                               ("serve-device", self._device_loop),
                               ("serve-tower", self._tower_loop))]
        for t in self._threads:
            t.start()
        self._started = True

    def _make_wave(self, requests: list) -> _Wave:
        """Pad a request group to the fixed (max_batch, S) wave shape.

        Padding rows carry quota 0 (they plan all-masked waves and never
        touch the tower) and k 1; because every budget knob is per-query in
        the core engine, padding never perturbs a real request's answer.
        """
        b, s = self.max_batch, self.corpus_tokens.shape[1]
        tokens = np.zeros((b, s), self.corpus_tokens.dtype)
        quota = np.zeros((b,), np.int32)
        k = np.ones((b,), np.int32)
        for i, r in enumerate(requests):
            tokens[i], quota[i], k[i] = r.tokens, r.quota, r.k
        return _Wave(requests=requests,
                     gen=self._wave_gen(tokens, quota, k, None, 1))

    def _admission_loop(self) -> None:
        stopping = False
        while not stopping:
            first = self._admit_q.get()
            if first is _STOP:
                break
            batch = [first]
            deadline = time.monotonic() + self.max_wait
            while len(batch) < self.max_batch:
                try:
                    r = self._admit_q.get(
                        timeout=max(deadline - time.monotonic(), 0.0))
                except queue.Empty:
                    break  # max_wait_ms flush: dispatch the partial wave
                if r is _STOP:
                    stopping = True
                    break
                batch.append(r)
            self._inflight_slots.acquire()  # the double buffer: ≤ max_inflight
            with self._inflight_lock:
                self._inflight += 1
            try:
                wave = self._make_wave(batch)
            except BaseException as exc:  # noqa: BLE001 — e.g. bad token shape
                # a malformed request must fail its own wave, not kill the
                # admission thread (which would wedge every later submit)
                for r in batch:
                    r.future._fail(exc)
                self._retire_wave()
                continue
            self._device_q.put(wave)
        self._device_q.put(_STOP)

    def _finish_wave(self, wave: _Wave, value) -> None:
        done = time.monotonic()
        ids, dd, stats = value
        for i, r in enumerate(wave.requests):
            row_ids, row_dd = ids[i, :r.k], dd[i, :r.k]
            ok = (row_ids >= 0) & np.isfinite(row_dd)
            # per-request wall clock: admission wait + wave compute — the
            # serving latency the async bench gates (p50/p95)
            stats[i].latency_ms = (done - r.t_submit) * 1e3
            r.future._resolve((row_ids[ok], row_dd[ok], stats[i]))

    def _fail_wave(self, wave: _Wave, exc: BaseException) -> None:
        for r in wave.requests:
            r.future._fail(exc)

    def _retire_wave(self) -> int:
        with self._inflight_lock:
            self._inflight -= 1
            left = self._inflight
        self._inflight_slots.release()
        return left

    def _device_loop(self) -> None:
        draining = False
        while True:
            item = self._device_q.get()
            if item is _STOP:
                draining = True
                with self._inflight_lock:
                    if self._inflight == 0:
                        break
                continue
            wave: _Wave = item
            try:
                if wave.tower_exc is not None:
                    raise wave.tower_exc
                if wave.started:
                    tower_item = wave.gen.send(wave.pending)
                else:
                    tower_item = next(wave.gen)
                    wave.started = True
                wave.pending = None
                wave.pending_item = tower_item
                self._tower_q.put(wave)
                continue
            except StopIteration as stop:
                self._finish_wave(wave, stop.value)
            except BaseException as exc:  # noqa: BLE001 — fail the futures
                self._fail_wave(wave, exc)
            if self._retire_wave() == 0 and draining:
                break
        self._tower_q.put(_STOP)

    def _tower_loop(self) -> None:
        while True:
            wave = self._tower_q.get()
            if wave is _STOP:
                break
            try:
                wave.pending = self._service_tower(wave.pending_item)
            except BaseException as exc:  # noqa: BLE001 — surfaced on device
                wave.tower_exc = exc
            self._device_q.put(wave)

    # --------------------------------------------------------------- rerank
    def _embed_queries(self, query_tokens: np.ndarray):
        """(B, S) tokens -> cheap (B, dim_d) on device, expensive (B, dim_D).

        Query-side embeddings are not charged to the quota: the budget counts
        *document* scorings (the paper's cost model)."""
        q_d = jnp.asarray(self.cheap.embed(query_tokens))
        q_D = jnp.asarray(self.expensive.embed(query_tokens))
        return q_d, q_D

    def rerank_query_batch(self, query_tokens: np.ndarray, *, quota: int,
                           k: int = 10,
                           ) -> tuple[np.ndarray, np.ndarray, list[ServeStats]]:
        """"Bi-metric (baseline)": top-quota by d, embed all with D, rerank."""
        b = query_tokens.shape[0]
        q_d, q_D = self._embed_queries(query_tokens)
        width = max(32, quota)
        res1 = self._stage1(q_d, width=width, pool=max(width, quota),
                            max_steps=8 * width)
        cand = np.asarray(res1.pool_ids[:, :quota])
        tower_batches = self._drain_tower(cand)
        doc_embs = self._emb_D[np.maximum(cand, 0)]  # host-side, no transfer
        diff = doc_embs - np.asarray(q_D)[:, None, :]
        dd = np.sqrt((diff * diff).sum(-1))
        dd = np.where(cand >= 0, dd, np.inf)
        order = np.argsort(dd, axis=1, kind="stable")[:, :k]
        d_calls = np.asarray(res1.n_calls)
        n_D = (cand >= 0).sum(1)
        stats = [ServeStats(d_calls=int(d_calls[i]), D_calls=int(n_D[i]),
                            tower_batches=tower_batches) for i in range(b)]
        return (np.take_along_axis(cand, order, 1).astype(np.int64),
                np.take_along_axis(dd, order, 1), stats)

    def rerank_query(self, query_tokens: np.ndarray, *, quota: int,
                     k: int = 10) -> tuple[np.ndarray, np.ndarray, ServeStats]:
        """One query (S,) tokens through the rerank baseline."""
        ids, dd, stats = self.rerank_query_batch(query_tokens[None],
                                                 quota=quota, k=k)
        ok = (ids[0] >= 0) & np.isfinite(dd[0])
        return ids[0][ok], dd[0][ok], stats[0]
