"""Bi-metric serving engine: the paper's deployment story, end to end.

* the **cheap tower** (e.g. qwen3-0.6b / bge-micro-like) runs locally and
  embeds the corpus once at index-build time — the graph index is built on
  those embeddings only (Theorem 1.1 property 1);
* the **expensive tower** (e.g. deepseek-v3 / SFR-Mistral-like) is the
  ground-truth metric D: scoring a document costs a forward pass. The engine
  enforces the call budget *exactly* — the quota is literally a compute
  budget on the big model;
* queries run the two-stage search **as a batch**. Stage 1 is one
  batched-engine run under d on device. Stage 2 drives the *same* core hot
  loop (``repro.core.beam.plan_step`` / ``commit_scores``) from the host:
  each wave is planned on device for every query at once, the union of
  documents the wave needs is drained through the expensive tower in
  ``serve/batcher.py``-style batched forward passes, and the scores are
  committed back on device. Per-query accounting is identical to running
  each query alone (a document counts against a query's quota the first
  time that query scores it), while the tower only ever embeds a document
  once per engine lifetime — the cross-query cache is pure compute savings.

``EmbedTower`` wraps (params, config, pooling); swap in any LM arch config.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import beam, distances, vamana
from repro.distributed import sharding
from repro.models import transformer as T

Array = jax.Array


@dataclasses.dataclass
class EmbedTower:
    params: dict
    cfg: T.TransformerConfig

    def __post_init__(self):
        self._embed = jax.jit(
            lambda p, toks: T.embed_pool(p, toks, self.cfg))

    def embed(self, tokens: np.ndarray, batch: int = 64) -> np.ndarray:
        out = []
        n = tokens.shape[0]
        pad = (-n) % batch
        toks = np.pad(tokens, ((0, pad), (0, 0))) if pad else tokens
        for s in range(0, len(toks), batch):
            out.append(np.asarray(self._embed(self.params, toks[s:s + batch])))
        return np.concatenate(out)[:n]


@dataclasses.dataclass
class ServeStats:
    d_calls: int = 0
    D_calls: int = 0  # expensive-tower document scorings (the budget)
    # forward-pass batches drained for the WHOLE request batch (replicated
    # on every query's stats for convenience — do not sum across a batch)
    tower_batches: int = 0


@functools.partial(
    jax.jit, static_argnames=("beam_width", "max_steps", "expand_width"))
def _plan_step_j(state, adjacency, quota, *, beam_width, max_steps,
                 expand_width):
    return beam.plan_step(
        state, adjacency, beam_width=beam_width, quota=quota,
        max_steps=max_steps, expand_width=expand_width)


@jax.jit
def _score_commit_j(state, safe, keep, doc_embs, q_D):
    """L2 under D from gathered doc embeddings; commit the wave."""
    diff = doc_embs.astype(jnp.float32) - q_D[:, None, :].astype(jnp.float32)
    d = jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    return beam.commit_scores(state, safe, keep, d)


@functools.partial(jax.jit, static_argnames=("beam_width", "max_steps"))
def _active_any_j(state, quota, *, beam_width, max_steps):
    return beam.active_mask(
        state, beam_width=beam_width, quota=quota, max_steps=max_steps).any()


class BiMetricEngine:
    """corpus_tokens: (N, S) int32 document tokens.

    ``shards > 1`` runs the device-side cheap-metric searches (stage 1 and
    the rerank baseline's stage 1) device-parallel over a corpus mesh —
    the cheap corpus embeddings and the scored bitmap are split across
    ``shards`` devices, pools stay replicated, results are bit-exact
    (``repro.core.beam.sharded_greedy_search``). The stage-2 loop stays
    host-driven and replicated: its metric is the expensive tower itself,
    so the device side of a stage-2 wave is plan/commit bookkeeping, not a
    corpus gather.
    """

    def __init__(self, cheap: EmbedTower, expensive: EmbedTower,
                 corpus_tokens: np.ndarray,
                 index_cfg: vamana.VamanaConfig | None = None,
                 tower_batch: int = 64, shards: int = 1):
        self.cheap = cheap
        self.expensive = expensive
        self.corpus_tokens = corpus_tokens
        self.n = corpus_tokens.shape[0]
        self.tower_batch = tower_batch
        self.shards = shards
        # --- index build: cheap metric ONLY --------------------------------
        self.emb_d = jnp.asarray(cheap.embed(corpus_tokens))
        self.index = vamana.build(self.emb_d,
                                  index_cfg or vamana.VamanaConfig(
                                      max_degree=16, l_build=24, pool_size=48,
                                      rev_candidates=16))
        self._em_d = distances.EmbeddingMetric(self.emb_d)
        self._adjacency = self.index.adjacency.astype(jnp.int32)
        # one mesh for the engine lifetime (stage-1 shard_map programs)
        self._mesh = (sharding.search_mesh(shards) if shards > 1 else None)
        # lazy expensive-tower document embeddings (engine-lifetime cache)
        self._emb_D: np.ndarray | None = None
        self._emb_D_valid = np.zeros((self.n,), bool)

    # ------------------------------------------------------------ internals
    def _embed_queries(self, query_tokens: np.ndarray):
        """(B, S) tokens -> cheap (B, dim_d) on device, expensive (B, dim_D).

        Query-side embeddings are not charged to the quota: the budget counts
        *document* scorings (the paper's cost model)."""
        q_d = jnp.asarray(self.cheap.embed(query_tokens))
        q_D = jnp.asarray(self.expensive.embed(query_tokens))
        return q_d, q_D

    def _stage1(self, q_d: Array, *, width: int, pool: int,
                max_steps: int) -> beam.SearchResult:
        """Batched cheap-metric greedy search from the medoid (stage 1).

        With ``shards > 1`` the same loop runs device-parallel over the
        engine's corpus mesh — bit-exact vs the single-device path."""
        b = q_d.shape[0]
        entries = jnp.broadcast_to(
            jnp.asarray(self.index.medoid, jnp.int32).reshape(1, 1), (b, 1))
        if self.shards > 1:
            return beam.sharded_greedy_search(
                self.emb_d, self._adjacency, q_d, entries,
                shards=self.shards, metric=self._em_d.metric,
                mesh=self._mesh, beam_width=width, pool_size=pool,
                max_steps=max_steps)
        return beam.batched_greedy_search(
            self._em_d.dists_batch, self._adjacency, q_d, entries,
            n_points=self.n, beam_width=width, pool_size=pool,
            max_steps=max_steps)

    def _drain_tower(self, ids: np.ndarray) -> int:
        """Embed not-yet-cached docs through the expensive tower; returns the
        number of forward batches drained."""
        need = np.unique(ids[(ids >= 0) & ~self._emb_D_valid[np.maximum(ids, 0)]])
        if need.size == 0:
            return 0
        embs = self.expensive.embed(self.corpus_tokens[need],
                                    batch=self.tower_batch)
        if self._emb_D is None:
            self._emb_D = np.zeros((self.n, embs.shape[1]), embs.dtype)
        self._emb_D[need] = embs
        self._emb_D_valid[need] = True
        return -(-need.size // self.tower_batch)

    # ---------------------------------------------------------------- query
    def query_batch(self, query_tokens: np.ndarray, *, quota: int,
                    k: int = 10, n_seeds: int | None = None,
                    expand_width: int = 1,
                    ) -> tuple[np.ndarray, np.ndarray, list[ServeStats]]:
        """Two-stage bi-metric search for a whole batch of (B, S) queries.

        Returns (ids (B, k), D-dists (B, k), per-query stats); unfilled
        result slots are id -1 / dist +inf.
        """
        b = query_tokens.shape[0]
        q_d, q_D = self._embed_queries(query_tokens)
        n_seeds = n_seeds or max(1, quota // 2)
        width1 = max(32, n_seeds)

        # stage 1 — one batched cheap-metric search on device
        res1 = self._stage1(q_d, width=width1, pool=max(width1, n_seeds),
                            max_steps=4 * width1)
        seeds = res1.pool_ids[:, :n_seeds]
        d_calls = np.asarray(res1.n_calls)

        # stage 2 — the core hot loop, host-driven: plan on device, drain the
        # tower for the wave's union of fresh docs, commit scores on device.
        L = max(k, min(quota, 2 * max(n_seeds, 1) + 8))
        P = max(L, k)
        max_steps = 4 * quota
        quota_arr = jnp.full((b,), quota, jnp.int32)
        tower_batches = 0

        state, safe, keep = beam.init_state(
            seeds, n_points=self.n, pool_size=P, quota=quota_arr)
        while True:
            safe_np = np.asarray(safe)
            tower_batches += self._drain_tower(safe_np[np.asarray(keep)])
            doc_embs = jnp.asarray(
                (self._emb_D if self._emb_D is not None
                 else np.zeros((self.n, q_D.shape[1]), np.float32)
                 )[np.maximum(safe_np, 0)])
            state = _score_commit_j(state, safe, keep, doc_embs, q_D)
            if not bool(_active_any_j(state, quota_arr, beam_width=L,
                                      max_steps=max_steps)):
                break
            state, safe, keep, _ = _plan_step_j(
                state, self._adjacency, quota_arr, beam_width=L,
                max_steps=max_steps, expand_width=expand_width)

        ids = np.asarray(state.pool_ids[:, :k], np.int64)
        dd = np.asarray(state.pool_dists[:, :k], np.float64)
        D_calls = np.asarray(state.n_calls)
        stats = [ServeStats(d_calls=int(d_calls[i]), D_calls=int(D_calls[i]),
                            tower_batches=tower_batches) for i in range(b)]
        return ids, dd, stats

    def query(self, query_tokens: np.ndarray, *, quota: int, k: int = 10,
              n_seeds: int | None = None,
              ) -> tuple[np.ndarray, np.ndarray, ServeStats]:
        """One query (S,) tokens. Returns (ids, D-dists, stats)."""
        ids, dd, stats = self.query_batch(query_tokens[None], quota=quota,
                                          k=k, n_seeds=n_seeds)
        ok = (ids[0] >= 0) & np.isfinite(dd[0])
        return ids[0][ok], dd[0][ok], stats[0]

    # --------------------------------------------------------------- rerank
    def rerank_query_batch(self, query_tokens: np.ndarray, *, quota: int,
                           k: int = 10,
                           ) -> tuple[np.ndarray, np.ndarray, list[ServeStats]]:
        """"Bi-metric (baseline)": top-quota by d, embed all with D, rerank."""
        b = query_tokens.shape[0]
        q_d, q_D = self._embed_queries(query_tokens)
        width = max(32, quota)
        res1 = self._stage1(q_d, width=width, pool=max(width, quota),
                            max_steps=8 * width)
        cand = np.asarray(res1.pool_ids[:, :quota])
        tower_batches = self._drain_tower(cand)
        doc_embs = self._emb_D[np.maximum(cand, 0)]  # host-side, no transfer
        diff = doc_embs - np.asarray(q_D)[:, None, :]
        dd = np.sqrt((diff * diff).sum(-1))
        dd = np.where(cand >= 0, dd, np.inf)
        order = np.argsort(dd, axis=1, kind="stable")[:, :k]
        rows = np.arange(b)[:, None]
        d_calls = np.asarray(res1.n_calls)
        n_D = (cand >= 0).sum(1)
        stats = [ServeStats(d_calls=int(d_calls[i]), D_calls=int(n_D[i]),
                            tower_batches=tower_batches) for i in range(b)]
        return (np.take_along_axis(cand, order, 1).astype(np.int64),
                np.take_along_axis(dd, order, 1), stats)

    def rerank_query(self, query_tokens: np.ndarray, *, quota: int,
                     k: int = 10) -> tuple[np.ndarray, np.ndarray, ServeStats]:
        """One query (S,) tokens through the rerank baseline."""
        ids, dd, stats = self.rerank_query_batch(query_tokens[None],
                                                 quota=quota, k=k)
        ok = (ids[0] >= 0) & np.isfinite(dd[0])
        return ids[0][ok], dd[0][ok], stats[0]
