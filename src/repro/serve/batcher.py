"""Request batcher: pads/pools pending queries so tower forward passes run
at serving-efficient batch sizes (the expensive tower is the bottleneck)."""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable

import numpy as np


@dataclasses.dataclass
class Request:
    tokens: np.ndarray
    quota: int
    result: "queue.Queue"


class Batcher:
    """Collects requests up to ``max_batch`` or ``max_wait_ms`` and runs them
    through ``handler(list[Request])`` on a worker thread."""

    def __init__(self, handler: Callable[[list[Request]], None],
                 max_batch: int = 8, max_wait_ms: float = 5.0):
        self.handler = handler
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._stop = False
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, tokens: np.ndarray, quota: int):
        r = Request(tokens=tokens, quota=quota, result=queue.Queue(maxsize=1))
        self._q.put(r)
        return r.result

    def _loop(self):
        while not self._stop:
            try:
                first = self._q.get(timeout=0.1)
            except queue.Empty:
                continue
            batch = [first]
            deadline = time.monotonic() + self.max_wait
            while len(batch) < self.max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    batch.append(self._q.get(timeout=remaining))
                except queue.Empty:
                    break
            self.handler(batch)

    def close(self):
        self._stop = True
        self._thread.join(timeout=1.0)
