"""Training launcher: ``python -m repro.launch.train --arch qwen3-0.6b
--preset smoke --steps 50``.

Presets:
  smoke — reduced config, host devices, runs in seconds (CI);
  full  — the exact assigned config; on real hardware pair with the
          production mesh (this process would be one host of the fleet).

Fault tolerance is on by default: checkpoints land in --ckpt-dir, a killed
run resumes (params, optimizer, data cursor) via Trainer.maybe_restore().
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import DeterministicIterator, lm_batch_fn
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--topk-compress", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    if spec.family != "lm":
        raise SystemExit("train launcher currently drives the LM family; "
                         "see examples/ for GNN/recsys training loops")
    cfg = spec.make_config(args.preset == "smoke")
    params = spec.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={args.arch} preset={args.preset} params={n_params/1e6:.1f}M")

    opt = AdamWConfig(lr=3e-4, warmup_steps=10, total_steps=max(args.steps, 100))
    tcfg = TrainerConfig(total_steps=args.steps, grad_accum=args.grad_accum,
                         ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 3, 10),
                         topk_compress=args.topk_compress, log_every=5)
    trainer = Trainer(lambda p, b: T.loss_fn(p, b, cfg), params, opt, tcfg)
    it = DeterministicIterator(lm_batch_fn(args.batch, args.seq, cfg.vocab))
    state = trainer.maybe_restore(it.state())
    if state is not None:
        it = DeterministicIterator.from_state(
            lm_batch_fn(args.batch, args.seq, cfg.vocab), state)
    out = trainer.run(it, data_state_fn=it.state)
    print(f"final loss {out['final_loss']:.4f} "
          f"stragglers={len(out['stragglers'])}")


if __name__ == "__main__":
    main()
