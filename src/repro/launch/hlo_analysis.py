"""Post-SPMD HLO cost analyzer with loop-trip-count accounting.

``compiled.cost_analysis()`` visits each while-loop *body once*, so a
scan-over-layers transformer under-reports FLOPs by ~n_layers ×. This module
re-derives the three roofline quantities from ``compiled.as_text()`` (the
partitioned, optimized, per-device HLO):

  * dot FLOPs        — 2 · |out| · K per dot (fused dots included), summed
                       along the call graph with while bodies weighted by
                       their ``known_trip_count``;
  * bytes accessed   — HBM traffic at *fusion boundaries*: for every
                       top-level instruction, operand + output bytes, where
                       - fusion internals are register/VMEM-resident (free),
                       - a fusion param consumed only via dynamic-slice /
                         gather counts the slice bytes, not the operand,
                       - a dynamic-update-slice (incl. as fusion root)
                         counts the update bytes (the base is aliased);
  * collective bytes — Σ output bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute.

The same text parser also exposes the module-header donation table
(:func:`parse_input_output_alias`) and a while-body copy scanner
(:func:`while_body_copies`) — the raw material for
``repro.analysis.aliasing``'s donation/carry verifier.

Shapes in this text are per-device; all numbers here are per chip.
"""
from __future__ import annotations

import dataclasses
import re

# element widths in BITS: s4/u4 are packed sub-byte types (2 elems/byte),
# everything else is byte-aligned. shape_bytes rounds each shape up to
# whole bytes, matching the physical buffer size.
_DTYPE_BITS = {
    "pred": 8, "s4": 4, "u4": 4, "s8": 8, "u8": 8, "s16": 16, "u16": 16,
    "s32": 32, "u32": 32, "s64": 64, "u64": 64,
    "f8e4m3": 8, "f8e4m3fn": 8, "f8e4m3fnuz": 8, "f8e4m3b11fnuz": 8,
    "f8e5m2": 8, "f8e5m2fnuz": 8,
    "bf16": 16, "f16": 16, "f32": 32, "f64": 64, "c64": 64, "c128": 128,
    "token": 0, "opaque": 0,
}

# dims may carry XLA's bounded-dynamic marker: f32[<=1024] is a bounded
# dynamic dim whose buffer is the bound — parse it like a static 1024
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,<=]*)\]")


def _dims(dims_str: str) -> list[int]:
    return [int(d.lstrip("<=")) for d in dims_str.split(",") if d]
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_FREE_OPS = ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "copy-done", "copy-start", "after-all", "partition-id")
_SLICE_OPS = ("dynamic-slice", "gather", "slice")


def shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BITS:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        total += (n * _DTYPE_BITS[dt] + 7) // 8
    return total


def shape_elems(text: str) -> int:
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BITS:
            continue
        n = 1
        for d in _dims(dims):
            n *= d
        return n
    return 0


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: list[str]
    raw: str
    is_root: bool


_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\("
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        s = line.rstrip()
        if (
            s.endswith("{") and "->" in s and not s.startswith(" ")
            and "=" not in s.split("(")[0]
        ):
            head = s.split("(")[0].strip()
            head = head.replace("ENTRY", "").strip().lstrip("%")
            cur = head
            comps[cur] = []
            continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is not None and s.strip():
            comps[cur].append(line)
    return comps


def _parse_instr(line: str) -> Instr | None:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    root, name, rtype, op = m.groups()
    after = line[m.end():]
    ops = re.findall(r"%([\w.\-]+)", after.split("),")[0] + ")")
    return Instr(name=name, result_type=rtype, op=op, operands=ops,
                 raw=line, is_root=bool(root))


@dataclasses.dataclass
class Comp:
    name: str
    instrs: list[Instr]
    symtab: dict[str, str]
    params: dict[int, str]  # parameter index -> instr name
    root: Instr | None

    def param_effective_bytes(self) -> dict[str, int]:
        """Effective read bytes per param name (slice-aware)."""
        out = {}
        for idx, pname in self.params.items():
            uses = [i for i in self.instrs if pname in i.operands]
            if not uses:
                out[pname] = 0
            elif all(u.op in _SLICE_OPS for u in uses):
                out[pname] = sum(shape_bytes(u.result_type) for u in uses)
            else:
                out[pname] = shape_bytes(self.symtab.get(pname, ""))
        return out

    def output_effective_bytes(self) -> int:
        if self.root is not None and self.root.op == "dynamic-update-slice":
            # base is aliased in place; traffic = the update tensor
            upd = self.root.operands[1] if len(self.root.operands) > 1 else None
            return shape_bytes(self.symtab.get(upd, "")) if upd else 0
        if self.root is not None:
            return shape_bytes(self.root.result_type)
        return 0


def _parse_comp(name: str, lines: list[str]) -> Comp:
    instrs, symtab, params = [], {}, {}
    root = None
    for line in lines:
        ins = _parse_instr(line)
        if ins is None:
            # parameter lines: "%p = f32[..] parameter(0)" match _INSTR_RE
            continue
        symtab[ins.name] = ins.result_type
        instrs.append(ins)
        if ins.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.raw)
            if m:
                params[int(m.group(1))] = ins.name
        if ins.is_root:
            root = ins
    return Comp(name=name, instrs=instrs, symtab=symtab, params=params,
                root=root)


@dataclasses.dataclass(frozen=True)
class AliasEntry:
    """One entry of the module header's ``input_output_alias`` table: flat
    output index ``output_index`` reuses the buffer of flat parameter
    ``param_number`` (``param_index`` subindexes a tuple-shaped parameter;
    jax emits flat parameters, so it is normally empty)."""

    output_index: tuple[int, ...]
    param_number: int
    param_index: tuple[int, ...]
    kind: str  # "may-alias" | "must-alias"


_ALIAS_ENTRY_RE = re.compile(
    r"\{([0-9,\s]*)\}:\s*\(([0-9]+),\s*\{([0-9,\s]*)\}(?:,\s*([a-z\-]+))?\)")


def parse_input_output_alias(hlo_text: str) -> list[AliasEntry]:
    """The donation table of an optimized ``compiled.as_text()`` module.

    Buffers jax actually donated (and XLA accepted) show up here; a
    ``donate_argnums`` declaration whose parameter is *absent* from this
    table was silently dropped — XLA allocates a fresh output buffer and
    the donation is a no-op. Returns [] when the module has no table.
    """
    m = re.search(r"input_output_alias=\{", hlo_text)
    if not m:
        return []
    depth, i = 1, m.end()
    while i < len(hlo_text) and depth:
        ch = hlo_text[i]
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
        i += 1
    block = hlo_text[m.end():i - 1]

    def _idx(s: str) -> tuple[int, ...]:
        return tuple(int(x) for x in s.replace(" ", "").split(",") if x)

    return [
        AliasEntry(output_index=_idx(e.group(1)),
                   param_number=int(e.group(2)),
                   param_index=_idx(e.group(3)),
                   kind=e.group(4) or "may-alias")
        for e in _ALIAS_ENTRY_RE.finditer(block)
    ]


def while_body_copies(hlo_text: str,
                      result_type_prefix: str | None = None) -> list[Instr]:
    """``copy`` instructions reachable from any while-loop *body*.

    When XLA cannot alias a while carry in place (the body still reads the
    old value, or layouts disagree) copy-insertion materializes a per-step
    ``copy`` of the carried buffer inside the body — the exact failure mode
    the fused-loop dedup-bitmap contract rules out. Copies in the entry
    computation (initial-carry setup, one-time) are NOT reported.
    ``result_type_prefix`` filters to copies of one buffer shape, e.g.
    ``"pred[4,64]"`` for a (B=4, N=64) bitmap carry.
    """
    comps = _split_computations(hlo_text)
    bodies: set[str] = set()
    for lines in comps.values():
        for line in lines:
            if re.search(r"\bwhile\(", line):
                mb = re.search(r"body=%?([\w.\-]+)", line)
                if mb:
                    bodies.add(mb.group(1))
    # copies may hide in fusions/calls the body invokes — walk the graph
    seen: set[str] = set()
    stack = list(bodies)
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        for line in comps[name]:
            for mc in re.finditer(r"(to_apply|calls|body|condition)=%?([\w.\-]+)",
                                  line):
                stack.append(mc.group(2))
    out = []
    for name in sorted(seen):
        for line in comps[name]:
            ins = _parse_instr(line)
            if ins is None or ins.op != "copy":
                continue
            if (result_type_prefix is None
                    or ins.result_type.startswith(result_type_prefix)):
                out.append(ins)
    return out


def _dot_flops(instr: Instr, symtab: dict[str, str]) -> float:
    mc = _CONTRACT_RE.search(instr.raw)
    out_elems = shape_elems(instr.result_type)
    if not mc or not instr.operands:
        return 2.0 * out_elems
    lhs_type = symtab.get(instr.operands[0], "")
    mshape = _SHAPE_RE.search(lhs_type)
    if not mshape:
        return 2.0 * out_elems
    dims = _dims(mshape.group(2))
    k = 1
    for ci in mc.group(1).split(","):
        if ci and int(ci) < len(dims):
            k *= dims[int(ci)]
    return 2.0 * out_elems * k


def analyze(hlo_text: str) -> dict:
    comps = {n: _parse_comp(n, ls)
             for n, ls in _split_computations(hlo_text).items()}

    flops: dict[str, float] = {}
    for name, comp in comps.items():
        flops[name] = sum(
            _dot_flops(i, comp.symtab) for i in comp.instrs if i.op == "dot"
        )

    # call edges: (callee, multiplier, kind)
    edges: dict[str, list[tuple[str, float, str]]] = {}
    for name, comp in comps.items():
        es = []
        for ins in comp.instrs:
            mult = 1.0
            t = _TRIP_RE.search(ins.raw)
            if ins.op == "while" and t:
                mult = float(t.group(1))
            for m in re.finditer(r"(to_apply|calls|body|condition)=%?([\w.\-]+)",
                                 ins.raw):
                if m.group(1) in ("body", "condition"):
                    kind = "while"
                elif ins.op in ("call", "conditional"):
                    # plain call wrappers (e.g. XLA:CPU's parallel-partition
                    # `call ... to_apply=%parallel_*`) execute their body's
                    # HBM traffic once — unlike fusions, whose internals are
                    # register-resident.
                    kind = "call"
                else:
                    kind = "fusion"
                es.append((m.group(2), mult, kind))
        edges[name] = es

    _GROUPSIZE_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
    _GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

    def _group_size(raw: str) -> int:
        m = _GROUPSIZE_RE.search(raw)
        if m:
            return int(m.group(2))
        m = _GROUPS_RE.search(raw)
        if m:
            return len(m.group(1).split(","))
        return 1

    def comp_bytes_and_coll(comp: Comp) -> tuple[float, float, float, float]:
        b = 0.0  # fusion-boundary model (what this HLO does)
        bf = 0.0  # fused model: every buffer written once (TPU-like lower bound)
        coll = 0.0
        coll_rs = 0.0  # with the TPU AR->RS rewrite applied
        # consumers (for detecting the all-reduce -> dynamic-slice pattern
        # that the TPU pipeline rewrites to reduce-scatter; XLA:CPU lacks
        # the ReduceScatterCreator pass so it survives in this artifact)
        consumers: dict[str, list[Instr]] = {}
        for ins in comp.instrs:
            for o in ins.operands:
                consumers.setdefault(o, []).append(ins)

        def feeds_dynamic_slice(name: str, depth=0) -> bool:
            if depth > 2:
                return False
            for u in consumers.get(name, []):
                if "dynamic-slice" in u.name or u.op == "dynamic-slice":
                    return True
                if u.op in ("get-tuple-element", "bitcast", "copy", "convert"):
                    if feeds_dynamic_slice(u.name, depth + 1):
                        return True
            return False

        for ins in comp.instrs:
            if ins.op in _FREE_OPS:
                continue
            out_b = shape_bytes(ins.result_type)
            if ins.op in _COLLECTIVES or any(ins.op.startswith(c)
                                             for c in _COLLECTIVES):
                coll += out_b
                # TPU AR->RS equivalence (XLA:CPU lacks ReduceScatterCreator):
                # (a) AR whose result is dynamic-sliced, or (b) AR of rank-2
                # weight-gradient (tuples) inside bwd loops — consumed only
                # at the optimizer's shard. Both lower to reduce-scatter on
                # the TPU pipeline; counted at the sharded size here.
                ranks = [len([d for d in dims.split(",") if d])
                         for _, dims in _SHAPE_RE.findall(ins.result_type)]
                grad_like = ranks and max(ranks) == 2  # weight(+norm) grads
                if ins.op.startswith("all-reduce") and (
                        feeds_dynamic_slice(ins.name) or grad_like):
                    coll_rs += out_b / max(_group_size(ins.raw), 1)
                else:
                    coll_rs += out_b
                b += 2 * out_b
                bf += 2 * out_b
                continue
            if ins.op in _SLICE_OPS:
                b += 2 * out_b
                bf += out_b
                continue
            if ins.op == "dynamic-update-slice":
                upd = ins.operands[1] if len(ins.operands) > 1 else None
                ub = shape_bytes(comp.symtab.get(upd, "")) if upd else out_b
                b += 2 * ub
                bf += ub
                continue
            if ins.op == "dot":
                opb = sum(shape_bytes(comp.symtab.get(o, ""))
                          for o in ins.operands)
                b += out_b + opb
                bf += out_b + opb
                continue
            if ins.op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.raw)
                callee = comps.get(m.group(1)) if m else None
                if callee is not None:
                    eff = callee.param_effective_bytes()
                    # operand order matches parameter index order
                    for idx, opnd in enumerate(ins.operands):
                        pname = callee.params.get(idx)
                        if pname is not None:
                            b += eff.get(pname, 0)
                        else:
                            b += shape_bytes(comp.symtab.get(opnd, ""))
                    ob = callee.output_effective_bytes()
                    b += ob
                    bf += ob
                else:
                    b += out_b + sum(shape_bytes(comp.symtab.get(o, ""))
                                     for o in ins.operands)
                    bf += out_b
                continue
            if ins.op in ("while", "conditional", "call"):
                continue  # accounted via their bodies
            b += out_b + sum(shape_bytes(comp.symtab.get(o, ""))
                             for o in ins.operands)
            bf += out_b
        return b, bf, coll, coll_rs

    bytes_: dict[str, float] = {}
    bytes_f: dict[str, float] = {}
    coll: dict[str, float] = {}
    coll_rs_d: dict[str, float] = {}
    for name, comp in comps.items():
        (bytes_[name], bytes_f[name], coll[name],
         coll_rs_d[name]) = comp_bytes_and_coll(comp)

    called = {c for es in edges.values() for c, _, _ in es}
    entries = [c for c in comps if c not in called]
    if not entries:
        entries = list(comps)
    entry = next((c for c in entries if "main" in c), entries[0])

    memo: dict[str, tuple] = {}

    def total(cname: str, depth=0) -> tuple:
        if cname in memo:
            return memo[cname]
        if cname not in comps or depth > 128:
            return (0.0, 0.0, 0.0, 0.0, 0.0)
        f = flops[cname]
        b = bytes_[cname]
        bf = bytes_f[cname]
        c = coll[cname]
        crs = coll_rs_d[cname]
        for callee, mult, kind in edges[cname]:
            cf, cb, cbf, cc, ccrs = total(callee, depth + 1)
            f += mult * cf
            if kind in ("while", "call"):  # fusions are not HBM traffic
                b += mult * cb
                bf += mult * cbf
                c += mult * cc
                crs += mult * ccrs
            else:
                c += mult * cc  # (collectives never live in fusions; safety)
                crs += mult * ccrs
        memo[cname] = (f, b, bf, c, crs)
        return memo[cname]

    f, b, bf, c, crs = total(entry)
    return {
        "entry": entry,
        "dot_flops_per_device": f,
        "bytes_per_device": b,
        "bytes_fused_per_device": bf,
        "collective_bytes_per_device": c,
        "collective_rs_bytes_per_device": crs,
        "n_computations": len(comps),
    }


# per-chip hardware peaks (TPU v5e)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
LINK_BW = 50e9  # per ICI link


def roofline_terms(an: dict) -> dict:
    compute_s = an["dot_flops_per_device"] / PEAK_FLOPS
    memory_s = an["bytes_per_device"] / HBM_BW
    memory_fused_s = an.get("bytes_fused_per_device", 0.0) / HBM_BW
    collective_s = an["collective_bytes_per_device"] / LINK_BW
    collective_rs_s = an.get("collective_rs_bytes_per_device",
                             an["collective_bytes_per_device"]) / LINK_BW
    dom = max(
        ("compute", compute_s), ("memory", memory_fused_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    step_s = max(compute_s, memory_fused_s, collective_s)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,  # CPU-HLO fusion-boundary upper model
        "memory_fused_s": memory_fused_s,  # TPU-like fused lower model
        "collective_s": collective_s,
        "collective_rs_s": collective_rs_s,
        "bottleneck": dom,
        "roofline_fraction": compute_s / step_s if step_s else 0.0,
        "roofline_fraction_rs": compute_s / max(
            compute_s, memory_fused_s, collective_rs_s, 1e-30),
    }
