import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks the device count on first
# init). The dry-run — and only the dry-run — builds the production meshes
# out of 512 placeholder host devices.

"""Multi-pod dry-run driver.

For every (architecture × input shape) cell:
    with mesh:
        lowered  = jax.jit(step, donate_argnums=...).lower(*abstract_args)
        compiled = lowered.compile()
        print(compiled.memory_analysis())    # proves the cell fits HBM
        print(compiled.cost_analysis())      # XLA FLOPs/bytes (body-once)
plus the trip-count-corrected HLO analysis (launch/hlo_analysis.py) that
feeds EXPERIMENTS.md §Roofline. Results are appended to a JSON cache so
cells can run in parallel worker processes and be merged.

Usage:
    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
        [--multi-pod] [--out results.json] [--all]
"""
import argparse
import json
import time
import traceback


def run_cell(arch_name: str, shape: str, *, multi_pod: bool,
             out_path: str | None = None, verbose: bool = True) -> dict:
    import jax

    from repro.configs import get_arch
    from repro.distributed import sharding as shr
    from repro.launch import hlo_analysis as H
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    spec = get_arch(arch_name)
    cell = spec.cell(shape)
    t0 = time.time()
    args = cell.abstract_args(mesh)
    dp = (shr.all_axes(mesh) if getattr(cell, "act_axes", "dp") == "all"
          else shr.batch_axes(mesh))
    out_sh = cell.out_shardings(args) if cell.out_shardings else None
    with mesh, shr.activation_mesh(mesh, dp):
        jitted = jax.jit(cell.fn, donate_argnums=cell.donate,
                         out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    an = H.analyze(hlo)
    terms = H.roofline_terms(an)
    result = {
        "cell": f"{arch_name}/{shape}",
        "arch": arch_name,
        "shape": shape,
        "mesh": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "n_chips": int(n_chips),
        "entry": cell.entry,
        "tokens": cell.tokens,
        "ok": True,
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "xla_cost": {
            "flops_body_once": cost.get("flops", 0.0),
            "bytes_accessed_body_once": cost.get("bytes accessed", 0.0),
        },
        "hlo_analysis": an,
        "roofline": terms,
    }
    if verbose:
        print(f"== {result['cell']} on {result['mesh']} ==")
        print("memory_analysis:", mem)
        print("cost_analysis flops (body-once):", cost.get("flops"))
        print("hlo per-device:", {k: f"{v:.3e}" for k, v in an.items()
                                  if isinstance(v, float)})
        print("roofline:", {k: (f"{v:.4e}" if isinstance(v, float) else v)
                            for k, v in terms.items()})
    if out_path:
        _append(out_path, result)
    return result


def _append(path: str, result: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            try:
                data = json.load(f)
            except json.JSONDecodeError:
                data = {}
    key = f"{result['cell']}@{'x'.join(map(str, result['mesh']))}"
    data[key] = result
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
    os.replace(tmp, path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="run all 40 cells")
    ap.add_argument("--out", type=str, default="dryrun_results.json")
    args = ap.parse_args()

    from repro.configs import ARCHS, all_cells

    todo = []
    if args.all:
        todo = all_cells()
    elif args.arch and args.shape:
        todo = [(args.arch, args.shape)]
    elif args.arch:
        todo = [(args.arch, s) for s in ARCHS[args.arch].shapes]
    else:
        ap.error("pass --arch [--shape] or --all")

    failures = []
    for arch, shape in todo:
        try:
            run_cell(arch, shape, multi_pod=args.multi_pod, out_path=args.out)
        except Exception as e:  # report and continue
            failures.append((arch, shape, repr(e)))
            traceback.print_exc()
            _append(args.out, {
                "cell": f"{arch}/{shape}",
                "arch": arch, "shape": shape,
                "mesh": [2, 16, 16] if args.multi_pod else [16, 16],
                "ok": False, "error": repr(e),
            })
    if failures:
        print("FAILED cells:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"dry-run OK for {len(todo)} cell(s)")


if __name__ == "__main__":
    main()
