"""Serving launcher: bi-metric search with model-backed metrics.

``python -m repro.launch.serve --corpus 512 --quota 48``

Builds the cheap/expensive towers (smoke sizes by default), indexes a
synthetic token corpus with the cheap tower only, then serves batched
queries under an exact expensive-model call budget, comparing the paper's
two-stage search against the re-rank baseline.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import bimetric_paper, qwen3_0_6b
from repro.serve.engine import BiMetricEngine, EmbedTower, SearchRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--corpus", type=int, default=256)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--quota", type=int, default=32)
    ap.add_argument("--seq", type=int, default=16)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    cheap_cfg = qwen3_0_6b.smoke()
    exp_cfg = bimetric_paper.cheap_tower_smoke()  # stand-in big tower (CPU)
    exp_cfg = exp_cfg.__class__(**{**exp_cfg.__dict__, "n_layers": 4,
                                   "d_model": 128, "n_heads": 8,
                                   "n_kv_heads": 8, "head_dim": 16,
                                   "d_ff": 256, "embed_dim": 64,
                                   "name": "expensive-smoke"})
    from repro.models import transformer as T

    cheap = EmbedTower(T.init_params(key, cheap_cfg), cheap_cfg)
    expensive = EmbedTower(T.init_params(jax.random.fold_in(key, 1), exp_cfg),
                           exp_cfg)
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, cheap_cfg.vocab, (args.corpus, args.seq),
                          dtype=np.int32)
    t0 = time.time()
    engine = BiMetricEngine(cheap, expensive, corpus)
    print(f"indexed {args.corpus} docs with the cheap tower in "
          f"{time.time()-t0:.1f}s (zero expensive calls)")

    # ground truth under D for evaluation
    emb_D = expensive.embed(corpus)
    for qi in range(args.queries):
        q = corpus[rng.integers(0, args.corpus)].copy()
        q[: args.seq // 2] = rng.integers(0, cheap_cfg.vocab, args.seq // 2)
        q_emb = expensive.embed(q[None])[0]
        true10 = np.argsort(np.linalg.norm(emb_D - q_emb, axis=1))[:10]
        res = engine.query(SearchRequest(tokens=q, quota=args.quota))
        ids_r, _, st_r = engine.rerank_query(q, quota=args.quota)
        rec_b = len(set(res.ids) & set(true10)) / 10
        rec_r = len(set(ids_r) & set(true10)) / 10
        print(f"q{qi}: bimetric recall@10={rec_b:.2f} "
              f"(D calls {res.stats.D_calls}) "
              f"| rerank recall@10={rec_r:.2f} (D calls {st_r.D_calls})")


if __name__ == "__main__":
    main()
