"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the leading "pod" axis
carries data parallelism (or pipeline stages, see distributed/pipeline.py)
across the DCN boundary.

Defined as functions so importing this module never touches jax device
state (required for the dry-run's forced host-device count to win).
"""
from __future__ import annotations

import jax


def axis_types_kw(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where the installed jax has AxisType.

    Older jaxlibs (< 0.5) predate ``jax.sharding.AxisType``; meshes there
    are implicitly Auto, so omitting the kwarg is the exact equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, axes):
    """Version-portable ``jax.make_mesh`` (always Auto axis types)."""
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def axis_size(axis_name) -> int:
    """Version-portable ``jax.lax.axis_size`` (static size of a named axis).

    Older jax spells it ``jax.core.axis_frame(name)`` (which returns the
    size directly, or a frame object with ``.size`` on some releases).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return getattr(frame, "size", frame)


def shard_map(f, *, mesh, in_specs, out_specs):
    """Version-portable ``jax.shard_map`` with replication checking off.

    Newer jax exposes ``jax.shard_map(..., check_vma=...)``; older releases
    have ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    n_model = min(n_model, n)
    n_data = max(1, min(n_data, n // n_model))
    return make_mesh((n_data, n_model), ("data", "model"))
