"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the leading "pod" axis
carries data parallelism (or pipeline stages, see distributed/pipeline.py)
across the DCN boundary.

Defined as functions so importing this module never touches jax device
state (required for the dry-run's forced host-device count to win).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh over whatever local devices exist (tests/examples)."""
    n = len(jax.devices())
    n_model = min(n_model, n)
    n_data = max(1, min(n_data, n // n_model))
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
