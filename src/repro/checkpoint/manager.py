"""Fault-tolerant checkpointing: atomic, async, elastic-reshard on restore.

Layout (one directory per step):

    <dir>/step_000042.tmp/   — written first
        manifest.json        — step, config hash, mesh shape, leaf index
        arrays.npz           — all leaves, keyed by flattened tree path
    <dir>/step_000042/       — atomic rename after fsync (crash-safe commit)

Restore is *mesh-agnostic*: leaves are loaded as host arrays and re-placed
with whatever shardings the (possibly different) current mesh dictates —
this is the elastic-scaling path: a job checkpointed on N pods restarts on
M pods by re-sharding the same logical arrays. Async saves run on a worker
thread so the step loop never blocks on I/O.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def config_hash(obj: Any) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 config: Any = None):
        self.directory = directory
        self.keep = keep
        self.config_hash = config_hash(config) if config is not None else None
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------------ save
    def _write(self, step: int, host_leaves: dict[str, np.ndarray],
               extra: dict) -> None:
        try:
            name = f"step_{step:08d}"
            tmp = os.path.join(self.directory, name + ".tmp")
            final = os.path.join(self.directory, name)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **host_leaves)
            manifest = {
                "step": step,
                "config_hash": self.config_hash,
                "leaves": sorted(host_leaves.keys()),
                **extra,
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._gc()
        except BaseException as e:  # surfaced on next wait()
            self._error = e

    def save(self, step: int, tree: Pytree, *, extra: dict | None = None,
             async_: bool = True) -> None:
        self.wait()
        leaves_p = jax.tree_util.tree_flatten_with_path(tree)[0]
        host = {}
        for path, leaf in leaves_p:
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype.name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
                arr = arr.astype(np.float32)  # lossless widening for npz
            host[_path_str(path)] = arr
        if async_:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host, extra or {})
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Pytree, *, step: int | None = None,
                sharding_for: Callable[[str, np.ndarray], Any] | None = None,
                strict_config: bool = True) -> tuple[Pytree, dict]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``sharding_for(path, array)`` may return a
        Sharding to place each leaf on the *current* mesh (elastic reshard);
        default is plain device_put.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if strict_config and self.config_hash and manifest.get("config_hash"):
            if manifest["config_hash"] != self.config_hash:
                raise ValueError(
                    "checkpoint config hash mismatch: "
                    f"{manifest['config_hash']} != {self.config_hash}"
                )
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path, leaf in leaves_p:
            key = _path_str(path)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            want_dtype = leaf.dtype
            if arr.shape != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != expected {leaf.shape}"
                )
            arr = jnp.asarray(arr).astype(want_dtype)
            if sharding_for is not None:
                out.append(jax.device_put(arr, sharding_for(key, arr)))
            else:
                out.append(jnp.asarray(arr))
        return treedef.unflatten(out), manifest
