"""AST contract lint: source-level rules the runtime can't observe.

Three rules, all scoped to ``src/repro`` (tests and benchmarks may
exercise the public shims deliberately); the kernel dispatch layer
(``src/repro/kernels/``) is the shim itself and is exempt:

* **retired-kwarg** — the boolean knobs the unified ``backend=`` replaced
  (``use_pallas`` / ``use_fused_merge`` / ``interpret``) may appear at a
  call site only when funneled into ``resolve_backend`` (the deprecation
  shim). Anywhere else they are a reintroduction of the retired API.
* **quantize-flow** — ``quantize=`` may flow only into the residency
  funnels (``resolve_backend`` / ``as_corpus_view`` /
  ``shard_corpus_view``). The bi-metric contract strips quantization
  before stage 2; a ``quantize=`` kwarg on any other internal call is a
  path for the lossy proxy to reach a ground-truth call site. A literal
  ``quantize=None`` is always legal — it *strips* residency (what the
  stage-2 boundary does), it cannot introduce it.
* **raw-knob-literal** — internal call sites pass resolved knobs, not raw
  ``backend="..."`` / ``dedup="..."`` string literals; a literal is legal
  only as the argument of ``resolve_backend`` / ``resolve_dedup`` (public
  entry-point *defaults* live in ``def`` signatures, which this rule does
  not touch).

Run as ``python -m repro.analysis.astlint [paths...]`` — what
``scripts/ci.sh --lint-contracts`` does.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import sys
from typing import Iterable

RETIRED_KWARGS = frozenset({"use_pallas", "use_fused_merge", "interpret"})
RESOLVE_FUNNELS = frozenset({"resolve_backend"})
QUANTIZE_FUNNELS = frozenset(
    {"resolve_backend", "as_corpus_view", "shard_corpus_view"})
KNOB_FUNNELS = frozenset({"resolve_backend", "resolve_dedup"})
#: path fragments of the shim layer — the dispatch code that *implements*
#: the knobs is allowed to plumb them; the analysis registry's probe
#: fixtures exercise literal knob grids deliberately, like tests
SHIM_PATH_PARTS = ("repro/kernels/", "repro/analysis/registry.py")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _callee_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


def _is_shim(path: str) -> bool:
    norm = path.replace("\\", "/")
    return any(part in norm for part in SHIM_PATH_PARTS)


def lint_source(source: str, path: str = "<string>") -> list[Violation]:
    """Lint one module's source; ``path`` scopes the shim exemption."""
    if _is_shim(path):
        return []
    tree = ast.parse(source, filename=path)
    out: list[Violation] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = _callee_name(node)
        for kw in node.keywords:
            if kw.arg in RETIRED_KWARGS and callee not in RESOLVE_FUNNELS:
                out.append(Violation(
                    path, kw.value.lineno, "retired-kwarg",
                    f"`{kw.arg}=` at a call to `{callee or '<expr>'}` — the "
                    "boolean knobs are retired; pass `backend=` (or funnel "
                    "through resolve_backend)"))
            elif (kw.arg == "quantize" and callee not in QUANTIZE_FUNNELS
                  and not (isinstance(kw.value, ast.Constant)
                           and kw.value.value is None)):
                out.append(Violation(
                    path, kw.value.lineno, "quantize-flow",
                    f"`quantize=` at a call to `{callee or '<expr>'}` — "
                    "residency may only enter via resolve_backend/"
                    "as_corpus_view/shard_corpus_view; stage-2 call sites "
                    "must never see the lossy proxy"))
            elif (kw.arg in ("backend", "dedup")
                  and isinstance(kw.value, ast.Constant)
                  and isinstance(kw.value.value, str)
                  and callee not in KNOB_FUNNELS):
                out.append(Violation(
                    path, kw.value.lineno, "raw-knob-literal",
                    f"`{kw.arg}={kw.value.value!r}` literal at a call to "
                    f"`{callee or '<expr>'}` — resolve the knob "
                    "(resolve_backend/resolve_dedup) and pass the result"))
    return out


def lint_paths(paths: Iterable[str]) -> list[Violation]:
    out: list[Violation] = []
    for root in paths:
        p = pathlib.Path(root)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            out.extend(lint_source(f.read_text(), str(f)))
    return out


def main(argv: list[str] | None = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    paths = args or ["src/repro"]
    violations = lint_paths(paths)
    for v in violations:
        print(v)
    n_files = sum(
        len(sorted(pathlib.Path(p).rglob("*.py"))) if pathlib.Path(p).is_dir()
        else 1 for p in paths)
    status = "FAIL" if violations else "OK"
    print(f"astlint: {n_files} file(s), {len(violations)} violation(s) "
          f"[{status}]")
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
