"""Retrace auditor: budget knobs stay operands, statics stay bucketed.

The serving path's compile-cost contract: per-request values (quota,
beam_width, max_steps, expand_width — all ``(B,)`` vectors) ride as jit
*operands*, so heterogeneous requests share one program; the only statics
are shape-class knobs with deliberately bounded value sets (pow2
``set_capacity`` buckets, ``expand_cap``, the dedup backend name, the
frozen ``Backend``). The regression this audits: a kwarg silently
becoming per-request-static, turning every distinct request into a fresh
trace + XLA compile.

The audit is behavioral, not structural: drive the *real* jitted entry
point over a representative input grid and measure how much its trace
cache grew. Registered programs declare the grid and the bound
(:mod:`repro.analysis.registry`).
"""
from __future__ import annotations

import dataclasses
from typing import Callable


def jit_cache_size(jitted) -> int:
    """Compiled-program cache entries of a ``jax.jit`` callable."""
    return jitted._cache_size()


@dataclasses.dataclass(frozen=True)
class RetraceReport:
    name: str
    traces: int  # cache growth observed while running the grid
    bound: int  # the program's declared maximum
    grid_points: int

    @property
    def ok(self) -> bool:
        return self.traces <= self.bound


def audit_retrace(
    name: str,
    run_grid: Callable[[], int],
    count: Callable[[], int],
    bound: int,
) -> RetraceReport:
    """Run ``run_grid`` (returns #points driven) and bound the cache delta.

    ``count`` reads the program's current trace count — for a plain jitted
    function :func:`jit_cache_size`; for a :class:`ShardedStepper`, the sum
    of cache sizes over its ``_programs`` plus the key count (each key is
    itself one trace family). Counting the *delta* keeps the audit correct
    when several registered programs share one module-level jitted entry.
    """
    before = count()
    points = run_grid()
    traces = count() - before
    return RetraceReport(name=name, traces=traces, bound=bound,
                         grid_points=points)


def stepper_trace_count(stepper) -> int:
    """Trace count of a ``ShardedStepper``: cached program keys × their
    inner jit-cache sizes (a program that retraces per call shows up here
    even though the key set stays fixed)."""
    return sum(jit_cache_size(p) for p in stepper._programs.values())
