"""The program registry: every hot jitted entry point, with its contract.

Each :class:`Program` names a real entry point, a representative input
grid (mixed per-query quotas, both dedup backends, pow2 capacity
buckets, shard counts — the shapes production traffic actually takes),
and the declared invariants the checkers gate:

* ``retrace_bound`` — max trace-cache growth over the grid (the pow2 /
  static-knob budget; one extra trace per *request* blows well past it);
* dtype allowlists — the sanctioned f32 ordering-view widenings;
* donation declarations — ``donate_argnums`` that must land in the
  compiled ``input_output_alias`` table;
* while-carry shapes — fused-loop buffers that must alias in place.

Programs needing more devices than the host exposes (``min_devices``)
are skipped by the runner; the CI ``analysis`` lane forces 8 host
devices so they always run there.

Bounds are measured on the committed grids and deliberately exact-ish:
slack hides regressions. If a legitimate new static (a new capacity
bucket, a new dedup route) raises a bound, raise it *in the same PR*
with a comment saying which static grew.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.analysis.retrace import jit_cache_size, stepper_trace_count

Array = jax.Array


@dataclasses.dataclass
class Probe:
    """What a built program hands the checkers (see the runner)."""

    run_grid: Callable[[], int]  # drive every grid point; return the count
    count: Callable[[], int]  # current trace count of the entry point(s)
    # dtype-flow checks: (label, fn, args, allow, expect_out_dtypes)
    dtype_checks: list[tuple] = dataclasses.field(default_factory=list)
    # donation check: (jitted, args, donate_argnums)
    donation: tuple | None = None
    # double-donation scan: (args, donate_argnums)
    double_donation: tuple | None = None
    # while-carry check: (fn, args, carry_shape)
    while_carry: tuple | None = None


@dataclasses.dataclass(frozen=True)
class Program:
    name: str
    retrace_bound: int
    build: Callable[[], Probe]
    min_devices: int = 1
    notes: str = ""


# ---------------------------------------------------------------------------
# shared small fixtures (deterministic, no PRNG: probes must be replayable)
# ---------------------------------------------------------------------------
_N, _D, _B, _R = 64, 8, 4, 4


def _corpus() -> Array:
    return jnp.sin(jnp.arange(_N * _D, dtype=jnp.float32)).reshape(_N, _D)


def _adjacency() -> Array:
    return ((jnp.arange(_N)[:, None] + jnp.arange(1, _R + 1)[None, :])
            % _N).astype(jnp.int32)


def _queries() -> Array:
    return jnp.cos(jnp.arange(_B * _D, dtype=jnp.float32)).reshape(_B, _D)


def _entries() -> Array:
    return jnp.broadcast_to(jnp.arange(2, dtype=jnp.int32)[None, :], (_B, 2))


#: the mixed-budget operand grid: none of these may retrace
_QUOTA_GRID = ((3, 5, 7, 9), (7, 7, 7, 7), (9, 2, 9, 2), (1, 1, 1, 1))
_BW_GRID = ((8, 8, 8, 8), (4, 8, 4, 8))


def _vec(vals) -> Array:
    return jnp.asarray(vals, jnp.int32)


# ---------------------------------------------------------------------------
# 1+2. the fused batched greedy search, both dedup backends
# ---------------------------------------------------------------------------
def _build_batched_greedy(dedup: str) -> Probe:
    from repro.core import beam

    corpus, adj = _corpus(), _adjacency()
    dist_fn = beam.fused_dist_fn(corpus, "sqeuclidean", backend="ref")

    @functools.partial(jax.jit, static_argnames=("set_capacity",))
    def search(q, entries, quota, bw, ms, *, set_capacity=None):
        r = beam.batched_greedy_search(
            dist_fn, adj, q, entries, n_points=_N, beam_width=bw,
            pool_size=8, quota=quota, max_steps=ms, dedup=dedup,
            set_capacity=set_capacity)
        return r.pool_ids, r.pool_dists, r.n_calls

    caps = (8, 16) if dedup == "sorted" else (None,)

    def run_grid() -> int:
        pts = 0
        for cap in caps:
            for quota in _QUOTA_GRID:
                for bw in _BW_GRID:
                    search(_queries(), _entries(), _vec(quota), _vec(bw),
                           _vec((12, 12, 12, 12)), set_capacity=cap)
                    pts += 1
        return pts

    probe = Probe(run_grid=run_grid, count=lambda: jit_cache_size(search))
    # the whole fused loop is f32 end-to-end: zero widenings allowed
    probe.dtype_checks.append((
        "fused-loop", lambda q, e, quota: search(
            q, e, quota, _vec(_BW_GRID[0]), _vec((12,) * _B),
            set_capacity=caps[0]),
        (_queries(), _entries(), _vec(_QUOTA_GRID[0])), {}, None))
    if dedup == "bitmap":
        # the dedup bitmap is the while carry XLA must alias in place
        probe.while_carry = (
            lambda q, e, quota: search(
                q, e, quota, _vec(_BW_GRID[0]), _vec((12,) * _B)),
            (_queries(), _entries(), _vec(_QUOTA_GRID[0])),
            f"pred[{_B},{_N}]")
    return probe


# ---------------------------------------------------------------------------
# 3. the serve engine's host-driven stage-2 stack (module-level jitted fns)
# ---------------------------------------------------------------------------
def _build_serve_stage2() -> Probe:
    from repro.core import beam
    from repro.kernels import backend as kernel_backend
    from repro.serve import engine as E

    be = kernel_backend.resolve_backend("ref")
    adj = _adjacency()
    entry_fns = (E._init_j, E._plan_step_j, E._commit_j, E._active_j,
                 E._active_any_j)

    # dedup/capacity configs: bitmap + two pow2 sorted buckets
    configs = (("bitmap", None), ("sorted", 8), ("sorted", 16))

    def drive(dedup, cap, quota, bw) -> None:
        state, safe, keep = E._init_j(
            _entries(), _vec(quota), n_points=_N, pool_size=8,
            dedup=dedup, set_capacity=cap)
        ms = _vec((12,) * _B)
        dists = jnp.where(safe >= 0, jnp.abs(safe).astype(jnp.float32),
                          jnp.inf)
        state = E._commit_j(state, safe, keep, dists, backend=be)
        for _ in range(2):
            state, safe, keep, _w = E._plan_step_j(
                state, adj, _vec(quota), _vec(bw), ms, _vec((1,) * _B),
                expand_cap=1)
            dists = jnp.where(safe >= 0, jnp.abs(safe).astype(jnp.float32),
                              jnp.inf)
            state = E._commit_j(state, safe, keep, dists, backend=be)
        E._active_j(state, _vec(quota), _vec(bw), ms)
        E._active_any_j(state, _vec(quota), _vec(bw), ms)

    def run_grid() -> int:
        pts = 0
        for dedup, cap in configs:
            for quota in _QUOTA_GRID:
                for bw in _BW_GRID:
                    drive(dedup, cap, quota, bw)
                    pts += 1
        return pts

    return Probe(
        run_grid=run_grid,
        count=lambda: sum(jit_cache_size(f) for f in entry_fns))


# ---------------------------------------------------------------------------
# 4. the sharded mesh path (needs forced host devices). The eager
# sharded_greedy_search entry builds its shard_map program per call (no
# introspectable cache), so the *countable* retrace contract of the mesh
# path is audited through ShardedStepper at shards {2, 4}; the eager entry
# rides the same grid as a crash canary at shards {1, 2, 4}.
# ---------------------------------------------------------------------------
def _build_sharded_mesh() -> Probe:
    from repro.core import beam

    corpus, adj = _corpus(), _adjacency()
    steppers = {s: beam.ShardedStepper(shards=s, n_points=_N, backend="ref")
                for s in (2, 4)}

    def drive(stepper, quota) -> None:
        state, safe, keep = stepper.init(
            _entries(), _vec(quota), pool_size=8, dedup="bitmap")
        ms = _vec((12,) * _B)
        dists = jnp.where(safe >= 0, jnp.abs(safe).astype(jnp.float32),
                          jnp.inf)
        state = stepper.commit(state, safe, keep, dists)
        state, safe, keep, _w = stepper.plan(
            state, adj, _vec(quota), _vec(_BW_GRID[0]), ms)
        stepper.active(state, _vec(quota), _vec(_BW_GRID[0]), ms)

    def run_grid() -> int:
        pts = 0
        for stepper in steppers.values():
            for quota in _QUOTA_GRID:
                drive(stepper, quota)
                pts += 1
        for s in (1, 2, 4):
            for quota in _QUOTA_GRID[:2]:
                beam.sharded_greedy_search(
                    corpus, adj, _queries(), _entries(), shards=s,
                    beam_width=8, pool_size=8, quota=_vec(quota),
                    max_steps=12, backend="ref", dedup="bitmap")
                pts += 1
        return pts

    return Probe(
        run_grid=run_grid,
        count=lambda: sum(stepper_trace_count(s)
                          for s in steppers.values()))


# ---------------------------------------------------------------------------
# 5. ShardedStepper plan/commit (the serving mesh's stage-2 bookkeeping)
# ---------------------------------------------------------------------------
def _build_stepper(shards: int) -> Probe:
    from repro.core import beam

    adj = _adjacency()
    stepper = beam.ShardedStepper(shards=shards, n_points=_N, backend="ref")
    configs = (("bitmap", None), ("sorted", 8), ("sorted", 16))

    def drive(dedup, cap, quota, bw) -> None:
        state, safe, keep = stepper.init(
            _entries(), _vec(quota), pool_size=8, dedup=dedup,
            set_capacity=cap)
        ms = _vec((12,) * _B)
        dists = jnp.where(safe >= 0, jnp.abs(safe).astype(jnp.float32),
                          jnp.inf)
        state = stepper.commit(state, safe, keep, dists)
        state, safe, keep, _w = stepper.plan(
            state, adj, _vec(quota), _vec(bw), ms)
        dists = jnp.where(safe >= 0, jnp.abs(safe).astype(jnp.float32),
                          jnp.inf)
        state = stepper.commit(state, safe, keep, dists)
        stepper.active(state, _vec(quota), _vec(bw), ms)
        stepper.scored_count(state)

    def run_grid() -> int:
        pts = 0
        for dedup, cap in configs:
            for quota in _QUOTA_GRID:
                for bw in _BW_GRID:
                    drive(dedup, cap, quota, bw)
                    pts += 1
        return pts

    return Probe(run_grid=run_grid,
                 count=lambda: stepper_trace_count(stepper))


# ---------------------------------------------------------------------------
# 6. cover-tree level scan (fused per-level lax.scan programs)
# ---------------------------------------------------------------------------
def _build_covertree() -> Probe:
    import numpy as np

    from repro.core import beam, covertree

    rng = np.random.default_rng(0)
    pts = rng.standard_normal((40, 4)).astype(np.float32)
    tree = covertree.build(pts)
    flat = covertree.flatten(tree)
    corpus = jnp.asarray(pts)
    qs = jnp.asarray(rng.standard_normal((_B, 4)).astype(np.float32))
    dist_fn = beam.fused_dist_fn(corpus, "l2", backend="ref")
    entry_fns = (covertree._init_j, covertree._commit_j, covertree._plan_j,
                 covertree._reopen_j, covertree._count_j)

    # mixed quotas within one pow2 bucket (max 9..12 -> 16): operands only
    quota_grid = ((12, 9, 12, 9), (10, 10, 10, 10), (11, 12, 9, 10))

    def run_grid() -> int:
        n = 0
        for quota in quota_grid:
            covertree.search_batched(
                flat, dist_fn, qs, eps=0.5, k=4,
                quota=np.asarray(quota, np.int32), pool_size=8,
                backend="ref")
            n += 1
        return n

    def count() -> int:
        total = sum(jit_cache_size(f) for f in entry_fns)
        # _level_fused's statics include the (hashed-by-identity) dist_fn —
        # its cache growth is the pow2 n_chunks bucket count
        total += jit_cache_size(covertree._level_fused)
        return total

    return Probe(run_grid=run_grid, count=count)


# ---------------------------------------------------------------------------
# 7. the fused train step (donation + double-donation live here)
# ---------------------------------------------------------------------------
def _build_train_step() -> Probe:
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"] + params["b"]
        err = pred - batch["y"]
        return jnp.mean(err * err), {}

    params = {"w": jnp.ones((_D, 2), jnp.float32) * 0.01,
              "b": jnp.zeros((2,), jnp.float32)}
    tr = Trainer(loss_fn, params, AdamWConfig(), TrainerConfig(),
                 donate=True)

    def batch(i: int) -> dict:
        x = jnp.sin(jnp.arange(8 * _D, dtype=jnp.float32) + i).reshape(8, _D)
        return {"x": x, "y": jnp.cos(jnp.arange(16,
                                                dtype=jnp.float32)).reshape(8, 2)}

    def run_grid() -> int:
        p, o, ef = tr.params, tr.opt_state, tr.ef
        for i in range(3):
            p, o, ef, _loss, _stats = tr._train_step(p, o, ef, batch(i))
        return 3

    args = (tr.params, tr.opt_state, tr.ef, batch(0))
    return Probe(
        run_grid=run_grid,
        count=lambda: jit_cache_size(tr._train_step),
        donation=(tr._train_step, args, (0, 1, 2)),
        double_donation=(args, (0, 1, 2)),
    )


# ---------------------------------------------------------------------------
# 8-10. kernel merge/scoring dtype programs (the PR-5 upcast guard)
# ---------------------------------------------------------------------------
def _build_local_topk_bf16() -> Probe:
    from repro.kernels import ops

    ids = jnp.arange(_B * 16, dtype=jnp.int32).reshape(_B, 16)
    dists = jnp.sin(jnp.arange(_B * 16,
                               dtype=jnp.float32)).reshape(_B, 16)
    dists = dists.astype(jnp.bfloat16)
    fn = jax.jit(lambda i, d: ops.local_topk(i, d, 4))

    def run_grid() -> int:
        fn(ids, dists)
        fn(ids, dists * 2)
        return 2

    return Probe(
        run_grid=run_grid, count=lambda: jit_cache_size(fn),
        dtype_checks=[(
            "local_topk[bf16]", lambda i, d: ops.local_topk(i, d, 4),
            (ids, dists),
            # one sanctioned widening: the f32 *ordering view* of the keys
            {"bfloat16->float32": 1},
            (jnp.int32, jnp.bfloat16))])


def _build_merge_pool_bf16() -> Probe:
    from repro.kernels import ops

    pool_ids = jnp.arange(_B * 8, dtype=jnp.int32).reshape(_B, 8)
    pool_d = jnp.sin(jnp.arange(_B * 8, dtype=jnp.float32)
                     ).reshape(_B, 8).astype(jnp.bfloat16)
    expanded = jnp.zeros((_B, 8), bool)
    cand_ids = (pool_ids + 100).astype(jnp.int32)
    cand_d = (pool_d * 0.5).astype(jnp.bfloat16)
    fn = jax.jit(lambda pi, pd, ex, ci, cd: ops.merge_pool_batch(
        pi, pd, ex, ci, cd))

    def run_grid() -> int:
        fn(pool_ids, pool_d, expanded, cand_ids, cand_d)
        fn(pool_ids, pool_d * 2, expanded, cand_ids, cand_d)
        return 2

    return Probe(
        run_grid=run_grid, count=lambda: jit_cache_size(fn),
        dtype_checks=[(
            "merge_pool_batch[bf16]",
            lambda pi, pd, ex, ci, cd: ops.merge_pool_batch(
                pi, pd, ex, ci, cd),
            (pool_ids, pool_d, expanded, cand_ids, cand_d),
            {"bfloat16->float32": 1},
            (jnp.int32, jnp.bfloat16, None))])


def _build_wave_dists_bf16() -> Probe:
    from repro.serve import engine as E

    doc = jnp.sin(jnp.arange(_B * 8 * _D, dtype=jnp.float32)
                  ).reshape(_B, 8, _D).astype(jnp.bfloat16)
    q = jnp.cos(jnp.arange(_B * _D,
                           dtype=jnp.float32)).reshape(_B, _D).astype(
        jnp.bfloat16)

    def run_grid() -> int:
        E._wave_dists_j(doc, q)
        E._wave_dists_j(doc * 2, q)
        return 2

    return Probe(
        run_grid=run_grid,
        count=lambda: jit_cache_size(E._wave_dists_j),
        dtype_checks=[(
            "wave_dists[bf16-tower]",
            lambda d, qq: E._wave_dists_j(d, qq), (doc, q),
            # contractual upcasts: ground-truth distances are f32 — both
            # operands widen before the subtract
            {"bfloat16->float32": 2},
            (jnp.float32,))])


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------
REGISTRY: tuple[Program, ...] = (
    Program(
        name="beam.batched_greedy_search[bitmap]",
        retrace_bound=1,
        build=lambda: _build_batched_greedy("bitmap"),
        notes="all budget knobs (B,) operands; one trace over the grid; "
              "dedup-bitmap while-carry must alias"),
    Program(
        name="beam.batched_greedy_search[sorted]",
        retrace_bound=2,
        build=lambda: _build_batched_greedy("sorted"),
        notes="one trace per pow2 set_capacity bucket {8, 16}"),
    Program(
        name="serve.stage2[init/plan/commit/active]",
        retrace_bound=18,
        build=_build_serve_stage2,
        notes="3 dedup/cap configs x 5 entry points, plus commit_scores "
              "compiling once per wave width (entry wave (B,2) vs plan "
              "wave (B,4)); quotas/widths are operands"),
    Program(
        name="beam.sharded_mesh[shards=2,4]",
        retrace_bound=8,
        build=_build_sharded_mesh,
        min_devices=4,
        notes="stepper {init, commit, plan, active} keys per shard count, "
              "one trace each; eager sharded_greedy_search rides the grid "
              "at shards {1, 2, 4} as a crash canary"),
    Program(
        name="beam.ShardedStepper[shards=1]",
        retrace_bound=18,
        build=lambda: _build_stepper(1),
        notes="3 dedup/cap configs x {init, commit, plan, active, "
              "scored_count} program keys, one trace each"),
    Program(
        name="covertree.search_batched[fused-levels]",
        retrace_bound=9,
        build=_build_covertree,
        notes="per-level plan/commit + pow2 n_chunks buckets of "
              "_level_fused; quota vectors are operands"),
    Program(
        name="train.Trainer.step[donated]",
        retrace_bound=1,
        build=_build_train_step,
        notes="one trace across batches; params/opt/ef donation must "
              "alias; no donated leaf shared (double-donation guard)"),
    Program(
        name="kernels.local_topk[bf16]",
        retrace_bound=1,
        build=_build_local_topk_bf16,
        notes="single sanctioned bf16->f32 ordering-view widening"),
    Program(
        name="kernels.merge_pool_batch[bf16]",
        retrace_bound=1,
        build=_build_merge_pool_bf16,
        notes="single sanctioned bf16->f32 ordering-view widening"),
    Program(
        name="serve.wave_dists[bf16-tower]",
        retrace_bound=1,
        build=_build_wave_dists_bf16,
        notes="contractual f32 upcast of tower embeddings (ground-truth "
              "distances are f32)"),
)


def get(name: str) -> Program:
    for p in REGISTRY:
        if p.name == name:
            return p
    raise KeyError(name)
