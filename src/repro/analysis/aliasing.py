"""Donation/aliasing verifier over optimized HLO text.

A ``donate_argnums`` declaration is a *request*: jax forwards it to XLA,
and XLA either records the alias in the compiled module's
``input_output_alias`` table or silently drops it (shape/dtype mismatch,
the buffer still read after the donated output is written) — in which
case every step pays a full-size copy and the declaration is dead code.
This module checks the declaration against what the compiler actually
did, via :mod:`repro.launch.hlo_analysis`'s text parser:

* :func:`check_donation` — every donated leaf's flat parameter number
  appears as an alias source in the compiled module;
* :func:`detect_double_donation` — no two donated leaves share one
  device buffer (donating the same buffer twice is undefined; the
  optimizer's ``copy=True`` master-weight init exists to prevent it);
* :func:`check_while_carry` — a fused ``while_loop`` carry (the dedup
  bitmap) aliases in place: no per-step ``copy`` of that buffer inside
  the loop body.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax

from repro.launch import hlo_analysis as H


def _leaf_spans(args: Sequence[Any]) -> list[tuple[int, int]]:
    """Flat-parameter index range [start, stop) contributed by each arg."""
    spans, off = [], 0
    for a in args:
        n = len(jax.tree_util.tree_leaves(a))
        spans.append((off, off + n))
        off += n
    return spans


def donated_leaf_params(args: Sequence[Any],
                        donate_argnums: Sequence[int]) -> set[int]:
    """Flat XLA parameter numbers covered by ``donate_argnums``.

    jax flattens positional args to one leaf list in order; entry
    parameter N of the compiled module is leaf N of that list.
    """
    spans = _leaf_spans(args)
    out: set[int] = set()
    for i in donate_argnums:
        lo, hi = spans[i]
        out.update(range(lo, hi))
    return out


@dataclasses.dataclass(frozen=True)
class DonationReport:
    name: str
    donated: tuple[int, ...]  # flat param numbers declared donated
    aliased: tuple[int, ...]  # flat param numbers XLA aliased
    missing: tuple[int, ...]  # declared but NOT aliased: silent copies

    @property
    def ok(self) -> bool:
        return not self.missing


def check_donation(
    fn: Callable,
    args: Sequence[Any],
    donate_argnums: Sequence[int],
    *,
    jitted: Callable | None = None,
    name: str = "",
) -> DonationReport:
    """Compile and verify that every donated leaf aliases an output.

    ``jitted`` passes a pre-built jit wrapper (e.g. a Trainer's fused
    step) that already carries the donation declaration; otherwise ``fn``
    is wrapped here. Lowering/compiling does not consume the example
    buffers — only a real call would.
    """
    j = jitted if jitted is not None else jax.jit(
        fn, donate_argnums=tuple(donate_argnums))
    text = j.lower(*args).compile().as_text()
    aliased = {e.param_number for e in H.parse_input_output_alias(text)}
    donated = donated_leaf_params(args, donate_argnums)
    return DonationReport(
        name=name,
        donated=tuple(sorted(donated)),
        aliased=tuple(sorted(aliased)),
        missing=tuple(sorted(donated - aliased)),
    )


def _buffer_key(leaf: Any):
    try:
        return leaf.unsafe_buffer_pointer()
    except Exception:
        return id(leaf)


def detect_double_donation(args: Sequence[Any],
                           donate_argnums: Sequence[int]) -> list[tuple]:
    """Donated leaves that share one device buffer.

    Returns ``(flat_param_a, flat_param_b)`` pairs (a < b). A non-empty
    result means the same buffer would be handed to XLA as two distinct
    donations — exactly what a no-op ``astype`` aliasing the param buffer
    into the optimizer's master weights would cause.
    """
    spans = _leaf_spans(args)
    seen: dict[Any, int] = {}
    dupes: list[tuple] = []
    for i in donate_argnums:
        lo, _hi = spans[i]
        for k, leaf in enumerate(jax.tree_util.tree_leaves(args[i])):
            key = _buffer_key(leaf)
            if key in seen:
                dupes.append((seen[key], lo + k))
            else:
                seen[key] = lo + k
    return dupes


@dataclasses.dataclass(frozen=True)
class WhileCarryReport:
    name: str
    carry_shape: str
    copies: tuple[str, ...]  # raw copy instrs of that shape in loop bodies

    @property
    def ok(self) -> bool:
        return not self.copies


def check_while_carry(
    fn_or_text: Callable | str,
    args: Sequence[Any] = (),
    *,
    carry_shape: str,
    name: str = "",
) -> WhileCarryReport:
    """Assert a while-carry buffer aliases in place across loop steps.

    ``carry_shape`` is the HLO type prefix of the carried buffer (e.g.
    ``"pred[4,64]"`` for a (B=4, N=64) dedup bitmap). Accepts either a
    callable to compile against ``args`` or pre-compiled HLO text.
    """
    if callable(fn_or_text):
        text = jax.jit(fn_or_text).lower(*args).compile().as_text()
    else:
        text = fn_or_text
    copies = H.while_body_copies(text, result_type_prefix=carry_shape)
    return WhileCarryReport(
        name=name, carry_shape=carry_shape,
        copies=tuple(c.raw.strip() for c in copies))
