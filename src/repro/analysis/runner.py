"""Drive the four checkers over the program registry; produce verdicts.

``run_registry()`` is what both the CI ``analysis`` lane
(``scripts/run_analysis.py``) and ``tests/test_analysis.py`` call: for
every registered program, build its probe, audit retraces over the grid,
lint the dtype flow, and verify donation/aliasing — skipping programs
whose ``min_devices`` exceeds the host's.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.analysis.aliasing import (
    DonationReport,
    WhileCarryReport,
    check_donation,
    check_while_carry,
    detect_double_donation,
)
from repro.analysis.dtypeflow import DtypeReport, check_dtype_flow
from repro.analysis.registry import REGISTRY, Program
from repro.analysis.retrace import RetraceReport, audit_retrace


@dataclasses.dataclass
class Verdict:
    program: str
    skipped: str | None = None  # reason, when not run
    retrace: RetraceReport | None = None
    dtype: list[DtypeReport] = dataclasses.field(default_factory=list)
    donation: DonationReport | None = None
    double_donation: list[tuple] | None = None  # offending pairs
    while_carry: WhileCarryReport | None = None

    @property
    def ok(self) -> bool:
        if self.skipped is not None:
            return True
        checks = [self.retrace is None or self.retrace.ok,
                  all(d.ok for d in self.dtype),
                  self.donation is None or self.donation.ok,
                  not self.double_donation,
                  self.while_carry is None or self.while_carry.ok]
        return all(checks)

    def failures(self) -> list[str]:
        out = []
        if self.skipped is not None:
            return out
        if self.retrace is not None and not self.retrace.ok:
            out.append(f"retrace: {self.retrace.traces} traces over "
                       f"{self.retrace.grid_points} grid points, bound "
                       f"{self.retrace.bound}")
        for d in self.dtype:
            out.extend(f"dtype[{d.name}]: {v}" for v in d.violations)
        if self.donation is not None and not self.donation.ok:
            out.append(
                f"donation: params {list(self.donation.missing)} declared "
                "donated but absent from input_output_alias (silent copy)")
        if self.double_donation:
            out.append(f"double-donation: leaf pairs "
                       f"{self.double_donation} share one buffer")
        if self.while_carry is not None and not self.while_carry.ok:
            out.append(
                f"while-carry: {len(self.while_carry.copies)} per-step "
                f"copy(s) of {self.while_carry.carry_shape} in the loop "
                "body")
        return out


def run_program(prog: Program) -> Verdict:
    if jax.local_device_count() < prog.min_devices:
        return Verdict(
            program=prog.name,
            skipped=f"needs {prog.min_devices} devices, host has "
                    f"{jax.local_device_count()}")
    probe = prog.build()
    v = Verdict(program=prog.name)
    v.retrace = audit_retrace(prog.name, probe.run_grid, probe.count,
                              prog.retrace_bound)
    for label, fn, args, allow, expect in probe.dtype_checks:
        v.dtype.append(check_dtype_flow(
            fn, args, allow=allow, expect_out_dtypes=expect, name=label))
    if probe.donation is not None:
        jitted, args, nums = probe.donation
        v.donation = check_donation(jitted, args, nums, jitted=jitted,
                                    name=prog.name)
    if probe.double_donation is not None:
        args, nums = probe.double_donation
        v.double_donation = detect_double_donation(args, nums)
    if probe.while_carry is not None:
        fn, args, shape = probe.while_carry
        v.while_carry = check_while_carry(fn, args, carry_shape=shape,
                                          name=prog.name)
    return v


def run_registry(names: list[str] | None = None) -> list[Verdict]:
    progs = REGISTRY if names is None else [
        p for p in REGISTRY if p.name in names]
    return [run_program(p) for p in progs]
