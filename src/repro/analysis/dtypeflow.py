"""Jaxpr dtype-flow lint: no unsanctioned float widening.

The kernel merges (``local_topk``, ``merge_pool_batch``,
``beam_merge_topk``) order by an f32 *view* of the distance keys — a
deliberate, counted ``convert_element_type`` — but payloads and returned
dists must stay in the storage dtype. PR 5 shipped (and reverted) a
merge that upcast the values themselves; this lint walks a program's
closed jaxpr, counts every widening convert (bf16/f16 → f32/f64,
f32 → f64), and fails when a widening is not covered by the program's
allowlist or an output dtype drifts from the declared contract.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

# in-dtype -> the set of dtypes that count as a *widening* of it
_WIDENINGS = {
    "bfloat16": {"float32", "float64"},
    "float16": {"float32", "float64"},
    "float32": {"float64"},
}


def _sub_jaxprs(params: Mapping[str, Any]):
    """Sub-jaxprs hiding in an eqn's params (pjit/scan/while/cond/...)."""
    for v in params.values():
        items = v if isinstance(v, (tuple, list)) else (v,)
        for x in items:
            if hasattr(x, "eqns") or hasattr(x, "jaxpr"):
                yield x  # Jaxpr or ClosedJaxpr (unwrapped by the caller)


def widening_events(jaxpr) -> list[tuple[str, str]]:
    """All float-widening converts in ``jaxpr`` (recursing into subjaxprs).

    Returns ``(tag, context)`` pairs where ``tag`` is
    ``"<in_dtype>-><out_dtype>"`` (numpy dtype names, e.g.
    ``"bfloat16->float32"``) and ``context`` is the eqn rendered as text.
    """
    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    events: list[tuple[str, str]] = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "convert_element_type":
            aval = getattr(eqn.invars[0], "aval", None)
            src = getattr(aval, "dtype", None)
            dst = eqn.params.get("new_dtype")
            if src is not None and dst is not None:
                src_n = jnp.dtype(src).name
                dst_n = jnp.dtype(dst).name
                if dst_n in _WIDENINGS.get(src_n, ()):
                    events.append((f"{src_n}->{dst_n}", str(eqn)))
        for sub in _sub_jaxprs(eqn.params):
            events.extend(widening_events(sub))
    return events


@dataclasses.dataclass(frozen=True)
class DtypeReport:
    name: str
    counts: dict[str, int]  # widening tag -> occurrences
    allow: dict[str, int]  # tag -> max sanctioned occurrences
    violations: list[str]
    out_dtypes: tuple[str, ...]

    @property
    def ok(self) -> bool:
        return not self.violations


def check_dtype_flow(
    fn: Callable,
    args: Sequence[Any],
    *,
    allow: Mapping[str, int] | None = None,
    expect_out_dtypes: Sequence[Any | None] | None = None,
    name: str = "",
) -> DtypeReport:
    """Trace ``fn(*args)`` and lint its widening converts.

    ``allow`` maps widening tags to the number of *sanctioned* occurrences
    (the ordering-view converts); any tag beyond its allowance — or absent
    from the allowlist entirely — is a violation. ``expect_out_dtypes``
    optionally pins output dtypes positionally (None entries skip).
    """
    allow = dict(allow or {})
    closed = jax.make_jaxpr(fn)(*args)
    counts = Counter(tag for tag, _ in widening_events(closed))
    violations = [
        f"{tag}: {n} widening convert(s), allowlist permits "
        f"{allow.get(tag, 0)}"
        for tag, n in sorted(counts.items()) if n > allow.get(tag, 0)
    ]
    out_dtypes = tuple(jnp.dtype(a.dtype).name for a in closed.out_avals
                       if hasattr(a, "dtype"))
    if expect_out_dtypes is not None:
        for i, want in enumerate(expect_out_dtypes):
            if want is None:
                continue
            want_n = jnp.dtype(want).name
            got = out_dtypes[i] if i < len(out_dtypes) else "<missing>"
            if got != want_n:
                violations.append(
                    f"output[{i}] dtype {got}, contract says {want_n}")
    return DtypeReport(name=name, counts=dict(counts), allow=allow,
                       violations=violations, out_dtypes=out_dtypes)
