"""Program-contract analyzers: the repo's load-bearing invariants, CI-gated.

The hot paths earn their guarantees from properties that neither unit
tests nor type checkers see — what is a jit *operand* vs a *static*, which
dtypes flow through a merge, which buffers XLA actually aliased. This
package checks those properties on the artifacts where they are decided
(traced jaxprs, optimized HLO text, the AST) against declarations a
program makes when it registers in :mod:`repro.analysis.registry`.

**The four checkers** (one module each):

* **retrace audit** (:mod:`.retrace`) — every budget knob on the serving
  path (quota / beam_width / max_steps / expand_width) is a per-query
  ``(B,)`` *operand*, and the only statics are shape-class knobs whose
  values are deliberately bucketed (pow2 ``set_capacity``, ``expand_cap``,
  the dedup backend, the frozen ``Backend``). A registered program
  declares a trace bound; the auditor drives a representative input grid
  (mixed quotas, both dedup backends, capacity buckets, shard counts)
  through the *real* jitted entry point and fails if the trace-cache grew
  past the bound — the regression where a kwarg silently becomes
  per-request-static and every request compiles.

* **dtype-flow lint** (:mod:`.dtypeflow`) — no ``convert_element_type``
  *widening* (bf16/f16 → f32/f64) in a program's jaxpr beyond its
  explicit allowlist, and output dtypes stay what the contract says.
  Kernel merges order by an f32 *view* of the keys but must carry
  payloads (and return dists) in the storage dtype — the PR-5 upcast bug
  class. Allowlist entries name the sanctioned widenings (e.g. the
  ordering view), so a new one is a lint failure, not a silent copy.

* **donation/aliasing verify** (:mod:`.aliasing`) — every
  ``donate_argnums`` declaration actually lands in the compiled module's
  ``input_output_alias`` table (a dropped donation is a silent full-size
  copy per step), no two donated leaves share one buffer (double
  donation — the hazard the optimizer's ``copy=True`` master-weight init
  guards), and the fused ``while_loop``'s dedup-bitmap carry aliases in
  place: no per-step ``copy`` of the bitmap inside the loop body. Built
  on :mod:`repro.launch.hlo_analysis`'s HLO-text parser.

* **AST contract lint** (:mod:`.astlint`, ``scripts/ci.sh
  --lint-contracts``) — source-level rules the runtime can't see: the
  retired boolean kwargs (``use_pallas`` / ``use_fused_merge`` /
  ``interpret``) appear only inside the kernel shim layer or funneled
  into ``resolve_backend``; ``quantize=`` flows only into the sanctioned
  residency funnels (``resolve_backend`` / ``as_corpus_view`` /
  ``shard_corpus_view``) so the lossy proxy can never reach a
  stage-2/ground-truth call site (the paper's bi-metric contract); and
  internal call sites pass resolved knobs, not raw ``backend=``/
  ``dedup=`` string literals.

**Registering a program** (see :mod:`.registry`): add a
:class:`~repro.analysis.registry.Program` with a ``build()`` returning a
:class:`~repro.analysis.registry.Probe` — the real jitted entry point,
its input grid, a trace counter, and optional dtype/donation/while-carry
declarations. ``scripts/run_analysis.py`` (the CI ``analysis`` lane) and
``tests/test_analysis.py`` both run the full registry; a program that
needs more devices than the host has (``min_devices``) is skipped there
and exercised in the multi-device lane.
"""
from repro.analysis.aliasing import (  # noqa: F401
    DonationReport,
    WhileCarryReport,
    check_donation,
    check_while_carry,
    detect_double_donation,
    donated_leaf_params,
)
from repro.analysis.astlint import Violation, lint_paths, lint_source  # noqa: F401
from repro.analysis.dtypeflow import (  # noqa: F401
    DtypeReport,
    check_dtype_flow,
    widening_events,
)
from repro.analysis.retrace import RetraceReport, audit_retrace  # noqa: F401
