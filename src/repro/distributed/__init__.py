"""Device-parallel building blocks: sharding rules, collectives, pipelining.

For the search engine, this package implements the **sharded corpus gather**
(`repro.core.beam.sharded_greedy_search` is the entry point):

* **Corpus placement** (``sharding.shard_corpus`` / ``sharding.search_mesh``)
  — the corpus is split into contiguous equal row blocks, one per device of
  a 1-D mesh (zero-padded when the device count does not divide N; pad rows
  have global ids >= N, which never appear in an adjacency list and so are
  never gathered or scored). Global row i lives on shard ``i // n_local``.
* **The wave-fanout collective** (``collectives.wave_gather_score``) — each
  plan/commit wave of the batched beam engine is a replicated (B, K) block
  of global candidate ids; every device scores the lanes whose rows it owns
  with the backend-dispatched local gather→score kernel
  (``repro.kernels.resolve_backend``: the ref oracle, the MXU-form
  ``xla_matmul`` path, or the Pallas tile), emitting the psum identity 0.0
  on foreign lanes, and one ``psum`` over the shard axis reconstructs the
  full wave bit-exactly (each id has exactly one owner and x + 0.0 == x).
  The matmul backends' corpus-norm cache (``repro.kernels.CorpusView``)
  shards **with** the corpus blocks — each device holds its rows' f32
  norms as a purely local operand (zero-pad rows carry norm 0), so the
  cache adds nothing to the wave's collective traffic.
  The dedup state follows the dedup backend (see ``repro.core.beam``):

  - the dense scored **bitmap** is column-sharded the same way — lookups
    OR-reduce the owning shard's answer (``collectives.bitmap_lookup``),
    scatters land only on the owner (``collectives.bitmap_scatter``) — at
    (B, N/shards) per device plus one lookup collective per wave;
  - the quota-proportional **sorted set** (``repro.core.beam.ScoredSet``,
    auto-selected for quota-bounded searches) is *replicated like the
    pools*: (B, quota) per device regardless of N and the shard count, and
    its membership ops (``collectives.member_lookup`` /
    ``member_insert`` / ``member_count``) are collective-free — the
    dedup traffic leaves the wave entirely. That is the trade: divided
    O(B·N) state + a per-wave collective, vs replicated O(B·quota) state
    and none.
* **The replicated-pool invariant** — pools, call counters and step
  counters stay replicated: every device runs the identical plan, quota
  mask and merge on identical replicated inputs, so the sharded engine is
  bit-exact vs the single-device engine (pool ids/dists, ``n_calls``, and
  the scored set), and the only cross-device traffic per step is the
  (B, K) wave psum (+ the (B, K) bitmap-lookup reduce under the bitmap
  backend). For merges of *independent per-shard* candidate sets (the
  scatter-gather path in ``repro.core.distributed``),
  ``collectives.gather_topk_merge`` cuts each shard to its top-k before
  the all-gather.

Also here: the model-parallel sharding rules (``sharding``), the ring
collective-matmuls (``collectives``), and GPipe pipelining (``pipeline``).
"""
from repro.distributed import collectives, pipeline, sharding  # noqa: F401
