"""Overlapped collective-matmul primitives (compute/comm overlap).

Ring algorithms via ``ppermute`` that interleave one chunk of matmul with one
chunk of neighbor exchange per step — the "collective matmul" transformation
(Wang et al., ASPLOS'23) that XLA applies automatically in favorable cases
and that we provide explicitly for the TP layers:

* ``allgather_matmul``:  computes  all_gather(x, axis) @ w  without ever
  materializing the gathered x: each ring step multiplies the resident chunk
  while the next chunk is in flight.
* ``matmul_reducescatter``: computes reduce_scatter(x @ w) chunk-by-chunk,
  sending partial sums around the ring.

Used inside shard_map with a named axis; verified numerically against the
dense reference on an 8-device host mesh in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.launch.mesh import axis_size

Array = jax.Array


def allgather_matmul(x: Array, w: Array, axis_name: str) -> Array:
    """x: (m_local, k) shard of a row-sharded M×K; w: (k, n) local weight.

    Returns (m_local * n_dev, n) — the full all_gather(x) @ w, computed by
    rotating shards around the ring and filling the output block that each
    incoming shard corresponds to. One send/recv overlaps one block matmul.
    """
    n_dev = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m_local = x.shape[0]
    out = jnp.zeros((m_local * n_dev, w.shape[1]), w.dtype)
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def body(i, carry):
        out, chunk = carry
        src = (idx - i) % n_dev  # whose shard we currently hold
        block = chunk @ w
        out = lax.dynamic_update_slice(out, block.astype(out.dtype),
                                       (src * m_local, 0))
        chunk = lax.ppermute(chunk, axis_name, perm)
        return out, chunk

    out, _ = lax.fori_loop(0, n_dev, body, (out, x))
    return out


def matmul_reducescatter(x: Array, w: Array, axis_name: str) -> Array:
    """x: (m, k_local) shard of a col-sharded M×K; w: (k_local, n) local shard
    of a row-sharded K×N. Returns the (m/n_dev, n) reduce-scattered product of
    the full x @ w, accumulating partial sums as they travel the ring.
    """
    n_dev = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m = x.shape[0]
    assert m % n_dev == 0, "row count must divide the axis size"
    m_local = m // n_dev
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def block(i):
        # chunk held by this device at ring step i: the accumulator for
        # output chunk c visits device (c + 1 + i) mod n at step i, so the
        # resident chunk here is c = (idx - i - 1) mod n. After n-1 hops the
        # accumulator for chunk idx lands home.
        row = ((idx - i - 1) % n_dev) * m_local
        return lax.dynamic_slice(x, (row, 0), (m_local, x.shape[1])) @ w

    def body(i, acc):
        acc = acc + block(i)
        return lax.ppermute(acc, axis_name, perm)

    # n_dev-1 hops with accumulation, final block added without a hop
    acc = jnp.zeros((m_local, w.shape[1]), jnp.result_type(x.dtype, w.dtype))
    acc = lax.fori_loop(0, n_dev - 1, body, acc)
    acc = acc + block(n_dev - 1)
    return acc
