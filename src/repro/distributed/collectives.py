"""Collective primitives: overlapped matmuls and the sharded-search wave ops.

Two families, both written against a named ``shard_map`` axis and verified on
an 8-device host mesh in tests:

**Ring collective-matmuls** — ``ppermute`` algorithms that interleave one
chunk of matmul with one chunk of neighbor exchange per step (the "collective
matmul" transformation, Wang et al. ASPLOS'23), provided explicitly for the
TP layers:

* ``allgather_matmul``:  computes  all_gather(x, axis) @ w  without ever
  materializing the gathered x: each ring step multiplies the resident chunk
  while the next chunk is in flight.
* ``matmul_reducescatter``: computes reduce_scatter(x @ w) chunk-by-chunk,
  sending partial sums around the ring.

**Sharded-search wave collectives** — the device-parallel form of the batched
beam engine's plan/commit step (``repro.core.beam``). Each device owns a
contiguous corpus block of ``n_local`` rows (global rows
``[idx * n_local, (idx + 1) * n_local)``) and the matching column slice of
every query's scored bitmap; pools stay replicated:

* ``wave_gather_score``: each shard scores the wave lanes it owns with the
  fused local gather→score kernel (foreign/padding lanes emit the psum
  identity 0.0) and a ``psum`` over the shard axis reconstructs the
  replicated (B, K) wave *bit-exactly* — each global id has exactly one
  owner and x + 0.0 == x.
* ``bitmap_lookup`` / ``bitmap_scatter``: membership tests OR-reduce the
  owning shard's answer across the axis; scatters land only on the owning
  shard's local columns. Both stage 1's in-``while_loop`` engine and the
  serving engine's host-driven stage 2 (``repro.core.beam.ShardedStepper``)
  run their bitmap traffic through these two ops.
* ``bitmap_count``: per-query psum popcount of the partitioned bitmap — the
  partition invariant (each bit owned by exactly one shard) makes the psum
  of local counts the exact global count.
* ``member_lookup`` / ``member_insert`` / ``member_count``: the same three
  operations against the **quota-proportional sorted dedup set**
  (``repro.core.beam.ScoredSet``). Unlike the column-sharded bitmap, the
  (B, quota) set is *replicated* like the pools — every device holds the
  identical ascending id rows — so all three are collective-free local ops:
  the per-device dedup state shrinks from (B, N/shards) to (B, quota) and
  the bitmap-lookup psum disappears from the wave entirely. The axis
  argument is accepted (and ignored) so call sites stay backend-agnostic.
* ``gather_topk_merge``: the scatter-gather merge — per-shard top-k cut
  (``ops.local_topk``) before an ``all_gather``, so merge traffic is O(k)
  per query instead of O(n_local).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import backend as kernel_backend
from repro.kernels import ops
from repro.launch.mesh import axis_size

Array = jax.Array


# --------------------------------------------------------------------------
# sharded-search wave collectives
# --------------------------------------------------------------------------
def shard_offset(axis_name: str, n_local: int) -> Array:
    """First global corpus row owned by this device (contiguous placement)."""
    return lax.axis_index(axis_name) * n_local


def wave_gather_score(corpus_local, queries: Array, ids: Array, *,
                      axis_name: str, metric: str = "sqeuclidean",
                      backend=None, use_pallas: bool | None = None,
                      interpret: bool | None = None) -> Array:
    """Device-parallel backend-dispatched gather→score of one wave.

    ``corpus_local`` is this device's corpus block — a raw (n_local, dim)
    array or its :class:`repro.kernels.CorpusView` (the matmul backends'
    norm cache — and a quantized view's per-row scale/zero-point
    metadata — shards with the rows, so it is a purely local operand);
    ``ids`` (B, K) is the replicated wave. Returns the replicated (B, K)
    distances, bit-exact vs the unsharded ``ops.gather_score`` under the
    same backend and residency (ids < 0 -> +inf). ``use_pallas`` /
    ``interpret`` are the deprecated shims for ``backend`` — resolved here
    at the API boundary so only a concrete Backend flows inward.
    """
    be = kernel_backend.resolve_backend(
        backend, use_pallas=use_pallas, interpret=interpret,
        _caller="collectives.wave_gather_score")
    rows = kernel_backend.corpus_rows(corpus_local)
    part = ops.gather_score_local(
        corpus_local, queries, ids,
        shard_offset(axis_name, rows.shape[0]),
        metric=metric, backend=be)
    d = lax.psum(part, axis_name)
    return jnp.where(ids >= 0, d, jnp.inf)


def bitmap_lookup(scored_local: Array, ids: Array, *,
                  axis_name: str) -> Array:
    """Replicated membership test against the shard-column bitmap.

    ``scored_local`` (B, n_local) holds this device's column slice of the
    (B, N) scored bitmap; ``ids`` (B, K) are replicated global ids. Each
    shard answers for the lanes it owns and an OR (psum > 0) replicates the
    result. Lanes with id < 0 return False.
    """
    n_local = scored_local.shape[1]
    loc = ids - shard_offset(axis_name, n_local)
    owned = (ids >= 0) & (loc >= 0) & (loc < n_local)
    hit = jnp.take_along_axis(
        scored_local, jnp.clip(loc, 0, n_local - 1), axis=1) & owned
    return lax.psum(hit.astype(jnp.int32), axis_name) > 0


def bitmap_scatter(scored_local: Array, ids: Array, mark: Array, *,
                   axis_name: str) -> Array:
    """Set bitmap bits for the marked lanes on their owning shard (only).

    The scatter is local — no collective: each device updates the columns it
    owns and ignores foreign lanes, which keeps the (B, N) bitmap exactly
    partitioned across the axis (no bit is ever duplicated or dropped).
    """
    n_local = scored_local.shape[1]
    loc = ids - shard_offset(axis_name, n_local)
    owned = mark & (loc >= 0) & (loc < n_local)
    rows = jnp.arange(ids.shape[0])[:, None]
    # scatter-OR (max): foreign/padding lanes all alias column 0, so a
    # plain set() would race — mirrors repro.core.beam.init_state.
    return scored_local.at[rows, jnp.clip(loc, 0, n_local - 1)].max(owned)


def bitmap_count(scored_local: Array, *, axis_name: str) -> Array:
    """(B,) replicated global popcount of the shard-partitioned bitmap.

    ``scored_local`` (B, n_local) is this device's column slice. Because the
    scatter discipline keeps the global (B, N) bitmap exactly partitioned
    (every bit has one owner — see :func:`bitmap_scatter`), the psum of the
    local row counts *is* the global count; tests use this as the partition
    invariant for the sharded stage-2 drive loop.
    """
    return lax.psum(
        scored_local.sum(axis=1, dtype=jnp.int32), axis_name)


def member_lookup(set_ids: Array, ids: Array, *, axis_name: str) -> Array:
    """Membership test against the replicated sorted dedup set.

    ``set_ids`` (B, C) are the ascending id rows of a
    ``repro.core.beam.ScoredSet`` — replicated across the axis like the
    pools, so the lookup is one local ``searchsorted`` per row with no
    collective at all (compare :func:`bitmap_lookup`'s psum-OR).
    """
    del axis_name  # replicated state: no collective needed
    return ops.sorted_set_lookup(set_ids, ids)


def member_insert(set_ids: Array, ids: Array, mark: Array, *,
                  axis_name: str) -> Array:
    """Insert the marked lanes' ids into the replicated sorted set.

    Every device performs the identical merge on identical replicated
    inputs, which *is* the replication invariant — the sorted-set analogue
    of :func:`bitmap_scatter`'s owner-only discipline.
    """
    del axis_name
    return ops.sorted_set_merge(
        set_ids, jnp.where(mark, ids, ops.SET_PAD))


def member_count(set_ids: Array, *, axis_name: str) -> Array:
    """(B,) distinct scored ids in the replicated set — the exact number
    :func:`bitmap_count` psums out of the partitioned bitmap, computed
    locally (duplicate slots from the E=1 duplicate-lane quirk collapse).
    """
    del axis_name
    return ops.sorted_set_unique_count(set_ids)


def gather_topk_merge(ids_local: Array, dists_local: Array, k: int, *,
                      axis_name: str) -> tuple[Array, Array]:
    """Per-shard top-k cut, then all-gather + merge into a global top-k.

    ``ids_local`` / ``dists_local`` (B, P) are each shard's candidates with
    *global* ids (+inf-padded). Each shard keeps only its k best before the
    collective, so the gather moves (S, B, k) instead of (S, B, P). Ties
    across shards resolve to the lower shard index (the all-gather is
    axis-ordered and the final cut is a stable top-k). Pools narrower than
    ``k`` are padded to width k with (-1, +inf) sentinels by the cut itself.
    """
    lids, ld = ops.local_topk(ids_local, dists_local, k)
    all_ids = lax.all_gather(lids, axis_name)  # (S, B, k)
    all_d = lax.all_gather(ld, axis_name)
    all_ids = jnp.moveaxis(all_ids, 0, 1).reshape(ids_local.shape[0], -1)
    all_d = jnp.moveaxis(all_d, 0, 1).reshape(ids_local.shape[0], -1)
    return ops.local_topk(all_ids, all_d, k)


def allgather_matmul(x: Array, w: Array, axis_name: str) -> Array:
    """x: (m_local, k) shard of a row-sharded M×K; w: (k, n) local weight.

    Returns (m_local * n_dev, n) — the full all_gather(x) @ w, computed by
    rotating shards around the ring and filling the output block that each
    incoming shard corresponds to. One send/recv overlaps one block matmul.
    """
    n_dev = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m_local = x.shape[0]
    out = jnp.zeros((m_local * n_dev, w.shape[1]), w.dtype)
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def body(i, carry):
        out, chunk = carry
        src = (idx - i) % n_dev  # whose shard we currently hold
        block = chunk @ w
        out = lax.dynamic_update_slice(out, block.astype(out.dtype),
                                       (src * m_local, 0))
        chunk = lax.ppermute(chunk, axis_name, perm)
        return out, chunk

    out, _ = lax.fori_loop(0, n_dev, body, (out, x))
    return out


def matmul_reducescatter(x: Array, w: Array, axis_name: str) -> Array:
    """x: (m, k_local) shard of a col-sharded M×K; w: (k_local, n) local shard
    of a row-sharded K×N. Returns the (m/n_dev, n) reduce-scattered product of
    the full x @ w, accumulating partial sums as they travel the ring.
    """
    n_dev = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    m = x.shape[0]
    assert m % n_dev == 0, "row count must divide the axis size"
    m_local = m // n_dev
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def block(i):
        # chunk held by this device at ring step i: the accumulator for
        # output chunk c visits device (c + 1 + i) mod n at step i, so the
        # resident chunk here is c = (idx - i - 1) mod n. After n-1 hops the
        # accumulator for chunk idx lands home.
        row = ((idx - i - 1) % n_dev) * m_local
        return lax.dynamic_slice(x, (row, 0), (m_local, x.shape[1])) @ w

    def body(i, acc):
        acc = acc + block(i)
        return lax.ppermute(acc, axis_name, perm)

    # n_dev-1 hops with accumulation, final block added without a hop
    acc = jnp.zeros((m_local, w.shape[1]), jnp.result_type(x.dtype, w.dtype))
    acc = lax.fori_loop(0, n_dev - 1, body, acc)
    acc = acc + block(n_dev - 1)
    return acc
