"""Sharding rules: param/batch pytrees -> PartitionSpecs per arch family.

Axis roles (names must exist in the mesh):
* ``tp``   — tensor/expert parallel axis ("model");
* ``fsdp`` — parameter-sharding data axes ("data", and "pod" when present):
  every ≥2-D weight is sharded over *both* tp and fsdp (ZeRO-3-equivalent),
  optimizer states included;
* batch axes — activations are batch-sharded over ("pod","data").

Rules are name+shape driven so the same engine covers dense LMs, MLA, MoE
(EP when n_experts divides tp, intra-expert TP otherwise), GNN (replicated
weights, node/edge-sharded data) and recsys (row-sharded tables).

Also home to the **search-corpus placement** used by the sharded beam engine
(``repro.core.beam.sharded_greedy_search``): ``shard_corpus`` splits the
corpus into contiguous equal blocks (zero-padded when the row count does not
divide), ``search_mesh`` builds the 1-D device mesh the engine's
``shard_map`` program runs over.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

SEARCH_AXIS = "shard"  # default mesh axis name for the sharded beam engine


def shard_corpus(corpus: jax.Array, n_shards: int) -> tuple[jax.Array, int]:
    """Contiguous-block corpus placement for the sharded search engine.

    (N, dim) -> ((S, n_local, dim), n_local) with zero-row padding when
    ``n_shards`` does not divide N. Global row i lives on shard
    ``i // n_local`` at local row ``i % n_local``; pad rows sit at global
    ids >= N, which never appear in an adjacency list, so they are never
    gathered, scored, or marked in the bitmap.
    """
    n, dim = corpus.shape
    n_local = -(-n // n_shards)
    pad = n_shards * n_local - n
    if pad:
        corpus = jnp.concatenate(
            [corpus, jnp.zeros((pad, dim), corpus.dtype)])
    return corpus.reshape(n_shards, n_local, dim), n_local


def shard_corpus_view(corpus, n_shards: int, *, quantize: str | None = None):
    """Contiguous-block placement of a full CorpusView (rows + metadata).

    ``corpus`` is a raw (N, dim) array or a prebuilt
    ``repro.kernels.CorpusView`` (possibly quantized). Returns
    ``(rows, sq, inv, scales, zero_points, n_local)`` stacks shaped
    (S, n_local, dim) / (S, n_local): the per-row dequant metadata shards
    **with** the corpus blocks — same placement, nothing enters the wave
    psum. ``scales`` / ``zero_points`` are zero-width (S, 0) stacks when
    the view has no such field (raw residency, or the symmetric fp8 modes
    for ``zero_points``) so ``shard_map`` operand arity stays fixed.

    Pad rows stay inert in every residency: a raw array is zero-padded
    *before* quantization (zero rows quantize to codes that dequantize to
    exact zeros), and a prebuilt quantized view is padded with
    code 0 / scale 1 / zero-point 0, which also dequantizes to exact
    zeros — norm 0, finite inverse norm, cosine 1.0, like every pad row.
    """
    from repro.kernels.backend import NORM_EPS, CorpusView, as_corpus_view

    if isinstance(corpus, CorpusView):
        view = as_corpus_view(corpus, quantize=quantize)  # validates mode
        n, dim = view.rows.shape
        n_local = -(-n // n_shards)
        pad = n_shards * n_local - n
        rows = jnp.concatenate(
            [view.rows, jnp.zeros((pad, dim), view.rows.dtype)])
        sq = jnp.concatenate([view.sq_norms, jnp.zeros(pad, jnp.float32)])
        inv = jnp.concatenate(
            [view.inv_norms,
             jnp.full(pad, jax.lax.rsqrt(jnp.float32(NORM_EPS)))])
        scales = view.scales
        if scales is not None:
            scales = jnp.concatenate([scales, jnp.ones(pad, jnp.float32)])
        zps = view.zero_points
        if zps is not None:
            zps = jnp.concatenate([zps, jnp.zeros(pad, jnp.float32)])
    else:
        stacked, n_local = shard_corpus(corpus, n_shards)
        flat = stacked.reshape(n_shards * n_local, corpus.shape[1])
        view = as_corpus_view(flat, quantize=quantize)
        rows, sq, inv = view.rows, view.sq_norms, view.inv_norms
        scales, zps = view.scales, view.zero_points

    def stack(a):
        if a is None:
            return jnp.zeros((n_shards, 0), jnp.float32)
        return a.reshape(n_shards, n_local, *a.shape[1:])

    return (stack(rows), stack(sq), stack(inv), stack(scales), stack(zps),
            n_local)


def search_mesh(n_shards: int, axis_name: str = SEARCH_AXIS) -> Mesh:
    """1-D mesh over the first ``n_shards`` local devices."""
    from repro.launch.mesh import axis_types_kw

    devices = jax.devices()
    if len(devices) < n_shards:
        raise ValueError(
            f"shards={n_shards} needs {n_shards} devices, have {len(devices)}"
            " (force host devices with"
            " XLA_FLAGS=--xla_force_host_platform_device_count=K)")
    return jax.make_mesh((n_shards,), (axis_name,),
                         devices=devices[:n_shards], **axis_types_kw(1))

# ZeRO stage for LM params: 3 = params FSDP+TP sharded (default);
# 1 = params TP-only (replicated over data; optimizer state stays FSDP
# sharded) — trades one param all-gather per *step* for the per-layer
# fwd/bwd weight gathers. Flipped by the perf harness.
ZERO_STAGE = 3

# weight name -> role
_IN_OUT = {  # (d_in, d_out) matrices: shard d_in over fsdp, d_out over tp
    "wq", "wk", "wv", "w_gate", "w_up", "q_a", "q_b", "kv_a", "k_b", "v_b",
    "proj", "embed_head",
}
_OUT_IN = {"wo", "w_down"}  # (d_in_tp_product, d_out): tp on axis 0
_TABLES = {"embed", "item_emb", "pos_emb", "table", "linear"}  # (vocab, d)
_REPL = {"router", "bias", "cin_out"}


def _path_keys(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(f"[{p.idx}]")
    return out


def _divisible(dim: int, axes: tuple[str, ...] | str | None, mesh: Mesh) -> bool:
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else axes
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % size == 0


def _maybe(dim: int, axes, mesh) -> Any:
    """Use the axes only if they divide the dim (else replicate that dim)."""
    return axes if _divisible(dim, axes, mesh) else None


def lm_param_specs(params: Pytree, mesh: Mesh, *, tp: str = "model",
                   fsdp: tuple[str, ...] = ("data",)) -> Pytree:
    """PartitionSpec pytree matching ``params`` (arrays or ShapeDtypeStructs)."""
    fsdp_t = tuple(fsdp)

    def spec_for(path, leaf) -> P:
        keys = _path_keys(path)
        name = keys[-1]
        shape = leaf.shape
        # optimizer moment scale tensors: shaped like the param with the last
        # dim reduced by the quant block — same spec, last axis replicated.
        scanned = any("blocks" in k for k in keys)
        lead = (None,) if scanned else ()
        body = shape[1:] if scanned else shape

        def build(*ax):
            ax = ax[: len(body)] + (None,) * (len(body) - len(ax))
            fixed = tuple(
                a if _divisible(d, a, mesh) else None for a, d in zip(ax, body)
            )
            return P(*(lead + fixed))

        if name in _REPL or len(body) <= 1:
            return P(*((None,) * len(shape)))
        if name in _TABLES:
            return build(tp, fsdp_t)
        if len(body) == 3 and name in ("w_gate", "w_up", "w_down"):
            # MoE expert stacks (E, a, b): EP over tp when divisible,
            # otherwise shard the wide ffn dim over tp.
            e = body[0]
            if e % mesh.shape[tp] == 0:
                if name == "w_down":
                    return build(tp, None, fsdp_t)
                return build(tp, fsdp_t, None)
            if name == "w_down":
                return build(None, tp, fsdp_t)
            return build(None, fsdp_t, tp)
        if name in _OUT_IN:
            return build(tp, fsdp_t)
        if name in _IN_OUT:
            return build(fsdp_t, tp)
        # default for unknown 2-D weights (recsys mlp "ws" lists etc.)
        if len(body) == 2:
            return build(fsdp_t, tp)
        return P(*((None,) * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, params)


def replicated_specs(tree: Pytree) -> Pytree:
    return jax.tree.map(lambda l: P(*((None,) * len(l.shape))), tree)


def opt_state_specs(param_specs: Pytree, opt_state, params) -> Any:
    """AdamWState sharding: master/m/v follow the param spec; quantized moment
    scales get the param spec with the last axis replicated."""
    from repro.train.optimizer import AdamWState

    def moment_spec(ps: P, mm) -> Any:
        if isinstance(mm, dict):  # quantized {"q","scale"}
            scale_spec = P(*ps[:-1], None) if len(ps) else P()
            return {"q": ps, "scale": scale_spec}
        return ps

    flat_ps, treedef = jax.tree.flatten(param_specs,
                                        is_leaf=lambda x: isinstance(x, P))
    flat_m = treedef.flatten_up_to(opt_state.m)
    flat_v = treedef.flatten_up_to(opt_state.v)
    m_specs = treedef.unflatten([moment_spec(ps, mm) for ps, mm in zip(flat_ps, flat_m)])
    v_specs = treedef.unflatten([moment_spec(ps, vv) for ps, vv in zip(flat_ps, flat_v)])
    return AdamWState(step=P(), master=param_specs, m=m_specs, v=v_specs)


def to_named(specs: Pytree, mesh: Mesh) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes present in this mesh (pod first if any)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def all_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.shape)


# --------------------------------------------------------------------------
# activation sharding constraints
#
# GSPMD propagation alone picks contraction-sharded matmuls against FSDP
# weights (replicating activations over the batch axes — catastrophic).
# Model code calls ``constrain_batch`` at layer boundaries; launchers opt in
# by installing the mesh here. When no mesh is installed (CPU tests) the
# calls are no-ops.
# --------------------------------------------------------------------------
_ACT_CTX: dict = {"mesh": None, "dp": ()}


class activation_mesh:
    """Context manager: install the mesh used for activation constraints."""

    def __init__(self, mesh: Mesh | None, dp: tuple[str, ...] = ()):
        self.new = (mesh, tuple(dp) or (batch_axes(mesh) if mesh else ()))

    def __enter__(self):
        self.old = (_ACT_CTX["mesh"], _ACT_CTX["dp"])
        _ACT_CTX["mesh"], _ACT_CTX["dp"] = self.new
        return self

    def __exit__(self, *exc):
        _ACT_CTX["mesh"], _ACT_CTX["dp"] = self.old
        return False


def constrain(x, spec: P):
    """with_sharding_constraint against the installed mesh (no-op if none)."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_batch(x, *, batch_dim: int = 0):
    """Pin dim ``batch_dim`` to the data-parallel axes (if they divide it)."""
    mesh = _ACT_CTX["mesh"]
    dp = _ACT_CTX["dp"]
    if mesh is None or not dp:
        return x
    total = int(np.prod([mesh.shape[a] for a in dp]))
    if x.shape[batch_dim] % total != 0:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_axis(x, dim: int, axes: tuple[str, ...] = ("model",)):
    """Pin dim ``dim`` of ``x`` to the given mesh axes. A sharding constraint
    is *total* (None = replicated), so when ``dim != 0`` the leading batch
    dim is co-pinned to the dp axes (if they divide it) — otherwise the
    constraint would silently force batch replication."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None:
        return x
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return x
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if x.shape[dim] % total != 0:
        return x
    spec = [None] * x.ndim
    spec[dim] = axes if len(axes) > 1 else axes[0]
    dp = tuple(a for a in _ACT_CTX["dp"] if a not in axes)
    if dim != 0 and dp:
        dp_total = int(np.prod([mesh.shape[a] for a in dp]))
        if x.shape[0] % dp_total == 0:
            spec[0] = dp if len(dp) > 1 else dp[0]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_moe_buf(x, expert_parallel: bool):
    """(G, E, C, d) grouped dispatch buffers: G→dp axes; E→"model" when EP;
    otherwise C→"model" (intra-expert-TP archs whose E doesn't divide)."""
    mesh = _ACT_CTX["mesh"]
    dp = _ACT_CTX["dp"]
    if mesh is None:
        return x
    g, e, c = x.shape[0], x.shape[1], x.shape[2]
    spec = [None] * x.ndim
    if dp:
        total = int(np.prod([mesh.shape[a] for a in dp]))
        if g % total == 0:
            spec[0] = dp if len(dp) > 1 else dp[0]
    if "model" in mesh.shape:
        m = mesh.shape["model"]
        if expert_parallel and e % m == 0:
            spec[1] = "model"
        elif c % m == 0:
            spec[2] = "model"
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_seq(x, *, batch_dim: int = 0, seq_dim: int = 1,
                  seq_axes: tuple[str, ...] = ("model",)):
    """Megatron-style sequence parallelism for the residual stream: batch on
    the dp axes AND sequence on ``seq_axes``. This is what keeps the
    per-layer activation stash (the remat carry) sharded 256-ways instead of
    16-ways — see EXPERIMENTS.md §Perf."""
    mesh = _ACT_CTX["mesh"]
    dp = _ACT_CTX["dp"]
    if mesh is None:
        return x
    spec = [None] * x.ndim
    if dp:
        total = int(np.prod([mesh.shape[a] for a in dp]))
        if x.shape[batch_dim] % total == 0:
            spec[batch_dim] = dp if len(dp) > 1 else dp[0]
    axes = tuple(a for a in seq_axes if a in mesh.shape)
    if axes:
        total = int(np.prod([mesh.shape[a] for a in axes]))
        if x.shape[seq_dim] % total == 0:
            spec[seq_dim] = axes if len(axes) > 1 else axes[0]
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
